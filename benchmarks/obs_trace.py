"""Observability bench: exercise the telemetry layer end to end.

Two legs, both gated (``passed`` folds every check into the exit code):

  solver leg  (numpy-only — runs in the minimal smoke environment)
      Solve one small CMVM twice, tracing disabled then enabled, and
      assert (a) bit-identity — tracing must never perturb solver
      decisions; (b) the enabled run produced spans on the expected
      names (``solver.solve_cmvm``, ``cse.*``); (c) the Chrome-trace
      export is schema-valid (every ``X`` event carries
      name/ph/ts/dur/pid/tid, thread-name ``M`` metadata present);
      (d) the process metrics registry renders parseable Prometheus
      text containing the ``cse_*`` counter families; (e) the solve
      log ring captured structured records for both solves.

  serve leg   (needs jax; skipped automatically when absent or with
      ``--no-serve``)
      ``Flow.compile`` a 2-layer model and serve a short burst under
      tracing, then assert the merged trace spans at least three
      threads (main + solve pool + dispatcher shards), the flight
      recorder holds per-request records with full 5-stage breakdowns,
      and ``Deployment.metrics_text()`` is parseable Prometheus
      covering the serve families.

``--json PATH`` writes the result dict; the trace document and the
Prometheus text land next to it as ``PATH-trace.json`` /
``PATH-metrics.prom`` (the per-SHA CI artifacts).  Exit code 1 on any
failed check.  No committed baseline: every check is deterministic or
self-relative, so there is no trajectory to track.
"""

from __future__ import annotations

import json
import re
import sys
import time

import numpy as np

# `name{labels} value` or `name value` — the subset of the Prometheus
# text exposition format our renderer emits (one sample per line)
_PROM_SAMPLE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? [^ ]+$"
)

_REQUIRED_X_KEYS = ("name", "ph", "ts", "dur", "pid", "tid")


def _validate_trace_doc(doc: dict) -> dict:
    """Schema checks a Perfetto/chrome://tracing load would require."""
    events = doc.get("traceEvents", [])
    xs = [e for e in events if e.get("ph") == "X"]
    ms = [e for e in events if e.get("ph") == "M"]
    x_ok = bool(xs) and all(all(k in e for k in _REQUIRED_X_KEYS) for e in xs)
    ts_ok = all(
        isinstance(e["ts"], (int, float)) and isinstance(e["dur"], (int, float))
        for e in xs
    )
    return {
        "n_events": len(events),
        "n_spans": len(xs),
        "n_threads": len({e["tid"] for e in xs}),
        "span_names": sorted({e["name"] for e in xs}),
        "schema_ok": bool(x_ok and ts_ok and ms),
    }


def _validate_prometheus(text: str, required: tuple) -> dict:
    """Line-format check + presence of the required metric families."""
    lines = [ln for ln in text.splitlines() if ln.strip()]
    samples = [ln for ln in lines if not ln.startswith("#")]
    fmt_ok = bool(samples) and all(_PROM_SAMPLE.match(ln) for ln in samples)
    names = {ln.split("{")[0].split(" ")[0] for ln in samples}
    missing = [r for r in required if not any(n.startswith(r) for n in names)]
    return {
        "n_samples": len(samples),
        "format_ok": fmt_ok,
        "missing_families": missing,
        "ok": bool(fmt_ok and not missing),
    }


def _solver_leg(m: int = 24, bw: int = 8, seed: int = 0) -> dict:
    from repro.core import solve_cmvm
    from repro.flow import SolverConfig
    from repro.obs import solvelog, trace
    from repro.obs.metrics import get_registry

    mat = np.random.default_rng(seed).integers(
        -(2 ** (bw - 1)), 2 ** (bw - 1), size=(m, m)
    )
    cfg = SolverConfig(dc=2, engine="arena")
    was = trace.enabled()
    reg = get_registry()
    try:
        trace.set_enabled(False)
        trace.reset()
        solvelog.reset()
        reg.reset()
        t0 = time.perf_counter()
        ref = solve_cmvm(mat, config=cfg)
        disabled_s = time.perf_counter() - t0
        n_events_disabled = trace.n_events()

        trace.set_enabled(True)
        trace.reset()
        t0 = time.perf_counter()
        sol = solve_cmvm(mat, config=cfg)
        enabled_s = time.perf_counter() - t0
        doc = trace.export()
    finally:
        trace.set_enabled(was)
        trace.reset()

    tr = _validate_trace_doc(doc)
    prom = _validate_prometheus(
        reg.to_prometheus(), ("cse_runs_total", "cse_patterns_implemented_total")
    )
    logs = solvelog.records()
    expected = {"solver.solve_cmvm", "cse.pair_build", "cse.select"}
    return {
        "m": m,
        "identical": (sol.n_adders, sol.cost_bits)
        == (ref.n_adders, ref.cost_bits),
        "disabled_s": disabled_s,
        "enabled_s": enabled_s,
        "noop_clean": n_events_disabled == 0,
        "spans_expected": sorted(expected - set(tr["span_names"])) == [],
        "trace": tr,
        "prometheus": prom,
        "n_solve_logs": len(logs),
        "solve_logs_ok": (
            len(logs) >= 2
            and all(r.get("adders") == ref.n_adders for r in logs[-2:])
        ),
        "ok": bool(
            (sol.n_adders, sol.cost_bits) == (ref.n_adders, ref.cost_bits)
            and n_events_disabled == 0
            and not (expected - set(tr["span_names"]))
            and tr["schema_ok"]
            and prom["ok"]
            and len(logs) >= 2
        ),
        "_doc": doc,
        "_metrics_text": reg.to_prometheus(),
    }


def _serve_leg(m: int = 16, seed: int = 0, n_requests: int = 64) -> dict:
    import jax

    from repro.flow import CompileConfig, Flow, ServeConfig, SolverConfig
    from repro.nn import QDense, QuantConfig, init_params
    from repro.obs import trace

    wq = QuantConfig(6, 2, signed=True)
    model = (QDense(m, wq), QDense(m, wq))
    in_shape = (m,)
    in_quant = QuantConfig(8, 4, signed=True)
    params, _ = init_params(jax.random.PRNGKey(seed), model, in_shape)

    was = trace.enabled()
    try:
        trace.set_enabled(True)
        trace.reset()
        design = Flow.compile(
            model, params, in_shape, in_quant,
            config=CompileConfig(solver=SolverConfig(dc=2)),
        )
        dep = Flow.serve(ServeConfig(max_batch=32, max_wait_us=100.0, shards=2))
        dep.register("obs", design)
        dep.warmup("obs")
        try:
            rng = np.random.default_rng(seed + 1)
            q = in_quant.qint
            xs = [
                np.asarray(rng.integers(q.lo, q.hi + 1, size=in_shape), np.int32)
                for _ in range(n_requests)
            ]
            for f in [dep.submit("obs", x) for x in xs]:
                f.result(30)
            stats = dep.stats("obs")
            metrics_text = dep.metrics_text()
        finally:
            dep.shutdown()
        doc = trace.export()
    finally:
        trace.set_enabled(was)
        trace.reset()

    tr = _validate_trace_doc(doc)
    prom = _validate_prometheus(
        metrics_text,
        ("serve_requests_total", "serve_batches_total", "serve_stage_us"),
    )
    flight = stats["flight"]
    slowest = flight.get("slowest", [])
    flight_ok = bool(
        flight["n_records"] >= n_requests
        and slowest
        and all(len(s["stages_us"]) == 5 for s in slowest)
    )
    per_layer = design.solver_stats.get("per_layer", {})
    serve_spans = {"compile.plan", "compile.solve_phase", "serve.batch"}
    return {
        "m": m,
        "n_requests": n_requests,
        "n_flight_records": flight["n_records"],
        "slowest_lat_us": slowest[0]["lat_us"] if slowest else None,
        "per_layer_names": sorted(per_layer),
        "trace": tr,
        "prometheus": prom,
        "flight_ok": flight_ok,
        "spans_expected": sorted(serve_spans - set(tr["span_names"])) == [],
        "ok": bool(
            tr["schema_ok"]
            and tr["n_threads"] >= 3  # main + solve pool + dispatcher(s)
            and not (serve_spans - set(tr["span_names"]))
            and prom["ok"]
            and flight_ok
            and len(per_layer) == 2
        ),
        "_doc": doc,
        "_metrics_text": metrics_text,
    }


def run(serve: bool | None = None, seed: int = 0) -> dict:
    if serve is None:
        try:
            import jax  # noqa: F401

            serve = True
        except ImportError:
            serve = False
    solver = _solver_leg(seed=seed)
    result = {
        "bench": "obs_trace",
        "solver": solver,
        "serve": _serve_leg(seed=seed) if serve else None,
        "serve_skipped": not serve,
    }
    result["ok"] = bool(
        solver["ok"] and (result["serve"] is None or result["serve"]["ok"])
    )
    return result


def passed(r: dict) -> bool:
    return bool(r["ok"])


def _pop_private(leg: dict | None):
    if not leg:
        return None, None
    return leg.pop("_doc", None), leg.pop("_metrics_text", None)


def main(csv: bool = True, json_path=None, serve: bool | None = None) -> dict:
    r = run(serve=serve)
    # side artifacts: prefer the serve leg's richer trace when it ran
    rich = r["serve"] or r["solver"]
    doc, metrics_text = rich.get("_doc"), rich.get("_metrics_text")
    for leg in (r["solver"], r["serve"]):
        _pop_private(leg)
    if csv:
        s = r["solver"]
        print("name,us_per_call,derived")
        print(
            f"obs_trace_solver,{s['enabled_s']*1e6:.0f},"
            f"identical={int(s['identical'])};noop_clean={int(s['noop_clean'])};"
            f"spans={s['trace']['n_spans']};schema_ok={int(s['trace']['schema_ok'])};"
            f"prom_ok={int(s['prometheus']['ok'])};solve_logs={s['n_solve_logs']}"
        )
        v = r["serve"]
        if v:
            print(
                f"obs_trace_serve,{v['slowest_lat_us'] or 0:.0f},"
                f"threads={v['trace']['n_threads']};spans={v['trace']['n_spans']};"
                f"flight_records={v['n_flight_records']};"
                f"flight_ok={int(v['flight_ok'])};"
                f"prom_ok={int(v['prometheus']['ok'])};"
                f"per_layer={','.join(v['per_layer_names'])}"
            )
        else:
            print("obs_trace_serve,0,skipped=1 (jax unavailable)")
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(r, fh, indent=2, sort_keys=True)
        print(f"# wrote {json_path}", file=sys.stderr)
        base = json_path.rsplit(".json", 1)[0]
        if doc is not None:
            with open(base + "-trace.json", "w") as fh:
                json.dump(doc, fh)
            print(f"# wrote {base}-trace.json", file=sys.stderr)
        if metrics_text is not None:
            with open(base + "-metrics.prom", "w") as fh:
                fh.write(metrics_text)
            print(f"# wrote {base}-metrics.prom", file=sys.stderr)
    return r


if __name__ == "__main__":
    args = sys.argv[1:]
    json_path = None
    serve = None
    if "--json" in args:
        k = args.index("--json")
        json_path = args[k + 1]
        del args[k : k + 2]
    if "--no-serve" in args:
        args.remove("--no-serve")
        serve = False
    result = main(json_path=json_path, serve=serve)
    sys.exit(0 if passed(result) else 1)
