"""Paper Table 2: da4ml vs H_cmvm on random 8-bit matrices.

Reproduces adder count, adder depth and solver CPU time for m x m
matrices (m = 2..16), dc in {-1, 0, 2}, sampling entries uniformly from
[2^(bw-1)+1, 2^bw - 1] (the convention of [4]).  Paper reference values
are embedded for a side-by-side delta.
"""

from __future__ import annotations

import concurrent.futures
import time

import numpy as np

from repro.core import QInterval, SolutionCache, naive_adder_tree, solve_cmvm
from repro.flow import SolverConfig
from repro.core.solver import solve_task

# (m, dc) -> (paper_depth, paper_adders) from Table 2 (da4ml columns)
PAPER = {
    (2, -1): (3.3, 8.7), (4, -1): (6.1, 29.3), (6, -1): (8.4, 59.0),
    (8, -1): (9.4, 98.0), (10, -1): (10.8, 146.6), (12, -1): (11.6, 203.6),
    (14, -1): (12.3, 269.3), (16, -1): (13.0, 343.4),
    (2, 0): (3.1, 9.9), (4, 0): (4.1, 37.0), (6, 0): (5.0, 77.8),
    (8, 0): (5.1, 130.9), (10, 0): (6.0, 195.6), (12, 0): (6.0, 271.8),
    (14, 0): (6.0, 358.5), (16, 0): (6.0, 456.0),
    (2, 2): (3.3, 8.7), (4, 2): (5.9, 30.0), (6, 2): (6.7, 62.6),
    (8, 2): (7.0, 102.3), (10, 2): (7.8, 152.8), (12, 2): (8.0, 214.9),
    (14, 2): (8.0, 279.2), (16, 2): (8.0, 358.7),
}


def run(sizes=(2, 4, 8, 12, 16), dcs=(-1, 0, 2), n_trials=3, bw=8, seed=0,
        engine="batch"):
    rng = np.random.default_rng(seed)
    rows = []
    for m in sizes:
        mats = [
            rng.integers(2 ** (bw - 1) + 1, 2**bw, size=(m, m))
            for _ in range(n_trials)
        ]
        base = np.mean([naive_adder_tree(mat).n_adders for mat in mats])
        for dc in dcs:
            cfg = SolverConfig(dc=dc, engine=engine)
            adders, depths, times = [], [], []
            for mat in mats:
                t0 = time.perf_counter()
                sol = solve_cmvm(mat, config=cfg)
                times.append(time.perf_counter() - t0)
                assert sol.verify(), "bit-exactness violated"
                adders.append(sol.n_adders)
                depths.append(sol.depth)
            p_depth, p_adders = PAPER.get((m, dc), (float("nan"), float("nan")))
            rows.append(
                {
                    "m": m,
                    "dc": dc,
                    "adders": float(np.mean(adders)),
                    "paper_adders": p_adders,
                    "depth": float(np.mean(depths)),
                    "paper_depth": p_depth,
                    "cpu_ms": float(np.mean(times) * 1e3),
                    "baseline_adders": float(base),
                }
            )
    return rows


def solve_wall(m=16, dc=2, n_mats=8, bw=8, seed=1, jobs=1, cache=None,
               engine="batch"):
    """Wall-clock to solve ``n_mats`` independent matrices — the unit of
    work a model compile farms out per layer (see compile_model jobs=)."""
    rng = np.random.default_rng(seed)
    qin = [QInterval.from_fixed(True, 8, 8)] * m
    cfg = SolverConfig(dc=dc, engine=engine)
    payloads = [
        (rng.integers(2 ** (bw - 1) + 1, 2**bw, size=(m, m)), qin, "da",
         cfg.to_dict())
        for _ in range(n_mats)
    ]
    t0 = time.perf_counter()
    if cache is not None:
        sols = [solve_cmvm(p[0], config=cfg, cache=cache) for p in payloads]
    elif jobs > 1:
        # same GIL-releasing thread pool as compile_model's solve phase
        # (no fork/spawn startup, no payload pickling)
        with concurrent.futures.ThreadPoolExecutor(jobs) as ex:
            sols = list(ex.map(solve_task, payloads))
    else:
        sols = [solve_task(p) for p in payloads]
    wall = time.perf_counter() - t0
    assert all(s.verify() for s in sols)
    return wall


def main(csv=True):
    rows = run()
    if csv:
        print("name,us_per_call,derived")
        for r in rows:
            name = f"table2_m{r['m']}_dc{r['dc']}"
            ratio = r["adders"] / r["paper_adders"] if r["paper_adders"] == r["paper_adders"] else 0
            print(
                f"{name},{r['cpu_ms']*1e3:.0f},"
                f"adders={r['adders']:.1f};paper={r['paper_adders']};"
                f"ratio={ratio:.3f};depth={r['depth']:.1f};paperdepth={r['paper_depth']};"
                f"baseline={r['baseline_adders']:.0f}"
            )
        # fast-path wiring: pool + content-addressed cache over one batch
        import os

        jobs = min(os.cpu_count() or 1, 4)
        t_serial = solve_wall(jobs=1)
        t_par = solve_wall(jobs=jobs)
        t_arena = solve_wall(jobs=1, engine="arena")
        cache = SolutionCache()
        solve_wall(cache=cache)  # populate
        t_cached = solve_wall(cache=cache)
        print(f"table2_solve_wall_serial,{t_serial*1e6:.0f},n_mats=8;m=16;dc=2")
        print(
            f"table2_solve_wall_jobs{jobs},{t_par*1e6:.0f},"
            f"speedup={t_serial/max(t_par,1e-9):.2f}x"
        )
        print(
            f"table2_solve_wall_arena,{t_arena*1e6:.0f},"
            f"speedup={t_serial/max(t_arena,1e-9):.2f}x"
        )
        print(
            f"table2_solve_wall_cached,{t_cached*1e6:.0f},"
            f"speedup={t_serial/max(t_cached,1e-9):.0f}x"
        )
    return rows


if __name__ == "__main__":
    main()
