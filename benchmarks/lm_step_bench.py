"""LM-framework microbench: wall-clock train/decode steps on the smoke
configs (CPU) — catches performance regressions in the substrate and
exercises the full train_step/serve path end to end."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import RunConfig
from repro.models import decode_step, init_params
from repro.models.transformer import prefill
from repro.train.train_lib import make_train_step


def _time(fn, *args, n=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def run(archs=("smollm-135m", "qwen3-moe-30b-a3b", "falcon-mamba-7b", "jamba-v0.1-52b")):
    rows = []
    for name in archs:
        cfg = configs.get_smoke(name)
        params = init_params(cfg, jax.random.PRNGKey(0))
        key = jax.random.PRNGKey(1)
        batch = {
            "tokens": jax.random.randint(key, (4, 64), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (4, 64), 0, cfg.vocab_size),
        }
        step_fn, opt_init = make_train_step(cfg, RunConfig(master_dtype=None))
        jitted = jax.jit(step_fn)
        opt = opt_init(params)
        t_train = _time(lambda p, o, b: jitted(p, o, b, 0)[2]["loss"], params, opt, batch)

        lg, cache = jax.jit(lambda p, b: prefill(cfg, p, b, 96))(params, batch)
        dec = jax.jit(lambda p, t, c: decode_step(cfg, p, t, c))
        tok = jnp.argmax(lg, -1)[:, None]
        t_dec = _time(lambda p, t, c: dec(p, t, c)[0], params, tok, cache)
        rows.append({"arch": name, "train_step_ms": t_train * 1e3, "decode_ms": t_dec * 1e3})
    return rows


def main(csv=True):
    rows = run()
    if csv:
        print("name,us_per_call,derived")
        for r in rows:
            print(f"lm_train_{r['arch']},{r['train_step_ms']*1e3:.0f},decode_ms={r['decode_ms']:.2f}")
    return rows


if __name__ == "__main__":
    main()
