"""Perf-trajectory gate: compare a fresh ``solver_smoke`` JSON against
the committed baseline (``BENCH_solver.json`` at the repo root).

Two classes of check:

  * **deterministic** — adder and cost-bit counts per (size, engine)
    must match the baseline exactly.  The solver is a pure function of
    its inputs, so any drift here is an algorithmic change and fails
    regardless of tolerances.
  * **timing** — per (size, engine) solve time must stay within
    ``(1 + tolerance)`` of the baseline (default 20%, the regression
    budget from the PR 5 issue), except under ``floor_s`` where
    shared-runner noise dominates signal.  CPU seconds
    (``cpu_seconds``, steal-immune) are compared when both sides carry
    them, wall seconds otherwise.  Machines still differ; the committed
    baseline records the dev container, so CI passes a wider
    ``--floor-s`` and relies on the deterministic checks plus its own
    archived artifact series for cross-push trends.

Usage::

    python -m benchmarks.perf_gate --fresh solver-smoke.json \
        [--baseline BENCH_solver.json] [--tolerance 0.2] [--floor-s 2.0]

Exit code 1 on any violation; prints one line per comparison.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


def _index(result: dict) -> dict:
    """(m, engine) -> {seconds, adders, cost_bits} from a smoke JSON."""
    out = {}
    for row in result.get("sizes", []):
        for engine, e in row.get("engines", {}).items():
            out[(int(row["m"]), engine)] = e
    return out


def compare(fresh: dict, baseline: dict, tolerance: float = 0.2,
            floor_s: float = 1.0,
            ratio_tolerance: float | None = None) -> list[str]:
    """Return a list of violation messages (empty = gate passes)."""
    violations: list[str] = []
    fi, bi = _index(fresh), _index(baseline)
    missing = sorted(set(bi) - set(fi))
    if missing:
        violations.append(f"fresh run lacks baseline points: {missing}")
    for key in sorted(set(fi) & set(bi)):
        m, engine = key
        f, b = fi[key], bi[key]
        for metric in ("adders", "cost_bits"):
            if f[metric] != b[metric]:
                violations.append(
                    f"m{m}/{engine}: {metric} {f[metric]} != baseline "
                    f"{b[metric]} (deterministic drift)"
                )
        tkey = "cpu_seconds" if "cpu_seconds" in f and "cpu_seconds" in b else "seconds"
        limit = max(b[tkey] * (1.0 + tolerance), floor_s)
        status = "ok" if f[tkey] <= limit else "REGRESSION"
        print(
            f"m{m}/{engine}: {f[tkey]:.3f}s ({tkey}) vs baseline "
            f"{b[tkey]:.3f}s (limit {limit:.3f}s) {status}"
        )
        if f[tkey] > limit:
            violations.append(
                f"m{m}/{engine}: {f[tkey]:.3f}s exceeds "
                f"{limit:.3f}s (> {tolerance:.0%} over baseline)"
            )
    if ratio_tolerance is None:
        # the two engines are timed in different windows, so contention
        # asymmetry adds noise the absolute checks don't see: default to
        # a flat 20 points on top of the absolute tolerance
        ratio_tolerance = tolerance + 0.2
    violations += _ratio_check(fresh, baseline, fi, bi, ratio_tolerance)
    return violations


def _ratio_check(fresh: dict, baseline: dict, fi: dict, bi: dict,
                 ratio_tolerance: float) -> list[str]:
    """Machine-independent check: the gate engine's time *relative to
    the batch engine in the same run* must not regress.  Absolute CPU
    seconds shift with the machine class; this ratio cancels machine
    speed, so it keeps its teeth on shared runners where the absolute
    limits are floored or widened away."""
    m = fresh.get("gate_size", baseline.get("gate_size"))
    eng = fresh.get("gate_engine", baseline.get("gate_engine"))
    out: list[str] = []
    try:
        tkey = "cpu_seconds" if "cpu_seconds" in fi[(m, eng)] else "seconds"
        f_ratio = fi[(m, eng)][tkey] / fi[(m, "batch")][tkey]
        b_ratio = bi[(m, eng)][tkey] / bi[(m, "batch")][tkey]
    except (KeyError, ZeroDivisionError):
        return out
    limit = b_ratio * (1.0 + ratio_tolerance)
    status = "ok" if f_ratio <= limit else "REGRESSION"
    print(
        f"m{m} {eng}/batch ratio: {f_ratio:.3f} vs baseline "
        f"{b_ratio:.3f} (limit {limit:.3f}) {status}"
    )
    if f_ratio > limit:
        out.append(
            f"m{m}: {eng}-vs-batch ratio {f_ratio:.3f} exceeds "
            f"{limit:.3f} (machine-independent regression)"
        )
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", required=True, help="fresh solver_smoke JSON")
    ap.add_argument(
        "--baseline", default=str(REPO_ROOT / "BENCH_solver.json"),
        help="committed baseline JSON (default: repo-root BENCH_solver.json)",
    )
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed relative slowdown (default 0.2 = 20%%)")
    ap.add_argument("--floor-s", type=float, default=1.0,
                    help="never fail a point whose time is under this many "
                         "seconds (noise floor; default 1.0 suits the "
                         "baseline machine)")
    ap.add_argument("--ratio-tolerance", type=float, default=None,
                    help="allowed slowdown of the gate-engine-vs-batch "
                         "same-run ratio (machine-independent; default "
                         "tolerance + 0.2)")
    args = ap.parse_args(argv)
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    baseline_path = Path(args.baseline)
    if not baseline_path.exists():
        print(f"no baseline at {baseline_path}: nothing to gate against")
        return 0
    with open(baseline_path) as fh:
        baseline = json.load(fh)
    violations = compare(
        fresh, baseline, args.tolerance, args.floor_s, args.ratio_tolerance
    )
    for v in violations:
        print(f"FAIL: {v}", file=sys.stderr)
    if not violations:
        print("perf gate passed")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
