"""Perf-trajectory gate: compare a fresh bench JSON against the
committed baseline at the repo root.

Three kinds (``--kind``):

  * ``solver`` (default) — ``solver_smoke`` vs ``BENCH_solver.json``;
  * ``serve``  — ``serve_load`` vs ``BENCH_serve.json``: correctness
    booleans (bit-exact artifact, zero solves on load, rollout ok,
    per-shard counter consistency, p99 SLO) are deterministic failures;
    throughput must not drop more than ``tolerance`` below baseline and
    p99 must not exceed baseline by more than ``tolerance`` (with a
    ``--p99-floor-ms`` noise floor for shared runners);
  * ``rtl``    — ``rtl_cosim`` vs ``BENCH_rtl.json``: everything is
    deterministic (the solver and the simulator are pure functions of
    the seeds): any bit mismatch or latency violation fails, the fresh
    grid must cover every baseline case, and per-case adder counts /
    cost bits / stage structure must match the baseline exactly;
  * ``chaos``  — ``chaos_soak`` vs ``BENCH_chaos.json``: the
    deterministic legs (breaker trip counts, deadline shed, bit-exact
    interpreter fallback, half-open recovery) and the soak invariants
    (every future resolved, zero slab-slot leaks) are hard failures;
    the disabled-path overhead ratio is gated against its in-report
    limit; degraded soak throughput must not drop more than
    ``tolerance`` below baseline.

Two classes of check:

  * **deterministic** — adder and cost-bit counts per (size, engine)
    must match the baseline exactly.  The solver is a pure function of
    its inputs, so any drift here is an algorithmic change and fails
    regardless of tolerances.
  * **timing** — per (size, engine) solve time must stay within
    ``(1 + tolerance)`` of the baseline (default 20%, the regression
    budget from the PR 5 issue), except under ``floor_s`` where
    shared-runner noise dominates signal.  CPU seconds
    (``cpu_seconds``, steal-immune) are compared when both sides carry
    them, wall seconds otherwise.  Machines still differ; the committed
    baseline records the dev container, so CI passes a wider
    ``--floor-s`` and relies on the deterministic checks plus its own
    archived artifact series for cross-push trends.

Usage::

    python -m benchmarks.perf_gate --fresh solver-smoke.json \
        [--baseline BENCH_solver.json] [--tolerance 0.2] [--floor-s 2.0]
    python -m benchmarks.perf_gate --kind serve --fresh serve.json \
        [--baseline BENCH_serve.json] [--tolerance 0.5] [--p99-floor-ms 50]

Exit code 1 on any violation; prints one line per comparison.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


def _index(result: dict) -> dict:
    """(m, engine) -> {seconds, adders, cost_bits} from a smoke JSON."""
    out = {}
    for row in result.get("sizes", []):
        for engine, e in row.get("engines", {}).items():
            out[(int(row["m"]), engine)] = e
    return out


def compare(fresh: dict, baseline: dict, tolerance: float = 0.2,
            floor_s: float = 1.0,
            ratio_tolerance: float | None = None,
            trace_overhead_limit: float = 1.5) -> list[str]:
    """Return a list of violation messages (empty = gate passes)."""
    violations: list[str] = []
    fi, bi = _index(fresh), _index(baseline)
    missing = sorted(set(bi) - set(fi))
    if missing:
        violations.append(f"fresh run lacks baseline points: {missing}")
    for key in sorted(set(fi) & set(bi)):
        m, engine = key
        f, b = fi[key], bi[key]
        for metric in ("adders", "cost_bits"):
            if f[metric] != b[metric]:
                violations.append(
                    f"m{m}/{engine}: {metric} {f[metric]} != baseline "
                    f"{b[metric]} (deterministic drift)"
                )
        tkey = "cpu_seconds" if "cpu_seconds" in f and "cpu_seconds" in b else "seconds"
        limit = max(b[tkey] * (1.0 + tolerance), floor_s)
        status = "ok" if f[tkey] <= limit else "REGRESSION"
        print(
            f"m{m}/{engine}: {f[tkey]:.3f}s ({tkey}) vs baseline "
            f"{b[tkey]:.3f}s (limit {limit:.3f}s) {status}"
        )
        if f[tkey] > limit:
            violations.append(
                f"m{m}/{engine}: {f[tkey]:.3f}s exceeds "
                f"{limit:.3f}s (> {tolerance:.0%} over baseline)"
            )
    if ratio_tolerance is None:
        # the two engines are timed in different windows, so contention
        # asymmetry adds noise the absolute checks don't see: default to
        # a flat 20 points on top of the absolute tolerance
        ratio_tolerance = tolerance + 0.2
    violations += _ratio_check(fresh, baseline, fi, bi, ratio_tolerance)
    violations += _tracing_check(fresh, trace_overhead_limit)
    return violations


def _tracing_check(fresh: dict, limit: float) -> list[str]:
    """Span-tracing gate on the fresh run's ``tracing`` section (older
    baselines predate it, so only the fresh side is consulted):

      * identity is deterministic — a traced solve that changes adders
        or cost bits fails outright on any machine;
      * enabled-mode overhead is gated loosely against ``limit`` (a
        same-run ratio, machine-independent), with a 0.1 s absolute
        floor on the enabled-minus-disabled delta so sub-noise gate
        times on fast machines can't trip a ratio of tiny numbers.
    """
    tr = fresh.get("tracing")
    if not tr:
        return []
    out: list[str] = []
    if not tr.get("identical", True):
        out.append(
            "tracing: traced solve diverged from untraced gate run "
            "(adders/cost_bits drift — deterministic)"
        )
    ratio = tr.get("overhead_ratio")
    delta = tr.get("enabled_cpu_s", 0.0) - tr.get("disabled_cpu_s", 0.0)
    if ratio is not None:
        over = ratio > limit and delta > 0.1
        status = "REGRESSION" if over else "ok"
        print(
            f"tracing: enabled/disabled ratio {ratio:.3f} "
            f"(limit {limit:.2f}, delta {delta:+.3f}s, "
            f"{tr.get('n_span_events', 0)} spans) {status}"
        )
        if over:
            out.append(
                f"tracing: enabled-mode overhead ratio {ratio:.3f} exceeds "
                f"{limit:.2f} with {delta:.3f}s absolute cost"
            )
    return out


def _ratio_check(fresh: dict, baseline: dict, fi: dict, bi: dict,
                 ratio_tolerance: float) -> list[str]:
    """Machine-independent check: the gate engine's time *relative to
    the batch engine in the same run* must not regress.  Absolute CPU
    seconds shift with the machine class; this ratio cancels machine
    speed, so it keeps its teeth on shared runners where the absolute
    limits are floored or widened away."""
    m = fresh.get("gate_size", baseline.get("gate_size"))
    eng = fresh.get("gate_engine", baseline.get("gate_engine"))
    out: list[str] = []
    try:
        tkey = "cpu_seconds" if "cpu_seconds" in fi[(m, eng)] else "seconds"
        f_ratio = fi[(m, eng)][tkey] / fi[(m, "batch")][tkey]
        b_ratio = bi[(m, eng)][tkey] / bi[(m, "batch")][tkey]
    except (KeyError, ZeroDivisionError):
        return out
    limit = b_ratio * (1.0 + ratio_tolerance)
    status = "ok" if f_ratio <= limit else "REGRESSION"
    print(
        f"m{m} {eng}/batch ratio: {f_ratio:.3f} vs baseline "
        f"{b_ratio:.3f} (limit {limit:.3f}) {status}"
    )
    if f_ratio > limit:
        out.append(
            f"m{m}: {eng}-vs-batch ratio {f_ratio:.3f} exceeds "
            f"{limit:.3f} (machine-independent regression)"
        )
    return out


def compare_serve(fresh: dict, baseline: dict, tolerance: float = 0.5,
                  p99_floor_ms: float = 50.0) -> list[str]:
    """Serve-load gate: correctness booleans are deterministic failures;
    throughput / p99 drift is bounded by ``tolerance`` (with a p99 noise
    floor — sub-floor tails on shared runners are all scheduler noise).

    Returns a list of violation messages (empty = gate passes).
    """
    violations: list[str] = []
    art = fresh.get("artifact", {})
    checks = [
        ("sustained", fresh.get("sustained", False),
         f"throughput below its own min_rps={fresh.get('min_rps')}"),
        ("slo_ok", fresh.get("slo_ok", False),
         f"p99 {fresh.get('p99_ms', float('nan')):.3f}ms over SLO "
         f"{fresh.get('slo_p99_ms')}ms"),
        ("shard_consistency", fresh.get("shard_consistency", False),
         "per-shard sum(bucket_hits) != n_batches"),
        ("artifact.bit_exact", art.get("bit_exact", False),
         "artifact round-trip not bit-exact"),
        ("artifact.n_solves_on_load", art.get("n_solves_on_load", -1) == 0,
         f"cold start performed {art.get('n_solves_on_load')} solves"),
        ("rollout.ok", fresh.get("rollout", {}).get("ok", False),
         "rollout under traffic failed"),
    ]
    for name, ok, why in checks:
        status = "ok" if ok else "FAIL"
        print(f"serve/{name}: {status}")
        if not ok:
            violations.append(f"serve/{name}: {why} (deterministic)")

    f_rps, b_rps = fresh.get("achieved_rps"), baseline.get("achieved_rps")
    if f_rps is not None and b_rps:
        limit = b_rps / (1.0 + tolerance)
        status = "ok" if f_rps >= limit else "REGRESSION"
        print(
            f"serve/throughput: {f_rps:.0f} rps vs baseline {b_rps:.0f} "
            f"(limit {limit:.0f}) {status}"
        )
        if f_rps < limit:
            violations.append(
                f"serve/throughput: {f_rps:.0f} rps under {limit:.0f} "
                f"(> {tolerance:.0%} below baseline)"
            )
    f_p99, b_p99 = fresh.get("p99_ms"), baseline.get("p99_ms")
    if f_p99 is not None and b_p99 is not None:
        limit = max(b_p99 * (1.0 + tolerance), p99_floor_ms)
        status = "ok" if f_p99 <= limit else "REGRESSION"
        print(
            f"serve/p99: {f_p99:.3f}ms vs baseline {b_p99:.3f}ms "
            f"(limit {limit:.3f}ms) {status}"
        )
        if f_p99 > limit:
            violations.append(
                f"serve/p99: {f_p99:.3f}ms exceeds {limit:.3f}ms "
                f"(> {tolerance:.0%} over baseline)"
            )
    return violations


def compare_rtl(fresh: dict, baseline: dict) -> list[str]:
    """RTL co-sim gate: fully deterministic, no timing tolerances.

    Fails on any bit mismatch or cycle-accounting violation in the
    fresh run, on baseline cases missing from the fresh grid (coverage
    must never silently shrink), and on drift of the per-case program
    shape (adders, cost bits, stages, latency) — the emitted RTL is a
    pure function of the grid seeds, so any change here is an
    intentional solver/emitter change that must land with a new
    baseline.  Returns a list of violation messages (empty = pass).
    """
    violations: list[str] = []
    if not fresh.get("all_bit_exact", False):
        violations.append("rtl: fresh run is not bit-exact on every leg")
    fi = {c["name"]: c for c in fresh.get("cases", [])}
    bi = {c["name"]: c for c in baseline.get("cases", [])}
    missing = sorted(set(bi) - set(fi))
    if missing:
        violations.append(f"rtl: fresh grid lacks baseline cases: {missing}")
    for name in sorted(fi):
        c = fi[name]
        ok = c.get("bit_exact", False) and c.get("latency_ok", False)
        jitleg = c.get("jit", {})
        if jitleg.get("status") == "checked" and not jitleg.get("bit_exact", False):
            ok = False
        ext = c.get("external", {})
        if ext.get("status") == "checked" and not ext.get("bit_exact", False):
            ok = False
        drift = []
        if name in bi:
            b = bi[name]
            for metric in ("adders", "cost_bits", "n_stages",
                           "expected_latency_cycles"):
                if c.get(metric) != b.get(metric):
                    drift.append(
                        f"{metric} {c.get(metric)} != baseline {b.get(metric)}"
                    )
        status = "ok" if ok and not drift else "FAIL"
        print(f"rtl/{name}: {status}" + (f" ({'; '.join(drift)})" if drift else ""))
        if not ok:
            violations.append(
                f"rtl/{name}: mismatch "
                f"(bit_exact={c.get('bit_exact')}, latency_ok={c.get('latency_ok')}, "
                f"jit={jitleg.get('status')}/{jitleg.get('bit_exact')})"
            )
        for d in drift:
            violations.append(f"rtl/{name}: {d} (deterministic drift)")
    return violations


def compare_chaos(fresh: dict, baseline: dict, tolerance: float = 0.5) -> list[str]:
    """Chaos-soak gate: resilience correctness is deterministic, the
    degraded-throughput trajectory is tolerance-bounded.

    Returns a list of violation messages (empty = gate passes).
    """
    violations: list[str] = []
    det = fresh.get("deterministic", {})
    soak = fresh.get("soak", {})
    ov = fresh.get("overhead", {})
    checks = [
        ("breaker_trip", det.get("breaker_trip", {}).get("ok", False),
         "breaker did not trip/fast-fail on the scheduled failure burst"),
        ("shed", det.get("shed", {}).get("ok", False),
         "expired deadline was not shed with the typed error"),
        ("fallback", det.get("fallback", {}).get("ok", False),
         "interpreter fallback missing or not bit-exact"),
        ("recovery", fresh.get("recovery", {}).get("ok", False),
         "breaker did not recover through the half-open probe"),
        ("soak.all_resolved", soak.get("all_resolved", False),
         f"{soak.get('n_hung')} futures hung under the fault storm"),
        ("soak.no_leaks", soak.get("slab_slots_leaked", -1) == 0,
         f"{soak.get('slab_slots_leaked')} slab slots leaked"),
        ("soak.bit_exact", soak.get("n_inexact", -1) == 0,
         f"{soak.get('n_inexact')} successful results were not bit-exact"),
        ("soak.served", soak.get("n_ok", 0) > 0,
         "soak served zero successful requests"),
    ]
    for name, ok, why in checks:
        status = "ok" if ok else "FAIL"
        print(f"chaos/{name}: {status}")
        if not ok:
            violations.append(f"chaos/{name}: {why} (deterministic)")
    ratio = ov.get("overhead_ratio")
    if ratio is not None:
        ok = ov.get("ok", False)
        status = "ok" if ok else "REGRESSION"
        print(
            f"chaos/overhead: disabled-path ratio {ratio:.3f} "
            f"(limit {ov.get('overhead_limit')}, "
            f"delta {ov.get('overhead_delta_s', 0.0):+.3f}s) {status}"
        )
        if not ok:
            violations.append(
                f"chaos/overhead: disabled fault_point costs {ratio:.3f}x "
                f"(> {ov.get('overhead_limit')}) with "
                f"{ov.get('overhead_delta_s', 0.0):.3f}s absolute delta"
            )
    f_rps = soak.get("degraded_rps")
    b_rps = baseline.get("soak", {}).get("degraded_rps")
    if f_rps is not None and b_rps:
        limit = b_rps / (1.0 + tolerance)
        status = "ok" if f_rps >= limit else "REGRESSION"
        print(
            f"chaos/degraded_rps: {f_rps:.0f} vs baseline {b_rps:.0f} "
            f"(limit {limit:.0f}) {status}"
        )
        if f_rps < limit:
            violations.append(
                f"chaos/degraded_rps: {f_rps:.0f} under {limit:.0f} "
                f"(> {tolerance:.0%} below baseline)"
            )
    return violations


_DEFAULT_BASELINES = {
    "solver": "BENCH_solver.json",
    "serve": "BENCH_serve.json",
    "rtl": "BENCH_rtl.json",
    "chaos": "BENCH_chaos.json",
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", required=True, help="fresh bench JSON")
    ap.add_argument("--kind", choices=("solver", "serve", "rtl", "chaos"),
                    default="solver",
                    help="which bench family the JSONs belong to")
    ap.add_argument(
        "--baseline", default=None,
        help="committed baseline JSON (default: repo-root "
             "BENCH_solver.json / BENCH_serve.json per --kind)",
    )
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed relative slowdown (default 0.2 = 20%%)")
    ap.add_argument("--floor-s", type=float, default=1.0,
                    help="never fail a point whose time is under this many "
                         "seconds (noise floor; default 1.0 suits the "
                         "baseline machine)")
    ap.add_argument("--ratio-tolerance", type=float, default=None,
                    help="allowed slowdown of the gate-engine-vs-batch "
                         "same-run ratio (machine-independent; default "
                         "tolerance + 0.2; solver kind only)")
    ap.add_argument("--p99-floor-ms", type=float, default=50.0,
                    help="never fail a serve p99 under this many ms "
                         "(noise floor; serve kind only)")
    ap.add_argument("--trace-overhead-limit", type=float, default=1.5,
                    help="max enabled-tracing/untraced CPU-seconds ratio "
                         "on the solver gate point (loose; identity is "
                         "gated separately and exactly; solver kind only)")
    args = ap.parse_args(argv)
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    baseline_path = Path(
        args.baseline or REPO_ROOT / _DEFAULT_BASELINES[args.kind]
    )
    if not baseline_path.exists():
        print(f"no baseline at {baseline_path}: nothing to gate against")
        return 0
    with open(baseline_path) as fh:
        baseline = json.load(fh)
    if args.kind == "rtl":
        violations = compare_rtl(fresh, baseline)
    elif args.kind == "chaos":
        violations = compare_chaos(fresh, baseline, args.tolerance)
    elif args.kind == "serve":
        violations = compare_serve(
            fresh, baseline, args.tolerance, args.p99_floor_ms
        )
    else:
        violations = compare(
            fresh, baseline, args.tolerance, args.floor_s,
            args.ratio_tolerance, args.trace_overhead_limit,
        )
    for v in violations:
        print(f"FAIL: {v}", file=sys.stderr)
    if not violations:
        print("perf gate passed")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
