"""Benchmark harness: one module per paper table/figure.

    table2_random_matrices  Table 2  (adders/depth/runtime vs H_cmvm)
    table3_4_resources      Tables 3-4 (resource proxies, 8/4-bit)
    tables5_12_networks     Tables 5-12 (network-level DA vs latency)
    fig7_runtime_scaling    Fig. 7 (solver runtime scaling)
    solver_smoke            solver fast-path wall-clock budget check
    serve_load              artifact round-trip + microbatched serve load
    rtl_cosim               RTL co-simulation gate (three-way bit-exact)
    obs_trace               telemetry layer gate (trace/metrics/flight)
    lint_designs            static design-verifier gate (repro.analysis)
    chaos_soak              fault-injection soak gate (repro.chaos)
    lm_step_bench           framework substrate microbench

Prints ``name,us_per_call,derived`` CSV.  ``run.py smoke --json PATH``
additionally writes the smoke result as JSON (the CI perf artifact) AND
refreshes ``BENCH_solver.json`` at the repo root — the committed perf
baseline that ``benchmarks/perf_gate.py`` compares future runs against
(solve seconds, adder counts, and cost bits per size and engine).
Exits 1 if the smoke budget/exactness/engine-equivalence gate fails.
Roofline numbers live in EXPERIMENTS.md (derived from the dry-run, see
repro.launch.dryrun).
"""

from __future__ import annotations

import importlib
import sys
from pathlib import Path

_REPO_ROOT = Path(__file__).resolve().parent.parent
BENCH_SOLVER_JSON = _REPO_ROOT / "BENCH_solver.json"
# benches whose --json run also refreshes a committed trajectory baseline
# (only when the gate passed, so a regressing run never poisons the ref)
_BASELINES = {
    "smoke": BENCH_SOLVER_JSON,
    "rtl": _REPO_ROOT / "BENCH_rtl.json",
    "chaos": _REPO_ROOT / "BENCH_chaos.json",
}


def main() -> None:
    args = [a for a in sys.argv[1:]]
    json_path = None
    if "--json" in args:
        k = args.index("--json")
        if k + 1 >= len(args):
            sys.exit("usage: benchmarks.run [name] --json PATH")
        json_path = args[k + 1]
        del args[k : k + 2]
    only = args[0] if args else None
    # modules are imported lazily so jax-free benches (e.g. `smoke`, which
    # only needs numpy + repro.core) run in minimal environments
    mods = {
        "table2": "table2_random_matrices",
        "table34": "table3_4_resources",
        "networks": "tables5_12_networks",
        "fig7": "fig7_runtime_scaling",
        "smoke": "solver_smoke",
        "serve": "serve_load",
        "rtl": "rtl_cosim",
        "obs": "obs_trace",
        "lint": "lint_designs",
        "chaos": "chaos_soak",
        "lm": "lm_step_bench",
    }
    failed = False
    for name, modname in mods.items():
        if only and only != name:
            continue
        mod = importlib.import_module(f".{modname}", __package__)
        print(f"# --- {name} ({mod.__name__}) ---", flush=True)
        if name in ("smoke", "serve", "rtl", "obs", "lint", "chaos"):
            # gated benches: JSON artifact + exit-1 on budget/exactness
            # failure.  --json targets the explicitly selected bench
            # (or smoke, the historical default, when running all).
            jp = json_path if (only == name or (name == "smoke" and only is None)) else None
            result = mod.main(json_path=jp)
            ok = mod.passed(result)
            if name in _BASELINES and jp is not None and ok:
                # --json runs refresh the committed perf baseline — but
                # only when the gate passed, so a regressing run can
                # never poison the reference
                import json as _json

                with open(_BASELINES[name], "w") as fh:
                    _json.dump(result, fh, indent=2, sort_keys=True)
                print(
                    f"# refreshed {_BASELINES[name]} with this run — "
                    "solver timings are machine-specific (commit those only "
                    "from the canonical perf box); rtl numbers are "
                    "deterministic",
                    file=sys.stderr,
                )
            failed = failed or not ok
        else:
            mod.main()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
