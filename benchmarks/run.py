"""Benchmark harness: one module per paper table/figure.

    table2_random_matrices  Table 2  (adders/depth/runtime vs H_cmvm)
    table3_4_resources      Tables 3-4 (resource proxies, 8/4-bit)
    tables5_12_networks     Tables 5-12 (network-level DA vs latency)
    fig7_runtime_scaling    Fig. 7 (solver runtime scaling)
    solver_smoke            solver fast-path wall-clock budget check
    lm_step_bench           framework substrate microbench

Prints ``name,us_per_call,derived`` CSV.  Roofline numbers live in
EXPERIMENTS.md (derived from the dry-run, see repro.launch.dryrun).
"""

from __future__ import annotations

import sys


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    from . import (
        fig7_runtime_scaling,
        lm_step_bench,
        solver_smoke,
        table2_random_matrices,
        table3_4_resources,
        tables5_12_networks,
    )

    mods = {
        "table2": table2_random_matrices,
        "table34": table3_4_resources,
        "networks": tables5_12_networks,
        "fig7": fig7_runtime_scaling,
        "smoke": solver_smoke,
        "lm": lm_step_bench,
    }
    for name, mod in mods.items():
        if only and only != name:
            continue
        print(f"# --- {name} ({mod.__name__}) ---", flush=True)
        mod.main()


if __name__ == "__main__":
    main()
