"""Solver fast-path budget check + perf-trajectory seed.

Solves one random m x m 8-bit matrix per size in ``SIZES`` (the largest,
64 x 64, is the Fig. 7 stress point: 22.4 s at the seed, ~3.1 s after
PR 1, ~1.6-1.8 s with the batch CSE engine, ~1.3 s with the arena
engine on the PR 5 dev container) with every CSE engine, and fails if

  * any engine disagrees with any other on adders / cost bits at any
    size (the cross-engine bit-level guard — programs are asserted
    identical in tier-1; adders+cost are the cheap proxy here);
  * the arena solution is not bit-exact (``verify()``);
  * the arena 64 x 64 wall clock exceeds ``budget_s``.

The budget is calibrated against the *reference machine* of the PR 1/2
docs (where batch = 1.6-1.8 s): the issue target there is <= 1.0 s.
Containers differ — on the PR 5 dev container batch measures 2.3-2.6 s
(~1.45x slower), so the enforced absolute budget is
``1.0 * CALIBRATION`` with head-room, see ``DEFAULT_BUDGET_S``.  The
relative trajectory (>20% regression vs the committed baseline)
is enforced separately by ``benchmarks/perf_gate.py`` on
``BENCH_solver.json``.

Prints the same ``name,us_per_call,derived`` CSV as the other benches
and optionally writes the full result dict as JSON (``--json PATH``,
or ``benchmarks/run.py smoke --json PATH`` — which *also* refreshes
``BENCH_solver.json`` at the repo root, the committed perf baseline,
but only when the gate passed — a regressing run can never poison the
reference) so CI can archive a perf trajectory across PRs.  Exit code 1
on budget/exactness/equivalence failure when run as a script.
"""

from __future__ import annotations

import json
import sys
import time

import numpy as np

from repro.core import solve_cmvm
from repro.flow import SolverConfig

SEED_REFERENCE_S = 22.4  # seed solve_cmvm on the reference machine
PR1_REFERENCE_S = 3.1  # after PR 1's solver fast path (lazy heap engine)
PR2_REFERENCE_S = 1.7  # after PR 2's batch engine (reference machine)

SIZES = (16, 32, 64)
ENGINES = ("batch", "heap", "arena")
GATE_SIZE = 64  # the budgeted stress point
GATE_ENGINE = "arena"
# <= 1.0 s on the reference machine; the PR 5 dev container runs the
# same code ~1.45x slower (batch: 1.6-1.8 s there vs a measured
# 2.3-2.6 s here; arena measures ~1.3 s here ~= 0.9 s reference), so
# the absolute gate is 1.0 * 1.45 rounded up with a little slack for
# shared-runner noise.  perf_gate.py enforces the tight 20% relative
# trajectory against the committed BENCH_solver.json.
DEFAULT_BUDGET_S = 1.8


def run(sizes=SIZES, bw=8, seed=0, dc=-1, budget_s=DEFAULT_BUDGET_S,
        engines=ENGINES):
    result = {
        "bw": bw,
        "dc": dc,
        "budget_s": budget_s,
        "gate_size": GATE_SIZE,
        "gate_engine": GATE_ENGINE,
        "sizes": [],
    }
    gate_seconds = None
    verified = True
    engines_identical = True
    for m in sizes:
        # fresh rng per size: every matrix is the FIRST draw from
        # default_rng(seed), so the 64x64 stress matrix is the exact
        # instance all historical reference timings were measured on
        mat = np.random.default_rng(seed).integers(
            2 ** (bw - 1) + 1, 2**bw, size=(m, m)
        )
        row = {"m": m, "engines": {}}
        ref = None
        for engine in engines:
            # the gate point is timed twice and keeps the best: the
            # arena engine's steady state is the *warm* solve (compiles
            # reuse one workspace across layers), and min-of-2 also
            # rejects shared-runner noise spikes.  The cold time is
            # recorded alongside for the trajectory.
            repeats = 3 if (m == GATE_SIZE and engine == GATE_ENGINE) else 1
            times = []
            cpu_times = []
            for _ in range(repeats):
                c0 = time.process_time()
                t0 = time.perf_counter()
                sol = solve_cmvm(mat, config=SolverConfig(dc=dc, engine=engine))
                times.append(time.perf_counter() - t0)
                cpu_times.append(time.process_time() - c0)
            # the budget and the perf_gate trajectory use CPU seconds:
            # immune to host steal / noisy neighbours on shared runners,
            # and equal to wall time on an idle machine.  Wall seconds
            # ride along for the human-facing trajectory.
            dt = min(cpu_times)
            row["engines"][engine] = {
                "seconds": min(times),
                "cpu_seconds": dt,
                "adders": sol.n_adders,
                "cost_bits": sol.cost_bits,
            }
            if repeats > 1:
                row["engines"][engine]["cold_seconds"] = times[0]
            if ref is None:
                ref = (sol.n_adders, sol.cost_bits)
            elif (sol.n_adders, sol.cost_bits) != ref:
                engines_identical = False
            if m == GATE_SIZE and engine == GATE_ENGINE:
                gate_seconds = dt
                verified = verified and sol.verify()
        result["sizes"].append(row)
    # the gated arena stress-point time (CPU seconds, steal-immune)
    result["seconds"] = gate_seconds
    result["within_budget"] = (
        gate_seconds is not None and gate_seconds <= budget_s
    )
    result["verified"] = verified
    result["engines_identical"] = engines_identical
    if gate_seconds:
        result["speedup_vs_seed_ref"] = SEED_REFERENCE_S / gate_seconds
        result["speedup_vs_pr1_ref"] = PR1_REFERENCE_S / gate_seconds
        result["speedup_vs_pr2_ref"] = PR2_REFERENCE_S / gate_seconds
    if GATE_SIZE in sizes and GATE_ENGINE in engines:
        result["tracing"] = _tracing_overhead(
            result, bw=bw, seed=seed, dc=dc
        )
    return result


def _tracing_overhead(result: dict, bw: int, seed: int, dc: int) -> dict:
    """Re-solve the gate point with span tracing ENABLED and compare.

    Two checks ride on this (gated by ``perf_gate.py``):
      * identity — the traced solve must produce the exact adders /
        cost bits of the untraced gate run (deterministic on any
        machine; tracing must never perturb solver decisions);
      * overhead — enabled-mode CPU seconds over the untraced gate
        time, reported as a ratio and gated loosely (the disabled-mode
        cost is what the <1% claim is about, and that is exactly the
        normal gate time already measured above).
    """
    from repro.obs import trace

    gate_row = next(r for r in result["sizes"] if r["m"] == GATE_SIZE)
    ref = gate_row["engines"][GATE_ENGINE]
    mat = np.random.default_rng(seed).integers(
        2 ** (bw - 1) + 1, 2**bw, size=(GATE_SIZE, GATE_SIZE)
    )
    was_enabled = trace.enabled()
    trace.set_enabled(True)
    try:
        cpu_times = []
        sol = None
        for _ in range(2):
            trace.reset()
            c0 = time.process_time()
            sol = solve_cmvm(mat, config=SolverConfig(dc=dc, engine=GATE_ENGINE))
            cpu_times.append(time.process_time() - c0)
        n_span_events = trace.n_events()
    finally:
        trace.set_enabled(was_enabled)
        trace.reset()
    enabled_s = min(cpu_times)
    disabled_s = ref["cpu_seconds"]
    return {
        "disabled_cpu_s": disabled_s,
        "enabled_cpu_s": enabled_s,
        "overhead_ratio": (enabled_s / disabled_s) if disabled_s > 0 else 1.0,
        "n_span_events": n_span_events,
        "identical": (sol.n_adders, sol.cost_bits)
        == (ref["adders"], ref["cost_bits"]),
    }


def passed(r: dict) -> bool:
    return bool(
        r["within_budget"]
        and r["verified"]
        and r["engines_identical"]
        # tracing must never change what the solver produces
        and r.get("tracing", {}).get("identical", True)
    )


def main(csv=True, json_path=None):
    r = run()
    if csv:
        print("name,us_per_call,derived")
        for row in r["sizes"]:
            for engine, e in row["engines"].items():
                print(
                    f"solver_smoke_m{row['m']}_{engine},{e['seconds']*1e6:.0f},"
                    f"cpu_s={e['cpu_seconds']:.3f};"
                    f"adders={e['adders']};cost_bits={e['cost_bits']}"
                )
        print(
            f"solver_smoke_gate,{(r['seconds'] or 0)*1e6:.0f},"
            f"metric=cpu_seconds;engine={r['gate_engine']};m={r['gate_size']};"
            f"budget_s={r['budget_s']};within_budget={int(r['within_budget'])};"
            f"verified={int(r['verified'])};"
            f"engines_identical={int(r['engines_identical'])};"
            f"speedup_vs_seed_ref={r.get('speedup_vs_seed_ref', 0):.1f}x;"
            f"speedup_vs_pr2_ref={r.get('speedup_vs_pr2_ref', 0):.2f}x"
        )
        tr = r.get("tracing")
        if tr:
            print(
                f"solver_smoke_tracing,{tr['enabled_cpu_s']*1e6:.0f},"
                f"overhead_ratio={tr['overhead_ratio']:.3f};"
                f"identical={int(tr['identical'])};"
                f"n_span_events={tr['n_span_events']}"
            )
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(r, fh, indent=2, sort_keys=True)
        print(f"# wrote {json_path}", file=sys.stderr)
    return r


if __name__ == "__main__":
    json_path = None
    if "--json" in sys.argv:
        k = sys.argv.index("--json")
        if k + 1 >= len(sys.argv):
            sys.exit("usage: solver_smoke [--json PATH]")
        json_path = sys.argv[k + 1]
    result = main(json_path=json_path)
    sys.exit(0 if passed(result) else 1)
