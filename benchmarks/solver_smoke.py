"""Solver fast-path budget check.

Solves one random 64 x 64 8-bit matrix (the Fig. 7 stress point: 22.4 s
at the seed, ~3.1 s after PR 1, ~1.5-2 s with the batch CSE engine on
the reference machine) with the default ``engine="batch"`` and fails if
the wall clock exceeds ``budget_s`` or the solution is not bit-exact.
It then re-solves with ``engine="heap"`` and fails unless the adder
count (and cost bits) are identical — the cross-engine guard of the
batch-scored CSE rewrite.

Prints the same ``name,us_per_call,derived`` CSV as the other benches
and optionally writes the full result dict as JSON (``--json PATH``, or
``benchmarks/run.py smoke --json PATH``) so CI can archive a perf
trajectory across PRs.  Exit code 1 on budget/exactness/equivalence
failure when run as a script.
"""

from __future__ import annotations

import json
import sys
import time

import numpy as np

from repro.core import solve_cmvm
from repro.flow import SolverConfig

SEED_REFERENCE_S = 22.4  # seed solve_cmvm on the reference machine
PR1_REFERENCE_S = 3.1  # after PR 1's solver fast path (lazy heap engine)


def run(m=64, bw=8, seed=0, dc=-1, budget_s=10.0, check_heap_engine=True):
    rng = np.random.default_rng(seed)
    mat = rng.integers(2 ** (bw - 1) + 1, 2**bw, size=(m, m))
    t0 = time.perf_counter()
    sol = solve_cmvm(mat, config=SolverConfig(dc=dc, engine="batch"))
    dt = time.perf_counter() - t0
    result = {
        "m": m,
        "bw": bw,
        "dc": dc,
        "engine": "batch",
        "seconds": dt,
        "budget_s": budget_s,
        "within_budget": dt <= budget_s,
        "adders": sol.n_adders,
        "cost_bits": sol.cost_bits,
        "verified": sol.verify(),
        "speedup_vs_seed_ref": SEED_REFERENCE_S / dt,
        "speedup_vs_pr1_ref": PR1_REFERENCE_S / dt,
    }
    if check_heap_engine:
        t0 = time.perf_counter()
        heap_sol = solve_cmvm(mat, config=SolverConfig(dc=dc, engine="heap"))
        result["heap_seconds"] = time.perf_counter() - t0
        result["heap_adders"] = heap_sol.n_adders
        result["engines_identical"] = (
            heap_sol.n_adders == sol.n_adders
            and heap_sol.cost_bits == sol.cost_bits
        )
    return result


def passed(r: dict) -> bool:
    return bool(
        r["within_budget"] and r["verified"] and r.get("engines_identical", True)
    )


def main(csv=True, json_path=None):
    r = run()
    if csv:
        print("name,us_per_call,derived")
        print(
            f"solver_smoke_m{r['m']},{r['seconds']*1e6:.0f},"
            f"engine=batch;adders={r['adders']};cost_bits={r['cost_bits']};"
            f"budget_s={r['budget_s']};within_budget={int(r['within_budget'])};"
            f"verified={int(r['verified'])};"
            f"speedup_vs_seed_ref={r['speedup_vs_seed_ref']:.1f}x;"
            f"speedup_vs_pr1_ref={r['speedup_vs_pr1_ref']:.1f}x"
        )
        if "heap_seconds" in r:
            print(
                f"solver_smoke_m{r['m']}_heap,{r['heap_seconds']*1e6:.0f},"
                f"engine=heap;adders={r['heap_adders']};"
                f"engines_identical={int(r['engines_identical'])}"
            )
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(r, fh, indent=2, sort_keys=True)
        print(f"# wrote {json_path}", file=sys.stderr)
    return r


if __name__ == "__main__":
    json_path = None
    if "--json" in sys.argv:
        k = sys.argv.index("--json")
        if k + 1 >= len(sys.argv):
            sys.exit("usage: solver_smoke [--json PATH]")
        json_path = sys.argv[k + 1]
    result = main(json_path=json_path)
    sys.exit(0 if passed(result) else 1)
