"""Solver fast-path budget check.

Solves one random 64 x 64 8-bit matrix (the Fig. 7 stress point: 22.4 s
at the seed on the reference machine) and fails if the wall clock
exceeds ``budget_s`` or the solution is not bit-exact.  Prints the same
``name,us_per_call,derived`` CSV as the other benches; exit code 1 on
budget/exactness failure when run as a script, so it doubles as a CI
guard against solver performance regressions.
"""

from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import solve_cmvm

SEED_REFERENCE_S = 22.4  # seed solve_cmvm on the reference machine


def run(m=64, bw=8, seed=0, dc=-1, budget_s=10.0):
    rng = np.random.default_rng(seed)
    mat = rng.integers(2 ** (bw - 1) + 1, 2**bw, size=(m, m))
    t0 = time.perf_counter()
    sol = solve_cmvm(mat, dc=dc)
    dt = time.perf_counter() - t0
    return {
        "m": m,
        "seconds": dt,
        "budget_s": budget_s,
        "within_budget": dt <= budget_s,
        "adders": sol.n_adders,
        "cost_bits": sol.cost_bits,
        "verified": sol.verify(),
        "speedup_vs_seed_ref": SEED_REFERENCE_S / dt,
    }


def main(csv=True):
    r = run()
    if csv:
        print("name,us_per_call,derived")
        print(
            f"solver_smoke_m{r['m']},{r['seconds']*1e6:.0f},"
            f"adders={r['adders']};cost_bits={r['cost_bits']};"
            f"budget_s={r['budget_s']};within_budget={int(r['within_budget'])};"
            f"verified={int(r['verified'])};"
            f"speedup_vs_seed_ref={r['speedup_vs_seed_ref']:.1f}x"
        )
    return r


if __name__ == "__main__":
    result = main()
    sys.exit(0 if (result["within_budget"] and result["verified"]) else 1)
