"""Chaos soak bench: the resilience layer under scripted fire.

Four legs, all driven through :mod:`repro.chaos` fault plans against a
compiled 16x16 8-bit CMVM design:

  deterministic   1 shard, ``at=``-scheduled faults, exact counters:
                  the breaker trips after exactly ``threshold``
                  consecutive injected dispatch failures, open-state
                  requests fail fast, an expired deadline is shed, and
                  the interpreter fallback answers bit-exactly while
                  the jit path fails on every dispatch.
  recovery        small cooldown: after a trip, the half-open probe
                  closes the breaker and normal service resumes
                  (recovery wall time recorded).
  soak            4 shards, rate-scheduled faults (jit failure + slab
                  gather failure + dispatcher thread kills) with the
                  interpreter fallback and supervision armed; the gate
                  invariant is the engine's core promise: **every
                  submitted future resolves within the bound, no
                  dispatcher hang, and every slab slot returns to the
                  free list** — plus the degraded throughput is
                  recorded for the trajectory baseline.
  overhead        the zero-cost-when-disabled claim, gated like
                  ``REPRO_TRACE``: serve throughput with no plan
                  installed vs an installed plan whose rules target
                  only artifact sites (the serve-path ``fault_point``
                  still runs) must stay within 1.05x CPU-seconds, with
                  an absolute noise floor; plus raw ns/call for the
                  disabled ``fault_point``.

Prints the usual ``name,us_per_call,derived`` CSV; ``--json PATH``
writes the ``BENCH_chaos.json``-compatible report compared by
``benchmarks/perf_gate.py --kind chaos``.  Exit 1 if any deterministic
leg fails, a future hangs or a slab slot leaks in the soak, or the
disabled-path overhead exceeds its bound.
"""

from __future__ import annotations

import json
import os
import sys
import time
from concurrent.futures import TimeoutError as FutureTimeoutError

import numpy as np

RESOLVE_TIMEOUT_S = 20.0


def _build_design(m: int = 16, w_bits: int = 8, seed: int = 0):
    import jax

    from repro.flow import CompileConfig, Flow, SolverConfig
    from repro.nn import QDense, QuantConfig, init_params

    wq = QuantConfig(w_bits, 2, signed=True)
    model = (QDense(m, wq),)
    in_quant = QuantConfig(8, 4, signed=True)
    params, _ = init_params(jax.random.PRNGKey(seed), model, (m,))
    design = Flow.compile(
        model, params, (m,), in_quant,
        config=CompileConfig(solver=SolverConfig(dc=2)),
    )
    rng = np.random.default_rng(seed + 1)
    q = in_quant.qint
    samples = np.asarray(
        rng.integers(q.lo, q.hi + 1, size=(256, m)), np.int32
    )
    return design, samples


def _engine(design, **overrides):
    from repro.flow import ServeConfig
    from repro.runtime import ServeEngine

    base = dict(max_batch=8, max_wait_us=0.0, shards=1)
    base.update(overrides)
    eng = ServeEngine(config=ServeConfig(**base))
    eng.register("m", design, warmup=True)
    return eng


def _slab_slots_leaked(eng, name="m") -> int:
    """Free-list audit across live AND retired (crashed) shards."""
    runner = eng._runner(name)
    with runner._restart_lock:
        shards = list(runner._retired) + list(runner.shards)
    leaked = 0
    for sh in shards:
        with sh._lock:
            leaked += sh.slab.shape[0] - len(sh._free) + len(sh._pending)
    return leaked


def _leg_deterministic(design, samples) -> dict:
    """Exact-count assertions under ``at=``-scheduled faults."""
    from repro.chaos import FaultInjectedError, FaultPlan, FaultRule, active
    from repro.runtime import CircuitOpenError, DeadlineExceededError

    out: dict = {}
    want = np.asarray(design.forward_int(samples))

    # breaker trip + fast fail (cooldown far past the leg's duration)
    plan = FaultPlan([FaultRule("serve.dispatch", at=(0, 1))])
    with active(plan):
        eng = _engine(
            design, breaker_threshold=2,
            breaker_cooldown_ms=60_000.0, breaker_cooldown_max_ms=60_000.0,
        )
        try:
            n_injected = n_fast = 0
            for i in range(3):
                try:
                    eng.submit("m", samples[i]).result(RESOLVE_TIMEOUT_S)
                except FaultInjectedError:
                    n_injected += 1
                except CircuitOpenError:
                    n_fast += 1
            s = eng.stats("m")
            out["breaker_trip"] = {
                "n_injected": n_injected,
                "n_fast_failed": s["n_fast_failed"],
                "state": s["breaker"]["state"],
                "n_trips": s["breaker"]["n_trips"],
                "ok": bool(
                    n_injected == 2 and n_fast == 1
                    and s["breaker"]["state"] == "open"
                    and s["breaker"]["n_trips"] == 1
                    and s["n_fast_failed"] == 1
                ),
            }
        finally:
            eng.shutdown()

    # deadline shed at the door: exact counter
    eng = _engine(design)
    try:
        try:
            eng.submit("m", samples[0], deadline_s=0.0).result(RESOLVE_TIMEOUT_S)
            shed_typed = False
        except DeadlineExceededError:
            shed_typed = True
        n_shed = eng.stats("m")["n_shed"]
        out["shed"] = {
            "typed": shed_typed,
            "n_shed": n_shed,
            "ok": bool(shed_typed and n_shed == 1),
        }
    finally:
        eng.shutdown()

    # interpreter fallback: jit fails on every dispatch, answers stay
    # bit-exact through the numpy interpreter
    plan = FaultPlan([FaultRule("serve.dispatch", rate=1.0)])
    with active(plan):
        eng = _engine(
            design, fallback="interpreter",
            breaker_threshold=2, breaker_cooldown_ms=50.0,
        )
        try:
            n = 32
            futs = [eng.submit("m", x) for x in samples[:n]]
            got = np.stack([f.result(RESOLVE_TIMEOUT_S) for f in futs])
            s = eng.stats("m")
            out["fallback"] = {
                "bit_exact": bool(np.array_equal(got, want[:n])),
                "n_fallback_batches": s["n_fallback_batches"],
                "breaker_state": s["breaker"]["state"],
                "ok": bool(
                    np.array_equal(got, want[:n])
                    and s["n_fallback_batches"] > 0
                ),
            }
        finally:
            eng.shutdown()
    return out


def _leg_recovery(design, samples) -> dict:
    """Trip with two scheduled failures, then measure the wall time from
    the trip until a request is served normally again."""
    from repro.chaos import FaultInjectedError, FaultPlan, FaultRule, active
    from repro.runtime import CircuitOpenError

    plan = FaultPlan([FaultRule("serve.dispatch", at=(0, 1))])
    with active(plan):
        eng = _engine(design, breaker_threshold=2, breaker_cooldown_ms=50.0)
        try:
            for i in range(2):
                try:
                    eng.submit("m", samples[i]).result(RESOLVE_TIMEOUT_S)
                except FaultInjectedError:
                    pass
            t_trip = time.perf_counter()
            tripped = eng.stats("m")["breaker"]["state"] == "open"
            recovered_s = None
            deadline = t_trip + 5.0
            while time.perf_counter() < deadline:
                try:
                    eng.submit("m", samples[2]).result(RESOLVE_TIMEOUT_S)
                    recovered_s = time.perf_counter() - t_trip
                    break
                except (CircuitOpenError, FaultInjectedError):
                    time.sleep(0.01)
            s = eng.stats("m")
            return {
                "tripped": tripped,
                "recovery_s": recovered_s,
                "n_recoveries": s["breaker"]["n_recoveries"],
                "state": s["breaker"]["state"],
                "ok": bool(
                    tripped and recovered_s is not None
                    and s["breaker"]["state"] == "closed"
                    and s["breaker"]["n_recoveries"] >= 1
                ),
            }
        finally:
            eng.shutdown()


def _leg_soak(design, samples, n_requests: int, shards: int, seed: int) -> dict:
    """Rate-scheduled fault storm over a sharded engine; the invariant is
    full resolution + zero slab leaks, with degraded throughput recorded."""
    from repro.chaos import FaultPlan, FaultRule, active

    plan = FaultPlan(
        [
            FaultRule("serve.dispatch", rate=0.05),
            FaultRule("serve.gather", rate=0.02),
            FaultRule("serve.dispatcher", mode="kill_thread", rate=0.02, max_fires=2),
        ],
        seed=seed,
    )
    with active(plan):
        eng = _engine(
            design,
            max_batch=8, max_wait_us=200.0, shards=shards,
            fallback="interpreter",
            breaker_threshold=4, breaker_cooldown_ms=20.0,
            supervise=True, restart_budget=4,
        )
        try:
            want = np.asarray(design.forward_int(samples))
            k = len(samples)
            t0 = time.perf_counter()
            futs = []
            for i in range(0, n_requests, 16):
                chunk = [samples[(i + j) % k] for j in range(16)]
                if (i // 16) % 3 == 0:
                    futs.extend(eng.submit_batch("m", chunk))
                else:
                    futs.extend(eng.submit("m", x) for x in chunk)
            n_ok = n_err = n_hung = n_inexact = 0
            for i, f in enumerate(futs):
                try:
                    exc = f.exception(timeout=RESOLVE_TIMEOUT_S)
                except FutureTimeoutError:
                    n_hung += 1
                    continue
                if exc is None:
                    if not np.array_equal(f.result(0), want[i % k]):
                        n_inexact += 1
                    n_ok += 1
                else:
                    n_err += 1
            elapsed = time.perf_counter() - t0
            leaked = _slab_slots_leaked(eng)
            s = eng.stats("m")
            return {
                "shards": shards,
                "n_requests": len(futs),
                "n_ok": n_ok,
                "n_err": n_err,
                "n_hung": n_hung,
                "n_inexact": n_inexact,
                "slab_slots_leaked": leaked,
                "degraded_rps": len(futs) / elapsed if elapsed > 0 else 0.0,
                "n_crashes": s["supervision"]["n_crashes"],
                "n_restarts": s["supervision"]["n_restarts"],
                "healthy": s["supervision"]["healthy"],
                "n_fallback_batches": s["n_fallback_batches"],
                "breaker_trips": s["breaker"]["n_trips"],
                "fault_stats": plan.stats(),
                "all_resolved": n_hung == 0,
                "ok": bool(
                    n_hung == 0 and leaked == 0 and n_inexact == 0 and n_ok > 0
                ),
            }
        finally:
            eng.shutdown()


def _leg_overhead(design, samples, n_requests: int) -> dict:
    """Disabled-path cost: serve throughput with no plan vs an installed
    plan whose rules never target the serve sites (the serve-path
    ``fault_point`` gate still executes every batch), plus raw ns/call
    of a disabled ``fault_point``."""
    from repro.chaos import FaultPlan, FaultRule, active, fault_point

    def run_leg(n):
        eng = _engine(design, max_batch=8, max_wait_us=50.0)
        try:
            t0, c0 = time.perf_counter(), time.process_time()
            futs = [eng.submit("m", samples[i % len(samples)]) for i in range(n)]
            for f in futs:
                f.result(RESOLVE_TIMEOUT_S)
            return time.perf_counter() - t0, time.process_time() - c0
        finally:
            eng.shutdown()

    run_leg(max(64, n_requests // 8))  # warm both code paths
    disabled_wall, disabled_cpu = run_leg(n_requests)
    plan = FaultPlan([FaultRule("artifact.load.read", rate=1.0)])
    with active(plan):
        enabled_wall, enabled_cpu = run_leg(n_requests)

    n_calls = 1_000_000
    t0 = time.perf_counter()
    for _ in range(n_calls):
        fault_point("serve.dispatch")
    ns_per_call = (time.perf_counter() - t0) / n_calls * 1e9

    ratio = enabled_cpu / disabled_cpu if disabled_cpu > 0 else 1.0
    delta = enabled_cpu - disabled_cpu
    limit = 1.05
    # an absolute floor: on fast machines both legs are fractions of a
    # second and the ratio is pure scheduler noise
    ok = bool(ratio <= limit or delta <= 0.15)
    return {
        "n_requests": n_requests,
        "disabled_cpu_s": disabled_cpu,
        "enabled_cpu_s": enabled_cpu,
        "disabled_wall_s": disabled_wall,
        "enabled_wall_s": enabled_wall,
        "overhead_ratio": ratio,
        "overhead_delta_s": delta,
        "overhead_limit": limit,
        "fault_point_disabled_ns": ns_per_call,
        "ok": ok,
    }


def run(
    m: int = 16,
    w_bits: int = 8,
    soak_requests: int = 512,
    soak_shards: int = 4,
    overhead_requests: int = 1024,
    seed: int = 1234,
) -> dict:
    design, samples = _build_design(m, w_bits)
    deterministic = _leg_deterministic(design, samples)
    recovery = _leg_recovery(design, samples)
    soak = _leg_soak(design, samples, soak_requests, soak_shards, seed)
    overhead = _leg_overhead(design, samples, overhead_requests)
    return {
        "bench": "chaos_soak",
        "n_cpus": os.cpu_count(),
        "m": m,
        "w_bits": w_bits,
        "seed": seed,
        "deterministic": deterministic,
        "recovery": recovery,
        "soak": soak,
        "overhead": overhead,
    }


def passed(r: dict) -> bool:
    d = r["deterministic"]
    return bool(
        d["breaker_trip"]["ok"]
        and d["shed"]["ok"]
        and d["fallback"]["ok"]
        and r["recovery"]["ok"]
        and r["soak"]["ok"]
        and r["overhead"]["ok"]
    )


def main(csv: bool = True, json_path=None, **kw) -> dict:
    r = run(**kw)
    if csv:
        soak, ov = r["soak"], r["overhead"]
        print("name,us_per_call,derived")
        print(
            f"chaos_soak_m{r['m']},"
            f"{1e6 / max(soak['degraded_rps'], 1e-9):.1f},"
            f"degraded_rps={soak['degraded_rps']:.0f};"
            f"shards={soak['shards']};ok={soak['n_ok']};err={soak['n_err']};"
            f"hung={soak['n_hung']};leaked={soak['slab_slots_leaked']};"
            f"crashes={soak['n_crashes']};restarts={soak['n_restarts']};"
            f"fallback_batches={soak['n_fallback_batches']};"
            f"breaker_trips={soak['breaker_trips']};"
            f"healthy={int(soak['healthy'])}"
        )
        print(
            f"chaos_deterministic,0.0,"
            f"trip_ok={int(r['deterministic']['breaker_trip']['ok'])};"
            f"shed_ok={int(r['deterministic']['shed']['ok'])};"
            f"fallback_ok={int(r['deterministic']['fallback']['ok'])};"
            f"recovery_ok={int(r['recovery']['ok'])};"
            f"recovery_s={r['recovery']['recovery_s'] or -1:.3f}"
        )
        print(
            f"chaos_overhead,{ov['fault_point_disabled_ns'] / 1e3:.4f},"
            f"ratio={ov['overhead_ratio']:.3f};limit={ov['overhead_limit']};"
            f"delta_s={ov['overhead_delta_s']:+.3f};ok={int(ov['ok'])}"
        )
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(r, fh, indent=2, sort_keys=True)
        print(f"# wrote {json_path}", file=sys.stderr)
    return r


if __name__ == "__main__":
    args = sys.argv[1:]
    kw: dict = {}
    json_path = None
    if "--json" in args:
        k = args.index("--json")
        json_path = args[k + 1]
        del args[k : k + 2]

    def _pop(flag, cast):
        if flag in args:
            k = args.index(flag)
            val = cast(args[k + 1])
            del args[k : k + 2]
            return val
        return None

    v = _pop("--soak-requests", int)
    if v is not None:
        kw["soak_requests"] = v
    v = _pop("--soak-shards", int)
    if v is not None:
        kw["soak_shards"] = v
    v = _pop("--seed", int)
    if v is not None:
        kw["seed"] = v
    result = main(json_path=json_path, **kw)
    sys.exit(0 if passed(result) else 1)
