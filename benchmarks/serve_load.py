"""Serve-load bench: compile-once / serve-many under sustained traffic.

End-to-end exercise of the deployable runtime (repro.runtime): compile a
32x32 8-bit CMVM model, round-trip it through the ``save_design`` /
``load_design`` artifact (verifying bit-exactness and that the cold
start performs **zero** CMVM solves), register the loaded design in the
microbatched :class:`ServeEngine`, and drive it with a load generator:

  closed loop   N workers, each submit -> wait -> repeat (throughput =
                N / latency; measures sustainable service rate);
  open loop     Poisson arrivals at ``target_rps`` regardless of
                completions (measures latency under offered load,
                including queueing delay).

Prints the usual ``name,us_per_call,derived`` CSV and writes a
``BENCH_serve.json``-compatible report (``--json PATH``) with achieved
throughput, p50/p95/p99 latency, batch occupancy, and artifact timings.
Exit code 1 if the engine cannot sustain ``min_rps`` or the artifact
round-trip is not bit-exact.
"""

from __future__ import annotations

import json
import sys
import tempfile
import threading
import time

import numpy as np


def build_model(m: int = 32, w_bits: int = 8):
    """One m x m dense CMVM with 8-bit weights (the acceptance model)."""
    from repro.nn import QDense, QuantConfig

    wq = QuantConfig(w_bits, 2, signed=True)
    model = (QDense(m, wq),)
    in_quant = QuantConfig(8, 4, signed=True)
    return model, (m,), in_quant


def _compile_and_roundtrip(m, w_bits, tmpdir, seed=0):
    import jax

    from repro.nn import compile_model, init_params
    from repro.runtime import load_design, save_design

    model, in_shape, in_quant = build_model(m, w_bits)
    params, _ = init_params(jax.random.PRNGKey(seed), model, in_shape)
    t0 = time.perf_counter()
    design = compile_model(model, params, in_shape, in_quant, dc=2)
    compile_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    save_design(design, f"{tmpdir}/design")
    save_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    loaded = load_design(f"{tmpdir}/design")
    load_s = time.perf_counter() - t0

    rng = np.random.default_rng(seed)
    q = in_quant.qint
    x = rng.integers(q.lo, q.hi + 1, size=(64, *in_shape)).astype(np.int32)
    bit_exact = bool(
        np.array_equal(np.asarray(design.forward_int(x)), np.asarray(loaded.forward_int(x)))
    )
    artifact = {
        "save_s": save_s,
        "load_s": load_s,
        "bit_exact": bit_exact,
        "n_solves_on_load": loaded.solver_stats["n_solves"],
        "digests_match": [
            a.digest == b.digest for a, b in zip(design.tables, loaded.tables)
        ],
    }
    return loaded, in_shape, in_quant, compile_s, artifact


def _closed_loop(engine, name, samples, duration_s, workers, window):
    """Fixed-concurrency load: ``workers`` generator threads, each with
    ``window`` requests in flight (total concurrency workers*window).

    Pipelining matters: with a window, ``result()`` usually pops an
    already-completed future, so a generator thread is only descheduled
    when the whole window is pending — per-request thread wakeups (the
    throughput ceiling of a submit->wait->repeat loop) disappear.
    """
    stop_t = time.perf_counter() + duration_s
    counts = [0] * workers

    def work(i):
        from collections import deque

        dq: deque = deque()
        n = 0
        k = len(samples)
        while time.perf_counter() < stop_t:
            while len(dq) < window:
                dq.append(engine.submit(name, samples[(i + n) % k]))
                n += 1
            dq.popleft().result(30)
        for f in dq:
            f.result(30)
        counts[i] = n

    threads = [threading.Thread(target=work, args=(i,)) for i in range(workers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    elapsed = time.perf_counter() - t0
    return sum(counts), elapsed


def _open_loop(engine, name, samples, duration_s, target_rps, seed=0):
    rng = np.random.default_rng(seed)
    k = len(samples)
    futures = []
    t0 = time.perf_counter()
    t_next = t0
    n = 0
    while True:
        now = time.perf_counter()
        if now - t0 >= duration_s:
            break
        if now < t_next:
            time.sleep(min(t_next - now, 0.001))
            continue
        futures.append(engine.submit(name, samples[n % k]))
        n += 1
        t_next += rng.exponential(1.0 / target_rps)
    for f in futures:
        f.result(30)
    elapsed = time.perf_counter() - t0
    return n, elapsed


def run(
    mode: str = "closed",
    m: int = 32,
    w_bits: int = 8,
    duration_s: float = 2.0,
    workers: int = 4,
    window: int = 32,
    target_rps: float = 20_000.0,
    max_batch: int = 256,
    max_wait_us: float = 200.0,
    min_rps: float = 10_000.0,
    seed: int = 0,
) -> dict:
    from repro.runtime import ServeEngine

    with tempfile.TemporaryDirectory() as tmpdir:
        loaded, in_shape, in_quant, compile_s, artifact = _compile_and_roundtrip(
            m, w_bits, tmpdir, seed
        )

    rng = np.random.default_rng(seed + 1)
    q = in_quant.qint
    samples = [
        np.asarray(rng.integers(q.lo, q.hi + 1, size=in_shape), np.int32)
        for _ in range(256)
    ]

    engine = ServeEngine(max_batch=max_batch, max_wait_us=max_wait_us)
    engine.register("bench", loaded)
    warmup_s = engine.warmup("bench")
    try:
        if mode == "closed":
            n_done, elapsed = _closed_loop(
                engine, "bench", samples, duration_s, workers, window
            )
        elif mode == "open":
            n_done, elapsed = _open_loop(
                engine, "bench", samples, duration_s, target_rps, seed
            )
        else:
            raise ValueError(f"unknown mode {mode!r}")
        stats = engine.stats("bench")
    finally:
        engine.shutdown()

    achieved = n_done / elapsed if elapsed > 0 else 0.0
    return {
        "bench": "serve_load",
        "mode": mode,
        "m": m,
        "w_bits": w_bits,
        "duration_s": duration_s,
        "workers": workers if mode == "closed" else None,
        "window": window if mode == "closed" else None,
        "concurrency": workers * window if mode == "closed" else None,
        "target_rps": target_rps if mode == "open" else None,
        "n_requests": n_done,
        "achieved_rps": achieved,
        "min_rps": min_rps,
        "sustained": achieved >= min_rps,
        "p50_ms": stats["p50_ms"],
        "p95_ms": stats["p95_ms"],
        "p99_ms": stats["p99_ms"],
        "mean_ms": stats["mean_ms"],
        "n_batches": stats["n_batches"],
        "mean_batch_occupancy": stats["mean_batch_occupancy"],
        "n_rejected": stats["n_rejected"],
        "compile_s": compile_s,
        "engine_warmup_s": warmup_s,
        "artifact": artifact,
    }


def passed(r: dict) -> bool:
    a = r["artifact"]
    return bool(
        r["sustained"]
        and a["bit_exact"]
        and a["n_solves_on_load"] == 0
        and all(a["digests_match"])
    )


def main(csv: bool = True, json_path=None, **kw) -> dict:
    r = run(**kw)
    if csv:
        print("name,us_per_call,derived")
        print(
            f"serve_load_{r['mode']}_m{r['m']},{1e6 / max(r['achieved_rps'], 1e-9):.1f},"
            f"rps={r['achieved_rps']:.0f};p50_ms={r['p50_ms']:.3f};"
            f"p99_ms={r['p99_ms']:.3f};batches={r['n_batches']};"
            f"occupancy={r['mean_batch_occupancy']:.2f};"
            f"artifact_bit_exact={int(r['artifact']['bit_exact'])};"
            f"load_solves={r['artifact']['n_solves_on_load']};"
            f"cold_start_ms={r['artifact']['load_s'] * 1e3:.1f};"
            f"sustained={int(r['sustained'])}"
        )
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(r, fh, indent=2, sort_keys=True)
        print(f"# wrote {json_path}", file=sys.stderr)
    return r


if __name__ == "__main__":
    args = sys.argv[1:]
    kw: dict = {}
    json_path = None
    if "--json" in args:
        k = args.index("--json")
        json_path = args[k + 1]
        del args[k : k + 2]
    if "--mode" in args:
        k = args.index("--mode")
        kw["mode"] = args[k + 1]
        del args[k : k + 2]
    if "--min-rps" in args:
        k = args.index("--min-rps")
        kw["min_rps"] = float(args[k + 1])
        del args[k : k + 2]
    if "--duration" in args:
        k = args.index("--duration")
        kw["duration_s"] = float(args[k + 1])
        del args[k : k + 2]
    result = main(json_path=json_path, **kw)
    sys.exit(0 if passed(result) else 1)
