"""Serve-load bench: compile-once / serve-many under sustained traffic.

End-to-end exercise of the ``repro.flow`` deployment path: compile a
32x32 8-bit CMVM model with ``Flow.compile``, round-trip it through the
``design.save`` / ``Flow.load`` artifact (verifying bit-exactness and
that the cold start performs **zero** CMVM solves), register the loaded
design as version 1 of a :class:`Deployment` running the **sharded**
dispatch path (``ServeConfig.shards``), and drive it with a load
generator:

  closed loop   N workers, each submit -> wait -> repeat (throughput =
                N / latency; measures sustainable service rate);
  open loop     Poisson arrivals at ``target_rps`` regardless of
                completions (measures latency under offered load,
                including queueing delay).

After the measured phase the bench exercises a **version rollout** under
traffic: a window of in-flight v1 requests is submitted (via
``submit_batch``), v2 is registered — atomic alias flip, v1 drained —
and the bench asserts the in-flight futures completed and that post-
rollout traffic is served by v2.  With ``compare_single`` (default) a
second measured phase repeats the load on a one-shard deployment, so
the report carries the sharded-vs-single-dispatcher speedup on the same
machine.

Prints the usual ``name,us_per_call,derived`` CSV and writes a
``BENCH_serve.json``-compatible report (``--json PATH``) with achieved
throughput, p50/p95/p99 latency, per-stage latency accounting (queue
wait / batch-form / pad / dispatch / copy-out), per-shard counter
consistency, batch occupancy, artifact timings, the rollout result, and
the single-dispatcher reference.  Exit code 1 if the engine cannot
sustain ``min_rps``, p99 exceeds the ``slo_p99_ms`` SLO, per-shard
counters do not reconcile, the artifact round-trip is not bit-exact, or
the rollout fails.  The committed repo-root ``BENCH_serve.json`` is the
trajectory baseline compared by ``benchmarks/perf_gate.py --kind serve``.
"""

from __future__ import annotations

import json
import os
import sys
import tempfile
import threading
import time

import numpy as np


def build_model(m: int = 32, w_bits: int = 8):
    """One m x m dense CMVM with 8-bit weights (the acceptance model)."""
    from repro.nn import QDense, QuantConfig

    wq = QuantConfig(w_bits, 2, signed=True)
    model = (QDense(m, wq),)
    in_quant = QuantConfig(8, 4, signed=True)
    return model, (m,), in_quant


def _compile_and_roundtrip(m, w_bits, tmpdir, seed=0):
    import jax

    from repro.flow import CompileConfig, Flow, SolverConfig
    from repro.nn import init_params

    model, in_shape, in_quant = build_model(m, w_bits)
    params, _ = init_params(jax.random.PRNGKey(seed), model, in_shape)
    cfg = CompileConfig(solver=SolverConfig(dc=2))
    t0 = time.perf_counter()
    design = Flow.compile(model, params, in_shape, in_quant, config=cfg)
    compile_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    design.save(f"{tmpdir}/design")
    save_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    loaded = Flow.load(f"{tmpdir}/design")
    load_s = time.perf_counter() - t0
    # second cold start: becomes v2 in the rollout phase (identical bits,
    # distinct design object — the registry treats it as a new rollout)
    loaded_v2 = Flow.load(f"{tmpdir}/design")

    rng = np.random.default_rng(seed)
    q = in_quant.qint
    x = rng.integers(q.lo, q.hi + 1, size=(64, *in_shape)).astype(np.int32)
    bit_exact = bool(
        np.array_equal(np.asarray(design.forward_int(x)), np.asarray(loaded.forward_int(x)))
    )
    artifact = {
        "save_s": save_s,
        "load_s": load_s,
        "bit_exact": bit_exact,
        "n_solves_on_load": loaded.solver_stats["n_solves"],
        "digests_match": [
            a.digest == b.digest for a, b in zip(design.tables, loaded.tables)
        ],
        "config_roundtrip": (
            loaded.config is not None and loaded.config.digest() == cfg.digest()
        ),
    }
    return loaded, loaded_v2, in_shape, in_quant, compile_s, artifact


def _closed_loop(engine, name, samples, duration_s, workers, window,
                 batch_submit: int = 0):
    """Fixed-concurrency load: ``workers`` generator threads, each with
    ``window`` requests in flight (total concurrency workers*window).

    Pipelining matters: with a window, ``result()`` usually pops an
    already-completed future, so a generator thread is only descheduled
    when the whole window is pending — per-request thread wakeups (the
    throughput ceiling of a submit->wait->repeat loop) disappear.  With
    ``batch_submit`` > 1 the generators refill their window through
    ``submit_batch`` chunks of that size (clients that already hold
    several requests), exercising the amortized slab write path.
    """
    stop_t = time.perf_counter() + duration_s
    counts = [0] * workers

    def work(i):
        from collections import deque

        dq: deque = deque()
        n = 0
        k = len(samples)
        while time.perf_counter() < stop_t:
            if batch_submit > 1:
                while len(dq) < window:
                    chunk = [
                        samples[(i + n + j) % k] for j in range(batch_submit)
                    ]
                    dq.extend(engine.submit_batch(name, chunk))
                    n += batch_submit
            else:
                while len(dq) < window:
                    dq.append(engine.submit(name, samples[(i + n) % k]))
                    n += 1
            dq.popleft().result(30)
        for f in dq:
            f.result(30)
        counts[i] = n

    threads = [threading.Thread(target=work, args=(i,)) for i in range(workers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    elapsed = time.perf_counter() - t0
    return sum(counts), elapsed


def _open_loop(engine, name, samples, duration_s, target_rps, seed=0):
    rng = np.random.default_rng(seed)
    k = len(samples)
    futures = []
    t0 = time.perf_counter()
    t_next = t0
    n = 0
    while True:
        now = time.perf_counter()
        if now - t0 >= duration_s:
            break
        if now < t_next:
            time.sleep(min(t_next - now, 0.001))
            continue
        futures.append(engine.submit(name, samples[n % k]))
        n += 1
        t_next += rng.exponential(1.0 / target_rps)
    for f in futures:
        f.result(30)
    elapsed = time.perf_counter() - t0
    return n, elapsed


def _rollout_under_traffic(dep, v2_design, samples, duration_s=0.3):
    """Register v2 while v1 has a window of in-flight requests: the alias
    must flip, v1 must drain (every in-flight future completes), and a
    short post-rollout closed loop must be served by v2."""
    v1 = dep.active_version("bench")
    inflight = dep.submit_batch("bench", samples[:128])
    t0 = time.perf_counter()
    v2 = dep.register("bench", v2_design, warmup=True)
    rollout_s = time.perf_counter() - t0
    completed = 0
    for f in inflight:
        f.result(30)
        completed += 1
    n_post, el_post = _closed_loop(dep, "bench", samples, duration_s, 2, 8)
    return {
        "from_version": v1,
        "to_version": v2,
        "rollout_s": rollout_s,
        "inflight_completed": completed,
        "inflight_submitted": len(inflight),
        "v1_drained": dep.versions("bench") == [v2],
        "active_version": dep.active_version("bench"),
        "post_rollout_requests": n_post,
        "post_rollout_rps": n_post / el_post if el_post > 0 else 0.0,
        "ok": bool(
            completed == len(inflight)
            and dep.versions("bench") == [v2]
            and dep.active_version("bench") == v2
            and n_post > 0
        ),
    }


def _shard_consistency(stats: dict) -> bool:
    """Every shard's bucket histogram must reconcile with its own batch
    count, and the aggregates must be the shard sums."""
    shards = stats.get("shards", [])
    per_shard = all(
        sum(ss["bucket_hits"].values()) == ss["n_batches"] for ss in shards
    )
    agg = sum(stats["bucket_hits"].values()) == stats["n_batches"]
    sums = stats["n_batches"] == sum(ss["n_batches"] for ss in shards)
    return bool(per_shard and agg and sums)


def _measure(design, mode, samples, duration_s, workers, window, target_rps,
             max_batch, max_wait_us, shards, batch_submit, seed):
    """One measured phase on a fresh deployment; returns the load + stats
    summary (the deployment is shut down before returning)."""
    from repro.flow import Flow, ServeConfig

    dep = Flow.serve(
        ServeConfig(max_batch=max_batch, max_wait_us=max_wait_us, shards=shards)
    )
    dep.register("bench", design)
    warmup_s = dep.warmup("bench")
    try:
        if mode == "closed":
            n_done, elapsed = _closed_loop(
                dep, "bench", samples, duration_s, workers, window, batch_submit
            )
        elif mode == "open":
            n_done, elapsed = _open_loop(
                dep, "bench", samples, duration_s, target_rps, seed
            )
        else:
            raise ValueError(f"unknown mode {mode!r}")
        stats = dep.stats("bench")
    finally:
        dep.shutdown()
    achieved = n_done / elapsed if elapsed > 0 else 0.0
    return {
        "shards": shards,
        "n_requests": n_done,
        "achieved_rps": achieved,
        "p50_ms": stats["p50_ms"],
        "p95_ms": stats["p95_ms"],
        "p99_ms": stats["p99_ms"],
        "mean_ms": stats["mean_ms"],
        "n_batches": stats["n_batches"],
        "mean_batch_occupancy": stats["mean_batch_occupancy"],
        "n_rejected": stats["n_rejected"],
        "per_stage": stats["per_stage"],
        # cross-shard flight-recorder snapshot: the slowest-K requests with
        # their full per-stage µs breakdowns (the p99 postmortem payload)
        "flight": stats["flight"],
        "shard_consistency": _shard_consistency(stats),
        "engine_warmup_s": warmup_s,
    }


def run(
    mode: str = "closed",
    m: int = 32,
    w_bits: int = 8,
    duration_s: float = 2.0,
    workers: int = 4,
    window: int = 32,
    target_rps: float = 20_000.0,
    max_batch: int = 256,
    max_wait_us: float = 200.0,
    min_rps: float = 10_000.0,
    shards: int = 4,
    batch_submit: int = 16,
    slo_p99_ms: float = 50.0,
    compare_single: bool = True,
    seed: int = 0,
) -> dict:
    from repro.flow import Flow, ServeConfig

    with tempfile.TemporaryDirectory() as tmpdir:
        loaded, loaded_v2, in_shape, in_quant, compile_s, artifact = (
            _compile_and_roundtrip(m, w_bits, tmpdir, seed)
        )

    rng = np.random.default_rng(seed + 1)
    q = in_quant.qint
    samples = [
        np.asarray(rng.integers(q.lo, q.hi + 1, size=in_shape), np.int32)
        for _ in range(256)
    ]

    # measured phase: the sharded dispatch path
    sharded = _measure(
        loaded, mode, samples, duration_s, workers, window, target_rps,
        max_batch, max_wait_us, shards, batch_submit, seed,
    )

    # single-dispatcher reference on the same machine (shards=1, same
    # workload): the denominator of the sharding speedup claim
    single = None
    if compare_single and shards > 1:
        single = _measure(
            loaded, mode, samples, duration_s, workers, window, target_rps,
            max_batch, max_wait_us, 1, batch_submit, seed,
        )

    # rollout under traffic on a fresh sharded deployment
    dep = Flow.serve(
        ServeConfig(max_batch=max_batch, max_wait_us=max_wait_us, shards=shards)
    )
    dep.register("bench", loaded)
    dep.warmup("bench")
    try:
        rollout = _rollout_under_traffic(dep, loaded_v2, samples)
    finally:
        dep.shutdown()

    achieved = sharded["achieved_rps"]
    slo_ok = bool(slo_p99_ms is None or sharded["p99_ms"] <= slo_p99_ms)
    return {
        "bench": "serve_load",
        "mode": mode,
        # shard speedup is parallelism: it needs cores.  Recording the
        # machine's core count makes a 1-core baseline's ~1.0x speedup
        # self-explanatory next to a many-core run's larger one.
        "n_cpus": os.cpu_count(),
        "m": m,
        "w_bits": w_bits,
        "duration_s": duration_s,
        "workers": workers if mode == "closed" else None,
        "window": window if mode == "closed" else None,
        "concurrency": workers * window if mode == "closed" else None,
        "batch_submit": batch_submit if mode == "closed" else None,
        "target_rps": target_rps if mode == "open" else None,
        "shards": shards,
        "n_requests": sharded["n_requests"],
        "achieved_rps": achieved,
        "min_rps": min_rps,
        "sustained": achieved >= min_rps,
        "slo_p99_ms": slo_p99_ms,
        "slo_ok": slo_ok,
        "p50_ms": sharded["p50_ms"],
        "p95_ms": sharded["p95_ms"],
        "p99_ms": sharded["p99_ms"],
        "mean_ms": sharded["mean_ms"],
        "n_batches": sharded["n_batches"],
        "mean_batch_occupancy": sharded["mean_batch_occupancy"],
        "n_rejected": sharded["n_rejected"],
        "per_stage": sharded["per_stage"],
        "flight": sharded["flight"],
        "shard_consistency": sharded["shard_consistency"],
        "single_dispatcher": single,
        "shard_speedup": (
            achieved / single["achieved_rps"]
            if single and single["achieved_rps"] > 0
            else None
        ),
        "compile_s": compile_s,
        "engine_warmup_s": sharded["engine_warmup_s"],
        "artifact": artifact,
        "rollout": rollout,
    }


def passed(r: dict) -> bool:
    a = r["artifact"]
    return bool(
        r["sustained"]
        and r["slo_ok"]
        and r["shard_consistency"]
        and a["bit_exact"]
        and a["n_solves_on_load"] == 0
        and all(a["digests_match"])
        and a["config_roundtrip"]
        and r["rollout"]["ok"]
    )


def main(csv: bool = True, json_path=None, **kw) -> dict:
    r = run(**kw)
    if csv:
        speedup = r["shard_speedup"]
        speedup_field = (
            f"speedup_vs_single={speedup:.2f};" if speedup is not None else ""
        )
        print("name,us_per_call,derived")
        print(
            f"serve_load_{r['mode']}_m{r['m']},"
            f"{1e6 / max(r['achieved_rps'], 1e-9):.1f},"
            f"rps={r['achieved_rps']:.0f};shards={r['shards']};"
            f"{speedup_field}"
            f"p50_ms={r['p50_ms']:.3f};p99_ms={r['p99_ms']:.3f};"
            f"slo_ok={int(r['slo_ok'])};batches={r['n_batches']};"
            f"occupancy={r['mean_batch_occupancy']:.2f};"
            f"artifact_bit_exact={int(r['artifact']['bit_exact'])};"
            f"load_solves={r['artifact']['n_solves_on_load']};"
            f"cold_start_ms={r['artifact']['load_s'] * 1e3:.1f};"
            f"sustained={int(r['sustained'])};"
            f"rollout_ok={int(r['rollout']['ok'])};"
            f"rollout_v{r['rollout']['from_version']}to{r['rollout']['to_version']}"
        )
        slowest = r["flight"].get("slowest", [])
        if slowest:
            # the flight recorder's p99 postmortem: where the single
            # slowest request of the measured phase spent its time
            s = slowest[0]
            stages = ";".join(
                f"{k}_us={v:.0f}" for k, v in s["stages_us"].items()
            )
            print(
                f"serve_load_slowest,{s['lat_us']:.0f},"
                f"trace_id={s['trace_id']};shard={s['shard']};"
                f"bucket={s['bucket']};batch={s['batch_size']};{stages}"
            )
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(r, fh, indent=2, sort_keys=True)
        print(f"# wrote {json_path}", file=sys.stderr)
        from repro.obs import trace

        if trace.enabled():
            # merged Perfetto timeline for this run (compile + solve pool
            # + every dispatcher shard), next to the JSON report
            tpath = json_path.rsplit(".json", 1)[0] + "-trace.json"
            trace.export(tpath)
            print(f"# wrote {tpath}", file=sys.stderr)
    return r


if __name__ == "__main__":
    args = sys.argv[1:]
    kw: dict = {}
    json_path = None

    def _pop(flag, cast=float):
        if flag in args:
            k = args.index(flag)
            val = cast(args[k + 1])
            del args[k : k + 2]
            return val
        return None

    if "--json" in args:
        k = args.index("--json")
        json_path = args[k + 1]
        del args[k : k + 2]
    v = _pop("--mode", str)
    if v is not None:
        kw["mode"] = v
    v = _pop("--min-rps")
    if v is not None:
        kw["min_rps"] = v
    v = _pop("--duration")
    if v is not None:
        kw["duration_s"] = v
    v = _pop("--shards", int)
    if v is not None:
        kw["shards"] = v
    v = _pop("--batch-submit", int)
    if v is not None:
        kw["batch_submit"] = v
    v = _pop("--slo-p99-ms")
    if v is not None:
        kw["slo_p99_ms"] = v
    if "--no-compare-single" in args:
        args.remove("--no-compare-single")
        kw["compare_single"] = False
    result = main(json_path=json_path, **kw)
    sys.exit(0 if passed(result) else 1)
