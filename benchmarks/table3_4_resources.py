"""Paper Tables 3-4: post-synthesis resource proxies on random matrices.

Without Vivado we report the implementation-independent columns the
solver controls: adder count (the paper prints it in the same tables),
cost-model LUT bits (Eq. 1 summed — tracks the paper's LUT column), FF
bits from pipelining, and adder depth vs the delay constraint.  Paper
adder counts are embedded for the delta.  8-bit and 4-bit weight
matrices, 8-bit inputs, matching the paper's setup.
"""

from __future__ import annotations

import numpy as np

from repro.core import naive_adder_tree, pipeline, solve_cmvm
from repro.flow import SolverConfig

# (bw, size, dc) -> paper adder count ('latency' baseline keyed dc=None)
PAPER_ADDERS = {
    (8, 8, None): 211, (8, 8, 0): 123, (8, 8, 2): 97, (8, 8, -1): 93,
    (8, 16, None): 845, (8, 16, 0): 436, (8, 16, 2): 361, (8, 16, -1): 349,
    (8, 32, None): 3501, (8, 32, 0): 1591, (8, 32, 2): 1263, (8, 32, -1): 1228,
    (8, 64, None): 14089, (8, 64, 0): 5715, (8, 64, 2): 5293, (8, 64, -1): 4428,
    (4, 8, None): 124, (4, 8, 0): 71, (4, 8, 2): 55, (4, 8, -1): 52,
    (4, 16, None): 529, (4, 16, 0): 269, (4, 16, 2): 195, (4, 16, -1): 178,
    (4, 32, None): 2108, (4, 32, 0): 927, (4, 32, 2): 653, (4, 32, -1): 625,
    (4, 64, None): 8724, (4, 64, 0): 3408, (4, 64, 2): 2371, (4, 64, -1): 2255,
}


def run(sizes=(8, 16, 32), bws=(8, 4), dcs=(0, 2, -1), seed=0):
    rng = np.random.default_rng(seed)
    rows = []
    for bw in bws:
        for m in sizes:
            mat = rng.integers(2 ** (bw - 1) + 1, 2**bw, size=(m, m))
            base = naive_adder_tree(mat)
            rows.append(
                {
                    "bw": bw, "size": m, "dc": "latency",
                    "adders": base.n_adders,
                    "paper_adders": PAPER_ADDERS.get((bw, m, None)),
                    "lut_bits": base.cost_bits,
                    "ff_bits": pipeline(base.program).ff_bits,
                    "depth": base.depth,
                }
            )
            for dc in dcs:
                sol = solve_cmvm(mat, config=SolverConfig(dc=dc))
                assert sol.verify()
                rows.append(
                    {
                        "bw": bw, "size": m, "dc": dc,
                        "adders": sol.n_adders,
                        "paper_adders": PAPER_ADDERS.get((bw, m, dc)),
                        "lut_bits": sol.cost_bits,
                        "ff_bits": pipeline(sol.program).ff_bits,
                        "depth": sol.depth,
                    }
                )
    return rows


def main(csv=True):
    rows = run()
    if csv:
        print("name,us_per_call,derived")
        for r in rows:
            print(
                f"table34_bw{r['bw']}_m{r['size']}_dc{r['dc']},0,"
                f"adders={r['adders']};paper={r['paper_adders']};"
                f"lutbits={r['lut_bits']};ffbits={r['ff_bits']};depth={r['depth']}"
            )
    return rows


if __name__ == "__main__":
    main()
