"""Paper Fig. 7: solver runtime scaling on random matrices up to
128 x 128 x 8-bit, vs the O(N^2 log^2 N) asymptote (N = m^2 * bw).

Our pure-Python+numpy implementation carries a constant-factor penalty
vs the paper's Numba JIT; the *scaling exponent* is the reproduction
target (fit printed at the end).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import SolutionCache, solve_cmvm
from repro.flow import SolverConfig


def run(sizes=(8, 16, 32, 64), bw=8, seed=0, budget_s=600.0, cache=None,
        engine="batch"):
    """Solve one random m x m matrix per size; with a cache, also time the
    warm re-solve (content-addressed hit, no CSE run)."""
    rng = np.random.default_rng(seed)
    cfg = SolverConfig(dc=-1, engine=engine)
    rows = []
    spent = 0.0
    for m in sizes:
        if spent > budget_s:
            break
        mat = rng.integers(2 ** (bw - 1) + 1, 2**bw, size=(m, m))
        t0 = time.perf_counter()
        sol = solve_cmvm(mat, config=cfg, cache=cache)
        dt = time.perf_counter() - t0
        spent += dt
        row = {"m": m, "N": m * m * bw, "seconds": dt, "adders": sol.n_adders}
        if cache is not None:
            t0 = time.perf_counter()
            hot = solve_cmvm(mat, config=cfg, cache=cache)
            row["cached_seconds"] = time.perf_counter() - t0
            assert hot.stats.get("cache_hit") and hot.n_adders == sol.n_adders
        rows.append(row)
    return rows


def main(csv=True):
    rows = run(cache=SolutionCache())
    arena_rows = run(engine="arena")
    if len(rows) >= 3:
        logn = np.log([r["N"] for r in rows])
        logt = np.log([r["seconds"] for r in rows])
        slope = np.polyfit(logn, logt, 1)[0]
    else:
        slope = float("nan")
    if csv:
        print("name,us_per_call,derived")
        # pair by size, not position: either run may truncate at its
        # time budget, and a positional zip would mispair the survivors
        arena_by_m = {r["m"]: r for r in arena_rows}
        for r in rows:
            print(
                f"fig7_m{r['m']},{r['seconds']*1e6:.0f},"
                f"N={r['N']};adders={r['adders']}"
            )
            ra = arena_by_m.get(r["m"])
            if ra is not None:
                print(
                    f"fig7_m{r['m']}_arena,{ra['seconds']*1e6:.0f},"
                    f"speedup_vs_batch={r['seconds']/max(ra['seconds'],1e-9):.2f}x"
                )
            if "cached_seconds" in r:
                speedup = r["seconds"] / max(r["cached_seconds"], 1e-9)
                print(
                    f"fig7_m{r['m']}_cached,{r['cached_seconds']*1e6:.0f},"
                    f"hit_speedup={speedup:.0f}x"
                )
        print(f"fig7_scaling_exponent,0,slope={slope:.2f};paper~2.0-2.3")
    return rows, slope


if __name__ == "__main__":
    main()
