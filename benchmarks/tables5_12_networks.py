"""Paper Tables 5-12: network-level DA vs latency-strategy comparison.

For each benchmark network (§6.2) we compile both strategies and report
adders, LUT-bit estimate, FF bits, depth and pipeline latency — the
solver-controlled quantities behind the paper's LUT/FF/latency columns —
plus the DA/latency resource ratio (the paper's headline: up to ~1/3 LUT
reduction, DSPs eliminated by construction).
"""

from __future__ import annotations

import time

import jax

from repro.flow import CompileConfig, SolverConfig
from repro.nn import compile_model, init_params, models


def _bench_net(name, builder, dc=2, seed=0):
    model, in_shape, in_quant = builder()
    params, _ = init_params(jax.random.PRNGKey(seed), model, in_shape)
    out = []
    for strategy in ("latency", "da"):
        cfg = CompileConfig(strategy=strategy, solver=SolverConfig(dc=dc))
        t0 = time.perf_counter()
        design = compile_model(model, params, in_shape, in_quant, config=cfg)
        dt = time.perf_counter() - t0
        out.append(
            {
                "net": name,
                "strategy": strategy,
                "adders": design.total_adders,
                "lut_bits": design.total_cost_bits,
                "ff_bits": design.total_ff_bits,
                "latency_cycles": design.latency_cycles,
                "max_depth": design.max_depth,
                "compile_s": dt,
            }
        )
    return out


def run(include_svhn=False):
    nets = [
        ("jet_tagger", models.jet_tagger),
        ("muon_tracker", models.muon_tracker),
        ("mlp_mixer_jet", lambda: models.mlp_mixer_jet(n_particles=16, n_features=16)),
    ]
    if include_svhn:
        nets.append(("svhn_cnn", models.svhn_cnn))
    rows = []
    for name, builder in nets:
        rows.extend(_bench_net(name, builder))
    return rows


def main(csv=True, include_svhn=False):
    rows = run(include_svhn)
    if csv:
        print("name,us_per_call,derived")
        by_net = {}
        for r in rows:
            by_net.setdefault(r["net"], {})[r["strategy"]] = r
            print(
                f"net_{r['net']}_{r['strategy']},{r['compile_s']*1e6:.0f},"
                f"adders={r['adders']};lutbits={r['lut_bits']};ffbits={r['ff_bits']};"
                f"latency={r['latency_cycles']};depth={r['max_depth']}"
            )
        for net, d in by_net.items():
            if "da" in d and "latency" in d:
                ratio = d["da"]["lut_bits"] / max(d["latency"]["lut_bits"], 1)
                print(f"net_{net}_lut_ratio,0,da_over_latency={ratio:.3f}")
    return rows


if __name__ == "__main__":
    main()
