"""Design-lint bench: statically verify a compile smoke corpus.

Compiles a small corpus spanning the layer/step vocabulary — dense MLPs
across the full strategy x engine grid, a conv/pool net, and the
mixer (residual + transpose + axis-dense) — then runs the strict tier of
``repro.analysis.verify_design`` on every design, plus the artifact
auditor on a save/load round trip and on any committed ``da4ml-design``
artifacts found in the repository.  A final leg compiles a 64x64 dense
layer with the default ``verify="cheap"`` gate and measures the
verifier's share of the compile wall clock (from
``solver_stats["verify"]["wall_s"]``), which must stay under 5%.

``passed`` folds every check into the exit code: any error-severity
diagnostic on any corpus design, any artifact-audit error, or a verify
overhead above budget fails the job.  ``--json PATH`` (via
``benchmarks.run lint --json``) writes the full diagnostics document —
the per-SHA CI artifact the design-lint job archives.
"""

from __future__ import annotations

import json
import sys
import tempfile
import time
from pathlib import Path

_REPO_ROOT = Path(__file__).resolve().parent.parent

# verify-overhead budget: cheap tier must cost <5% of a 64x64 compile
OVERHEAD_BUDGET = 0.05


def _corpus():
    """(name, model builder, in_shape, in_quant, config) smoke corpus."""
    from repro.flow import CompileConfig, SolverConfig
    from repro.nn import (
        AvgPool2D,
        Flatten,
        MaxPool2D,
        QConv2D,
        QDense,
        QuantConfig,
        ReLU,
        models,
    )

    wq = QuantConfig(6, 2, signed=True)
    aq = QuantConfig(8, 4, signed=False)
    dense = (QDense(12, wq), ReLU(aq), QDense(5, wq))
    conv = (
        QConv2D(4, (3, 3), w_quant=wq), ReLU(aq), MaxPool2D((2, 2)),
        AvgPool2D((2, 2)), Flatten(), QDense(3, wq),
    )
    mixer, mixer_shape, mixer_q = models.mlp_mixer_jet(
        n_particles=4, n_features=4, d_ff=4
    )

    cases = []
    for strategy in ("da", "latency"):
        for engine in ("batch", "arena", "heap"):
            cfg = CompileConfig(
                strategy=strategy,
                solver=SolverConfig(dc=2, engine=engine),
                verify="off",  # the bench collects diagnostics itself
            )
            cases.append(
                (f"dense[{strategy}/{engine}]", dense, (10,),
                 QuantConfig(8, 4, signed=True), cfg)
            )
    base = CompileConfig(solver=SolverConfig(dc=2), verify="off")
    cases.append(("conv[da/batch]", conv, (10, 10, 2),
                  QuantConfig(8, 1, signed=False), base))
    cases.append(("mixer[da/batch]", mixer, mixer_shape, mixer_q, base))
    return cases


def _verify_one(design_or_path, tier="strict") -> dict:
    from repro.analysis import verify_design

    rep = verify_design(design_or_path, tier=tier)
    return {
        "ok": rep.ok,
        "n_errors": len(rep.errors),
        "n_warnings": len(rep.warnings),
        "codes": sorted(rep.codes()),
        "diagnostics": [d.to_dict() for d in rep.diagnostics],
        "pass_wall_s": {
            k: v for k, v in rep.pass_wall_s.items() if isinstance(v, float)
        },
    }


def _committed_artifacts() -> list:
    """Committed da4ml-design artifact dirs (manifest.json anywhere in
    the tree outside build/venv dirs)."""
    found = []
    for mf in _REPO_ROOT.rglob("manifest.json"):
        if any(part.startswith(".") or part in ("build", "node_modules")
               for part in mf.relative_to(_REPO_ROOT).parts):
            continue
        try:
            if json.loads(mf.read_text()).get("format") == "da4ml-design":
                found.append(mf.parent)
        except (OSError, ValueError):
            continue
    return sorted(found)


def main(json_path=None) -> dict:
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.flow import CompileConfig, SolverConfig
    from repro.nn import QDense, QuantConfig, compile_model, init_params
    from repro.runtime import save_design

    designs = {}
    keep_one = None
    for name, model, in_shape, in_quant, cfg in _corpus():
        params, _ = init_params(jax.random.PRNGKey(0), model, in_shape)
        t0 = time.perf_counter()
        design = compile_model(model, params, in_shape, in_quant, config=cfg)
        compile_s = time.perf_counter() - t0
        entry = _verify_one(design, tier="strict")
        entry["compile_s"] = compile_s
        designs[name] = entry
        if keep_one is None:
            keep_one = design
        print(f"lint,{name},{'OK' if entry['ok'] else 'FAIL'},"
              f"{entry['n_errors']}e/{entry['n_warnings']}w", flush=True)

    artifacts = {}
    with tempfile.TemporaryDirectory() as td:
        path = save_design(keep_one, Path(td) / "roundtrip")
        artifacts["roundtrip"] = _verify_one(path, tier="strict")
    for path in _committed_artifacts():
        artifacts[str(path.relative_to(_REPO_ROOT))] = _verify_one(
            path, tier="strict"
        )
    for name, entry in artifacts.items():
        print(f"lint,artifact:{name},{'OK' if entry['ok'] else 'FAIL'},"
              f"{entry['n_errors']}e/{entry['n_warnings']}w", flush=True)

    # -- verify-overhead leg: cheap tier on a 64x64 compile ------------
    wq = QuantConfig(6, 2, signed=True)
    model = (QDense(64, wq),)
    params, _ = init_params(jax.random.PRNGKey(1), model, (64,))
    cfg = CompileConfig(solver=SolverConfig(dc=2), verify="cheap")
    t0 = time.perf_counter()
    design = compile_model(model, params, (64,), QuantConfig(8, 4, signed=True),
                           config=cfg)
    compile_s = time.perf_counter() - t0
    vstats = design.solver_stats["verify"]
    fraction = vstats["wall_s"] / compile_s if compile_s > 0 else 0.0
    overhead = {
        "compile_s": compile_s,
        "verify_s": vstats["wall_s"],
        "fraction": fraction,
        "budget": OVERHEAD_BUDGET,
        "ok": bool(vstats["ok"]) and fraction < OVERHEAD_BUDGET,
    }
    print(f"lint,overhead-64x64,{'OK' if overhead['ok'] else 'FAIL'},"
          f"{fraction * 100:.2f}% of {compile_s:.2f}s", flush=True)

    result = {"designs": designs, "artifacts": artifacts, "overhead": overhead}
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(result, fh, indent=2, sort_keys=True)
    return result


def passed(result: dict) -> bool:
    ok = all(e["ok"] for e in result["designs"].values())
    ok = ok and all(e["ok"] for e in result["artifacts"].values())
    return ok and result["overhead"]["ok"]


if __name__ == "__main__":
    json_path = None
    if "--json" in sys.argv:
        json_path = sys.argv[sys.argv.index("--json") + 1]
    sys.exit(0 if passed(main(json_path=json_path)) else 1)
