"""RTL co-simulation gate: simulated RTL ≡ DAIS interpreter ≡ jitted
forward, bit-exact per output and cycle-accurate per pipeline stage.

Runs the default co-sim grid ({strategy × engine × pipelined/comb ×
matrix shape incl. zero/negative-output columns, unsigned inputs, and
fractional-grid negative output shifts}) and writes a JSON report —
the CI artifact and, via ``benchmarks.perf_gate --kind rtl``, the
deterministic trajectory gate against the committed ``BENCH_rtl.json``.

Legs:

* RTL-vs-interpreter — numpy only, always on (the hard gate);
* jitted forward — on when JAX is importable (``--jit require`` to
  force, as the tier-1 CI environment does);
* external reference simulator (Verilator / Icarus) — ``--external
  require`` in the weekly cross-check job; skips loudly otherwise.

Usage::

    python -m benchmarks.run rtl --json rtl-cosim.json
    python -m benchmarks.rtl_cosim --external require --json rtl-verilator.json
"""

from __future__ import annotations

import argparse
import json
import sys


def main(json_path=None, jit: str = "auto", external: str = "skip") -> dict:
    from repro.core.cosim import cosim_grid, default_grid, external_tool

    cases = default_grid()
    result = cosim_grid(cases, jit=jit, external=external)
    for c in result["cases"]:
        ok = c["bit_exact"] and c["latency_ok"]
        jit_s = c["jit"].get("status", "skipped")
        if c["jit"].get("status") == "checked" and not c["jit"]["bit_exact"]:
            ok = False
        ext = c.get("external", {})
        if ext.get("status") == "checked" and not ext["bit_exact"]:
            ok = False
        print(
            f"rtl_cosim,{c['name']},adders={c['adders']},"
            f"latency={c['accounting']['latency_cycles']},"
            f"stages={c['n_stages']},jit={jit_s},"
            f"{'OK' if ok else 'MISMATCH'}",
            flush=True,
        )
    if external != "skip":
        tool = external_tool()
        print(f"# external simulator: {tool or 'NONE (skipped loudly)'}")
    print(
        f"# {result['n_cases']} cases, {result['n_bit_exact']} bit-exact, "
        f"jit checked {result['jit']['checked']}, "
        f"external checked {result['external']['checked']}"
    )
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(result, fh, indent=2, sort_keys=True)
        print(f"# wrote {json_path}")
    return result


def passed(result: dict) -> bool:
    """Gate: every leg that ran must be bit-exact and cycle-accurate."""
    if not result["all_bit_exact"]:
        return False
    return all(c["latency_ok"] for c in result["cases"])


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", dest="json_path", default=None)
    ap.add_argument("--jit", choices=("auto", "require", "skip"), default="auto")
    ap.add_argument("--external", choices=("auto", "require", "skip"),
                    default="skip")
    args = ap.parse_args()
    result = main(args.json_path, jit=args.jit, external=args.external)
    sys.exit(0 if passed(result) else 1)
