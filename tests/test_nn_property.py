"""Property-based end-to-end NN compiler exactness: random quantized
Sequential models must compile to integer pipelines that bit-match the
float forward (float64 reference) — the system-level invariant behind
the paper's 'full numerical precision' claim."""

import jax
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.nn import QuantConfig, apply_model, compile_model, init_params
from repro.nn.layers import Flatten, MaxPool2D, QConv2D, QDense, ReLU

jax.config.update("jax_enable_x64", True)


@st.composite
def mlp_models(draw):
    n_layers = draw(st.integers(1, 4))
    d_in = draw(st.integers(2, 10))
    wq = QuantConfig(draw(st.integers(3, 8)), 2)
    aq = QuantConfig(draw(st.integers(4, 9)), draw(st.integers(2, 4)), signed=False)
    layers = []
    for i in range(n_layers):
        layers.append(QDense(draw(st.integers(2, 12)), wq))
        if i < n_layers - 1:
            layers.append(ReLU(aq))
    in_quant = QuantConfig(8, draw(st.integers(2, 5)), signed=True)
    dc = draw(st.sampled_from([-1, 0, 2]))
    return tuple(layers), (d_in,), in_quant, dc


@given(mlp_models(), st.integers(0, 10**6))
@settings(max_examples=20, deadline=None)
def test_random_mlp_bit_exact(spec, seed):
    model, in_shape, in_quant, dc = spec
    params, _ = init_params(jax.random.PRNGKey(seed % 2**31), model, in_shape)
    design = compile_model(model, params, in_shape, in_quant, dc=dc)
    rng = np.random.default_rng(seed)
    x = jax.numpy.asarray(
        rng.uniform(in_quant.lo, in_quant.hi, size=(8, *in_shape)), jax.numpy.float64
    )
    want = apply_model(params, model, x, in_quant=in_quant)
    got = design.forward(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(0, 10**6), st.integers(3, 5))
@settings(max_examples=6, deadline=None)
def test_random_conv_bit_exact(seed, filters):
    model = (
        QConv2D(filters, (3, 3), w_quant=QuantConfig(5, 2)),
        ReLU(QuantConfig(7, 3, signed=False)),
        MaxPool2D((2, 2)),
        Flatten(),
        QDense(4, QuantConfig(5, 2)),
    )
    in_shape = (8, 8, 2)
    in_quant = QuantConfig(6, 1, signed=False)
    params, _ = init_params(jax.random.PRNGKey(seed % 2**31), model, in_shape)
    design = compile_model(model, params, in_shape, in_quant, dc=2)
    rng = np.random.default_rng(seed)
    x = jax.numpy.asarray(
        rng.uniform(0, in_quant.hi, size=(3, *in_shape)), jax.numpy.float64
    )
    want = apply_model(params, model, x, in_quant=in_quant)
    got = design.forward(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_compiled_design_da_never_more_adders_than_latency():
    """DA strategy should never use more adders across random models."""
    for seed in range(3):
        model = (QDense(16, QuantConfig(6, 2)), ReLU(QuantConfig(8, 4, signed=False)),
                 QDense(8, QuantConfig(6, 2)))
        params, _ = init_params(jax.random.PRNGKey(seed), model, (12,))
        da = compile_model(model, params, (12,), QuantConfig(8, 4), strategy="da")
        base = compile_model(model, params, (12,), QuantConfig(8, 4), strategy="latency")
        assert da.total_adders <= base.total_adders
