"""Telemetry layer: the tracer must be an exact no-op when disabled and
schema-valid Perfetto JSON when enabled, the sharded metrics registry
must merge concurrent single-writer shards without losing a count, the
flight recorder's ring/slowest-K bookkeeping must be exact through
wraparound, and the instrumented pipeline (solver spans + per-layer
compile stats + serve flight records + Prometheus exposition) must
surface real numbers without perturbing results."""

import json
import threading

import numpy as np
import pytest

import jax

from repro.core import SolutionCache, solve_cmvm
from repro.flow import CompileConfig, Deployment, ServeConfig, SolverConfig
from repro.nn import QDense, QuantConfig, compile_model, init_params
from repro.obs import flight as flight_mod
from repro.obs import solvelog, trace
from repro.obs.flight import FlightRecorder
from repro.obs.metrics import (
    Histogram,
    MetricsRegistry,
    render_prometheus,
)
from repro.runtime.metrics import LatencyRecorder


@pytest.fixture(autouse=True)
def _clean_tracer():
    """Every test starts and ends with tracing off and empty rings."""
    was = trace.enabled()
    trace.set_enabled(False)
    trace.reset()
    yield
    trace.set_enabled(was)
    trace.reset()


# ---------------------------------------------------------------- trace


def test_disabled_span_is_shared_noop():
    assert not trace.enabled()
    s1 = trace.span("a", k=1)
    s2 = trace.span("b")
    assert s1 is s2  # module singleton: zero allocation on the hot path
    with s1:
        pass
    trace.instant("tick")
    assert trace.n_events() == 0


def test_disabled_tracing_is_bit_exact_on_solver():
    mat = np.random.default_rng(7).integers(-64, 64, size=(12, 12))
    cfg = SolverConfig(dc=2, engine="arena")
    ref = solve_cmvm(mat, config=cfg)
    assert trace.n_events() == 0
    trace.set_enabled(True)
    traced = solve_cmvm(mat, config=cfg)
    assert trace.n_events() > 0
    assert (traced.n_adders, traced.cost_bits) == (ref.n_adders, ref.cost_bits)


def test_span_records_nesting_and_args():
    trace.set_enabled(True)
    with trace.span("outer", phase="x"):
        with trace.span("inner"):
            pass
        trace.instant("mark", n=3)
    doc = trace.export()
    xs = {e["name"]: e for e in doc["traceEvents"] if e["ph"] == "X"}
    inst = [e for e in doc["traceEvents"] if e["ph"] == "i"]
    assert set(xs) == {"outer", "inner"}
    assert xs["outer"]["args"] == {"phase": "x"}
    assert xs["outer"]["dur"] >= xs["inner"]["dur"] >= 0
    assert [e["name"] for e in inst] == ["mark"]


def test_trace_ring_wraparound_counts_dropped():
    trace.set_enabled(True)
    results = {}

    def work():
        # fresh thread => fresh buffer created at the tiny capacity
        for i in range(10):
            with trace.span(f"s{i}"):
                pass
        b = trace._buf()
        results["names"] = [ev[0] for ev in b.iter_events()]
        results["n_dropped"] = b.n_dropped

    old_cap = trace._capacity
    trace.set_capacity(4)
    try:
        t = threading.Thread(target=work)
        t.start()
        t.join()
    finally:
        trace.set_capacity(old_cap)
    # ring keeps the newest 4 of 10, oldest-first, and counts the rest
    assert results["names"] == ["s6", "s7", "s8", "s9"]
    assert results["n_dropped"] == 6
    doc = trace.export()
    assert doc["otherData"]["n_dropped"] >= 6


def test_export_is_valid_chrome_trace_json(tmp_path):
    trace.set_enabled(True)

    def work():
        with trace.span("pool.work", idx=1):
            pass

    t = threading.Thread(target=work, name="worker-0")
    t.start()
    t.join()
    with trace.span("main.work"):
        pass
    path = tmp_path / "trace.json"
    doc = trace.export(str(path))
    reloaded = json.loads(path.read_text())
    assert reloaded == doc
    events = doc["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    ms = [e for e in events if e["ph"] == "M"]
    assert {e["name"] for e in xs} == {"pool.work", "main.work"}
    for e in xs:
        for key in ("name", "cat", "ph", "ts", "dur", "pid", "tid"):
            assert key in e
        assert e["ts"] >= 0 and e["dur"] >= 0
    # spans on two distinct threads, each with thread_name metadata
    assert len({e["tid"] for e in xs}) == 2
    assert {e["tid"] for e in xs} <= {e["tid"] for e in ms}
    assert any(e["args"]["name"] == "worker-0" for e in ms)


# -------------------------------------------------------------- metrics


def test_registry_empty_snapshot_and_prometheus():
    reg = MetricsRegistry()
    snap = reg.snapshot()
    assert snap == {"counters": {}, "gauges": {}, "histograms": {}}
    assert reg.to_prometheus() == "\n"


def test_registry_concurrent_writers_sum_exactly():
    reg = MetricsRegistry()
    n_threads, n_incs = 8, 500

    def work(i):
        for k in range(n_incs):
            reg.inc("ops_total", kind="w")
            reg.observe("lat_us", float(k % 100))
        reg.set_gauge("depth", i, shard=str(i))

    threads = [threading.Thread(target=work, args=(i,)) for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = reg.snapshot()
    assert snap["counters"]['ops_total{kind="w"}'] == n_threads * n_incs
    assert snap["histograms"]["lat_us"]["count"] == n_threads * n_incs
    for i in range(n_threads):
        assert snap["gauges"][f'depth{{shard="{i}"}}'] == i


def test_gauge_last_write_wins_across_shards():
    reg = MetricsRegistry()
    reg.set_gauge("q", 1.0)

    def late_writer():
        reg.set_gauge("q", 42.0)

    t = threading.Thread(target=late_writer)
    t.start()
    t.join()
    assert reg.snapshot()["gauges"]["q"] == 42.0


def test_histogram_merge_and_percentiles():
    a, b = Histogram(), Histogram()
    for v in (5.0, 50.0, 500.0):
        a.observe(v)
    b.observe(5_000.0)
    m = Histogram.merged([a, b])
    assert (m.n, m.sum) == (4, 5555.0)
    assert Histogram.merged([]).n == 0  # merged over nothing: empty hist
    snap = m.snapshot()
    assert snap["buckets"][float("inf")] == 4
    # cumulative monotonicity
    cum = list(snap["buckets"].values())
    assert cum == sorted(cum)
    assert m.percentile(0) <= m.percentile(50) <= m.percentile(100)
    with pytest.raises(ValueError):
        a.merge_from(Histogram(bounds=(1.0, 2.0)))


def test_prometheus_histogram_exposition_shape():
    h = Histogram(bounds=(10.0, 100.0))
    for v in (5.0, 50.0, 500.0):
        h.observe(v)
    text = render_prometheus(
        [("stage_us", "histogram", "per-stage µs", [({"stage": "pad"}, h)])]
    )
    lines = text.strip().splitlines()
    assert "# TYPE stage_us histogram" in lines
    assert 'stage_us_bucket{stage="pad",le="10"} 1' in lines
    assert 'stage_us_bucket{stage="pad",le="100"} 2' in lines
    assert 'stage_us_bucket{stage="pad",le="+Inf"} 3' in lines
    assert 'stage_us_count{stage="pad"} 3' in lines


# --------------------------------------------------------------- flight


def test_flight_ring_wraparound_and_slowest_k():
    fr = FlightRecorder(capacity=8, slow_k=3)
    for i in range(20):
        # latency pattern puts the slowest three at i = 17, 18, 19 * 10
        fr.record(i, shard=0, bucket=16, batch_size=4,
                  lat_us=float(i * 10), stages_us=(1, 2, 3, 4, float(i)))
    snap = fr.snapshot()
    assert snap["n_records"] == 20
    assert snap["n_evicted"] == 12
    recent = fr.recent()
    assert [r["trace_id"] for r in recent] == list(range(12, 20))
    assert [r["lat_us"] for r in snap["slowest"]] == [190.0, 180.0, 170.0]
    r = snap["slowest"][0]
    assert r["stages_us"] == {
        "queue_wait": 1, "batch_form": 2, "pad": 3, "dispatch": 4,
        "copy_out": 19.0,
    }
    assert set(r["stages_us"]) == set(flight_mod.STAGES)


def test_flight_merged_over_empty_and_mixed():
    assert FlightRecorder.merged([]) == {
        "n_records": 0, "capacity": 0, "n_evicted": 0, "slowest": [],
        "n_events": 0, "events": [],
    }
    empty = FlightRecorder(capacity=4, slow_k=2)
    busy = FlightRecorder(capacity=4, slow_k=2)
    busy.record(1, 0, 16, 1, 100.0, (1, 1, 1, 1, 1))
    busy.record(2, 0, 16, 1, 900.0, (2, 2, 2, 2, 2))
    m = FlightRecorder.merged([empty, busy])
    assert m["n_records"] == 2
    assert [r["trace_id"] for r in m["slowest"]] == [2, 1]


def test_flight_merged_interleaves_shards():
    a = FlightRecorder(capacity=16, slow_k=2)
    b = FlightRecorder(capacity=16, slow_k=2)
    a.record(10, 0, 16, 1, 50.0, (0, 0, 0, 0, 0))
    b.record(20, 1, 16, 1, 70.0, (0, 0, 0, 0, 0))
    a.record(11, 0, 16, 1, 60.0, (0, 0, 0, 0, 0))
    m = FlightRecorder.merged([a, b])
    assert [r["trace_id"] for r in m["slowest"]] == [20, 11]
    assert {r["shard"] for r in m["slowest"]} == {1, 0}


# ------------------------------------------------------- reservoir fix


def test_latency_reservoir_is_deterministic_and_uniformish():
    r1 = LatencyRecorder(max_samples=100, seed=3)
    r2 = LatencyRecorder(max_samples=100, seed=3)
    vals = [float(i) for i in range(1000)]
    for v in vals:
        r1.record(v, now=0.0)
    r2.record_many(vals, now=0.0)
    assert r1.n_total == r2.n_total == 1000
    assert r1.n_sampled_out == r2.n_sampled_out == 900
    # same seed, same arrival order => identical reservoirs however fed
    assert r1._lat == r2._lat
    # Algorithm R must not freeze on the first max_samples observations
    assert max(r1._lat) >= 100.0
    snap = r1.snapshot()
    assert snap["n_sampled_out"] == 900
    assert snap["n_latency_samples"] == 100
    r1.reset()
    assert (r1.n_total, r1.n_sampled_out, r1._lat) == (0, 0, [])


def test_latency_reservoir_seed_changes_sample():
    a = LatencyRecorder(max_samples=50, seed=0)
    b = LatencyRecorder(max_samples=50, seed=1)
    for v in range(500):
        a.record(float(v), now=0.0)
        b.record(float(v), now=0.0)
    assert a._lat != b._lat


# ------------------------------------------- instrumented pipeline (jax)


@pytest.fixture(scope="module")
def design():
    wq = QuantConfig(6, 2, signed=True)
    model = (QDense(8, wq), QDense(4, wq))
    params, _ = init_params(jax.random.PRNGKey(0), model, (8,))
    return compile_model(
        model, params, (8,), QuantConfig(8, 4, signed=True),
        config=CompileConfig(solver=SolverConfig(dc=2)),
    )


def test_per_layer_solver_stats(design):
    per_layer = design.solver_stats["per_layer"]
    assert sorted(per_layer) == ["dense0", "dense1"]
    for st in per_layer.values():
        assert st["cache_hit"] is False
        assert st["solve_wall_s"] >= 0.0
        assert st["adders"] > 0 and st["cost_bits"] > 0
    assert per_layer["dense0"]["shape"] == "8x8"
    assert per_layer["dense1"]["shape"] == "8x4"


def test_per_layer_cache_hits_with_shared_cache():
    cache = SolutionCache()
    wq = QuantConfig(6, 2, signed=True)
    model = (QDense(8, wq),)
    params, _ = init_params(jax.random.PRNGKey(1), model, (8,))
    in_q = QuantConfig(8, 4, signed=True)
    cfg = CompileConfig(solver=SolverConfig(dc=2), cache=cache)
    first = compile_model(model, params, (8,), in_q, config=cfg)
    second = compile_model(model, params, (8,), in_q, config=cfg)
    assert first.solver_stats["per_layer"]["dense0"]["cache_hit"] is False
    assert second.solver_stats["per_layer"]["dense0"]["cache_hit"] is True


def test_solvelog_captures_structured_records(tmp_path):
    path = tmp_path / "solves.jsonl"
    solvelog.reset()
    old = solvelog.get_path()
    solvelog.set_path(str(path))
    try:
        mat = np.random.default_rng(11).integers(-64, 64, size=(10, 10))
        sol = solve_cmvm(mat, config=SolverConfig(dc=2, engine="arena"))
    finally:
        solvelog.set_path(old)
    recs = [r for r in solvelog.records() if r.get("d_in") == 10]
    assert recs, "solve record missing from ring"
    rec = recs[-1]
    assert rec["adders"] == sol.n_adders
    assert rec["cost_bits"] == sol.cost_bits
    assert rec["cache_hit"] is False
    on_disk = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert any(r["d_in"] == 10 and r["adders"] == sol.n_adders for r in on_disk)


def test_engine_stats_carry_flight_and_metrics_text(design):
    rng = np.random.default_rng(2)
    xs = [
        np.asarray(rng.integers(-8, 8, size=(8,)), np.int32) for _ in range(32)
    ]
    with Deployment(ServeConfig(max_batch=8, max_wait_us=100.0, shards=2)) as dep:
        dep.register("m", design)
        dep.warmup("m")
        for f in [dep.submit("m", x) for x in xs]:
            f.result(30)
        stats = dep.stats("m")
        text = dep.metrics_text()
    flight = stats["flight"]
    assert flight["n_records"] >= len(xs)
    assert flight["slowest"], "tail sample must pin at least one request"
    for rec in flight["slowest"]:
        assert set(rec["stages_us"]) == set(flight_mod.STAGES)
        assert rec["lat_us"] > 0
    # trace ids unique across shards (shard index in the high bits)
    tids = [r["trace_id"] for r in flight["slowest"]]
    assert len(tids) == len(set(tids))
    # Prometheus text: every sample line parses, serve families present
    samples = [
        ln for ln in text.splitlines() if ln.strip() and not ln.startswith("#")
    ]
    import re

    pat = re.compile(r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? [^ ]+$')
    assert samples and all(pat.match(ln) for ln in samples)
    assert any(
        ln.startswith('serve_requests_total{model="m@v1"}') for ln in samples
    )
    for family in ("serve_batches_total", "serve_stage_us_bucket",
                   "serve_queue_depth"):
        assert family in text
