"""NN substrate: float/STE forward must bit-match the compiled integer
adder-graph pipeline (the paper's 'full numerical precision' claim,
end-to-end), and the DA strategy must beat the latency baseline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import (
    apply_model,
    compile_model,
    init_params,
    models,
)

jax.config.update("jax_enable_x64", True)  # exact float reference


def _random_input(rng, shape, in_quant, batch=16):
    x = rng.uniform(in_quant.lo, in_quant.hi, size=(batch, *shape))
    return jnp.asarray(x, jnp.float64)


@pytest.mark.parametrize("builder", [
    models.jet_tagger,
    models.muon_tracker,
    lambda: models.mlp_mixer_jet(n_particles=8, n_features=8, d_ff=8),
])
def test_float_matches_integer_pipeline(builder):
    model, in_shape, in_quant = builder()
    rng = np.random.default_rng(0)
    params, _ = init_params(jax.random.PRNGKey(0), model, in_shape)
    design = compile_model(model, params, in_shape, in_quant, dc=2)
    x = _random_input(rng, in_shape, in_quant)
    y_float = apply_model(params, model, x, in_quant=in_quant)
    y_int = design.forward(x)
    np.testing.assert_allclose(
        np.asarray(y_int, np.float64),
        np.asarray(y_float, np.float64),
        rtol=0,
        atol=0,
    )


def test_svhn_cnn_small_exact():
    model, _, in_quant = models.svhn_cnn()
    in_shape = (22, 22, 3)  # reduced spatial size for test speed
    rng = np.random.default_rng(1)
    params, out_shape = init_params(jax.random.PRNGKey(1), model, in_shape)
    design = compile_model(model, params, in_shape, in_quant, dc=2)
    x = _random_input(rng, in_shape, in_quant, batch=4)
    y_float = apply_model(params, model, x, in_quant=in_quant)
    y_int = design.forward(x)
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_float), rtol=0, atol=0)


def test_da_beats_latency_strategy():
    model, in_shape, in_quant = models.jet_tagger()
    params, _ = init_params(jax.random.PRNGKey(2), model, in_shape)
    da = compile_model(model, params, in_shape, in_quant, dc=2, strategy="da")
    base = compile_model(model, params, in_shape, in_quant, dc=2, strategy="latency")
    assert da.total_adders < base.total_adders
    assert da.total_cost_bits < base.total_cost_bits
    # both strategies must be bit-exact
    rng = np.random.default_rng(3)
    x = _random_input(rng, in_shape, in_quant)
    np.testing.assert_array_equal(
        np.asarray(da.forward(x)), np.asarray(base.forward(x))
    )


def test_latency_cycles_and_report():
    model, in_shape, in_quant = models.jet_tagger()
    params, _ = init_params(jax.random.PRNGKey(4), model, in_shape)
    design = compile_model(model, params, in_shape, in_quant, dc=2)
    assert design.latency_cycles >= len(design.reports)
    s = design.summary()
    assert "TOTAL" in s and "dense" in s


def test_quantized_training_step_reduces_loss():
    """QAT sanity: a few SGD steps on a toy task reduce loss."""
    model, in_shape, in_quant = models.jet_tagger(w_bits=8)
    params, _ = init_params(jax.random.PRNGKey(5), model, in_shape)
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (256, 16))
    w_true = jax.random.normal(jax.random.PRNGKey(7), (16, 5))
    y = jnp.argmax(x @ w_true, axis=-1)

    def loss_fn(p):
        logits = apply_model(p, model, x, in_quant=in_quant)
        lp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(lp, y[:, None], axis=-1).mean()

    loss0 = loss_fn(params)
    grads = jax.grad(loss_fn)(params)
    lr = 0.05
    p2 = jax.tree.map(lambda a, g: a - lr * g, params, grads)
    for _ in range(10):
        g = jax.grad(loss_fn)(p2)
        p2 = jax.tree.map(lambda a, gg: a - lr * gg, p2, g)
    assert loss_fn(p2) < loss0
