"""Engine equivalence for the batch-scored CSE rewrite (hypothesis-free).

The ``engine="batch"`` candidate-array engine and the ``engine="heap"``
lazy max-heap engine realise the same selection rule (max priority,
smallest-key tie-break, dormancy on failed implementation), so they must
produce *identical* DAIS programs — not merely equal adder counts.
These tests pin that contract, the batch delay scorer, and the
compile_model fast path under the new default engine.
"""

import numpy as np
import pytest

from repro.core import min_tree_depth_hist, solve_cmvm
from repro.core.cost import min_tree_depth_hist_batch


def _mat(m, seed, bw=8):
    rng = np.random.default_rng(seed)
    return rng.integers(2 ** (bw - 1) + 1, 2**bw, size=(m, m))


CASES = [
    (8, 3, -1),
    (10, 5, 0),
    (12, 7, 1),
    (16, 42, -1),
    (16, 42, 2),
    (16, 44, 0),
]


def _program_arrays(sol):
    return sol.program.to_arrays()


@pytest.mark.parametrize("m,seed,dc", CASES)
def test_engines_produce_identical_programs(m, seed, dc):
    mat = _mat(m, seed)
    batch = solve_cmvm(mat, dc=dc, engine="batch")
    heap = solve_cmvm(mat, dc=dc, engine="heap")
    assert batch.verify() and heap.verify()
    a, b = _program_arrays(batch), _program_arrays(heap)
    for key in ("rows", "outputs", "n_inputs"):
        np.testing.assert_array_equal(a[key], b[key], err_msg=f"{key} diverged")
    assert batch.n_adders == heap.n_adders
    assert batch.cost_bits == heap.cost_bits
    assert batch.stats["engine"] == "batch"
    assert heap.stats["engine"] == "heap"


def test_engines_identical_on_rectangular_and_sparse():
    rng = np.random.default_rng(11)
    mat = rng.integers(-(2**7), 2**7, size=(24, 6))
    mat[rng.random(mat.shape) < 0.5] = 0
    for dc in (-1, 2):
        a = solve_cmvm(mat, dc=dc, engine="batch")
        b = solve_cmvm(mat, dc=dc, engine="heap")
        assert a.verify()
        np.testing.assert_array_equal(
            _program_arrays(a)["rows"], _program_arrays(b)["rows"]
        )


def test_unknown_engine_rejected():
    with pytest.raises(ValueError, match="engine"):
        solve_cmvm(_mat(4, 0), engine="quantum")


def test_batch_depth_scorer_matches_scalar():
    """min_tree_depth_hist_batch == the scalar simulation on shared-level
    histograms, including zero-count levels (which the scalar filters)."""
    rng = np.random.default_rng(7)
    for _ in range(300):
        n_l = int(rng.integers(1, 8))
        levels = np.sort(rng.choice(20, size=n_l, replace=False))
        counts = rng.integers(0, 10, size=(int(rng.integers(1, 5)), n_l))
        got = min_tree_depth_hist_batch(levels, counts)
        for bi in range(counts.shape[0]):
            hist = {int(d): int(c) for d, c in zip(levels, counts[bi])}
            assert got[bi] == min_tree_depth_hist(hist), (levels, counts[bi])


def test_compile_model_parallel_bit_identical_default_engine():
    """jobs=N must stay bit-identical to serial under the default (batch)
    engine, and engine="heap" must produce the same integers."""
    jax = pytest.importorskip("jax")
    from repro.nn import QuantConfig, compile_model, init_params
    from repro.nn.layers import QDense, ReLU, Sequential

    model = Sequential(
        (
            QDense(12, QuantConfig(6, 2)),
            ReLU(QuantConfig(7, 4, signed=False)),
            QDense(6, QuantConfig(6, 2)),
        )
    )
    in_shape = (10,)
    in_quant = QuantConfig(8, 3, signed=True)
    params, _ = init_params(jax.random.PRNGKey(2), model, in_shape)
    serial = compile_model(model, params, in_shape, in_quant, dc=2, jobs=1)
    par = compile_model(model, params, in_shape, in_quant, dc=2, jobs=2)
    heap = compile_model(model, params, in_shape, in_quant, dc=2, jobs=1, engine="heap")
    assert serial.solver_stats["engine"] == "batch"
    assert heap.solver_stats["engine"] == "heap"
    rng = np.random.default_rng(3)
    q = in_quant.qint
    xi = np.asarray(rng.integers(q.lo, q.hi + 1, size=(16, *in_shape)), np.int32)
    y_serial = np.asarray(serial.forward_int(xi))
    np.testing.assert_array_equal(y_serial, np.asarray(par.forward_int(xi)))
    np.testing.assert_array_equal(y_serial, np.asarray(heap.forward_int(xi)))
    assert [r.adders for r in serial.reports] == [r.adders for r in heap.reports]
