"""Engine equivalence for the CSE engines (hypothesis-free).

The ``engine="batch"`` candidate-array engine, the ``engine="arena"``
preallocated-workspace engine, and the ``engine="heap"`` lazy max-heap
engine realise the same selection rule (max priority, smallest-key
tie-break, dormancy on failed implementation), so they must produce
*identical* DAIS programs — not merely equal adder counts.  These tests
pin that contract over a seed x depth-budget x scoring-variant grid, the
arena workspace reuse guarantee (second solve: zero reallocations), the
batch delay scorer, and the compile_model fast path under the default
engine.
"""

import numpy as np
import pytest

from repro.core import min_tree_depth_hist, solve_cmvm
from repro.core.cost import min_tree_depth_hist_batch
from repro.core.cse import CSEArena, get_thread_arena
from repro.flow import SolverConfig

ENGINES = ("heap", "batch", "arena")


def _mat(m, seed, bw=8):
    rng = np.random.default_rng(seed)
    return rng.integers(2 ** (bw - 1) + 1, 2**bw, size=(m, m))


CASES = [
    (8, 3, -1),
    (10, 5, 0),
    (12, 7, 1),
    (16, 42, -1),
    (16, 42, 2),
    (16, 44, 0),
]

# scoring-rule variants exercised by the full engine grid: default,
# unweighted counts, and no assembly dedup
VARIANTS = [
    {"weighted": True, "dedup": True},
    {"weighted": False, "dedup": True},
    {"weighted": True, "dedup": False},
]


def _program_arrays(sol):
    return sol.program.to_arrays()


def _assert_programs_identical(sols, ctx=""):
    ref = _program_arrays(sols[0])
    for sol in sols[1:]:
        arr = _program_arrays(sol)
        for key in ("rows", "outputs", "n_inputs"):
            np.testing.assert_array_equal(
                ref[key], arr[key], err_msg=f"{key} diverged {ctx}"
            )


@pytest.mark.parametrize("m,seed,dc", CASES)
def test_engines_produce_identical_programs(m, seed, dc):
    mat = _mat(m, seed)
    sols = {
        eng: solve_cmvm(mat, config=SolverConfig(dc=dc, engine=eng))
        for eng in ENGINES
    }
    assert all(s.verify() for s in sols.values())
    _assert_programs_identical(list(sols.values()), f"(m={m} seed={seed} dc={dc})")
    assert len({s.n_adders for s in sols.values()}) == 1
    assert len({s.cost_bits for s in sols.values()}) == 1
    for eng, s in sols.items():
        assert s.stats["engine"] == eng


@pytest.mark.parametrize("variant", VARIANTS, ids=["default", "unweighted", "nodedup"])
@pytest.mark.parametrize("m,seed,dc", [(10, 5, 0), (12, 7, 2), (14, 9, -1)])
def test_engine_grid_with_scoring_variants(m, seed, dc, variant):
    """heap x batch x arena bit-identity across scoring-rule variants."""
    mat = _mat(m, seed)
    cfgs = [SolverConfig(dc=dc, engine=eng, **variant) for eng in ENGINES]
    sols = [solve_cmvm(mat, config=c) for c in cfgs]
    assert sols[0].verify()
    _assert_programs_identical(sols, f"(m={m} seed={seed} dc={dc} {variant})")


def test_engines_identical_on_rectangular_and_sparse():
    rng = np.random.default_rng(11)
    mat = rng.integers(-(2**7), 2**7, size=(24, 6))
    mat[rng.random(mat.shape) < 0.5] = 0
    for dc in (-1, 2):
        sols = [
            solve_cmvm(mat, config=SolverConfig(dc=dc, engine=eng))
            for eng in ENGINES
        ]
        assert sols[0].verify()
        _assert_programs_identical(sols, f"(sparse dc={dc})")


def test_unknown_engine_rejected():
    with pytest.raises(ValueError, match="engine"):
        solve_cmvm(_mat(4, 0), engine="quantum")


# ----------------------------------------------------------------------
# Arena workspace reuse
# ----------------------------------------------------------------------
def test_arena_reuse_zero_reallocations():
    """Two consecutive solves on one (thread) arena produce identical
    programs, and the second performs zero arena reallocations — the hot
    loop runs entirely inside buffers grown by the first solve."""
    mat = _mat(20, 21)
    cfg = SolverConfig(dc=-1, engine="arena")
    arena = get_thread_arena()
    first = solve_cmvm(mat, config=cfg)
    solves_before = arena.n_solves
    reallocs_before = arena.n_reallocs
    second = solve_cmvm(mat, config=cfg)
    assert arena.n_solves > solves_before, "solve did not use the thread arena"
    assert arena.n_reallocs == reallocs_before, (
        f"repeat solve reallocated {arena.n_reallocs - reallocs_before} buffers"
    )
    _assert_programs_identical([first, second], "(arena reuse)")
    assert first.verify()


def test_arena_explicit_workspace_and_busy_fallback():
    """An explicitly passed arena is used (and reusable), and a busy
    arena falls back to a private workspace instead of corrupting
    state."""
    from repro.core.cse import CSE
    from repro.core.dais import DAISProgram
    from repro.core.fixed_point import QInterval

    arena = CSEArena()
    mat = _mat(8, 5)
    prog = DAISProgram()
    rows = [prog.add_input(QInterval.from_fixed(True, 8, 8)) for _ in range(8)]
    cols = [
        {rows[i]: int(mat[i, j]) for i in range(8) if mat[i, j]}
        for j in range(8)
    ]
    cse = CSE(prog, cols, engine="arena", arena=arena)
    assert arena.busy  # acquired at construction
    # a second arena CSE while the first is live must not steal the arena
    prog2 = DAISProgram()
    rows2 = [prog2.add_input(QInterval.from_fixed(True, 8, 8)) for _ in range(8)]
    cols2 = [
        {rows2[i]: int(mat[i, j]) for i in range(8) if mat[i, j]}
        for j in range(8)
    ]
    cse2 = CSE(prog2, cols2, engine="arena", arena=arena)
    assert cse2.arena is not arena
    cse2.run()
    cse.run()
    assert not arena.busy  # released at the end of run()
    assert arena.n_solves == 1


def test_arena_reclaimed_from_dead_owner():
    """A CSE that dies without running (failed construction, abandoned
    object) must not wedge its arena: the weakref'd owner lets the next
    acquire reclaim it."""
    import gc

    from repro.core.cse import CSE
    from repro.core.dais import DAISProgram
    from repro.core.fixed_point import QInterval

    arena = CSEArena()
    prog = DAISProgram()
    rows = [prog.add_input(QInterval.from_fixed(True, 8, 8)) for _ in range(2)]
    cse = CSE(prog, [{rows[0]: 3, rows[1]: 5}], engine="arena", arena=arena)
    assert arena.busy
    del cse, prog
    gc.collect()
    assert arena.busy  # not released, owner just died
    mat = _mat(6, 2)
    prog2 = DAISProgram()
    rows2 = [prog2.add_input(QInterval.from_fixed(True, 8, 8)) for _ in range(6)]
    cols2 = [
        {rows2[i]: int(mat[i, j]) for i in range(6) if mat[i, j]}
        for j in range(6)
    ]
    cse2 = CSE(prog2, cols2, engine="arena", arena=arena)
    assert cse2.arena is arena  # reclaimed, not a private fallback
    cse2.run()
    assert not arena.busy


def test_batch_depth_scorer_matches_scalar():
    """min_tree_depth_hist_batch == the scalar simulation on shared-level
    histograms, including zero-count levels (which the scalar filters)."""
    rng = np.random.default_rng(7)
    for _ in range(300):
        n_l = int(rng.integers(1, 8))
        levels = np.sort(rng.choice(20, size=n_l, replace=False))
        counts = rng.integers(0, 10, size=(int(rng.integers(1, 5)), n_l))
        got = min_tree_depth_hist_batch(levels, counts)
        for bi in range(counts.shape[0]):
            hist = {int(d): int(c) for d, c in zip(levels, counts[bi])}
            assert got[bi] == min_tree_depth_hist(hist), (levels, counts[bi])


def test_compile_model_parallel_bit_identical_default_engine():
    """jobs=N (thread pool) must stay bit-identical to serial under the
    default (batch) engine, and engine="heap"/"arena" must produce the
    same integers.  The serial path records its pool fallback reason."""
    jax = pytest.importorskip("jax")
    from repro.nn import QuantConfig, compile_model, init_params
    from repro.nn.layers import QDense, ReLU, Sequential

    model = Sequential(
        (
            QDense(12, QuantConfig(6, 2)),
            ReLU(QuantConfig(7, 4, signed=False)),
            QDense(6, QuantConfig(6, 2)),
        )
    )
    in_shape = (10,)
    in_quant = QuantConfig(8, 3, signed=True)
    params, _ = init_params(jax.random.PRNGKey(2), model, in_shape)
    serial = compile_model(model, params, in_shape, in_quant, dc=2, jobs=1)
    par = compile_model(model, params, in_shape, in_quant, dc=2, jobs=2)
    heap = compile_model(model, params, in_shape, in_quant, dc=2, jobs=1, engine="heap")
    arena = compile_model(
        model, params, in_shape, in_quant, dc=2, jobs=2, engine="arena"
    )
    assert serial.solver_stats["engine"] == "batch"
    assert heap.solver_stats["engine"] == "heap"
    assert arena.solver_stats["engine"] == "arena"
    # the serial compile went serial for a *recorded* reason; the pooled
    # compile either ran the pool or says why not
    assert serial.solver_stats["pool_fallback"] == "jobs=1"
    if par.solver_stats["n_pool_solves"]:
        assert par.solver_stats["pool_fallback"] is None
    else:
        assert par.solver_stats["pool_fallback"] is not None
    rng = np.random.default_rng(3)
    q = in_quant.qint
    xi = np.asarray(rng.integers(q.lo, q.hi + 1, size=(16, *in_shape)), np.int32)
    y_serial = np.asarray(serial.forward_int(xi))
    np.testing.assert_array_equal(y_serial, np.asarray(par.forward_int(xi)))
    np.testing.assert_array_equal(y_serial, np.asarray(heap.forward_int(xi)))
    np.testing.assert_array_equal(y_serial, np.asarray(arena.forward_int(xi)))
    assert [r.adders for r in serial.reports] == [r.adders for r in heap.reports]
    assert [r.adders for r in serial.reports] == [r.adders for r in arena.reports]
