"""Public-API surface snapshot.

The exported names and callable signatures of the four public packages
(``repro.flow``, ``repro.core``, ``repro.nn``, ``repro.runtime``) are
pinned in ``tests/public_api_snapshot.json``.  Any drift — a renamed
export, a changed default, a dropped method — fails this test, so
surface changes are always explicit diffs of the checked-in snapshot.

Regenerate intentionally with:

    PYTHONPATH=src python tests/test_public_api.py --regen

CI runs this module as its own ruff-adjacent job (``api-surface``).
"""

import importlib
import inspect
import json
import sys
from pathlib import Path

MODULES = ("repro.flow", "repro.core", "repro.nn", "repro.runtime")
SNAPSHOT = Path(__file__).parent / "public_api_snapshot.json"


# builtin members (object / BaseException) vary across Python minors
# (e.g. add_note arrived in 3.11) — keep them out of the snapshot
_BUILTIN_MEMBERS = set(dir(object)) | set(dir(BaseException))


def _describe(obj) -> dict:
    if inspect.ismodule(obj):
        return {"kind": "module"}
    if inspect.isclass(obj):
        try:
            sig = str(inspect.signature(obj))
        except (ValueError, TypeError):
            sig = None
        return {
            "kind": "class",
            "signature": sig,
            "members": sorted(
                n
                for n in dir(obj)
                if not n.startswith("_") and n not in _BUILTIN_MEMBERS
            ),
        }
    if callable(obj):
        try:
            sig = str(inspect.signature(obj))
        except (ValueError, TypeError):
            sig = None
        return {"kind": "function", "signature": sig}
    return {"kind": "value", "type": type(obj).__name__}


def build_surface() -> dict:
    surface: dict = {}
    for modname in MODULES:
        mod = importlib.import_module(modname)
        exported = getattr(mod, "__all__")
        surface[modname] = {name: _describe(getattr(mod, name)) for name in exported}
    return surface


def _flatten(surface: dict) -> dict:
    out = {}
    for modname, names in surface.items():
        for name, desc in names.items():
            out[f"{modname}.{name}"] = desc
    return out


def test_public_api_matches_snapshot():
    assert SNAPSHOT.exists(), (
        f"{SNAPSHOT} missing — regenerate with "
        "`PYTHONPATH=src python tests/test_public_api.py --regen`"
    )
    want = _flatten(json.loads(SNAPSHOT.read_text()))
    got = _flatten(build_surface())
    problems = []
    for key in sorted(set(want) - set(got)):
        problems.append(f"removed export: {key}")
    for key in sorted(set(got) - set(want)):
        problems.append(f"new unpinned export: {key}")
    for key in sorted(set(want) & set(got)):
        if want[key] != got[key]:
            problems.append(
                f"changed: {key}\n  pinned:  {want[key]}\n  current: {got[key]}"
            )
    assert not problems, (
        "public API drifted from tests/public_api_snapshot.json "
        "(regenerate intentionally with `PYTHONPATH=src python "
        "tests/test_public_api.py --regen`):\n" + "\n".join(problems)
    )


if __name__ == "__main__":
    if "--regen" in sys.argv:
        SNAPSHOT.write_text(json.dumps(build_surface(), indent=2, sort_keys=True) + "\n")
        print(f"wrote {SNAPSHOT}")
    else:
        print(json.dumps(build_surface(), indent=2, sort_keys=True))
