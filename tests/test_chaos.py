"""Chaos suite: provoke every failure mode the resilience layer claims
to handle, deterministically where possible, randomized where the bug
class is an interleaving.

Covered here:

* :class:`repro.chaos.FaultPlan` itself — seeded replay determinism,
  rule validation, spec round-trip;
* per-site unit scenarios — jit-dispatch failure (breaker trip, fast
  fail, half-open recovery, interpreter fallback), slab-gather failure,
  dispatcher thread death (supervised restart, budget exhaustion,
  unsupervised escalation), deadline shedding at the door and at
  batch-form time, client-timeout accounting;
* crash-safe artifacts — torn npz, crash-before-commit, crash-between
  generations (mixed), quarantine-and-continue;
* the randomized soak — fault schedules over {jit failure, gather
  failure, thread kill} x {1, 4} shards, asserting the core invariant:
  **every future resolves (result or typed error) and every slab slot
  returns to the free list.**
"""

import time
from concurrent.futures import TimeoutError as FutureTimeoutError

import numpy as np
import pytest

import jax

from repro.chaos import (
    FaultInjectedError,
    FaultPlan,
    FaultRule,
    active,
    plan_from_spec,
)
from repro.flow import CompileConfig, ServeConfig, SolverConfig
from repro.nn import (
    QDense,
    QuantConfig,
    ReLU,
    compile_model,
    init_params,
    numpy_forward_fn,
)
from repro.runtime import (
    ArtifactCorruptError,
    CircuitOpenError,
    DeadlineExceededError,
    ModelUnhealthyError,
    ServeEngine,
    load_design,
    save_design,
)

IN_QUANT = QuantConfig(8, 4, signed=True)


@pytest.fixture(scope="module")
def design():
    wq = QuantConfig(6, 2, signed=True)
    aq = QuantConfig(8, 4, signed=False)
    model = (QDense(8, wq), ReLU(aq), QDense(6, wq))
    params, _ = init_params(jax.random.PRNGKey(7), model, (8,))
    return compile_model(
        model, params, (8,), IN_QUANT,
        config=CompileConfig(solver=SolverConfig(dc=2)),
    )


@pytest.fixture(scope="module")
def design2():
    """A second design with different weights (for mixed-generation
    artifact tests)."""
    wq = QuantConfig(6, 2, signed=True)
    aq = QuantConfig(8, 4, signed=False)
    model = (QDense(8, wq), ReLU(aq), QDense(6, wq))
    params, _ = init_params(jax.random.PRNGKey(8), model, (8,))
    return compile_model(
        model, params, (8,), IN_QUANT,
        config=CompileConfig(solver=SolverConfig(dc=2)),
    )


def _samples(n, seed=0, d=8):
    rng = np.random.default_rng(seed)
    q = IN_QUANT.qint
    return np.asarray(rng.integers(q.lo, q.hi + 1, size=(n, d)), np.int32)


def _engine(design, **overrides):
    base = dict(max_batch=8, max_wait_us=0.0, shards=1)
    base.update(overrides)
    eng = ServeEngine(config=ServeConfig(**base))
    eng.register("m", design, warmup=True)
    return eng


def _drain(futures, timeout=10.0):
    """Resolve every future; returns (results, exceptions) and fails the
    test if any future hangs past the timeout."""
    oks, errs = [], []
    for f in futures:
        try:
            exc = f.exception(timeout=timeout)
        except FutureTimeoutError:
            pytest.fail("future left hanging past the resolution timeout")
        (errs if exc is not None else oks).append(exc if exc is not None else f.result(0))
    return oks, errs


def _free_lists_full(eng, name="m"):
    """The leak invariant: once traffic has drained, every slab slot of
    every shard — live and retired — is back on the free list."""
    runner = eng._runner(name)
    with runner._restart_lock:
        shards = list(runner._retired) + list(runner.shards)
    for sh in shards:
        with sh._lock:
            assert len(sh._free) == sh.slab.shape[0], (
                f"shard {sh.idx} (dead={sh.dead}) leaked "
                f"{sh.slab.shape[0] - len(sh._free)} slab slots"
            )
            assert not sh._pending


# -- FaultPlan mechanics ---------------------------------------------------


def test_fault_rule_validation():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultRule("serve.nonsense")
    with pytest.raises(ValueError, match="unknown fault mode"):
        FaultRule("serve.dispatch", mode="explode")
    with pytest.raises(ValueError, match="rate must be in"):
        FaultRule("serve.dispatch", rate=1.5)
    with pytest.raises(ValueError, match="either rate or at"):
        FaultRule("serve.dispatch", rate=0.5, at=(1,))


def test_fault_plan_replays_identically():
    """Same plan, same seed -> the same fault schedule, independent of
    reset; a different seed gives a different schedule."""
    def schedule(plan, n=200):
        return [plan.check("serve.dispatch") is not None for _ in range(n)]

    p1 = FaultPlan([FaultRule("serve.dispatch", rate=0.3)], seed=42)
    s1 = schedule(p1)
    p1.reset()
    assert schedule(p1) == s1  # exact replay after reset
    p2 = FaultPlan([FaultRule("serve.dispatch", rate=0.3)], seed=42)
    assert schedule(p2) == s1  # exact replay across instances
    p3 = FaultPlan([FaultRule("serve.dispatch", rate=0.3)], seed=43)
    assert schedule(p3) != s1
    assert any(s1)  # rate 0.3 over 200 hits fires with p ~ 1


def test_fault_plan_per_site_independence():
    """A site's schedule must not depend on how other sites interleave
    (per-site RNGs): interleaving a second site's checks between hits
    leaves the first site's schedule unchanged."""
    rules = [
        FaultRule("serve.dispatch", rate=0.3),
        FaultRule("serve.gather", rate=0.3),
    ]
    pure = FaultPlan(rules, seed=9)
    want = [pure.check("serve.dispatch") is not None for _ in range(100)]
    mixed = FaultPlan(rules, seed=9)
    got = []
    for i in range(100):
        if i % 3 == 0:
            mixed.check("serve.gather")
        got.append(mixed.check("serve.dispatch") is not None)
    assert got == want


def test_fault_plan_at_after_max_fires():
    plan = FaultPlan(
        [FaultRule("serve.dispatch", at=(1, 3, 4), after=2, max_fires=1)]
    )
    fires = [plan.check("serve.dispatch") is not None for _ in range(6)]
    # at=1 is masked by after=2; at=3 fires; at=4 is masked by max_fires=1
    assert fires == [False, False, False, True, False, False]
    assert plan.stats()["sites"]["serve.dispatch"] == {"hits": 6, "fires": 1}


def test_plan_from_spec_round_trip():
    spec = {
        "seed": 5,
        "rules": [
            {"site": "serve.dispatch", "mode": "raise", "rate": 0.1},
            {"site": "artifact.save.truncate", "mode": "truncate", "at": [0]},
        ],
    }
    plan = plan_from_spec(spec)
    assert plan.seed == 5 and len(plan.rules) == 2
    assert plan_from_spec(plan.to_dict()).to_dict() == plan.to_dict()


# -- interpreter fallback path --------------------------------------------


def test_numpy_interpreter_bit_exact(design):
    xs = _samples(64, seed=1)
    want = np.asarray(design.forward_int(xs))
    got = numpy_forward_fn(design)(xs)
    assert got.dtype == np.int32
    np.testing.assert_array_equal(got, want)


# -- dispatch failures: breaker trip / fast fail / recovery / fallback ----


def test_dispatch_fault_fails_future_and_trips_breaker(design):
    """Two consecutive injected dispatch failures (threshold=2) trip the
    breaker; with a huge cooldown the next request fails fast with
    CircuitOpenError instead of touching the jit path."""
    plan = FaultPlan([FaultRule("serve.dispatch", at=(0, 1))])
    with active(plan):
        eng = _engine(
            design,
            breaker_threshold=2,
            breaker_cooldown_ms=60_000.0,
            breaker_cooldown_max_ms=60_000.0,
        )
        try:
            xs = _samples(3, seed=2)
            for i in range(2):
                with pytest.raises(FaultInjectedError):
                    eng.submit("m", xs[i]).result(10)
            s = eng.stats("m")
            assert s["breaker"]["state"] == "open"
            assert s["breaker"]["n_trips"] == 1
            with pytest.raises(CircuitOpenError):
                eng.submit("m", xs[2]).result(10)
            s = eng.stats("m")
            assert s["n_fast_failed"] == 1
            kinds = {e["kind"] for e in s["flight"]["events"]}
            assert "breaker_open" in kinds
        finally:
            eng.shutdown()
        assert plan.stats()["sites"]["serve.dispatch"]["fires"] == 2


def test_breaker_half_open_recovery(design):
    """After the cooldown the breaker admits one probe; a clean probe
    closes it and normal service resumes."""
    plan = FaultPlan([FaultRule("serve.dispatch", at=(0, 1))])
    with active(plan):
        eng = _engine(design, breaker_threshold=2, breaker_cooldown_ms=50.0)
        try:
            xs = _samples(4, seed=3)
            want = np.asarray(design.forward_int(xs))
            for i in range(2):
                with pytest.raises(FaultInjectedError):
                    eng.submit("m", xs[i]).result(10)
            assert eng.stats("m")["breaker"]["state"] == "open"
            time.sleep(0.08)  # past the cooldown: next batch is the probe
            np.testing.assert_array_equal(eng.submit("m", xs[2]).result(10), want[2])
            s = eng.stats("m")
            assert s["breaker"]["state"] == "closed"
            assert s["breaker"]["n_recoveries"] == 1
            np.testing.assert_array_equal(eng.submit("m", xs[3]).result(10), want[3])
            kinds = {e["kind"] for e in s["flight"]["events"]}
            assert {"breaker_open", "breaker_closed"} <= kinds
        finally:
            eng.shutdown()


def test_interpreter_fallback_serves_bit_exact_while_open(design):
    """With fallback="interpreter" and the jit path failing on every
    dispatch, all requests are still answered — bit-exactly — through
    the numpy interpreter, and the breaker sits open."""
    plan = FaultPlan([FaultRule("serve.dispatch", rate=1.0)])
    with active(plan):
        eng = _engine(
            design,
            fallback="interpreter",
            breaker_threshold=2,
            breaker_cooldown_ms=50.0,
        )
        try:
            xs = _samples(24, seed=4)
            want = np.asarray(design.forward_int(xs))
            futs = [eng.submit("m", x) for x in xs]
            got = np.stack([f.result(10) for f in futs])
            np.testing.assert_array_equal(got, want)
            s = eng.stats("m")
            assert s["breaker"]["state"] == "open"
            assert s["n_fallback_batches"] > 0
            assert s["n_requests"] == 24  # nothing failed
        finally:
            eng.shutdown()


# -- gather failures -------------------------------------------------------


def test_gather_fault_fails_batch_but_not_engine(design):
    """An injected slab-gather failure fails that batch's futures with
    the fault error — the dispatcher survives, later traffic is served,
    and no slab slot leaks."""
    plan = FaultPlan([FaultRule("serve.gather", at=(0,))])
    with active(plan):
        eng = _engine(design)
        try:
            xs = _samples(5, seed=5)
            want = np.asarray(design.forward_int(xs))
            with pytest.raises(FaultInjectedError):
                eng.submit("m", xs[0]).result(10)
            for i in range(1, 5):
                np.testing.assert_array_equal(
                    eng.submit("m", xs[i]).result(10), want[i]
                )
            assert eng.stats("m")["breaker"]["state"] == "closed"
            _free_lists_full(eng)
        finally:
            eng.shutdown()


# -- deadlines and client timeouts ----------------------------------------


def test_expired_deadline_shed_at_the_door(design):
    eng = _engine(design)
    try:
        f = eng.submit("m", _samples(1, seed=6)[0], deadline_s=0.0)
        with pytest.raises(DeadlineExceededError):
            f.result(5)
        assert eng.stats("m")["n_shed"] == 1
    finally:
        eng.shutdown()


def test_deadline_shed_at_batch_form(design):
    """A request whose deadline expires while it waits behind a slow
    batch is shed at batch-form time instead of executed."""
    plan = FaultPlan(
        [FaultRule("serve.dispatch", mode="delay", at=(0,), delay_s=0.3)]
    )
    with active(plan):
        eng = _engine(design)
        try:
            xs = _samples(2, seed=7)
            slow = eng.submit("m", xs[0])  # batch 0: dispatch delayed 300 ms
            time.sleep(0.05)  # make sure it is in flight before the next
            doomed = eng.submit("m", xs[1], deadline_s=0.05)
            assert slow.result(10).shape == (6,)
            with pytest.raises(DeadlineExceededError):
                doomed.result(10)
            assert eng.stats("m")["n_shed"] == 1
            _free_lists_full(eng)
        finally:
            eng.shutdown()


def test_config_default_deadline_applies(design):
    eng = _engine(design, deadline_ms=0.0001)  # ~0: everything expires
    try:
        futs = eng.submit_batch("m", _samples(4, seed=8))
        _, errs = _drain(futs)
        assert len(errs) == 4
        assert all(isinstance(e, DeadlineExceededError) for e in errs)
        assert eng.stats("m")["n_shed"] == 4
    finally:
        eng.shutdown()


def test_client_timeout_counted_and_work_shed(design):
    """infer()'s result timeout is tied into the deadline path: the
    expiry is counted, and the abandoned request was carrying
    deadline_s=timeout so the dispatcher sheds it rather than executing
    work nobody is waiting on."""
    plan = FaultPlan(
        [FaultRule("serve.dispatch", mode="delay", at=(0,), delay_s=0.4)]
    )
    with active(plan):
        eng = _engine(design)
        try:
            xs = _samples(2, seed=9)
            blocker = eng.submit("m", xs[0])  # occupies the dispatcher
            time.sleep(0.05)
            with pytest.raises(FutureTimeoutError):
                eng.infer("m", xs[1], timeout=0.05)
            assert blocker.result(10).shape == (6,)
            s = eng.stats("m")
            assert s["n_client_timeouts"] == 1
            assert s["n_shed"] == 1  # the abandoned request was shed, not run
        finally:
            eng.shutdown()


# -- dispatcher death and supervision -------------------------------------


def test_supervised_restart_serves_through_thread_death(design):
    """A killed dispatcher thread is detected and restarted; submits
    that race the death retry onto the replacement; restart accounting
    is visible in stats."""
    plan = FaultPlan([FaultRule("serve.dispatcher", mode="kill_thread", at=(0,))])
    with active(plan):
        eng = _engine(design, supervise=True, restart_budget=2)
        try:
            xs = _samples(8, seed=10)
            want = np.asarray(design.forward_int(xs))
            # the kill fires on the dispatcher's first loop iteration;
            # these submits land before/after the revive and must all work
            futs = [eng.submit("m", x) for x in xs]
            got = np.stack([f.result(10) for f in futs])
            np.testing.assert_array_equal(got, want)
            s = eng.stats("m")
            sup = s["supervision"]
            assert sup["healthy"] and sup["n_restarts"] == 1
            assert sup["n_crashes"] == 1
            assert any(snap["retired"] for snap in s["shards"])
            kinds = {e["kind"] for e in s["flight"]["events"]}
            assert {"shard_crash", "shard_restart"} <= kinds
            _free_lists_full(eng)
        finally:
            eng.shutdown()


def test_restart_budget_exhaustion_escalates_unhealthy(design):
    plan = FaultPlan([FaultRule("serve.dispatcher", mode="kill_thread", at=(0,))])
    with active(plan):
        eng = _engine(design, supervise=True, restart_budget=0)
        try:
            deadline = time.perf_counter() + 5.0
            while time.perf_counter() < deadline:
                if not eng.stats("m")["supervision"]["healthy"]:
                    break
                time.sleep(0.02)
            s = eng.stats("m")
            assert not s["supervision"]["healthy"]
            with pytest.raises(ModelUnhealthyError):
                eng.submit("m", _samples(1, seed=11)[0])
            kinds = {e["kind"] for e in s["flight"]["events"]}
            assert "model_unhealthy" in kinds
        finally:
            eng.shutdown()


def test_unsupervised_crash_fails_fast_and_stop_does_not_hang(design):
    """With supervision off, a mid-execute thread kill fails the batch's
    futures with ShardCrashedError, marks the model unhealthy, and a
    subsequent shutdown returns promptly (no drain-timeout burn waiting
    on a dead dispatcher) with nothing leaked."""
    plan = FaultPlan([FaultRule("serve.dispatch", mode="kill_thread", at=(0,))])
    with active(plan):
        eng = _engine(design, supervise=False)
        futs = [eng.submit("m", x) for x in _samples(6, seed=12)]
        _, errs = _drain(futs, timeout=5.0)
        assert errs  # at least the killed batch failed
        assert all(isinstance(e, RuntimeError) for e in errs)
        assert not eng.stats("m")["supervision"]["healthy"]
        _free_lists_full(eng)
        t0 = time.perf_counter()
        eng.shutdown(timeout=5.0)
        assert time.perf_counter() - t0 < 3.0  # dead shard skipped, not waited


# -- crash-safe artifacts --------------------------------------------------


def test_torn_npz_write_is_detected(design, tmp_path):
    from repro.chaos import FaultRule as R

    plan = FaultPlan([R("artifact.save.truncate", mode="truncate", at=(0,))])
    with active(plan):
        save_design(design, tmp_path / "d")
    with pytest.raises(ArtifactCorruptError):
        load_design(tmp_path / "d")


def test_crash_before_any_write_preserves_previous_artifact(design, tmp_path):
    path = save_design(design, tmp_path / "d")
    xs = _samples(4, seed=13)
    want = np.asarray(design.forward_int(xs))
    plan = FaultPlan([FaultRule("artifact.save.arrays", at=(0,))])
    with active(plan):
        with pytest.raises(FaultInjectedError):
            save_design(design, path)
    got = np.asarray(load_design(path).forward_int(xs))
    np.testing.assert_array_equal(got, want)


def test_crash_inside_commit_window_never_commits(design, tmp_path):
    """Crash between the npz replace and the manifest write: a fresh
    directory has arrays but no commit record -> typed corruption."""
    plan = FaultPlan([FaultRule("artifact.save.commit", at=(0,))])
    with active(plan):
        with pytest.raises(FaultInjectedError):
            save_design(design, tmp_path / "d")
    assert (tmp_path / "d" / "design.npz").exists()
    assert not (tmp_path / "d" / "manifest.json").exists()
    with pytest.raises(ArtifactCorruptError, match="never committed"):
        load_design(tmp_path / "d")


def test_mixed_generation_after_partial_resave(design, design2, tmp_path):
    """A crash mid-resave leaves new arrays under the old manifest; the
    digest binding catches the mix."""
    path = save_design(design, tmp_path / "d")
    plan = FaultPlan([FaultRule("artifact.save.commit", at=(0,))])
    with active(plan):
        with pytest.raises(FaultInjectedError):
            save_design(design2, path)
    with pytest.raises(ArtifactCorruptError, match="does not match"):
        load_design(path)


def test_quarantine_moves_corrupt_artifact_aside(design, tmp_path):
    plan = FaultPlan([FaultRule("artifact.save.truncate", mode="truncate", at=(0,))])
    with active(plan):
        save_design(design, tmp_path / "d")
    with pytest.raises(ArtifactCorruptError) as ei:
        load_design(tmp_path / "d", on_corrupt="quarantine")
    assert not (tmp_path / "d").exists()
    q = ei.value.quarantined_to
    assert q is not None and q.exists() and q.name == "d.quarantined"
    # the sweep can now retry the name without tripping twice
    with pytest.raises(FileNotFoundError):
        load_design(tmp_path / "d", on_corrupt="quarantine")


def test_injected_load_read_fault(design, tmp_path):
    path = save_design(design, tmp_path / "d")
    plan = FaultPlan([FaultRule("artifact.load.read", at=(0,))])
    with active(plan):
        with pytest.raises(FaultInjectedError):
            load_design(path)
    assert load_design(path) is not None  # artifact itself is intact


# -- metrics surface -------------------------------------------------------


def test_resilience_metrics_families_exposed(design):
    eng = _engine(design)
    try:
        eng.submit("m", _samples(1, seed=14)[0]).result(10)
        text = eng.metrics_text()
        for family in (
            "serve_shed_total",
            "serve_client_timeouts_total",
            "serve_fallback_batches_total",
            "serve_fast_failed_total",
            "serve_breaker_state",
            "serve_breaker_trips_total",
            "serve_restarts_total",
            "serve_healthy",
        ):
            assert family in text
        s = eng.stats("m")
        assert s["breaker"]["state"] == "closed"
        assert s["supervision"]["healthy"]
    finally:
        eng.shutdown()


# -- randomized soak -------------------------------------------------------


@pytest.mark.parametrize("shards", [1, 4])
def test_chaos_soak_every_future_resolves(design, shards):
    """Randomized fault schedule over {jit failure, gather failure,
    thread kill} x {1, 4} shards, with the interpreter fallback armed:
    every future resolves (result or typed error), results that arrive
    are bit-exact, and every slab slot returns to the free list."""
    plan = FaultPlan(
        [
            FaultRule("serve.dispatch", rate=0.05),
            FaultRule("serve.gather", rate=0.02),
            FaultRule(
                "serve.dispatcher", mode="kill_thread", rate=0.02, max_fires=2
            ),
        ],
        seed=1234,
    )
    with active(plan):
        eng = ServeEngine(
            config=ServeConfig(
                max_batch=8,
                max_wait_us=200.0,
                shards=shards,
                fallback="interpreter",
                breaker_threshold=4,
                breaker_cooldown_ms=20.0,
                supervise=True,
                restart_budget=4,
            )
        )
        eng.register("m", design, warmup=True)
        try:
            xs = _samples(240, seed=15)
            want = np.asarray(design.forward_int(xs))
            futs = []
            for i in range(0, 240, 12):
                chunk = xs[i : i + 12]
                if (i // 12) % 3 == 0:
                    futs.extend(eng.submit_batch("m", chunk))
                else:
                    futs.extend(eng.submit("m", x) for x in chunk)
            oks = errs = 0
            for i, f in enumerate(futs):
                try:
                    exc = f.exception(timeout=15.0)
                except FutureTimeoutError:
                    pytest.fail(f"future {i} hung under chaos")
                if exc is None:
                    np.testing.assert_array_equal(f.result(0), want[i])
                    oks += 1
                else:
                    assert isinstance(exc, RuntimeError), exc
                    errs += 1
            assert oks + errs == 240
            assert oks > 0  # the engine kept serving through the faults
            _free_lists_full(eng)
            s = eng.stats("m")
            assert s["supervision"]["n_crashes"] <= 2  # max_fires bound
        finally:
            eng.shutdown()
        assert plan.stats()["sites"]["serve.dispatch"]["hits"] > 0
