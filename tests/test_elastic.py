"""Elastic rescale: a checkpoint written under one mesh restores onto a
different mesh shape with identical values (the restart-after-resize
path for fleet scale-up/down)."""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os, json, tempfile, shutil
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.distributed import MeshRules, use_rules
from repro.launch.mesh import make_test_mesh
from repro.models import init_params, param_shardings
from repro.train import checkpoint

cfg = configs.get_smoke("stablelm-3b")
params = init_params(cfg, jax.random.PRNGKey(0))
d = tempfile.mkdtemp()

# save under a 2x4 mesh
mesh_a = make_test_mesh(2, 4)
rules_a = MeshRules(mesh_a)
with use_rules(rules_a):
    sh_a = param_shardings(cfg, rules_a)
    params_a = jax.device_put(params, sh_a)
checkpoint.save(d, 3, {"p": params_a})

# restore under a 4x2 mesh (elastic reshape), then under 1 device
mesh_b = make_test_mesh(4, 2)
rules_b = MeshRules(mesh_b)
with use_rules(rules_b):
    sh_b = param_shardings(cfg, rules_b)
    restored_b = checkpoint.restore(d, 3, {"p": params}, shardings={"p": sh_b})
restored_1 = checkpoint.restore(d, 3, {"p": params})

d1 = max(float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
         for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored_b["p"])))
d2 = max(float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
         for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored_1["p"])))
ok_shard = all(
    x.sharding.is_equivalent_to(s, x.ndim)
    for x, s in zip(jax.tree.leaves(restored_b["p"]), jax.tree.leaves(sh_b))
)
shutil.rmtree(d)
print(json.dumps({"d_mesh_b": d1, "d_single": d2, "resharded": bool(ok_shard),
                  "n_dev": jax.device_count()}))
"""


@pytest.fixture(scope="module")
def result():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_values_identical_after_mesh_reshape(result):
    assert result["d_mesh_b"] == 0.0


def test_values_identical_on_single_device(result):
    assert result["d_single"] == 0.0


def test_target_shardings_applied(result):
    assert result["resharded"]
