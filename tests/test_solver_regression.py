"""Fixed-seed solver regression guardrails (hypothesis-free).

These anchor the CSE fast path: bit-exactness must hold exactly, and
adder/cost quality must not regress past the recorded baselines (taken
after the vectorized-CSE refactor; the pre-refactor seed numbers were
349/368 adders at 16x16 and 1231/1261 at 32x32, so the ceilings below
also keep us within ~2% of the original solver's quality).
"""

import numpy as np
import pytest

from repro.core import (
    min_tree_depth,
    min_tree_depth_hist,
    naive_adder_tree,
    solve_cmvm,
)

# (m, seed, dc) -> max adders, max cost_bits  (recorded baseline + ~2%)
BASELINES = {
    (16, 42, -1): (355, 4960),
    (16, 42, 2): (371, 5310),
    (32, 43, -1): (1262, 17760),
    (32, 43, 2): (1293, 18410),
}


def _mat(m, seed):
    return np.random.default_rng(seed).integers(2**7 + 1, 2**8, size=(m, m))


@pytest.mark.parametrize("m,seed,dc", sorted(BASELINES))
def test_fixed_seed_quality_and_exactness(m, seed, dc):
    mat = _mat(m, seed)
    sol = solve_cmvm(mat, dc=dc)
    assert sol.verify(), "adder graph must compute x @ M bit-exactly"
    max_adders, max_cost = BASELINES[(m, seed, dc)]
    assert sol.n_adders <= max_adders, (
        f"adder regression: {sol.n_adders} > baseline {max_adders}"
    )
    assert sol.cost_bits <= max_cost, (
        f"cost regression: {sol.cost_bits} > baseline {max_cost}"
    )


@pytest.mark.parametrize("m,seed", [(16, 42), (32, 43)])
def test_da_beats_naive_tree(m, seed):
    mat = _mat(m, seed)
    da = solve_cmvm(mat, dc=-1)
    base = naive_adder_tree(mat)
    assert da.n_adders < base.n_adders
    assert da.cost_bits < base.cost_bits
    # exactness of both, against the same integer product
    x = np.random.default_rng(0).integers(-128, 128, size=(32, m))
    np.testing.assert_array_equal(da.evaluate(x), x @ mat)
    np.testing.assert_array_equal(base.evaluate(x), x @ mat)


def test_solver_deterministic():
    mat = _mat(16, 42)
    a = solve_cmvm(mat, dc=2)
    b = solve_cmvm(mat, dc=2)
    assert a.n_adders == b.n_adders
    assert a.cost_bits == b.cost_bits
    x = np.random.default_rng(1).integers(-128, 128, size=(16, 16))
    np.testing.assert_array_equal(a.evaluate(x), b.evaluate(x))


def test_depth_budget_still_respected():
    """The histogram-memoized delay simulation must honour dc budgets."""
    from repro.core import ceil_log2, csd_nnz

    mat = _mat(16, 44)
    for dc in (0, 1, 2):
        sol = solve_cmvm(mat, dc=dc)
        assert sol.verify()
        nnz = csd_nnz(mat)
        for j, t in enumerate(sol.program.outputs):
            budget = ceil_log2(int(nnz[:, j].sum())) + dc
            assert sol.program.rows[t.row].depth <= budget


def test_min_tree_depth_hist_matches_heap_version():
    rng = np.random.default_rng(7)
    for _ in range(2000):
        depths = rng.integers(0, 9, size=rng.integers(0, 14)).tolist()
        hist: dict[int, int] = {}
        for d in depths:
            hist[d] = hist.get(d, 0) + 1
        assert min_tree_depth_hist(hist) == min_tree_depth(depths), depths
    # zero-count entries must be ignored
    assert min_tree_depth_hist({3: 0}) == 0
    assert min_tree_depth_hist({}) == 0
    assert min_tree_depth_hist({2: 1, 5: 0}) == 2
