"""SSM scan modes must agree: sequential (HBM-optimal) vs chunked
associative (log-depth) vs single-step decode recurrence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import init_params
from repro.models.ssm import mamba_block


def _setup(mode, s=48):
    cfg = configs.get_smoke("falcon-mamba-7b")
    cfg = dataclasses.replace(cfg, ssm_mode=mode)
    params = init_params(cfg, jax.random.PRNGKey(0))
    p = jax.tree.map(lambda a: a[0], params["blocks"][0]["ssm"])  # layer 0
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s, cfg.d_model)) * 0.3
    return cfg, p, x


def test_seq_matches_assoc():
    cfg_s, p, x = _setup("seq")
    cfg_a, _, _ = _setup("assoc")
    y_s, _ = mamba_block(cfg_s, p, x)
    y_a, _ = mamba_block(cfg_a, p, x)
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_a), atol=2e-5)


def test_seq_matches_assoc_gradients():
    cfg_s, p, x = _setup("seq", s=32)
    cfg_a, _, _ = _setup("assoc", s=32)
    g_s = jax.grad(lambda pp: mamba_block(cfg_s, pp, x)[0].sum())(p)
    g_a = jax.grad(lambda pp: mamba_block(cfg_a, pp, x)[0].sum())(p)
    for a, b in zip(jax.tree.leaves(g_s), jax.tree.leaves(g_a)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4, rtol=1e-3)


def test_seq_matches_stepwise_decode():
    """Sequential full-sequence scan == decode recurrence step by step."""
    cfg, p, x = _setup("seq", s=8)
    b = x.shape[0]
    y_full, _ = mamba_block(cfg, p, x)
    cache = {
        "conv": jnp.zeros((b, cfg.ssm_conv - 1, cfg.d_inner)),
        "h": jnp.zeros((b, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }
    ys = []
    for t in range(x.shape[1]):
        y_t, cache = mamba_block(cfg, p, x[:, t : t + 1], cache)
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full), atol=2e-5)
