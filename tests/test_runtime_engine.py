"""Microbatched serving engine: results must be bit-identical to direct
``forward_int``, the registry must isolate models, and backpressure /
shape validation must fail requests loudly instead of corrupting
batches."""

import numpy as np
import pytest

import jax

from repro.nn import QDense, QuantConfig, ReLU, compile_model, init_params
from repro.runtime import QueueFullError, ServeEngine, save_design


@pytest.fixture(scope="module")
def designs():
    wq = QuantConfig(6, 2, signed=True)
    aq = QuantConfig(8, 4, signed=False)
    in_quant = QuantConfig(8, 4, signed=True)
    out = {}
    for name, units in (("a", 6), ("b", 3)):
        model = (QDense(8, wq), ReLU(aq), QDense(units, wq))
        params, _ = init_params(jax.random.PRNGKey(ord(name)), model, (8,))
        out[name] = compile_model(model, params, (8,), in_quant, dc=2)
    return out


def _samples(n, in_quant=QuantConfig(8, 4, signed=True), d=8, seed=0):
    rng = np.random.default_rng(seed)
    q = in_quant.qint
    return np.asarray(rng.integers(q.lo, q.hi + 1, size=(n, d)), np.int32)


def test_engine_results_bit_identical(designs):
    design = designs["a"]
    xs = _samples(100)
    want = np.asarray(design.forward_int(xs))
    with ServeEngine(max_batch=16, max_wait_us=100.0) as eng:
        eng.register("a", design, warmup=True)
        futs = [eng.submit("a", x) for x in xs]
        got = np.stack([f.result(30) for f in futs])
    np.testing.assert_array_equal(got, want)


def test_multi_model_registry(designs):
    xs = _samples(40)
    want = {n: np.asarray(d.forward_int(xs)) for n, d in designs.items()}
    with ServeEngine(max_batch=8, max_wait_us=100.0) as eng:
        for n, d in designs.items():
            eng.register(n, d)
        assert eng.models() == ["a", "b"]
        # interleave the two models' traffic
        futs = [(n, i, eng.submit(n, xs[i])) for i in range(40) for n in ("a", "b")]
        for n, i, f in futs:
            np.testing.assert_array_equal(f.result(30), want[n][i])
        with pytest.raises(ValueError, match="already registered"):
            eng.register("a", designs["a"])
    with pytest.raises(KeyError, match="not registered"):
        eng.submit("a", xs[0])  # shut-down engine has an empty registry


def test_register_from_artifact_path(designs, tmp_path):
    path = save_design(designs["a"], tmp_path / "a")
    xs = _samples(10, seed=5)
    with ServeEngine(max_batch=8) as eng:
        loaded = eng.register("a", path)
        assert loaded.solver_stats["n_solves"] == 0
        futs = [eng.submit("a", x) for x in xs]
        got = np.stack([f.result(30) for f in futs])
    np.testing.assert_array_equal(got, np.asarray(designs["a"].forward_int(xs)))


def test_submit_validates_shape_and_dtype(designs):
    with ServeEngine() as eng:
        eng.register("a", designs["a"])
        with pytest.raises(ValueError, match="expects one sample"):
            eng.submit("a", np.zeros((3, 8), np.int32))
        with pytest.raises(TypeError, match="integer-grid"):
            eng.submit("a", np.zeros((8,), np.float64))


def test_shutdown_never_leaves_hanging_futures(designs):
    """A request in flight when shutdown is called is either served
    during the drain or failed loudly — never left to hang until the
    client's result() timeout (even under a long batching window)."""
    eng = ServeEngine(max_batch=4, max_wait_us=500_000.0)
    eng.register("a", designs["a"], warmup=True)
    f = eng.submit("a", _samples(1, seed=6)[0])
    eng.shutdown()
    try:
        assert f.result(5).shape == (6,)
    except RuntimeError as e:
        assert "shut down" in str(e)


def test_backpressure_reject(designs):
    # tiny queue + a long batching window: the dispatcher sits in its
    # collect wait while we flood the queue, so put_nowait must overflow
    eng = ServeEngine(
        max_batch=4, queue_depth=4, max_wait_us=200_000.0, overflow="reject"
    )
    try:
        eng.register("a", designs["a"], warmup=True)
        xs = _samples(200, seed=1)
        rejected = 0
        futs = []
        for x in xs:
            try:
                futs.append(eng.submit("a", x))
            except QueueFullError:
                rejected += 1
        assert rejected > 0
        assert eng.stats("a")["n_rejected"] == rejected
        for f in futs:
            assert f.result(30).shape == (6,)
    finally:
        eng.shutdown()


def test_cancelled_future_does_not_kill_dispatcher(designs):
    """A client cancelling a queued request must not crash the
    dispatcher thread: the request is dropped and later traffic is
    still served."""
    eng = ServeEngine(max_batch=2, max_wait_us=100_000.0)
    try:
        eng.register("a", designs["a"], warmup=True)
        eng.submit("a", _samples(1, seed=3)[0]).cancel()
        xs = _samples(4, seed=4)
        futs = [eng.submit("a", x) for x in xs]
        got = np.stack([f.result(30) for f in futs])
        np.testing.assert_array_equal(got, np.asarray(designs["a"].forward_int(xs)))
    finally:
        eng.shutdown()


def test_stats_shape(designs):
    with ServeEngine(max_batch=8, max_wait_us=100.0) as eng:
        eng.register("a", designs["a"])
        warm_s = eng.warmup("a")
        assert warm_s > 0
        for f in [eng.submit("a", x) for x in _samples(30, seed=2)]:
            f.result(30)
        s = eng.stats("a")
    assert s["n_requests"] == 30
    assert s["n_batches"] >= 1
    assert 0 < s["mean_batch_occupancy"] <= 1.0
    for k in ("p50_ms", "p95_ms", "p99_ms", "mean_ms", "throughput_rps"):
        assert np.isfinite(s[k]) and s[k] >= 0
    assert s["buckets"][-1] == 8


def test_stats_bucket_histograms(designs):
    """Per-bucket hit histogram and jit-compile counts: warmup compiles
    every bucket, dispatched batches land in exactly one bucket each,
    and the totals reconcile with n_batches."""
    with ServeEngine(max_batch=8, max_wait_us=100.0) as eng:
        eng.register("a", designs["a"])
        s0 = eng.stats("a")
        # fresh runner: nothing hit, nothing compiled yet
        assert s0["bucket_hits"] == {1: 0, 2: 0, 4: 0, 8: 0}
        assert s0["jit_compiles"] == {1: 0, 2: 0, 4: 0, 8: 0}
        assert s0["n_jit_compiles"] == 0
        eng.warmup("a")
        s1 = eng.stats("a")
        # warmup compiles every bucket shape but dispatches no batches
        assert s1["jit_compiles"] == {1: 1, 2: 1, 4: 1, 8: 1}
        assert s1["n_jit_compiles"] == 4
        assert sum(s1["bucket_hits"].values()) == 0
        # a lone request is a 1-element batch -> bucket 1, exactly once
        eng.submit("a", _samples(1, seed=3)[0]).result(30)
        s2 = eng.stats("a")
        assert s2["bucket_hits"][1] == 1
        assert sum(s2["bucket_hits"].values()) == 1
        # a burst: every dispatched batch lands in exactly one bucket
        for f in eng.submit_batch("a", _samples(20, seed=4)):
            f.result(30)
        s3 = eng.stats("a")
        assert sum(s3["bucket_hits"].values()) == s3["n_batches"]
        assert set(s3["bucket_hits"]) == {1, 2, 4, 8}
        # compiles never exceed one per bucket shape (jit caches by shape)
        assert all(c <= 1 for c in s3["jit_compiles"].values())
