"""Microbatched serving engine: results must be bit-identical to direct
``forward_int``, the registry must isolate models, backpressure /
shape validation must fail requests loudly instead of corrupting
batches, and — the serving-shutdown stress net — every Future handed
out by a submit racing ``unregister``/``shutdown``/rollout must resolve
(result or exception) within a bounded timeout, on both the
single-dispatcher and the sharded path."""

import threading
import time
from concurrent.futures import TimeoutError as FutureTimeoutError

import numpy as np
import pytest

import jax

from repro.flow import Deployment, ServeConfig
from repro.nn import QDense, QuantConfig, ReLU, compile_model, init_params
from repro.runtime import EngineClosedError, QueueFullError, ServeEngine, save_design
from repro.runtime.engine import _ModelRunner


@pytest.fixture(scope="module")
def designs():
    wq = QuantConfig(6, 2, signed=True)
    aq = QuantConfig(8, 4, signed=False)
    in_quant = QuantConfig(8, 4, signed=True)
    out = {}
    for name, units in (("a", 6), ("b", 3)):
        model = (QDense(8, wq), ReLU(aq), QDense(units, wq))
        params, _ = init_params(jax.random.PRNGKey(ord(name)), model, (8,))
        out[name] = compile_model(model, params, (8,), in_quant, dc=2)
    return out


def _samples(n, in_quant=None, d=8, seed=0):
    rng = np.random.default_rng(seed)
    q = (in_quant or QuantConfig(8, 4, signed=True)).qint
    return np.asarray(rng.integers(q.lo, q.hi + 1, size=(n, d)), np.int32)


def test_engine_results_bit_identical(designs):
    design = designs["a"]
    xs = _samples(100)
    want = np.asarray(design.forward_int(xs))
    with ServeEngine(max_batch=16, max_wait_us=100.0) as eng:
        eng.register("a", design, warmup=True)
        futs = [eng.submit("a", x) for x in xs]
        got = np.stack([f.result(30) for f in futs])
    np.testing.assert_array_equal(got, want)


def test_multi_model_registry(designs):
    xs = _samples(40)
    want = {n: np.asarray(d.forward_int(xs)) for n, d in designs.items()}
    with ServeEngine(max_batch=8, max_wait_us=100.0) as eng:
        for n, d in designs.items():
            eng.register(n, d)
        assert eng.models() == ["a", "b"]
        # interleave the two models' traffic
        futs = [(n, i, eng.submit(n, xs[i])) for i in range(40) for n in ("a", "b")]
        for n, i, f in futs:
            np.testing.assert_array_equal(f.result(30), want[n][i])
        with pytest.raises(ValueError, match="already registered"):
            eng.register("a", designs["a"])
    with pytest.raises(KeyError, match="not registered"):
        eng.submit("a", xs[0])  # shut-down engine has an empty registry


def test_register_from_artifact_path(designs, tmp_path):
    path = save_design(designs["a"], tmp_path / "a")
    xs = _samples(10, seed=5)
    with ServeEngine(max_batch=8) as eng:
        loaded = eng.register("a", path)
        assert loaded.solver_stats["n_solves"] == 0
        futs = [eng.submit("a", x) for x in xs]
        got = np.stack([f.result(30) for f in futs])
    np.testing.assert_array_equal(got, np.asarray(designs["a"].forward_int(xs)))


def test_submit_validates_shape_and_dtype(designs):
    with ServeEngine() as eng:
        eng.register("a", designs["a"])
        with pytest.raises(ValueError, match="expects one sample"):
            eng.submit("a", np.zeros((3, 8), np.int32))
        with pytest.raises(TypeError, match="integer-grid"):
            eng.submit("a", np.zeros((8,), np.float64))


def test_shutdown_never_leaves_hanging_futures(designs):
    """A request in flight when shutdown is called is either served
    during the drain or failed loudly — never left to hang until the
    client's result() timeout (even under a long batching window)."""
    eng = ServeEngine(max_batch=4, max_wait_us=500_000.0)
    eng.register("a", designs["a"], warmup=True)
    f = eng.submit("a", _samples(1, seed=6)[0])
    eng.shutdown()
    try:
        assert f.result(5).shape == (6,)
    except RuntimeError as e:
        assert "shut down" in str(e)


def test_backpressure_reject(designs):
    # tiny queue + a long batching window: the dispatcher sits in its
    # collect wait while we flood the queue, so put_nowait must overflow
    eng = ServeEngine(
        max_batch=4, queue_depth=4, max_wait_us=200_000.0, overflow="reject"
    )
    try:
        eng.register("a", designs["a"], warmup=True)
        xs = _samples(200, seed=1)
        rejected = 0
        futs = []
        for x in xs:
            try:
                futs.append(eng.submit("a", x))
            except QueueFullError:
                rejected += 1
        assert rejected > 0
        assert eng.stats("a")["n_rejected"] == rejected
        for f in futs:
            assert f.result(30).shape == (6,)
    finally:
        eng.shutdown()


def test_cancelled_future_does_not_kill_dispatcher(designs):
    """A client cancelling a queued request must not crash the
    dispatcher thread: the request is dropped and later traffic is
    still served."""
    eng = ServeEngine(max_batch=2, max_wait_us=100_000.0)
    try:
        eng.register("a", designs["a"], warmup=True)
        eng.submit("a", _samples(1, seed=3)[0]).cancel()
        xs = _samples(4, seed=4)
        futs = [eng.submit("a", x) for x in xs]
        got = np.stack([f.result(30) for f in futs])
        np.testing.assert_array_equal(got, np.asarray(designs["a"].forward_int(xs)))
    finally:
        eng.shutdown()


def test_stats_shape(designs):
    with ServeEngine(max_batch=8, max_wait_us=100.0) as eng:
        eng.register("a", designs["a"])
        warm_s = eng.warmup("a")
        assert warm_s > 0
        for f in [eng.submit("a", x) for x in _samples(30, seed=2)]:
            f.result(30)
        s = eng.stats("a")
    assert s["n_requests"] == 30
    assert s["n_batches"] >= 1
    assert 0 < s["mean_batch_occupancy"] <= 1.0
    for k in ("p50_ms", "p95_ms", "p99_ms", "mean_ms", "throughput_rps"):
        assert np.isfinite(s[k]) and s[k] >= 0
    assert s["buckets"][-1] == 8


def test_stats_bucket_histograms(designs):
    """Per-bucket hit histogram and jit-compile counts: warmup compiles
    every bucket, dispatched batches land in exactly one bucket each,
    and the totals reconcile with n_batches."""
    with ServeEngine(max_batch=8, max_wait_us=100.0) as eng:
        eng.register("a", designs["a"])
        s0 = eng.stats("a")
        # fresh runner: nothing hit, nothing compiled yet
        assert s0["bucket_hits"] == {1: 0, 2: 0, 4: 0, 8: 0}
        assert s0["jit_compiles"] == {1: 0, 2: 0, 4: 0, 8: 0}
        assert s0["n_jit_compiles"] == 0
        eng.warmup("a")
        s1 = eng.stats("a")
        # warmup compiles every bucket shape but dispatches no batches
        assert s1["jit_compiles"] == {1: 1, 2: 1, 4: 1, 8: 1}
        assert s1["n_jit_compiles"] == 4
        assert sum(s1["bucket_hits"].values()) == 0
        # a lone request is a 1-element batch -> bucket 1, exactly once
        eng.submit("a", _samples(1, seed=3)[0]).result(30)
        s2 = eng.stats("a")
        assert s2["bucket_hits"][1] == 1
        assert sum(s2["bucket_hits"].values()) == 1
        # a burst: every dispatched batch lands in exactly one bucket
        for f in eng.submit_batch("a", _samples(20, seed=4)):
            f.result(30)
        s3 = eng.stats("a")
        assert sum(s3["bucket_hits"].values()) == s3["n_batches"]
        assert set(s3["bucket_hits"]) == {1, 2, 4, 8}
        # compiles never exceed one per bucket shape (jit caches by shape)
        assert all(c <= 1 for c in s3["jit_compiles"].values())


# -- sharded dispatch path ------------------------------------------------


def test_sharded_results_bit_identical(designs):
    """shards=4: same bits as direct forward_int, through both submit
    and submit_batch, with traffic spread over every shard."""
    design = designs["a"]
    xs = _samples(200)
    want = np.asarray(design.forward_int(xs))
    cfg = ServeConfig(max_batch=16, max_wait_us=100.0, shards=4)
    with ServeEngine(config=cfg) as eng:
        eng.register("a", design, warmup=True)
        futs = [eng.submit("a", x) for x in xs[:100]]
        futs += eng.submit_batch("a", xs[100:])
        got = np.stack([f.result(30) for f in futs])
        s = eng.stats("a")
    np.testing.assert_array_equal(got, want)
    assert s["n_shards"] == 4 and len(s["shards"]) == 4
    assert all(ss["n_requests"] > 0 for ss in s["shards"])  # round-robin


def test_per_shard_stats_consistency(designs):
    """Per-shard counters reconcile: sum(bucket_hits) == n_batches on
    every shard AND on the aggregate, request counts sum across shards,
    and the per-stage accounting covers every executed batch."""
    cfg = ServeConfig(max_batch=8, max_wait_us=100.0, shards=3)
    with ServeEngine(config=cfg) as eng:
        eng.register("a", designs["a"], warmup=True)
        for f in [eng.submit("a", x) for x in _samples(60, seed=7)]:
            f.result(30)
        for f in eng.submit_batch("a", _samples(40, seed=8)):
            f.result(30)
        s = eng.stats("a")
    for ss in s["shards"]:
        assert sum(ss["bucket_hits"].values()) == ss["n_batches"]
    assert sum(s["bucket_hits"].values()) == s["n_batches"]
    assert s["n_batches"] == sum(ss["n_batches"] for ss in s["shards"])
    assert s["n_requests"] == 100 == sum(ss["n_requests"] for ss in s["shards"])
    ps = s["per_stage"]
    assert ps["dispatch"]["count"] == s["n_batches"]
    assert ps["pad"]["count"] == s["n_batches"]
    assert ps["queue_wait"]["count"] == 100  # one sample per served request
    for rec in ps.values():
        assert np.isfinite(rec["total_ms"]) and rec["total_ms"] >= 0.0
        assert np.isfinite(rec["mean_us"]) and rec["mean_us"] >= 0.0


def test_warmup_failure_leaves_truthful_flags():
    """A warmup that raises mid-loop must flag only the buckets whose
    trace actually completed (pre-fix: flags were set before the call,
    reporting never-traced buckets as compiled)."""

    class _Boom:
        in_shape = (8,)

        @staticmethod
        def forward_int(x):
            if x.shape[0] >= 4:
                raise ValueError("boom bucket")
            return x

    runner = _ModelRunner("boom", _Boom(), 8, 16, 100.0, None, shards=2)
    with pytest.raises(ValueError, match="boom bucket"):
        runner.warmup()
    assert runner.jit_compiles == {1: 1, 2: 1, 4: 0, 8: 0}


def test_rejected_counter_exact_under_concurrency(designs):
    """n_rejected was a racy read-modify-write from submitter threads;
    now it is lock-guarded per shard, so the engine's count must equal
    the rejections the clients actually observed — exactly."""
    cfg = ServeConfig(
        max_batch=4, queue_depth=4, max_wait_us=200_000.0,
        backpressure="reject", shards=2,
    )
    eng = ServeEngine(config=cfg)
    try:
        eng.register("a", designs["a"], warmup=True)
        xs = _samples(64, seed=9)
        n_threads = 4
        rejects = [0] * n_threads
        accepted = [[] for _ in range(n_threads)]

        def flood(i):
            for x in xs:
                try:
                    accepted[i].append(eng.submit("a", x))
                except QueueFullError:
                    rejects[i] += 1

        threads = [
            threading.Thread(target=flood, args=(i,)) for i in range(n_threads)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(30)
        total_rejected = sum(rejects)
        assert total_rejected > 0
        assert eng.stats("a")["n_rejected"] == total_rejected
        for futs in accepted:
            for f in futs:
                assert f.result(30).shape == (6,)
    finally:
        eng.shutdown()


# -- serving-shutdown stress: no future may ever hang ---------------------


def _resolve_all(futures, timeout=5.0):
    """Every future must resolve (result or exception) within timeout;
    returns (n_ok, n_failed) and fails the test on a hang."""
    n_ok = n_failed = 0
    for f in futures:
        try:
            exc = f.exception(timeout=timeout)
        except FutureTimeoutError:
            pytest.fail("future left hanging past the resolution timeout")
        if exc is None:
            n_ok += 1
        else:
            assert isinstance(exc, RuntimeError)  # closed / queue-full
            n_failed += 1
    return n_ok, n_failed


@pytest.mark.parametrize("shards", [1, 4])
def test_shutdown_stress_no_hung_futures(designs, shards):
    """Hammer submit + submit_batch from several threads while shutdown
    proceeds: every Future ever handed out resolves within a bounded
    timeout (the regression net for the put-after-final-sweep race)."""
    cfg = ServeConfig(max_batch=8, max_wait_us=200.0, shards=shards)
    eng = ServeEngine(config=cfg)
    eng.register("a", designs["a"], warmup=True)
    xs = _samples(8, seed=10)
    futures: list = []
    flock = threading.Lock()
    stop = threading.Event()

    def hammer(i):
        n = 0
        while not stop.is_set():
            try:
                if n % 3 == 0:
                    fs = eng.submit_batch("a", xs)
                else:
                    fs = [eng.submit("a", xs[n % len(xs)])]
            except (EngineClosedError, KeyError):
                break
            with flock:
                futures.extend(fs)
            n += 1

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    time.sleep(0.15)
    eng.shutdown(timeout=5.0)
    stop.set()
    for t in threads:
        t.join(5.0)
        assert not t.is_alive()
    n_ok, _ = _resolve_all(futures)
    assert n_ok > 0  # the drain served real traffic before closing


def test_unregister_race_futures_resolve(designs):
    """submit_batch racing unregister across repeated register/drop
    cycles: the drain serves what it can, fails the rest loudly with
    the shut-down error, and nothing hangs."""
    eng = ServeEngine(config=ServeConfig(max_batch=8, max_wait_us=100.0, shards=2))
    try:
        for trial in range(3):
            eng.register("a", designs["a"])
            xs = _samples(16, seed=11 + trial)
            futures: list = []
            flock = threading.Lock()

            def hammer():
                while True:
                    try:
                        fs = eng.submit_batch("a", xs)
                    except (KeyError, EngineClosedError):
                        return
                    with flock:
                        futures.extend(fs)

            threads = [threading.Thread(target=hammer) for _ in range(3)]
            for t in threads:
                t.start()
            time.sleep(0.05)
            eng.unregister("a", timeout=5.0)
            for t in threads:
                t.join(5.0)
                assert not t.is_alive()
            _resolve_all(futures)
    finally:
        eng.shutdown()


def test_rollout_drain_race_futures_resolve(designs):
    """Deployment rollout under concurrent traffic: the alias retry
    hides the flip from clients (no KeyError escapes), v1's in-flight
    futures complete during the drain, and every future resolves."""
    with Deployment(ServeConfig(max_batch=8, max_wait_us=100.0, shards=2)) as dep:
        dep.register("m", designs["a"])
        xs = _samples(8, seed=12)
        futures: list = []
        flock = threading.Lock()
        stop = threading.Event()
        escaped: list = []

        def hammer(i):
            n = 0
            while not stop.is_set():
                try:
                    if i % 2:
                        fs = dep.submit_batch("m", xs)
                    else:
                        fs = [dep.submit("m", xs[n % len(xs)])]
                except Exception as e:  # noqa: BLE001 - recorded and asserted
                    escaped.append(e)
                    return
                with flock:
                    futures.extend(fs)
                n += 1

        threads = [threading.Thread(target=hammer, args=(i,)) for i in range(4)]
        for t in threads:
            t.start()
        for _ in range(3):
            time.sleep(0.05)
            dep.register("m", designs["a"])  # rollout: flip alias, drain old
        stop.set()
        for t in threads:
            t.join(10.0)
            assert not t.is_alive()
        assert not escaped
        n_ok, _ = _resolve_all(futures)
        assert n_ok > 0


def test_blocked_submitters_wake_on_shutdown(designs):
    """Submitters blocked on a saturated queue (block policy) are woken
    by shutdown and fail fast with the shut-down error instead of
    deadlocking inside submit."""
    cfg = ServeConfig(max_batch=4, queue_depth=2, max_wait_us=500_000.0, shards=1)
    eng = ServeEngine(config=cfg)
    eng.register("a", designs["a"], warmup=True)
    xs = _samples(4, seed=13)
    futures: list = []
    flock = threading.Lock()
    outcome: list = []

    def pusher():
        try:
            while True:
                f = eng.submit("a", xs[0])
                with flock:
                    futures.append(f)
        except EngineClosedError:
            outcome.append("closed")

    threads = [threading.Thread(target=pusher) for _ in range(3)]
    for t in threads:
        t.start()
    time.sleep(0.2)  # queue and slab saturate; pushers block in submit
    eng.shutdown(timeout=5.0)
    for t in threads:
        t.join(5.0)
        assert not t.is_alive()
    assert outcome == ["closed"] * 3
    _resolve_all(futures)


def test_submit_after_stop_fails_fast(designs):
    """The shutdown race, deterministically: a submitter that grabbed
    the runner reference just before shutdown popped it must fail fast
    on the put path (or get failed futures) — never enqueue into a
    dispatcherless queue."""
    eng = ServeEngine(config=ServeConfig(max_batch=4, shards=2))
    eng.register("a", designs["a"])
    runner = eng._runner("a")
    x = _samples(1, seed=14)[0]
    eng.shutdown()
    with pytest.raises(EngineClosedError, match="shut down"):
        runner.submit_one(x, time.perf_counter(), block=True)
    futs = runner.submit_many([x] * 3, time.perf_counter(), block=True)
    for f in futs:
        with pytest.raises(EngineClosedError, match="shut down"):
            f.result(1)
