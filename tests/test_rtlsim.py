"""RTL netlist simulator (core/rtlsim.py) and three-way co-simulation
harness (core/cosim.py): parser/evaluator unit tests, Verilog-semantics
regressions (width wrapping, arithmetic shift, signed-width emission),
register fill latency, and grid-level bit-exactness."""

import numpy as np
import pytest

from repro.core import (
    DAISProgram,
    QInterval,
    RTLSimError,
    RTLSimulator,
    Term,
    cosim_case,
    cosim_program,
    emit_verilog,
    parse_verilog,
    pipeline,
    solve_cmvm,
)
from repro.core.cosim import default_grid, external_tool, run_external
from repro.flow import SolverConfig


def _toy_program() -> DAISProgram:
    p = DAISProgram()
    q8 = QInterval.from_fixed(True, 8, 8)
    i0 = p.add_input(q8)
    i1 = p.add_input(q8)
    r2 = p.add_op(i0, i1, 0, 0, 1)
    r3 = p.add_op(r2, i1, 0, 2, 1)
    r4 = p.add_op(r3, i0, 0, 0, -1)
    p.outputs = [Term(1, r4, 0), Term(-1, r2, 1)]
    return p


# ----------------------------------------------------------------------
# parser
# ----------------------------------------------------------------------
def test_parse_toy_module():
    v = emit_verilog(_toy_program(), "toy", max_delay_per_stage=2)
    mod = parse_verilog(v)
    assert mod.name == "toy"
    assert mod.clock == "clk"
    assert mod.inputs == ["x0", "x1"]
    assert mod.outputs == ["y0", "y1"]
    assert mod.signals["x0"].width == 8 and mod.signals["x0"].signed
    assert mod.signals["y0"].width == 11
    # v0, v2, v3 cross the one stage boundary
    assert sorted(a.dst for a in mod.clocked) == ["v0_s1", "v2_s1", "v3_s1"]
    assert mod.latency_cycles == 1


def test_parse_combinational_module():
    v = emit_verilog(_toy_program(), "toy", max_delay_per_stage=None)
    mod = parse_verilog(v)
    assert mod.clock is None
    assert mod.clocked == []
    assert mod.latency_cycles == 0


@pytest.mark.parametrize(
    "src,err",
    [
        ("module m (input wire signed [3:0] a);\n initial x = 1;\nendmodule", "unsupported"),
        ("module m (input wire a, output wire y);\n assign y = b;\nendmodule", "undeclared"),
        ("module m (input wire a, output wire y);\nendmodule", "undriven"),
        (
            "module m (input wire a, output wire y);\n"
            "  wire u;\n  assign u = y;\n  assign y = u;\nendmodule",
            "combinational loop",
        ),
        (
            "module m (input wire a, output wire y);\n"
            "  assign y = a;\n  assign y = a;\nendmodule",
            "multiple drivers",
        ),
    ],
)
def test_parse_rejects(src, err):
    with pytest.raises(RTLSimError, match=err):
        parse_verilog(src)


# ----------------------------------------------------------------------
# Verilog expression semantics
# ----------------------------------------------------------------------
def test_width_wrapping_two_complement():
    """A sum stored in a too-narrow signed wire wraps, exactly as RTL."""
    src = """
module wrap (
  input wire signed [3:0] a,
  input wire signed [3:0] b,
  output wire signed [3:0] y
);
  wire signed [3:0] s;
  assign s = a + b;
  assign y = s;
endmodule
"""
    sim = RTLSimulator(src)
    x = np.array([[7, 7], [-8, -8], [7, 1], [-8, 7]], dtype=np.int64)
    got = sim.run_combinational(x)[:, 0]
    assert got.tolist() == [-2, 0, -8, -1]  # mod-16 two's complement


def test_arithmetic_vs_logical_right_shift():
    src = """
module sh (
  input wire signed [7:0] a,
  output wire signed [7:0] ya,
  output wire signed [7:0] yl
);
  assign ya = (a >>> 2);
  assign yl = (a >> 2);
endmodule
"""
    sim = RTLSimulator(src)
    got = sim.run_combinational(np.array([[-5], [-128], [100]], dtype=np.int64))
    # >>> sign-extends (floor); >> shifts the raw 8-bit pattern in zeros
    assert got[:, 0].tolist() == [-2, -32, 25]
    assert got[:, 1].tolist() == [(-5 & 0xFF) >> 2, (-128 & 0xFF) >> 2, 25]


def test_left_shift_wraps_at_context_width():
    """(a <<< k) inside a narrow assignment wraps mod 2^width."""
    src = """
module shw (
  input wire signed [3:0] a,
  output wire signed [4:0] y
);
  assign y = (a <<< 2);
endmodule
"""
    sim = RTLSimulator(src)
    got = sim.run_combinational(np.array([[7], [-8], [3]], dtype=np.int64))[:, 0]
    # context width max(5, 4) = 5: 28 wraps to -4, -32 wraps to 0
    assert got.tolist() == [-4, 0, 12]


def test_unsigned_expression_zero_extends():
    """One unsigned operand makes the whole expression unsigned (LRM)."""
    src = """
module uz (
  input wire signed [3:0] a,
  input wire [3:0] b,
  output wire [7:0] y
);
  assign y = a + b;
endmodule
"""
    sim = RTLSimulator(src)
    # a = -1 is zero-extended to 15 in the unsigned 8-bit context
    got = sim.run_combinational(np.array([[-1, 1]], dtype=np.int64))[0, 0]
    assert got == 16


def test_unbalanced_pipeline_rejected():
    src = """
module ub (
  input wire clk,
  input wire signed [3:0] a,
  output wire signed [4:0] y
);
  reg signed [3:0] a_q;
  always @(posedge clk) begin
    a_q <= a;
  end
  assign y = a + a_q;
endmodule
"""
    with pytest.raises(RTLSimError, match="unbalanced"):
        parse_verilog(src)


# ----------------------------------------------------------------------
# cycle accuracy
# ----------------------------------------------------------------------
def test_register_fill_latency_and_stream_alignment():
    prog = _toy_program()
    v = emit_verilog(prog, "toy", max_delay_per_stage=1)
    rep = pipeline(prog, 1)
    sim = RTLSimulator(v)
    assert sim.module.latency_cycles == rep.latency_cycles == 2
    rng = np.random.default_rng(7)
    x = rng.integers(-128, 128, size=(32, 2), dtype=np.int64)
    want = prog.evaluate(x)

    # streamed API: aligned, bit-exact
    res = sim.run_stream(x)
    assert np.array_equal(res.y, want)
    assert res.n_cycles == 32 + 2
    assert res.accounting()["stage_register_bits"] == [25, 28]
    assert sum(res.accounting()["stage_register_bits"]) == rep.ff_bits

    # manual stepping proves WHEN outputs appear: y(t) == f(x(t-2)),
    # with the reset state (zeros) flushing out during fill
    sim.reset()
    seen = [sim.step(x[t]) for t in range(6)]
    zero_resp = prog.evaluate(np.zeros(2, dtype=np.int64))
    assert np.array_equal(seen[0], zero_resp)  # cycle 0: reset state
    for t in (2, 3, 4, 5):
        assert np.array_equal(seen[t], want[t - 2])


def test_multistage_carry_chain_mdps1():
    m = np.random.default_rng(3).integers(-64, 64, size=(6, 6))
    sol = solve_cmvm(m, config=SolverConfig(dc=-1))
    rep = pipeline(sol.program, 1)
    assert rep.n_stages >= 3  # actually exercises multi-stage carries
    v = emit_verilog(sol.program, "chain", max_delay_per_stage=1)
    sim = RTLSimulator(v)
    x = np.random.default_rng(4).integers(-128, 128, size=(40, 6), dtype=np.int64)
    assert np.array_equal(sim.run_stream(x).y, sol.program.evaluate(x))


def test_lane_parallel_streams():
    """Lanes are independent module instances clocked in lockstep."""
    prog = _toy_program()
    sim = RTLSimulator(emit_verilog(prog, "toy", max_delay_per_stage=2))
    x = np.random.default_rng(5).integers(-128, 128, size=(10, 3, 2), dtype=np.int64)
    y = sim.run_stream(x).y
    want = prog.evaluate(x)
    assert y.shape == want.shape
    assert np.array_equal(y, want)


# ----------------------------------------------------------------------
# emission regressions surfaced by co-sim
# ----------------------------------------------------------------------
def test_unsigned_interval_gets_explicit_sign_bit():
    """Non-negative intervals on signed wires need width+1 (the co-sim
    caught 255 wrapping to -1 on an 8-bit signed port)."""
    p = DAISProgram()
    qu = QInterval.from_fixed(False, 8, 8)
    a = p.add_input(qu)
    b = p.add_input(qu)
    s = p.add_op(a, b, 0, 0, 1)
    p.outputs = [Term(1, s, 0)]
    v = emit_verilog(p, "uns", max_delay_per_stage=None)
    assert "input wire signed [8:0] x0" in v
    assert "output wire signed [9:0] y0" in v
    x = np.random.default_rng(2).integers(0, 256, size=(64, 2), dtype=np.int64)
    assert np.array_equal(RTLSimulator(v).run_combinational(x), p.evaluate(x))


def test_narrow_signed_port_diverges_is_detected():
    """The simulator must IMPLEMENT wrapping, not paper over it: the
    pre-fix 8-bit-signed-port module really diverges from the
    interpreter on unsigned data (this is the bug the width fix
    removed, kept as a canary that the sim has teeth)."""
    src = """
module narrow (
  input wire signed [7:0] x0,
  input wire signed [7:0] x1,
  output wire signed [8:0] y0
);
  wire signed [8:0] s;
  assign s = x0 + x1;
  assign y0 = s;
endmodule
"""
    sim = RTLSimulator(src)
    x = np.array([[255, 1]], dtype=np.int64)  # 255 wraps to -1 on the port
    assert sim.run_combinational(x)[0, 0] == 0  # RTL truth
    assert 255 + 1 == 256  # what the integer model would say


def test_negative_shift_output_regression_vectors():
    """Fractional fixed point: terms with shift < 0 emit (src >>> k) and
    -(src >>> k); pinned vectors cover both signs and odd residues."""
    p = DAISProgram()
    q = QInterval.from_fixed(True, 10, 4)
    a = p.add_input(q)
    b = p.add_input(q)
    s = p.add_op(a, b, 0, 1, -1)
    p.outputs = [Term(-1, s, -2), Term(1, s, -1)]
    v = emit_verilog(p, "nshift", max_delay_per_stage=None)
    assert "(v2_s0 >>> 2)" in v and "(v2_s0 >>> 1)" in v
    sim = RTLSimulator(v)
    x = np.array(
        [[-512, 511], [511, -512], [-1, 1], [3, -3], [7, 5], [-511, -512]],
        dtype=np.int64,
    )
    got = sim.run_combinational(x)
    want = p.evaluate(x)
    assert np.array_equal(got, want)
    # floor-shift semantics pinned explicitly: -3 >> 1 == -2, not -1
    sm = x[:, 0] - 2 * x[:, 1]
    assert np.array_equal(want[:, 1], sm >> 1)
    assert np.array_equal(want[:, 0], -(sm >> 2))


def test_output_row_consumed_by_later_stage_op():
    """last_use regression (found by the rtlsim property sweep): an
    output row that also feeds an op in a LATER stage than any output
    must keep its stage-carry register — the old code clobbered
    last_use down to the output stage, the register vanished, and the
    late op read a value one cycle too new (rtlsim rejects the result
    as an unbalanced pipeline)."""
    p = DAISProgram()
    q8 = QInterval.from_fixed(True, 8, 8)
    i0 = p.add_input(q8)
    i1 = p.add_input(q8)
    r2 = p.add_op(i0, i1, 0, 2, 1)
    r3 = p.add_op(r2, r2, 1, 0, 1)
    r4 = p.add_op(r3, r2, 1, 0, -1)
    p.add_op(i0, r4, 0, 1, -1)  # stage-1 op consuming input i0; not an output
    p.outputs = [Term(1, i0, 0)]  # the output is the stage-0 input itself
    v = emit_verilog(p, "lu", max_delay_per_stage=2)
    mod = parse_verilog(v)  # pre-fix: RTLSimError("unbalanced pipeline")
    assert "v0_s1" in mod.signals  # the carry register survives
    sim = RTLSimulator(mod)
    x = np.random.default_rng(8).integers(-128, 128, size=(16, 2), dtype=np.int64)
    assert np.array_equal(sim.run_stream(x).y, p.evaluate(x))


def test_zero_output_column():
    m = np.array([[3, 0, -5], [7, 0, 2]])
    rep = cosim_case(m, strategy="da", engine="batch", max_delay_per_stage=2,
                     n_vectors=32, seed=11, jit="skip")
    assert rep["bit_exact"] and rep["latency_ok"]
    assert rep["mismatches_per_output"] == [0, 0, 0]


# ----------------------------------------------------------------------
# co-sim harness
# ----------------------------------------------------------------------
def test_cosim_program_report_shape():
    rep = cosim_program(_toy_program(), max_delay_per_stage=2, n_vectors=16,
                        seed=1, jit="skip")
    assert rep["bit_exact"] and rep["latency_ok"]
    assert rep["n_stages"] == 2
    assert rep["accounting"]["latency_cycles"] == 1
    assert rep["accounting"]["ii"] == 1
    assert rep["accounting"]["register_bits"] == sum(
        rep["accounting"]["stage_register_bits"]
    )


@pytest.mark.parametrize("strategy,engine", [("da", "batch"), ("da", "heap"),
                                             ("da", "arena"), ("latency", None)])
@pytest.mark.parametrize("mdps", [1, None])
def test_cosim_strategy_engine_grid(strategy, engine, mdps):
    m = np.random.default_rng(9).integers(-32, 32, size=(4, 4))
    rep = cosim_case(m, strategy=strategy, engine=engine or "batch",
                     max_delay_per_stage=mdps, n_vectors=48, seed=13, jit="skip")
    assert rep["bit_exact"], rep
    assert rep["latency_ok"], rep


def test_cosim_jit_three_way():
    """The third leg: jitted integer forward, bit-exact with the others."""
    pytest.importorskip("jax")
    m = np.random.default_rng(21).integers(-64, 64, size=(5, 3))
    rep = cosim_case(m, strategy="da", engine="batch", max_delay_per_stage=3,
                     n_vectors=32, seed=17, jit="require")
    assert rep["jit"]["status"] == "checked"
    assert rep["jit"]["bit_exact"]
    assert rep["bit_exact"] and rep["latency_ok"]


def test_default_grid_covers_required_axes():
    cases = default_grid()
    names = [c["name"] for c in cases]
    assert any("zeroneg" in n for n in names)
    assert any("unsigned" in n for n in names)
    assert any("fracgrid" in n for n in names)
    assert any("comb" in n for n in names) and any("-p1" in n for n in names)
    for eng in ("batch", "heap", "arena", "tree"):
        assert any(f"-{eng}-" in n for n in names), eng
    # every case must carry a distinct name (gate keys off names)
    assert len(set(names)) == len(names)


def test_external_leg_skips_loudly_without_tools(capsys):
    if external_tool() is not None:
        pytest.skip("external simulator present; skip-path not reachable")
    p = _toy_program()
    v = emit_verilog(p, "toy", max_delay_per_stage=None)
    x = np.zeros((2, 2), dtype=np.int64)
    rep = run_external(v, "toy", x, p.evaluate(x), 0, mode="auto")
    assert rep["status"] == "skipped"
    assert "SKIP" in capsys.readouterr().out
    with pytest.raises(RuntimeError, match="no external simulator"):
        run_external(v, "toy", x, p.evaluate(x), 0, mode="require")
