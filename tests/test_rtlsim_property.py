"""RTL co-simulation: property-based bit-exactness.

Random small CMVM problems (and random hand-built DAIS programs) are
emitted as Verilog, executed by the pure-Python netlist simulator
(:mod:`repro.core.rtlsim`), and compared against the exact DAIS
interpreter — bit-for-bit, per output and per cycle.  This is the
shrinking counterpart of the fixed grid in benchmarks/rtl_cosim.py:
hypothesis hunts the corner the grid missed, and a failing example
shrinks to a minimal matrix/program.
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import DAISProgram, QInterval, Term, cosim_case, cosim_program


@given(
    st.integers(2, 6),
    st.integers(2, 6),
    st.integers(0, 10**6),
    st.sampled_from([1, 3, None]),
)
@settings(max_examples=20, deadline=None)
def test_cosim_random_cmvm(d_in, d_out, seed, mdps):
    m = np.random.default_rng(seed).integers(-64, 64, size=(d_in, d_out))
    rep = cosim_case(m, max_delay_per_stage=mdps, n_vectors=24,
                     seed=seed, jit="skip")
    assert rep["bit_exact"], rep
    assert rep["latency_ok"], rep
    assert all(c == 0 for c in rep["mismatches_per_output"])


@given(
    st.integers(1, 4),          # n_inputs
    st.integers(0, 10**6),      # seed driving ops/shifts/signs
    st.booleans(),              # signed vs non-negative input intervals
    st.sampled_from([1, 2, None]),
)
@settings(max_examples=20, deadline=None)
def test_cosim_random_programs(n_in, seed, signed, mdps):
    """Hand-built random shift-add programs, bypassing the solver:
    covers operand shifts, NEG outputs, and fractional output shifts
    the solver may not produce for a given matrix."""
    rng = np.random.default_rng(seed)
    p = DAISProgram()
    q = QInterval.from_fixed(signed, 8, 8)
    rows = [p.add_input(q) for _ in range(n_in)]
    for _ in range(int(rng.integers(1, 6))):
        a, b = rng.integers(0, len(rows), size=2)
        rows.append(p.add_op(
            int(rows[a]), int(rows[b]),
            int(rng.integers(0, 3)), int(rng.integers(0, 3)),
            1 if rng.random() < 0.5 else -1,
        ))
    n_out = int(rng.integers(1, 4))
    p.outputs = [
        Term(1 if rng.random() < 0.5 else -1,
             int(rows[int(rng.integers(0, len(rows)))]),
             int(rng.integers(-2, 3)))
        for _ in range(n_out)
    ]
    rep = cosim_program(p, max_delay_per_stage=mdps, n_vectors=24,
                        seed=seed + 1, jit="skip")
    assert rep["bit_exact"], rep
    assert rep["latency_ok"], rep
