"""Distributed correctness on a virtual multi-device CPU mesh.

Runs in a subprocess (XLA_FLAGS must be set before jax initialises) and
checks that the *sharded* train/decode paths produce the same numbers as
the unsharded ones — i.e. the sharding rules change layout, not math —
and that checkpoints written under one mesh restore under another.
"""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.configs.base import RunConfig
from repro.distributed import MeshRules, use_rules
from repro.launch.mesh import make_test_mesh
from repro.models import init_params, param_shardings, loss_fn, decode_step
from repro.models.transformer import prefill
from repro.train.train_lib import make_train_step
from repro.train import checkpoint

cfg = configs.get_smoke("qwen3-moe-30b-a3b")  # MoE: hardest sharding path
run_cfg = RunConfig(learning_rate=1e-3, warmup_steps=1)
params = init_params(cfg, jax.random.PRNGKey(0))
key = jax.random.PRNGKey(1)
batch = {
    "tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
    "labels": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
}
step_fn, opt_init = make_train_step(cfg, run_cfg)

# --- single device reference ---
p1, o1, m1 = jax.jit(step_fn)(params, opt_init(params), batch, 0)
ref_loss = float(m1["loss"])

# --- sharded on a 2x4 (data x model) mesh ---
mesh = make_test_mesh(2, 4)
rules = MeshRules(mesh)
with use_rules(rules):
    p_sh = param_shardings(cfg, rules)
    params_s = jax.device_put(params, p_sh)
    opt_s = jax.jit(opt_init, out_shardings=None)(params_s)
    batch_s = jax.device_put(
        batch, jax.tree.map(lambda x: rules.sharding(("batch",) + (None,)*(x.ndim-1), x.shape), batch)
    )
    p2, o2, m2 = jax.jit(step_fn)(params_s, opt_s, batch_s, 0)
    sh_loss = float(m2["loss"])

# params must match elementwise after the update
dmax = max(
    float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))
)

# --- decode equivalence under sharding ---
with use_rules(rules):
    lg_s, cache_s = jax.jit(lambda p, b: prefill(cfg, p, b, 24))(params_s, batch_s)
lg_r, cache_r = jax.jit(lambda p, b: prefill(cfg, p, b, 24))(params, batch)
dec_diff = float(jnp.abs(lg_s - lg_r).max())

# --- checkpoint written sharded, restored unsharded (reshard) ---
import tempfile, shutil
d = tempfile.mkdtemp()
checkpoint.save(d, 1, {"p": p2})
restored = checkpoint.restore(d, 1, {"p": p1})
ck_diff = max(
    float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(restored["p"]))
)
shutil.rmtree(d)

print(json.dumps({
    "ref_loss": ref_loss, "sh_loss": sh_loss, "param_dmax": dmax,
    "decode_dmax": dec_diff, "ckpt_dmax": ck_diff,
    "n_dev": jax.device_count(),
}))
"""


@pytest.fixture(scope="module")
def dist_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_virtual_mesh_active(dist_result):
    assert dist_result["n_dev"] == 8


def test_sharded_train_step_matches_reference(dist_result):
    import jax

    if not hasattr(jax.sharding, "AxisType"):
        # jax < 0.5 has no explicit mesh axis types; sharded reductions
        # on virtual CPU devices then reassociate float sums, so strict
        # loss parity only holds on versions with Auto axis types.
        pytest.skip("strict sharded-numerics parity needs jax.sharding.AxisType")
    assert abs(dist_result["ref_loss"] - dist_result["sh_loss"]) < 1e-4
    assert dist_result["param_dmax"] < 5e-5


def test_sharded_decode_matches_reference(dist_result):
    assert dist_result["decode_dmax"] < 1e-3


def test_checkpoint_reshard_roundtrip(dist_result):
    assert dist_result["ckpt_dmax"] == 0.0
