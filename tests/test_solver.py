"""da4ml solver: bit-exactness, delay constraints, paper-anchored numbers."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    QInterval,
    ceil_log2,
    csd_nnz,
    decompose,
    emit_verilog,
    min_tree_depth,
    naive_adder_tree,
    pipeline,
    solve_cmvm,
)


def _rand_matrix(rng, d_in, d_out, bw, signed=True):
    lo, hi = (-(2 ** (bw - 1)), 2 ** (bw - 1)) if signed else (0, 2**bw)
    return rng.integers(lo, hi, size=(d_in, d_out))


# ----------------------------------------------------------------------
# Exactness: the adder graph computes x @ M bit-exactly, full precision.
# ----------------------------------------------------------------------
@given(
    st.integers(1, 12),
    st.integers(1, 12),
    st.integers(1, 8),
    st.integers(0, 10**6),
    st.sampled_from([-1, 0, 1, 2]),
)
@settings(max_examples=60, deadline=None)
def test_solver_exact_random(d_in, d_out, bw, seed, dc):
    rng = np.random.default_rng(seed)
    m = _rand_matrix(rng, d_in, d_out, bw)
    sol = solve_cmvm(m, dc=dc)
    x = rng.integers(-128, 128, size=(32, d_in))
    assert np.array_equal(sol.evaluate(x), x @ m)


@given(st.integers(0, 10**6))
@settings(max_examples=20, deadline=None)
def test_solver_exact_sparse(seed):
    rng = np.random.default_rng(seed)
    m = _rand_matrix(rng, 16, 16, 8) * (rng.random((16, 16)) < 0.3)
    sol = solve_cmvm(m)
    x = rng.integers(-128, 128, size=(16, 16))
    assert np.array_equal(sol.evaluate(x), x @ m)


def test_zero_and_duplicate_columns():
    rng = np.random.default_rng(3)
    col = rng.integers(-128, 128, size=(8, 1))
    m = np.concatenate([col, np.zeros((8, 1), np.int64), col, -col, 2 * col], axis=1)
    sol = solve_cmvm(m)
    x = rng.integers(-128, 128, size=(8, 8))
    assert np.array_equal(sol.evaluate(x), x @ m)
    # duplicated/scaled/negated columns should cost (almost) nothing extra
    single = solve_cmvm(col)
    assert sol.n_adders <= single.n_adders + 1


def test_fractional_fixed_point_matrix():
    m = np.array([[0.5, -1.25], [0.75, 2.0]])
    sol = solve_cmvm(m)
    assert sol.out_scale_exp == -2
    x = np.array([[4, 8], [-4, 12]])
    got = sol.evaluate(x) * 2.0**sol.out_scale_exp
    np.testing.assert_allclose(got, x @ m)


def test_wide_input_qints():
    qin = [QInterval.from_fixed(True, 16, 16)] * 6
    rng = np.random.default_rng(7)
    m = _rand_matrix(rng, 6, 6, 6)
    sol = solve_cmvm(m, qint_in=qin)
    x = rng.integers(-(2**15), 2**15, size=(64, 6))
    assert np.array_equal(sol.evaluate(x), x @ m)


# ----------------------------------------------------------------------
# Paper-anchored numbers (Table 2 / Fig 4)
# ----------------------------------------------------------------------
def test_h264_example_eight_adders():
    """Paper Fig. 4: H.264 transform goes 12 -> 8 adders."""
    h264 = np.array(
        [[1, 1, 1, 1], [2, 1, -1, -2], [1, -1, -1, 1], [1, -2, 2, -1]]
    ).T
    base = naive_adder_tree(h264)
    sol = solve_cmvm(h264, decompose_stage=False)
    assert base.n_adders == 12
    assert sol.n_adders == 8
    assert sol.verify()


def test_table2_16x16_adder_counts():
    """16x16 8-bit random matrices: paper reports ~343 (dc=-1), ~456
    (dc=0), ~359 (dc=2) adders vs ~845-baseline. Allow 8% slack."""
    rng = np.random.default_rng(0)
    counts = {-1: [], 0: [], 2: []}
    base_counts = []
    for _trial in range(3):
        m = rng.integers(2**7 + 1, 2**8, size=(16, 16))
        base_counts.append(naive_adder_tree(m).n_adders)
        for dc in counts:
            counts[dc].append(solve_cmvm(m, dc=dc).n_adders)
    assert np.mean(base_counts) == pytest.approx(845, rel=0.08)
    assert np.mean(counts[-1]) == pytest.approx(343, rel=0.08)
    assert np.mean(counts[0]) == pytest.approx(456, rel=0.12)  # ours is better
    assert np.mean(counts[2]) == pytest.approx(359, rel=0.08)
    assert np.mean(counts[0]) <= 456 * 1.02  # must not be worse than paper


def test_delay_constraint_dc0_minimal_depth():
    """dc=0 must achieve the minimal possible adder depth per output."""
    rng = np.random.default_rng(1)
    for _ in range(3):
        m = rng.integers(2**7 + 1, 2**8, size=(12, 12))
        sol = solve_cmvm(m, dc=0)
        nnz = csd_nnz(m)
        for j, t in enumerate(sol.program.outputs):
            min_d = ceil_log2(int(nnz[:, j].sum()))
            assert sol.program.rows[t.row].depth <= min_d
        assert sol.verify()


def test_delay_constraint_dc_monotonic():
    rng = np.random.default_rng(2)
    m = rng.integers(2**7 + 1, 2**8, size=(12, 12))
    adders = [solve_cmvm(m, dc=dc).n_adders for dc in (0, 1, 2)]
    depth = [solve_cmvm(m, dc=dc).depth for dc in (0, 1, 2)]
    un = solve_cmvm(m, dc=-1)
    # relaxing the constraint should never cost more adders (on average;
    # per-matrix we allow 3% heuristic noise)
    assert adders[2] <= adders[0] * 1.03
    assert un.n_adders <= adders[2] * 1.03
    assert depth[0] <= depth[1] <= depth[2] + 1


def test_dc2_depth_budget_respected():
    rng = np.random.default_rng(5)
    m = rng.integers(2**7 + 1, 2**8, size=(16, 16))
    sol = solve_cmvm(m, dc=2)
    nnz = csd_nnz(m)
    for j, t in enumerate(sol.program.outputs):
        budget = ceil_log2(int(nnz[:, j].sum())) + 2
        assert sol.program.rows[t.row].depth <= budget


# ----------------------------------------------------------------------
# Stage 1 decomposition
# ----------------------------------------------------------------------
@given(st.integers(0, 10**6), st.sampled_from([-1, 1, 2, 3]))
@settings(max_examples=40, deadline=None)
def test_decompose_exact(seed, dc):
    rng = np.random.default_rng(seed)
    m = _rand_matrix(rng, 8, 8, 6)
    d = decompose(m, dc)
    assert np.array_equal(d.m1 @ d.m2, m)
    assert np.all(np.abs(d.m2) <= 1)
    if dc >= 0:
        assert d.mst_depth.max() <= 2**dc


def test_decompose_correlated_columns_saves_digits():
    """Columns that differ by small deltas should decompose well."""
    rng = np.random.default_rng(11)
    base = rng.integers(-128, 128, size=16)
    cols = [base + rng.integers(-2, 3, size=16) for _ in range(8)]
    m = np.stack(cols, axis=1)
    d = decompose(m, -1)
    digits_m = int(csd_nnz(m).sum())
    digits_m1 = int(csd_nnz(d.m1).sum())
    assert digits_m1 < digits_m  # transfer vectors are cheaper
    assert not d.is_trivial


def test_decompose_full_scale_random_cancels_msb():
    """Entries drawn from [2^7+1, 2^8) share their MSB, so transfer
    vectors between columns are ~7-bit: stage 1 helps even for random
    matrices in the paper's sampling convention."""
    rng = np.random.default_rng(13)
    m = rng.integers(2**7 + 1, 2**8, size=(12, 12))
    d = decompose(m, -1)
    assert int(csd_nnz(d.m1).sum()) < int(csd_nnz(m).sum())


def test_decompose_never_hurts_much():
    """With CSE downstream, enabling stage 1 should not cost adders."""
    rng = np.random.default_rng(13)
    tot_dec = tot_dir = 0
    for _ in range(3):
        m = rng.integers(-(2**7), 2**7, size=(12, 12))
        tot_dec += solve_cmvm(m, decompose_stage=True).n_adders
        tot_dir += solve_cmvm(m, decompose_stage=False).n_adders
    assert tot_dec <= tot_dir * 1.05


# ----------------------------------------------------------------------
# Pipelining + RTL emission
# ----------------------------------------------------------------------
def test_pipeline_stages_and_ff():
    rng = np.random.default_rng(17)
    m = rng.integers(2**7 + 1, 2**8, size=(16, 16))
    sol = solve_cmvm(m, dc=2)
    rep1 = pipeline(sol.program, max_delay_per_stage=1)
    rep5 = pipeline(sol.program, max_delay_per_stage=5)
    assert rep1.n_stages >= rep5.n_stages
    assert rep1.ff_bits >= rep5.ff_bits  # more stages => more registers
    assert rep5.n_stages == -(-sol.depth // 5) + 1 or rep5.n_stages <= sol.depth + 1
    assert rep1.ii == 1


def test_verilog_emission_smoke():
    rng = np.random.default_rng(19)
    m = rng.integers(-8, 8, size=(4, 3))
    sol = solve_cmvm(m)
    v = emit_verilog(sol.program, "cmvm_t", max_delay_per_stage=2)
    assert "module cmvm_t" in v and "endmodule" in v
    assert v.count("input wire signed") == 4
    assert v.count("output wire signed") == 3
    comb = emit_verilog(sol.program, "cmvm_c", max_delay_per_stage=None)
    assert "posedge" not in comb


def test_min_tree_depth():
    assert min_tree_depth([0, 0, 0, 0]) == 2
    assert min_tree_depth([0] * 5) == 3
    assert min_tree_depth([2, 0, 0]) == 3  # (0,0)->1, (1,2)->3
    assert min_tree_depth([3]) == 3
    assert min_tree_depth([]) == 0


# ----------------------------------------------------------------------
# Cost model sanity
# ----------------------------------------------------------------------
def test_cost_bits_positive_and_scaling():
    rng = np.random.default_rng(23)
    m8 = rng.integers(2**7 + 1, 2**8, size=(8, 8))
    m4 = rng.integers(2**3 + 1, 2**4, size=(8, 8))
    s8, s4 = solve_cmvm(m8), solve_cmvm(m4)
    assert s4.cost_bits < s8.cost_bits  # narrower weights => cheaper
    base8 = naive_adder_tree(m8)
    assert s8.cost_bits < base8.cost_bits


def test_weighting_helps_or_neutral():
    rng = np.random.default_rng(29)
    tot_w = tot_u = 0
    for _ in range(4):
        m = rng.integers(2**7 + 1, 2**8, size=(12, 12))
        tot_w += solve_cmvm(m, weighted=True).cost_bits
        tot_u += solve_cmvm(m, weighted=False).cost_bits
    assert tot_w <= tot_u * 1.05


def test_depth_weight_exact_and_helps_at_dc0():
    """Beyond-paper depth-aware CSE weighting: still bit-exact, and not
    meaningfully worse on average at dc=0 (where its hypothesis applies;
    1% slack for greedy tie-break noise, as in the sibling tests)."""
    tot_dw = tot_base = 0
    for s in range(3):
        m = np.random.default_rng(s).integers(2**7 + 1, 2**8, size=(12, 12))
        sol = solve_cmvm(m, dc=0, depth_weight=0.6)
        assert sol.verify()
        tot_dw += sol.n_adders
        tot_base += solve_cmvm(m, dc=0).n_adders
    assert tot_dw <= tot_base * 1.01
