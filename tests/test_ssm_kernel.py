"""Fused selective-scan Pallas kernel vs jnp oracle (and vs the model's
mamba_block recurrence semantics)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssm_scan import selective_scan
from repro.kernels.ssm_scan.ref import selective_scan_ref


def _inputs(b, s, d, n, seed=0):
    k = jax.random.split(jax.random.PRNGKey(seed), 6)
    dt = jax.nn.softplus(jax.random.normal(k[0], (b, s, d)) - 1.0)
    bm = jax.random.normal(k[1], (b, s, n)) * 0.5
    cm = jax.random.normal(k[2], (b, s, n)) * 0.5
    x = jax.random.normal(k[3], (b, s, d))
    a = -jnp.exp(jax.random.normal(k[4], (d, n)) * 0.3)
    h0 = jax.random.normal(k[5], (b, d, n)) * 0.1
    return dt, bm, cm, x, a, h0


@pytest.mark.parametrize("b,s,d,n,tile", [
    (2, 16, 32, 8, 32),    # single tile
    (1, 32, 64, 16, 16),   # multi-tile channels
    (3, 8, 16, 4, 8),      # small odd-ish
])
def test_ssm_kernel_matches_ref(b, s, d, n, tile):
    args = _inputs(b, s, d, n, seed=b * 10 + s)
    y_ref, h_ref = selective_scan_ref(*args)
    y_k, h_k = selective_scan(*args, use_pallas=True, tile_d=tile)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_ref), atol=1e-5, rtol=1e-5)


def test_ssm_kernel_state_chaining():
    """Scanning two halves with carried state == one full scan."""
    dt, bm, cm, x, a, h0 = _inputs(2, 24, 16, 8, seed=5)
    y_full, h_full = selective_scan(dt, bm, cm, x, a, h0, use_pallas=True, tile_d=16)
    y1, h1 = selective_scan(
        dt[:, :12], bm[:, :12], cm[:, :12], x[:, :12], a, h0,
        use_pallas=True, tile_d=16,
    )
    y2, h2 = selective_scan(
        dt[:, 12:], bm[:, 12:], cm[:, 12:], x[:, 12:], a, h1,
        use_pallas=True, tile_d=16,
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], axis=1)), np.asarray(y_full), atol=1e-5
    )
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), atol=1e-5)
