"""Pipelining + Verilog emission: structural invariants (property-based)."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import emit_verilog, pipeline, solve_cmvm
from repro.core.dais import KIND_INPUT


@given(st.integers(2, 8), st.integers(2, 8), st.integers(0, 10**6), st.integers(1, 6))
@settings(max_examples=25, deadline=None)
def test_pipeline_invariants(d_in, d_out, seed, mdps):
    rng = np.random.default_rng(seed)
    m = rng.integers(-64, 64, size=(d_in, d_out))
    sol = solve_cmvm(m)
    rep = pipeline(sol.program, max_delay_per_stage=mdps)
    prog = sol.program
    for i, r in enumerate(prog.rows):
        if r.kind == KIND_INPUT:
            assert rep.stage_of_row[i] == 0
            continue
        ops = [r.a] if r.b < 0 else [r.a, r.b]
        # operands never live in a later stage
        assert all(rep.stage_of_row[o] <= rep.stage_of_row[i] for o in ops)
        # intra-stage depth bounded by the threshold
        assert 1 <= rep.intra_depth[i] <= mdps
    assert rep.n_stages >= 1
    assert rep.latency_cycles == rep.n_stages - 1
    # ceil(depth / mdps) stages are necessary and sufficient
    assert rep.n_stages - 1 <= -(-sol.depth // mdps)


@given(st.integers(2, 6), st.integers(2, 6), st.integers(0, 10**6))
@settings(max_examples=15, deadline=None)
def test_verilog_structure(d_in, d_out, seed):
    rng = np.random.default_rng(seed)
    m = rng.integers(-32, 32, size=(d_in, d_out))
    sol = solve_cmvm(m)
    v = emit_verilog(sol.program, "m0", max_delay_per_stage=3)
    assert v.count("module ") == 1 and v.count("endmodule") == 1
    assert v.count("input wire signed") == d_in
    assert v.count("output wire signed") == d_out
    # every adder row appears as exactly one assign
    n_assign_ops = sum(
        1 for line in v.splitlines() if "assign" in line and ("+" in line or "-" in line)
    )
    assert n_assign_ops >= sol.n_adders - sum(
        1 for t in sol.program.outputs if t is not None and t.sign < 0
    )


def test_verilog_combinational_has_no_clock():
    m = np.array([[3, -5], [7, 2]])
    sol = solve_cmvm(m)
    v = emit_verilog(sol.program, "comb", max_delay_per_stage=None)
    assert "clk" not in v and "posedge" not in v
