"""Quantized-interval arithmetic: exactness of range propagation."""

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import QInterval
from repro.core.fixed_point import qint_add_shifted

qints = st.builds(
    lambda lo, span, exp: QInterval(lo, lo + span, exp),
    st.integers(-(2**20), 2**20),
    st.integers(0, 2**20),
    st.integers(-8, 8),
)


def test_from_fixed():
    q = QInterval.from_fixed(True, 8, 8)  # signed 8-bit integer
    assert (q.lo, q.hi, q.exp) == (-128, 127, 0)
    assert q.width == 8 and q.signed
    q = QInterval.from_fixed(False, 4, 2)  # ufixed<4,2>: step 1/4, max 3.75
    assert (q.lo, q.hi, q.exp) == (0, 15, -2)
    assert q.width == 4 and not q.signed
    q = QInterval.from_fixed(True, 6, 3)  # fixed<6,3>: [-4, 3.875] step 1/8
    assert (q.lo, q.hi, q.exp) == (-32, 31, -3)


@given(qints, qints, st.integers(0, 12), st.sampled_from([1, -1]))
@settings(max_examples=300, deadline=None)
def test_add_shifted_is_exact_hull(qa, qb, shift, sign):
    """Interval of a + sign*(b<<shift) is the exact reachable hull."""
    q = qint_add_shifted(qa, qb, shift, sign)
    # endpoints are reachable
    for av in (qa.lo, qa.hi):
        for bv in (qb.lo, qb.hi):
            val_num = av * 2 ** (qa.exp - min(qa.exp, qb.exp + shift)) + sign * bv * 2 ** (
                qb.exp + shift - min(qa.exp, qb.exp + shift)
            )
            assert q.lo <= val_num <= q.hi or qa.is_zero or qb.is_zero


@given(qints)
@settings(max_examples=200, deadline=None)
def test_width_covers_range(q):
    w = q.width
    if q.is_zero:
        assert w == 0
        return
    if q.signed:
        assert -(2 ** (w - 1)) <= q.lo and q.hi <= 2 ** (w - 1) - 1
        # minimal: w-1 bits would not fit
        assert q.lo < -(2 ** (w - 2)) or q.hi > 2 ** (w - 2) - 1 or w == 1
    else:
        assert q.hi <= 2**w - 1
        assert q.hi > 2 ** (w - 1) - 1 or w == 0


def test_shift_and_neg():
    q = QInterval(-3, 5, 0)
    assert q.shift(3) == QInterval(-3, 5, 3)
    assert q.neg() == QInterval(-5, 3, 0)
    assert q.shift(3).msb == q.msb + 3


def test_msb_lsb():
    q = QInterval(0, 255, 0)
    assert q.lsb == 0 and q.msb == 7
    q = QInterval(0, 255, -4)
    assert q.lsb == -4 and q.msb == 3
