"""Distributed-runtime substrate: optimizers, checkpointing + crash
recovery, deterministic data, serving engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import RunConfig
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import init_params
from repro.optim import make_adafactor, make_adamw
from repro.optim.quantized_state import dequantize, quantize
from repro.serve.engine import Engine, Request
from repro.train import checkpoint
from repro.train.train_lib import Trainer, make_train_step


# ----------------------------------------------------------------------
# optimizers
# ----------------------------------------------------------------------
def _toy_params(key):
    k1, k2 = jax.random.split(key)
    return {
        "w": jax.random.normal(k1, (32, 16), jnp.float32),
        "b": jnp.zeros((16,)),
        "deep": [{"u": jax.random.normal(k2, (16, 8))}],
    }


def _quad_loss(p, x):
    h = jnp.tanh(x @ p["w"] + p["b"])
    return jnp.sum((h @ p["deep"][0]["u"]) ** 2) / x.shape[0]


@pytest.mark.parametrize("make_opt", [
    lambda: make_adamw(),
    lambda: make_adamw(master_dtype=None),
    lambda: make_adamw(state_dtype="int8"),
    lambda: make_adafactor(),
])
def test_optimizers_descend(make_opt):
    init, update = make_opt()
    key = jax.random.PRNGKey(0)
    params = _toy_params(key)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    state = init(params)
    l0 = float(_quad_loss(params, x))
    for _ in range(20):
        grads = jax.grad(_quad_loss)(params, x)
        params, state = update(grads, state, params, 1e-2)
    assert float(_quad_loss(params, x)) < l0 * 0.7


def test_int8_state_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(2), (1000,)) * 3.0
    z = quantize(x, signed=True)
    err = jnp.abs(dequantize(z) - x).max() / jnp.abs(x).max()
    assert float(err) < 0.02
    x = jnp.abs(x)
    z = quantize(x, signed=False)
    assert float(jnp.abs(dequantize(z) - x).max() / x.max()) < 0.01
    assert z.q.dtype == jnp.uint8


def test_adamw_bf16_params():
    init, update = make_adamw(master_dtype="float32")
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = init(params)
    grads = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    new_params, state = update(grads, state, params, 0.1)
    assert new_params["w"].dtype == jnp.bfloat16
    assert state.master["w"].dtype == jnp.float32


# ----------------------------------------------------------------------
# data pipeline
# ----------------------------------------------------------------------
def test_pipeline_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=512, seq_len=64, global_batch=8, seed=3)
    p1, p2 = Pipeline(cfg), Pipeline(cfg)
    b1 = p1.batch_at(17)
    b2 = p2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch_at(18)["tokens"], b1["tokens"])
    s0 = p1.batch_at(17, shard=0, n_shards=2)
    s1 = p1.batch_at(17, shard=1, n_shards=2)
    assert s0["tokens"].shape[0] == 4
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    # labels are next tokens
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_pipeline_markov_learnable():
    cfg = DataConfig(vocab_size=256, seq_len=128, global_batch=4, seed=5)
    b = Pipeline(cfg).batch_at(0)
    # the chain re-visits states: token distribution must be non-uniform
    _, counts = np.unique(b["tokens"], return_counts=True)
    assert counts.max() > 3 * counts.mean()


# ----------------------------------------------------------------------
# checkpoint + trainer fault tolerance
# ----------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10), "b": [jnp.ones((3, 3)), jnp.zeros(2)]}
    checkpoint.save(str(tmp_path), 5, tree)
    assert checkpoint.latest_step(str(tmp_path)) == 5
    like = jax.tree.map(jnp.zeros_like, tree)
    out = checkpoint.restore(str(tmp_path), 5, like)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_gc_and_atomicity(tmp_path):
    tree = {"a": jnp.arange(4)}
    for s in range(6):
        checkpoint.save(str(tmp_path), s, tree, keep=2)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2 and kept[-1] == "step_00000005"


def _trainer_setup(tmp_path, ckpt_every=2):
    cfg = configs.get_smoke("smollm-135m")
    run_cfg = RunConfig(
        learning_rate=1e-3,
        warmup_steps=2,
        checkpoint_every=ckpt_every,
        checkpoint_dir=str(tmp_path),
        microbatch=1,
    )
    pipe = Pipeline(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4, seed=0)
    )
    train_step, opt_init = make_train_step(cfg, run_cfg)
    jit_step = jax.jit(train_step, donate_argnums=(0, 1))
    def init_fn():
        return init_params(cfg, jax.random.PRNGKey(0))
    return cfg, run_cfg, pipe, init_fn, jit_step, opt_init


def test_trainer_loss_decreases(tmp_path):
    _, run_cfg, pipe, init_fn, jit_step, opt_init = _trainer_setup(tmp_path)
    t = Trainer.resume_or_init(None, run_cfg, pipe, init_fn, jit_step, opt_init)
    first = t._one_step()
    losses = [t._one_step()["loss"] for _ in range(30)]
    assert losses[-1] < first["loss"]


def test_trainer_crash_recovery_resumes_exactly(tmp_path):
    """Crash at step 5; recovery must resume from the last checkpoint and
    reach the same final state as an uninterrupted run (determinism)."""
    _, run_cfg, pipe, init_fn, jit_step, opt_init = _trainer_setup(tmp_path, ckpt_every=2)

    # uninterrupted reference
    t_ref = Trainer.resume_or_init(None, run_cfg, pipe, init_fn, jit_step, opt_init)
    for _ in range(8):
        t_ref._one_step()
    ref_leaves = [np.asarray(x) for x in jax.tree.leaves(t_ref.params)]

    # crashing run (fresh dir)
    run_cfg2 = RunConfig(**{**run_cfg.__dict__, "checkpoint_dir": str(tmp_path) + "_b"})
    t = Trainer.resume_or_init(None, run_cfg2, pipe, init_fn, jit_step, opt_init)
    boom = {"armed": True}

    def fail_hook(step):
        if step == 5 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("simulated node failure")

    t.run(8, fail_hook=fail_hook)
    assert t.step == 8
    got_leaves = [np.asarray(x) for x in jax.tree.leaves(t.params)]
    for a, b in zip(ref_leaves, got_leaves):
        np.testing.assert_allclose(a.astype(np.float32), b.astype(np.float32), atol=2e-5)


def test_microbatch_equivalence(tmp_path):
    """grad accumulation over 2 microbatches ~= single big batch."""
    cfg = configs.get_smoke("smollm-135m")
    base = dict(learning_rate=1e-3, warmup_steps=1, checkpoint_dir=str(tmp_path))
    rc1 = RunConfig(microbatch=1, **base)
    rc2 = RunConfig(microbatch=2, **base)
    pipe = Pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    params = init_params(cfg, jax.random.PRNGKey(0))
    s1, oi1 = make_train_step(cfg, rc1)
    s2, oi2 = make_train_step(cfg, rc2)
    p1, _, m1 = s1(params, oi1(params), batch, 0)
    p2, _, m2 = s2(params, oi2(params), batch, 0)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-5
        )


# ----------------------------------------------------------------------
# serving engine
# ----------------------------------------------------------------------
def test_engine_generates():
    cfg = configs.get_smoke("stablelm-3b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, batch_size=2, max_seq=48, eos_id=-123)
    reqs = [
        Request(np.arange(8, dtype=np.int32), max_new_tokens=6),
        Request(np.arange(8, dtype=np.int32) + 1, max_new_tokens=4),
    ]
    out = eng.generate(reqs)
    assert len(out[0].out_tokens) == 6
    assert len(out[1].out_tokens) == 4
    assert all(0 <= t < cfg.padded_vocab for t in out[0].out_tokens)


def test_engine_deterministic_greedy():
    cfg = configs.get_smoke("stablelm-3b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    outs = []
    for _ in range(2):
        eng = Engine(cfg, params, batch_size=1, max_seq=32, eos_id=-1)
        r = eng.generate([Request(np.arange(8, dtype=np.int32), 5)])
        outs.append(r[0].out_tokens)
    assert outs[0] == outs[1]
