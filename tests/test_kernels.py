"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import solve_cmvm
from repro.kernels.adder_graph import adder_graph_apply, compile_tables
from repro.kernels.adder_graph.ref import adder_graph_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


# ----------------------------------------------------------------------
# adder_graph: Pallas kernel == jnp oracle == numpy DAIS == x @ M
# ----------------------------------------------------------------------
@pytest.mark.parametrize("d_in,d_out,bw,dc", [
    (4, 4, 4, -1),
    (8, 8, 8, -1),
    (16, 12, 6, 2),
    (12, 16, 8, 0),
    (3, 7, 5, 1),
])
def test_adder_graph_kernel_exact(d_in, d_out, bw, dc):
    rng = np.random.default_rng(d_in * 100 + d_out)
    m = rng.integers(-(2 ** (bw - 1)), 2 ** (bw - 1), size=(d_in, d_out))
    sol = solve_cmvm(m, dc=dc)
    tables = compile_tables(sol.program)
    x = rng.integers(-128, 128, size=(37, d_in)).astype(np.int32)
    want = x.astype(np.int64) @ m
    ref = adder_graph_ref(tables, jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(ref), want)
    pallas = adder_graph_apply(tables, jnp.asarray(x), use_pallas=True, block_b=16)
    np.testing.assert_array_equal(np.asarray(pallas), want)


def test_adder_graph_batch_padding_and_lead_dims():
    rng = np.random.default_rng(0)
    m = rng.integers(-16, 16, size=(6, 5))
    sol = solve_cmvm(m)
    tables = compile_tables(sol.program)
    x = rng.integers(-64, 64, size=(3, 11, 6)).astype(np.int32)
    want = x.reshape(-1, 6).astype(np.int64) @ m
    got = adder_graph_apply(tables, jnp.asarray(x), use_pallas=True, block_b=8)
    np.testing.assert_array_equal(np.asarray(got).reshape(-1, 5), want)


def test_adder_graph_zero_column_masked():
    m = np.array([[3, 0], [5, 0]])
    sol = solve_cmvm(m)
    tables = compile_tables(sol.program)
    x = jnp.asarray([[1, 2], [3, -4]], jnp.int32)
    got = adder_graph_apply(tables, x, use_pallas=True, block_b=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x) @ m)


# ----------------------------------------------------------------------
# flash attention: sweep shapes / dtypes / causality / GQA groups
# ----------------------------------------------------------------------
@pytest.mark.parametrize("b,hq,hkv,sq,sk,d", [
    (2, 4, 4, 128, 128, 64),     # MHA square
    (1, 8, 2, 128, 128, 32),     # GQA 4:1
    (2, 4, 1, 64, 256, 32),      # MQA, decode-ish (sq < sk)
    (1, 2, 2, 256, 256, 128),    # larger head dim
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, hq, hkv, sq, sk, d, causal, dtype):
    key = jax.random.PRNGKey(b * 1000 + hq * 100 + sq + int(causal))
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, hq, sq, d), dtype)
    k = jax.random.normal(kk, (b, hkv, sk, d), dtype)
    v = jax.random.normal(kv, (b, hkv, sk, d), dtype)
    want = attention_ref(q, k, v, causal=causal)
    got = flash_attention(q, k, v, causal=causal, use_pallas=True,
                          block_q=64, block_k=64)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=atol
    )


def test_flash_attention_decode_single_query():
    key = jax.random.PRNGKey(7)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (2, 8, 1, 64))
    k = jax.random.normal(kk, (2, 2, 512, 64))
    v = jax.random.normal(kv, (2, 2, 512, 64))
    want = attention_ref(q, k, v, causal=True)
    got = flash_attention(q, k, v, causal=True, use_pallas=True, block_q=1, block_k=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_causal_masks_future():
    """Perturbing future keys must not change causal output."""
    key = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (1, 2, 128, 32))
    k = jax.random.normal(kk, (1, 2, 128, 32))
    v = jax.random.normal(kv, (1, 2, 128, 32))
    out1 = flash_attention(q, k, v, causal=True, use_pallas=True, block_q=64, block_k=64)
    k2 = k.at[:, :, 64:, :].set(99.0)
    v2 = v.at[:, :, 64:, :].set(-99.0)
    out2 = flash_attention(q, k2, v2, causal=True, use_pallas=True, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(out1[:, :, :64]), np.asarray(out2[:, :, :64]), atol=1e-6)
