"""Shared test configuration.

The six property-test modules below use ``hypothesis``.  The package is
an optional dev dependency (see requirements-dev.txt); when it is not
installed those modules are skipped at collection so the rest of the
suite still collects and runs green.
"""

_HYPOTHESIS_MODULES = [
    "test_csd.py",
    "test_fixed_point.py",
    "test_nn_property.py",
    "test_pipelining_verilog.py",
    "test_rtlsim_property.py",
    "test_solver.py",
]

try:
    import hypothesis  # noqa: F401

    collect_ignore: list[str] = []
except ImportError:
    collect_ignore = list(_HYPOTHESIS_MODULES)
