"""Content-addressed solution cache: hit fidelity, key sensitivity,
DAISProgram array round-trip, and disk persistence."""

import numpy as np

from repro.core import (
    DAISProgram,
    QInterval,
    SolutionCache,
    solve_cmvm,
    solve_key,
)


def _mat(seed=0, m=12):
    return np.random.default_rng(seed).integers(2**7 + 1, 2**8, size=(m, m))


def test_cache_hit_evaluates_identically():
    cache = SolutionCache()
    m = _mat()
    cold = solve_cmvm(m, dc=2, cache=cache)
    hot = solve_cmvm(m, dc=2, cache=cache)
    assert not cold.stats.get("cache_hit")
    assert hot.stats.get("cache_hit")
    assert cache.stats.hits == 1 and cache.stats.misses == 1
    x = np.random.default_rng(1).integers(-128, 128, size=(32, m.shape[0]))
    np.testing.assert_array_equal(cold.evaluate(x), hot.evaluate(x))
    np.testing.assert_array_equal(hot.evaluate(x), x @ m)
    assert hot.n_adders == cold.n_adders
    assert hot.cost_bits == cold.cost_bits
    assert hot.verify()


def test_cache_key_changes_with_dc_and_qints():
    m = _mat()
    qin8 = [QInterval.from_fixed(True, 8, 8)] * m.shape[0]
    qin6 = [QInterval.from_fixed(True, 6, 6)] * m.shape[0]
    base = solve_key(m, qin8, [0] * m.shape[0], dc=2, kind="da")
    assert solve_key(m, qin8, [0] * m.shape[0], dc=-1, kind="da") != base
    assert solve_key(m, qin6, [0] * m.shape[0], dc=2, kind="da") != base
    assert solve_key(m, qin8, [1] * m.shape[0], dc=2, kind="da") != base
    assert solve_key(m + 1, qin8, [0] * m.shape[0], dc=2, kind="da") != base
    assert solve_key(m, qin8, [0] * m.shape[0], dc=2, kind="da") == base
    # end-to-end: changing dc or qints misses the cache
    cache = SolutionCache()
    solve_cmvm(m, dc=2, cache=cache)
    s = solve_cmvm(m, dc=-1, cache=cache)
    assert not s.stats.get("cache_hit")
    s = solve_cmvm(m, qint_in=qin6, dc=2, cache=cache)
    assert not s.stats.get("cache_hit")


def test_program_array_round_trip_exact():
    m = _mat(3)
    sol = solve_cmvm(m, dc=2)
    arrays = sol.program.to_arrays()
    clone = DAISProgram.from_arrays(arrays)
    assert clone.n_inputs == sol.program.n_inputs
    assert len(clone.rows) == len(sol.program.rows)
    assert clone.outputs == sol.program.outputs
    for a, b in zip(clone.rows, sol.program.rows):
        assert a == b
    x = np.random.default_rng(2).integers(-128, 128, size=(16, m.shape[0]))
    np.testing.assert_array_equal(clone.evaluate(x), sol.program.evaluate(x))
    assert clone.cost_bits == sol.program.cost_bits
    assert clone.depth == sol.program.depth


def test_disk_round_trip(tmp_path):
    m = _mat(5)
    cache = SolutionCache(disk_dir=str(tmp_path))
    cold = solve_cmvm(m, dc=2, cache=cache)
    # a brand-new cache instance reads the same directory
    cache2 = SolutionCache(disk_dir=str(tmp_path))
    hot = solve_cmvm(m, dc=2, cache=cache2)
    assert hot.stats.get("cache_hit")
    assert cache2.stats.disk_hits == 1
    x = np.random.default_rng(3).integers(-128, 128, size=(8, m.shape[0]))
    np.testing.assert_array_equal(cold.evaluate(x), hot.evaluate(x))
    assert hot.out_scale_exp == cold.out_scale_exp
    assert hot.dc == cold.dc and hot.decomposed == cold.decomposed


def test_fractional_scale_not_cached_wrong():
    """Matrices that integerize identically must still get the caller's
    scale exponent (the cache key covers the integer grid only)."""
    cache = SolutionCache()
    a = solve_cmvm(np.array([[1.0, 3.0]]), cache=cache)
    b = solve_cmvm(np.array([[0.5, 1.5]]), cache=cache)
    assert b.stats.get("cache_hit")
    assert a.out_scale_exp == 0 and b.out_scale_exp == -1


def test_lru_eviction():
    cache = SolutionCache(max_items=2)
    mats = [_mat(seed, m=4) for seed in range(3)]
    for m in mats:
        solve_cmvm(m, cache=cache)
    assert len(cache) == 2
    s = solve_cmvm(mats[0], cache=cache)  # evicted -> miss, re-solved
    assert not s.stats.get("cache_hit")
    s = solve_cmvm(mats[2], cache=cache)  # still resident
    assert s.stats.get("cache_hit")
