"""Golden tests for RTL emission (core/verilog.py) and register
insertion (core/pipelining.py) on small hand-built DAIS programs.

The toy program goldens (stage counts, FF bits) are hand-derived:
with max_delay_per_stage=2 the values crossing the one stage boundary
are v0 (8b), v2 (9b), v3 (11b) -> 28 FF bits; with 1 adder level per
stage v0 crosses twice (16b), v1 once (8b), v2 twice via the y1 output
(18b), v3 once (11b) -> 53 FF bits."""

import re

import numpy as np
import pytest

from repro.core import (
    DAISProgram,
    QInterval,
    Term,
    emit_verilog,
    pipeline,
    solve_cmvm,
)


def _toy_program() -> DAISProgram:
    p = DAISProgram()
    q8 = QInterval.from_fixed(True, 8, 8)
    i0 = p.add_input(q8)
    i1 = p.add_input(q8)
    r2 = p.add_op(i0, i1, 0, 0, 1)     # x0 + x1
    r3 = p.add_op(r2, i1, 0, 2, 1)     # r2 + (x1 << 2)
    r4 = p.add_op(r3, i0, 0, 0, -1)    # r3 - x0
    p.outputs = [Term(1, r4, 0), Term(-1, r2, 1)]
    return p


# ----------------------------------------------------------------------
# pipelining
# ----------------------------------------------------------------------
@pytest.mark.parametrize(
    "mdps,n_stages,ff_bits,stage_of_row",
    [
        (1, 3, 53, [0, 0, 0, 1, 2]),
        (2, 2, 28, [0, 0, 0, 0, 1]),
        (10, 1, 0, [0, 0, 0, 0, 0]),
    ],
)
def test_pipeline_stage_and_ff_goldens(mdps, n_stages, ff_bits, stage_of_row):
    rep = pipeline(_toy_program(), mdps)
    assert rep.n_stages == n_stages
    assert rep.ff_bits == ff_bits
    assert rep.stage_of_row == stage_of_row
    assert rep.latency_cycles == n_stages - 1
    assert rep.ii == 1


def test_pipeline_stages_monotone_in_depth():
    """Tighter delay budgets can only add stages, never remove them."""
    prog = solve_cmvm(np.array([[7, 11], [13, -5], [3, 9]]), dc=-1).program
    stages = [pipeline(prog, mdps).n_stages for mdps in (1, 2, 3, 8)]
    assert stages == sorted(stages, reverse=True)
    assert stages[-1] == 1  # everything fits one stage with a huge budget


# ----------------------------------------------------------------------
# verilog structure
# ----------------------------------------------------------------------
def test_verilog_pipelined_structure_golden():
    v = emit_verilog(_toy_program(), "toy", max_delay_per_stage=2)
    lines = [ln.strip() for ln in v.splitlines()]
    assert lines[0] == "module toy ("
    assert lines[-1] == "endmodule"
    assert "input wire clk" in v
    # ports: 2 inputs at their qint widths, 2 outputs at 11 bits
    assert "input wire signed [7:0] x0" in v
    assert "input wire signed [7:0] x1" in v
    assert v.count("output wire signed [10:0] y") == 2
    # one register per value crossing the stage boundary (v0, v2, v3)
    clocked = re.findall(r"(\w+) <= (\w+);", v)
    assert sorted(dst for dst, _ in clocked) == ["v0_s1", "v2_s1", "v3_s1"]
    assert ("v4_s1", "v3_s1 - v0_s1") in [
        (m.group(1), m.group(2))
        for m in re.finditer(r"assign (\w+) = (.+);", v)
    ]
    # outputs read stage-1 values with term shift/sign applied
    assert "assign y0 = v4_s1;" in v
    assert "assign y1 = -(v2_s1 <<< 1);" in v


def test_verilog_combinational_has_no_clock():
    v = emit_verilog(_toy_program(), "toy_comb", max_delay_per_stage=None)
    assert "clk" not in v
    assert "reg " not in v
    assert "always" not in v
    assert v.count("assign") >= 5  # 2 inputs + 3 ops + 2 outputs


def test_verilog_constant_zero_output():
    p = DAISProgram()
    p.add_input(QInterval.from_fixed(True, 4, 4))
    p.outputs = [None, Term(1, 0, 0)]
    v = emit_verilog(p, "zeros", max_delay_per_stage=None)
    assert "assign y0 = 0;" in v
    assert "assign y1 = v0_s0;" in v


def test_verilog_negation_row():
    p = DAISProgram()
    i0 = p.add_input(QInterval.from_fixed(True, 6, 6))
    r1 = p.add_neg(i0)
    p.outputs = [Term(1, r1, 0)]
    v = emit_verilog(p, "neg", max_delay_per_stage=None)
    assert "assign v1_s0 = -v0_s0;" in v


def test_verilog_solver_program_wellformed():
    """Every op row and every output of a solver-produced program must
    appear as an assignment; stage count matches the pipeline report."""
    sol = solve_cmvm(np.array([[3, 5, -7], [9, 1, 13], [-11, 6, 2]]), dc=2)
    prog = sol.program
    mdps = 2
    rep = pipeline(prog, mdps)
    v = emit_verilog(prog, "cmvm3", max_delay_per_stage=mdps)
    assert v.count("input wire signed") == prog.n_inputs
    assert v.count("output wire signed") == len(prog.outputs)
    for j in range(len(prog.outputs)):
        assert f"assign y{j} = " in v
    # every non-input row gets exactly one combinational assignment
    n_op_assigns = len(re.findall(r"assign v\d+_s\d+ = [^v;]*v\d+", v))
    assert n_op_assigns >= prog.n_adders
    # highest stage suffix ever declared == n_stages - 1
    max_stage = max(int(m.group(1)) for m in re.finditer(r"v\d+_s(\d+)", v))
    assert max_stage == rep.n_stages - 1
    # FF golden consistency: #clocked assigns == #values crossing
    clocked = len(re.findall(r"\w+ <= \w+;", v))
    crossings = 0
    last_use = list(rep.stage_of_row)
    for i, r in enumerate(prog.rows):
        if r.kind != 0:
            for o in ([r.a] if r.b < 0 else [r.a, r.b]):
                last_use[o] = max(last_use[o], rep.stage_of_row[i])
    for t in prog.outputs:
        if t is not None:
            last_use[t.row] = rep.n_stages - 1
    for i in range(len(prog.rows)):
        crossings += max(last_use[i] - rep.stage_of_row[i], 0)
    assert clocked == crossings
