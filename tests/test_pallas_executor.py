"""Pallas adder-graph executor vs the numpy DAIS oracle.

``adder_graph_pallas`` (interpret mode, bit-exact on CPU) must agree
with ``DAISProgram.evaluate`` for solved programs, including the
batch-padding path (batch % block_b != 0) and the degenerate program
with no ops at all.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import solve_cmvm
from repro.kernels.adder_graph import compile_tables
from repro.kernels.adder_graph.kernel import adder_graph_pallas


def _solved_tables(m, dc=-1):
    sol = solve_cmvm(m, dc=dc)
    return sol, compile_tables(sol.program)


@pytest.mark.parametrize("seed,dc", [(0, -1), (1, 0), (2, 2)])
def test_pallas_matches_evaluate(seed, dc):
    rng = np.random.default_rng(seed)
    m = rng.integers(-64, 64, size=(6, 5))
    sol, tables = _solved_tables(m, dc)
    x = rng.integers(-32, 32, size=(16, 6))
    want = sol.program.evaluate(x)
    got = adder_graph_pallas(tables, jnp.asarray(x, jnp.int32), block_b=16)
    np.testing.assert_array_equal(np.asarray(got), want.astype(np.int32))


def test_pallas_batch_padding_path():
    """batch % block_b != 0 exercises the pad/slice path."""
    rng = np.random.default_rng(3)
    m = rng.integers(-16, 16, size=(4, 3))
    sol, tables = _solved_tables(m)
    for batch in (1, 5, 13):
        x = rng.integers(-16, 16, size=(batch, 4))
        want = sol.program.evaluate(x)
        got = adder_graph_pallas(tables, jnp.asarray(x, jnp.int32), block_b=8)
        assert got.shape == (batch, 3)
        np.testing.assert_array_equal(np.asarray(got), want.astype(np.int32))


def test_pallas_degenerate_no_ops():
    """A pure wiring program (identity-ish matrix) has n_ops == 0."""
    m = np.array([[1, 0], [0, -2]])
    sol, tables = _solved_tables(m)
    assert tables.n_ops == 0
    x = np.random.default_rng(4).integers(-8, 8, size=(13, 2))
    want = sol.program.evaluate(x)
    got = adder_graph_pallas(tables, jnp.asarray(x, jnp.int32), block_b=8)
    np.testing.assert_array_equal(np.asarray(got), want.astype(np.int32))


def test_pallas_zero_matrix_masked_outputs():
    """All-zero columns become constant-0 outputs via the mask column."""
    m = np.zeros((3, 2), dtype=np.int64)
    sol, tables = _solved_tables(m)
    assert tables.n_ops == 0
    x = np.random.default_rng(5).integers(-8, 8, size=(6, 3))
    got = adder_graph_pallas(tables, jnp.asarray(x, jnp.int32), block_b=8)
    np.testing.assert_array_equal(np.asarray(got), np.zeros((6, 2), np.int32))
