"""CSD representation: round-trip, canonical form, digit-count minimality."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import csd_nnz, from_csd, to_csd


@given(st.lists(st.integers(-(2**40), 2**40), min_size=1, max_size=64))
@settings(max_examples=200, deadline=None)
def test_csd_roundtrip(values):
    x = np.array(values, dtype=np.int64)
    digits = to_csd(x)
    assert np.array_equal(from_csd(digits), x)


@given(st.lists(st.integers(-(2**40), 2**40), min_size=1, max_size=64))
@settings(max_examples=200, deadline=None)
def test_csd_no_adjacent_nonzero(values):
    x = np.array(values, dtype=np.int64)
    d = to_csd(x)
    adjacent = (d[..., :-1] != 0) & (d[..., 1:] != 0)
    assert not adjacent.any()


@given(st.lists(st.integers(-(2**40), 2**40), min_size=1, max_size=64))
@settings(max_examples=200, deadline=None)
def test_csd_nnz_matches_encoding(values):
    x = np.array(values, dtype=np.int64)
    d = to_csd(x)
    assert np.array_equal((d != 0).sum(axis=-1), csd_nnz(x))


def test_csd_nnz_known_values():
    # 1 -> [1]; 3 -> 4-1; 5 -> 4+1; 7 -> 8-1; 0 -> none; 255 -> 256-1
    x = np.array([0, 1, 2, 3, 5, 7, -7, 255, 170])
    want = np.array([0, 1, 1, 2, 2, 2, 2, 2, 4])
    assert np.array_equal(csd_nnz(x), want)


def test_csd_minimality_small_range():
    """CSD is the minimum-weight signed-digit representation."""
    for v in range(-512, 513):
        nnz = int(csd_nnz(np.array([v]))[0])
        # brute-force lower bound: any signed-binary repr of v needs at
        # least ceil over greedy NAF; check nnz <= popcount(binary)
        assert nnz <= bin(abs(v)).count("1")
        if v != 0:
            assert nnz >= 1


def test_span_too_small_raises():
    with pytest.raises(ValueError):
        to_csd(np.array([1024]), span=5)


def test_csd_average_density():
    """~1/3 of digit positions non-zero on average (paper §4.2)."""
    rng = np.random.default_rng(0)
    x = rng.integers(2**15, 2**16, size=4096)
    density = csd_nnz(x).mean() / 16.0
    assert 0.27 < density < 0.40
