"""compile_model fast path: parallel per-layer solves and the solution
cache must be invisible in the produced integers.

Acceptance anchors: compile_model(jobs=N) is bit-identical to the serial
path, and a second compile of the same model with a cache skips every
solve (asserted via solver stats)."""

import jax
import numpy as np
import pytest

from repro.core import SolutionCache
from repro.nn import compile_model, init_params, models


@pytest.fixture(scope="module")
def jet():
    model, in_shape, in_quant = models.jet_tagger()
    params, _ = init_params(jax.random.PRNGKey(0), model, in_shape)
    return model, params, in_shape, in_quant


def _int_input(in_shape, in_quant, batch=16, seed=0):
    rng = np.random.default_rng(seed)
    q = in_quant.qint
    return np.asarray(
        rng.integers(q.lo, q.hi + 1, size=(batch, *in_shape)), np.int32
    )


def test_parallel_compile_bit_identical(jet):
    model, params, in_shape, in_quant = jet
    serial = compile_model(model, params, in_shape, in_quant, dc=2, jobs=1)
    par = compile_model(model, params, in_shape, in_quant, dc=2, jobs=2)
    xi = _int_input(in_shape, in_quant)
    np.testing.assert_array_equal(
        np.asarray(serial.forward_int(xi)), np.asarray(par.forward_int(xi))
    )
    # identical resource reports too (same solutions stitched)
    assert [r.adders for r in serial.reports] == [r.adders for r in par.reports]
    assert serial.total_cost_bits == par.total_cost_bits


def test_second_compile_skips_all_solves(jet):
    model, params, in_shape, in_quant = jet
    cache = SolutionCache()
    first = compile_model(model, params, in_shape, in_quant, dc=2, jobs=1, cache=cache)
    second = compile_model(model, params, in_shape, in_quant, dc=2, jobs=1, cache=cache)
    n_unique = first.solver_stats["n_solves"] + first.solver_stats["n_cache_hits"]
    assert second.solver_stats["n_solves"] == 0
    assert second.solver_stats["n_cache_hits"] == n_unique
    # solver time on the cached compile is lookup-only (near-free)
    assert second.solver_stats["solver_time_s"] < 0.1
    assert second.solver_stats["solver_time_s"] * 20 < max(
        first.solver_stats["solver_time_s"], 1e-3
    )
    xi = _int_input(in_shape, in_quant, seed=1)
    np.testing.assert_array_equal(
        np.asarray(first.forward_int(xi)), np.asarray(second.forward_int(xi))
    )


def test_cache_counters_in_solver_stats(jet):
    """compile_model surfaces the per-compile SolutionCache counter
    deltas, so artifact-vs-cache savings are directly measurable."""
    model, params, in_shape, in_quant = jet
    cache = SolutionCache()
    first = compile_model(model, params, in_shape, in_quant, dc=2, jobs=1, cache=cache)
    cs1 = first.solver_stats["cache_stats"]
    assert cs1["hits"] == 0
    assert cs1["misses"] == first.solver_stats["n_solves"]
    assert cs1["puts"] == first.solver_stats["n_solves"]
    second = compile_model(model, params, in_shape, in_quant, dc=2, jobs=1, cache=cache)
    cs2 = second.solver_stats["cache_stats"]
    assert cs2["hits"] == second.solver_stats["n_cache_hits"] > 0
    assert cs2["misses"] == 0 and cs2["puts"] == 0
    # no cache -> no counters surfaced
    plain = compile_model(model, params, in_shape, in_quant, dc=2, jobs=1)
    assert "cache_stats" not in plain.solver_stats


def test_warm_cache_compile_skips_repack(jet):
    """The SolutionCache's already-packed arrays are threaded straight
    into ``design.programs``: a warm-cache compile performs **zero**
    ``to_arrays`` repacks (and a cold compile with a cache reuses the
    pack made for the cache entry)."""
    model, params, in_shape, in_quant = jet
    cache = SolutionCache()
    cold = compile_model(model, params, in_shape, in_quant, dc=2, jobs=1, cache=cache)
    # cold path: the pack made by cache.put is reused, never redone
    assert cold.solver_stats["n_program_packs"] == 0
    assert cold.solver_stats["n_program_arrays_reused"] == len(cold.programs)
    warm = compile_model(model, params, in_shape, in_quant, dc=2, jobs=1, cache=cache)
    assert warm.solver_stats["n_cache_hits"] == len(warm.programs)
    assert warm.solver_stats["n_program_packs"] == 0  # no unpack->repack round trip
    assert warm.solver_stats["n_program_arrays_reused"] == len(warm.programs)
    # packed arrays are the same content either way
    for pa, pb in zip(cold.programs, warm.programs):
        for k in pa:
            np.testing.assert_array_equal(pa[k], pb[k])
    # without a cache there is nothing to reuse: every program is packed
    plain = compile_model(model, params, in_shape, in_quant, dc=2, jobs=1)
    assert plain.solver_stats["n_program_packs"] == len(plain.programs)
    assert plain.solver_stats["n_program_arrays_reused"] == 0


def test_solver_stats_populated(jet):
    model, params, in_shape, in_quant = jet
    design = compile_model(model, params, in_shape, in_quant, dc=2, jobs=1)
    st = design.solver_stats
    assert st["n_solves"] >= 1
    assert st["n_cache_hits"] == 0
    assert st["solver_time_s"] > 0
    assert len(design.reports) >= st["n_solves"]
