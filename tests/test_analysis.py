"""Static design verifier: mutation canaries and PR-7 regression closure.

Every canary plants one specific defect in an otherwise-clean compiled
design (or its emitted RTL / saved artifact) and asserts the verifier
reports the *expected* DA0xx code — and the clean design stays silent
across the full strategy x engine compile grid.  The two PR 7 bug
classes are re-introduced at the source level (string-patching the
production module and executing the mutant) and must be flagged
statically, with distinct codes, without running a single test vector.
"""

import copy
import json
import re
import sys
import types
from dataclasses import replace
from pathlib import Path

import jax
import numpy as np
import pytest

import repro.core.pipelining as pipelining_mod
import repro.core.verilog as verilog_mod
from repro.analysis import (
    CODES,
    DesignVerificationError,
    DiagnosticReport,
    check_emission,
    check_pipeline,
    check_program,
    required_signed_width,
    verify_design,
)
from repro.analysis.__main__ import main as analysis_cli
from repro.core.dais import DAISProgram, Term
from repro.core.fixed_point import QInterval
from repro.flow import CompileConfig, Flow, SolverConfig
from repro.nn import QDense, QuantConfig, ReLU, compile_model, init_params
from repro.runtime import load_design, save_design

jax.config.update("jax_enable_x64", True)

# rows-array columns (see DAISProgram.to_arrays)
_KIND, _A, _B, _SH_A, _SH_B, _SIGN, _DEPTH, _COST, _QLO, _QHI, _QEXP = range(11)


def _small_dense():
    wq = QuantConfig(6, 2, signed=True)
    aq = QuantConfig(8, 4, signed=False)
    model = (QDense(12, wq), ReLU(aq), QDense(5, wq))
    return model, (10,), QuantConfig(8, 4, signed=True)


def _compile(config=None):
    model, in_shape, in_quant = _small_dense()
    params, _ = init_params(jax.random.PRNGKey(0), model, in_shape)
    cfg = config or CompileConfig(verify="off")
    return compile_model(model, params, in_shape, in_quant, config=cfg)


@pytest.fixture(scope="module")
def design():
    return _compile()


def _mutant(design):
    """Shallow design copy whose packed arrays/reports can be doctored."""
    d = copy.copy(design)
    d.programs = [
        None if p is None else {k: np.array(v) for k, v in p.items()}
        for p in design.programs
    ]
    d.reports = list(design.reports)
    return d


def _first_op_row(parr):
    rows = parr["rows"]
    return int(np.nonzero(rows[:, _KIND] != 0)[0][0])


# ----------------------------------------------------------------------
# clean designs are silent
# ----------------------------------------------------------------------
def test_clean_design_verifies_strict(design):
    rep = verify_design(design, tier="strict")
    assert rep.ok, rep.summary()
    assert {"program", "steps", "emission"} <= set(rep.pass_wall_s)


@pytest.mark.parametrize("strategy", ["da", "latency"])
@pytest.mark.parametrize("engine", ["batch", "arena", "heap"])
def test_compile_grid_silent(strategy, engine):
    cfg = CompileConfig(
        strategy=strategy,
        solver=SolverConfig(dc=2, engine=engine),
        verify="strict",  # the gate itself would raise on any error
    )
    d = _compile(cfg)
    v = d.solver_stats["verify"]
    assert v["ok"] and v["tier"] == "strict"
    assert v["n_errors"] == 0
    assert v["wall_s"] > 0
    assert all(layer["ok"] for layer in v["per_layer"].values())


def test_flow_verify_returns_report(design):
    rep = Flow.verify(design, tier="cheap")
    assert isinstance(rep, DiagnosticReport)
    assert rep.ok


# ----------------------------------------------------------------------
# mutation canaries: one defect -> one expected code
# ----------------------------------------------------------------------
def test_canary_stale_interval_da004(design):
    d = _mutant(design)
    parr = d.programs[0]
    i = _first_op_row(parr)
    parr["rows"][i, _QHI] += 1  # interval no longer the derived truth
    rep = verify_design(d, tier="cheap")
    assert not rep.ok
    assert "DA004" in rep.codes(), rep.summary()


def test_canary_flipped_shift_sign_da003(design):
    d = _mutant(design)
    parr = d.programs[0]
    rows = parr["rows"]
    cand = np.nonzero((rows[:, _KIND] == 1) & (rows[:, _SH_A] + rows[:, _SH_B] > 0))[0]
    assert cand.size, "fixture program has no shifted adder to mutate"
    i = int(cand[0])
    col = _SH_A if rows[i, _SH_A] > 0 else _SH_B
    rows[i, col] = -rows[i, col]
    rep = verify_design(d, tier="cheap")
    assert not rep.ok
    assert "DA003" in rep.codes(), rep.summary()


def test_canary_dangling_ref_da001(design):
    d = _mutant(design)
    parr = d.programs[0]
    i = _first_op_row(parr)
    parr["rows"][i, _A] = i  # self-reference: must name an earlier row
    rep = verify_design(d, tier="cheap")
    assert not rep.ok
    assert "DA001" in rep.codes(), rep.summary()


def test_canary_wrong_latency_da047(design):
    d = _mutant(design)
    d.reports[0] = replace(d.reports[0], stages=d.reports[0].stages + 1)
    rep = verify_design(d, tier="cheap")
    assert not rep.ok
    assert "DA047" in rep.codes(), rep.summary()


def test_canary_width_minus_one_da009(design):
    prog = DAISProgram.from_arrays(design.programs[0])
    src = verilog_mod.emit_verilog(prog, max_delay_per_stage=5)
    m = re.search(r"(wire|reg) signed \[(\d+):0\] v\d+_s\d+", src)
    assert m is not None
    w = int(m.group(2))
    doctored = src[: m.start(2)] + str(w - 1) + src[m.end(2):]
    rep = check_emission(prog, 5, src=doctored)
    assert "DA009" in rep.codes(), rep.summary()
    # the undoctored emission is clean
    assert check_emission(prog, 5, src=src).ok


def test_canary_tampered_npz_da041(design, tmp_path):
    path = save_design(design, tmp_path / "art")
    with np.load(path / "design.npz", allow_pickle=False) as z:
        arrays = {k: np.array(z[k]) for k in z.files}
    key = next(k for k in sorted(arrays) if arrays[k].size)
    arrays[key].flat[0] += 1
    np.savez_compressed(path / "design.npz", **arrays)  # manifest kept stale
    rep = verify_design(path, tier="cheap")
    assert not rep.ok
    assert "DA041" in rep.codes(), rep.summary()


def test_canary_config_digest_da042(design, tmp_path):
    path = save_design(design, tmp_path / "art")
    manifest = json.loads((path / "manifest.json").read_text())
    manifest["compile_config"]["max_delay_per_stage"] += 1
    (path / "manifest.json").write_text(json.dumps(manifest))
    rep = verify_design(path, tier="cheap")
    assert not rep.ok
    assert "DA042" in rep.codes(), rep.summary()


# ----------------------------------------------------------------------
# PR 7 bug classes, re-introduced at the source level
# ----------------------------------------------------------------------
def _exec_mutant(module, old: str, new: str) -> types.ModuleType:
    """Execute a copy of ``module`` with ``old`` replaced by ``new``."""
    src = Path(module.__file__).read_text()
    assert old in src, f"mutation anchor not found in {module.__name__}"
    mutated = src.replace(old, new)
    mod = types.ModuleType(module.__name__ + "_mutant")
    mod.__package__ = module.__package__  # keep relative imports working
    mod.__file__ = module.__file__
    sys.modules[mod.__name__] = mod  # dataclass decorators resolve via here
    exec(compile(mutated, module.__file__, "exec"), mod.__dict__)
    return mod


def _carry_tap_program():
    """Output row consumed by an op in a LATER stage than any output.

    Exactly the shape whose carry registers PR 7's ``last_use`` clobber
    dropped: with max_delay_per_stage=1 the chained adds land in stages
    past the output tap, so row ``o``'s value must still be carried."""
    p = DAISProgram()
    x0 = p.add_input(QInterval(-8, 7, 0))
    x1 = p.add_input(QInterval(-8, 7, 0))
    o = p.add_op(x0, x1, 0, 0, 1)
    t = p.add_op(o, x0, 0, 0, 1)  # stage 1 consumer of the output row
    p.add_op(t, x1, 0, 0, 1)  # keeps the late logic two stages deep
    p.outputs = [Term(1, o, 0)]
    return p


def test_pr7_signed_width_bug_da009(design, monkeypatch):
    buggy = _exec_mutant(
        verilog_mod,
        "w = q.width + (0 if q.lo < 0 else 1)",
        "w = q.width",  # the pre-PR-7 emitter: no sign bit for q.lo >= 0
    )
    import repro.analysis.program as program_mod

    # the second CMVM sits behind a ReLU, so its input rows are
    # non-negative — exactly where the missing sign bit bites
    prog = DAISProgram.from_arrays(design.programs[-1])
    assert any(r.qint.lo >= 0 and not r.qint.is_zero for r in prog.rows)
    assert check_emission(prog, 5).ok  # production emitter is clean
    monkeypatch.setattr(program_mod, "emit_verilog", buggy.emit_verilog)
    rep = check_emission(prog, 5)
    assert "DA009" in rep.codes(), rep.summary()


def test_pr7_last_use_clobber_da010():
    buggy = _exec_mutant(
        pipelining_mod,
        "last_use[t.row] = max(last_use[t.row], n_stages - 1)",
        "last_use[t.row] = n_stages - 1",  # the pre-PR-7 assignment
    )
    prog = _carry_tap_program()
    assert check_pipeline(prog, 1).ok  # production pipeliner is clean
    rep = check_pipeline(prog, 1, claimed=buggy.pipeline(prog, 1))
    assert "DA010" in rep.codes(), rep.summary()


def test_pr7_last_use_clobber_emission_da011(monkeypatch):
    buggy = _exec_mutant(
        verilog_mod,
        "last_use[t.row] = max(last_use[t.row], n_stage - 1)",
        "last_use[t.row] = n_stage - 1",
    )
    import repro.analysis.program as program_mod

    prog = _carry_tap_program()
    assert check_emission(prog, 1).ok
    monkeypatch.setattr(program_mod, "emit_verilog", buggy.emit_verilog)
    rep = check_emission(prog, 1)
    assert "DA011" in rep.codes(), rep.summary()
    # distinct codes for the two PR 7 classes (DA009 vs DA010/DA011)
    assert not {"DA009"} & rep.codes()


# ----------------------------------------------------------------------
# gates: compile / load / CLI
# ----------------------------------------------------------------------
def test_compile_gate_records_stats():
    d = _compile(CompileConfig())  # default tier is "cheap"
    v = d.solver_stats["verify"]
    assert v["tier"] == "cheap" and v["ok"]
    # per_layer is keyed by CMVM slot name (layers deduplicate onto slots)
    assert v["per_layer"] and all(w["ok"] for w in v["per_layer"].values())
    assert all(isinstance(w["wall_s"], float) for w in v["per_layer"].values())
    assert "pass_wall_s" in v and "program" in v["pass_wall_s"]


def test_bad_verify_tier_rejected():
    with pytest.raises(Exception, match="verify"):
        CompileConfig(verify="bogus")
    with pytest.raises(ValueError, match="tier"):
        verify_design(_compile(), tier="bogus")


def test_load_gate_raises(design, tmp_path):
    path = save_design(design, tmp_path / "art")
    manifest = json.loads((path / "manifest.json").read_text())
    manifest["reports"][0]["stages"] += 1  # digest covers arrays, not reports
    (path / "manifest.json").write_text(json.dumps(manifest))
    with pytest.raises(DesignVerificationError) as ei:
        load_design(path, verify="cheap")
    assert "DA047" in {d.code for d in ei.value.report.errors}
    loaded = load_design(path)  # default stays off: digest-only loading
    assert loaded.solver_stats["n_solves"] == 0


def test_cli_roundtrip(design, tmp_path, capsys):
    good = save_design(design, tmp_path / "good")
    out = tmp_path / "diag.json"
    rc = analysis_cli([str(good), "--tier", "cheap", "--json", str(out)])
    assert rc == 0
    doc = json.loads(out.read_text())
    assert doc[str(good)]["ok"]

    bad = save_design(design, tmp_path / "bad")
    manifest = json.loads((bad / "manifest.json").read_text())
    manifest["resources"]["total_adders"] += 1
    (bad / "manifest.json").write_text(json.dumps(manifest))
    rc = analysis_cli([str(bad), "--tier", "cheap", "--quiet"])
    assert rc == 1
    assert "FAIL" in capsys.readouterr().out


# ----------------------------------------------------------------------
# unit seams
# ----------------------------------------------------------------------
def test_required_signed_width_rule():
    assert required_signed_width(QInterval(0, 0, 0)) == 1
    assert required_signed_width(QInterval(0, 1, 0)) == 2  # sign bit paid
    assert required_signed_width(QInterval(0, 255, 0)) == 9
    assert required_signed_width(QInterval(-1, 0, 0)) == 1
    assert required_signed_width(QInterval(-256, 255, 0)) == 9


def test_dead_row_warning_da008():
    p = DAISProgram()
    x0 = p.add_input(QInterval(-4, 3, 0))
    x1 = p.add_input(QInterval(-4, 3, 0))
    o = p.add_op(x0, x1, 0, 0, 1)
    p.add_op(o, x1, 0, 0, 1)  # never tapped
    p.outputs = [Term(1, o, 0)]
    rep = check_program(p)
    assert rep.ok  # warning severity: gates stay green
    assert "DA008" in rep.codes()


def test_codes_registry_is_stable():
    # append-only registry: canaries and CI logs key on these meanings
    assert CODES["DA009"][0] == "error"
    assert CODES["DA010"][0] == "error"
    assert CODES["DA041"][0] == "error"
    assert CODES["DA008"][0] == "warning"
    assert all(re.fullmatch(r"DA0\d\d", c) for c in CODES)
