"""Versioned rollout (`flow.Deployment`) and batched submit.

Anchors
-------
* register v2 -> alias flips atomically, v1 drains (its queued /
  in-flight futures complete with **v1's** results), new traffic lands
  on v2;
* `submit_batch` is bit-identical to per-request submit and fails
  overflowing futures (reject policy) instead of losing the batch;
* `ServeEngine.register` rejects duplicate names loudly (replacement is
  a Deployment versioning operation, never silent).
"""

import numpy as np
import pytest

import jax

from repro.flow import CompileConfig, Deployment, Flow, ServeConfig
from repro.nn import QDense, QuantConfig, init_params
from repro.runtime import QueueFullError, ServeEngine


@pytest.fixture(scope="module")
def two_designs():
    """Two designs over the same in/out shapes with different weights."""
    wq = QuantConfig(6, 2, signed=True)
    iq = QuantConfig(8, 4, signed=True)
    model = (QDense(4, wq),)
    out = []
    for seed in (1, 2):
        params, _ = init_params(jax.random.PRNGKey(seed), model, (8,))
        out.append(Flow.compile(model, params, (8,), iq, config=CompileConfig(jobs=1)))
    return out


def _samples(n, seed=0):
    rng = np.random.default_rng(seed)
    return np.asarray(rng.integers(-8, 8, size=(n, 8)), np.int32)


def test_rollout_v1_drains_v2_serves(two_designs):
    d1, d2 = two_designs
    xs = _samples(24)
    want1 = np.asarray(d1.forward_int(xs))
    want2 = np.asarray(d2.forward_int(xs))
    # long batching window: v1's requests sit queued while we roll v2
    with Flow.serve(ServeConfig(max_batch=4, max_wait_us=300_000.0)) as dep:
        assert dep.register("m", d1, warmup=True) == 1
        inflight = [dep.submit("m", x) for x in xs]
        assert dep.register("m", d2, warmup=True) == 2  # flip + drain v1
        # every in-flight v1 future completed with v1's results
        got1 = np.stack([f.result(30) for f in inflight])
        np.testing.assert_array_equal(got1, want1)
        # v1 is gone, alias serves v2
        assert dep.versions("m") == [2]
        assert dep.active_version("m") == 2
        assert dep.engine.models() == ["m@v2"]
        got2 = np.stack([f.result(30) for f in dep.submit_batch("m", xs)])
        np.testing.assert_array_equal(got2, want2)
        assert dep.stats("m")["version"] == 2


def test_rollout_explicit_versions_and_rollback(two_designs):
    d1, d2 = two_designs
    x = _samples(1, seed=9)[0]
    w1 = np.asarray(d1.forward_int(x[None]))[0]
    w2 = np.asarray(d2.forward_int(x[None]))[0]
    with Deployment(ServeConfig(max_batch=4, max_wait_us=100.0)) as dep:
        dep.register("m", d1, version=10)
        assert dep.active_version("m") == 10
        dep.register("m", d2, version=20, drain=False)  # keep v10 alive
        assert dep.versions("m") == [10, 20]
        np.testing.assert_array_equal(dep.infer("m", x), w2)
        dep.activate("m", 10)  # rollback
        np.testing.assert_array_equal(dep.infer("m", x), w1)
        with pytest.raises(ValueError, match="already registered"):
            dep.register("m", d1, version=20)
        with pytest.raises(KeyError, match="no live version"):
            dep.activate("m", 99)
        dep.unregister("m", 10)
        with pytest.raises(KeyError, match="no active version"):
            dep.infer("m", x)  # active version was dropped explicitly
        dep.activate("m", 20)
        np.testing.assert_array_equal(dep.infer("m", x), w2)


def test_deployment_registry_isolation(two_designs):
    d1, d2 = two_designs
    x = _samples(1, seed=3)[0]
    with Flow.serve(models={"a": d1, "b": d2}) as dep:
        assert dep.models() == ["a", "b"]
        assert dep.versions("a") == [1] and dep.versions("b") == [1]
        np.testing.assert_array_equal(dep.infer("a", x), np.asarray(d1.forward_int(x[None]))[0])
        np.testing.assert_array_equal(dep.infer("b", x), np.asarray(d2.forward_int(x[None]))[0])
        dep.unregister("a")
        assert dep.models() == ["b"]
        with pytest.raises(KeyError, match="no active version"):
            dep.submit("a", x)


def test_submit_batch_bit_identical(two_designs):
    d1, _ = two_designs
    xs = _samples(50, seed=4)
    want = np.asarray(d1.forward_int(xs))
    with ServeEngine(config=ServeConfig(max_batch=16, max_wait_us=100.0)) as eng:
        eng.register("m", d1, warmup=True)
        futs = eng.submit_batch("m", xs)
        assert len(futs) == 50
        got = np.stack([f.result(30) for f in futs])
    np.testing.assert_array_equal(got, want)


def test_submit_batch_reject_fails_futures_not_batch(two_designs):
    d1, _ = two_designs
    cfg = ServeConfig(max_batch=4, queue_depth=4, max_wait_us=200_000.0, backpressure="reject")
    eng = ServeEngine(config=cfg)
    try:
        eng.register("m", d1, warmup=True)
        futs = eng.submit_batch("m", _samples(64, seed=5))
        assert len(futs) == 64
        ok = rejected = 0
        for f in futs:
            try:
                assert f.result(30).shape == (4,)
                ok += 1
            except QueueFullError:
                rejected += 1
        assert rejected > 0 and ok > 0
        assert eng.stats("m")["n_rejected"] == rejected
    finally:
        eng.shutdown()


def test_engine_duplicate_register_is_loud(two_designs):
    d1, d2 = two_designs
    with ServeEngine(config=ServeConfig(max_batch=4)) as eng:
        eng.register("m", d1)
        with pytest.raises(ValueError, match="already registered"):
            eng.register("m", d2)  # silent replacement would mix designs
        assert eng.models() == ["m"]


def test_engine_legacy_kwargs_warn_and_match_config():
    with pytest.warns(DeprecationWarning, match="ServeConfig"):
        eng = ServeEngine(max_batch=8, overflow="reject")
    assert eng.config == ServeConfig(max_batch=8, backpressure="reject")
    assert eng.overflow == "reject" and eng.max_batch == 8
    with pytest.raises(TypeError, match="not both"):
        ServeEngine(max_batch=8, config=ServeConfig())
