"""The repro.flow API: typed configs and the legacy-shim equivalence.

Anchors
-------
* configs validate, round-trip through to_dict/from_dict, and digest by
  content (runtime-only fields excluded);
* the legacy kwarg shims (`solve_cmvm(dc=...)`, `compile_model(dc=...)`)
  and the config paths (`config=`, `Flow.compile`) produce **bit-
  identical** DAIS programs and artifacts across strategy x engine;
* mixing config= with legacy kwargs is a loud TypeError, and the legacy
  path warns DeprecationWarning.
"""

import warnings

import numpy as np
import pytest

import jax

from repro.core import QInterval, SolutionCache, config_solve_key, solve_cmvm
from repro.flow import (
    CompileConfig,
    ConfigError,
    Flow,
    ServeConfig,
    SolverConfig,
)
from repro.nn import QDense, QuantConfig, ReLU, compile_model, init_params
from repro.runtime import load_design, save_design


def _legacy(fn, *args, **kw):
    """Call a deprecated-kwarg shim with its warning silenced."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return fn(*args, **kw)


def _mat(d_in=8, d_out=8, seed=0):
    return np.random.default_rng(seed).integers(-128, 128, size=(d_in, d_out))


@pytest.fixture(scope="module")
def tiny():
    wq = QuantConfig(6, 2, signed=True)
    aq = QuantConfig(8, 4, signed=False)
    model = (QDense(6, wq), ReLU(aq), QDense(4, wq))
    params, _ = init_params(jax.random.PRNGKey(0), model, (8,))
    return model, params, (8,), QuantConfig(8, 4, signed=True)


# ----------------------------------------------------------------------
# config objects
# ----------------------------------------------------------------------
def test_config_validation():
    with pytest.raises(ConfigError, match="engine"):
        SolverConfig(engine="quantum")
    with pytest.raises(ConfigError, match="dc"):
        SolverConfig(dc=-2)
    with pytest.raises(ConfigError, match="strategy"):
        CompileConfig(strategy="resource")
    with pytest.raises(ConfigError, match="jobs"):
        CompileConfig(jobs=0)
    with pytest.raises(ConfigError, match="backpressure"):
        ServeConfig(backpressure="drop")
    with pytest.raises(ConfigError, match="bucket"):
        ServeConfig(max_batch=16, buckets=(4,))


def test_config_roundtrip_and_digest():
    for cfg in (
        SolverConfig(dc=3, engine="heap", depth_weight=0.5),
        CompileConfig(strategy="latency", jobs=4, solver=SolverConfig(dc=0)),
        ServeConfig(max_batch=8, buckets=(8, 2, 1), backpressure="reject"),
    ):
        d = cfg.to_dict()
        back = type(cfg).from_dict(d)
        assert back == cfg
        assert back.digest() == cfg.digest()
    with pytest.raises(ConfigError, match="unknown"):
        SolverConfig.from_dict({"dc": 2, "warp": 9})


def test_digest_is_content_identity():
    assert SolverConfig(dc=2).digest() == SolverConfig(dc=2).digest()
    assert SolverConfig(dc=2).digest() != SolverConfig(dc=3).digest()
    assert SolverConfig().digest() != CompileConfig().digest()  # class-tagged
    # runtime-only fields never change compile identity
    base = CompileConfig()
    assert base.digest() == CompileConfig(jobs=16).digest()
    assert base.digest() == CompileConfig(cache=SolutionCache()).digest()
    assert base.digest() != CompileConfig(max_delay_per_stage=3).digest()
    # nested solver feeds the compile digest
    assert base.digest() != CompileConfig(solver=SolverConfig(dc=3)).digest()


def test_config_replace():
    cfg = ServeConfig()
    assert cfg.replace(max_batch=8).max_batch == 8
    assert cfg.max_batch == 256  # frozen original untouched


def test_cache_excluded_from_serialization():
    cfg = CompileConfig(cache=SolutionCache(), jobs=2)
    d = cfg.to_dict()
    assert "cache" not in d and d["jobs"] == 2
    assert CompileConfig.from_dict(d).cache is None


def test_wrong_config_type_rejected(tiny):
    from repro.runtime import ServeEngine

    model, params, in_shape, in_quant = tiny
    with pytest.raises(ConfigError, match="CompileConfig"):
        Flow.compile(model, params, in_shape, in_quant, config=SolverConfig())
    with pytest.raises(ConfigError, match="SolverConfig"):
        solve_cmvm(_mat(), config=CompileConfig())
    with pytest.raises(ConfigError, match="ServeConfig"):
        ServeEngine(config=SolverConfig())


def test_design_config_does_not_pin_live_cache(tiny):
    """CompiledDesign keeps the config *identity*; the runtime-only
    cache handle is stripped so the design never pins the LRU's packed
    entries (and matches what load_design can reconstruct)."""
    model, params, in_shape, in_quant = tiny
    cache = SolutionCache()
    design = Flow.compile(
        model, params, in_shape, in_quant, config=CompileConfig(jobs=1, cache=cache)
    )
    assert design.config.cache is None
    assert design.config.digest() == CompileConfig(jobs=1).digest()


# ----------------------------------------------------------------------
# shim <-> config equivalence
# ----------------------------------------------------------------------
def test_solve_cmvm_shim_warns_and_matches():
    m = _mat()
    with pytest.warns(DeprecationWarning, match="SolverConfig"):
        legacy = solve_cmvm(m, dc=2, engine="batch")
    cfg = solve_cmvm(m, config=SolverConfig(dc=2))
    a, b = legacy.program.to_arrays(), cfg.program.to_arrays()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_solve_cmvm_rejects_mixed_spelling():
    with pytest.raises(TypeError, match="not both"):
        solve_cmvm(_mat(), dc=2, config=SolverConfig())


def test_compile_model_rejects_mixed_spelling(tiny):
    model, params, in_shape, in_quant = tiny
    with pytest.raises(TypeError, match="not both"):
        compile_model(model, params, in_shape, in_quant, dc=2, config=CompileConfig())


@pytest.mark.parametrize("strategy", ["da", "latency"])
@pytest.mark.parametrize("engine", ["batch", "heap", "arena"])
def test_flow_compile_bit_identical_to_legacy_kwargs(tiny, strategy, engine):
    """The acceptance grid: old kwargs vs Flow.compile(config=) produce
    bit-identical DAIS programs, steps, reports, and artifacts."""
    model, params, in_shape, in_quant = tiny
    legacy = _legacy(
        compile_model, model, params, in_shape, in_quant,
        dc=2, strategy=strategy, engine=engine, jobs=1,
    )
    cfg = CompileConfig(
        strategy=strategy, jobs=1, solver=SolverConfig(dc=2, engine=engine)
    )
    flow = Flow.compile(model, params, in_shape, in_quant, config=cfg)

    # identical packed programs
    assert len(legacy.programs) == len(flow.programs)
    for pa, pb in zip(legacy.programs, flow.programs):
        assert (pa is None) == (pb is None)
        for k in pa or ():
            np.testing.assert_array_equal(pa[k], pb[k])
    # identical step topology
    assert [s.kind for s in legacy.step_specs] == [s.kind for s in flow.step_specs]
    # identical execution + reports
    rng = np.random.default_rng(1)
    q = in_quant.qint
    x = rng.integers(q.lo, q.hi + 1, size=(32, *in_shape)).astype(np.int32)
    np.testing.assert_array_equal(
        np.asarray(legacy.forward_int(x)), np.asarray(flow.forward_int(x))
    )
    # identical reports up to solver wall time
    from dataclasses import asdict

    def _rep(reports):
        out = []
        for r in reports:
            d = asdict(r)
            d.pop("solver_time_s")
            out.append(d)
        return out

    assert _rep(legacy.reports) == _rep(flow.reports)
    # both paths carry the same config identity
    assert legacy.config.digest() == flow.config.digest()


def test_artifacts_identical_through_both_paths(tiny, tmp_path):
    model, params, in_shape, in_quant = tiny
    legacy = _legacy(compile_model, model, params, in_shape, in_quant, dc=2, jobs=1)
    flow = Flow.compile(model, params, in_shape, in_quant, config=CompileConfig(jobs=1))
    import json

    save_design(legacy, tmp_path / "legacy")
    flow.save(tmp_path / "flow")
    ma = json.loads((tmp_path / "legacy" / "manifest.json").read_text())
    mb = json.loads((tmp_path / "flow" / "manifest.json").read_text())
    # identical design bytes and identical embedded config
    assert ma["arrays_sha256"] == mb["arrays_sha256"]
    assert ma["compile_config"] == mb["compile_config"]
    assert ma["compile_config_digest"] == mb["compile_config_digest"]


def test_config_roundtrips_through_artifact(tiny, tmp_path):
    model, params, in_shape, in_quant = tiny
    cfg = CompileConfig(jobs=1, solver=SolverConfig(dc=1, engine="heap"))
    design = Flow.compile(model, params, in_shape, in_quant, config=cfg)
    design.save(tmp_path / "d")
    loaded = Flow.load(tmp_path / "d")
    assert loaded.config == CompileConfig.from_dict(cfg.to_dict())
    assert loaded.config.digest() == cfg.digest()
    # Design.load classmethod is the same loader
    from repro.flow import Design

    again = Design.load(tmp_path / "d")
    x = np.zeros((2, *in_shape), np.int32)
    np.testing.assert_array_equal(
        np.asarray(loaded.forward_int(x)), np.asarray(again.forward_int(x))
    )


def test_pre_config_artifacts_still_load(tiny, tmp_path):
    """Manifests written before the config era (no compile_config key)
    must keep loading — config comes back as None."""
    import json

    model, params, in_shape, in_quant = tiny
    design = Flow.compile(model, params, in_shape, in_quant, config=CompileConfig(jobs=1))
    path = design.save(tmp_path / "d")
    mpath = path / "manifest.json"
    manifest = json.loads(mpath.read_text())
    del manifest["compile_config"], manifest["compile_config_digest"]
    mpath.write_text(json.dumps(manifest, indent=2, sort_keys=True))
    loaded = load_design(path)
    assert loaded.config is None
    x = np.zeros((2, *in_shape), np.int32)
    np.testing.assert_array_equal(
        np.asarray(loaded.forward_int(x)), np.asarray(design.forward_int(x))
    )


# ----------------------------------------------------------------------
# config-digest cache keys
# ----------------------------------------------------------------------
def test_cache_keys_shared_between_solver_and_compiler_paths(tiny):
    """solve_cmvm(config=, cache=) and compile_model(config=, cache=)
    must hit the same SolutionCache entries: both derive keys from the
    SolverConfig digest (config identity, not ad-hoc kwarg tuples)."""
    m = _mat(6, 5, seed=3)
    cache = SolutionCache()
    scfg = SolverConfig(dc=2)
    solve_cmvm(m, config=scfg, cache=cache)
    assert cache.stats.puts == 1
    qin8 = [QInterval.from_fixed(True, 8, 8)] * 6
    key = config_solve_key(m, qin8, [0] * 6, scfg)
    assert cache.get(key) is not None  # the solver's internal key == config_solve_key


def test_solver_digest_partitions_cache():
    m = _mat(6, 5, seed=4)
    cache = SolutionCache()
    a = solve_cmvm(m, config=SolverConfig(dc=2), cache=cache)
    b = solve_cmvm(m, config=SolverConfig(dc=-1), cache=cache)  # different digest
    assert cache.stats.misses == 2 and cache.stats.puts == 2
    assert not a.stats.get("cache_hit") and not b.stats.get("cache_hit")
    hot = solve_cmvm(m, config=SolverConfig(dc=2), cache=cache)
    assert hot.stats.get("cache_hit")


def test_engine_in_digest_and_cache_keys():
    """Every engine has its own config digest, hence its own solution-
    cache key — a heap-solved entry never masquerades as an arena one —
    and the legacy ``engine=`` kwarg shim accepts "arena"."""
    engines = ("batch", "heap", "arena")
    digests = {SolverConfig(dc=2, engine=e).digest() for e in engines}
    assert len(digests) == len(engines)
    m = _mat(6, 5, seed=9)
    qin = [QInterval.from_fixed(True, 8, 8)] * 6
    keys = {
        config_solve_key(m, qin, [0] * 6, SolverConfig(dc=2, engine=e))
        for e in engines
    }
    assert len(keys) == len(engines)
    # end-to-end: one cache, three engines -> three distinct entries
    cache = SolutionCache()
    for e in engines:
        s = solve_cmvm(m, config=SolverConfig(dc=2, engine=e), cache=cache)
        assert not s.stats.get("cache_hit")
    assert cache.stats.puts == len(engines)
    # legacy spelling accepts the new engine (deprecated but equivalent)
    with pytest.warns(DeprecationWarning):
        legacy = solve_cmvm(m, dc=2, engine="arena")
    cfg_sol = solve_cmvm(m, config=SolverConfig(dc=2, engine="arena"))
    np.testing.assert_array_equal(
        legacy.program.to_arrays()["rows"], cfg_sol.program.to_arrays()["rows"]
    )
