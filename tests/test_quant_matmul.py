"""W8A8 Pallas kernel vs jnp oracle: shape/dtype sweep + exactness."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.quant_matmul import quant_matmul
from repro.kernels.quant_matmul.ref import quant_matmul_ref


def _inputs(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, size=(m, k)).astype(np.int8)
    w = rng.integers(-128, 128, size=(k, n)).astype(np.int8)
    xs = rng.uniform(0.5, 2.0, m).astype(np.float32)
    ws = rng.uniform(0.01, 0.1, n).astype(np.float32)
    return map(jnp.asarray, (x, w, xs, ws))


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 256, 128, 128, 128, 256),   # exactly one block
    (256, 512, 256, 128, 128, 256),   # multi-block all dims
    (64, 128, 32, 32, 32, 64),        # small blocks
    (100, 200, 60, 32, 32, 64),       # ragged (padded)
])
def test_quant_matmul_matches_ref(m, k, n, bm, bn, bk):
    x, w, xs, ws = _inputs(m, k, n, seed=m + n)
    want = quant_matmul_ref(x, w, xs, ws)
    got = quant_matmul(x, w, xs, ws, use_pallas=True, block_m=bm, block_n=bn, block_k=bk)
    # int8 x int8 sums over <=512 terms stay exact in f32 (<2^24)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_quant_matmul_int_exactness():
    """With unit scales the result equals the exact integer product."""
    x, w, _, _ = _inputs(64, 128, 64, seed=7)
    ones_m = jnp.ones((64,), jnp.float32)
    ones_n = jnp.ones((64,), jnp.float32)
    got = quant_matmul(x, w, ones_m, ones_n, use_pallas=True, block_m=32, block_n=32, block_k=64)
    want = np.asarray(x, np.int64) @ np.asarray(w, np.int64)
    np.testing.assert_array_equal(np.asarray(got).astype(np.int64), want)
