"""Per-architecture smoke tests: reduced same-family config, one forward
+ one train step on CPU, asserting output shapes and finiteness (the FULL
configs are exercised via the dry-run only — ShapeDtypeStruct, no
allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import RunConfig
from repro.models import decode_step, forward, init_params
from repro.models.transformer import prefill
from repro.train.train_lib import make_train_step

ALL_ARCHS = list(configs.ARCHS)


def _batch(cfg, key, b=2, s=32):
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["enc_frames"] = jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(key, (b, cfg.vision_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_shapes_and_finite(name):
    cfg = configs.get_smoke(name)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, aux = forward(cfg, params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_one_train_step(name):
    cfg = configs.get_smoke(name)
    run_cfg = RunConfig(learning_rate=1e-3, warmup_steps=1, master_dtype=None)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    step_fn, opt_init = make_train_step(cfg, run_cfg)
    batch = _batch(cfg, key)
    new_params, _, metrics = step_fn(params, opt_init(params), batch, 0)
    assert bool(jnp.isfinite(metrics["loss"]))
    # params must actually change
    diffs = [
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    ]
    assert max(diffs) > 0
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(new_params))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_consistency(name):
    """Greedy decode after prefill must equal teacher-forced argmax:
    position bookkeeping, cache masking and RoPE offsets all line up."""
    cfg = configs.get_smoke(name)
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    b, s = 2, 16
    batch = _batch(cfg, key, b, s)
    extra = cfg.vision_tokens if cfg.family == "vlm" else 0

    # MoE top-k routing sits on a discrete boundary: chunked-scan float
    # regrouping can flip an expert choice, shifting logits by O(1e-3).
    atol = 1e-2 if cfg.n_experts else 5e-4
    logits_full, _ = forward(cfg, params, batch)
    lg, cache = prefill(cfg, params, batch, max_seq=s + extra + 8)
    np.testing.assert_allclose(
        np.asarray(lg, np.float32),
        np.asarray(logits_full[:, -1, :], np.float32),
        atol=atol,
    )
    # decode 2 steps matches teacher forcing on the extended sequence
    tok = jnp.argmax(lg, -1)[:, None]
    lg2, cache = decode_step(cfg, params, tok, cache)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], tok], axis=1)
    logits_ext, _ = forward(cfg, params, batch2)
    np.testing.assert_allclose(
        np.asarray(lg2, np.float32),
        np.asarray(logits_ext[:, -1, :], np.float32),
        atol=atol,
    )


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_param_count_formula_matches(name):
    """configs.param_count() (used for MODEL_FLOPS in the roofline) must
    equal the actual parameter tree size on the smoke config."""
    cfg = configs.get_smoke(name)
    params = init_params(cfg, jax.random.PRNGKey(0))
    actual = sum(x.size for x in jax.tree.leaves(params))
    assert cfg.param_count() == actual


def test_full_config_param_counts_plausible():
    """Full configs: parameter totals in the expected ballpark."""
    expect = {
        "stablelm-3b": (2.5e9, 4.5e9),
        # 28B with our uniform SwiGLU FFN (3 matrices); the original
        # GPT-BigCode MLP has 2 (see DESIGN.md §Arch notes)
        "granite-20b": (18e9, 30e9),
        "smollm-135m": (1e8, 2e8),
        "qwen3-32b": (30e9, 37e9),
        "whisper-base": (6e7, 1.3e8),
        "falcon-mamba-7b": (6e9, 8.5e9),
        "internvl2-26b": (18e9, 28e9),
        "jamba-v0.1-52b": (45e9, 58e9),
        "kimi-k2-1t-a32b": (0.95e12, 1.15e12),
        "qwen3-moe-30b-a3b": (26e9, 34e9),
    }
    for name, (lo, hi) in expect.items():
        n = configs.get(name).param_count()
        assert lo <= n <= hi, f"{name}: {n:,} not in [{lo:,.0f}, {hi:,.0f}]"


def test_moe_active_params():
    cfg = configs.get("kimi-k2-1t-a32b")
    active = cfg.active_param_count()
    assert 2.5e10 <= active <= 4.5e10  # ~32B active
    assert active < cfg.param_count() / 10
