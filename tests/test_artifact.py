"""Design-artifact round trips: save -> load must be bit-identical to
the in-memory design, cold-start with zero CMVM solves, and reuse the
jit cache via content-digest table identity (acceptance anchors of the
deployable-runtime PR)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import (
    AvgPool2D,
    Flatten,
    MaxPool2D,
    QConv2D,
    QDense,
    QuantConfig,
    ReLU,
    apply_model,
    compile_model,
    init_params,
    models,
)
from repro.runtime import load_design, save_design

jax.config.update("jax_enable_x64", True)


def _small_dense():
    wq = QuantConfig(6, 2, signed=True)
    aq = QuantConfig(8, 4, signed=False)
    model = (QDense(12, wq), ReLU(aq), QDense(5, wq))
    return model, (10,), QuantConfig(8, 4, signed=True)


def _small_conv():
    wq = QuantConfig(6, 2, signed=True)
    aq = QuantConfig(8, 4, signed=False)
    model = (
        QConv2D(4, (3, 3), w_quant=wq), ReLU(aq), MaxPool2D((2, 2)),
        AvgPool2D((2, 2)), Flatten(), QDense(3, wq),
    )
    return model, (10, 10, 2), QuantConfig(8, 1, signed=False)


def _small_mixer():
    return models.mlp_mixer_jet(n_particles=4, n_features=4, d_ff=4)


def _compile(builder, tmp_path, seed=0, **kw):
    model, in_shape, in_quant = builder()
    params, _ = init_params(jax.random.PRNGKey(seed), model, in_shape)
    design = compile_model(model, params, in_shape, in_quant, dc=2, **kw)
    path = save_design(design, tmp_path / "design")
    loaded = load_design(path)
    return model, params, in_shape, in_quant, design, loaded


def _int_input(in_shape, in_quant, batch=16, seed=0):
    rng = np.random.default_rng(seed)
    q = in_quant.qint
    return np.asarray(rng.integers(q.lo, q.hi + 1, size=(batch, *in_shape)), np.int32)


@pytest.mark.parametrize("strategy", ["da", "latency"])
@pytest.mark.parametrize("engine", ["batch", "heap", "arena"])
def test_roundtrip_bit_exact_strategy_engine_grid(tmp_path, strategy, engine):
    _, _, in_shape, in_quant, design, loaded = _compile(
        _small_dense, tmp_path, strategy=strategy, engine=engine
    )
    xi = _int_input(in_shape, in_quant)
    np.testing.assert_array_equal(
        np.asarray(design.forward_int(xi)), np.asarray(loaded.forward_int(xi))
    )
    # cold start performed zero CMVM solves
    assert loaded.solver_stats["n_solves"] == 0
    assert loaded.solver_stats["loaded_from_artifact"] is True


@pytest.mark.parametrize("builder", [_small_conv, _small_mixer])
def test_roundtrip_conv_pool_mixer(tmp_path, builder):
    """Conv/im2col, max+avg pools, transpose and residual steps all
    survive the declarative spec round trip bit-exactly."""
    _, _, in_shape, in_quant, design, loaded = _compile(builder, tmp_path)
    xi = _int_input(in_shape, in_quant, batch=4)
    np.testing.assert_array_equal(
        np.asarray(design.forward_int(xi)), np.asarray(loaded.forward_int(xi))
    )


def test_loaded_float_forward_matches_ste(tmp_path):
    """in_quant/out_qints survive: the float wrapper of the loaded design
    still bit-matches the STE float forward pass."""
    model, params, in_shape, in_quant, _, loaded = _compile(_small_dense, tmp_path)
    rng = np.random.default_rng(3)
    x = jnp.asarray(
        rng.uniform(in_quant.lo, in_quant.hi, size=(8, *in_shape)), jnp.float64
    )
    y_float = apply_model(params, model, x, in_quant=in_quant)
    np.testing.assert_allclose(
        np.asarray(loaded.forward(x), np.float64), np.asarray(y_float), rtol=0, atol=0
    )


def test_tables_digest_and_reports_survive(tmp_path):
    _, _, _, _, design, loaded = _compile(_small_dense, tmp_path)
    # content-digest identity: rebuilt tables hash/compare equal, so the
    # pallas jit cache (static `tables` argument) is shared across loads
    assert len(design.tables) == len(loaded.tables) > 0
    for a, b in zip(design.tables, loaded.tables):
        assert a is not b
        assert a.digest == b.digest
        assert a == b and hash(a) == hash(b)
    # resource reports and totals round-trip exactly
    assert [r.__dict__ for r in loaded.reports] == [r.__dict__ for r in design.reports]
    assert loaded.total_adders == design.total_adders
    assert loaded.total_cost_bits == design.total_cost_bits
    assert loaded.latency_cycles == design.latency_cycles
    assert loaded.out_qints == design.out_qints
    assert loaded.in_shape == design.in_shape
    assert loaded.out_shape == design.out_shape


def test_manifest_is_plain_json(tmp_path):
    model, in_shape, in_quant = _small_dense()
    params, _ = init_params(jax.random.PRNGKey(0), model, in_shape)
    design = compile_model(model, params, in_shape, in_quant, dc=2)
    path = save_design(design, tmp_path / "design")
    manifest = json.loads((path / "manifest.json").read_text())
    assert manifest["format"] == "da4ml-design"
    assert manifest["version"] == 1
    assert manifest["resources"]["total_adders"] == design.total_adders
    assert len(manifest["reports"]) == len(design.reports)
    # npz holds no pickled objects
    with np.load(path / "design.npz", allow_pickle=False) as z:
        assert "out_qints" in z.files


def test_load_rejects_bad_artifacts(tmp_path):
    d = tmp_path / "bogus"
    d.mkdir()
    (d / "manifest.json").write_text(json.dumps({"format": "other"}))
    with pytest.raises(ValueError, match="not a da4ml-design"):
        load_design(d)
    (d / "manifest.json").write_text(
        json.dumps({"format": "da4ml-design", "version": 999})
    )
    with pytest.raises(ValueError, match="unsupported artifact version"):
        load_design(d)


def test_load_rejects_mixed_generation_artifact(tmp_path):
    """manifest.json is content-bound to design.npz: pairing a stale
    manifest with fresh arrays (crash between the two file replaces)
    fails loudly instead of mis-executing."""
    model, in_shape, in_quant = _small_dense()
    params, _ = init_params(jax.random.PRNGKey(0), model, in_shape)
    d1 = compile_model(model, params, in_shape, in_quant, dc=2)
    params2, _ = init_params(jax.random.PRNGKey(9), model, in_shape)
    d2 = compile_model(model, params2, in_shape, in_quant, dc=2)
    p1 = save_design(d1, tmp_path / "gen1")
    p2 = save_design(d2, tmp_path / "gen2")
    (p1 / "design.npz").write_bytes((p2 / "design.npz").read_bytes())
    with pytest.raises(ValueError, match="mixed-generation"):
        load_design(p1)


def test_resave_loaded_design_is_stable(tmp_path):
    """A loaded design can itself be saved; the second-generation load
    is still bit-identical (programs survive as packed arrays)."""
    _, _, in_shape, in_quant, design, loaded = _compile(_small_dense, tmp_path)
    path2 = save_design(loaded, tmp_path / "gen2")
    gen2 = load_design(path2)
    xi = _int_input(in_shape, in_quant, seed=7)
    np.testing.assert_array_equal(
        np.asarray(design.forward_int(xi)), np.asarray(gen2.forward_int(xi))
    )
