"""Weighted HLO cost analysis: calibration against known-cost programs."""

import os
import subprocess
import sys

import json
import pytest

SCRIPT = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh(2, 4)  # version-compatible Auto-axis mesh
out = {}

# 1) scan with known trip count: flops must be trips * body
W = jax.ShapeDtypeStruct((8, 512, 512), jnp.float32)
X = jax.ShapeDtypeStruct((128, 512), jnp.float32)
def f(w, x):
    return jax.lax.scan(lambda c, wi: (jnp.tanh(c @ wi), None), x, w)[0]


c = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "data", "model")),
                             NamedSharding(mesh, P("data", None)))).lower(W, X).compile()
r = analyze(c.as_text(), 8)
out["scan_flops"] = r.flops
out["scan_expected"] = 8 * 2 * 128 * 512 * 512 / 8  # per chip

# 2) single sharded matmul: per-chip flops
A = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
g = jax.jit(lambda a, b: a @ b,
            in_shardings=(NamedSharding(mesh, P("data", None)),
                          NamedSharding(mesh, P(None, "model"))))
c2 = g.lower(A, A).compile()
r2 = analyze(c2.as_text(), 8)
out["mm_flops"] = r2.flops
out["mm_expected"] = 2 * 1024**3 / 8

# 3) explicit psum via constraint: nonzero collective bytes
h = jax.jit(lambda a: jax.lax.with_sharding_constraint(a.sum(axis=0), NamedSharding(mesh, P())),
            in_shardings=(NamedSharding(mesh, P("data", None)),))
c3 = h.lower(jax.ShapeDtypeStruct((128, 256), jnp.float32)).compile()
r3 = analyze(c3.as_text(), 8)
out["reduce_coll"] = r3.coll_wire_bytes
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def result():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_scan_trip_weighting_exact(result):
    assert result["scan_flops"] == pytest.approx(result["scan_expected"], rel=1e-6)


def test_single_matmul_per_chip(result):
    assert result["mm_flops"] == pytest.approx(result["mm_expected"], rel=1e-6)


def test_collectives_detected(result):
    assert result["reduce_coll"] > 0
