"""The paper's benchmark networks (§6.2), as quantized Sequential specs.

Datasets are not redistributable offline; training uses synthetic tasks
(examples/) — the *hardware* results (adders, LUT bits, depth, latency)
depend only on architecture + weight statistics, which is what the
benchmark harness reproduces.

  jet_tagger      §6.2.1: high-level-feature jet tagging MLP,
                  16 -> 64 -> 32 -> 16 -> 16 -> 5 dense + ReLU.
  svhn_cnn        §6.2.2: LeNet-like SVHN classifier [3, 16]:
                  conv16-pool-conv16-pool-conv24-pool-dense42-dense64-dense10.
  muon_tracker    §6.2.3: multi-stage dense network (binary inputs,
                  structured sparsity approximated by plain dense stages).
  mlp_mixer_jet   §6.2.4 [49]: 4 MLP blocks alternating feature-mix /
                  particle-mix with one skip connection, 64 particles x
                  16 features, 5-class head.
"""

from __future__ import annotations

from .layers import (
    AvgPool2D,
    Flatten,
    MaxPool2D,
    QConv2D,
    QDense,
    QDenseOnAxis,
    ReLU,
    Residual,
)
from .quant import QuantConfig


def _act(bits: int) -> QuantConfig:
    # unsigned post-ReLU activations: fixed<0, bits, bits/2>
    return QuantConfig(bits, max(bits // 2, 1), signed=False)


def _wq(bits: int) -> QuantConfig:
    # weights in [-2, 2): fixed<1, bits, 2>
    return QuantConfig(bits, 2, signed=True)


def jet_tagger(w_bits: int = 6, a_bits: int = 8):
    """16 -> 64 -> 32 -> 16 -> 16 -> 5 fully-connected tagger."""
    wq, aq = _wq(w_bits), _act(a_bits)
    model = (
        QDense(64, wq), ReLU(aq),
        QDense(32, wq), ReLU(aq),
        QDense(16, wq), ReLU(aq),
        QDense(16, wq), ReLU(aq),
        QDense(5, wq),
    )
    in_quant = QuantConfig(8, 4, signed=True)
    return model, (16,), in_quant


def svhn_cnn(w_bits: int = 6, a_bits: int = 8):
    """LeNet-like SVHN classifier (paper Fig. 8).

    VALID convolutions, so the 32x32 SVHN frame is center-cropped to
    30x30 (the standard hls4ml variant uses SAME padding; resource
    counts are equivalent — the CMVM kernels are identical)."""
    wq, aq = _wq(w_bits), _act(a_bits)
    model = (
        QConv2D(16, (3, 3), w_quant=wq), ReLU(aq), MaxPool2D((2, 2)),
        QConv2D(16, (3, 3), w_quant=wq), ReLU(aq), MaxPool2D((2, 2)),
        QConv2D(24, (3, 3), w_quant=wq), ReLU(aq), AvgPool2D((2, 2)),
        Flatten(),
        QDense(42, wq), ReLU(aq),
        QDense(64, wq), ReLU(aq),
        QDense(10, wq),
    )
    in_quant = QuantConfig(8, 1, signed=False)  # pixel intensities [0,1)
    return model, (30, 30, 3), in_quant


def muon_tracker(w_bits: int = 6, a_bits: int = 8, d_in: int = 64):
    """Multi-stage dense network; inputs are 1-bit hits (paper §6.2.3:
    the initial conv stage is left un-optimized there too)."""
    wq, aq = _wq(w_bits), _act(a_bits)
    model = (
        QDense(64, wq), ReLU(aq),
        QDense(48, wq), ReLU(aq),
        QDense(32, wq), ReLU(aq),
        QDense(16, wq), ReLU(aq),
        QDense(1, wq),
    )
    in_quant = QuantConfig(1, 1, signed=False)  # binary hits
    return model, (d_in,), in_quant


def mlp_mixer_jet(
    n_particles: int = 16,
    n_features: int = 16,
    d_ff: int = 16,
    w_bits: int = 6,
    a_bits: int = 8,
    full_size: bool = False,
):
    """MLP-Mixer jet tagger (paper Fig. 10, [49]).

    MLP1/MLP3 mix the feature axis, MLP2/MLP4 mix the particle axis; one
    skip connection spans MLP2..MLP3.  ``full_size=True`` uses the
    paper's 64-particle configuration.
    """
    if full_size:
        n_particles = 64
    wq, aq = _wq(w_bits), _act(a_bits)
    mlp1 = (QDense(d_ff, wq), ReLU(aq), QDense(n_features, wq), ReLU(aq))
    mlp2 = (
        QDenseOnAxis(n_particles, axis=0, w_quant=wq), ReLU(aq),
        QDenseOnAxis(n_particles, axis=0, w_quant=wq), ReLU(aq),
    )
    mlp3 = (QDense(d_ff, wq), ReLU(aq), QDense(n_features, wq), ReLU(aq))
    mlp4 = (
        QDenseOnAxis(n_particles, axis=0, w_quant=wq), ReLU(aq),
        QDenseOnAxis(n_particles, axis=0, w_quant=wq), ReLU(aq),
    )
    head = (Flatten(), QDense(32, wq), ReLU(aq), QDense(5, wq))
    model = mlp1 + (Residual(mlp2 + mlp3),) + (ReLU(aq),) + mlp4 + head
    in_quant = QuantConfig(8, 4, signed=True)
    return model, (n_particles, n_features), in_quant
