"""Pure-numpy StepSpec interpreter: the serve path's degraded mode.

This is a bit-exact mirror of the jnp executor built by
:func:`repro.nn.compiler.build_steps` — same step kinds, same int32
arithmetic, same shift/clip/sum semantics — expressed entirely in numpy.
The serve engine's circuit breaker routes batches here when
``ServeConfig.fallback="interpreter"`` and the jit path is tripped:
correctness survives a poisoned jit cache at reduced throughput, and
the fallback shares no jax machinery with the failing path.

Bit-exactness notes (each is load-bearing and covered by
``tests/test_chaos.py``):

* everything runs in int32 with C wrap semantics, matching jax;
  reductions pass ``dtype=np.int32`` explicitly because numpy would
  otherwise widen int32 sums to the platform int,
* right shifts are arithmetic on negatives in both numpy and jax,
* ``np.clip`` results are cast back to int32 (value-based promotion
  against Python int bounds must not leak a wider dtype).
"""

from __future__ import annotations

from collections.abc import Callable

import numpy as np

__all__ = ["adder_graph_numpy", "build_numpy_steps", "numpy_forward_fn"]


def adder_graph_numpy(tables, x: np.ndarray) -> np.ndarray:
    """Evaluate the levelized adder graph on ``x`` [batch, n_inputs].

    numpy twin of :func:`repro.kernels.adder_graph.ref.adder_graph_ref`,
    with one mechanical change: the row buffer is preallocated instead
    of grown by concatenation (same values, fewer copies).
    Returns int32 [batch, n_outputs].
    """
    x2 = np.ascontiguousarray(x).reshape(-1, x.shape[-1])
    batch = x2.shape[0]
    n_in = int(tables.n_inputs)
    instr = np.asarray(tables.instr)
    buf = np.empty((n_in + instr.shape[0], batch), dtype=np.int32)
    buf[:n_in] = x2.T.astype(np.int32)
    row = n_in
    for lo, hi in tables.level_bounds:
        ops = instr[lo:hi]
        a = buf[ops[:, 0]] << ops[:, 2][:, None]
        b = buf[ops[:, 1]] << ops[:, 3][:, None]
        buf[row : row + (hi - lo)] = a + ops[:, 4][:, None] * b
        row += hi - lo
    outs = np.asarray(tables.outs)
    y = buf[outs[:, 0]]
    shift = outs[:, 1][:, None]
    y = np.where(shift >= 0, y << np.maximum(shift, 0), y >> np.maximum(-shift, 0))
    y = y * outs[:, 2][:, None] * outs[:, 3][:, None]
    return np.ascontiguousarray(y.T.astype(np.int32))


def _build_numpy_cmvm(spec, tables):
    tab = tables[spec.table]
    bias = (
        np.asarray(spec.arrays["bias"], np.int32) if "bias" in spec.arrays else None
    )
    shift = (
        np.asarray(np.asarray(spec.arrays["shift"])[None, :], np.int32)
        if "shift" in spec.arrays
        else None
    )

    def cmvm(v, tab=tab, bias=bias, shift=shift):
        y = adder_graph_numpy(tab, v)
        if shift is not None:
            y = y << shift
        return y + bias if bias is not None else y

    return cmvm


def _build_numpy_step(spec, tables) -> Callable[[np.ndarray], np.ndarray]:
    kind, p = spec.kind, spec.params
    if kind == "dense":
        f = _build_numpy_cmvm(spec, tables)

        def step(v, d_in=p["d_in"], f=f):
            n = v.shape[0]
            return f(v.reshape(-1, d_in)).reshape(n, -1)

        return step
    if kind == "conv":
        f = _build_numpy_cmvm(spec, tables)
        h, w, cin = p["h"], p["w"], p["cin"]
        kh, kw, sh, sw = p["kh"], p["kw"], p["sh"], p["sw"]
        oh, ow = p["oh"], p["ow"]

        def step(v, h=h, w=w, cin=cin, kh=kh, kw=kw, sh=sh, sw=sw, oh=oh, ow=ow, f=f):
            x = v.reshape(-1, h, w, cin)
            patches = [
                x[:, dy : dy + sh * (oh - 1) + 1 : sh, dx : dx + sw * (ow - 1) + 1 : sw, :]
                for dy in range(kh)
                for dx in range(kw)
            ]
            cols = np.concatenate(patches, axis=-1)
            y = f(cols.reshape(-1, kh * kw * cin))
            return y.reshape(-1, oh * ow * y.shape[-1])

        return step
    if kind == "requant":
        d = np.asarray(spec.arrays["d"], np.int64)
        dpos = np.asarray(np.maximum(d, 0)[None, :], np.int32)
        dneg = np.asarray(np.maximum(-d, 0)[None, :], np.int32)

        def step(v, dpos=dpos, dneg=dneg, lo=p["lo"], hi=p["hi"]):
            v = np.where(dpos > 0, v << dpos, v >> dneg)
            return np.clip(v, lo, hi).astype(np.int32)

        return step
    if kind == "transpose":
        _shape, _perm = tuple(p["shape"]), tuple(p["perm"])

        def step(v, shape=_shape, perm=_perm):
            n = v.shape[0]
            return v.reshape(n, *shape).transpose(0, *[q + 1 for q in perm]).reshape(n, -1)

        return step
    if kind == "relu":
        return lambda v: np.maximum(v, 0)
    if kind in ("maxpool", "avgpool"):
        h, w, c, ph, pw = p["h"], p["w"], p["c"], p["ph"], p["pw"]

        def step(v, h=h, w=w, c=c, ph=ph, pw=pw, is_max=(kind == "maxpool")):
            x = v.reshape(-1, h // ph, ph, w // pw, pw, c)
            if is_max:
                r = x.max(axis=(2, 4))
            else:
                # numpy widens int32 sums to the platform int by default;
                # pin int32 so wrap semantics match the jitted path
                r = x.sum(axis=(2, 4), dtype=np.int32)
            return r.reshape(v.shape[0], -1)

        return step
    if kind == "residual":
        body = tuple(_build_numpy_step(s, tables) for s in spec.body or [])
        sa = np.asarray(np.asarray(spec.arrays["sa"])[None, :], np.int32)
        sb = np.asarray(np.asarray(spec.arrays["sb"])[None, :], np.int32)

        def step(v, body=body, sa=sa, sb=sb):
            u = v
            for s in body:
                u = s(u)
            return (v << sa) + (u << sb)

        return step
    raise ValueError(f"unknown step kind {kind!r}")


def build_numpy_steps(specs, tables) -> list[Callable[[np.ndarray], np.ndarray]]:
    """numpy twin of :func:`repro.nn.compiler.build_steps`."""
    return [_build_numpy_step(s, tables) for s in specs]


def numpy_forward_fn(design) -> Callable[[np.ndarray], np.ndarray]:
    """Build a numpy-only ``forward_int`` for a compiled design.

    Semantically identical to ``design.forward_int`` (same StepSpecs,
    same tables) but touching no jax code, so it keeps serving bit-exact
    answers while the jit path is broken.  Raises ``ValueError`` for
    designs without step specs (hand-built designs predating the
    declarative pipeline cannot be interpreted).
    """
    if not design.step_specs:
        raise ValueError("design has no step_specs; interpreter fallback unavailable")
    steps = build_numpy_steps(design.step_specs, design.tables)
    out_shape = tuple(design.out_shape)

    def forward_int(x_int: np.ndarray) -> np.ndarray:
        v = np.asarray(x_int).reshape(x_int.shape[0], -1).astype(np.int32)
        for step in steps:
            v = step(v)
        return v.reshape(x_int.shape[0], *out_shape)

    return forward_int
