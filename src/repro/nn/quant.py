"""Fixed-point quantization-aware training utilities (HGQ-lite).

The paper's networks are trained with HGQ [16]: per-weight bitwidths with
differentiable quantization, yielding bit-level sparsity that da4ml then
exploits.  We reproduce the deployment-relevant contract:

  * every tensor lives on a power-of-two grid fixed<S, W, I>
    (step 2^(I-W), range [-2^(I-1), 2^(I-1) - step] when signed);
  * the forward pass is *bit-exact* with the integer hardware semantics:
    floor rounding, saturation clipping — so a compiled adder graph
    reproduces the trained float forward exactly (tests enforce this);
  * straight-through estimators pass gradients through round/clip;
  * an optional bit-count regulariser (mean |w|/step surrogate) drives
    weights toward few CSD digits, mimicking HGQ's resource loss.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..core.fixed_point import QInterval


@dataclass(frozen=True)
class QuantConfig:
    """fixed<signed, bits, int_bits> (int_bits includes the sign bit)."""

    bits: int
    int_bits: int
    signed: bool = True

    @property
    def step(self) -> float:
        return 2.0 ** (self.int_bits - self.bits)

    @property
    def qint(self) -> QInterval:
        return QInterval.from_fixed(self.signed, self.bits, self.int_bits)

    @property
    def lo(self) -> float:
        return self.qint.lo * self.step

    @property
    def hi(self) -> float:
        return self.qint.hi * self.step

    def scale_exp(self) -> int:
        return self.int_bits - self.bits


def fake_quant(x: jnp.ndarray, cfg: QuantConfig, rounding: str = "floor") -> jnp.ndarray:
    """Quantize to the fixed-point grid with a straight-through gradient."""
    s = cfg.step
    if rounding == "floor":
        q = jnp.floor(x / s)
    else:
        q = jnp.round(x / s)
    q = jnp.clip(q, cfg.qint.lo, cfg.qint.hi) * s
    return x + jax.lax.stop_gradient(q - x)


def to_grid_int(x: jnp.ndarray, cfg: QuantConfig, rounding: str = "floor") -> jnp.ndarray:
    """Integer grid coordinates of x (exact deployment representation)."""
    s = cfg.step
    q = jnp.floor(x / s) if rounding == "floor" else jnp.round(x / s)
    return jnp.clip(q, cfg.qint.lo, cfg.qint.hi).astype(jnp.int32)


def bit_count_surrogate(w: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """Differentiable proxy for the CSD digit count of quantized weights.

    log2(1 + |w|/step) grows ~linearly in the bitwidth a weight needs;
    minimising its sum drives bit-level sparsity like HGQ's resource
    term.
    """
    return jnp.log2(1.0 + jnp.abs(w) / cfg.step).sum()
