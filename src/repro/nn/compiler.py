"""Model -> adder-graph compiler: the hls4ml+da4ml integration analogue.

``compile_model`` walks a quantized ``Sequential``, replaces every CMVM
(QDense / QDenseOnAxis / QConv2D-via-im2col) by a da4ml-optimized DAIS
program (strategy="da") or by the per-output naive CSD tree
(strategy="latency", the hls4ml latency-strategy baseline), and stitches
the layers into a bit-exact *integer* executor plus a resource report
(adders, cost bits ~ LUTs, FF estimate from pipelining, adder depth,
latency in pipeline stages) mirroring the paper's network tables.

Exact quantized intervals are propagated feature-by-feature through the
whole network — ReLU clips, pool merges, residual sums — so downstream
CMVMs are solved with true per-input ranges (tighter adders than blanket
bitwidths; this is the qint machinery of paper §4.1 applied end-to-end).

Internal convention: activations flow as int32 [batch, prod(shape)] in
C-order, with ``shape`` (batch excluded) and per-feature ``QInterval``
tracked symbolically at compile time.
"""

from __future__ import annotations

import concurrent.futures
import os
import time
from dataclasses import dataclass, field
from collections.abc import Callable

import jax.numpy as jnp
import numpy as np

from ..core.cache import SolutionCache, solve_key
from ..core.fixed_point import QInterval
from ..core.pipelining import pipeline
from ..core.solver import (
    Solution,
    config_solve_key,
    solve_task,
)
from ..flow.config import UNSET, CompileConfig, SolverConfig, resolve_legacy
from ..kernels.adder_graph import adder_graph_apply, compile_tables
from ..obs import trace
from .layers import (
    AvgPool2D,
    Flatten,
    MaxPool2D,
    QConv2D,
    QDense,
    QDenseOnAxis,
    ReLU,
    Residual,
    Sequential,
)
from .quant import QuantConfig


@dataclass
class LayerReport:
    name: str
    shape: str
    adders: int
    cost_bits: int
    depth: int
    stages: int
    ff_bits: int
    solver_time_s: float


@dataclass
class StepSpec:
    """Declarative description of one executor step.

    The compiled design's execution pipeline is a list of these specs;
    :func:`build_steps` turns them into jnp callables.  Because the
    artifact loader (repro.runtime.artifact) rebuilds steps through the
    same builder, a design restored from disk executes byte-for-byte the
    same program as the design that was saved.

    kind    one of dense / conv / requant / transpose / relu / maxpool /
            avgpool / residual.
    params  JSON-serializable scalars (shapes, strides, clip bounds).
    arrays  integer numpy arrays (bias, pre-shift, requant shifts).
    table   index into ``CompiledDesign.tables`` for CMVM kinds, else -1.
    body    nested specs (residual only).
    """

    kind: str
    params: dict = field(default_factory=dict)
    arrays: dict = field(default_factory=dict)
    table: int = -1
    body: list["StepSpec"] | None = None


@dataclass
class CompiledDesign:
    steps: list[Callable] = field(default_factory=list)
    reports: list[LayerReport] = field(default_factory=list)
    in_quant: QuantConfig | None = None
    in_shape: tuple = ()
    out_shape: tuple = ()
    out_qints: list[QInterval] = field(default_factory=list)
    # solve-phase accounting: n_solves / n_cache_hits / n_pool_solves /
    # pool_fallback (why the solve pool went serial, None when it ran) /
    # solver_time_s (sum over unique CMVMs, ~0 when everything hits cache)
    solver_stats: dict = field(default_factory=dict)
    # declarative pipeline: step specs + per-unique-CMVM instruction
    # tables + packed DAIS programs (``DAISProgram.to_arrays`` dicts; an
    # entry is None when a program's qints exceed int64 and cannot be
    # serialized).  ``steps`` is always built from these via build_steps.
    step_specs: list[StepSpec] = field(default_factory=list)
    tables: list = field(default_factory=list)
    programs: list = field(default_factory=list)
    use_pallas: bool = False
    # the CompileConfig that produced this design (embedded in saved
    # artifact manifests; None for designs loaded from pre-config
    # artifacts or built by hand)
    config: CompileConfig | None = None

    # ------------------------------------------------------------------
    def save(self, path):
        """Persist this design as a ``da4ml-design`` artifact directory
        (see :func:`repro.runtime.save_design`); the compile config is
        embedded in the manifest."""
        from ..runtime.artifact import save_design  # lazy: runtime imports nn

        return save_design(self, path)

    @classmethod
    def load(
        cls, path, verify: str = "off", on_corrupt: str = "raise"
    ) -> "CompiledDesign":
        """Rebuild a design from a ``save_design`` artifact — millisecond
        cold start, zero CMVM solves, bit-identical execution.  ``verify``
        optionally runs the static verifier on the rebuilt design;
        ``on_corrupt="quarantine"`` moves a damaged artifact aside before
        raising :class:`repro.runtime.ArtifactCorruptError`."""
        from ..runtime.artifact import load_design  # lazy: runtime imports nn

        return load_design(path, verify=verify, on_corrupt=on_corrupt)

    @property
    def total_adders(self) -> int:
        return sum(r.adders for r in self.reports)

    @property
    def total_cost_bits(self) -> int:
        return sum(r.cost_bits for r in self.reports)

    @property
    def total_ff_bits(self) -> int:
        return sum(r.ff_bits for r in self.reports)

    @property
    def latency_cycles(self) -> int:
        return sum(r.stages for r in self.reports)

    @property
    def max_depth(self) -> int:
        return max((r.depth for r in self.reports), default=0)

    # ------------------------------------------------------------------
    def forward_int(self, x_int: jnp.ndarray) -> jnp.ndarray:
        """Run the integer pipeline. x_int: [batch, *in_shape] grid ints."""
        v = x_int.reshape(x_int.shape[0], -1).astype(jnp.int32)
        for step in self.steps:
            v = step(v)
        return v.reshape(x_int.shape[0], *self.out_shape)

    def forward(self, x: jnp.ndarray) -> jnp.ndarray:
        """Float-in/float-out wrapper around the integer pipeline."""
        assert self.in_quant is not None
        q = self.in_quant
        xi = jnp.clip(jnp.floor(x / q.step), q.qint.lo, q.qint.hi).astype(jnp.int32)
        y = self.forward_int(xi)
        exps = np.array([q_.exp if not q_.is_zero else 0 for q_ in self.out_qints])
        return y.astype(jnp.float32) * (2.0 ** exps).reshape(self.out_shape)

    def summary(self) -> str:
        hdr = (
            f"{'layer':<20}{'shape':<14}{'adders':>8}{'LUTbits':>9}{'depth':>7}"
            f"{'stages':>7}{'FFbits':>8}{'t[s]':>8}"
        )
        rows = [hdr, "-" * len(hdr)]
        for r in self.reports:
            rows.append(
                f"{r.name:<20}{r.shape:<14}{r.adders:>8}{r.cost_bits:>9}{r.depth:>7}"
                f"{r.stages:>7}{r.ff_bits:>8}{r.solver_time_s:>8.2f}"
            )
        rows.append("-" * len(hdr))
        rows.append(
            f"{'TOTAL':<20}{'':<14}{self.total_adders:>8}{self.total_cost_bits:>9}"
            f"{self.max_depth:>7}{self.latency_cycles:>7}{self.total_ff_bits:>8}"
        )
        return "\n".join(rows)


# ----------------------------------------------------------------------
# Step builder: StepSpec -> executable jnp callable
# ----------------------------------------------------------------------
def build_steps(specs: list[StepSpec], tables: list, use_pallas: bool = False):
    """Construct the executable pipeline from declarative step specs.

    ``tables``: the design's per-unique-CMVM ``AdderGraphTables`` list.
    Both ``compile_model`` and the artifact loader go through this
    single builder, which is what makes save->load bit-exact.
    """
    return [_build_step(s, tables, use_pallas) for s in specs]


def _build_cmvm_fn(spec: StepSpec, tables: list, use_pallas: bool):
    tab = tables[spec.table]
    bias = (
        jnp.asarray(spec.arrays["bias"], jnp.int32) if "bias" in spec.arrays else None
    )
    shift = (
        jnp.asarray(np.asarray(spec.arrays["shift"])[None, :], jnp.int32)
        if "shift" in spec.arrays
        else None
    )

    def cmvm(v, tab=tab, bias=bias, shift=shift, use_pallas=use_pallas):
        y = adder_graph_apply(tab, v, use_pallas=use_pallas)
        if shift is not None:
            y = y << shift
        return y + bias if bias is not None else y

    return cmvm


def _build_step(spec: StepSpec, tables: list, use_pallas: bool) -> Callable:
    kind, p = spec.kind, spec.params
    if kind == "dense":
        f = _build_cmvm_fn(spec, tables, use_pallas)

        def step(v, d_in=p["d_in"], f=f):
            n = v.shape[0]
            return f(v.reshape(-1, d_in)).reshape(n, -1)

        return step
    if kind == "conv":
        f = _build_cmvm_fn(spec, tables, use_pallas)
        h, w, cin = p["h"], p["w"], p["cin"]
        kh, kw, sh, sw = p["kh"], p["kw"], p["sh"], p["sw"]
        oh, ow = p["oh"], p["ow"]

        def step(v, h=h, w=w, cin=cin, kh=kh, kw=kw, sh=sh, sw=sw, oh=oh, ow=ow, f=f):
            x = v.reshape(-1, h, w, cin)
            patches = [
                x[:, dy : dy + sh * (oh - 1) + 1 : sh, dx : dx + sw * (ow - 1) + 1 : sw, :]
                for dy in range(kh)
                for dx in range(kw)
            ]
            cols = jnp.concatenate(patches, axis=-1)  # [B, oh, ow, kh*kw*cin]
            y = f(cols.reshape(-1, kh * kw * cin))
            return y.reshape(-1, oh * ow * y.shape[-1])

        return step
    if kind == "requant":
        d = np.asarray(spec.arrays["d"], np.int64)

        def step(v, d=d, lo=p["lo"], hi=p["hi"]):
            dpos = jnp.asarray(np.maximum(d, 0)[None, :], jnp.int32)
            dneg = jnp.asarray(np.maximum(-d, 0)[None, :], jnp.int32)
            v = jnp.where(dpos > 0, v << dpos, v >> dneg)
            return jnp.clip(v, lo, hi)

        return step
    if kind == "transpose":
        _shape, _perm = tuple(p["shape"]), tuple(p["perm"])

        def step(v, shape=_shape, perm=_perm):
            n = v.shape[0]
            return v.reshape(n, *shape).transpose(0, *[q + 1 for q in perm]).reshape(n, -1)

        return step
    if kind == "relu":
        return lambda v: jnp.maximum(v, 0)
    if kind in ("maxpool", "avgpool"):
        h, w, c, ph, pw = p["h"], p["w"], p["c"], p["ph"], p["pw"]

        def step(v, h=h, w=w, c=c, ph=ph, pw=pw, is_max=(kind == "maxpool")):
            x = v.reshape(-1, h // ph, ph, w // pw, pw, c)
            r = x.max(axis=(2, 4)) if is_max else x.sum(axis=(2, 4))
            return r.reshape(v.shape[0], -1)

        return step
    if kind == "residual":
        body = tuple(_build_step(s, tables, use_pallas) for s in spec.body or [])
        sa = jnp.asarray(np.asarray(spec.arrays["sa"])[None, :], jnp.int32)
        sb = jnp.asarray(np.asarray(spec.arrays["sb"])[None, :], jnp.int32)

        def step(v, body=body, sa=sa, sb=sb):
            u = v
            for s in body:
                u = s(u)
            return (v << sa) + (u << sb)

        return step
    raise ValueError(f"unknown step kind {kind!r}")


# ----------------------------------------------------------------------
# qint helpers
# ----------------------------------------------------------------------
def _relu_qint(q: QInterval) -> QInterval:
    if q.is_zero:
        return q
    return QInterval(max(q.lo, 0), max(q.hi, 0), q.exp)


def _requant_qint(q: QInterval, cfg: QuantConfig) -> QInterval:
    """floor+saturate of a value with interval q onto cfg's grid."""
    t = cfg.qint
    if q.is_zero:
        return QInterval(0, 0, t.exp)
    d = q.exp - t.exp
    lo = q.lo << d if d >= 0 else q.lo >> (-d)
    hi = q.hi << d if d >= 0 else q.hi >> (-d)
    lo = min(max(lo, t.lo), t.hi)
    hi = min(max(hi, t.lo), t.hi)
    return QInterval(lo, hi, t.exp)


def _union_all(qs: list[QInterval]) -> QInterval:
    q0 = qs[0]
    if all(q is q0 or q == q0 for q in qs):
        return q0
    for q in qs[1:]:
        q0 = q0.union(q)
    return q0


def _exps(qints: list[QInterval], fallback: int = 0) -> np.ndarray:
    return np.array([fallback if q.is_zero else q.exp for q in qints], dtype=np.int64)


def _requant_spec(qints: list[QInterval], cfg: QuantConfig) -> StepSpec:
    t = cfg.qint
    d = _exps(qints, fallback=t.exp) - t.exp
    # "exp" (the target grid exponent) is not read by the executor; it is
    # the metadata that lets the static verifier (repro.analysis) replay
    # this requant's interval transfer exactly
    return StepSpec(
        "requant",
        params={"lo": int(t.lo), "hi": int(t.hi), "exp": int(t.exp)},
        arrays={"d": d},
    )


def _align_exps(qints_a, qints_b):
    """Shift arrays onto the common (finer) per-feature grid + summed qints."""
    ea, eb = _exps(qints_a), _exps(qints_b)
    e = np.minimum(ea, eb)
    out_q = []
    for qa, qb, ee in zip(qints_a, qints_b, e):
        qa2 = QInterval(qa.lo, qa.hi, qa.exp) if not qa.is_zero else QInterval(0, 0, int(ee))
        qb2 = QInterval(qb.lo, qb.hi, qb.exp) if not qb.is_zero else QInterval(0, 0, int(ee))
        out_q.append(qa2.add(qb2))
    return (ea - e).astype(np.int64), (eb - e).astype(np.int64), out_q


# ----------------------------------------------------------------------
# Compiler
# ----------------------------------------------------------------------
# compile_model runs in three phases:
#
#   plan    walk the layer graph, quantize weights, and propagate exact
#           per-feature qints WITHOUT solving: the output interval of a
#           CMVM is the exact affine range of y = x @ W (structure-
#           independent), so downstream layers can be planned before any
#           solver runs.  Each unique (matrix, qints, dc, strategy) is
#           registered once as a _SolveSlot.
#   solve   resolve the slots: content-addressed cache first, then the
#           remaining solves either serially or on a GIL-releasing
#           thread pool (``jobs=``; the solver hot loop is pure numpy).
#           Results stitch back by slot identity, so the parallel path
#           is bit-identical to the serial one.
#   stitch  compile instruction tables, pipeline reports, and layer
#           reports in original layer order.


class _SolveSlot:
    """One deferred CMVM solve.  After stitch, everything except the
    compiled instruction tables is released (apply_fn closures keep the
    slot alive for the design's lifetime, and the weight matrices /
    solved programs would otherwise be pinned along with it)."""

    __slots__ = (
        "w_int", "qin", "strategy", "solver_cfg", "key", "solution", "tables", "idx",
    )

    def __init__(self, w_int, qin, strategy, solver_cfg, idx):
        self.w_int = w_int
        self.qin = qin
        self.strategy = strategy
        self.solver_cfg: SolverConfig = solver_cfg
        self.key = None
        self.solution: Solution | None = None
        self.tables = None
        self.idx = idx  # position in ctx.slots == design.tables index


class _Ctx:
    def __init__(self, cfg: CompileConfig, design):
        self.cfg = cfg
        self.strategy = cfg.strategy
        self.mdps = cfg.max_delay_per_stage
        self.design = design
        self._solver_digest = cfg.solver.digest()
        self.slots: list[_SolveSlot] = []
        self.slot_map: dict = {}
        self.pending_reports: list = []

    def request(self, w_int: np.ndarray, qin: list[QInterval]) -> _SolveSlot:
        dedup = (
            self.strategy, self._solver_digest,
            w_int.shape, w_int.tobytes(), tuple(qin),
        )
        slot = self.slot_map.get(dedup)
        if slot is None:
            slot = _SolveSlot(w_int, qin, self.strategy, self.cfg.solver, len(self.slots))
            self.slot_map[dedup] = slot
            self.slots.append(slot)
        return slot


def _slot_key(slot: _SolveSlot) -> str:
    """Cache key; matches solve_cmvm's internal key for the "da" path
    (both derive from the SolverConfig digest, so they cannot drift)."""
    depth_in = [0] * len(slot.qin)
    if slot.strategy == "latency":
        return solve_key(slot.w_int, slot.qin, depth_in, kind="latency")
    return config_solve_key(slot.w_int, slot.qin, depth_in, slot.solver_cfg)


def _solve_slots(
    slots: list[_SolveSlot],
    jobs: int | None,
    cache: SolutionCache | None,
    slot_names: dict[int, list[str]] | None = None,
) -> dict:
    """Resolve the deferred CMVM solves: cache first, then the remaining
    misses in a thread pool.

    Versus the process pool this replaces there is no fork/spawn
    startup and no payload pickling (the old pool serialized every
    weight matrix twice and paid ~1s of interpreter spin-up, which
    dominated small-layer compiles).  numpy drops the GIL inside its
    kernels but the solver's Python-level bookkeeping still serializes
    part of each solve, so the thread speedup is sublinear — on boxes
    with little parallel headroom ``jobs=1`` wins outright (see
    docs/solver_performance.md for measurements).  Each worker thread
    keeps its own ``CSEArena`` (see repro.core.cse), so
    ``engine="arena"`` solves stay allocation-quiet across layers.
    Results stitch back by slot identity: any ``jobs`` value is
    bit-identical to serial.

    Going serial is never silent: ``pool_fallback`` in the returned
    stats records why the pool was skipped (None when it actually ran).
    """
    t0 = time.perf_counter()
    cache_before = cache.stats.as_dict() if cache is not None else None
    names = slot_names or {}
    slot_wall: dict[int, float] = {}
    slot_hit: dict[int, bool] = {}
    n_hits = 0
    misses: list[_SolveSlot] = []
    for slot in slots:
        if cache is not None:
            th0 = time.perf_counter()
            slot.key = _slot_key(slot)
            hit = cache.get(slot.key)
            if hit is not None:
                slot.solution = hit
                slot_wall[slot.idx] = time.perf_counter() - th0
                slot_hit[slot.idx] = True
                n_hits += 1
                continue
        misses.append(slot)
    n_pool = 0
    fallback: str | None = None
    if misses:
        # (payload, label) units: the label names the solve's trace span
        # and keys the per-slot wall time (satellite per-layer stats)
        work = [
            (
                (s.w_int, s.qin, s.strategy, s.solver_cfg.to_dict()),
                names.get(s.idx, [f"slot{s.idx}"])[0],
            )
            for s in misses
        ]
        results: list[tuple[Solution, float]] | None = None
        jobs_eff = os.cpu_count() or 1 if jobs is None else jobs
        if jobs_eff == 1:
            fallback = "jobs=1"
        elif len(misses) == 1:
            fallback = "single_solve"
        else:
            workers = min(jobs_eff, len(misses))
            try:
                with concurrent.futures.ThreadPoolExecutor(
                    workers, thread_name_prefix="da4ml-solve"
                ) as ex:
                    results = list(ex.map(_timed_solve_task, work))
                n_pool = len(results)
            except Exception as e:  # pool unavailable: loud serial fallback
                results = None
                fallback = f"thread_pool_error: {type(e).__name__}: {e}"
        if results is None:
            results = [_timed_solve_task(w) for w in work]
        for slot, (sol, wall) in zip(misses, results):
            slot.solution = sol
            slot_wall[slot.idx] = wall
            slot_hit[slot.idx] = False
            if cache is not None:
                cache.put(slot.key, sol)
    else:
        fallback = "no_cache_misses" if slots else "no_cmvm_layers"
    stats = {
        "n_solves": len(misses),
        "n_cache_hits": n_hits,
        "n_pool_solves": n_pool,
        "pool_fallback": fallback,
        "solver_time_s": sum(s.solution.solver_time_s for s in slots),
        "solve_phase_s": time.perf_counter() - t0,
        "per_layer": _per_layer_stats(slots, names, slot_wall, slot_hit),
    }
    if cache is not None:
        # per-compile delta of the cache counters (hits/misses/puts/
        # disk_hits/...), so artifact-vs-cache savings are measurable
        # even when one SolutionCache is shared across compiles.
        after = cache.stats.as_dict()
        stats["cache_stats"] = {k: after[k] - cache_before[k] for k in after}
    return stats


def _timed_solve_task(work: tuple) -> tuple[Solution, float]:
    """One pool unit: solve + wall time, under a labelled trace span so
    the Perfetto timeline shows which layer each pool thread solved."""
    payload, label = work
    t0 = time.perf_counter()
    with trace.span("compile.solve", layer=label):
        sol = solve_task(payload)
    return sol, time.perf_counter() - t0


def _per_layer_stats(
    slots: list[_SolveSlot],
    names: dict[int, list[str]],
    slot_wall: dict[int, float],
    slot_hit: dict[int, bool],
) -> dict:
    """Per-layer solve attribution: wall seconds and cache hit/miss keyed
    by layer name (layers deduplicated onto one slot each get an entry
    pointing at the shared slot)."""
    per_layer: dict[str, dict] = {}
    for slot in slots:
        layer_names = names.get(slot.idx, [f"slot{slot.idx}"])
        sol = slot.solution
        for nm in layer_names:
            per_layer[nm] = {
                "slot": slot.idx,
                "shape": f"{slot.w_int.shape[0]}x{slot.w_int.shape[1]}"
                if slot.w_int is not None
                else "?",
                "cache_hit": slot_hit.get(slot.idx, False),
                "solve_wall_s": slot_wall.get(slot.idx, 0.0),
                "adders": int(sol.n_adders) if sol is not None else 0,
                "cost_bits": int(sol.cost_bits) if sol is not None else 0,
                "depth": int(sol.depth) if sol is not None else 0,
                "shared_slot": len(layer_names) > 1,
            }
    return per_layer


# legacy kwarg name -> how it maps into CompileConfig
_LEGACY_COMPILE_DEFAULTS = {
    "dc": 2,
    "strategy": "da",
    "max_delay_per_stage": 5,
    "use_pallas": False,
    "jobs": None,
    "cache": None,
    "engine": "batch",
}


def compile_model(
    model: Sequential,
    params: list,
    in_shape: tuple[int, ...],
    in_quant: QuantConfig,
    dc=UNSET,
    strategy=UNSET,
    max_delay_per_stage=UNSET,
    use_pallas=UNSET,
    jobs=UNSET,
    cache=UNSET,
    engine=UNSET,
    config: CompileConfig | None = None,
) -> CompiledDesign:
    """Compile a quantized Sequential into a bit-exact integer design.

    The canonical way to set options is ``config=``, a
    :class:`repro.flow.CompileConfig` (this is what ``Flow.compile``
    passes).  The individual option kwargs are a deprecated shim kept
    for one release: they construct the equivalent config and delegate,
    so both spellings produce bit-identical designs.

    Config highlights — ``strategy`` ("da" solver / "latency" baseline);
    ``jobs`` (CMVM solver thread-pool width: None = cpu_count, 1 =
    serial; any value is bit-identical, and serial fallbacks are
    recorded in ``solver_stats["pool_fallback"]``); ``cache`` (a
    :class:`SolutionCache` so repeated compiles skip solved CMVMs
    entirely); ``solver`` (nested :class:`SolverConfig`: dc, CSE engine
    — "arena" reuses per-thread workspaces across layers — and scoring
    knobs; compile default dc=2).
    """
    legacy = {
        name: val
        for name, val in (
            ("dc", dc),
            ("strategy", strategy),
            ("max_delay_per_stage", max_delay_per_stage),
            ("use_pallas", use_pallas),
            ("jobs", jobs),
            ("cache", cache),
            ("engine", engine),
        )
        if val is not UNSET
    }
    config = resolve_legacy(
        "compile_model", config, legacy, CompileConfig, _config_from_legacy
    )
    return _compile_model(model, params, in_shape, in_quant, config)


def _config_from_legacy(legacy: dict) -> CompileConfig:
    def get(k):
        return legacy.get(k, _LEGACY_COMPILE_DEFAULTS[k])

    return CompileConfig(
        strategy=get("strategy"),
        max_delay_per_stage=get("max_delay_per_stage"),
        use_pallas=get("use_pallas"),
        jobs=get("jobs"),
        cache=get("cache"),
        solver=SolverConfig(dc=get("dc"), engine=get("engine")),
    )


def _compile_model(
    model: Sequential,
    params: list,
    in_shape: tuple[int, ...],
    in_quant: QuantConfig,
    cfg: CompileConfig,
) -> CompiledDesign:
    """Config-consuming compiler core (all public paths delegate here)."""
    if not isinstance(cfg, CompileConfig):
        from ..flow.config import ConfigError

        raise ConfigError(
            f"compile_model: config must be a CompileConfig, got {type(cfg).__name__}"
        )
    design = CompiledDesign(
        in_quant=in_quant, in_shape=tuple(in_shape), use_pallas=cfg.use_pallas,
        # the design keeps the config *identity*, not the live cache
        # handle (runtime-only; storing it would pin every cached entry
        # for the design's lifetime — and load_design can't restore it)
        config=cfg.replace(cache=None),
    )
    ctx = _Ctx(cfg, design)
    shape = tuple(in_shape)
    qints = [in_quant.qint] * int(np.prod(shape))
    # plan
    with trace.span("compile.plan", n_layers=len(model)):
        specs, shape, qints = _compile_seq(model, params, shape, qints, ctx)
    # slot -> unique layer names ("dense0", "conv1", ... in layer order);
    # layers deduplicated onto one slot contribute one name each
    slot_names: dict[int, list[str]] = {}
    for k, (slot, name, _shape_str, _nb, _bb) in enumerate(ctx.pending_reports):
        slot_names.setdefault(slot.idx, []).append(f"{name}{k}")
    # solve
    with trace.span("compile.solve_phase", n_slots=len(ctx.slots)):
        design.solver_stats = _solve_slots(ctx.slots, cfg.jobs, cfg.cache, slot_names)
    design.solver_stats["engine"] = cfg.solver.engine
    # stitch
    _stitch_span = trace.span("compile.stitch")
    _stitch_span.__enter__()
    for slot, name, shape_str, n_bias, bias_bits in ctx.pending_reports:
        sol = slot.solution
        if slot.tables is None:
            slot.tables = compile_tables(sol.program)
        rep = pipeline(sol.program, ctx.mdps)
        design.reports.append(
            LayerReport(
                name=f"{name}[{ctx.strategy}]",
                shape=shape_str,
                adders=sol.n_adders + n_bias,
                cost_bits=sol.cost_bits + bias_bits,
                depth=sol.depth + (1 if n_bias else 0),
                stages=rep.n_stages,
                ff_bits=rep.ff_bits,
                solver_time_s=sol.solver_time_s,
            )
        )
    n_packs = 0
    n_reused = 0
    for slot in ctx.slots:
        if slot.tables is None:
            slot.tables = compile_tables(slot.solution.program)
        design.tables.append(slot.tables)
        # prefer the SolutionCache's already-packed arrays (set on both
        # cache hits and puts) over a fresh to_arrays pack; warm-cache
        # compiles therefore perform zero repacks (n_program_packs == 0)
        parr = slot.solution.program_arrays
        if parr is not None:
            design.programs.append(parr)
            n_reused += 1
        else:
            try:
                design.programs.append(slot.solution.program.to_arrays())
                n_packs += 1
            except OverflowError:
                design.programs.append(None)  # not serializable: save_design rejects
        slot.w_int = slot.qin = slot.solution = slot.key = None
    design.solver_stats["n_program_packs"] = n_packs
    design.solver_stats["n_program_arrays_reused"] = n_reused
    design.step_specs = specs
    design.steps = build_steps(specs, design.tables, cfg.use_pallas)
    design.out_shape = shape
    design.out_qints = qints
    _stitch_span.__exit__(None, None, None)
    if cfg.verify != "off":
        _verify_design_gate(design, cfg, slot_names)
    return design


def _verify_design_gate(design: CompiledDesign, cfg: CompileConfig, slot_names) -> None:
    """Run the static verifier on a freshly compiled design.

    Findings land in ``solver_stats["verify"]`` (overall + per-layer
    pass/fail and wall time, keyed by the same layer names as
    ``per_layer`` solve stats); error-severity findings raise
    ``repro.analysis.DesignVerificationError`` — a design the verifier
    rejects must not be silently returned.
    """
    from ..analysis import DesignVerificationError, verify_design  # lazy: no cycle

    t0 = time.perf_counter()
    with trace.span("analysis.verify", tier=cfg.verify):
        vrep = verify_design(
            design, tier=cfg.verify, max_delay_per_stage=cfg.max_delay_per_stage
        )
    wall = time.perf_counter() - t0
    by_prog = vrep.pass_wall_s.get("program_by_index", {})
    per_layer = {}
    for idx, names in slot_names.items():
        n_err = sum(
            1 for d in vrep.errors if d.loc.get("program") == idx
        )
        for nm in names:
            per_layer[nm] = {
                "ok": n_err == 0,
                "n_errors": n_err,
                "wall_s": by_prog.get(idx, 0.0),
            }
    design.solver_stats["verify"] = {
        "tier": cfg.verify,
        "ok": vrep.ok,
        "n_errors": len(vrep.errors),
        "n_warnings": len(vrep.warnings),
        "wall_s": wall,
        "pass_wall_s": {
            k: v for k, v in vrep.pass_wall_s.items() if isinstance(v, float)
        },
        "per_layer": per_layer,
    }
    if not vrep.ok:
        raise DesignVerificationError(vrep, context="compiled design")


def _affine_out_qints(w_int: np.ndarray, qin: list[QInterval]) -> list[QInterval]:
    """Exact per-output intervals of y = x @ w_int.

    The adder graph computes each output exactly, so its value range is
    the affine-form interval — independent of how the solver structures
    the computation.  This is what lets the plan phase propagate qints
    through the network before any CMVM is solved (and it is never wider
    than interval propagation through the adder tree)."""
    out: list[QInterval] = []
    for jcol in range(w_int.shape[1]):
        q: QInterval | None = None
        col = w_int[:, jcol]
        for i in np.nonzero(col)[0]:
            term = qin[int(i)].scale(int(col[i]))
            q = term if q is None else q.add(term)
        out.append(QInterval(0, 0, 0) if q is None else q)
    return out


def _cmvm(name, w, b, wq: QuantConfig, qin: list[QInterval], ctx: _Ctx):
    """Plan one CMVM + bias. Returns ((table_idx, arrays), out_qints)
    for a cmvm-kind StepSpec; the solve itself is deferred to a
    _SolveSlot."""
    w_int = np.clip(
        np.round(np.asarray(w, np.float64) / wq.step), wq.qint.lo, wq.qint.hi
    ).astype(np.int64)
    we = wq.scale_exp()
    slot = ctx.request(w_int, list(qin))
    out_qints = [q.shift(we) for q in _affine_out_qints(w_int, qin)]

    b_int = None
    pre_shift = None
    if b is not None:
        # bias lives on the accumulator grid e_b = in_exp + w_exp; outputs
        # whose qint landed on a coarser grid are shifted down to the
        # common grid first (wiring, not logic).
        e_b = we + min(q.exp for q in qin)
        exps = _exps(out_qints, fallback=e_b)
        tgt = np.minimum(exps, e_b)
        pre_shift = (exps - tgt).astype(np.int64)
        b_int = np.floor(np.asarray(b, np.float64) / (2.0 ** tgt) + 0.5).astype(np.int64)
        out_qints = [
            QInterval((q.lo << int(s)) + int(bi), (q.hi << int(s)) + int(bi), int(t))
            if not q.is_zero
            else QInterval(min(int(bi), 0), max(int(bi), 0), int(t))
            for q, bi, s, t in zip(out_qints, b_int, pre_shift, tgt)
        ]

    n_bias = int(np.count_nonzero(b_int)) if b_int is not None else 0
    bias_bits = (
        sum(q.width for q, bi in zip(out_qints, b_int) if bi) if b_int is not None else 0
    )
    ctx.pending_reports.append(
        (slot, name, f"{w_int.shape[0]}x{w_int.shape[1]}", n_bias, bias_bits)
    )

    arrays: dict = {}
    if b_int is not None:
        arrays["bias"] = np.asarray(b_int, np.int64)
    if pre_shift is not None and pre_shift.any():
        arrays["shift"] = np.asarray(pre_shift, np.int64)
    return (slot.idx, arrays), out_qints


def _compile_seq(model, params, shape, qints, ctx):
    specs: list[StepSpec] = []
    for spec, p in zip(model, params):
        if isinstance(spec, QDense):
            s, shape, qints = _compile_dense_last(spec, p, shape, qints, ctx)
            specs.append(s)
            if spec.out_quant is not None:
                specs.append(_requant_spec(qints, spec.out_quant))
                qints = [_requant_qint(q, spec.out_quant) for q in qints]
        elif isinstance(spec, QDenseOnAxis):
            ax = spec.axis % len(shape)
            perm = [i for i in range(len(shape)) if i != ax] + [ax]
            inv = np.argsort(perm).tolist()
            pshape = tuple(shape[i] for i in perm)
            specs.append(StepSpec("transpose", params={"shape": list(shape), "perm": perm}))
            qints_t = _transpose_qints(qints, shape, perm)
            inner = QDense(spec.units, spec.w_quant, None, spec.use_bias)
            s, pshape2, qints_t = _compile_dense_last(inner, p, pshape, qints_t, ctx)
            specs.append(s)
            specs.append(
                StepSpec("transpose", params={"shape": list(pshape2), "perm": inv})
            )
            shape = tuple(pshape2[i] for i in inv)
            qints = _transpose_qints(qints_t, pshape2, inv)
            if spec.out_quant is not None:
                specs.append(_requant_spec(qints, spec.out_quant))
                qints = [_requant_qint(q, spec.out_quant) for q in qints]
        elif isinstance(spec, QConv2D):
            s, shape, qints = _compile_conv(spec, p, shape, qints, ctx)
            specs.append(s)
            if spec.out_quant is not None:
                specs.append(_requant_spec(qints, spec.out_quant))
                qints = [_requant_qint(q, spec.out_quant) for q in qints]
        elif isinstance(spec, ReLU):
            specs.append(StepSpec("relu"))
            qints = [_relu_qint(q) for q in qints]
            if spec.out_quant is not None:
                specs.append(_requant_spec(qints, spec.out_quant))
                qints = [_requant_qint(q, spec.out_quant) for q in qints]
        elif isinstance(spec, MaxPool2D):
            s, shape, qints = _compile_maxpool(spec, shape, qints)
            specs.append(s)
        elif isinstance(spec, AvgPool2D):
            s, shape, qints = _compile_avgpool(spec, shape, qints)
            specs.append(s)
        elif isinstance(spec, Flatten):
            shape = (int(np.prod(shape)),)
        elif isinstance(spec, Residual):
            body_specs, bshape, bq = _compile_seq(spec.body, p["body"], shape, qints, ctx)
            assert bshape == shape, "residual body must preserve shape"
            sa, sb, qints = _align_exps(qints, bq)
            specs.append(
                StepSpec("residual", arrays={"sa": sa, "sb": sb}, body=body_specs)
            )
        else:
            raise TypeError(f"cannot compile {spec}")
    return specs, shape, qints


def _compile_dense_last(spec: QDense, p, shape, qints, ctx):
    d_in = shape[-1]
    lead = int(np.prod(shape[:-1]))
    # union input qints across leading positions (shared CMVM instance)
    qarr = np.array(qints, dtype=object).reshape(lead, d_in)
    qin = [_union_all(list(qarr[:, k])) for k in range(d_in)]
    b = np.asarray(p["b"]) if spec.use_bias else None
    (table, arrays), out_q = _cmvm("dense", np.asarray(p["w"]), b, spec.w_quant, qin, ctx)
    # "wscale" (the weight grid exponent) is verifier metadata, like the
    # requant "exp" param — the executor never reads it
    s = StepSpec(
        "dense",
        params={"d_in": d_in, "wscale": int(spec.w_quant.scale_exp())},
        arrays=arrays,
        table=table,
    )
    return s, shape[:-1] + (spec.units,), list(out_q) * lead


def _transpose_qints(qints, shape, perm):
    arr = np.array(qints, dtype=object).reshape(shape)
    return list(arr.transpose(perm).reshape(-1))


def _pool_spec(kind: str, h, w, c, ph, pw) -> StepSpec:
    return StepSpec(kind, params={"h": h, "w": w, "c": c, "ph": ph, "pw": pw})


def _compile_maxpool(spec: MaxPool2D, shape, qints):
    h, w, c = shape
    ph, pw = spec.size
    oh, ow = h // ph, w // pw

    qarr = np.array(qints, dtype=object).reshape(h, w, c)
    new = []
    for i in range(oh):
        for j in range(ow):
            for ch in range(c):
                block = [
                    qarr[i * ph + a, j * pw + bb, ch] for a in range(ph) for bb in range(pw)
                ]
                new.append(_union_all(block))
    return _pool_spec("maxpool", h, w, c, ph, pw), (oh, ow, c), new


def _compile_avgpool(spec: AvgPool2D, shape, qints):
    """Power-of-two window: avg == sum with exponent shift (exact)."""
    h, w, c = shape
    ph, pw = spec.size
    k = ph * pw
    assert k & (k - 1) == 0
    shift = int(np.log2(k))
    oh, ow = h // ph, w // pw

    qarr = np.array(qints, dtype=object).reshape(h, w, c)
    new = []
    for i in range(oh):
        for j in range(ow):
            for ch in range(c):
                q = None
                for a in range(ph):
                    for bb in range(pw):
                        qq = qarr[i * ph + a, j * pw + bb, ch]
                        q = qq if q is None else q.add(qq)
                new.append(q.shift(-shift))
    return _pool_spec("avgpool", h, w, c, ph, pw), (oh, ow, c), new


def _compile_conv(spec: QConv2D, p, shape, qints, ctx):
    """Conv2D via im2col + shared CMVM (kernel reused spatially)."""
    h, w, cin = shape
    kh, kw = spec.kernel
    sh, sw = spec.strides
    assert spec.padding == "VALID", "compile path supports VALID convs"
    oh = (h - kh) // sh + 1
    ow = (w - kw) // sw + 1

    qarr = np.array(qints, dtype=object).reshape(h, w, cin)
    patch_qints = []
    for dy in range(kh):
        for dx in range(kw):
            for ch in range(cin):
                qs = [
                    qarr[i * sh + dy, j * sw + dx, ch]
                    for i in range(oh)
                    for j in range(ow)
                ]
                patch_qints.append(_union_all(qs))

    wmat = np.asarray(p["w"]).reshape(kh * kw * cin, spec.filters)
    b = np.asarray(p["b"]) if spec.use_bias else None
    (table, arrays), out_q = _cmvm("conv", wmat, b, spec.w_quant, patch_qints, ctx)
    s = StepSpec(
        "conv",
        params={
            "h": h, "w": w, "cin": cin, "kh": kh, "kw": kw,
            "sh": sh, "sw": sw, "oh": oh, "ow": ow,
            "wscale": int(spec.w_quant.scale_exp()),
        },
        arrays=arrays,
        table=table,
    )
    return s, (oh, ow, spec.filters), list(out_q) * (oh * ow)
