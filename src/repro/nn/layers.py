"""Functional quantized NN layers (pure JAX, explicit param pytrees).

Models are ``Sequential`` tuples of frozen layer specs.  The float
forward path (``apply_model``) uses straight-through fixed-point fake
quantization and is *bit-compatible* with the compiled integer adder
graph (see compiler.py): floor rounding, saturation, power-of-two-exact
average pooling.  Run in float64 for exact equality; float32 training is
within 1 ulp of the hardware semantics.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .quant import QuantConfig, bit_count_surrogate, fake_quant

# ----------------------------------------------------------------------
# Layer specs
# ----------------------------------------------------------------------


@dataclass(frozen=True)
class QDense:
    units: int
    w_quant: QuantConfig = QuantConfig(8, 2)
    out_quant: QuantConfig | None = None  # activation re-quantization
    use_bias: bool = True


@dataclass(frozen=True)
class QDenseOnAxis:
    """Dense along a non-final axis (EinsumDense, e.g. MLP-Mixer token mix)."""

    units: int
    axis: int
    w_quant: QuantConfig = QuantConfig(8, 2)
    out_quant: QuantConfig | None = None
    use_bias: bool = True


@dataclass(frozen=True)
class QConv2D:
    filters: int
    kernel: tuple[int, int] = (3, 3)
    strides: tuple[int, int] = (1, 1)
    padding: str = "VALID"
    w_quant: QuantConfig = QuantConfig(8, 2)
    out_quant: QuantConfig | None = None
    use_bias: bool = True


@dataclass(frozen=True)
class ReLU:
    out_quant: QuantConfig | None = None


@dataclass(frozen=True)
class MaxPool2D:
    size: tuple[int, int] = (2, 2)


@dataclass(frozen=True)
class AvgPool2D:
    """Power-of-two window: exact on the grid (sum then exponent shift)."""

    size: tuple[int, int] = (2, 2)


@dataclass(frozen=True)
class Flatten:
    pass


@dataclass(frozen=True)
class Residual:
    """y = x + body(x) (MLP-Mixer skip connection)."""

    body: tuple = ()


LayerSpec = QDense | QDenseOnAxis | QConv2D | ReLU | MaxPool2D | AvgPool2D | Flatten | Residual
Sequential = tuple  # tuple[LayerSpec, ...]


# ----------------------------------------------------------------------
# Initialisation
# ----------------------------------------------------------------------
def _glorot(rng, shape):
    fan_in = int(np.prod(shape[:-1]))
    lim = (3.0 / fan_in) ** 0.5
    return jax.random.uniform(rng, shape, jnp.float32, -lim, lim)


def init_params(rng: jax.Array, model: Sequential, in_shape: tuple[int, ...]):
    """Returns (params_list, out_shape). in_shape excludes batch."""
    params: list[dict] = []
    shape = tuple(in_shape)
    for spec in model:
        rng, sub = jax.random.split(rng)
        if isinstance(spec, QDense):
            w = _glorot(sub, (shape[-1], spec.units))
            p = {"w": w}
            if spec.use_bias:
                p["b"] = jnp.zeros((spec.units,), jnp.float32)
            params.append(p)
            shape = shape[:-1] + (spec.units,)
        elif isinstance(spec, QDenseOnAxis):
            ax = spec.axis % len(shape)
            w = _glorot(sub, (shape[ax], spec.units))
            p = {"w": w}
            if spec.use_bias:
                p["b"] = jnp.zeros((spec.units,), jnp.float32)
            params.append(p)
            shape = tuple(spec.units if i == ax else s for i, s in enumerate(shape))
        elif isinstance(spec, QConv2D):
            kh, kw = spec.kernel
            cin = shape[-1]
            w = _glorot(sub, (kh, kw, cin, spec.filters))
            p = {"w": w}
            if spec.use_bias:
                p["b"] = jnp.zeros((spec.filters,), jnp.float32)
            params.append(p)
            h, wd = shape[0], shape[1]
            if spec.padding == "VALID":
                h = (h - kh) // spec.strides[0] + 1
                wd = (wd - kw) // spec.strides[1] + 1
            else:
                h = -(-h // spec.strides[0])
                wd = -(-wd // spec.strides[1])
            shape = (h, wd, spec.filters)
        elif isinstance(spec, (MaxPool2D, AvgPool2D)):
            params.append({})
            shape = (shape[0] // spec.size[0], shape[1] // spec.size[1], shape[2])
        elif isinstance(spec, Flatten):
            params.append({})
            shape = (int(np.prod(shape)),)
        elif isinstance(spec, ReLU):
            params.append({})
        elif isinstance(spec, Residual):
            sub_params, sub_shape = init_params(sub, spec.body, shape)
            assert sub_shape == shape, "residual body must preserve shape"
            params.append({"body": sub_params})
        else:
            raise TypeError(f"unknown layer spec {spec}")
    return params, shape


# ----------------------------------------------------------------------
# Forward pass (float, STE quantization)
# ----------------------------------------------------------------------
def _bias_quant(spec_w: QuantConfig, in_quant: QuantConfig) -> QuantConfig:
    """Bias lives on the accumulator grid (in_step * w_step), wide range."""
    exp = spec_w.scale_exp() + in_quant.scale_exp()
    bits = 24
    return QuantConfig(bits, bits + exp, True)


def apply_model(
    params: list,
    model: Sequential,
    x: jnp.ndarray,
    in_quant: QuantConfig | None = None,
    collect_bits: bool = False,
):
    """Run the float/STE forward pass.

    Every QDense/QConv input must already be on a known grid; pass
    ``in_quant`` to quantize the model input.  Returns y (and the
    bit-count regularisation penalty if collect_bits).
    """
    penalty = 0.0
    cur_quant = in_quant
    if in_quant is not None:
        x = fake_quant(x, in_quant)
    for spec, p in zip(model, params):
        if isinstance(spec, (QDense, QDenseOnAxis)):
            wq = fake_quant(p["w"], spec.w_quant, rounding="round")
            if collect_bits:
                penalty = penalty + bit_count_surrogate(p["w"], spec.w_quant)
            if isinstance(spec, QDenseOnAxis):
                ax = spec.axis % (x.ndim - 1) + 1  # feature axes exclude batch
                x = jnp.moveaxis(x, ax, -1)
                x = x @ wq
                x = jnp.moveaxis(x, -1, ax)
                bshape = tuple(
                    spec.units if i == ax else 1 for i in range(1, x.ndim)
                )
            else:
                x = x @ wq
                bshape = (spec.units,)
            if spec.use_bias and cur_quant is not None:
                bq = fake_quant(
                    p["b"], _bias_quant(spec.w_quant, cur_quant), rounding="round"
                )
                x = x + bq.reshape(bshape)
            elif spec.use_bias:
                x = x + p["b"].reshape(bshape)
            if spec.out_quant is not None:
                x = fake_quant(x, spec.out_quant)
                cur_quant = spec.out_quant
            else:
                cur_quant = None
        elif isinstance(spec, QConv2D):
            wq = fake_quant(p["w"], spec.w_quant, rounding="round")
            if collect_bits:
                penalty = penalty + bit_count_surrogate(p["w"], spec.w_quant)
            x = jax.lax.conv_general_dilated(
                x, wq.astype(x.dtype), spec.strides, spec.padding,
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            if spec.use_bias and cur_quant is not None:
                bq = fake_quant(p["b"], _bias_quant(spec.w_quant, cur_quant), rounding="round")
                x = x + bq
            elif spec.use_bias:
                x = x + p["b"]
            if spec.out_quant is not None:
                x = fake_quant(x, spec.out_quant)
                cur_quant = spec.out_quant
            else:
                cur_quant = None
        elif isinstance(spec, ReLU):
            x = jnp.maximum(x, 0.0)
            if spec.out_quant is not None:
                x = fake_quant(x, spec.out_quant)
                cur_quant = spec.out_quant
        elif isinstance(spec, MaxPool2D):
            x = _pool(x, spec.size, jax.lax.max, -jnp.inf)
        elif isinstance(spec, AvgPool2D):
            k = spec.size[0] * spec.size[1]
            assert k & (k - 1) == 0, "AvgPool window must be a power of two"
            x = _pool(x, spec.size, jax.lax.add, 0.0) / k
        elif isinstance(spec, Flatten):
            x = x.reshape(x.shape[0], -1)
        elif isinstance(spec, Residual):
            y = apply_model(p["body"], spec.body, x, in_quant=cur_quant)
            x = x + y
            cur_quant = None
        else:
            raise TypeError(f"unknown layer spec {spec}")
    if collect_bits:
        return x, penalty
    return x


def _pool(x, size, op, init):
    return jax.lax.reduce_window(
        x, init, op,
        window_dimensions=(1, size[0], size[1], 1),
        window_strides=(1, size[0], size[1], 1),
        padding="VALID",
    )
