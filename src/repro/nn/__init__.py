"""Quantized-NN substrate: HGQ-style QAT layers, the paper's benchmark
networks, and the da4ml compile path (hls4ml-integration analogue)."""

from .quant import QuantConfig, fake_quant
from .layers import (
    QDense,
    QConv2D,
    ReLU,
    MaxPool2D,
    AvgPool2D,
    Flatten,
    Residual,
    QDenseOnAxis,
    Sequential,
    init_params,
    apply_model,
)
from .compiler import CompiledDesign, LayerReport, StepSpec, build_steps, compile_model
from .interpreter import adder_graph_numpy, build_numpy_steps, numpy_forward_fn
from . import models

__all__ = [
    "AvgPool2D",
    "CompiledDesign",
    "LayerReport",
    "StepSpec",
    "adder_graph_numpy",
    "build_numpy_steps",
    "build_steps",
    "numpy_forward_fn",
    "Flatten",
    "MaxPool2D",
    "QConv2D",
    "QDense",
    "QDenseOnAxis",
    "QuantConfig",
    "ReLU",
    "Residual",
    "Sequential",
    "apply_model",
    "compile_model",
    "fake_quant",
    "init_params",
    "models",
]
