"""Pure-jnp oracle for GQA flash attention (causal or full).

Two paths, numerically identical:

  * dense — materialises the [B,H,Sq,Sk] logits; used for short
    sequences and as the oracle in kernel tests;
  * chunked — static Python loop over query chunks, each attending only
    to its causal K prefix (exact flops, no S x S buffer).  This is the
    long-context path the dry-run lowers: peak attention memory is
    O(Sq_chunk x Sk_chunk_limit) per chip instead of O(S^2).

GQA is computed with a grouped einsum (no K/V repeat materialisation).
"""

from __future__ import annotations

import jax.numpy as jnp

_DENSE_MAX_ELEMS = 1 << 24  # logits entries per (b,h) slice before chunking
_CHUNK = 1024


def _attend(q, k, v, scale, causal, q_start, sk_valid=None):
    """Grouped attention for one q chunk vs k[:, :, :Sk'].

    q: [B, Hq, Cq, D]; k/v: [B, Hkv, Sk', D].  q_start: absolute position
    of q[0] (int or traced scalar).  Masks ki > q_start + i.
    """
    b, hq, cq, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    # bf16 operands with f32 accumulation (preferred_element_type): the
    # MXU does bf16xbf16->f32 natively, and this avoids materialising f32
    # copies of K/V (2x the cache traffic at decode time).
    q5 = q.reshape(b, hkv, g, cq, d)
    logits = (
        jnp.einsum("bhgqd,bhkd->bhgqk", q5, k, preferred_element_type=jnp.float32)
        * scale
    )
    sk = k.shape[2]
    if causal:
        qi = jnp.arange(cq)[:, None] + q_start
        ki = jnp.arange(sk)[None, :]
        mask = ki <= qi
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    m = logits.max(axis=-1, keepdims=True)
    # fully-masked rows (can't happen for causal with q_start>=0) guard:
    m = jnp.maximum(m, -1e30)
    p = jnp.exp(logits - m)
    out = jnp.einsum(
        "bhgqk,bhkd->bhgqd", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    out = out / p.sum(axis=-1, keepdims=True)
    return out.reshape(b, hq, cq, d)


def attention_ref(
    q: jnp.ndarray,  # [B, Hq, Sq, D]
    k: jnp.ndarray,  # [B, Hkv, Sk, D]
    v: jnp.ndarray,  # [B, Hkv, Sk, D]
    causal: bool = True,
    scale: float | None = None,
    offset=None,  # absolute position of q[0]; default end-aligned (Sk - Sq)
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    sk = k.shape[2]
    scale = d**-0.5 if scale is None else scale
    static_offset = (sk - sq) if offset is None else offset

    if sq * sk <= _DENSE_MAX_ELEMS or sq == 1:
        out = _attend(q, k, v, scale, causal, static_offset)
        return out.astype(q.dtype)

    # chunked: static loop over q chunks; causal chunks slice K to the
    # live prefix (exact flops; requires a static offset)
    assert not hasattr(static_offset, "dtype") or not causal, (
        "chunked causal attention needs a static offset"
    )
    outs = []
    for i0 in range(0, sq, _CHUNK):
        cq = min(_CHUNK, sq - i0)
        qi = q[:, :, i0 : i0 + cq]
        if causal:
            hi = min(int(static_offset) + i0 + cq, sk)
            hi = -(-hi // 128) * 128  # keep lane-aligned slices
            hi = min(hi, sk)
        else:
            hi = sk
        outs.append(
            _attend(qi, k[:, :, :hi], v[:, :, :hi], scale, causal, static_offset + i0)
        )
    return jnp.concatenate(outs, axis=2).astype(q.dtype)
