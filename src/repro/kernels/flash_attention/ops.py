"""Public attention op: Pallas kernel on TPU, fused-jnp oracle elsewhere.

The LM stack calls :func:`flash_attention`; backend selection is explicit
so the multi-pod dry-run (CPU lowering) always takes the jnp path while
TPU deployments flip ``use_pallas=True`` per config.

``offset`` is the absolute position of the first query token: None means
end-aligned (training/prefill without cache, offset = Sk - Sq); decode
into a preallocated cache passes the current write position so unwritten
cache slots are masked out.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_ref


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    scale: float | None = None,
    offset=None,
    use_pallas: bool = False,
    interpret: bool = True,
    block_q: int = 128,
    block_k: int = 128,
) -> jnp.ndarray:
    """GQA attention. q: [B,Hq,Sq,D]; k/v: [B,Hkv,Sk,D] with Hq % Hkv == 0."""
    if use_pallas:
        off = (k.shape[2] - q.shape[2]) if offset is None else offset
        return flash_attention_pallas(
            q, k, v, jnp.asarray(off, jnp.int32),
            causal=causal, scale=scale, block_q=block_q, block_k=block_k,
            interpret=interpret,
        )
    return attention_ref(q, k, v, causal=causal, scale=scale, offset=offset)
