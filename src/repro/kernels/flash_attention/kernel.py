"""Pallas TPU flash-attention kernel (GQA, causal/full), online softmax.

Grid: (batch, q_heads, q_blocks).  Each program instance streams the KV
sequence for its (b, h) pair in ``block_k`` tiles held in VMEM, keeping
the FlashAttention running max / normaliser / accumulator in registers.
MXU-aligned block shapes (multiples of 128 on the contracting dims) are
chosen by the wrapper in ops.py.

Causal masking uses an absolute query offset (``offset`` = position of
the first query token), so the same kernel serves training (offset 0),
prefill into a preallocated cache (offset 0, Sk = cache size) and decode
(Sq = 1, offset = current position).  KV blocks entirely above the
causal frontier are skipped via the loop bound, so causal prefill does
~half the work and decode touches only the live prefix of the cache.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, off_ref, o_ref, *, scale, causal, block_k, sk):
    q = q_ref[0, 0].astype(jnp.float32) * scale  # [bq, d]
    bq, d = q.shape
    nk = sk // block_k
    q_block = pl.program_id(2)
    offset = off_ref[0]
    q_pos = q_block * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0) + offset

    if causal:
        # last kv block intersecting this q block's causal window
        hi = (q_block + 1) * bq + offset  # exclusive max key pos
        nk_eff = jnp.minimum((hi + block_k - 1) // block_k, nk)
    else:
        nk_eff = nk

    def body(ik, carry):
        acc, m_prev, l_prev = carry
        k = k_ref[0, 0, pl.dslice(ik * block_k, block_k), :]
        v = v_ref[0, 0, pl.dslice(ik * block_k, block_k), :]
        s = q @ k.astype(jnp.float32).T  # [bq, block_k]
        if causal:
            k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_cur = l_prev * alpha + p.sum(axis=-1, keepdims=True)
        acc = acc * alpha + p @ v.astype(jnp.float32)
        return acc, m_cur, l_cur

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    acc, _, lsum = jax.lax.fori_loop(0, nk_eff, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / jnp.maximum(lsum, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "block_q", "block_k", "interpret")
)
def flash_attention_pallas(
    q: jnp.ndarray,  # [B, Hq, Sq, D]
    k: jnp.ndarray,  # [B, Hkv, Sk, D]
    v: jnp.ndarray,
    offset: jnp.ndarray,  # scalar int32: absolute position of q[0]
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    scale = d**-0.5 if scale is None else scale
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q or sk % block_k:
        raise ValueError("sequence lengths must divide block sizes")
    grid = (b, hq, sq // block_q)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_k=block_k, sk=sk
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, sk, d), lambda b_, h, iq, g=group: (b_, h // g, 0, 0)),
            pl.BlockSpec((1, 1, sk, d), lambda b_, h, iq, g=group: (b_, h // g, 0, 0)),
            pl.BlockSpec((1,), lambda b_, h, iq: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        interpret=interpret,
    )(q, k, v, jnp.asarray(offset, jnp.int32).reshape(1))
