"""Pure-jnp oracle for the levelized adder-graph executor.

Evaluates a DAIS program (compiled to level-contiguous instruction
tables) on a batch of integer inputs: the bit-exact FPGA semantics of the
da4ml adder tree, expressed as data-parallel gathers + shifts + adds.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def adder_graph_ref(tables, x: jnp.ndarray) -> jnp.ndarray:
    """Evaluate the program.

    tables : AdderGraphTables (see ops.py) — levelized instruction arrays.
    x      : int array [batch, n_inputs] on the input integer grid.
    returns int32 [batch, n_outputs].
    """
    v = x.T.astype(jnp.int32)  # [n_inputs, B] — values as rows
    instr = np.asarray(tables.instr)
    for lo, hi in tables.level_bounds:
        ops = instr[lo:hi]
        a = jnp.take(v, ops[:, 0], axis=0) << ops[:, 2][:, None]
        b = jnp.take(v, ops[:, 1], axis=0) << ops[:, 3][:, None]
        v = jnp.concatenate([v, a + ops[:, 4][:, None] * b], axis=0)
    outs = np.asarray(tables.outs)
    y = jnp.take(v, outs[:, 0], axis=0)
    shift = outs[:, 1][:, None]
    y = jnp.where(shift >= 0, y << np.maximum(shift, 0), y >> np.maximum(-shift, 0))
    y = y * outs[:, 2][:, None] * outs[:, 3][:, None]
    return y.T
