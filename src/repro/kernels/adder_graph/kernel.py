"""Pallas TPU kernel: levelized adder-graph execution over batch tiles.

TPU adaptation of the paper's FPGA adder tree (DESIGN.md §Hardware
adaptation): instead of spatial unrolling onto LUTs, the DAIS program is
levelized (ops grouped by adder depth, operands always in earlier rows)
and executed as VPU-parallel gathers + shifts + adds over a batch tile
held in VMEM:

    V[level_k rows] = (V[a] << sh_a) + sign * (V[b] << sh_b)

The instruction table is a real kernel input (Pallas forbids captured
array constants); level boundaries are static, so XLA sees one gather +
shift + add per level, vectorised across that level's ops and across the
batch tile.

BlockSpec tiling: the batch dimension is tiled to ``block_b`` lanes; the
value buffer for one tile ([n_rows, block_b] int32) lives in VMEM.  For a
typical quantized NN layer (n_rows ~ 4k, block_b = 256) that is ~4 MB —
comfortably inside the ~16 MB VMEM of a TPU core.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adder_graph_kernel(x_ref, instr_ref, outs_ref, o_ref, *, level_bounds):
    v = x_ref[...].T.astype(jnp.int32)  # [n_inputs, block_b]
    for lo, hi in level_bounds:
        ops = instr_ref[lo:hi]  # static slice: [n_level, 5]
        a = jnp.take(v, ops[:, 0], axis=0) << ops[:, 2][:, None]
        b = jnp.take(v, ops[:, 1], axis=0) << ops[:, 3][:, None]
        v = jnp.concatenate([v, a + ops[:, 4][:, None] * b], axis=0)
    outs = outs_ref[...]
    y = jnp.take(v, outs[:, 0], axis=0)
    shift = outs[:, 1][:, None]
    y = jnp.where(shift >= 0, y << jnp.maximum(shift, 0), y >> jnp.maximum(-shift, 0))
    o_ref[...] = (y * outs[:, 2][:, None] * outs[:, 3][:, None]).T


@functools.partial(jax.jit, static_argnames=("tables", "block_b", "interpret"))
def adder_graph_pallas(tables, x: jnp.ndarray, block_b: int = 256, interpret: bool = True):
    """Run the adder graph on int32 inputs [batch, n_in] via pallas_call.

    ``interpret=True`` executes the kernel body on CPU (bit-exact); on a
    real TPU pass ``interpret=False``.
    """
    batch, n_in = x.shape
    n_out = tables.n_outputs
    n_ops = max(tables.n_ops, 1)
    instr = jnp.asarray(tables.instr) if tables.n_ops else jnp.zeros((1, 5), jnp.int32)
    outs = jnp.asarray(tables.outs)
    pad = (-batch) % block_b
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    padded = batch + pad
    grid = (padded // block_b,)
    out = pl.pallas_call(
        functools.partial(_adder_graph_kernel, level_bounds=tables.level_bounds),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n_in), lambda i: (i, 0)),
            pl.BlockSpec((n_ops, 5), lambda i: (0, 0)),
            pl.BlockSpec((n_out, 4), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, n_out), jnp.int32),
        interpret=interpret,
    )(x.astype(jnp.int32), instr, outs)
    return out[:batch]
