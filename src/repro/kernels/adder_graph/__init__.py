from .ops import AdderGraphTables, adder_graph_apply, compile_tables

__all__ = ["AdderGraphTables", "adder_graph_apply", "compile_tables"]
