"""JIT'd wrapper + DAIS->instruction-table compiler for the adder-graph
executor (Pallas kernel in kernel.py, pure-jnp oracle in ref.py)."""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from ...core.dais import KIND_ADD, KIND_INPUT, KIND_NEG, DAISProgram


@dataclass(frozen=True)
class AdderGraphTables:
    """Levelized instruction tables.

    instr : int32 [n_ops, 5] — (a_idx, b_idx, sh_a, sh_b, sign), rows
            ordered level-contiguously; ops in level k only reference
            rows produced before level k (inputs occupy rows
            [0, n_inputs)).  Passed to the kernel as a real input.
    level_bounds : static (lo, hi) op ranges per level.
    outs  : int32 [n_out, 4] — (row, shift, sign, mask); mask zeroes the
            constant-0 outputs.
    digest : content hash over every field that determines execution.
            Hash/eq key on it — NOT on identity — so tables rebuilt from
            a saved artifact (or by a second compile of the same model)
            hit the same jit cache entry as the original instead of
            silently re-triggering kernel compilation (``tables`` is a
            static argument of ``adder_graph_pallas``).  The instruction
            arrays are frozen read-only to keep the digest truthful.
    """

    n_inputs: int
    n_rows: int
    level_bounds: tuple[tuple[int, int], ...]
    instr: np.ndarray = field(repr=False)
    outs: np.ndarray = field(repr=False)
    digest: str = ""

    def __post_init__(self):
        if not self.digest:
            object.__setattr__(self, "digest", self._content_digest())
        for arr in (self.instr, self.outs):
            arr.setflags(write=False)

    def _content_digest(self) -> str:
        h = hashlib.sha256(b"adder-graph-tables-v1")
        h.update(np.array([self.n_inputs, self.n_rows], np.int64).tobytes())
        h.update(repr(self.level_bounds).encode())
        h.update(np.ascontiguousarray(self.instr).tobytes())
        h.update(np.ascontiguousarray(self.outs).tobytes())
        return h.hexdigest()

    def __hash__(self):
        return hash(self.digest)

    def __eq__(self, other):
        return isinstance(other, AdderGraphTables) and self.digest == other.digest

    @property
    def n_ops(self) -> int:
        return int(self.instr.shape[0])

    @property
    def n_outputs(self) -> int:
        return int(self.outs.shape[0])


def compile_tables(prog: DAISProgram) -> AdderGraphTables:
    """Reorder a DAIS program level-contiguously and pack instruction
    tables.  Negation rows are lowered onto the same add/sub datapath as
    ``u = (a << 0) - (a << 1) = -a`` (one op, same operand twice)."""
    order = sorted(
        range(len(prog.rows)),
        key=lambda i: (prog.rows[i].kind != KIND_INPUT, prog.rows[i].depth, i),
    )
    remap = {old: new for new, old in enumerate(order)}
    n_inputs = prog.n_inputs

    by_depth: dict[int, list[int]] = {}
    for i in order:
        r = prog.rows[i]
        if r.kind != KIND_INPUT:
            by_depth.setdefault(r.depth, []).append(i)

    instr_rows: list[tuple[int, int, int, int, int]] = []
    bounds: list[tuple[int, int]] = []
    for d in sorted(by_depth):
        lo = len(instr_rows)
        for i in by_depth[d]:
            r = prog.rows[i]
            if r.kind == KIND_ADD:
                instr_rows.append((remap[r.a], remap[r.b], r.sh_a, r.sh_b, r.sign))
            elif r.kind == KIND_NEG:
                instr_rows.append((remap[r.a], remap[r.a], 0, 1, -1))
            else:  # pragma: no cover
                raise AssertionError
        bounds.append((lo, len(instr_rows)))

    instr = np.array(instr_rows, dtype=np.int32).reshape(-1, 5)
    # level-contiguity invariant: operands strictly precede their level
    start = n_inputs
    for lo, hi in bounds:
        if hi > lo:
            assert instr[lo:hi, :2].max() < start
        start += hi - lo

    outs = []
    for t in prog.outputs:
        if t is None:
            outs.append((0, 0, 1, 0))
        else:
            outs.append((remap[t.row], t.shift, t.sign, 1))
    return AdderGraphTables(
        n_inputs=n_inputs,
        n_rows=len(prog.rows),
        level_bounds=tuple(bounds),
        instr=instr,
        outs=np.array(outs, dtype=np.int32).reshape(-1, 4),
    )


def adder_graph_apply(
    tables: AdderGraphTables,
    x: jnp.ndarray,
    *,
    use_pallas: bool = False,
    block_b: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Evaluate y = x @ M through the optimized adder graph.

    x: int array [..., n_inputs] (integer grid). Returns int32
    [..., n_outputs]. ``use_pallas`` selects the Pallas TPU kernel
    (interpret=True executes it on CPU for validation); the default is
    the pure-jnp reference, which XLA fuses well on any backend.
    """
    from .kernel import adder_graph_pallas
    from .ref import adder_graph_ref

    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if use_pallas:
        y = adder_graph_pallas(tables, x2, block_b=block_b, interpret=interpret)
    else:
        y = adder_graph_ref(tables, x2)
    return y.reshape(*lead, y.shape[-1])
