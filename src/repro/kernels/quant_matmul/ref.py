"""Pure-jnp oracle for the int8 quantized matmul."""

from __future__ import annotations

import jax.numpy as jnp


def quant_matmul_ref(
    x: jnp.ndarray,  # int8 [M, K]
    w: jnp.ndarray,  # int8 [K, N]
    x_scale: jnp.ndarray,  # f32 [M] per-row scales
    w_scale: jnp.ndarray,  # f32 [N] per-channel scales
) -> jnp.ndarray:
    acc = jnp.matmul(
        x.astype(jnp.int32), w.astype(jnp.int32), preferred_element_type=jnp.int32
    )
    return acc.astype(jnp.float32) * x_scale[:, None] * w_scale[None, :]
