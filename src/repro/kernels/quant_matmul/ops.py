"""Public W8A8 matmul op with padding + backend selection."""

from __future__ import annotations

import jax.numpy as jnp

from .kernel import quant_matmul_pallas
from .ref import quant_matmul_ref


def quant_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    x_scale: jnp.ndarray,
    w_scale: jnp.ndarray,
    use_pallas: bool = False,
    interpret: bool = True,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
) -> jnp.ndarray:
    """y = (x_int8 @ w_int8) * x_scale[:,None] * w_scale[None,:].

    Pads M/N/K up to block multiples for the Pallas path (zero padding is
    exact for integer matmul)."""
    if not use_pallas:
        return quant_matmul_ref(x, w, x_scale, w_scale)
    m, k = x.shape
    _, n = w.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
        x_scale = jnp.pad(x_scale, (0, pm))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
        w_scale = jnp.pad(w_scale, (0, pn))
    y = quant_matmul_pallas(
        x, w, x_scale, w_scale,
        block_m=bm, block_n=bn, block_k=bk, interpret=interpret,
    )
    return y[:m, :n]
