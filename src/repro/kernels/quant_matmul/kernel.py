"""Pallas TPU kernel: int8 x int8 -> int32 matmul with per-row/-channel
dequantisation (W8A8).

This is the MXU-native realisation of a heavily-quantized CMVM — the
counterpart to the adder-graph executor in DESIGN.md §Hardware
adaptation: on LUT fabric the paper's shift-add graph wins; on a systolic
MXU an int8 matmul at 2x bf16 throughput is roofline-optimal.  The
framework exposes both per layer.

Grid: (M/bm, N/bn, K/bk) with K innermost (sequential), accumulating
into the revisited f32 output tile; dequant scales apply in the epilogue
at the final K step.  Block shapes default to MXU-aligned (128,128,256).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qmm_kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, *, n_k):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ik == n_k - 1)
    def _epilogue():
        o_ref[...] = o_ref[...] * xs_ref[...][:, None] * ws_ref[...][None, :]


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def quant_matmul_pallas(
    x: jnp.ndarray,  # int8 [M, K]
    w: jnp.ndarray,  # int8 [K, N]
    x_scale: jnp.ndarray,  # f32 [M]
    w_scale: jnp.ndarray,  # f32 [N]
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    m, k = x.shape
    _, n = w.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    if m % bm or n % bn or k % bk:
        raise ValueError("dims must divide block sizes (pad upstream)")
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_qmm_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w, x_scale, w_scale)
