from .ops import quant_matmul

__all__ = ["quant_matmul"]
