"""Public selective-scan op: Pallas kernel on TPU, jnp scan elsewhere."""

from __future__ import annotations

import jax.numpy as jnp

from .kernel import selective_scan_pallas
from .ref import selective_scan_ref


def selective_scan(
    dt: jnp.ndarray,
    bmat: jnp.ndarray,
    cmat: jnp.ndarray,
    x: jnp.ndarray,
    a: jnp.ndarray,
    h0: jnp.ndarray,
    use_pallas: bool = False,
    tile_d: int = 128,
    interpret: bool = True,
):
    """Mamba-1 recurrence. Returns (y [B,S,D], h_final [B,D,N])."""
    # kernel contract is f32 (the scan state must be f32 regardless of
    # the surrounding compute dtype / x64 mode)
    f32 = jnp.float32
    dt, bmat, cmat, x, a, h0 = (
        u.astype(f32) for u in (dt, bmat, cmat, x, a, h0)
    )
    if use_pallas:
        return selective_scan_pallas(
            dt, bmat, cmat, x, a, h0, tile_d=tile_d, interpret=interpret
        )
    return selective_scan_ref(dt, bmat, cmat, x, a, h0)
