"""Pallas TPU kernel: fused Mamba-1 selective scan.

The production answer to the SSM memory floor measured in EXPERIMENTS.md
§Perf cell 2: the recurrent state h [tile_d, N] lives in VMEM for the
whole sequence, so HBM traffic is exactly the input/output streams
(dt, B, C, x in; y out) — the [B, S, D, N] state tensor never exists,
matching the hand-derived optimum the time-major jnp scan approximates.

Grid: (B, D/tile_d); each program instance scans its channel tile over
the full sequence with a fori_loop, carrying h in registers/VMEM.
Sequence blocks of the inputs are resident per instance (choose tile_d
so (4 streams x S x tile_d x 4B) fits VMEM; e.g. S=4096, tile_d=128 ->
~8.5 MB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssm_kernel(dt_ref, b_ref, c_ref, x_ref, a_ref, h0_ref, y_ref, hout_ref, *, seq_len):
    a = a_ref[...]  # [tile_d, N]
    h = h0_ref[0]  # [tile_d, N]

    def body(t, h):
        dt_t = dt_ref[0, t, :]  # [tile_d]
        decay = jnp.exp(dt_t[:, None] * a)
        bx = dt_t[:, None] * b_ref[0, t, :][None, :] * x_ref[0, t, :][:, None]
        h = decay * h + bx
        y_ref[0, t, :] = jnp.sum(h * c_ref[0, t, :][None, :], axis=-1)
        return h

    h = jax.lax.fori_loop(0, seq_len, body, h)
    hout_ref[0] = h


@functools.partial(jax.jit, static_argnames=("tile_d", "interpret"))
def selective_scan_pallas(
    dt: jnp.ndarray,  # f32 [B, S, D]
    bmat: jnp.ndarray,  # f32 [B, S, N]
    cmat: jnp.ndarray,  # f32 [B, S, N]
    x: jnp.ndarray,  # f32 [B, S, D]
    a: jnp.ndarray,  # f32 [D, N]
    h0: jnp.ndarray,  # f32 [B, D, N]
    tile_d: int = 128,
    interpret: bool = True,
):
    b, s, d = dt.shape
    n = a.shape[1]
    tile_d = min(tile_d, d)
    if d % tile_d:
        raise ValueError("d_inner must divide tile_d")
    grid = (b, d // tile_d)
    kernel = functools.partial(_ssm_kernel, seq_len=s)
    y, h_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, s, tile_d), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, s, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s, tile_d), lambda i, j: (i, 0, j)),
            pl.BlockSpec((tile_d, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tile_d, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, s, tile_d), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, tile_d, n), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d, n), jnp.float32),
        ],
        interpret=interpret,
    )(dt, bmat, cmat, x, a, h0)
    return y, h_out
