from .ops import selective_scan

__all__ = ["selective_scan"]
