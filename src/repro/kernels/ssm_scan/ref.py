"""Pure-jnp oracle for the Mamba-1 selective scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(
    dt: jnp.ndarray,  # f32 [B, S, D]   (post-softplus)
    bmat: jnp.ndarray,  # f32 [B, S, N]
    cmat: jnp.ndarray,  # f32 [B, S, N]
    x: jnp.ndarray,  # f32 [B, S, D]
    a: jnp.ndarray,  # f32 [D, N]      (negative)
    h0: jnp.ndarray,  # f32 [B, D, N]
):
    """h_t = exp(dt_t * A) h_{t-1} + dt_t B_t x_t ; y_t = C_t . h_t.

    Returns (y [B, S, D], h_final [B, D, N])."""

    def step(h, inp):
        dt_t, b_t, x_t, c_t = inp
        decay = jnp.exp(dt_t[:, :, None] * a)
        h = decay * h + dt_t[:, :, None] * b_t[:, None, :] * x_t[:, :, None]
        return h, jnp.einsum("bdn,bn->bd", h, c_t)

    def tm(u):
        return u.swapaxes(0, 1)
    h, ys = jax.lax.scan(step, h0, (tm(dt), tm(bmat), tm(x), tm(cmat)))
    return ys.swapaxes(0, 1), h
