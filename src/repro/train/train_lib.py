"""Train-step factory: mixed precision, grad clipping, microbatch
accumulation, sharded optimizer update — plus the fault-tolerant driver.

``make_train_step(cfg, run_cfg)`` returns a pure function
    train_step(params, opt_state, batch, step) -> (params, opt_state, metrics)
suitable for jit with donated (params, opt_state).  Gradient accumulation
runs as a lax.scan over microbatches with f32 accumulators, so the
memory-optimal schedule (one microbatch live at a time) is what XLA sees.

The ``Trainer`` driver adds the production concerns: checkpoint/restart
(async, atomic), deterministic data resume (the step counter is the data
cursor), crash recovery with bounded retries, and a straggler/heartbeat
hook (on real fleets this is wired to the cluster health service; here it
is a timing watchdog around the step future).
"""

from __future__ import annotations

import logging
import time

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, RunConfig
from ..distributed import current_rules
from ..models import loss_fn, param_specs
from ..optim import lr_schedule, make_optimizer
from . import checkpoint

log = logging.getLogger("repro.train")


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), norm


def make_train_step(cfg: ArchConfig, run_cfg: RunConfig):
    opt_init, opt_update = make_optimizer(run_cfg)

    def constrain_like_params(tree):
        """Pin a param-shaped tree (e.g. the f32 grad accumulator) to the
        parameter sharding — left unconstrained XLA tends to shard it
        only along one mesh axis, inflating temp memory 16x."""
        rules = current_rules()
        if rules is None:
            return tree
        specs = param_specs(cfg)
        leaves, treedef = jax.tree.flatten(tree)
        from ..models.transformer import PSpec

        spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, PSpec))
        out = [rules.constrain(x, *s.axes) for x, s in zip(leaves, spec_leaves)]
        return treedef.unflatten(out)

    def grads_of(params, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True
        )(params)
        return loss, parts, grads

    def train_step(params, opt_state, batch, step):
        mb = run_cfg.microbatch
        if mb > 1:
            def body(carry, micro):
                acc, loss_acc = carry
                loss, _, grads = grads_of(params, micro)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / mb, acc, grads
                )
                return (constrain_like_params(acc), loss_acc + loss / mb), None

            micro = jax.tree.map(
                lambda x: x.reshape(mb, x.shape[0] // mb, *x.shape[1:]), batch
            )
            zeros = constrain_like_params(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            )
            (grads, loss), _ = jax.lax.scan(body, (zeros, 0.0), micro)
        else:
            loss, _, grads = grads_of(params, batch)
        grads, gnorm = clip_by_global_norm(grads, run_cfg.grad_clip)
        lr = lr_schedule(run_cfg, step)
        new_params, new_opt = opt_update(grads, opt_state, params, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_params, new_opt, metrics

    return train_step, opt_init


class Trainer:
    """Fault-tolerant training driver (checkpoint/restart/elastic)."""

    def __init__(
        self,
        cfg: ArchConfig,
        run_cfg: RunConfig,
        pipeline,
        params,
        jit_train_step,
        opt_state,
        step: int = 0,
        straggler_warn_s: float | None = None,
    ):
        self.cfg = cfg
        self.run_cfg = run_cfg
        self.pipeline = pipeline
        self.params = params
        self.opt_state = opt_state
        self.step = step
        self.train_step = jit_train_step
        self.straggler_warn_s = straggler_warn_s
        self._save_thread = None
        self._step_times: list[float] = []

    # ------------------------------------------------------------------
    @classmethod
    def resume_or_init(cls, cfg, run_cfg, pipeline, init_params_fn, jit_train_step, opt_init):
        params = init_params_fn()
        opt_state = opt_init(params)
        step = 0
        last = checkpoint.latest_step(run_cfg.checkpoint_dir)
        if last is not None:
            log.info("restoring checkpoint step %d", last)
            state = checkpoint.restore(
                run_cfg.checkpoint_dir, last, {"p": params, "o": opt_state}
            )
            params, opt_state, step = state["p"], state["o"], last
        return cls(cfg, run_cfg, pipeline, params, jit_train_step, opt_state, step)

    # ------------------------------------------------------------------
    def run(self, n_steps: int, max_restarts: int = 3, fail_hook=None) -> dict:
        """Run n_steps with crash recovery. ``fail_hook(step)`` may raise
        to simulate node failure (tests use this)."""
        target = self.step + n_steps
        restarts = 0
        metrics = {}
        while self.step < target:
            try:
                if fail_hook is not None:
                    fail_hook(self.step)
                metrics = self._one_step()
            except (RuntimeError, OSError) as e:  # node failure / preemption
                restarts += 1
                if restarts > max_restarts:
                    raise
                log.warning("step %d failed (%s); restoring last checkpoint", self.step, e)
                self._restore_latest()
        self._checkpoint(force=True)
        if self._save_thread is not None:
            self._save_thread.join()
        return metrics

    def _one_step(self) -> dict:
        t0 = time.perf_counter()
        batch = self.pipeline.batch_at(self.step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        self.params, self.opt_state, metrics = self.train_step(
            self.params, self.opt_state, batch, self.step
        )
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        self._step_times.append(dt)
        if len(self._step_times) >= 8:
            recent = self._step_times[-16:]
            med = sorted(recent)[len(recent) // 2]
            thresh = self.straggler_warn_s if self.straggler_warn_s else 3 * med
            if dt > thresh:
                log.warning(
                    "straggler: step %d took %.2fs (median %.2fs) — on a real "
                    "fleet this triggers hot-spare promotion", self.step, dt, med,
                )
        self.step += 1
        if self.step % self.run_cfg.checkpoint_every == 0:
            self._checkpoint()
        return {k: float(v) for k, v in metrics.items()}

    def _checkpoint(self, force: bool = False):
        if self._save_thread is not None:
            self._save_thread.join()
        self._save_thread = checkpoint.save(
            self.run_cfg.checkpoint_dir,
            self.step,
            {"p": self.params, "o": self.opt_state},
            keep=self.run_cfg.keep_checkpoints,
            async_=not force,
        )

    def _restore_latest(self):
        last = checkpoint.latest_step(self.run_cfg.checkpoint_dir)
        if last is None:
            raise RuntimeError("no checkpoint to restore from")
        state = checkpoint.restore(
            self.run_cfg.checkpoint_dir, last, {"p": self.params, "o": self.opt_state}
        )
        self.params, self.opt_state, self.step = state["p"], state["o"], last
