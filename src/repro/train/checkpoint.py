"""Checkpointing: atomic, async-capable, mesh-reshardable.

Layout: <dir>/step_<n>/ containing one .npy per flattened pytree leaf
plus MANIFEST.json (step, leaf paths/dtypes, run metadata).  Writes go to
a temp directory renamed into place, so a crash mid-save never corrupts
the latest checkpoint (restore scans for the newest complete manifest).

Resharding: leaves are saved as full (replicated-view) host arrays;
``restore`` re-places them under whatever mesh/shardings the restoring
job passes — a 256-chip checkpoint restores onto 512 chips (elastic
rescale) or onto the CPU test harness unchanged.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _leaf_paths(tree) -> list[str]:
    paths = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(path).replace("/", "_"))
    return paths


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3, async_: bool = False):
    """Save a pytree. Returns immediately if async_ (joinable via the
    returned thread)."""
    leaves = jax.tree.leaves(tree)
    host = [np.asarray(jax.device_get(x)) for x in leaves]

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        names = []
        for i, arr in enumerate(host):
            name = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, name), arr)
            names.append(name)
        manifest = {"step": step, "leaves": names}
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir) if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for d in sorted(os.listdir(ckpt_dir)):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "MANIFEST.json")):
                best = int(d.split("_")[1])
    return best


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; optionally re-place each
    leaf with the given shardings (mesh resharding / elastic restore)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == len(manifest["leaves"]), (
        f"checkpoint has {len(manifest['leaves'])} leaves, expected {len(leaves)}"
    )
    host = [np.load(os.path.join(path, n)) for n in manifest["leaves"]]
    if shardings is not None:
        sh_leaves = treedef.flatten_up_to(shardings)
        out = [jax.device_put(h, s) for h, s in zip(host, sh_leaves)]
    else:
        out = [
            jax.device_put(h.astype(lf.dtype) if hasattr(lf, "dtype") else h)
            for h, lf in zip(host, leaves)
        ]
    return treedef.unflatten(out)
