import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16x16 single-pod /
2x16x16 multi-pod placeholder devices), abstract params / optimizer state
/ batches / caches (ShapeDtypeStruct — zero allocation), jits the real
train_step / prefill / serve_step with explicit in/out shardings,
``.lower().compile()``s it, and records:

  * memory_analysis()  — per-chip HBM footprint (proves it fits),
  * cost_analysis()    — per-chip FLOPs / bytes for §Roofline,
  * collective wire bytes parsed from the optimized HLO,
  * the three roofline terms + bottleneck + MFU bound.

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system, not in the harness.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out experiments/dryrun.json
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from .. import configs  # noqa: E402
from ..configs.base import SHAPES, RunConfig  # noqa: E402
from ..distributed import MeshRules, use_rules  # noqa: E402
from ..models import (  # noqa: E402
    abstract_params,
    decode_step,
    param_shardings,
)
from ..models.transformer import cache_shardings, init_cache, prefill  # noqa: E402
from ..optim import make_optimizer  # noqa: E402
from ..optim.quantized_state import Quantized  # noqa: E402
from ..train.train_lib import make_train_step  # noqa: E402
from .hlo_analysis import analyze  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .roofline import Roofline, model_flops  # noqa: E402
from .specs import batch_shardings, input_specs  # noqa: E402


def _microbatch_for(cfg, shape, n_data: int) -> int:
    """Grad-accumulation factor bounding the per-chip per-microbatch
    activation memory — the scan carries saved for backward plus the f32
    logits/one-hot of the loss — to ~4 GiB."""
    per_chip_batch = max(shape.global_batch // n_data, 1)
    tokens_chip = per_chip_batch * shape.seq_len
    carry = tokens_chip * cfg.d_model * 2 * cfg.n_layers  # bf16 per layer
    logits = tokens_chip * (cfg.padded_vocab // 16) * 4 * 2  # f32, vocab/model
    total = carry + logits
    mb = 1
    while total / mb > 4e9 and mb < per_chip_batch:
        mb *= 2
    return mb


def _run_cfg_for(cfg, shape=None, n_data: int = 16) -> RunConfig:
    """Memory-appropriate optimizer settings per architecture scale."""
    mb = _microbatch_for(cfg, shape, n_data) if shape is not None else 1
    if cfg.param_count() > 3e11:  # 1T-class: factored states, pod-fsdp
        return RunConfig(
            optimizer="adafactor", master_dtype=None, fsdp_over_pod=True,
            microbatch=mb,
        )
    if cfg.param_count() > 1.5e10:  # 20B+: bf16 params are the master;
        # f32 moments sharded like params are ~1 GiB/chip at this scale
        return RunConfig(master_dtype=None, microbatch=mb)
    return RunConfig(microbatch=mb)


def _shard_state_like(abs_state, abs_params, p_shardings, rules: MeshRules):
    """Tree of shardings for an (abstract) optimizer state."""
    replicated = jax.sharding.NamedSharding(rules.mesh, jax.sharding.PartitionSpec())
    p_leaves, p_def = jax.tree.flatten(abs_params)
    s_leaves = jax.tree.leaves(p_shardings)
    by_shape = {}
    for pl, sl in zip(p_leaves, s_leaves):
        by_shape.setdefault(pl.shape, sl)

    def pick(leaf):
        if not hasattr(leaf, "shape") or leaf.ndim == 0:
            return replicated
        hit = by_shape.get(leaf.shape)
        if hit is not None:
            return hit
        # factored / quantized states: shard dim0 over fsdp if divisible
        spec = rules.spec(("fsdp",) + (None,) * (leaf.ndim - 1), leaf.shape)
        return jax.sharding.NamedSharding(rules.mesh, spec)

    return jax.tree.map(pick, abs_state)


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True) -> dict:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_data = mesh.shape["data"] * mesh.shape.get("pod", 1)
    run_cfg = _run_cfg_for(cfg, shape if shape.kind == "train" else None, n_data)
    # inference: replicate params over data unless they don't fit per chip
    serve_fsdp = cfg.param_count() * 2 / mesh.shape["model"] > 8e9
    fsdp = True if shape.kind == "train" else serve_fsdp
    rules = MeshRules(mesh, fsdp_over_pod=run_cfg.fsdp_over_pod, fsdp=fsdp)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    n_dev = mesh.devices.size

    t0 = time.perf_counter()
    with use_rules(rules):
        abs_params = abstract_params(cfg)
        p_sh = param_shardings(cfg, rules)
        b_specs = input_specs(cfg, shape)
        b_sh = batch_shardings(cfg, shape, rules)
        repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

        if shape.kind == "train":
            train_step, opt_init = make_train_step(cfg, run_cfg)
            abs_opt = jax.eval_shape(opt_init, abs_params)
            o_sh = _shard_state_like(abs_opt, abs_params, p_sh, rules)
            step_spec = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(
                train_step,
                in_shardings=(p_sh, o_sh, b_sh, repl),
                out_shardings=(p_sh, o_sh, repl),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(abs_params, abs_opt, b_specs, step_spec)
        elif shape.kind == "prefill":
            def fn(p, b):
                return prefill(cfg, p, b, shape.seq_len)
            abs_cache = jax.eval_shape(
                lambda: init_cache(cfg, shape.global_batch, shape.seq_len)
            )
            c_sh = cache_shardings(cfg, rules, shape.global_batch, shape.seq_len)
            logits_sh = rules.sharding(
                ("batch", "model"), (shape.global_batch, cfg.padded_vocab)
            )
            jitted = jax.jit(
                fn,
                in_shardings=(p_sh, b_sh),
                out_shardings=(logits_sh, c_sh),
            )
            lowered = jitted.lower(abs_params, b_specs)
        else:  # decode
            abs_cache = jax.eval_shape(
                lambda: init_cache(cfg, shape.global_batch, shape.seq_len)
            )
            c_sh = cache_shardings(cfg, rules, shape.global_batch, shape.seq_len)
            if cfg.family == "encdec":
                cross_sh = jax.tree.map(
                    lambda l: rules.sharding(
                        (None, "batch", "model", None, None), l.shape
                    ),
                    abs_cache["cross"],
                )
                c_sh["cross"] = cross_sh
            logits_sh = rules.sharding(
                ("batch", "model"), (shape.global_batch, cfg.padded_vocab)
            )
            def serve_step(p, t, c):
                return decode_step(cfg, p, t, c)
            jitted = jax.jit(
                serve_step,
                in_shardings=(p_sh, b_sh["tokens"], c_sh),
                out_shardings=(logits_sh, c_sh),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(abs_params, b_specs["tokens"], abs_cache)

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # trip-count-weighted analysis (XLA's cost_analysis counts while
    # bodies once; see hlo_analysis.py)
    wc = analyze(hlo, n_dev)

    flops_chip = wc.flops
    bytes_chip = wc.hbm_bytes
    arg_b = getattr(mem, "argument_size_in_bytes", 0)
    out_b = getattr(mem, "output_size_in_bytes", 0)
    tmp_b = getattr(mem, "temp_size_in_bytes", 0)
    alias = getattr(mem, "alias_size_in_bytes", 0)
    donated = shape.kind in ("train", "decode")
    # CPU memory_analysis does not account donation: on TPU the donated
    # inputs (params+opt / cache) alias the outputs, so peak ~ args+temps.
    mem_bytes = arg_b + tmp_b + (0 if donated else out_b) - alias

    rl = Roofline(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        n_devices=n_dev,
        flops_per_chip=flops_chip,
        bytes_per_chip=bytes_chip,
        coll_bytes_per_chip=wc.coll_wire_bytes,
        coll_by_kind=wc.coll_by_kind,
        model_flops_total=model_flops(cfg, shape),
        memory_per_chip_bytes=mem_bytes,
    )
    row = rl.row()
    row.update(
        {
            "status": "ok",
            "args_gb": round(arg_b / 2**30, 2),
            "out_gb": round(out_b / 2**30, 2),
            "temp_gb": round(tmp_b / 2**30, 2),
            "microbatch": run_cfg.microbatch,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "n_collectives": wc.n_collectives,
            "xla_flops_unweighted": float(cost.get("flops", 0.0)),
            "sharding_fallbacks": [str(f) for f in rules.fallbacks],
            "optimizer": run_cfg.optimizer
            + ("/int8" if run_cfg.state_dtype == "int8" else "")
            + ("/f32master" if run_cfg.master_dtype == "float32" else ""),
        }
    )
    if verbose:
        print(
            f"[{arch} x {shape_name} x {mesh_name}] OK  "
            f"mem/chip={row['memory_per_chip_gb']:.2f}GiB  "
            f"t_comp={rl.t_compute*1e3:.2f}ms t_mem={rl.t_memory*1e3:.2f}ms "
            f"t_coll={rl.t_collective*1e3:.2f}ms -> {rl.bottleneck}  "
            f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
            flush=True,
        )
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun.json")
    args = ap.parse_args()

    archs = list(configs.ARCHS) if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results if r.get("status") == "ok"}

    for arch in archs:
        cfg = configs.get(arch)
        shapes = (
            configs.applicable_shapes(cfg)
            if args.shape == "all"
            else args.shape.split(",")
        )
        for shape_name in shapes:
            if shape_name not in configs.applicable_shapes(cfg):
                print(f"[{arch} x {shape_name}] SKIPPED (inapplicable family)")
                continue
            for multi in meshes:
                mesh_name = "pod2x16x16" if multi else "pod16x16"
                if (arch, shape_name, mesh_name) in done:
                    continue
                try:
                    row = dryrun_cell(arch, shape_name, multi)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    row = {
                        "arch": arch,
                        "shape": shape_name,
                        "mesh": mesh_name,
                        "status": f"FAIL: {type(e).__name__}: {e}",
                    }
                results = [
                    r
                    for r in results
                    if (r["arch"], r["shape"], r["mesh"]) != (arch, shape_name, mesh_name)
                ] + [row]
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1, default=str)

    n_ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"\n{n_ok}/{len(results)} cells OK -> {args.out}")


if __name__ == "__main__":
    main()
