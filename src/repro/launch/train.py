"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --seq-len 128 --batch 8 [--smoke] [--mesh single|multi|none]

On the CPU harness use --smoke (reduced config, no mesh).  On a real
TPU fleet, drop --smoke: the launcher builds the production mesh, shards
params/optimizer/batches per the rules, and runs the fault-tolerant
Trainer (async checkpoints, crash recovery, deterministic data resume).
"""

from __future__ import annotations

import argparse
import logging

import jax

from .. import configs
from ..configs.base import RunConfig
from ..data.pipeline import DataConfig, Pipeline
from ..distributed import MeshRules, use_rules
from ..models import init_params, param_shardings
from ..train.train_lib import Trainer, make_train_step
from .mesh import make_production_mesh


def main() -> None:
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="none", choices=["none", "single", "multi"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--microbatch", type=int, default=1)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    run_cfg = RunConfig(
        learning_rate=args.lr,
        checkpoint_dir=args.ckpt_dir,
        checkpoint_every=args.ckpt_every,
        microbatch=args.microbatch,
        master_dtype=None if cfg.param_count() > 1.5e10 else "float32",
    )
    pipe = Pipeline(
        DataConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq_len, global_batch=args.batch
        )
    )
    rules = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        rules = MeshRules(mesh)

    with use_rules(rules):
        step_fn, opt_init = make_train_step(cfg, run_cfg)
        if rules is not None:
            p_sh = param_shardings(cfg, rules)
            jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
            def init_fn():
                return jax.jit(
                    lambda k: init_params(cfg, k), out_shardings=p_sh
                )(jax.random.PRNGKey(0))
        else:
            jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
            def init_fn():
                return init_params(cfg, jax.random.PRNGKey(0))

        trainer = Trainer.resume_or_init(cfg, run_cfg, pipe, init_fn, jit_step, opt_init)
        print(
            f"training {cfg.name}: {cfg.param_count():,} params, "
            f"resuming at step {trainer.step}"
        )
        metrics = trainer.run(args.steps)
        print(f"done at step {trainer.step}: {metrics}")


if __name__ == "__main__":
    main()
