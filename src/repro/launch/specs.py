"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

Everything the dry-run lowers against — params, batches, caches — is
abstract: weak-type-correct, shardable, zero device allocation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import applicable_shapes
from ..configs.base import ArchConfig, ShapeConfig
from ..distributed import MeshRules
from ..models import init_cache


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Abstract inputs for one cell (excluding params/cache)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(n):
        return jax.ShapeDtypeStruct((b, n), i32)

    if shape.kind == "train":
        batch = {"tokens": tok(s), "labels": tok(s)}
        if cfg.family == "vlm":
            # image tokens replace a prefix of the sequence budget
            batch = {
                "tokens": tok(s - cfg.vision_tokens),
                "labels": tok(s - cfg.vision_tokens),
                "img_embeds": jax.ShapeDtypeStruct(
                    (b, cfg.vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
                ),
            }
        if cfg.family == "encdec":
            batch["enc_frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        return batch

    if shape.kind == "prefill":
        batch = {"tokens": tok(s)}
        if cfg.family == "vlm":
            batch = {
                "tokens": tok(s - cfg.vision_tokens),
                "img_embeds": jax.ShapeDtypeStruct(
                    (b, cfg.vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
                ),
            }
        if cfg.family == "encdec":
            batch["enc_frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        return batch

    # decode: one new token against a seq_len-deep cache
    return {"tokens": tok(1)}


def batch_shardings(cfg: ArchConfig, shape: ShapeConfig, rules: MeshRules) -> dict:
    specs = input_specs(cfg, shape)

    def shard(leaf):
        axes = ("batch",) + (None,) * (leaf.ndim - 1)
        return rules.sharding(axes, leaf.shape)

    return jax.tree.map(shard, specs)


def abstract_cache(cfg: ArchConfig, shape: ShapeConfig):
    return init_cache(cfg, shape.global_batch, shape.seq_len, abstract=True)


def cell_names(cfg: ArchConfig) -> list[str]:
    return applicable_shapes(cfg)
