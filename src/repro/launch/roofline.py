"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e targets):

    compute    = FLOPs_per_chip   / 197e12   (bf16 MXU peak)
    memory     = bytes_per_chip   / 819e9    (HBM bandwidth)
    collective = coll_bytes_chip  / 50e9     (ICI, per-link)

``compiled.cost_analysis()`` reports the post-SPMD per-partition module,
i.e. per-chip FLOPs / bytes.  Collective bytes are NOT in cost_analysis:
we parse the optimized HLO text and sum wire traffic per collective op
(result shapes are per-partition):

    all-reduce         2 x size          (ring: reduce-scatter+all-gather)
    all-gather         size x (G-1)/G    (result is the gathered buffer)
    reduce-scatter     size x (G-1)      (input = G x result)
    all-to-all         size x (G-1)/G
    collective-permute size

MODEL_FLOPS uses 6*N*D (train) or 2*N*D (inference) with N = active
params, D = tokens; the ratio MODEL_FLOPS / HLO_FLOPs exposes remat and
redundant-compute overhead.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?:[a-z0-9_]+)\[[^\]]*\][^ ]*)?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c64|c128)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(line: str) -> int:
    """Total bytes of the instruction's result (left of the op name)."""
    lhs = line.split("=", 1)[1]
    # result shape(s) appear before the op name token
    for op in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute"):
        idx = lhs.find(op)
        if idx >= 0:
            lhs = lhs[:idx]
            break
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(lhs))


def _group_size(line: str, default: int) -> int:
    m = _IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    by_kind: dict = field(default_factory=dict)
    count: int = 0

    def add(self, kind: str, b: float):
        self.wire_bytes += b
        self.by_kind[kind] = self.by_kind.get(kind, 0.0) + b
        self.count += 1


def collective_bytes(hlo_text: str, n_devices: int) -> CollectiveStats:
    """Per-chip wire bytes summed over every collective in the module."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-start" in line and ("-done" in hlo_text):
            pass  # async pairs: count the -start only (done carries no shape)
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        if "-done" in line.split("=")[0] if "=" in line else False:
            continue
        rb = _result_bytes(line)
        if rb == 0:
            continue
        g = _group_size(line, n_devices)
        frac = (g - 1) / g if g > 1 else 0.0
        if kind == "all-reduce":
            stats.add(kind, 2.0 * rb * frac)
        elif kind == "all-gather":
            stats.add(kind, rb * frac)
        elif kind == "reduce-scatter":
            stats.add(kind, rb * (g - 1))
        elif kind == "all-to-all":
            stats.add(kind, rb * frac)
        else:  # collective-permute
            stats.add(kind, float(rb))
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_by_kind: dict
    model_flops_total: float
    memory_per_chip_bytes: float  # from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs (remat/redundancy waste)."""
        total_hlo = self.flops_per_chip * self.n_devices
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def mfu_bound(self) -> float:
        """Upper bound on MFU implied by the dominant roofline term."""
        t = self.t_bound
        if t == 0:
            return 0.0
        return self.model_flops_total / (self.n_devices * PEAK_FLOPS * t)

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops_total,
            "hlo_flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "coll_by_kind": self.coll_by_kind,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu_bound": self.mfu_bound,
            "memory_per_chip_gb": self.memory_per_chip_bytes / 2**30,
        }


def model_flops(cfg, shape) -> float:
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch  # decode: one token per sequence
