"""Trip-count-weighted cost analysis of optimized HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts every ``while`` body
exactly once — for scan-over-layers / microbatch-accumulation programs
that under-reports FLOPs, bytes and collectives by the product of trip
counts (~350x for a 64-layer, 16-microbatch train step).  Post-
optimization HLO carries ``backend_config={"known_trip_count":{"n":..}}``
on while ops, so an exact weighting is recoverable from the text.

This module parses the module into computations, walks the call graph
from ENTRY multiplying by trip counts, and accumulates:

  * flops            — 2 * prod(lhs_shape) * prod(rhs_free) per dot
                       (plus convolutions), weighted by trips;
  * coll_wire_bytes  — per-chip wire traffic per collective kind, using
                       the same ring-cost model as roofline.py;
  * hbm_bytes        — HBM traffic proxy: every walked instruction
                       contributes its result bytes (one write) plus its
                       operand bytes (one read per consumer).  Fusion
                       internals are excluded (they live in registers /
                       VMEM); fusion parameters/results are the buffer
                       edges that actually hit memory.

Everything is per-chip: the post-SPMD module is the per-partition
program.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"([a-z][\w\-]*)\(")
_CALLED_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRUE_FALSE_RE = re.compile(r"(?:true_computation|false_computation)=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BDIMS_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_RCDIMS_RE = re.compile(r"rhs_contracting_dims=\{([0-9,]*)\}")
_RBDIMS_RE = re.compile(r"rhs_batch_dims=\{([0-9,]*)\}")

_SKIP_BYTES = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "iota", "after-all", "partition-id", "replica-id",
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _first_shapes_bytes(text: str) -> int:
    return sum(
        _shape_elems(dims) * _DTYPE_BYTES[dt] for dt, dims in _SHAPE_RE.findall(text)
    )


@dataclass
class Instr:
    name: str
    opcode: str
    result_text: str
    operands: list[str]
    line: str
    is_root: bool = False
    param_idx: int = -1


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    table: dict = field(default_factory=dict)  # var -> result_text


def parse_module(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        if s.startswith(("HloModule",)):
            continue
        if (s.startswith("%") or s.startswith("ENTRY")) and s.endswith("{"):
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", s)
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            if s.startswith("ENTRY"):
                comps["__entry__"] = cur
            continue
        if s == "}" or s.startswith("}"):
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        rhs = m.group(3)
        op_m = _OPCODE_RE.search(rhs)
        if not op_m:
            continue
        opcode = op_m.group(1)
        result_text = rhs[: op_m.start()]
        # operands: first (...) group after the opcode
        rest = rhs[op_m.end() - 1 :]
        ops_m = _OPERANDS_RE.match(rest)
        operands = []
        if ops_m:
            for tok in ops_m.group(1).split(","):
                tok = tok.strip()
                if "%" in tok:
                    # older XLA print options inline operand shapes
                    # ("f32[512,1024]{1,0} %param"); commas inside the
                    # shape split it into junk pieces, but exactly one
                    # piece carries the %name.
                    operands.extend(re.findall(r"%([\w.\-]+)", tok))
                elif re.match(r"^[\w.\-]+$", tok) and not tok[0].isdigit():
                    operands.append(tok)
        name = m.group(2)
        instr = Instr(name, opcode, result_text, operands, s, is_root=bool(m.group(1)))
        if opcode == "parameter":
            pm = re.search(r"parameter\((\d+)\)", s)
            if pm:
                instr.param_idx = int(pm.group(1))
        cur.instrs.append(instr)
        cur.table[name] = result_text
    return comps


@dataclass
class Costs:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_wire_bytes: float = 0.0
    coll_by_kind: dict = field(default_factory=dict)
    n_collectives: int = 0

    def add_coll(self, kind: str, b: float, mult: float):
        self.coll_wire_bytes += b * mult
        self.coll_by_kind[kind] = self.coll_by_kind.get(kind, 0.0) + b * mult
        self.n_collectives += 1


def _dot_flops(instr: Instr, comp: Computation) -> float:
    if not instr.operands:
        return 0.0
    lhs = comp.table.get(instr.operands[0], "")
    rhs = comp.table.get(instr.operands[1], "") if len(instr.operands) > 1 else ""
    lhs_shapes = _SHAPE_RE.findall(lhs)
    rhs_shapes = _SHAPE_RE.findall(rhs)
    if not lhs_shapes or not rhs_shapes:
        return 0.0
    lhs_dims = [int(d) for d in lhs_shapes[0][1].split(",") if d]
    rhs_dims = [int(d) for d in rhs_shapes[0][1].split(",") if d]
    cd = {int(x) for x in _RCDIMS_RE.search(instr.line).group(1).split(",") if x} if _RCDIMS_RE.search(instr.line) else set()
    bd = {int(x) for x in _RBDIMS_RE.search(instr.line).group(1).split(",") if x} if _RBDIMS_RE.search(instr.line) else set()
    lhs_total = 1
    for d in lhs_dims:
        lhs_total *= d
    rhs_free = 1
    for i, d in enumerate(rhs_dims):
        if i not in cd and i not in bd:
            rhs_free *= d
    return 2.0 * lhs_total * rhs_free


def _conv_flops(instr: Instr, comp: Computation) -> float:
    # 2 * output_elems * (kernel spatial x in_channels) — approximate via
    # operand/result shapes: flops = 2 * out_elems * prod(kernel)/out_feat
    if len(instr.operands) < 2:
        return 0.0
    ker = comp.table.get(instr.operands[1], "")
    ker_shapes = _SHAPE_RE.findall(ker)
    if not ker_shapes:
        return 0.0
    ker_elems = _shape_elems(ker_shapes[0][1])
    out_shapes = _SHAPE_RE.findall(instr.result_text)
    out_elems = _shape_elems(out_shapes[0][1]) if out_shapes else 0
    # assume last kernel dim is out-features
    ker_dims = [int(d) for d in ker_shapes[0][1].split(",") if d]
    out_feat = ker_dims[-1] if ker_dims else 1
    return 2.0 * out_elems * (ker_elems / max(out_feat, 1))


def _fusion_bytes(ins: Instr, comp: Computation, fc: Computation | None) -> float:
    """HBM traffic of one fusion: result write + operand reads, with
    window-access repair — an operand whose only internal consumers are
    (dynamic-)slice/gather ops is read only through those windows, and a
    root dynamic-update-slice writes only its update window (the rest of
    the buffer aliases in place)."""
    result_b = _first_shapes_bytes(ins.result_text)
    if fc is None:
        return result_b + sum(
            _first_shapes_bytes(comp.table.get(o, "")) for o in ins.operands
        )
    params = {i.param_idx: i.name for i in fc.instrs if i.opcode == "parameter"}
    consumers: dict[str, list[Instr]] = {}
    for fi in fc.instrs:
        for o in fi.operands:
            consumers.setdefault(o, []).append(fi)
    root = next((i for i in fc.instrs if i.is_root), None)

    total = 0.0
    for idx, oname in enumerate(ins.operands):
        full = _first_shapes_bytes(comp.table.get(oname, ""))
        pname = params.get(idx)
        cons = consumers.get(pname, []) if pname else []
        if not cons:
            total += full
            continue
        # per-consumer window accounting: (dynamic-)slice/gather reads only
        # its window; a root dynamic-update-slice destination aliases in
        # place (loop-carried caches) and costs nothing beyond the update
        # write; any other consumer reads the full buffer.
        acc = 0.0
        for c in cons:
            if c.opcode in ("dynamic-slice", "slice", "gather"):
                acc += _first_shapes_bytes(c.result_text)
            elif (
                c is root
                and root.opcode == "dynamic-update-slice"
                and root.operands
                and root.operands[0] == pname
            ):
                pass
            else:
                acc = full
                break
        total += min(acc, full)
    if root is not None and root.opcode == "dynamic-update-slice" and len(root.operands) > 1:
        total += _first_shapes_bytes(fc.table.get(root.operands[1], ""))
    else:
        total += result_b
    return total


def _group_size(line: str, default: int) -> int:
    m = _IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _walk(comps, name: str, mult: float, costs: Costs, n_devices: int, flops_only: bool):
    comp = comps.get(name)
    if comp is None:
        return
    for ins in comp.instrs:
        op = ins.opcode
        if op == "while":
            trips = 1
            m = _TRIP_RE.search(ins.line)
            if m:
                trips = int(m.group(1))
            body = None
            bm = re.search(r"body=%?([\w.\-]+)", ins.line)
            if bm:
                body = bm.group(1)
            if body:
                _walk(comps, body, mult * trips, costs, n_devices, flops_only)
            continue
        if op == "conditional":
            branches = _COND_BRANCHES_RE.search(ins.line)
            names = []
            if branches:
                names = [b.strip().lstrip("%") for b in branches.group(1).split(",")]
            else:
                names = _TRUE_FALSE_RE.findall(ins.line)
            for b in names:
                _walk(comps, b, mult, costs, n_devices, flops_only)
            continue
        if op in ("call", "async-start"):
            m = _CALLED_RE.search(ins.line)
            if m:
                _walk(comps, m.group(1), mult, costs, n_devices, flops_only)

        if op == "fusion":
            m = _CALLED_RE.search(ins.line)
            if m:
                # fusion internals: flops only (buffers stay on-chip)
                _walk(comps, m.group(1), mult, costs, n_devices, True)
        elif op == "dot":
            costs.flops += _dot_flops(ins, comp) * mult
        elif op == "convolution":
            costs.flops += _conv_flops(ins, comp) * mult

        is_coll = None
        for c in COLLECTIVES:
            if op == c or op == c + "-start":
                is_coll = c
                break
        if is_coll and not flops_only:
            rb = _first_shapes_bytes(ins.result_text)
            g = _group_size(ins.line, n_devices)
            frac = (g - 1) / g if g > 1 else 0.0
            if is_coll == "all-reduce":
                costs.add_coll(is_coll, 2.0 * rb * frac, mult)
            elif is_coll == "all-gather":
                costs.add_coll(is_coll, rb * frac, mult)
            elif is_coll == "reduce-scatter":
                costs.add_coll(is_coll, rb * (g - 1), mult)
            elif is_coll == "all-to-all":
                costs.add_coll(is_coll, rb * frac, mult)
            else:
                costs.add_coll(is_coll, float(rb), mult)

        if not flops_only and op not in _SKIP_BYTES and not op.endswith("-done"):
            rb = _first_shapes_bytes(ins.result_text)
            if op == "fusion":
                m = _CALLED_RE.search(ins.line)
                fc = comps.get(m.group(1)) if m else None
                costs.hbm_bytes += _fusion_bytes(ins, comp, fc) * mult
            elif op == "dynamic-update-slice":
                # in-place: traffic = read + write of the update window only
                upd = _first_shapes_bytes(comp.table.get(ins.operands[1], "")) if len(ins.operands) > 1 else 0
                costs.hbm_bytes += 2.0 * upd * mult
            elif op in ("dynamic-slice", "slice", "gather", "broadcast", "reshape",
                        "transpose", "copy", "reverse", "concatenate", "pad"):
                # data-movement ops: read + write of the (smaller) result
                costs.hbm_bytes += 2.0 * rb * mult
            elif op == "scatter":
                upd = _first_shapes_bytes(comp.table.get(ins.operands[-1], "")) if ins.operands else 0
                costs.hbm_bytes += (2.0 * upd + rb * 0) * mult
            else:
                ob = sum(
                    _first_shapes_bytes(comp.table.get(o, "")) for o in ins.operands
                )
                costs.hbm_bytes += (rb + ob) * mult


def analyze(hlo_text: str, n_devices: int) -> Costs:
    comps = parse_module(hlo_text)
    costs = Costs()
    entry = comps.get("__entry__")
    if entry is None:
        return costs
    _walk(comps, entry.name, 1.0, costs, n_devices, False)
    return costs
