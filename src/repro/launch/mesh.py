"""Production mesh definition.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries data parallelism across the DCN/ICI-superpod boundary
(and optionally FSDP for the 1T-parameter cells via fsdp_over_pod).

Defined as a function, not a module constant: importing this module must
never touch jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init).
"""

from __future__ import annotations

import jax


def _make_auto_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis types where supported.

    ``jax.sharding.AxisType`` appeared after 0.4.x; on older versions
    every axis is implicitly auto-sharded, so omitting the kwarg is
    semantically identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_auto_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU multi-device tests (device count forced by the
    test harness via subprocess)."""
    return _make_auto_mesh((data, model), ("data", "model"))
