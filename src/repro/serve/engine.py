"""Batched serving engine: prefill + decode with slot management.

A static-batch continuous-batching-lite engine: requests occupy slots;
finished slots (EOS or max tokens) are refilled from the queue between
decode steps.  Both phases are jitted once per shape; the KV cache is
preallocated to ``max_seq`` and sharded per the mesh rules.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import decode_step
from ..models.transformer import prefill


@dataclass
class Request:
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int = 32
    out_tokens: list = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(
        self,
        cfg: ArchConfig,
        params,
        batch_size: int,
        max_seq: int,
        eos_id: int = 1,
        sample: str = "greedy",
        temperature: float = 1.0,
        extra_inputs: dict | None = None,
    ):
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.sample = sample
        self.temperature = temperature
        self.extra_inputs = extra_inputs or {}
        self._prefill = jax.jit(
            lambda p, b: prefill(cfg, p, b, max_seq), static_argnums=()
        )
        self._decode = jax.jit(lambda p, t, c: decode_step(cfg, p, t, c))
        self.key = jax.random.PRNGKey(0)

    def _pick(self, logits: jnp.ndarray) -> jnp.ndarray:
        if self.sample == "greedy":
            return jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / self.temperature, axis=-1)

    def generate(self, requests: list[Request]) -> list[Request]:
        """Serve a list of requests with a fixed prompt length per batch."""
        assert len(requests) <= self.batch_size
        while len(requests) < self.batch_size:
            requests.append(Request(requests[0].prompt, 0, done=True))
        prompts = np.stack([r.prompt for r in requests])
        batch = {"tokens": jnp.asarray(prompts)}
        batch.update(self.extra_inputs)
        logits, cache = self._prefill(self.params, batch)
        tok = self._pick(logits)
        budget = max(r.max_new_tokens for r in requests)
        for r, t in zip(requests, np.asarray(tok)):
            if not r.done:
                r.out_tokens.append(int(t))
        for _ in range(budget - 1):
            logits, cache = self._decode(self.params, tok[:, None], cache)
            tok = self._pick(logits)
            alive = False
            for r, t in zip(requests, np.asarray(tok)):
                if r.done or len(r.out_tokens) >= r.max_new_tokens:
                    r.done = True
                    continue
                if r.out_tokens and r.out_tokens[-1] == self.eos_id:
                    r.done = True
                    continue
                r.out_tokens.append(int(t))
                alive = True
            if not alive:
                break
        return requests
