"""Deterministic, shard-aware, resumable data pipeline.

The batch for global step ``s`` is a pure function of (seed, s): restart
from any checkpoint reproduces the exact token stream with no iterator
state to persist — the checkpoint's step counter IS the data cursor.
This is the fault-tolerance contract the trainer relies on.

Two sources:
  * synthetic: order-k Markov token chains (fast, endless; gives a real
    learnable signal so loss curves are meaningful);
  * corpus: a memory-mapped token array sampled at deterministic offsets.

Sharding: each data-parallel rank materialises only its slice
(``host_batch``); under jit the global batch is assembled by
``jax.make_array_from_process_local_data`` or sharded host puts.  On the
single-process CPU harness the full batch is returned directly.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"  # synthetic | corpus
    corpus_path: str | None = None
    markov_order: int = 2


class Pipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.source == "corpus":
            if not cfg.corpus_path:
                raise ValueError("corpus source needs corpus_path")
            self.corpus = np.memmap(cfg.corpus_path, dtype=np.int32, mode="r")
        else:
            # fixed random transition structure for the Markov chain
            rng = np.random.default_rng(cfg.seed)
            self._trans = rng.integers(
                0, cfg.vocab_size, size=(min(cfg.vocab_size, 4096), 4), dtype=np.int64
            )

    # ------------------------------------------------------------------
    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        """Batch (tokens, labels) for a global step; pure in (step, shard)."""
        cfg = self.cfg
        per = cfg.global_batch // n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, shard, 0xDA4])
        )
        if cfg.source == "corpus":
            max_start = self.corpus.size - cfg.seq_len - 1
            starts = rng.integers(0, max_start, size=per)
            toks = np.stack(
                [self.corpus[s : s + cfg.seq_len + 1] for s in starts]
            ).astype(np.int32)
        else:
            toks = self._markov(rng, per, cfg.seq_len + 1)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def _markov(self, rng, batch: int, length: int) -> np.ndarray:
        cfg = self.cfg
        n_states = self._trans.shape[0]
        out = np.empty((batch, length), dtype=np.int64)
        state = rng.integers(0, n_states, size=batch)
        noise = rng.random((batch, length))
        choices = rng.integers(0, 4, size=(batch, length))
        rand_tok = rng.integers(0, cfg.vocab_size, size=(batch, length))
        for t in range(length):
            nxt = self._trans[state % n_states, choices[:, t]]
            tok = np.where(noise[:, t] < 0.1, rand_tok[:, t], nxt)
            out[:, t] = tok
            state = tok
        return out
