from .sharding import MeshRules, current_rules, constrain, use_rules

__all__ = ["MeshRules", "constrain", "current_rules", "use_rules"]
