"""Logical-axis sharding rules for the production mesh.

Mesh axes: ``("data", "model")`` single-pod (16x16 = 256 chips) or
``("pod", "data", "model")`` multi-pod (2x16x16 = 512).  Model code
annotates tensors with *logical* tokens; the rules resolve them to mesh
axes with divisibility fallback (a dim that does not divide its mesh axes
is silently left unsharded and recorded in ``fallbacks`` for the dry-run
report — e.g. smollm's 9 query heads on a 16-way model axis).

Logical tokens:
    batch    -> ("pod", "data")            (whichever exist in the mesh)
    fsdp     -> ("data",) or ("pod","data") (param sharding / ZeRO-3)
    model    -> "model"                     (tensor parallel)
    seq      -> "model" when sequence parallelism is on, else None
    None     -> unsharded
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass
class MeshRules:
    mesh: Mesh
    fsdp_over_pod: bool = False
    seq_shard: bool = False
    fsdp: bool = True  # False: replicate params over data (small-model serving)
    fallbacks: list = field(default_factory=list)

    def axes_for(self, token: str | None):
        names = self.mesh.axis_names
        if token is None:
            return ()
        if token == "batch":
            return tuple(a for a in ("pod", "data") if a in names)
        if token == "fsdp":
            if not self.fsdp:
                return ()
            if self.fsdp_over_pod and "pod" in names:
                return ("pod", "data")
            return ("data",) if "data" in names else ()
        if token == "model":
            return ("model",) if "model" in names else ()
        if token == "seq":
            return ("model",) if (self.seq_shard and "model" in names) else ()
        raise ValueError(f"unknown logical axis {token!r}")

    def _axis_size(self, axes) -> int:
        return int(np.prod([self.mesh.shape[a] for a in axes], initial=1))

    def spec(self, tokens, shape=None) -> P:
        """PartitionSpec for logical tokens, dropping non-divisible dims."""
        parts = []
        used: set[str] = set()
        for i, tok in enumerate(tokens):
            axes = tuple(a for a in self.axes_for(tok) if a not in used)
            if not axes:
                parts.append(None)
                continue
            if shape is not None and shape[i] % self._axis_size(axes):
                # try trailing sub-tuples (e.g. batch=("pod","data")->("data",))
                ok = ()
                for k in range(1, len(axes)):
                    sub = axes[k:]
                    if shape[i] % self._axis_size(sub) == 0:
                        ok = sub
                        break
                if not ok:
                    self.fallbacks.append((tokens, i, tok, None if shape is None else shape[i]))
                parts.append(ok if len(ok) != 1 else ok[0])
                used.update(ok)
                continue
            used.update(axes)
            parts.append(axes if len(axes) != 1 else axes[0])
        parts = [None if p == () else p for p in parts]
        return P(*parts)

    def sharding(self, tokens, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(tokens, shape))

    def constrain(self, x, *tokens):
        return jax.lax.with_sharding_constraint(x, self.sharding(tokens, x.shape))


_local = threading.local()


def current_rules() -> MeshRules | None:
    return getattr(_local, "rules", None)


@contextlib.contextmanager
def use_rules(rules: MeshRules | None):
    prev = current_rules()
    _local.rules = rules
    try:
        yield rules
    finally:
        _local.rules = prev


def constrain(x, *tokens):
    """Apply a logical sharding constraint if a mesh is active (no-op on
    single-device smoke tests)."""
    rules = current_rules()
    if rules is None:
        return x
    return rules.constrain(x, *tokens)


def axis_size(token: str) -> int:
    """Mesh extent of a logical axis (1 when no mesh is active)."""
    rules = current_rules()
    if rules is None:
        return 1
    return rules._axis_size(rules.axes_for(token))


def gathered(w, *axes):
    """FSDP weight-gather hint: constrain a parameter to its compute
    layout (fsdp dim unsharded) right before use.  Without it the SPMD
    partitioner often keeps weights 2-D-sharded and all-reduces
    activation-sized partial sums instead — weights are orders of
    magnitude smaller than activations at LM batch sizes."""
    return constrain(w, *axes)
