"""Shared LM building blocks: RMSNorm, RoPE, SwiGLU, embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed import constrain


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: [B, S, H, D]; positions: [B, S] or [S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    ).astype(dt)


def swiglu(x: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    from ..distributed.sharding import gathered

    g = constrain(x @ gathered(w_gate, None, "model"), "batch", "seq", "model")
    u = constrain(x @ gathered(w_up, None, "model"), "batch", "seq", "model")
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return constrain(h @ gathered(w_down, "model", None), "batch", "seq", None)


def embed_tokens(embedding: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    out = jnp.take(embedding, tokens, axis=0)
    return constrain(out, "batch", "seq", None)


def unembed(x: jnp.ndarray, head: jnp.ndarray) -> jnp.ndarray:
    logits = x @ head
    return constrain(logits, "batch", "seq", "model")
