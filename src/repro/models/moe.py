"""Top-k token-choice MoE with shard-local capacity dispatch.

Dispatch is expressed with an explicit leading shard dimension: tokens
[T, D] are viewed as [n_shards, T_local, D] (dim 0 laid out on the data
axes), every shard routes its own tokens with a *local* capacity
C = cf * T_local * k / E, and expert buffers are [n, E, C, D] sharded
(data, model, -, -).  Under SPMD this lowers to the canonical
all-to-all on the model axis, and — critically — no global-capacity
buffer ever exists: per-chip dispatch memory is C_local * E/model * D.
Rank computation is a per-shard stable sort (no [T, E] one-hot matrix).

Over-capacity tokens are dropped (Switch/GShard semantics); the Switch
load-balance auxiliary loss is returned for training.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed import constrain
from ..distributed.sharding import axis_size


def moe_block(cfg, p: dict, x: jnp.ndarray):
    """x: [B, S, D] -> (y [B, S, D], aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    t = b * s
    n = axis_size("batch")
    if t % n or n < 1:
        n = 1
    tl = t // n
    xt = constrain(x.reshape(n, tl, d), "batch", None, None)

    logits = (
        jnp.einsum("ntd,de->nte", xt, p["router"].astype(xt.dtype))
    ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # [n, tl, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch load-balance loss: E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))
    oh = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # [n, tl, k, E]
    ce = oh.sum(axis=(0, 1, 2)) / (t * k)
    aux = e * jnp.sum(me * ce)

    capacity = max(int(cfg.capacity_factor * tl * k / e), 1)
    capacity = -(-capacity // 8) * 8

    # shard-local slot assignment via stable sort by expert id
    flat_e = gate_idx.transpose(0, 2, 1).reshape(n, k * tl)  # slot-major
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    rows = jnp.arange(n)[:, None]
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    ar = jnp.broadcast_to(jnp.arange(k * tl, dtype=jnp.int32), (n, k * tl))
    seg_start = jnp.concatenate(
        [jnp.ones((n, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1
    )
    seg_origin = jax.lax.cummax(jnp.where(seg_start, ar, 0), axis=1)
    ranks_sorted = ar - seg_origin
    ranks = jnp.zeros((n, k * tl), jnp.int32).at[rows, order].set(ranks_sorted)

    keep = ranks < capacity
    slot = jnp.where(keep, ranks, 0)
    tok_idx = jnp.broadcast_to(
        jnp.tile(jnp.arange(tl, dtype=jnp.int32), k), (n, k * tl)
    )

    # SPMD-friendly dispatch: every scatter/gather runs along ONE
    # unsharded flat axis (E*C) with batch-sharded indices — the
    # partitioner keeps them fully shard-local.  Cross-shard indexing
    # (ye[rows, flat_e, slot] with a model-sharded expert axis) would
    # make XLA replicate the operand over both axes and emit full-size
    # all-reduces (measured: 48 TB/chip/step wire on qwen3-moe train).
    dest = flat_e * capacity + slot  # [n, k*tl] in [0, E*C)
    xg = jnp.take_along_axis(xt, tok_idx[..., None], axis=1)  # local gather
    contrib = jnp.where(keep[..., None], xg, 0)
    # vmap of a 1-D scatter lowers with operand_batching_dims, letting the
    # partitioner keep the whole scatter (and its transpose in backward)
    # parallel over the batch-sharded dim 0; `.at[rows, dest]` would not.
    scatter1 = jax.vmap(lambda buf, i, u: buf.at[i].add(u))
    xe_flat = scatter1(jnp.zeros((n, e * capacity, d), xt.dtype), dest, contrib)
    # per data-shard the full [E, C, D] buffer exists; slicing E onto the
    # model axis is communication-free (it was replicated across model)
    xe = constrain(xe_flat.reshape(n, e, capacity, d), "batch", "model", None, None)

    # expert MLPs (SwiGLU), batched over E; E stays model-sharded and the
    # fsdp dim of the expert weights is gathered before use
    from ..distributed.sharding import gathered

    wg = gathered(p["w_gate"], "model", None, None)
    wu = gathered(p["w_up"], "model", None, None)
    wd = gathered(p["w_down"], "model", None, None)
    g = constrain(jnp.einsum("necd,edf->necf", xe, wg), "batch", "model", None, None)
    u = constrain(jnp.einsum("necd,edf->necf", xe, wu), "batch", "model", None, None)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xe.dtype) * u
    ye = constrain(jnp.einsum("necf,efd->necd", h, wd), "batch", "model", None, None)

    # combine: all-gather the expert outputs over the model axis (the one
    # real collective of the block: E*C*D bf16 per data row), then gather
    # and weight locally
    ye_flat = constrain(ye.reshape(n, e * capacity, d), "batch", None, None)
    out = jnp.take_along_axis(ye_flat, dest[..., None], axis=1)  # local
    out = jnp.where(keep[..., None], out, 0)
    w = gate_vals.transpose(0, 2, 1).reshape(n, k * tl)[..., None].astype(out.dtype)
    yt = scatter1(jnp.zeros((n, tl, d), out.dtype), tok_idx, out * w)
    y = yt.reshape(b, s, d)
    return constrain(y, "batch", "seq", None), aux
