from .transformer import (
    abstract_params,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    param_shardings,
    param_specs,
)

__all__ = [
    "abstract_params",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "param_shardings",
    "param_specs",
]
