"""Unified LM stack: dense / MoE / SSM / hybrid decoders, encoder-decoder
(whisper), and VLM (frontend-stub) variants, built for pjit/shard_map.

Layer stacks are scanned over *periods* (see configs.base.layer_pattern):
all parameters of one period position are stacked with a leading
``n_periods`` dimension, so HLO size is O(period length) regardless of
depth, and XLA overlaps the per-layer FSDP all-gathers with compute
across scan iterations.  Rematerialisation wraps the period body.

Params are declared via ``param_specs`` (shape + logical sharding axes +
init), so the dry-run can lower against ``ShapeDtypeStruct`` params with
exact shardings and never allocates memory.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ATTN, MLP, MOE, SSM, ArchConfig
from ..distributed import MeshRules, constrain
from .attention import attention_block, precompute_cross_cache
from .layers import embed_tokens, rmsnorm, swiglu, unembed
from .moe import moe_block
from .ssm import mamba_block


@dataclass(frozen=True)
class PSpec:
    shape: tuple
    axes: tuple  # logical sharding tokens per dim
    init: str = "normal"  # normal | zeros | ones
    fan_in_axis: int | None = None  # for 1/sqrt(fan_in) scaling


# ----------------------------------------------------------------------
# Parameter specs
# ----------------------------------------------------------------------
def _attn_specs(cfg: ArchConfig, periods: int) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = (periods,)
    s = {
        "wq": PSpec(p + (d, hq * hd), (None, "fsdp", "model"), fan_in_axis=1),
        "wk": PSpec(p + (d, hkv * hd), (None, "fsdp", "model"), fan_in_axis=1),
        "wv": PSpec(p + (d, hkv * hd), (None, "fsdp", "model"), fan_in_axis=1),
        "wo": PSpec(p + (hq * hd, d), (None, "model", "fsdp"), fan_in_axis=1),
    }
    if cfg.qk_norm:
        s["q_norm"] = PSpec(p + (hd,), (None, None), "ones")
        s["k_norm"] = PSpec(p + (hd,), (None, None), "ones")
    return s


def _ssm_specs(cfg: ArchConfig, periods: int) -> dict:
    d, di, st, k, dtr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv, cfg.dt_rank
    p = (periods,)
    return {
        "in_proj": PSpec(p + (d, 2 * di), (None, "fsdp", "model"), fan_in_axis=1),
        "conv": PSpec(p + (di, k), (None, "model", None), fan_in_axis=2),
        "x_proj": PSpec(p + (di, dtr + 2 * st), (None, "model", None), fan_in_axis=1),
        "dt_proj": PSpec(p + (dtr, di), (None, None, "model"), fan_in_axis=1),
        "dt_bias": PSpec(p + (di,), (None, "model"), "zeros"),
        "a_log": PSpec(p + (di, st), (None, "model", None), "ssm_a"),
        "d": PSpec(p + (di,), (None, "model"), "ones"),
        "out_proj": PSpec(p + (di, d), (None, "model", "fsdp"), fan_in_axis=1),
    }


def _mlp_specs(cfg: ArchConfig, periods: int) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    p = (periods,)
    return {
        "w_gate": PSpec(p + (d, f), (None, "fsdp", "model"), fan_in_axis=1),
        "w_up": PSpec(p + (d, f), (None, "fsdp", "model"), fan_in_axis=1),
        "w_down": PSpec(p + (f, d), (None, "model", "fsdp"), fan_in_axis=1),
    }


def _moe_specs(cfg: ArchConfig, periods: int) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = (periods,)
    return {
        "router": PSpec(p + (d, e), (None, "fsdp", None), fan_in_axis=1),
        "w_gate": PSpec(p + (e, d, f), (None, "model", "fsdp", None), fan_in_axis=2),
        "w_up": PSpec(p + (e, d, f), (None, "model", "fsdp", None), fan_in_axis=2),
        "w_down": PSpec(p + (e, f, d), (None, "model", None, "fsdp"), fan_in_axis=2),
    }


def _block_specs(cfg: ArchConfig, mixer: str, ffn: str | None, periods: int, cross: bool) -> dict:
    d = cfg.d_model
    p = (periods,)
    s: dict = {"norm1": PSpec(p + (d,), (None, None), "ones")}
    if mixer == ATTN:
        s["attn"] = _attn_specs(cfg, periods)
    else:
        s["ssm"] = _ssm_specs(cfg, periods)
    if cross:
        s["norm_x"] = PSpec(p + (d,), (None, None), "ones")
        s["cross"] = _attn_specs(cfg, periods)
    if ffn is not None:
        s["norm2"] = PSpec(p + (d,), (None, None), "ones")
        s[ffn] = _mlp_specs(cfg, periods) if ffn == MLP else _moe_specs(cfg, periods)
    return s


def param_specs(cfg: ArchConfig) -> dict:
    d, v = cfg.d_model, cfg.padded_vocab
    period, n_periods = cfg.layer_pattern()
    cross = cfg.family == "encdec"
    specs: dict = {
        "embed": PSpec((v, d), ("model", "fsdp"), "embed"),
        "final_norm": PSpec((d,), (None,), "ones"),
        "blocks": [
            _block_specs(cfg, mixer, ffn, n_periods, cross) for mixer, ffn in period
        ],
    }
    if not cfg.tie_embeddings:
        specs["head"] = PSpec((d, v), ("fsdp", "model"), fan_in_axis=0)
    if cross:
        specs["enc_blocks"] = [_block_specs(cfg, ATTN, MLP, cfg.encoder_layers, False)]
        specs["enc_final_norm"] = PSpec((d,), (None,), "ones")
    return specs


# ----------------------------------------------------------------------
# Param materialisation
# ----------------------------------------------------------------------
def _init_leaf(key, spec: PSpec, dtype) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "ssm_a":
        # mamba: A_log = log(1..state) broadcast over d_inner
        st = spec.shape[-1]
        a = jnp.log(jnp.arange(1, st + 1, dtype=jnp.float32))
        return jnp.broadcast_to(a, spec.shape).astype(dtype)
    scale = 0.02 if spec.init == "embed" else 1.0
    if spec.fan_in_axis is not None:
        scale = 1.0 / math.sqrt(spec.shape[spec.fan_in_axis])
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dtype)


def init_params(cfg: ArchConfig, key: jax.Array) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    specs = param_specs(cfg)
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, PSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(cfg: ArchConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        param_specs(cfg),
        is_leaf=lambda x: isinstance(x, PSpec),
    )


def param_shardings(cfg: ArchConfig, rules: MeshRules) -> dict:
    return jax.tree.map(
        lambda s: rules.sharding(s.axes, s.shape),
        param_specs(cfg),
        is_leaf=lambda x: isinstance(x, PSpec),
    )


# ----------------------------------------------------------------------
# Stack application
# ----------------------------------------------------------------------
def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def _apply_block(
    cfg, bp, mixer, ffn, x, positions, cache, pos, causal, enc_out, cross_cache
):
    """cache: this period-position's cache dict, already sliced to the
    current layer (scan xs); updated caches return via scan ys."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(x, bp["norm1"])
    if mixer == ATTN:
        attn_cache = None
        if cache is not None:
            attn_cache = {"k": cache["k"], "v": cache["v"], "pos": pos}
        h, new_c = attention_block(cfg, bp["attn"], h, positions, attn_cache, causal)
        new_cache = None if cache is None else {"k": new_c["k"], "v": new_c["v"]}
    else:
        h, new_cache = mamba_block(cfg, bp["ssm"], h, cache)
    x = x + h
    if enc_out is not None or cross_cache is not None:
        h = rmsnorm(x, bp["norm_x"])
        if cross_cache is None:
            h, _ = attention_block(cfg, bp["cross"], h, positions, None, False, enc_out)
        else:
            # decode: K/V come from the precomputed cross cache; kv_source
            # only flags the cross path (its tiny 1-token K/V is discarded)
            h, _ = attention_block(cfg, bp["cross"], h, positions, cross_cache, False, h)
        x = x + h
    if ffn is not None:
        h = rmsnorm(x, bp["norm2"])
        if ffn == MLP:
            m = bp[MLP]
            h = swiglu(h, m["w_gate"], m["w_up"], m["w_down"])
        else:
            h, aux = moe_block(cfg, bp[MOE], h)
        x = x + h
    return constrain(x, "batch", "seq", None), new_cache, aux


def _apply_stack(
    cfg,
    blocks,
    pattern,
    x,
    positions,
    caches=None,
    pos=None,
    causal=True,
    enc_out=None,
    cross_caches=None,
):
    """Scan the layer stack.  blocks/caches/cross_caches: per-period-
    position pytrees with leading n_periods dim, consumed as scan xs and
    (for caches) regenerated as scan ys — the cache streams through HBM
    once per step, and the sharded-seq masked update spans only one
    layer's slice.  (Carrying the stacked cache in the scan carry instead
    makes every per-position update a masked select over the FULL stack:
    measured 64x worse on 32k decode.)  Returns (x, new_caches, aux)."""

    def body(carry, xs):
        x, aux = carry
        bps, cs, ccs = xs
        new_cs = []
        for i, (mixer, ffn) in enumerate(pattern):
            c_i = None if cs is None else cs[i]
            cc_i = None if ccs is None else ccs[i]
            x, nc, a = _apply_block(
                cfg, bps[i], mixer, ffn, x, positions, c_i, pos, causal,
                enc_out, cc_i,
            )
            new_cs.append(nc)
            aux = aux + a
        if all(c is None for c in new_cs):
            new_cs = None
        return (x, aux), new_cs

    body = _remat(cfg, body)
    aux0 = jnp.zeros((), jnp.float32)
    if cfg.scan_layers:
        (x, aux), new_caches = jax.lax.scan(
            body, (x, aux0), (blocks, caches, cross_caches)
        )
    else:
        n_periods = jax.tree.leaves(blocks)[0].shape[0]
        aux = aux0
        outs = []
        for t in range(n_periods):
            def sl(a, t=t):
                return a[t]
            xs = (
                jax.tree.map(sl, blocks),
                None if caches is None else jax.tree.map(sl, caches),
                None if cross_caches is None else jax.tree.map(sl, cross_caches),
            )
            (x, aux), nc = body((x, aux), xs)
            outs.append(nc)
        new_caches = (
            None if caches is None else jax.tree.map(lambda *a: jnp.stack(a), *outs)
        )
    return x, new_caches, aux


# ----------------------------------------------------------------------
# Public model functions
# ----------------------------------------------------------------------
def _encode(cfg, params, enc_frames):
    """Whisper-style encoder over frontend-stub frame embeddings."""
    x = constrain(enc_frames, "batch", "seq", None)
    pos = jnp.arange(x.shape[1])
    x, _, _ = _apply_stack(
        cfg, params["enc_blocks"], [(ATTN, MLP)], x, pos, causal=False
    )
    return rmsnorm(x, params["enc_final_norm"])


def forward(cfg: ArchConfig, params: dict, batch: dict):
    """Training/prefill forward. batch: tokens [B,S] (+enc_frames/img_embeds).

    Returns (logits [B, S_text, Vp], aux_loss).
    """
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens)
    n_img = 0
    if cfg.family == "vlm":
        img = batch["img_embeds"].astype(x.dtype)  # [B, vt, D] (frontend stub)
        x = jnp.concatenate([img, x], axis=1)
        n_img = img.shape[1]
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(cfg, params, batch["enc_frames"].astype(x.dtype))
    positions = jnp.arange(x.shape[1])
    pattern, _ = cfg.layer_pattern()
    x, _, aux = _apply_stack(
        cfg, params["blocks"], pattern, x, positions, enc_out=enc_out
    )
    x = rmsnorm(x, params["final_norm"])
    if n_img:
        x = x[:, n_img:, :]
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = unembed(x, head.astype(x.dtype))
    return logits, aux


def loss_fn(cfg: ArchConfig, params: dict, batch: dict):
    """Next-token cross-entropy (labels = -1 are masked), + MoE aux."""
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(safe, logits.shape[-1], dtype=jnp.float32)
    label_logit = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = (lse - label_logit) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1)
    return loss + 0.01 * aux, {"nll": loss, "aux": aux}


def kv_cache_heads(cfg: ArchConfig) -> int:
    """KV heads held in the cache: replicated up to the smallest multiple
    that the model axis divides (the classic GQA/MQA tensor-parallel
    serving trick — vLLM does the same).  Exact: query head q reads
    replicated head (q * H_eff) // H_q == q // group.  Without it, an
    H_kv < model_parallelism cache must shard its sequence dim, turning
    every decode write into a full-buffer masked select."""
    from ..distributed.sharding import axis_size

    hkv = cfg.n_kv_heads
    ms = max(axis_size("model"), 1)
    if hkv == 0 or hkv % ms == 0 or cfg.n_heads % ms != 0:
        return hkv
    r = 1
    while (hkv * r) % ms or (cfg.n_heads % (hkv * r)):
        r += 1
        if hkv * r > cfg.n_heads:
            return hkv  # no exact replication factor; keep seq sharding
    return hkv * r


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, abstract: bool = False):
    """Decode cache pytree (per period position, stacked over periods)."""
    dtype = jnp.dtype(cfg.dtype)
    period, n_periods = cfg.layer_pattern()

    def make(shape, dt=dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.zeros(shape, dt)

    blocks = []
    for mixer, _ in period:
        if mixer == ATTN:
            # head-major [P, B, H, S, hd]: the layout attention consumes —
            # a seq-major cache costs a full relayout of the stacked cache
            # every decode step (measured 569 GB/step on qwen3-32b)
            shp = (n_periods, batch, kv_cache_heads(cfg), max_seq, cfg.hd)
            blocks.append({"k": make(shp), "v": make(shp)})
        else:
            blocks.append(
                {
                    "conv": make((n_periods, batch, cfg.ssm_conv - 1, cfg.d_inner)),
                    "h": make((n_periods, batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
                }
            )
    cache = {"blocks": blocks, "pos": make((), jnp.int32)}
    if cfg.family == "encdec":
        shp = (n_periods, batch, cfg.n_kv_heads, cfg.encoder_seq, cfg.hd)
        cache["cross"] = [{"k": make(shp), "v": make(shp)}]
    return cache


def cache_shardings(cfg: ArchConfig, rules: MeshRules, batch: int, max_seq: int):
    """KV caches: batch on the data axes; the model axis takes kv heads
    when they divide it, otherwise the cache *sequence* dim (sequence-
    parallel decode attention: SPMD all-reduces the softmax stats)."""
    cache = init_cache(cfg, batch, max_seq, abstract=True)
    model_size = rules._axis_size(rules.axes_for("model"))

    def shard(leaf):
        if leaf.ndim == 5:  # attention KV: [P, B, H, S, hd] (head-major)
            if model_size and leaf.shape[2] % max(model_size, 1) == 0:
                axes = (None, "batch", "model", None, None)
            else:
                axes = (None, "batch", None, "model", None)
            return rules.sharding(axes, leaf.shape)
        if leaf.ndim == 4:  # ssm: [P, B, k-1, d_inner] or [P, B, d_inner, st]
            if leaf.shape[2] % max(model_size, 1) == 0 and leaf.shape[2] >= model_size:
                axes = (None, "batch", "model", None)
            else:
                axes = (None, "batch", None, "model")
            return rules.sharding(axes, leaf.shape)
        return rules.sharding((None,) * leaf.ndim, leaf.shape)

    return jax.tree.map(shard, cache)


def decode_step(cfg: ArchConfig, params: dict, tokens: jnp.ndarray, cache: dict):
    """One-token decode. tokens: [B, 1]. Returns (logits [B, Vp], cache)."""
    x = embed_tokens(params["embed"], tokens)
    pos = cache["pos"]
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    pattern, _ = cfg.layer_pattern()
    cross = cache.get("cross")
    x, new_blocks, _ = _apply_stack(
        cfg,
        params["blocks"],
        pattern,
        x,
        positions,
        caches=cache["blocks"],
        pos=pos,
        cross_caches=cross,
    )
    x = rmsnorm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = unembed(x, head.astype(x.dtype))[:, 0, :]
    new_cache = {"blocks": new_blocks, "pos": pos + 1}
    if cross is not None:
        new_cache["cross"] = cross
    return logits, new_cache


def prefill(cfg: ArchConfig, params: dict, batch: dict, max_seq: int):
    """Prefill: forward over the prompt, building the decode cache."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_seq)
    x = embed_tokens(params["embed"], tokens)
    if cfg.family == "vlm":
        x = jnp.concatenate([batch["img_embeds"].astype(x.dtype), x], axis=1)
    assert x.shape[1] <= max_seq, (
        f"prefill length {x.shape[1]} (incl. vision tokens) exceeds cache size {max_seq}"
    )
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(cfg, params, batch["enc_frames"].astype(x.dtype))
        cache["cross"] = _build_cross_caches(cfg, params, enc_out)
    positions = jnp.arange(x.shape[1])
    pattern, _ = cfg.layer_pattern()
    x, new_blocks, _ = _apply_stack(
        cfg,
        params["blocks"],
        pattern,
        x,
        positions,
        caches=cache["blocks"],
        pos=0,  # static: lets chunked causal attention bound its K slices
        enc_out=enc_out,
        cross_caches=cache.get("cross"),
    )
    x = rmsnorm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = unembed(x[:, -1:, :], head.astype(x.dtype))[:, 0, :]
    new_cache = {"blocks": new_blocks, "pos": jnp.asarray(x.shape[1], jnp.int32)}
    if cfg.family == "encdec":
        new_cache["cross"] = cache["cross"]
    return logits, new_cache


def _build_cross_caches(cfg, params, enc_out):
    """Precompute cross-attention K/V for every decoder block (vmapped
    over the period-stacked params)."""
    out = []
    for bp in params["blocks"]:
        cc = jax.vmap(lambda w: precompute_cross_cache(cfg, w, enc_out))(bp["cross"])
        out.append(cc)
    return out
