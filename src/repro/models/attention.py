"""GQA attention with optional qk-norm, RoPE, KV cache, flash kernel path."""

from __future__ import annotations


import jax
import jax.numpy as jnp

from ..distributed import constrain
from ..distributed.sharding import axis_size
from ..kernels.flash_attention import flash_attention
from .layers import rmsnorm, rope


def attention_block(
    cfg,
    p: dict,
    x: jnp.ndarray,  # [B, S, D]
    positions: jnp.ndarray,  # [B, S] or [S]
    cache: dict | None = None,  # {"k","v": [B, S_max, Hkv, hd], "pos": scalar}
    causal: bool = True,
    kv_source: jnp.ndarray | None = None,  # cross-attention keys/values
):
    """Returns (out [B, S, D], new_cache)."""
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    from ..distributed.sharding import gathered

    q = (x @ gathered(p["wq"], None, "model")).reshape(b, s, hq, hd)
    src = x if kv_source is None else kv_source
    k = (src @ gathered(p["wk"], None, "model")).reshape(b, src.shape[1], hkv, hd)
    v = (src @ gathered(p["wv"], None, "model")).reshape(b, src.shape[1], hkv, hd)
    q = constrain(q, "batch", "seq", "model", None)
    k = constrain(k, "batch", "seq", "model", None)
    v = constrain(v, "batch", "seq", "model", None)

    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if kv_source is None:  # no RoPE on cross-attention
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    # Self-attention caches are head-major [B, Hkv, S, hd] (the layout
    # attention consumes — a seq-major cache costs a full relayout of the
    # stacked cache every decode step), sliced per layer by the scan.
    new_cache = None
    offset = None
    kh = vh = None
    if cache is not None:
        if kv_source is None:
            pos = cache["pos"]
            # cache may hold KV heads replicated up to the TP degree (see
            # transformer.kv_cache_heads); replicate the fresh K/V to match
            h_eff = cache["k"].shape[1]
            if h_eff != hkv:
                r = h_eff // hkv
                k = jnp.repeat(k, r, axis=2)
                v = jnp.repeat(v, r, axis=2)
            kc = _dus_seq(cache["k"], k.transpose(0, 2, 1, 3), pos)
            vc = _dus_seq(cache["v"], v.transpose(0, 2, 1, 3), pos)
            new_cache = {"k": kc, "v": vc, "pos": pos + s}
            if s == 1:
                # decode: attend over this layer's cache (its layout may
                # shard the seq dim; softmax stats all-reduce under SPMD)
                kh, vh = kc, vc
                offset = pos  # mask unwritten slots beyond the frontier
            # prefill (s > 1, pos == 0): attend over the fresh contiguous
            # K/V — avoids resharding chunked slices of the cache layout
        else:
            # cross-attention cache: precomputed K/V over the encoder
            # output, already sliced per period position (scan xs)
            kh, vh = cache["k"], cache["v"]
            new_cache = cache

    if kh is None:
        # GQA head-sharding repair: when q heads divide the model axis but
        # kv heads do not, the grouped attention einsum cannot stay
        # head-sharded (8x8 reshape of a 16-sharded 64-head axis
        # replicates the logits).  Repeating K/V to full heads *under a
        # sharding constraint* keeps attention 16-way head-parallel; the
        # repeat is local per shard.  (Head count taken from the tensor:
        # the cache path may already have replicated kv heads.)
        ms = axis_size("model")
        hkv_cur = k.shape[2]
        if s > 1 and hq != hkv_cur and ms > 1 and hq % ms == 0 and hkv_cur % ms != 0:
            g = hq // hkv_cur
            k = constrain(jnp.repeat(k, g, axis=2), "batch", "seq", "model", None)
            v = constrain(jnp.repeat(v, g, axis=2), "batch", "seq", "model", None)
        kh = k.transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)

    qh = q.transpose(0, 2, 1, 3)  # [B, H, S, hd]
    out = flash_attention(
        qh, kh, vh,
        causal=causal and kv_source is None,
        offset=offset,
        use_pallas=cfg.use_flash_kernel,
    )
    out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    out = constrain(out, "batch", "seq", "model")
    y = out @ gathered(p["wo"], "model", None)
    return constrain(y, "batch", "seq", None), new_cache


def precompute_cross_cache(cfg, p: dict, enc_out: jnp.ndarray) -> dict:
    """K/V over encoder output for decode-time cross attention
    (head-major [B, Hkv, T, hd])."""
    b, t, _ = enc_out.shape
    hkv, hd = cfg.n_kv_heads, cfg.hd
    k = (enc_out @ p["wk"]).reshape(b, t, hkv, hd).transpose(0, 2, 1, 3)
    v = (enc_out @ p["wv"]).reshape(b, t, hkv, hd).transpose(0, 2, 1, 3)
    return {"k": k, "v": v}


def _dus_seq(buf: jnp.ndarray, update: jnp.ndarray, pos) -> jnp.ndarray:
    """dynamic_update_slice along the sequence axis of a head-major
    [B, H, S, hd] cache slice (axis 2)."""
    idx = (jnp.int32(0), jnp.int32(0), jnp.asarray(pos, jnp.int32), jnp.int32(0))
    return jax.lax.dynamic_update_slice(buf, update.astype(buf.dtype), idx)
