"""Mamba-1 selective-state-space block (falcon-mamba / Jamba mixer).

Training path: chunked associative scan — the sequence is split into
``cfg.ssm_chunk``-token chunks; within a chunk the recurrence

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t
    y_t = C_t . h_t + D x_t

is evaluated with ``jax.lax.associative_scan`` (work-efficient, depth
log C), and chunks are chained with a carry scan.  The chunk body is
rematerialised in the backward pass, so the [B, C, d_inner, state]
intermediate never outlives a chunk — this is what makes 500k-token
sequences trainable/servable (see DESIGN.md §Hardware adaptation).

Decode path: single-step recurrence with (conv window, h) carried in the
cache.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from ..distributed import constrain


def _ssm_params(cfg, p):
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # [d_inner, state]
    d = p["d"].astype(jnp.float32)  # [d_inner]
    return a, d


def _dt_bx(cfg, p, x):
    """Input-dependent dt, B, C. x: [B, L, d_inner] (f32)."""
    proj = x @ p["x_proj"].astype(jnp.float32)  # [B, L, dt_rank + 2*state]
    dtr, st = cfg.dt_rank, cfg.ssm_state
    dt, bmat, cmat = jnp.split(proj, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    return dt, bmat, cmat  # [B,L,d_inner], [B,L,state], [B,L,state]


def _scan_chunk(a, dt, bx, h0):
    """Associative scan of h_t = exp(dt_t a) h_{t-1} + bx_t within a chunk.

    a: [d_inner, state]; dt: [B, C, d_inner]; bx: [B, C, d_inner, state];
    h0: [B, d_inner, state].  Returns hs [B, C, d_inner, state].
    """
    decay = jnp.exp(dt[..., None] * a)  # [B, C, d, s]
    # fold the incoming state into the first step
    bx = bx.at[:, 0].add(decay[:, 0] * h0)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (decay, bx), axis=1)
    return hs


def mamba_block(
    cfg,
    p: dict,
    x: jnp.ndarray,  # [B, S, D]
    cache: dict | None = None,
    # cache: {"conv": [B, k-1, d_inner], "h": [B, d_inner, state]} —
    # this layer's slice (scan xs); updates return via scan ys
):
    """Returns (y [B, S, D], new_cache)."""
    b, s, _ = x.shape
    di, st, k = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    xz = x @ p["in_proj"]  # [B, S, 2*d_inner]
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = constrain(xs, "batch", "seq", "model")

    # depthwise causal conv1d (kernel k), SiLU
    if cache is not None:
        conv_in = jnp.concatenate([cache["conv"].astype(xs.dtype), xs], axis=1)
        new_conv = conv_in[:, -(k - 1):, :]
    else:
        conv_in = jnp.pad(xs, ((0, 0), (k - 1, 0), (0, 0)))
        new_conv = conv_in[:, -(k - 1):, :]
    w = p["conv"]  # [d_inner, k]
    xc = sum(conv_in[:, i : i + s, :] * w[:, i] for i in range(k))
    xc = jax.nn.silu(xc.astype(jnp.float32))

    a, d = _ssm_params(cfg, p)
    dt, bmat, cmat = _dt_bx(cfg, p, xc)

    h0 = (
        cache["h"].astype(jnp.float32)
        if cache is not None
        else jnp.zeros((b, di, st), jnp.float32)
    )

    if s == 1:  # decode: single recurrence step
        decay = jnp.exp(dt[:, 0, :, None] * a)
        h = decay * h0 + dt[:, 0, :, None] * bmat[:, 0, None, :] * xc[:, 0, :, None]
        y = jnp.einsum("bds,bs->bd", h, cmat[:, 0])[:, None, :]
        new_h = h
    elif cfg.ssm_mode == "seq":
        # time-major sequential scan: only the [B, d_inner, state] carry
        # and the per-step inputs/outputs touch HBM — the chunk-state
        # tensor [B, C, d_inner, state] never materialises.
        def step(h, inp):
            dt_t, b_t, x_t, c_t = inp
            decay = jnp.exp(dt_t[:, :, None] * a)
            h = decay * h + dt_t[:, :, None] * b_t[:, None, :] * x_t[:, :, None]
            y_t = jnp.einsum("bds,bs->bd", h, c_t)
            return h, y_t

        def tm(u):
            return u.swapaxes(0, 1)  # [B,S,...] -> [S,B,...]
        new_h, ys = jax.lax.scan(step, h0, (tm(dt), tm(bmat), tm(xc), tm(cmat)))
        y = ys.swapaxes(0, 1)
    else:
        chunk = min(cfg.ssm_chunk, s)
        pad = (-s) % chunk
        if pad:
            # identity-pad the recurrence: dt=0 -> decay=1, bx=0
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
            xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
            cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        sp = s + pad
        n_chunks = sp // chunk

        def body(h_carry, inp):
            # bx materialises only at chunk granularity ([B,C,d,st]) and is
            # rematerialised in backward: HBM traffic stays O(B*S*(d+st))
            dt_c, b_c, x_c, c_c = inp
            bx_c = dt_c[..., None] * b_c[:, :, None, :] * x_c[..., None]
            hs = _scan_chunk(a, dt_c, bx_c, h_carry)
            y_c = jnp.einsum("bcds,bcs->bcd", hs, c_c)
            return hs[:, -1], y_c

        body = jax.checkpoint(body)
        dt_r = dt.reshape(b, n_chunks, chunk, di).swapaxes(0, 1)
        b_r = bmat.reshape(b, n_chunks, chunk, st).swapaxes(0, 1)
        x_r = xc.reshape(b, n_chunks, chunk, di).swapaxes(0, 1)
        c_r = cmat.reshape(b, n_chunks, chunk, st).swapaxes(0, 1)
        new_h, ys = jax.lax.scan(body, h0, (dt_r, b_r, x_r, c_r))
        y = ys.swapaxes(0, 1).reshape(b, sp, di)[:, :s]
        xc = xc[:, :s]  # drop the identity padding for the skip term

    y = y + d * xc
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = constrain(y, "batch", "seq", "model")
    out = y @ p["out_proj"]
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "h": new_h.astype(cache["h"].dtype)}
    return constrain(out, "batch", "seq", None), new_cache
