"""da4ml core: distributed-arithmetic CMVM optimization (the paper's
primary contribution), hardware-independent.

Public API:
    solve_cmvm        two-stage da4ml optimizer -> Solution (DAIS program)
    naive_adder_tree  hls4ml 'latency'-strategy baseline in the same units
    QInterval         quantized-interval fixed-point bookkeeping
    DAISProgram/Term  SSA shift-add IR
    decompose         stage-1 graph decomposition (M = M1 @ M2)
    pipeline          greedy register insertion
    emit_verilog      standalone RTL generation
    parse_verilog     netlist parser for the emitted subset
    RTLSimulator      cycle-accurate pure-Python RTL simulation
    cosim_case        three-way RTL/interpreter/jit co-simulation
"""

from .cache import CacheStats, SolutionCache, pack_solution, solve_key, unpack_solution
from .cosim import cosim_case, cosim_grid, cosim_program, default_grid
from .csd import csd_nnz, csd_span, from_csd, to_csd, vector_csd_nnz
from .cost import adder_cost, ceil_log2, min_tree_depth, min_tree_depth_hist, overlap_bits
from .cse import CSE
from .dais import DAISProgram, Term, qints_from_array, qints_to_array
from .fixed_point import QInterval
from .graph_decompose import Decomposition, decompose
from .pipelining import PipelineReport, pipeline
from .rtlsim import RTLModule, RTLSimError, RTLSimulator, SimResult, parse_verilog
from .solver import Solution, config_solve_key, naive_adder_tree, solve_cmvm
from .verilog import emit_verilog

__all__ = [
    "CSE",
    "CacheStats",
    "DAISProgram",
    "Decomposition",
    "PipelineReport",
    "QInterval",
    "RTLModule",
    "RTLSimError",
    "RTLSimulator",
    "SimResult",
    "Solution",
    "SolutionCache",
    "Term",
    "adder_cost",
    "ceil_log2",
    "config_solve_key",
    "cosim_case",
    "cosim_grid",
    "cosim_program",
    "csd_nnz",
    "csd_span",
    "decompose",
    "default_grid",
    "emit_verilog",
    "from_csd",
    "min_tree_depth",
    "min_tree_depth_hist",
    "naive_adder_tree",
    "overlap_bits",
    "pack_solution",
    "parse_verilog",
    "pipeline",
    "qints_from_array",
    "qints_to_array",
    "solve_key",
    "solve_cmvm",
    "to_csd",
    "unpack_solution",
    "vector_csd_nnz",
]
