"""Pure-Python RTL simulator for the Verilog subset of :mod:`verilog`.

``emit_verilog`` produces the hardware claim of the paper — a shift-add
adder graph as synthesizable Verilog-2001 — but structural goldens alone
never *execute* that RTL.  This module closes the loop without any
external toolchain: it parses the emitted module into a small netlist IR
and evaluates it cycle-accurately with real Verilog expression
semantics, so divergences between the Python integer model and what the
HDL actually computes (width truncation, signedness, arithmetic-shift
behaviour, pipeline misalignment) become test failures.

Supported subset (everything ``emit_verilog`` emits, plus a little
slack so hand-written regression modules stay convenient):

* ``module NAME ( ports );`` with ``input``/``output`` ``wire``/``reg``
  port declarations, optional ``signed``, optional ``[msb:0]`` ranges;
* body declarations ``wire|reg [signed] [msb:0] name;``;
* continuous assignments ``assign dst = expr;`` where ``expr`` is built
  from identifiers, decimal integer literals, unary ``-``, binary
  ``+``/``-``, and parenthesised shifts ``(e <<< k)`` / ``(e >>> k)``
  (``<<`` and ``>>`` are also accepted);
* a single ``always @(posedge clk) begin ... end`` region of
  non-blocking assignments ``dst <= src_expr;``.

Semantics implemented (IEEE 1364-2001 expression evaluation):

* the size of the RHS of an assignment is
  ``max(width(LHS), self_size(RHS))`` where shifts take their left
  operand's size, ``+``/``-`` take the max of their operands, and shift
  amounts are self-determined;
* the expression is signed iff every context-determined operand is
  signed (shift results inherit the left operand's signedness; the
  LHS never affects signedness);
* context-determined operands are extended to the final size
  (sign-extended only for signed expressions) *before* any operation,
  every operation wraps modulo ``2**size``, and ``>>>`` is an
  arithmetic shift only for signed expressions;
* the result is truncated to the LHS width on assignment — every signal
  stores exactly the two's-complement value its declared width can hold;
* registers initialise to 0 and update simultaneously (non-blocking) on
  the clock edge.

The simulator also derives the pipeline structure from the netlist
itself: every input→output path is walked counting register crossings,
unbalanced paths (a real pipelining bug) raise, and the resulting
latency is cross-checked against :class:`pipelining.PipelineReport` by
the co-sim harness (:mod:`cosim`).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "RTLSimError",
    "RTLModule",
    "RTLSimulator",
    "SimResult",
    "parse_verilog",
]

_MAX_WIDTH = 62  # int64 evaluation with post-op masking stays exact below this


class RTLSimError(Exception):
    """Parse error, unsupported construct, or netlist inconsistency."""


# ----------------------------------------------------------------------
# Netlist IR
# ----------------------------------------------------------------------
#
# Expressions are plain nested tuples (hashable, cheap to walk):
#   ("ref", name)            signal reference
#   ("const", value)         decimal literal (32-bit signed, like Verilog)
#   ("neg", e)               unary minus
#   ("add", l, r) / ("sub", l, r)
#   ("shl", e, k) / ("sra", e, k) / ("srl", e, k)
# ``sra`` is the `>>>` token; whether it actually shifts arithmetically
# is decided by the signedness of the whole expression, per the LRM.

Expr = tuple


@dataclass(frozen=True)
class Signal:
    name: str
    width: int
    signed: bool
    kind: str  # "input" | "output" | "wire" | "reg"


@dataclass
class Assign:
    dst: str
    expr: Expr


@dataclass
class RTLModule:
    """Parsed netlist of one module."""

    name: str
    clock: str | None
    signals: dict[str, Signal]
    inputs: list[str]  # data inputs, clock excluded, declaration order
    outputs: list[str]
    assigns: list[Assign]  # continuous assignments
    clocked: list[Assign]  # non-blocking assignments in the always block
    # filled by _analyze():
    comb_order: list[Assign] = field(default_factory=list)
    latency_of: dict[str, int | None] = field(default_factory=dict)

    @property
    def latency_cycles(self) -> int:
        """Register stages between inputs and outputs (0 = combinational)."""
        return max(
            (self.latency_of[o] for o in self.outputs if self.latency_of[o] is not None),
            default=0,
        )

    @property
    def n_registers(self) -> int:
        return len(self.clocked)

    def register_bits(self) -> int:
        """Total flip-flop bits (sum of clocked destination widths)."""
        return sum(self.signals[a.dst].width for a in self.clocked)

    def stage_register_bits(self) -> list[int]:
        """FF bits per stage boundary: entry ``s`` counts registers whose
        destination lives after boundary ``s``/``s+1`` (i.e. has register
        depth ``s+1``).  Registers can sit deeper than the last output
        (auxiliary logic past the final output stage), so the list is
        sized by the deepest register, not by ``latency_cycles``."""
        depths = [
            self.latency_of[a.dst]
            for a in self.clocked
            if self.latency_of[a.dst] is not None
        ]
        bits = [0] * max([self.latency_cycles] + depths)
        for a in self.clocked:
            d = self.latency_of[a.dst]
            if d is not None and d >= 1:
                bits[d - 1] += self.signals[a.dst].width
        return bits


# ----------------------------------------------------------------------
# Parsing
# ----------------------------------------------------------------------
_TOKEN_RE = re.compile(r"\s*(<<<|>>>|<<|>>|[A-Za-z_]\w*|\d+|[()+\-=;])")


def _tokenize(text: str) -> list[str]:
    toks, pos = [], 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if not m:
            if text[pos:].strip():
                raise RTLSimError(f"cannot tokenize {text[pos:]!r}")
            break
        toks.append(m.group(1))
        pos = m.end()
    return toks


class _ExprParser:
    """Recursive-descent parser for the expression subset."""

    def __init__(self, toks: list[str], context: str):
        self.toks = toks
        self.i = 0
        self.context = context

    def peek(self) -> str | None:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def next(self) -> str:
        if self.i >= len(self.toks):
            raise RTLSimError(f"unexpected end of expression in {self.context!r}")
        t = self.toks[self.i]
        self.i += 1
        return t

    def expect(self, tok: str) -> None:
        t = self.next()
        if t != tok:
            raise RTLSimError(f"expected {tok!r}, got {t!r} in {self.context!r}")

    def parse(self) -> Expr:
        e = self.expr()
        if self.peek() is not None:
            raise RTLSimError(f"trailing tokens {self.toks[self.i:]} in {self.context!r}")
        return e

    def expr(self) -> Expr:
        e = self.unary()
        while self.peek() in ("+", "-"):
            op = self.next()
            e = ("add" if op == "+" else "sub", e, self.unary())
        return e

    def unary(self) -> Expr:
        if self.peek() == "-":
            self.next()
            return ("neg", self.unary())
        return self.primary()

    def primary(self) -> Expr:
        t = self.next()
        if t == "(":
            e = self.expr()
            if self.peek() in ("<<<", ">>>", "<<", ">>"):
                op = self.next()
                k = self.next()
                if not k.isdigit():
                    raise RTLSimError(
                        f"only constant shift amounts supported, got {k!r} "
                        f"in {self.context!r}"
                    )
                tag = {"<<<": "shl", "<<": "shl", ">>>": "sra", ">>": "srl"}[op]
                e = (tag, e, int(k))
            self.expect(")")
            return e
        if t.isdigit():
            return ("const", int(t))
        if re.fullmatch(r"[A-Za-z_]\w*", t):
            return ("ref", t)
        raise RTLSimError(f"unexpected token {t!r} in {self.context!r}")


def _parse_expr(text: str) -> Expr:
    return _ExprParser(_tokenize(text), text.strip()).parse()


_PORT_RE = re.compile(
    r"^(input|output)\s+(?:(wire|reg)\s+)?(signed\s+)?(?:\[(\d+):0\]\s*)?([A-Za-z_]\w*)$"
)
_DECL_RE = re.compile(
    r"^(wire|reg)\s+(signed\s+)?(?:\[(\d+):0\]\s*)?([A-Za-z_]\w*)\s*;$"
)
_ASSIGN_RE = re.compile(r"^assign\s+([A-Za-z_]\w*)\s*=\s*(.+?)\s*;$")
_ALWAYS_RE = re.compile(r"^always\s*@\s*\(\s*posedge\s+([A-Za-z_]\w*)\s*\)\s*begin$")
_NBA_RE = re.compile(r"^([A-Za-z_]\w*)\s*<=\s*(.+?)\s*;$")


def parse_verilog(src: str) -> RTLModule:
    """Parse one module in the emitted subset into an :class:`RTLModule`."""
    # strip comments, normalise whitespace
    src = re.sub(r"//[^\n]*", "", src)
    src = re.sub(r"/\*.*?\*/", "", src, flags=re.S)

    m = re.search(r"\bmodule\s+([A-Za-z_]\w*)\s*\((.*?)\)\s*;(.*?)\bendmodule\b",
                  src, flags=re.S)
    if not m:
        raise RTLSimError("no `module ... ( ... ); ... endmodule` found")
    name, portlist, body = m.group(1), m.group(2), m.group(3)

    signals: dict[str, Signal] = {}
    inputs: list[str] = []
    outputs: list[str] = []
    clock: str | None = None

    for raw in portlist.split(","):
        decl = " ".join(raw.split())
        if not decl:
            continue
        pm = _PORT_RE.match(decl)
        if not pm:
            raise RTLSimError(f"unsupported port declaration {decl!r}")
        direction, _, signed, msb, pname = pm.groups()
        width = int(msb) + 1 if msb is not None else 1
        if direction == "input" and pname == "clk" and msb is None:
            clock = pname
            continue
        sig = Signal(pname, width, signed is not None, direction)
        if pname in signals:
            raise RTLSimError(f"duplicate signal {pname!r}")
        signals[pname] = sig
        (inputs if direction == "input" else outputs).append(pname)

    assigns: list[Assign] = []
    clocked: list[Assign] = []
    in_always = False
    for raw in body.split("\n"):
        line = " ".join(raw.split())
        if not line:
            continue
        if in_always:
            if line == "end":
                in_always = False
                continue
            nm = _NBA_RE.match(line)
            if not nm:
                raise RTLSimError(f"unsupported statement in always block: {line!r}")
            clocked.append(Assign(nm.group(1), _parse_expr(nm.group(2))))
            continue
        am = _ALWAYS_RE.match(line)
        if am:
            if clock is None:
                raise RTLSimError("always @(posedge ...) in a module with no clk port")
            if am.group(1) != clock:
                raise RTLSimError(f"unknown clock {am.group(1)!r}")
            in_always = True
            continue
        dm = _DECL_RE.match(line)
        if dm:
            kind, signed, msb, dname = dm.groups()
            width = int(msb) + 1 if msb is not None else 1
            if dname in signals:
                raise RTLSimError(f"duplicate signal {dname!r}")
            signals[dname] = Signal(dname, width, signed is not None, kind)
            continue
        sm = _ASSIGN_RE.match(line)
        if sm:
            assigns.append(Assign(sm.group(1), _parse_expr(sm.group(2))))
            continue
        raise RTLSimError(f"unsupported construct: {line!r}")
    if in_always:
        raise RTLSimError("always block not closed with `end`")

    mod = RTLModule(name, clock, signals, inputs, outputs, assigns, clocked)
    _analyze(mod)
    return mod


# ----------------------------------------------------------------------
# Static analysis: drivers, schedule, register depth
# ----------------------------------------------------------------------
def _refs(expr: Expr) -> list[str]:
    out: list[str] = []
    stack = [expr]
    while stack:
        node = stack.pop()
        tag = node[0]
        if tag == "ref":
            out.append(node[1])
        elif tag == "const":
            pass
        elif tag == "neg":
            stack.append(node[1])
        elif tag in ("add", "sub"):
            stack.append(node[1])
            stack.append(node[2])
        else:  # shifts
            stack.append(node[1])
    return out


def _analyze(mod: RTLModule) -> None:
    sigs = mod.signals
    for w in (s for s in sigs.values()):
        if w.width > _MAX_WIDTH:
            raise RTLSimError(
                f"signal {w.name!r} is {w.width} bits; the simulator supports "
                f"at most {_MAX_WIDTH} (int64 evaluation)"
            )
    driver: dict[str, Assign] = {}
    for a in mod.assigns:
        if a.dst not in sigs:
            raise RTLSimError(f"assignment to undeclared signal {a.dst!r}")
        if a.dst in driver:
            raise RTLSimError(f"multiple drivers for {a.dst!r}")
        if sigs[a.dst].kind == "reg":
            raise RTLSimError(f"continuous assignment to reg {a.dst!r}")
        driver[a.dst] = a
    reg_driver: dict[str, Assign] = {}
    for a in mod.clocked:
        if a.dst not in sigs:
            raise RTLSimError(f"clocked assignment to undeclared signal {a.dst!r}")
        if sigs[a.dst].kind != "reg":
            raise RTLSimError(f"non-blocking assignment to non-reg {a.dst!r}")
        if a.dst in reg_driver:
            raise RTLSimError(f"multiple clocked drivers for {a.dst!r}")
        reg_driver[a.dst] = a
    for a in mod.assigns + mod.clocked:
        for r in _refs(a.expr):
            if r not in sigs:
                raise RTLSimError(f"{a.dst!r} reads undeclared signal {r!r}")
    for s in sigs.values():
        if s.kind in ("wire", "output") and s.name not in driver:
            raise RTLSimError(f"undriven {s.kind} {s.name!r}")

    # combinational schedule: topological order over assign dependencies
    # (registers and inputs are state and break the ordering).  Iterative
    # DFS so deep adder chains never hit the Python recursion limit.
    order: list[Assign] = []
    state = {a.dst: 0 for a in mod.assigns}  # 0=unvisited 1=visiting 2=done

    for root in mod.assigns:
        if state[root.dst] == 2:
            continue
        stack: list[tuple[str, int]] = [(root.dst, 0)]
        while stack:
            nm, phase = stack.pop()
            if phase == 1:
                state[nm] = 2
                order.append(driver[nm])
                continue
            if state[nm] == 2:
                continue
            if state[nm] == 1:
                raise RTLSimError(f"combinational loop through {nm!r}")
            state[nm] = 1
            stack.append((nm, 1))
            for r in _refs(driver[nm].expr):
                if r in state and sigs[r].kind != "reg" and state[r] != 2:
                    if state[r] == 1:
                        raise RTLSimError(f"combinational loop through {r!r}")
                    stack.append((r, 0))
    mod.comb_order = order

    # register depth per signal: None for signals with no input dependency
    # (constants); otherwise (min, max) register crossings from any input.
    # Unbalanced min/max on a signal is a genuine pipeline bug: two
    # arrivals of the same logical value from different cycles.  The
    # comb schedule above is already topological, and every reg source is
    # combinational (or an input/reg), so one pass over `order` followed
    # by rounds of reg relaxation terminates: reg depths only ever depend
    # on values produced strictly earlier in clock time.
    depth: dict[str, tuple[int, int] | None] = {
        nm: (0, 0) for nm in sigs if sigs[nm].kind == "input"
    }
    for nm in sigs:
        if sigs[nm].kind == "reg" and nm not in reg_driver:
            depth[nm] = None  # free-running reg; stays at reset value

    def expr_depth(expr: Expr) -> tuple[int, int] | None:
        # callers guarantee every ref is already resolved in `depth`
        ds = [d for d in (depth[r] for r in _refs(expr)) if d is not None]
        if not ds:
            return None
        return (min(d[0] for d in ds), max(d[1] for d in ds))

    # regs first (their sources are pre-edge values: any signal), then
    # wires in topological order; iterate until the reg depths are fixed
    # (two rounds suffice for feed-forward pipelines, but loop defensively)
    for _ in range(len(mod.clocked) + 2):
        changed = False
        for a in mod.comb_order:
            if all(r in depth for r in _refs(a.expr)):
                d = expr_depth(a.expr)
                if depth.get(a.dst, "missing") != d:
                    depth[a.dst] = d
                    changed = True
        for a in mod.clocked:
            if all(r in depth for r in _refs(a.expr)):
                d = expr_depth(a.expr)
                d = None if d is None else (d[0] + 1, d[1] + 1)
                if depth.get(a.dst, "missing") != d:
                    depth[a.dst] = d
                    changed = True
        if not changed:
            break
    unresolved = [
        a.dst for a in mod.comb_order + mod.clocked if a.dst not in depth
    ]
    if unresolved:
        raise RTLSimError(
            f"register feedback loop: pipeline depth does not settle for {unresolved}"
        )

    lat: dict[str, int | None] = {}
    for nm in sigs:
        d = depth.get(nm)
        if d is not None and d[0] != d[1]:
            raise RTLSimError(
                f"unbalanced pipeline: {nm!r} mixes values that crossed "
                f"{d[0]} and {d[1]} register stages"
            )
        lat[nm] = None if d is None else d[0]
    mod.latency_of = lat


# ----------------------------------------------------------------------
# Expression sizing / signedness (IEEE 1364-2001 §4.4-4.5)
# ----------------------------------------------------------------------
def _self_size(expr: Expr, sigs: dict[str, Signal]) -> int:
    tag = expr[0]
    if tag == "ref":
        return sigs[expr[1]].width
    if tag == "const":
        return 32
    if tag == "neg":
        return _self_size(expr[1], sigs)
    if tag in ("add", "sub"):
        return max(_self_size(expr[1], sigs), _self_size(expr[2], sigs))
    return _self_size(expr[1], sigs)  # shifts: left operand's size


def _self_signed(expr: Expr, sigs: dict[str, Signal]) -> bool:
    tag = expr[0]
    if tag == "ref":
        return sigs[expr[1]].signed
    if tag == "const":
        return True  # unsized decimal literals are signed
    if tag == "neg":
        return _self_signed(expr[1], sigs)
    if tag in ("add", "sub"):
        return _self_signed(expr[1], sigs) and _self_signed(expr[2], sigs)
    return _self_signed(expr[1], sigs)  # shift: left operand only


def _wrap(v: np.ndarray, width: int, signed: bool) -> np.ndarray:
    """Truncate to ``width`` bits and reinterpret (two's complement)."""
    mask = (1 << width) - 1
    u = v & mask
    if not signed:
        return u
    sbit = 1 << (width - 1)
    return (u ^ sbit) - sbit


def _eval_expr(
    expr: Expr,
    size: int,
    signed: bool,
    values: dict[str, np.ndarray],
    sigs: dict[str, Signal],
) -> np.ndarray:
    """Evaluate at context ``size``/``signed``; result wrapped to size."""
    tag = expr[0]
    if tag == "ref":
        sig = sigs[expr[1]]
        v = values[expr[1]]
        # stored canonically at declared width; extension to the context
        # follows the *expression* signedness (LRM: operands of an
        # unsigned expression are zero-extended even if declared signed)
        if not signed and sig.signed:
            v = v & ((1 << sig.width) - 1)
        return v
    if tag == "const":
        return _wrap(np.int64(expr[1]), size, signed)
    if tag == "neg":
        return _wrap(-_eval_expr(expr[1], size, signed, values, sigs), size, signed)
    if tag in ("add", "sub"):
        a = _eval_expr(expr[1], size, signed, values, sigs)
        b = _eval_expr(expr[2], size, signed, values, sigs)
        return _wrap(a - b if tag == "sub" else a + b, size, signed)
    # shifts: amount is a self-determined constant
    k = expr[2]
    v = _eval_expr(expr[1], size, signed, values, sigs)
    if k >= 64:
        raise RTLSimError(f"shift amount {k} out of simulator range")
    if tag == "shl":
        return _wrap(v << k, size, signed)
    if tag == "srl" or not signed:
        return (v & ((1 << size) - 1)) >> k  # logical
    return v >> k  # arithmetic: v is already sign-correct at `size`


# ----------------------------------------------------------------------
# Simulation
# ----------------------------------------------------------------------
@dataclass
class SimResult:
    """Outputs plus the cycle accounting of one streamed simulation."""

    y: np.ndarray  # int64 [T, ..., n_outputs], aligned to the input stream
    latency_cycles: int
    n_cycles: int  # total clock cycles simulated (T + latency)
    n_registers: int
    register_bits: int
    stage_register_bits: list[int]

    def accounting(self) -> dict:
        """JSON-ready per-stage cycle/register accounting."""
        return {
            "latency_cycles": self.latency_cycles,
            "ii": 1,
            "n_cycles": self.n_cycles,
            "n_registers": self.n_registers,
            "register_bits": self.register_bits,
            "stage_register_bits": list(self.stage_register_bits),
        }


class RTLSimulator:
    """Cycle-accurate evaluator for a parsed :class:`RTLModule`.

    Values are numpy ``int64`` arrays over an arbitrary *lane* shape —
    lanes are independent instances of the module (batch dimension), all
    clocked in lockstep.  Registers reset to 0.
    """

    def __init__(self, module: RTLModule | str):
        if isinstance(module, str):
            module = parse_verilog(module)
        self.module = module
        self._sigs = module.signals
        # precompute (context size, context signedness) per assignment
        self._ctx: dict[int, tuple[int, bool]] = {}
        for a in module.comb_order + module.clocked:
            lhs = self._sigs[a.dst]
            # signal widths are bounded by _MAX_WIDTH (checked in _analyze)
            # and decimal literals self-size to 32, so the context never
            # exceeds the exact-int64 range
            size = max(lhs.width, _self_size(a.expr, self._sigs))
            if size > _MAX_WIDTH:
                raise RTLSimError(f"expression for {a.dst!r} exceeds {_MAX_WIDTH} bits")
            self._ctx[id(a)] = (size, _self_signed(a.expr, self._sigs))
        self.reset()

    # -- state ---------------------------------------------------------
    def reset(self, lane_shape: tuple[int, ...] = ()) -> None:
        self._lanes = tuple(lane_shape)
        z = np.zeros(self._lanes, dtype=np.int64)
        self.values: dict[str, np.ndarray] = {s: z.copy() for s in self._sigs}

    # -- one cycle -----------------------------------------------------
    def _drive(self, x: np.ndarray) -> None:
        x = np.asarray(x, dtype=np.int64)
        if x.shape[-1] != len(self.module.inputs):
            raise RTLSimError(
                f"expected {len(self.module.inputs)} inputs, got {x.shape[-1]}"
            )
        if x.shape[:-1] != self._lanes:
            self.reset(x.shape[:-1])
        for i, nm in enumerate(self.module.inputs):
            s = self._sigs[nm]
            self.values[nm] = _wrap(x[..., i], s.width, s.signed)

    def _compute(self, a: Assign) -> np.ndarray:
        size, signed = self._ctx[id(a)]
        v = _eval_expr(a.expr, size, signed, self.values, self._sigs)
        lhs = self._sigs[a.dst]
        v = _wrap(v, lhs.width, lhs.signed)
        if np.shape(v) != self._lanes:  # constant expressions are scalar
            v = np.broadcast_to(np.asarray(v, dtype=np.int64), self._lanes)
        return v

    def _settle(self) -> None:
        for a in self.module.comb_order:
            self.values[a.dst] = self._compute(a)

    def _clock_edge(self) -> None:
        nxt = [(a.dst, self._compute(a)) for a in self.module.clocked]
        for dst, v in nxt:  # non-blocking: commit after all samples
            self.values[dst] = v

    def step(self, x: np.ndarray) -> np.ndarray:
        """Drive one input vector, settle, sample outputs, clock.

        ``x``: int array [..., n_inputs].  Returns int64 [..., n_outputs]
        as observed *this* cycle (pre-edge), i.e. the module's response
        to the input presented ``latency_cycles`` cycles ago.
        """
        self._drive(x)
        self._settle()
        y = np.stack([self.values[o] for o in self.module.outputs], axis=-1)
        if self.module.clock is not None:
            self._clock_edge()
        return y

    # -- streams -------------------------------------------------------
    def run_stream(self, x: np.ndarray) -> SimResult:
        """Stream ``x`` at II=1 and return latency-aligned outputs.

        ``x``: int array [T, ..., n_inputs] — one new vector per clock
        cycle.  The stream is padded with ``latency_cycles`` flush
        vectors; the returned ``y[t]`` is the output observed at cycle
        ``t + latency_cycles``, i.e. the module's response to ``x[t]``.
        """
        x = np.asarray(x, dtype=np.int64)
        if x.ndim < 2:
            raise RTLSimError("run_stream expects [T, ..., n_inputs]")
        mod = self.module
        lat = mod.latency_cycles
        self.reset(x.shape[1:-1])
        t_total = x.shape[0] + lat
        ys = []
        flush = np.zeros_like(x[0])
        for t in range(t_total):
            ys.append(self.step(x[t] if t < x.shape[0] else flush))
        y = np.stack(ys[lat:], axis=0)
        return SimResult(
            y=y,
            latency_cycles=lat,
            n_cycles=t_total,
            n_registers=mod.n_registers,
            register_bits=mod.register_bits(),
            stage_register_bits=mod.stage_register_bits(),
        )

    def run_combinational(self, x: np.ndarray) -> np.ndarray:
        """Evaluate a combinational module on a whole batch in one settle."""
        if self.module.clock is not None:
            raise RTLSimError("module is clocked; use run_stream")
        x = np.asarray(x, dtype=np.int64)
        self.reset(x.shape[:-1])
        return self.step(x)
