"""Synthesizable Verilog emission from DAIS programs (paper §5.2).

Each DAIS op maps 1:1 to an RTL statement; pipelining inserts register
stages per :mod:`pipelining`.  Values are signed wires on the integer
grid (the power-of-two exponent is a compile-time annotation, free in
hardware).  The module is fully pipelined with II = 1, or purely
combinational when ``max_delay_per_stage`` is None.
"""

from __future__ import annotations


from .dais import KIND_ADD, KIND_INPUT, KIND_NEG, DAISProgram
from .pipelining import pipeline


def _signed_width(q) -> int:
    """Declared width of a value carried on a ``signed`` wire.

    ``QInterval.width`` is the minimal two's-complement width for the
    interval — but for a non-negative interval (e.g. unsigned inputs or
    all-positive dot products) that count has no sign bit, and a
    ``signed [w-1:0]`` wire of that width wraps the upper half of the
    range (255 on an 8-bit signed wire reads back as -1, and every
    downstream sign-extension propagates the corruption).  All wires in
    the emitted module are declared signed so the Verilog expression
    rules keep arithmetic signed throughout; non-negative values
    therefore pay one explicit sign bit.  Caught by RTL co-simulation
    (see rtlsim/cosim); exercised in tests/test_rtlsim.py.
    """
    w = q.width + (0 if q.lo < 0 else 1)
    return max(w, 1)


def _w(prog: DAISProgram, i: int) -> int:
    return _signed_width(prog.rows[i].qint)


def emit_verilog(
    prog: DAISProgram,
    module_name: str = "cmvm",
    max_delay_per_stage: int | None = 5,
) -> str:
    """Emit a Verilog-2001 module computing the program's outputs."""
    pipelined = max_delay_per_stage is not None
    rep = pipeline(prog, max_delay_per_stage if pipelined else 1 << 30)
    n_stage = rep.n_stages if pipelined else 1

    lines: list[str] = []
    ports = ["input wire clk"] if pipelined else []
    for i in range(prog.n_inputs):
        ports.append(f"input wire signed [{_w(prog, i)-1}:0] x{i}")
    out_widths = [_signed_width(q) for q in prog.output_qints()]
    for j, w in enumerate(out_widths):
        ports.append(f"output wire signed [{w-1}:0] y{j}")
    lines.append(f"module {module_name} (")
    lines.append("  " + ",\n  ".join(ports))
    lines.append(");")

    # Declarations: each row value, once per pipeline stage it survives.
    names: dict[tuple[int, int], str] = {}  # (row, stage) -> wire/reg name

    def declare(i: int, s: int, reg: bool) -> str:
        name = f"v{i}_s{s}"
        kind = "reg" if reg else "wire"
        lines.append(f"  {kind} signed [{_w(prog, i)-1}:0] {name};")
        names[(i, s)] = name
        return name

    last_use = [rep.stage_of_row[i] for i in range(len(prog.rows))]
    for i, r in enumerate(prog.rows):
        if r.kind != KIND_INPUT:
            for o in ([r.a] if r.b < 0 else [r.a, r.b]):
                last_use[o] = max(last_use[o], rep.stage_of_row[i])
    for t in prog.outputs:
        if t is not None:
            # max, not assignment: an output row may also feed an op in a
            # LATER stage than any output (dead or auxiliary logic), and
            # clobbering its last_use would drop the stage-carry register
            # — the late op would then read a value one cycle too new
            # (caught by rtlsim's register-balance check)
            last_use[t.row] = max(last_use[t.row], n_stage - 1)

    regs: list[tuple[str, str]] = []  # (dst, src) clocked assignments
    for i, r in enumerate(prog.rows):
        s0 = rep.stage_of_row[i]
        name = declare(i, s0, reg=False)
        if r.kind == KIND_INPUT:
            lines.append(f"  assign {name} = x{i};")
        elif r.kind == KIND_ADD:
            a = names[(r.a, s0)] if (r.a, s0) in names else names[(r.a, rep.stage_of_row[r.a])]
            b = names[(r.b, s0)] if (r.b, s0) in names else names[(r.b, rep.stage_of_row[r.b])]
            sa = f"({a} <<< {r.sh_a})" if r.sh_a else a
            sb = f"({b} <<< {r.sh_b})" if r.sh_b else b
            op = "+" if r.sign > 0 else "-"
            lines.append(f"  assign {name} = {sa} {op} {sb};")
        else:  # KIND_NEG
            a = names[(r.a, s0)]
            lines.append(f"  assign {name} = -{a};")
        # carry across stage boundaries
        for s in range(s0 + 1, last_use[i] + 1):
            nm = declare(i, s, reg=pipelined)
            if pipelined:
                regs.append((nm, names[(i, s - 1)]))
            else:
                lines.append(f"  assign {nm} = {names[(i, s - 1)]};")

    if regs:
        lines.append("  always @(posedge clk) begin")
        for dst, src in regs:
            lines.append(f"    {dst} <= {src};")
        lines.append("  end")

    for j, t in enumerate(prog.outputs):
        if t is None:
            lines.append(f"  assign y{j} = 0;")
            continue
        src = names[(t.row, n_stage - 1)]
        expr = f"({src} <<< {t.shift})" if t.shift > 0 else (f"({src} >>> {-t.shift})" if t.shift < 0 else src)
        if t.sign < 0:
            expr = f"-{expr}"
        lines.append(f"  assign y{j} = {expr};")
    lines.append("endmodule")
    return "\n".join(lines)
