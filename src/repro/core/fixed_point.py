"""Fixed-point / quantized-interval arithmetic for the da4ml solver.

The paper (§4.1) tracks every value in the adder graph as a *quantized
interval* ``[l, h, delta]``: the lowest representable value, the highest
representable value, and the step size.  For a generic fixed-point number
``fixed<S, W, I>`` (S = sign bit, W = total width, I = integer bits
including sign):

    l     = -S * 2^(I-S)
    h     =  2^(I-S) - 2^(-W+I)
    delta =  2^(-W+I)

Tracking intervals instead of (W, I) pairs lets the solver accumulate many
terms without paying a blanket carry bit per addition: the exact reachable
range is propagated instead.

All interval endpoints are stored as *exact* integers scaled by the step:
we represent a qint as ``(lo, hi, exp)`` meaning the real interval
``[lo * 2^exp, hi * 2^exp]`` with step ``2^exp``, where ``lo``/``hi`` are
Python ints (arbitrary precision, no overflow).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class QInterval:
    """Quantized interval [lo * 2^exp, hi * 2^exp] with step 2^exp.

    ``lo`` and ``hi`` are exact integers; ``exp`` is the base-2 exponent of
    the quantization step.  ``lo <= hi`` always.  The degenerate constant 0
    is represented as (0, 0, 0).
    """

    lo: int
    hi: int
    exp: int

    def __post_init__(self) -> None:
        if self.lo > self.hi:
            raise ValueError(f"QInterval lo {self.lo} > hi {self.hi}")

    # ------------------------------------------------------------------
    # Constructors
    # ------------------------------------------------------------------
    @staticmethod
    def from_fixed(signed: bool, width: int, int_bits: int) -> "QInterval":
        """Build from a fixed<S, W, I> spec (I includes the sign bit)."""
        if width <= 0:
            raise ValueError("width must be positive")
        s = 1 if signed else 0
        exp = int_bits - width  # step = 2^(I - W)
        n_mag = width - s
        if signed:
            lo = -(1 << n_mag)
            hi = (1 << n_mag) - 1
        else:
            lo = 0
            hi = (1 << n_mag) - 1
        return QInterval(lo, hi, exp)

    @staticmethod
    def constant(value_num: int, exp: int = 0) -> "QInterval":
        return QInterval(value_num, value_num, exp)

    # ------------------------------------------------------------------
    # Properties
    # ------------------------------------------------------------------
    @property
    def is_zero(self) -> bool:
        return self.lo == 0 and self.hi == 0

    @property
    def signed(self) -> bool:
        return self.lo < 0

    @property
    def width(self) -> int:
        """Total bitwidth W needed to represent every point on the grid."""
        if self.is_zero:
            return 0
        # magnitude bits to cover max(|lo|, hi) given two's complement
        if self.lo < 0:
            mag = max(self.hi, -self.lo - 1)
            return mag.bit_length() + 1 if mag > 0 else 1
        return self.hi.bit_length()

    @property
    def msb(self) -> int:
        """Position (exponent) of the most significant bit, inclusive."""
        return self.exp + self.width - 1

    @property
    def lsb(self) -> int:
        """Position (exponent) of the least significant bit."""
        return self.exp

    # ------------------------------------------------------------------
    # Arithmetic
    # ------------------------------------------------------------------
    def shift(self, s: int) -> "QInterval":
        """Multiply by 2^s (free in hardware: bit reinterpretation)."""
        if s == 0 or self.is_zero:
            return self
        return QInterval(self.lo, self.hi, self.exp + s)

    def neg(self) -> "QInterval":
        return QInterval(-self.hi, -self.lo, self.exp)

    def add(self, other: "QInterval") -> "QInterval":
        return _combine(self, other, +1)

    def sub(self, other: "QInterval") -> "QInterval":
        return _combine(self, other, -1)

    def scale(self, k: int) -> "QInterval":
        """Multiply by an exact integer constant k."""
        if k == 0:
            return QInterval(0, 0, 0)
        a, b = self.lo * k, self.hi * k
        return QInterval(min(a, b), max(a, b), self.exp)

    def union(self, other: "QInterval") -> "QInterval":
        if self.is_zero:
            return other
        if other.is_zero:
            return self
        exp = min(self.exp, other.exp)
        lo = min(self.lo << (self.exp - exp), other.lo << (other.exp - exp))
        hi = max(self.hi << (self.exp - exp), other.hi << (other.exp - exp))
        return QInterval(lo, hi, exp)

    def contains_value(self, v_num: int, v_exp: int) -> bool:
        """Whether value v_num * 2^v_exp lies on this interval's grid."""
        if self.is_zero:
            return v_num == 0
        d = v_exp - self.exp
        if d < 0:
            return False
        n = v_num << d
        return self.lo <= n <= self.hi


def _combine(a: QInterval, b: QInterval, sign: int) -> QInterval:
    """a + sign*b with exact interval propagation."""
    if b.is_zero:
        return a
    if a.is_zero:
        return b if sign > 0 else b.neg()
    exp = min(a.exp, b.exp)
    alo, ahi = a.lo << (a.exp - exp), a.hi << (a.exp - exp)
    blo, bhi = b.lo << (b.exp - exp), b.hi << (b.exp - exp)
    if sign > 0:
        return QInterval(alo + blo, ahi + bhi, exp)
    return QInterval(alo - bhi, ahi - blo, exp)


def qint_add_shifted(a: QInterval, b: QInterval, shift: int, sign: int) -> QInterval:
    """Interval of ``a + sign * (b << shift)``."""
    return _combine(a, b.shift(shift), sign)
