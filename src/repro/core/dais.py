"""DAIS — Distributed Arithmetic Instruction Set (paper §5.2).

A DAIS program is a static-single-assignment list of shift-add operations
that directly describes a combinational circuit.  Every value is a row in
the program; every non-input row is one two-operand adder/subtractor of
the canonical form

    u = (a << sh_a)  +/-  (b << sh_b)          (sh_a, sh_b >= 0, min == 0)

plus a rare unary negation ``u = -a`` (realised in hardware as ``0 - a``
and therefore costed as an adder).  Outputs are *terms*: a row reference
with a free power-of-two scale and a sign, ``y = sign * (row << shift)``
(shift may be negative for fractional fixed point).

The program carries exact quantized intervals (:class:`QInterval`) and
adder depths per row, which drive the paper's cost model (Eq. 1) and the
delay-constraint machinery.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .fixed_point import QInterval

KIND_INPUT = 0
KIND_ADD = 1  # u = (a << sh_a) + sign * (b << sh_b)
KIND_NEG = 2  # u = -a


def qints_to_array(qints: list[QInterval]) -> np.ndarray:
    """Pack QIntervals into an int64 [n, 3] (lo, hi, exp) array.

    Shared serialization helper for the solution cache and the design
    artifact format.  Raises ``OverflowError`` when an endpoint does not
    fit in int64 (callers then skip serialization)."""
    lim = 1 << 62
    out = np.empty((len(qints), 3), dtype=np.int64)
    for i, q in enumerate(qints):
        if not (-lim < q.lo <= q.hi < lim):
            raise OverflowError("qint endpoints exceed int64 range")
        out[i] = (q.lo, q.hi, q.exp)
    return out


def qints_from_array(arr: np.ndarray) -> list[QInterval]:
    """Exact inverse of :func:`qints_to_array`."""
    return [
        QInterval(lo, hi, exp)
        for lo, hi, exp in np.asarray(arr, dtype=np.int64).tolist()
    ]


@dataclass
class Row:
    kind: int
    a: int = -1
    b: int = -1
    sh_a: int = 0
    sh_b: int = 0
    sign: int = 1  # sign applied to operand b
    qint: QInterval = QInterval(0, 0, 0)
    depth: int = 0
    cost: int = 0  # full/half adder bits (Eq. 1)


@dataclass(frozen=True)
class Term:
    """A value reference: ``sign * (row << shift)``."""

    sign: int
    row: int
    shift: int


@dataclass
class DAISProgram:
    """SSA shift-add program with per-row interval/depth metadata."""

    rows: list[Row] = field(default_factory=list)
    n_inputs: int = 0
    # One entry per output; None encodes the constant 0 output.
    outputs: list[Term | None] = field(default_factory=list)

    # ------------------------------------------------------------------
    # Construction
    # ------------------------------------------------------------------
    def add_input(self, qint: QInterval, depth: int = 0) -> int:
        if any(r.kind != KIND_INPUT for r in self.rows):
            raise ValueError("inputs must be added before ops")
        self.rows.append(Row(KIND_INPUT, qint=qint, depth=depth))
        self.n_inputs += 1
        return len(self.rows) - 1

    def add_op(self, a: int, b: int, sh_a: int, sh_b: int, sign: int) -> int:
        """Append ``u = (a << sh_a) + sign * (b << sh_b)``; returns row idx.

        The interval and cost arithmetic is inlined (equivalent to
        ``qint_add_shifted`` + ``adder_cost`` on the shifted qints, with
        operands pre-shifted so the cost model sees zero shifts): this is
        the solver's per-adder hot path, and constructing the
        intermediate shifted QIntervals dominated its runtime.
        """
        if min(sh_a, sh_b) != 0:
            # normalise: factor out the common power of two (free shift)
            m = min(sh_a, sh_b)
            sh_a, sh_b = sh_a - m, sh_b - m
        ra, rb = self.rows[a], self.rows[b]
        qA, qB = ra.qint, rb.qint
        alo, ahi = qA.lo, qA.hi
        blo, bhi = qB.lo, qB.hi
        az = alo == 0 == ahi
        bz = blo == 0 == bhi
        # QInterval.shift keeps exp unchanged on zero intervals
        aexp = qA.exp if az else qA.exp + sh_a
        bexp = qB.exp if bz else qB.exp + sh_b
        if bz:
            qint = QInterval(alo, ahi, aexp)
            cost = 0
        elif az:
            qint = (
                QInterval(blo, bhi, bexp) if sign > 0 else QInterval(-bhi, -blo, bexp)
            )
            cost = 0
        else:
            exp = aexp if aexp <= bexp else bexp
            al, ah = alo << (aexp - exp), ahi << (aexp - exp)
            bl, bh = blo << (bexp - exp), bhi << (bexp - exp)
            if sign > 0:
                qint = QInterval(al + bl, ah + bh, exp)
            else:
                qint = QInterval(al - bh, ah - bl, exp)
            # two's-complement widths (QInterval.width inlined)
            if alo < 0:
                mag = ahi if ahi > -alo - 1 else -alo - 1
                wa = mag.bit_length() + 1 if mag > 0 else 1
            else:
                wa = ahi.bit_length()
            if blo < 0:
                mag = bhi if bhi > -blo - 1 else -blo - 1
                wb = mag.bit_length() + 1 if mag > 0 else 1
            else:
                wb = bhi.bit_length()
            amsb = aexp + wa - 1
            bmsb = bexp + wb - 1
            msb = amsb if amsb >= bmsb else bmsb
            lsb_hi = aexp if aexp >= bexp else bexp
            lsb_lo = aexp if aexp <= bexp else bexp
            # disjoint ranges: splice, not adder logic (see adder_cost)
            cost = 1 if lsb_hi > msb else msb - lsb_lo + 2
        depth = max(ra.depth, rb.depth) + 1
        self.rows.append(Row(KIND_ADD, a, b, sh_a, sh_b, sign, qint, depth, cost))
        return len(self.rows) - 1

    def add_neg(self, a: int) -> int:
        ra = self.rows[a]
        self.rows.append(
            Row(KIND_NEG, a, -1, 0, 0, -1, ra.qint.neg(), ra.depth + 1, ra.qint.width + 1)
        )
        return len(self.rows) - 1

    # ------------------------------------------------------------------
    # Stats
    # ------------------------------------------------------------------
    @property
    def n_adders(self) -> int:
        return sum(1 for r in self.rows if r.kind != KIND_INPUT)

    @property
    def depth(self) -> int:
        """Longest adder path from any input to any output."""
        d = 0
        for t in self.outputs:
            if t is not None:
                d = max(d, self.rows[t.row].depth)
        return d

    @property
    def cost_bits(self) -> int:
        """Total full/half-adder bit cost (proxy for FPGA LUTs)."""
        return sum(r.cost for r in self.rows if r.kind != KIND_INPUT)

    def output_qints(self) -> list[QInterval]:
        """Intervals of the *evaluated* outputs, on the evaluation grid.

        ``evaluate`` (and the Pallas executor) returns integers with the
        term shift already applied, i.e. on the term's row grid — so the
        interval endpoints are shifted while ``exp`` stays the row's.
        """
        out = []
        for t in self.outputs:
            if t is None:
                out.append(QInterval(0, 0, 0))
            else:
                q = self.rows[t.row].qint
                if t.shift >= 0:
                    q = QInterval(q.lo << t.shift, q.hi << t.shift, q.exp)
                else:
                    q = QInterval(q.lo >> (-t.shift), q.hi >> (-t.shift), q.exp)
                out.append(q.neg() if t.sign < 0 else q)
        return out

    def output_depths(self) -> list[int]:
        return [0 if t is None else self.rows[t.row].depth for t in self.outputs]

    # ------------------------------------------------------------------
    # Pruning
    # ------------------------------------------------------------------
    def prune(self) -> "DAISProgram":
        """Drop rows not reachable from any output (keep all inputs)."""
        live = [False] * len(self.rows)
        stack = [t.row for t in self.outputs if t is not None]
        while stack:
            i = stack.pop()
            if live[i]:
                continue
            live[i] = True
            r = self.rows[i]
            if r.kind != KIND_INPUT:
                stack.append(r.a)
                if r.kind == KIND_ADD:
                    stack.append(r.b)
        remap: dict[int, int] = {}
        new = DAISProgram()
        rows = new.rows
        for i, r in enumerate(self.rows):
            if r.kind == KIND_INPUT:
                remap[i] = len(rows)
                rows.append(Row(KIND_INPUT, qint=r.qint, depth=r.depth))
                new.n_inputs += 1
            elif live[i]:
                # qint/depth/cost are invariant under pruning: copy the row
                # with remapped operands instead of recomputing through
                # add_op (which would redo the exact interval arithmetic)
                remap[i] = len(rows)
                b = remap[r.b] if r.kind == KIND_ADD else -1
                rows.append(
                    Row(r.kind, remap[r.a], b, r.sh_a, r.sh_b, r.sign,
                        r.qint, r.depth, r.cost)
                )
        new.outputs = [
            None if t is None else Term(t.sign, remap[t.row], t.shift) for t in self.outputs
        ]
        return new

    # ------------------------------------------------------------------
    # Array round-trip (solution cache / disk serialization)
    # ------------------------------------------------------------------
    def to_arrays(self) -> dict[str, np.ndarray]:
        """Pack the program into plain int64 numpy arrays.

        The row table stores (kind, a, b, sh_a, sh_b, sign, depth, cost,
        q_lo, q_hi, q_exp); outputs store (present, sign, row, shift).
        Exact round-trip via :meth:`from_arrays` — qints are stored, not
        recomputed.  Raises ``OverflowError`` if an interval endpoint does
        not fit in int64 (callers should then skip caching).
        """
        lim = 1 << 62
        rows = np.empty((len(self.rows), 11), dtype=np.int64)
        for i, r in enumerate(self.rows):
            q = r.qint
            if not (-lim < q.lo <= q.hi < lim):
                raise OverflowError("qint endpoints exceed int64 range")
            rows[i] = (
                r.kind, r.a, r.b, r.sh_a, r.sh_b, r.sign, r.depth, r.cost,
                q.lo, q.hi, q.exp,
            )
        outs = np.zeros((len(self.outputs), 4), dtype=np.int64)
        for i, t in enumerate(self.outputs):
            if t is not None:
                outs[i] = (1, t.sign, t.row, t.shift)
        return {
            "rows": rows,
            "outputs": outs,
            "n_inputs": np.array([self.n_inputs], dtype=np.int64),
        }

    @staticmethod
    def from_arrays(arrays: dict[str, np.ndarray]) -> "DAISProgram":
        """Exact inverse of :meth:`to_arrays`."""
        prog = DAISProgram()
        prog.n_inputs = int(arrays["n_inputs"][0])
        for row in np.asarray(arrays["rows"], dtype=np.int64).tolist():
            kind, a, b, sh_a, sh_b, sign, depth, cost, lo, hi, exp = row
            prog.rows.append(
                Row(kind, a, b, sh_a, sh_b, sign, QInterval(lo, hi, exp), depth, cost)
            )
        prog.outputs = [
            Term(sign, row, shift) if present else None
            for present, sign, row, shift in
            np.asarray(arrays["outputs"], dtype=np.int64).tolist()
        ]
        return prog

    # ------------------------------------------------------------------
    # Evaluation (exact, integer)
    # ------------------------------------------------------------------
    def evaluate(self, x: np.ndarray) -> np.ndarray:
        """Evaluate the program on integer inputs.

        ``x``: int array [..., n_inputs] on the *integer grid* of each
        input's qint (i.e. x_real = x * 2^exp).  Returns the outputs as
        int64 on the grids given by :meth:`output_qints` — concretely,
        output j equals ``sign * (value_row << shift)`` computed exactly,
        with negative shifts handled by the caller via the qint exps.
        Here all shifts produced by the solver on the integer grid are
        non-negative, so plain int64 shifts are exact.
        """
        x = np.asarray(x)
        if x.shape[-1] != self.n_inputs:
            raise ValueError(f"expected {self.n_inputs} inputs, got {x.shape[-1]}")
        vals: list[np.ndarray] = []
        for i, r in enumerate(self.rows):
            if r.kind == KIND_INPUT:
                vals.append(x[..., i].astype(np.int64))
            elif r.kind == KIND_ADD:
                vals.append((vals[r.a] << r.sh_a) + r.sign * (vals[r.b] << r.sh_b))
            else:
                vals.append(-vals[r.a])
        outs = []
        zero = np.zeros(x.shape[:-1], dtype=np.int64)
        for t in self.outputs:
            if t is None:
                outs.append(zero)
            elif t.shift >= 0:
                outs.append(t.sign * (vals[t.row] << t.shift))
            else:
                outs.append(t.sign * (vals[t.row] >> (-t.shift)))
        return np.stack(outs, axis=-1)

    # ------------------------------------------------------------------
    # Levelisation (for the Pallas executor)
    # ------------------------------------------------------------------
    def levelize(self) -> list[list[int]]:
        """Group op row indices by adder depth (ascending)."""
        by_depth: dict[int, list[int]] = {}
        for i, r in enumerate(self.rows):
            if r.kind != KIND_INPUT:
                by_depth.setdefault(r.depth, []).append(i)
        return [by_depth[d] for d in sorted(by_depth)]
