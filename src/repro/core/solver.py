"""da4ml CMVM solver: two-stage pipeline (paper §4).

``solve_cmvm`` takes a constant matrix (integer, or fixed-point floats on
a power-of-two grid) and emits a :class:`DAISProgram` computing
``y = x @ M`` exactly as a shift-add adder graph:

  stage 1  graph decomposition  M = M1 @ M2      (graph_decompose)
  stage 2  cost-aware CSE on M1 and on M2        (cse)
  final    per-output minimal-depth adder trees  (cse._assemble)

The delay constraint ``dc`` is the number of extra adder-depth levels
allowed beyond each output's minimal achievable depth (dc = -1: none).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from collections.abc import Sequence

import numpy as np

from ..flow.config import UNSET, SolverConfig, resolve_legacy
from ..obs import solvelog, trace
from .cache import SolutionCache, solve_key
from .cost import ceil_log2, min_tree_depth
from .csd import csd_nnz
from .cse import CSE
from .dais import DAISProgram
from .fixed_point import QInterval
from .graph_decompose import decompose


@dataclass
class Solution:
    program: DAISProgram
    matrix: np.ndarray  # integer matrix on the input grid
    out_scale_exp: int  # real M = matrix * 2^out_scale_exp
    dc: int
    solver_time_s: float
    decomposed: bool
    stats: dict = field(default_factory=dict)
    # packed ``DAISProgram.to_arrays`` dict when one already exists (set
    # by the SolutionCache on hit AND on put) — consumers treat it as
    # read-only and skip re-packing the program (see compile_model)
    program_arrays: dict | None = field(default=None, repr=False)

    @property
    def n_adders(self) -> int:
        return self.program.n_adders

    @property
    def depth(self) -> int:
        return self.program.depth

    @property
    def cost_bits(self) -> int:
        return self.program.cost_bits

    @property
    def lut_estimate(self) -> int:
        return self.program.cost_bits

    def evaluate(self, x: np.ndarray) -> np.ndarray:
        """Exact integer evaluation of y = x @ matrix (grid units)."""
        return self.program.evaluate(x)

    def verify(self, n: int = 64, seed: int = 0) -> bool:
        rng = np.random.default_rng(seed)
        lo = np.array([q.lo for q in self._in_qints()], dtype=np.int64)
        hi = np.array([q.hi for q in self._in_qints()], dtype=np.int64)
        x = rng.integers(lo, hi + 1, size=(n, len(lo)), dtype=np.int64)
        want = x @ self.matrix
        got = self.evaluate(x)
        return bool(np.array_equal(want, got))

    def _in_qints(self) -> list[QInterval]:
        return [r.qint for r in self.program.rows[: self.program.n_inputs]]


def _integerize(m: np.ndarray, max_frac_bits: int = 32) -> tuple[np.ndarray, int]:
    """Scale a float matrix on a power-of-two grid to exact integers."""
    m = np.asarray(m)
    if np.issubdtype(m.dtype, np.integer):
        return m.astype(np.int64), 0
    for k in range(max_frac_bits + 1):
        scaled = m * (1 << k)
        if np.allclose(scaled, np.round(scaled), rtol=0, atol=0):
            return np.round(scaled).astype(np.int64), -k
    raise ValueError("matrix entries are not on a power-of-two grid")


def _budgets(
    m: np.ndarray, in_depths: Sequence[int], dc: int
) -> tuple[list[int | None], list[int]]:
    """Per-output depth budgets: minimal achievable depth + dc."""
    if dc < 0:
        # unconstrained: no caller consumes the per-output minima, so
        # skip the CSD population counts and tree simulations entirely
        return [None] * m.shape[1], []
    nnz = csd_nnz(m)  # [d_in, d_out]
    mins: list[int] = []
    for j in range(m.shape[1]):
        leaf_depths: list[int] = []
        for i in range(m.shape[0]):
            leaf_depths.extend([in_depths[i]] * int(nnz[i, j]))
        mins.append(min_tree_depth(leaf_depths) if leaf_depths else 0)
    return [mn + dc for mn in mins], mins


# legacy kwarg name -> SolverConfig field
_LEGACY_SOLVER_KWARGS = {
    "dc": "dc",
    "decompose_stage": "decompose",
    "weighted": "weighted",
    "assembly_dedup": "dedup",
    "depth_weight": "depth_weight",
    "engine": "engine",
}


def solve_cmvm(
    m: np.ndarray,
    qint_in: Sequence[QInterval] | None = None,
    depth_in: Sequence[int] | None = None,
    dc=UNSET,
    decompose_stage=UNSET,
    weighted=UNSET,
    assembly_dedup=UNSET,
    depth_weight=UNSET,
    engine=UNSET,
    program: DAISProgram | None = None,
    input_rows: Sequence[int] | None = None,
    cache: SolutionCache | None = None,
    config: SolverConfig | None = None,
) -> Solution:
    """Optimize ``y = x @ m`` into an adder graph.

    The canonical way to set solver options is ``config=``, a
    :class:`repro.flow.SolverConfig`.  The individual option kwargs
    (``dc``, ``decompose_stage``, ``weighted``, ``assembly_dedup``,
    ``depth_weight``, ``engine``) are a deprecated shim kept for one
    release: they construct the equivalent config and delegate, so both
    spellings produce bit-identical programs.

    Parameters
    ----------
    m : [d_in, d_out] constant matrix (ints, or floats on a 2^-k grid).
    qint_in : per-input quantized intervals (default: signed 8-bit ints).
    depth_in : per-input adder depths (default 0; used when chaining
        CMVMs, e.g. consecutive NN layers).
    config : :class:`SolverConfig` — dc (delay constraint, -1 =
        unconstrained as in the paper's tables), CSE ``engine`` ("batch"
        vectorized default / "arena" preallocated-workspace fast path /
        "heap" exact reference — all bit-identical), stage-1
        ``decompose``, ``weighted``/``dedup``/``depth_weight`` CSE
        scoring knobs.
    program / input_rows : optionally extend an existing program whose
        rows ``input_rows`` are this CMVM's inputs (NN layer chaining).
    cache : optional content-addressed :class:`SolutionCache`; only used
        on the fresh-program path (not when extending via ``program``).
    """
    legacy = {
        name: val
        for name, val in (
            ("dc", dc),
            ("decompose_stage", decompose_stage),
            ("weighted", weighted),
            ("assembly_dedup", assembly_dedup),
            ("depth_weight", depth_weight),
            ("engine", engine),
        )
        if val is not UNSET
    }
    config = resolve_legacy(
        "solve_cmvm", config, legacy, SolverConfig,
        lambda lg: SolverConfig(**{_LEGACY_SOLVER_KWARGS[k]: v for k, v in lg.items()}),
    )
    return _solve_cmvm(
        m, qint_in, depth_in, config, program=program, input_rows=input_rows, cache=cache
    )


def _solve_cmvm(
    m: np.ndarray,
    qint_in: Sequence[QInterval] | None,
    depth_in: Sequence[int] | None,
    cfg: SolverConfig,
    program: DAISProgram | None = None,
    input_rows: Sequence[int] | None = None,
    cache: SolutionCache | None = None,
) -> Solution:
    """Config-consuming solver core (all public paths delegate here).

    Wraps the implementation in a ``solver.solve_cmvm`` trace span (a
    no-op unless ``REPRO_TRACE`` is on) and appends one structured
    record per solve to :mod:`repro.obs.solvelog`.
    """
    shape = getattr(m, "shape", (0, 0))
    with trace.span(
        "solver.solve_cmvm",
        d_in=int(shape[0]),
        d_out=int(shape[1]) if len(shape) > 1 else 1,
        engine=getattr(cfg, "engine", "?"),
        dc=getattr(cfg, "dc", None),
    ):
        return _solve_cmvm_impl(
            m, qint_in, depth_in, cfg,
            program=program, input_rows=input_rows, cache=cache,
        )


def _solve_cmvm_impl(
    m: np.ndarray,
    qint_in: Sequence[QInterval] | None,
    depth_in: Sequence[int] | None,
    cfg: SolverConfig,
    program: DAISProgram | None = None,
    input_rows: Sequence[int] | None = None,
    cache: SolutionCache | None = None,
) -> Solution:
    if not isinstance(cfg, SolverConfig):
        from ..flow.config import ConfigError

        raise ConfigError(
            f"solve_cmvm: config must be a SolverConfig, got {type(cfg).__name__}"
        )
    dc = cfg.dc
    decompose_stage = cfg.decompose
    weighted = cfg.weighted
    assembly_dedup = cfg.dedup
    depth_weight = cfg.depth_weight
    engine = cfg.engine
    t0 = time.perf_counter()
    m_int, scale_exp = _integerize(m)
    d_in, d_out = m_int.shape

    key = None
    if program is None:
        program = DAISProgram()
        if qint_in is None:
            qint_in = [QInterval.from_fixed(True, 8, 8)] * d_in
        if depth_in is None:
            depth_in = [0] * d_in
        if cache is not None:
            # cache identity = matrix/qints/depths + the config digest
            # (one definition of "same solve" across solver and compiler)
            key = solve_key(m_int, qint_in, depth_in, kind="da", solver=cfg.digest())
            hit = cache.get(key)
            if hit is not None:
                hit.out_scale_exp = scale_exp
                _log_solve_record(hit, m_int, cfg, time.perf_counter() - t0, True)
                return hit
        input_rows = [program.add_input(q, d) for q, d in zip(qint_in, depth_in)]
    else:
        if input_rows is None:
            raise ValueError("input_rows required when extending a program")
        input_rows = list(input_rows)
    in_depths = [program.rows[r].depth for r in input_rows]

    budgets, _ = _budgets(m_int, in_depths, dc)

    use_decomp = decompose_stage and dc != 0 and d_out > 1
    stats: dict = {"engine": engine}
    if use_decomp:
        with trace.span("solver.decompose", d_in=d_in, d_out=d_out):
            dec = decompose(m_int, dc)
        stats["decomposition_trivial"] = dec.is_trivial
        stats["m1_cols"] = int(dec.m1.shape[1])
        if dec.is_trivial:
            use_decomp = False

    if use_decomp:
        # ---- stage 2a: CSE on M1 ----
        # budget for M1 column e: tightest consumer budget minus the depth
        # reserve needed to merge that consumer's path terms.
        k = dec.m1.shape[1]
        m1_budgets: list[int | None] = [None] * k
        if dc >= 0:
            for e in range(k):
                consumers = np.nonzero(dec.m2[e, :])[0]
                b = None
                for j in consumers:
                    bj = budgets[j]
                    if bj is None:
                        continue
                    cand = bj - ceil_log2(int(dec.path_len[j]))
                    b = cand if b is None else min(b, cand)
                m1_budgets[e] = None if b is None else max(b, 0)
        cols1 = [
            {input_rows[i]: int(dec.m1[i, e]) for i in range(d_in) if dec.m1[i, e] != 0}
            for e in range(k)
        ]
        cse1 = CSE(
            program, cols1, m1_budgets, weighted, assembly_dedup, depth_weight,
            engine=engine,
        )
        z_terms = cse1.run()
        stats["stage1_cse"] = cse1.stats

        # ---- stage 2b: CSE on M2 (rows rebased onto z program rows) ----
        cols2: list[dict[int, int]] = []
        for j in range(d_out):
            col: dict[int, int] = {}
            for e in range(k):
                c = int(dec.m2[e, j])
                if c == 0 or z_terms[e] is None:
                    continue
                t = z_terms[e]
                col[t.row] = col.get(t.row, 0) + c * t.sign * (1 << t.shift)
            cols2.append(col)
        cse2 = CSE(
            program, cols2, budgets, weighted, assembly_dedup, depth_weight,
            engine=engine,
        )
        outputs = cse2.run()
        stats["stage2_cse"] = cse2.stats
    else:
        cols = [
            {input_rows[i]: int(m_int[i, j]) for i in range(d_in) if m_int[i, j] != 0}
            for j in range(d_out)
        ]
        cse = CSE(
            program, cols, budgets, weighted, assembly_dedup, depth_weight,
            engine=engine,
        )
        outputs = cse.run()
        stats["stage2_cse"] = cse.stats

    program.outputs = outputs
    pruned = program.prune()
    dt = time.perf_counter() - t0
    sol = Solution(pruned, m_int, scale_exp, dc, dt, use_decomp, stats)
    if key is not None:
        cache.put(key, sol)
    _log_solve_record(sol, m_int, cfg, dt, False)
    return sol


def _log_solve_record(
    sol: Solution, m_int: np.ndarray, cfg: SolverConfig,
    wall_s: float, cache_hit: bool,
) -> None:
    """One flat per-solve record (matrix stats -> outcome) for the
    resource-predictor training log (repro.obs.solvelog)."""
    solvelog.log_solve(
        {
            "kind": "cmvm",
            "engine": cfg.engine,
            "dc": cfg.dc,
            "decomposed": bool(sol.decomposed),
            "d_in": int(m_int.shape[0]),
            "d_out": int(m_int.shape[1]),
            "nnz": int(np.count_nonzero(m_int)),
            "w_max_abs": int(np.abs(m_int).max()) if m_int.size else 0,
            "adders": int(sol.n_adders),
            "cost_bits": int(sol.cost_bits),
            "depth": int(sol.depth),
            "wall_s": wall_s,
            "cache_hit": cache_hit,
        }
    )


def config_solve_key(
    m_int, qint_in, depth_in, cfg: SolverConfig, kind: str = "da"
) -> str:
    """Cache key of one solve under ``cfg`` — exactly the key
    ``solve_cmvm(..., config=cfg, cache=...)`` uses internally, so the
    compiler's deferred-solve path and direct solver calls share cache
    entries by construction."""
    return solve_key(m_int, qint_in, depth_in, kind=kind, solver=cfg.digest())


def default_solve_key(
    m_int, qint_in, depth_in, dc: int, kind: str = "da",
    engine: str | None = None,
) -> str:
    """Deprecated shim: cache key for a solve with every option at its
    :class:`SolverConfig` default (``engine`` optionally overridden).
    Use :func:`config_solve_key`."""
    cfg = SolverConfig(dc=dc) if engine is None else SolverConfig(dc=dc, engine=engine)
    return config_solve_key(m_int, qint_in, depth_in, cfg, kind=kind)


def solve_task(payload) -> "Solution":
    """One CMVM solve from a plain-tuple payload
    ``(w_int, qin, strategy, solver_config_dict)`` — the compiler's
    deferred-solve unit.  Legacy ``(w_int, qin, strategy, dc[, engine])``
    tuples are still accepted.

    Lives in this jax-free module so solve-pool workers (the compiler's
    GIL-releasing thread pool, see ``repro.nn.compiler``) touch only
    numpy-land code; the payload stays picklable for callers that still
    want to farm solves across processes.
    """
    w_int, qin, strategy, opts = payload[:4]
    if isinstance(opts, dict):
        cfg = SolverConfig.from_dict(opts)
    else:  # legacy payload: opts is dc, optional 5th element is engine
        engine = payload[4] if len(payload) > 4 else "batch"
        cfg = SolverConfig(dc=opts, engine=engine)
    if strategy == "latency":
        return naive_adder_tree(w_int, qint_in=qin)
    return _solve_cmvm(w_int, qin, None, cfg)


def naive_adder_tree(
    m: np.ndarray,
    qint_in: Sequence[QInterval] | None = None,
    depth_in: Sequence[int] | None = None,
) -> Solution:
    """Baseline: per-output CSD adder tree without any sharing.

    This models the resource behaviour of the fully-unrolled hls4ml
    'latency' strategy (each output is an independent MAC tree), expressed
    in the same adder/cost units so comparisons are apples-to-apples.
    """
    t0 = time.perf_counter()
    m_int, scale_exp = _integerize(m)
    d_in, d_out = m_int.shape
    program = DAISProgram()
    if qint_in is None:
        qint_in = [QInterval.from_fixed(True, 8, 8)] * d_in
    if depth_in is None:
        depth_in = [0] * d_in
    input_rows = [program.add_input(q, d) for q, d in zip(qint_in, depth_in)]
    cols = [
        {input_rows[i]: int(m_int[i, j]) for i in range(d_in) if m_int[i, j] != 0}
        for j in range(d_out)
    ]
    # skip the CSE loop entirely (no counts, empty heap): assembly only
    cse = CSE(
        program, cols, [None] * d_out, weighted=False, assembly_dedup=False,
        build_counts=False,
    )
    outputs = cse.run()
    program.outputs = outputs
    dt = time.perf_counter() - t0
    sol = Solution(program.prune(), m_int, scale_exp, -1, dt, False, {"baseline": True})
    return sol
