"""Content-addressed CMVM solution cache.

``solve_cmvm`` is deterministic: the resulting :class:`DAISProgram` is a
pure function of (integer matrix, input qints, input depths, dc, solver
options).  This module hashes exactly that tuple and memoizes the solved
program, so repeated compiles — conv layers sharing one CMVM, benchmark
reruns, serve restarts — skip the solver entirely:

  * key: sha256 over the matrix bytes/shape, the (lo, hi, exp) triple of
    every input qint, the input depths, and every solver option
    (:func:`solve_key`);
  * value: the program serialized with ``DAISProgram.to_arrays`` (plain
    int64 arrays, exact round-trip) plus the integer matrix and solution
    metadata;
  * storage: in-memory LRU, optionally backed by a directory of ``.npz``
    files (``np.savez_compressed``, no pickle) that survives processes.

``get`` rebuilds a fresh ``Solution`` per call (no aliasing between
callers); hits carry ``stats={"cache_hit": True}`` and a near-zero
``solver_time_s`` so callers can assert that solves were skipped.
"""

from __future__ import annotations

import hashlib
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from pathlib import Path
from collections.abc import Sequence

import numpy as np

from .dais import DAISProgram
from .fixed_point import QInterval

_KEY_VERSION = b"da4ml-solution-cache-v1"


def solve_key(
    m_int: np.ndarray,
    qint_in: Sequence[QInterval],
    depth_in: Sequence[int],
    **options,
) -> str:
    """Content hash of one CMVM solve request."""
    h = hashlib.sha256(_KEY_VERSION)
    m = np.ascontiguousarray(np.asarray(m_int, dtype=np.int64))
    h.update(repr(m.shape).encode())
    h.update(m.tobytes())
    for q in qint_in:
        h.update(f"q{q.lo},{q.hi},{q.exp};".encode())
    h.update(("d" + ",".join(str(int(d)) for d in depth_in)).encode())
    for name in sorted(options):
        h.update(f"o{name}={options[name]!r};".encode())
    return h.hexdigest()


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    puts: int = 0
    disk_hits: int = 0
    skipped_unserializable: int = 0

    def as_dict(self) -> dict:
        return dict(self.__dict__)


def pack_solution(sol) -> dict:
    """Serialize a Solution to plain int64 numpy arrays (no pickle).

    Shared by the cache entries and any artifact code that persists
    solved programs.  Raises ``OverflowError`` if the program's qints do
    not fit in int64."""
    entry = dict(sol.program.to_arrays())
    entry["matrix"] = np.ascontiguousarray(sol.matrix, dtype=np.int64)
    entry["meta"] = np.array(
        [sol.out_scale_exp, sol.dc, int(sol.decomposed)], dtype=np.int64
    )
    return entry


_PROGRAM_ARRAY_KEYS = ("rows", "outputs", "n_inputs")


def program_arrays_of(entry: dict) -> dict:
    """The packed-program slice of a cache entry (the dict layout of
    ``DAISProgram.to_arrays``).  Threaded into ``Solution.program_arrays``
    so consumers (``design.programs``) reuse the already-packed arrays
    instead of round-tripping unpack -> repack."""
    return {k: entry[k] for k in _PROGRAM_ARRAY_KEYS}


def unpack_solution(entry: dict, lookup_s: float = 0.0):
    """Exact inverse of :func:`pack_solution` (fresh Solution per call).

    The returned Solution carries ``program_arrays`` aliasing the entry's
    packed program (treated read-only by all consumers), so a warm-cache
    compile never repacks a program it just unpacked."""
    from .solver import Solution  # local import: solver imports this module

    program = DAISProgram.from_arrays(entry)
    out_scale_exp, dc, decomposed = entry["meta"].tolist()
    return Solution(
        program=program,
        matrix=np.array(entry["matrix"], dtype=np.int64),
        out_scale_exp=int(out_scale_exp),
        dc=int(dc),
        solver_time_s=lookup_s,
        decomposed=bool(decomposed),
        stats={"cache_hit": True},
        program_arrays=program_arrays_of(entry),
    )


@dataclass
class SolutionCache:
    """In-memory LRU of solved CMVM programs, with optional disk backing."""

    max_items: int = 256
    disk_dir: str | None = None
    stats: CacheStats = field(default_factory=CacheStats)

    def __post_init__(self) -> None:
        self._mem: OrderedDict[str, dict] = OrderedDict()
        if self.disk_dir is not None:
            Path(self.disk_dir).mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._mem)

    def clear(self) -> None:
        self._mem.clear()
        self.stats = CacheStats()

    # ------------------------------------------------------------------
    def get(self, key: str):
        """Return a fresh ``Solution`` for ``key`` or None on miss."""
        t0 = time.perf_counter()
        entry = self._mem.get(key)
        if entry is not None:
            self._mem.move_to_end(key)
        elif self.disk_dir is not None:
            path = Path(self.disk_dir) / f"{key}.npz"
            if path.exists():
                with np.load(path, allow_pickle=False) as z:
                    entry = {name: z[name] for name in z.files}
                self.stats.disk_hits += 1
                self._remember(key, entry)
        if entry is None:
            self.stats.misses += 1
            return None
        self.stats.hits += 1
        return self._to_solution(entry, time.perf_counter() - t0)

    def put(self, key: str, sol) -> None:
        """Store a Solution; silently skipped if not int64-serializable.

        On success the Solution's ``program_arrays`` is populated with
        the freshly packed program, so even a cold compile that caches
        its solves never packs the same program twice."""
        try:
            entry = pack_solution(sol)
        except OverflowError:
            self.stats.skipped_unserializable += 1
            return
        sol.program_arrays = program_arrays_of(entry)
        self._remember(key, entry)
        self.stats.puts += 1
        if self.disk_dir is not None:
            path = Path(self.disk_dir) / f"{key}.npz"
            if not path.exists():
                tmp = path.with_suffix(".tmp.npz")
                np.savez_compressed(tmp, **entry)
                tmp.replace(path)

    # ------------------------------------------------------------------
    def _remember(self, key: str, entry: dict) -> None:
        self._mem[key] = entry
        self._mem.move_to_end(key)
        while len(self._mem) > self.max_items:
            self._mem.popitem(last=False)

    @staticmethod
    def _to_solution(entry: dict, lookup_s: float):
        return unpack_solution(entry, lookup_s)
