"""Canonical signed digit (CSD) representation (Avizienis 1961).

CSD is a radix-2 signed-digit encoding with digits in {-1, 0, +1} in which
no two consecutive digits are non-zero.  It is the minimum-weight signed
digit representation: an x-digit number has at most floor(x/2 + 1)
non-zero digits (~1/3 of positions non-zero on average).  The da4ml CSE
stage (paper §4.4) operates on the CSD digit tensor of the constant
matrix.

All functions here are vectorised over numpy integer arrays.
"""

from __future__ import annotations

import numpy as np


def csd_span(values: np.ndarray) -> int:
    """Number of digit positions B needed to CSD-encode all of ``values``.

    CSD of an n-bit number can carry into bit n, so we add one guard
    position.
    """
    m = int(np.max(np.abs(values.astype(np.int64)))) if values.size else 0
    return max(m.bit_length() + 1, 1)


def to_csd(values: np.ndarray, span: int | None = None) -> np.ndarray:
    """CSD-encode an integer array.

    Returns an int8 array of shape ``values.shape + (B,)`` with entries in
    {-1, 0, +1}; position b carries weight 2^b.
    """
    x = np.asarray(values, dtype=np.int64).copy()
    B = span if span is not None else csd_span(x)
    digits = np.zeros(x.shape + (B,), dtype=np.int8)
    for b in range(B):
        odd = (x & 1) != 0
        # For odd x: digit = +1 if x ≡ 1 (mod 4) else -1 (x ≡ 3 mod 4).
        rem4 = x & 3
        d = np.where(odd, np.where(rem4 == 3, -1, 1), 0).astype(np.int8)
        digits[..., b] = d
        x = (x - d) >> 1
    if np.any(x != 0):
        raise ValueError(f"span {B} too small to CSD-encode values")
    return digits


def from_csd(digits: np.ndarray) -> np.ndarray:
    """Decode a CSD digit tensor back to int64 values."""
    B = digits.shape[-1]
    weights = (1 << np.arange(B, dtype=np.int64))
    return (digits.astype(np.int64) * weights).sum(axis=-1)


def csd_nnz(values: np.ndarray) -> np.ndarray:
    """Number of non-zero CSD digits of each element (vectorised).

    Uses the closed form: nnz(x) = popcount((x ^ 3x) >> 1) — the CSD
    non-zero digit count equals the number of positions where x and 3x
    differ above bit 0 (carries in x + 2x mark signed-digit boundaries).
    """
    x = np.abs(np.asarray(values, dtype=np.int64))
    y = (x ^ (3 * x)) >> 1
    return popcount64(y)


def popcount64(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64)
    x = x - ((x >> np.uint64(1)) & np.uint64(0x5555555555555555))
    x = (x & np.uint64(0x3333333333333333)) + ((x >> np.uint64(2)) & np.uint64(0x3333333333333333))
    x = (x + (x >> np.uint64(4))) & np.uint64(0x0F0F0F0F0F0F0F0F)
    return ((x * np.uint64(0x0101010101010101)) >> np.uint64(56)).astype(np.int64)


def vector_csd_nnz(vec: np.ndarray) -> int:
    """Total CSD non-zero digit count of an integer vector."""
    return int(csd_nnz(vec).sum())
