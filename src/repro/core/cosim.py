"""Three-way RTL co-simulation harness (the hardware-honest gate).

The paper's claims are hardware claims: the DA adder graphs must
produce bit-exact CMVM results *as RTL*, cycle-accurately, not just as
jitted integer math.  This module drives the same fixed-seed vectors
through three implementations of one :class:`DAISProgram` and asserts
bit equality per output and per cycle:

1. **simulated RTL** — ``emit_verilog`` output executed by the
   pure-Python netlist simulator (:mod:`rtlsim`), streamed at II=1 with
   real register fill latency;
2. **the DAIS interpreter** — ``DAISProgram.evaluate`` (exact int64);
3. **the jitted integer forward** — ``adder_graph_apply`` over compiled
   instruction tables (optional: skipped cleanly when JAX is absent, so
   the numpy-only CI leg still proves RTL ≡ interpreter).

On top of value equality the harness cross-checks the *cycle*
contract: the latency the netlist actually exhibits (register crossings
counted by :func:`rtlsim.parse_verilog`) must equal
``PipelineReport.latency_cycles``, and every input→output path must
cross the same number of registers (checked structurally by rtlsim).

An optional external leg replays the exact same vectors through a real
event-driven simulator (Verilator 5 ``--binary --timing``, or Icarus
Verilog) via a generated self-checking testbench, so the pure-Python
simulator itself is periodically validated in CI.
"""

from __future__ import annotations

import shutil
import subprocess
import tempfile
from pathlib import Path
from collections.abc import Sequence

import numpy as np

from ..flow.config import SolverConfig
from .dais import DAISProgram
from .fixed_point import QInterval
from .pipelining import pipeline
from .rtlsim import RTLSimulator, parse_verilog
from .solver import _solve_cmvm, naive_adder_tree
from .verilog import emit_verilog

__all__ = [
    "cosim_program",
    "cosim_case",
    "cosim_grid",
    "default_grid",
    "external_tool",
    "run_external",
]

_JIT_SAFE_BITS = 31  # the jitted forward evaluates in int32


def random_vectors(prog: DAISProgram, n: int, seed: int) -> np.ndarray:
    """Uniform random integer vectors within each input's exact interval."""
    rng = np.random.default_rng(seed)
    qs = [prog.rows[i].qint for i in range(prog.n_inputs)]
    lo = np.array([q.lo for q in qs], dtype=np.int64)
    hi = np.array([q.hi for q in qs], dtype=np.int64)
    return rng.integers(lo, hi + 1, size=(n, len(qs)), dtype=np.int64)


def _jit_leg(prog: DAISProgram, x: np.ndarray, want: np.ndarray, mode: str) -> dict:
    """Run the jitted integer forward; skip cleanly per ``mode``.

    mode: "require" (ImportError propagates), "auto" (record the skip),
    "skip" (never attempt).
    """
    if mode == "skip":
        return {"status": "skipped", "reason": "disabled"}
    widths = [q.width for q in prog.output_qints()] + [
        prog.rows[i].qint.width for i in range(prog.n_inputs)
    ]
    if max(widths, default=0) > _JIT_SAFE_BITS:
        if mode == "require":
            raise ValueError("program exceeds the jitted forward's int32 range")
        return {"status": "skipped", "reason": "exceeds int32"}
    try:
        from ..kernels.adder_graph import adder_graph_apply, compile_tables
    except ImportError as e:
        if mode == "require":
            raise
        return {"status": "skipped", "reason": f"jax unavailable: {e}"}
    tables = compile_tables(prog)
    got = np.asarray(adder_graph_apply(tables, x)).astype(np.int64)
    mismatches = int(np.count_nonzero(np.any(got != want, axis=-1)))
    return {"status": "checked", "bit_exact": mismatches == 0, "mismatches": mismatches}


def cosim_program(
    prog: DAISProgram,
    *,
    module_name: str = "cmvm",
    max_delay_per_stage: int | None = 3,
    n_vectors: int = 64,
    seed: int = 0,
    jit: str = "auto",
    external: str = "skip",
) -> dict:
    """Co-simulate one DAIS program; returns a JSON-ready report.

    The report never raises on a mismatch — gates key off
    ``bit_exact``/``latency_ok`` so a failing case still reports which
    outputs and how many vectors diverged.
    """
    pipelined = max_delay_per_stage is not None
    verilog = emit_verilog(prog, module_name, max_delay_per_stage)
    module = parse_verilog(verilog)
    rep = pipeline(prog, max_delay_per_stage if pipelined else 1 << 30)

    x = random_vectors(prog, n_vectors, seed)
    want = prog.evaluate(x)

    sim = RTLSimulator(module)
    if pipelined:
        res = sim.run_stream(x)
        got = res.y
        accounting = res.accounting()
    else:
        got = sim.run_combinational(x)
        accounting = {
            "latency_cycles": 0,
            "ii": 1,
            "n_cycles": 1,
            "n_registers": 0,
            "register_bits": 0,
            "stage_register_bits": [],
        }

    per_output = np.count_nonzero(got != want, axis=0)
    mismatches = int(np.count_nonzero(np.any(got != want, axis=-1)))
    expected_latency = rep.latency_cycles if pipelined else 0
    report = {
        "module": module_name,
        "pipelined": pipelined,
        "max_delay_per_stage": max_delay_per_stage,
        "n_vectors": int(n_vectors),
        "seed": int(seed),
        "n_inputs": prog.n_inputs,
        "n_outputs": len(prog.outputs),
        "adders": prog.n_adders,
        "cost_bits": prog.cost_bits,
        "n_stages": rep.n_stages if pipelined else 1,
        "expected_latency_cycles": expected_latency,
        "latency_ok": module.latency_cycles == expected_latency,
        "bit_exact": mismatches == 0,
        "mismatched_vectors": mismatches,
        "mismatches_per_output": [int(c) for c in per_output],
        "accounting": accounting,
        "jit": _jit_leg(prog, x, want, jit),
    }
    if external != "skip":
        report["external"] = run_external(
            verilog, module_name, x, want, expected_latency, mode=external
        )
    return report


def cosim_case(
    m: np.ndarray,
    *,
    name: str | None = None,
    strategy: str = "da",
    engine: str = "batch",
    dc: int = -1,
    max_delay_per_stage: int | None = 3,
    qint_in: Sequence[QInterval] | None = None,
    n_vectors: int = 64,
    seed: int = 0,
    jit: str = "auto",
    external: str = "skip",
) -> dict:
    """Solve ``y = x @ m`` with the given strategy/engine and co-simulate."""
    m = np.asarray(m)
    if strategy == "latency":
        sol = naive_adder_tree(m, qint_in=qint_in)
    elif strategy == "da":
        sol = _solve_cmvm(m, qint_in, None, SolverConfig(dc=dc, engine=engine))
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    mdps = max_delay_per_stage
    label = name or (
        f"{strategy}-{engine if strategy == 'da' else 'tree'}-"
        f"{m.shape[0]}x{m.shape[1]}-{'p' + str(mdps) if mdps else 'comb'}"
    )
    report = cosim_program(
        sol.program,
        module_name=label.replace("-", "_"),
        max_delay_per_stage=mdps,
        n_vectors=n_vectors,
        seed=seed,
        jit=jit,
        external=external,
    )
    report.update(
        name=label,
        shape=[int(m.shape[0]), int(m.shape[1])],
        strategy=strategy,
        engine=engine if strategy == "da" else None,
        dc=dc,
    )
    return report


# ----------------------------------------------------------------------
# The grid
# ----------------------------------------------------------------------
def _grid_matrix(shape: tuple[int, int], seed: int, lo: int = -64, hi: int = 64) -> np.ndarray:
    return np.random.default_rng(seed).integers(lo, hi, size=shape)


def default_grid(seed: int = 0, n_vectors: int = 64) -> list[dict]:
    """The CI co-sim grid: {strategy × engine × pipelined/comb × shape}.

    Shapes include an all-zero output column (emitted as ``assign y = 0``)
    and an all-negative column; one case drives unsigned (non-negative)
    input intervals — the regression for the signed-width emission fix —
    and one exercises the negative-shift (``>>>``) output path via
    fractional fixed-point inputs.
    """
    m_zero_neg = _grid_matrix((3, 4), seed + 1)
    m_zero_neg[:, 1] = 0  # constant-zero output column
    m_zero_neg[:, 2] = -np.abs(m_zero_neg[:, 2]) - 1  # all-negative column
    shapes = {
        "3x4-zeroneg": m_zero_neg,
        "4x4": _grid_matrix((4, 4), seed + 2),
        "6x3": _grid_matrix((6, 3), seed + 3),
        "8x8": _grid_matrix((8, 8), seed + 4, lo=-32, hi=32),
    }
    cases: list[dict] = []
    for label, m in shapes.items():
        for mdps in (1, 3, None):
            for strategy, engine in (
                ("da", "batch"),
                ("da", "heap"),
                ("da", "arena"),
                ("latency", None),
            ):
                # full engine cross only on the pipelined mdps=3 leg;
                # engines are bit-identical by construction (enforced in
                # tests/test_cse_engines.py) so one engine suffices on
                # the other timing legs
                if mdps != 3 and engine not in ("batch", None):
                    continue
                cases.append(dict(
                    name=f"{strategy}-{engine or 'tree'}-{label}-"
                         f"{'p' + str(mdps) if mdps else 'comb'}",
                    m=m,
                    strategy=strategy,
                    engine=engine or "batch",
                    max_delay_per_stage=mdps,
                    n_vectors=n_vectors,
                    seed=seed + len(cases),
                ))
    # unsigned (non-negative) input intervals: the signed-width regression
    cases.append(dict(
        name="da-batch-4x3-unsigned-p2",
        m=_grid_matrix((4, 3), seed + 5),
        strategy="da",
        engine="batch",
        max_delay_per_stage=2,
        qint_in=[QInterval.from_fixed(False, 8, 8)] * 4,
        n_vectors=n_vectors,
        seed=seed + 101,
    ))
    # fractional fixed-point inputs: output terms carry negative shifts,
    # exercising the `(src >>> k)` / `-(src >>> k)` emission paths
    cases.append(dict(
        name="da-batch-4x4-fracgrid-comb",
        m=_grid_matrix((4, 4), seed + 6) / 4.0,
        strategy="da",
        engine="batch",
        max_delay_per_stage=None,
        qint_in=[QInterval.from_fixed(True, 10, 4)] * 4,
        n_vectors=n_vectors,
        seed=seed + 102,
    ))
    return cases


def cosim_grid(
    cases: list[dict] | None = None,
    *,
    jit: str = "auto",
    external: str = "skip",
) -> dict:
    """Run a list of :func:`cosim_case` kwargs; aggregate into one report."""
    if cases is None:
        cases = default_grid()
    reports = []
    for c in cases:
        kw = dict(c)
        m = kw.pop("m")
        reports.append(cosim_case(m, jit=jit, external=external, **kw))
    jit_checked = sum(1 for r in reports if r["jit"].get("status") == "checked")
    ext = [r.get("external") for r in reports if r.get("external") is not None]
    ext_checked = sum(1 for e in ext if e.get("status") == "checked")
    all_ok = all(r["bit_exact"] and r["latency_ok"] for r in reports)
    jit_ok = all(
        r["jit"].get("bit_exact", True) for r in reports
    )
    ext_ok = all(e.get("bit_exact", True) for e in ext)
    return {
        "kind": "rtl_cosim",
        "n_cases": len(reports),
        "n_bit_exact": sum(1 for r in reports if r["bit_exact"]),
        "all_bit_exact": all_ok and jit_ok and ext_ok,
        "jit": {
            "checked": jit_checked,
            "skipped": len(reports) - jit_checked,
            "ok": jit_ok,
        },
        "external": {
            "tool": ext[0].get("tool") if ext else None,
            "checked": ext_checked,
            "ok": ext_ok,
        },
        "cases": reports,
    }


# ----------------------------------------------------------------------
# External reference simulators (Verilator / Icarus Verilog)
# ----------------------------------------------------------------------
def external_tool() -> str | None:
    """Which external simulator is available: 'verilator', 'iverilog', None."""
    if shutil.which("verilator"):
        return "verilator"
    if shutil.which("iverilog"):
        return "iverilog"
    return None


def _make_testbench(module, module_name: str, x: np.ndarray) -> str:
    """Self-contained Verilog testbench replaying ``x`` at II=1.

    The event ordering matches :meth:`RTLSimulator.step`: drive inputs,
    let combinational logic settle (#1), display outputs, then clock.
    Outputs are printed every cycle; the first ``latency`` lines are
    pipeline fill (Icarus prints x's there — ignored by the parser).
    """
    sigs = module.signals
    lines = ["`timescale 1ns/1ps", "module tb;"]
    conns = []
    if module.clock is not None:
        lines.append("  reg clk = 0;")
        conns.append(".clk(clk)")
    for nm in module.inputs:
        s = sigs[nm]
        lines.append(f"  reg signed [{s.width - 1}:0] {nm};")
        conns.append(f".{nm}({nm})")
    for nm in module.outputs:
        s = sigs[nm]
        lines.append(f"  wire signed [{s.width - 1}:0] {nm};")
        conns.append(f".{nm}({nm})")
    lines.append(f"  {module_name} u_dut ({', '.join(conns)});")
    fmt = " ".join(["%0d"] * len(module.outputs))
    args = ", ".join(module.outputs)
    lat = module.latency_cycles
    lines.append("  initial begin")
    total = x.shape[0] + lat
    for t in range(total):
        row = x[t] if t < x.shape[0] else np.zeros(x.shape[1], dtype=np.int64)
        for i, nm in enumerate(module.inputs):
            lines.append(f"    {nm} = {int(row[i])};")
        lines.append("    #1;")
        lines.append(f'    $display("{fmt}", {args});')
        if module.clock is not None:
            lines.append("    clk = 1; #1; clk = 0;")
    lines.append("    $finish;")
    lines.append("  end")
    lines.append("endmodule")
    return "\n".join(lines)


def run_external(
    verilog_src: str,
    module_name: str,
    x: np.ndarray,
    want: np.ndarray,
    latency: int,
    mode: str = "auto",
    tool: str | None = None,
) -> dict:
    """Replay ``x`` through a real simulator and compare against ``want``.

    mode: "require" raises when no tool is available; "auto" returns a
    loud skip record instead.  Returns a JSON-ready report.
    """
    tool = tool or external_tool()
    if tool is None:
        msg = "no external simulator found (need verilator or iverilog on PATH)"
        if mode == "require":
            raise RuntimeError(msg)
        print(f"SKIP external co-sim: {msg}")
        return {"status": "skipped", "reason": msg}
    module = parse_verilog(verilog_src)
    tb = _make_testbench(module, module_name, x)
    with tempfile.TemporaryDirectory(prefix="rtl_cosim_") as td:
        tdir = Path(td)
        (tdir / "dut.v").write_text(verilog_src)
        (tdir / "tb.v").write_text(tb)
        if tool == "verilator":
            build = subprocess.run(
                ["verilator", "--binary", "--timing", "-Wno-fatal", "-Wno-WIDTH",
                 "--Mdir", str(tdir / "obj"), "-o", "sim", "tb.v", "dut.v"],
                cwd=tdir, capture_output=True, text=True,
            )
            if build.returncode != 0:
                return {"status": "error", "tool": tool,
                        "reason": build.stderr[-2000:]}
            run = subprocess.run(
                [str(tdir / "obj" / "sim")], cwd=tdir, capture_output=True, text=True
            )
        else:
            build = subprocess.run(
                ["iverilog", "-g2001", "-o", "tb.vvp", "tb.v", "dut.v"],
                cwd=tdir, capture_output=True, text=True,
            )
            if build.returncode != 0:
                return {"status": "error", "tool": tool,
                        "reason": build.stderr[-2000:]}
            run = subprocess.run(
                ["vvp", "tb.vvp"], cwd=tdir, capture_output=True, text=True
            )
        if run.returncode != 0:
            return {"status": "error", "tool": tool, "reason": run.stderr[-2000:]}
    rows = []
    for line in run.stdout.splitlines():
        parts = line.split()
        if len(parts) == len(module.outputs) and all(
            p.lstrip("-").isdigit() or "x" in p.lower() for p in parts
        ):
            rows.append(parts)
    if len(rows) < x.shape[0] + latency:
        return {"status": "error", "tool": tool,
                "reason": f"expected {x.shape[0] + latency} output lines, "
                          f"got {len(rows)}"}
    got = np.zeros((x.shape[0], len(module.outputs)), dtype=np.int64)
    bad = 0
    for t in range(x.shape[0]):
        for j, p in enumerate(rows[latency + t]):
            if "x" in p.lower():
                bad += 1  # X after the fill window is itself a failure
            else:
                got[t, j] = int(p)
    mismatches = int(np.count_nonzero(np.any(got != want, axis=-1))) + bad
    return {
        "status": "checked",
        "tool": tool,
        "bit_exact": mismatches == 0,
        "mismatched_vectors": mismatches,
        "x_states_after_fill": bad,
    }
