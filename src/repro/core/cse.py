"""Stage 2 of da4ml: cost-aware two-term common subexpression elimination.

Operates on the CSD digit tensor of an integer coefficient matrix whose
rows are *existing program values* (inputs, or stage-1 intermediates).
State (paper §4.4):

  * ``M_expr`` — sparse digit storage, per output column a dict
    ``{(row, bit_pos): digit}`` with digit in {-1, +1};
  * ``L_impl`` — the DAIS program rows (implemented values).

Each update step selects a two-term subexpression — canonical four-tuple
``(i, j, s, sign)`` encoding ``u = (x_i << max(0,-s)) + sign * (x_j <<
max(0,s))`` — and implements it, replacing every occurrence's digit pair
with a single digit on the new row.

Key differences from prior art that this module reproduces:

  * subexpressions are matched across *different power-of-two scalings*
    (relative shift ``s`` is part of the key, not a uniform row/column
    shift as in MCMT [13]) and across *signed digits* (``sign`` in key),
    unlike Scalable CMVM [57];
  * selection is most-frequent-first, O(|L_impl|) per step via a cached
    frequency table (a lazy max-heap here), not the O(|L_impl|^2)
    one-step-lookahead of [4, 14] — the paper measures the lookahead is
    worth <2% adders;
  * frequency is weighted by the *operand bit overlap* (paper §4.4): the
    cost model (Eq. 1) prefers operands with similar bitwidths/shifts, but
    weighting by full cost would reward half-adder overhead bits; overlap
    weighting is the paper's compromise;
  * a delay constraint is enforced per output column: a replacement is
    rejected if the column's minimal achievable merge-tree depth would
    exceed its budget.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Optional

import numpy as np

from .cost import min_tree_depth, overlap_bits
from .csd import to_csd
from .dais import DAISProgram, Term

# ----------------------------------------------------------------------
# Pattern keys
# ----------------------------------------------------------------------
# Canonical key (i, j, s, sign): rows i <= j in program order; when i == j,
# s > 0.  Digit pair ((i, p), (j, p + s)) with product sign realises
#   d_i * 2^min(p, p+s) * u,   u = (x_i << max(0,-s)) + sign*(x_j << max(0,s))


def _canon_key(r1: int, p1: int, d1: int, r2: int, p2: int, d2: int):
    if (r1, p1) > (r2, p2):
        r1, p1, d1, r2, p2, d2 = r2, p2, d2, r1, p1, d1
    return (r1, r2, p2 - p1, d1 * d2)


@dataclass
class CSEStats:
    n_patterns_implemented: int = 0
    n_occurrences_replaced: int = 0
    n_rejected_by_depth: int = 0
    n_assembly_adders: int = 0


class CSE:
    def __init__(
        self,
        prog: DAISProgram,
        coeff_cols: list[dict[int, int]],
        budgets: Optional[list[Optional[int]]] = None,
        weighted: bool = True,
        assembly_dedup: bool = True,
        depth_weight: float = 0.0,
    ) -> None:
        self.prog = prog
        self.budgets = budgets if budgets is not None else [None] * len(coeff_cols)
        self.weighted = weighted
        self.assembly_dedup = assembly_dedup
        # beyond-paper: under tight delay budgets, prefer subexpressions
        # with shallow operands (they leave headroom for further reuse
        # before the per-output depth budget binds):
        # priority /= (1 + depth_weight * max(depth_a, depth_b))
        self.depth_weight = depth_weight
        self.stats = CSEStats()

        # Sparse digit state: per column, {(row, pos): digit}
        self.cols: list[dict[tuple[int, int], int]] = []
        for col in coeff_cols:
            digits: dict[tuple[int, int], int] = {}
            for row, coeff in col.items():
                if coeff == 0:
                    continue
                csd = to_csd(np.array([coeff]))[0]
                for pos in np.nonzero(csd)[0]:
                    digits[(row, int(pos))] = int(csd[pos])
            self.cols.append(digits)

        # Frequency machinery
        self.counts: dict[tuple, int] = {}
        self.pattern_cols: dict[tuple, dict[int, int]] = {}
        self.heap: list[tuple[float, int, tuple]] = []
        self._seq = 0
        self._weights: dict[tuple, float] = {}
        self._impl_cache: dict[tuple, int] = {}
        self._combine_cache: dict[tuple, Term] = {}

        self._build_initial_counts()

    # ------------------------------------------------------------------
    # Weights (static per key: operand qints are fixed at row creation)
    # ------------------------------------------------------------------
    def _weight(self, key: tuple) -> float:
        w = self._weights.get(key)
        if w is None:
            i, j, s, _sign = key
            w = 1.0
            if self.weighted:
                qa = self.prog.rows[i].qint
                qb = self.prog.rows[j].qint
                w = float(overlap_bits(qa, qb, max(0, -s), max(0, s)) + 1)
            if self.depth_weight:
                d = max(self.prog.rows[i].depth, self.prog.rows[j].depth)
                w = w / (1.0 + self.depth_weight * d)
            self._weights[key] = w
        return w

    # ------------------------------------------------------------------
    # Frequency table construction and maintenance
    # ------------------------------------------------------------------
    def _build_initial_counts(self) -> None:
        for c, digits in enumerate(self.cols):
            if len(digits) < 2:
                continue
            items = list(digits.items())
            n = len(items)
            rows = np.fromiter((it[0][0] for it in items), dtype=np.int64, count=n)
            poss = np.fromiter((it[0][1] for it in items), dtype=np.int64, count=n)
            digs = np.fromiter((it[1] for it in items), dtype=np.int64, count=n)
            ii, jj = np.triu_indices(n, k=1)
            r1, r2 = rows[ii], rows[jj]
            p1, p2 = poss[ii], poss[jj]
            d1, d2 = digs[ii], digs[jj]
            # canonical order: (row, pos) lexicographic
            swap = (r1 > r2) | ((r1 == r2) & (p1 > p2))
            r1s = np.where(swap, r2, r1)
            r2s = np.where(swap, r1, r2)
            p1s = np.where(swap, p2, p1)
            p2s = np.where(swap, p1, p2)
            s = p2s - p1s
            sg = d1 * d2
            # pack keys for np.unique
            packed = (((r1s << 21) | r2s) << 16 | (s + (1 << 14))) << 1 | (sg > 0)
            uniq, cnt = np.unique(packed, return_counts=True)
            for k_packed, k_cnt in zip(uniq.tolist(), cnt.tolist()):
                sign = 1 if (k_packed & 1) else -1
                rest = k_packed >> 1
                s_v = (rest & 0xFFFF) - (1 << 14)
                rest >>= 16
                key = (rest >> 21, rest & ((1 << 21) - 1), s_v, sign)
                self.counts[key] = self.counts.get(key, 0) + k_cnt
                self.pattern_cols.setdefault(key, {})[c] = (
                    self.pattern_cols.setdefault(key, {}).get(c, 0) + k_cnt
                )
        for key, cnt in self.counts.items():
            if cnt >= 2:
                self._push(key, cnt)

    def _push(self, key: tuple, cnt: int) -> None:
        heapq.heappush(self.heap, (-cnt * self._weight(key), self._seq, key))
        self._seq += 1

    def _inc(self, key: tuple, c: int) -> None:
        n = self.counts.get(key, 0) + 1
        self.counts[key] = n
        pc = self.pattern_cols.setdefault(key, {})
        pc[c] = pc.get(c, 0) + 1
        if n >= 2:
            self._push(key, n)

    def _dec(self, key: tuple, c: int) -> None:
        n = self.counts[key] - 1
        if n:
            self.counts[key] = n
        else:
            del self.counts[key]
        pc = self.pattern_cols[key]
        if pc[c] == 1:
            del pc[c]
            if not pc:
                del self.pattern_cols[key]
        else:
            pc[c] -= 1

    def _remove_digit(self, c: int, row: int, pos: int) -> None:
        digits = self.cols[c]
        d = digits.pop((row, pos))
        for (r2, p2), d2 in digits.items():
            self._dec(_canon_key(row, pos, d, r2, p2, d2), c)

    def _add_digit(self, c: int, row: int, pos: int, d: int) -> None:
        digits = self.cols[c]
        for (r2, p2), d2 in digits.items():
            self._inc(_canon_key(row, pos, d, r2, p2, d2), c)
        digits[(row, pos)] = d

    # ------------------------------------------------------------------
    # Occurrence search
    # ------------------------------------------------------------------
    def _find_occurrences(self, key: tuple) -> dict[int, list[int]]:
        """Disjoint occurrences per column: base positions p such that the
        digit pair ((i, p), (j, p+s)) matches the pattern."""
        i, j, s, sign = key
        out: dict[int, list[int]] = {}
        for c in list(self.pattern_cols.get(key, {})):
            digits = self.cols[c]
            if i != j:
                ps = [
                    p
                    for (r, p), d in digits.items()
                    if r == i and (j, p + s) in digits and d * digits[(j, p + s)] == sign
                ]
            else:
                # chains like p, p+s, p+2s share digits: greedy disjoint match
                own = sorted(p for (r, p) in digits if r == i)
                used: set[int] = set()
                ps = []
                for p in own:
                    if p in used or (p + s) in used:
                        continue
                    if (i, p + s) in digits and digits[(i, p)] * digits[(i, p + s)] == sign:
                        ps.append(p)
                        used.add(p)
                        used.add(p + s)
            if ps:
                out[c] = sorted(ps)
        return out

    # ------------------------------------------------------------------
    # Main loop
    # ------------------------------------------------------------------
    def run(self) -> list[Optional[Term]]:
        while self.heap:
            neg_pri, _, key = heapq.heappop(self.heap)
            cnt = self.counts.get(key, 0)
            if cnt < 2:
                continue
            cur_pri = cnt * self._weight(key)
            if -neg_pri > cur_pri + 1e-9:
                self._push(key, cnt)  # stale (count dropped): re-sort
                continue
            if -neg_pri < cur_pri - 1e-9:
                continue  # a fresher (higher-priority) entry is in the heap
            self._implement(key)
        return self._assemble()

    def _implement(self, key: tuple) -> None:
        i, j, s, sign = key
        occs = self._find_occurrences(key)
        u_depth = max(self.prog.rows[i].depth, self.prog.rows[j].depth) + 1
        # Delay-constraint filter, per column, occurrence by occurrence.
        accepted: dict[int, list[int]] = {}
        total = 0
        for c, ps in occs.items():
            budget = self.budgets[c]
            if budget is None:
                accepted[c] = ps
                total += len(ps)
                continue
            kept: list[int] = []
            pending: list[tuple[int, int]] = []
            for p in ps:
                trial = pending + [(p, p + s)]
                # exact per-column simulation with row identity
                rm = {(i, pi) for pi, _ in trial} | {(j, pj) for _, pj in trial}
                depths = [
                    self.prog.rows[r].depth
                    for (r, pp) in self.cols[c]
                    if (r, pp) not in rm
                ]
                d = min_tree_depth(depths + [u_depth] * len(trial))
                if d <= budget:
                    kept.append(p)
                    pending = trial
                else:
                    self.stats.n_rejected_by_depth += 1
            if kept:
                accepted[c] = kept
                total += len(kept)
        if total < 2:
            return  # dormant until counts change again
        u = self._impl_cache.get(key)
        if u is None:
            u = self.prog.add_op(i, j, max(0, -s), max(0, s), sign)
            self._impl_cache[key] = u
        self.stats.n_patterns_implemented += 1
        for c, ps in accepted.items():
            for p in ps:
                d_i = self.cols[c][(i, p)]
                self._remove_digit(c, i, p)
                self._remove_digit(c, j, p + s)
                self._add_digit(c, u, p + min(0, s), d_i)
                self.stats.n_occurrences_replaced += 1

    # ------------------------------------------------------------------
    # Final adder-tree assembly per column
    # ------------------------------------------------------------------
    def _combine(self, t1: Term, t2: Term) -> Term:
        if self.assembly_dedup:
            ck = (t1, t2) if (t1.row, t1.shift, t1.sign) <= (t2.row, t2.shift, t2.sign) else (t2, t1)
            hit = self._combine_cache.get(ck)
            if hit is not None:
                return hit
        if t1.sign == t2.sign:
            m = min(t1.shift, t2.shift)
            u = self.prog.add_op(t1.row, t2.row, t1.shift - m, t2.shift - m, +1)
            res = Term(t1.sign, u, m)
        else:
            pos, neg = (t1, t2) if t1.sign > 0 else (t2, t1)
            m = min(pos.shift, neg.shift)
            u = self.prog.add_op(pos.row, neg.row, pos.shift - m, neg.shift - m, -1)
            res = Term(1, u, m)
        self.stats.n_assembly_adders += 1
        if self.assembly_dedup:
            self._combine_cache[ck] = res
        return res

    def _assemble(self) -> list[Optional[Term]]:
        outputs: list[Optional[Term]] = []
        for c, digits in enumerate(self.cols):
            if not digits:
                outputs.append(None)
                continue
            # merge two shallowest first: optimal max-depth (min-max Huffman)
            h: list[tuple[int, int, int, Term]] = []
            seq = 0
            for (row, pos), d in sorted(digits.items()):
                t = Term(d, row, pos)
                h.append((self.prog.rows[row].depth, self.prog.rows[row].qint.width, seq, t))
                seq += 1
            heapq.heapify(h)
            while len(h) > 1:
                _, _, _, t1 = heapq.heappop(h)
                _, _, _, t2 = heapq.heappop(h)
                t = self._combine(t1, t2)
                heapq.heappush(h, (self.prog.rows[t.row].depth, self.prog.rows[t.row].qint.width, seq, t))
                seq += 1
            outputs.append(h[0][3])
        return outputs
