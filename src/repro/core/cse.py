"""Stage 2 of da4ml: cost-aware two-term common subexpression elimination.

Operates on the CSD digit tensor of an integer coefficient matrix whose
rows are *existing program values* (inputs, or stage-1 intermediates).
State (paper §4.4):

  * ``M_expr`` — sparse digit storage, per output column a compacted
    numpy triple ``(rows, poss, digs)`` with digit in {-1, +1} plus a
    ``(row, pos) -> slot`` index (:class:`_ColStore`);
  * ``L_impl`` — the DAIS program rows (implemented values).

Each update step selects a two-term subexpression — canonical four-tuple
``(i, j, s, sign)`` encoding ``u = (x_i << max(0,-s)) + sign * (x_j <<
max(0,s))``, packed into a single int64 key — and implements it,
replacing every occurrence's digit pair with a single digit on the new
row.

Key differences from prior art that this module reproduces:

  * subexpressions are matched across *different power-of-two scalings*
    (relative shift ``s`` is part of the key, not a uniform row/column
    shift as in MCMT [13]) and across *signed digits* (``sign`` in key),
    unlike Scalable CMVM [57];
  * selection is most-frequent-first via a cached frequency table (a
    lazy max-heap here), not the O(|L_impl|^2) one-step-lookahead of
    [4, 14] — the paper measures the lookahead is worth <2% adders;
  * frequency is weighted by the *operand bit overlap* (paper §4.4): the
    cost model (Eq. 1) prefers operands with similar bitwidths/shifts, but
    weighting by full cost would reward half-adder overhead bits; overlap
    weighting is the paper's compromise;
  * a delay constraint is enforced per output column: a replacement is
    rejected if the column's minimal achievable merge-tree depth would
    exceed its budget.

Performance notes (the solver fast path; see docs/solver_performance.md):

  * pattern keys are packed int64s, so the count update after replacing a
    pattern's occurrences is ONE vectorized signed-delta batch per
    implementation step (removed/added digits against the live stores,
    all accepted columns concatenated), deduplicated with a single
    ``np.unique`` and written back through C-level ``map(dict.get, ...)``
    / ``dict.update`` — no per-pair Python loop;
  * the lazy max-heap tracks exact membership (``_inheap``): a key is
    (re)inserted only when it gains pairs while absent, when its stored
    priority is stale at pop time, or after an implementation leaves it
    viable — instead of one heap entry per count increment;
  * ``row_cols`` maps each program row to the set of columns that may
    hold its digits (pruned lazily when a scan finds none), so locating a
    pattern's columns is one set intersection — no per-(key, column)
    count bookkeeping on the hot path;
  * heap priorities (overlap-bit weights) are computed vectorized from
    per-row ``lsb/msb/depth`` metadata arrays synced with the program;
  * the delay-constraint simulation in ``_implement`` works on a
    per-column depth *histogram*: replacing k occurrences shifts exactly
    k digits of row i and k of row j onto the new row's depth, so the
    feasibility of the k-th acceptance is :func:`min_tree_depth_hist` on
    an O(distinct depths) histogram instead of ``min_tree_depth`` over
    the whole column per occurrence.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Optional

import numpy as np

from .cost import min_tree_depth_hist, overlap_bits  # noqa: F401  (re-export)
from .csd import to_csd
from .dais import DAISProgram, Term

# ----------------------------------------------------------------------
# Pattern keys
# ----------------------------------------------------------------------
# Canonical key (i, j, s, sign): rows i <= j in program order; when i == j,
# s > 0.  Digit pair ((i, p), (j, p + s)) with product sign realises
#   d_i * 2^min(p, p+s) * u,   u = (x_i << max(0,-s)) + sign*(x_j << max(0,s))
#
# Keys are packed into a single int64 (rows < 2^21, |s| < 2^14, 1 sign
# bit) so they can be produced and deduplicated by vectorized numpy code.
# ``key >> 17`` strips shift and sign, leaving the packed row pair.

_ROW_BITS = 21
_ROW_MASK = (1 << _ROW_BITS) - 1
_S_OFF = 1 << 14


def _pack_keys(r1, r2, s, sg):
    """Pack canonical key components (scalars or arrays) into int64."""
    return (((r1 << _ROW_BITS) | r2) << 16 | (s + _S_OFF)) << 1 | (sg > 0)


def _unpack_key(key: int) -> tuple[int, int, int, int]:
    sign = 1 if (key & 1) else -1
    rest = key >> 1
    s = (rest & 0xFFFF) - _S_OFF
    rest >>= 16
    return (rest >> _ROW_BITS, rest & _ROW_MASK, s, sign)


def _canon_pack(rA, pA, dA, rB, pB, dB):
    """Vectorized canonical packed keys for digit pairs (arrays broadcast)."""
    swap = (rB < rA) | ((rB == rA) & (pB < pA))
    r1 = np.where(swap, rB, rA)
    p1 = np.where(swap, pB, pA)
    r2 = np.where(swap, rA, rB)
    p2 = np.where(swap, pA, pB)
    return _pack_keys(r1, r2, p2 - p1, dA * dB)


_HASH_MULT = np.uint64(0x9E3779B97F4A7C15)


class _CountTable:
    """Open-addressed int64 -> int64 counter with vectorized batch ops.

    Replaces a Python dict on the CSE hot path: a whole implementation
    step's count delta becomes a handful of numpy gathers/scatters with
    linear probing (multiplicative hashing on the HIGH product bits)
    instead of one dict operation per key.  Keys must be >= 0 (-1 is the
    empty sentinel); absent keys read as 0 and zeroed entries are kept.
    """

    __slots__ = ("mask", "shift", "keys", "vals", "n")

    def __init__(self, cap: int = 1 << 16) -> None:
        self.mask = cap - 1
        self.shift = np.uint64(64 - (cap.bit_length() - 1))
        self.keys = np.full(cap, -1, dtype=np.int64)
        self.vals = np.zeros(cap, dtype=np.int64)
        self.n = 0

    def _slots_claim(self, k: np.ndarray) -> np.ndarray:
        """Slot per key (existing or newly claimed); keys must be unique."""
        mask = self.mask
        idx = ((k.astype(np.uint64) * _HASH_MULT) >> self.shift).astype(np.int64)
        out = np.empty(k.shape[0], dtype=np.int64)
        pending = np.arange(k.shape[0])
        while pending.size:
            slots = idx[pending]
            cur = self.keys[slots]
            hit = cur == k[pending]
            empty = cur == -1
            if empty.any():
                e = pending[empty]
                self.keys[idx[e]] = k[e]  # duplicate slots: last write wins
                won = self.keys[idx[e]] == k[e]
                self.n += int(won.sum())
                hit = hit.copy()
                hit[empty] = won
            out[pending[hit]] = idx[pending[hit]]
            pending = pending[~hit]
            idx[pending] = (idx[pending] + 1) & mask
        return out

    def add_batch(self, k: np.ndarray, delta: np.ndarray) -> np.ndarray:
        """counts[k] += delta for unique keys; returns the new counts."""
        # grow until the worst case (every key new) fits under 60% load —
        # a single under-sized growth step could leave the table full and
        # turn the linear probe into an infinite loop
        while (self.n + k.shape[0]) * 5 > (self.mask + 1) * 3:
            self._grow()
        slots = self._slots_claim(k)
        new = self.vals[slots] + delta
        self.vals[slots] = new
        return new

    def get_batch(self, k: np.ndarray) -> np.ndarray:
        mask = self.mask
        idx = ((k.astype(np.uint64) * _HASH_MULT) >> self.shift).astype(np.int64)
        out = np.zeros(k.shape[0], dtype=np.int64)
        pending = np.arange(k.shape[0])
        while pending.size:
            slots = idx[pending]
            cur = self.keys[slots]
            hit = cur == k[pending]
            out[pending[hit]] = self.vals[slots[hit]]
            done = hit | (cur == -1)
            pending = pending[~done]
            idx[pending] = (idx[pending] + 1) & mask
        return out

    def get(self, key: int) -> int:
        mask = self.mask
        keys = self.keys
        idx = ((key * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF) >> int(self.shift)
        while True:
            cur = keys[idx]
            if cur == key:
                return int(self.vals[idx])
            if cur == -1:
                return 0
            idx = (idx + 1) & mask

    def _grow(self) -> None:
        live = self.keys != -1
        lk, lv = self.keys[live], self.vals[live]
        cap = (self.mask + 1) * 2
        while self.n * 2 > cap:
            cap *= 2
        self.mask = cap - 1
        self.shift = np.uint64(64 - (cap.bit_length() - 1))
        self.keys = np.full(cap, -1, dtype=np.int64)
        self.vals = np.zeros(cap, dtype=np.int64)
        self.n = 0
        slots = self._slots_claim(lk)
        self.vals[slots] = lv


_TRIU_CACHE: dict[int, tuple[np.ndarray, np.ndarray]] = {}


def _triu(m: int) -> tuple[np.ndarray, np.ndarray]:
    hit = _TRIU_CACHE.get(m)
    if hit is None:
        hit = _TRIU_CACHE[m] = np.triu_indices(m, k=1)
    return hit


class _ColStore:
    """Compacted column digit store: parallel (rows, poss, digs) vectors
    for the live digits plus a ``(row, pos) -> slot`` index.  Removal
    swaps the last live slot in, so ``[:n]`` is always dense and directly
    usable by vectorized pair-key / occurrence / depth computations."""

    __slots__ = ("rows", "poss", "digs", "n", "index", "by_row")

    def __init__(self, rows, poss, digs) -> None:
        self.rows = np.asarray(rows, dtype=np.int64)
        self.poss = np.asarray(poss, dtype=np.int64)
        self.digs = np.asarray(digs, dtype=np.int64)
        self.n = int(self.rows.shape[0])
        self.index = {}
        self.by_row: dict[int, dict[int, int]] = {}
        for k, (r, p, d) in enumerate(
            zip(self.rows.tolist(), self.poss.tolist(), self.digs.tolist())
        ):
            self.index[(r, p)] = k
            self.by_row.setdefault(r, {})[p] = d

    def __len__(self) -> int:
        return self.n

    def __contains__(self, rp) -> bool:
        return rp in self.index

    def get(self, row: int, pos: int) -> int:
        return int(self.digs[self.index[(row, pos)]])

    def live(self):
        return self.rows[: self.n], self.poss[: self.n], self.digs[: self.n]

    def add(self, row: int, pos: int, d: int) -> None:
        assert (row, pos) not in self.index, "duplicate digit slot"
        if self.n == self.rows.shape[0]:
            cap = max(2 * self.n, 8)
            for name in ("rows", "poss", "digs"):
                a = getattr(self, name)
                b = np.zeros(cap, dtype=np.int64)
                b[: self.n] = a[: self.n]
                setattr(self, name, b)
        k = self.n
        self.rows[k] = row
        self.poss[k] = pos
        self.digs[k] = d
        self.index[(row, pos)] = k
        self.by_row.setdefault(row, {})[pos] = d
        self.n += 1

    def remove(self, row: int, pos: int) -> int:
        k = self.index.pop((row, pos))
        d = int(self.digs[k])
        last = self.n - 1
        if k != last:
            r2, p2 = int(self.rows[last]), int(self.poss[last])
            self.rows[k] = r2
            self.poss[k] = p2
            self.digs[k] = self.digs[last]
            self.index[(r2, p2)] = k
        self.n = last
        m = self.by_row[row]
        del m[pos]
        if not m:
            del self.by_row[row]
        return d


@dataclass
class CSEStats:
    n_patterns_implemented: int = 0
    n_occurrences_replaced: int = 0
    n_rejected_by_depth: int = 0
    n_assembly_adders: int = 0


class CSE:
    def __init__(
        self,
        prog: DAISProgram,
        coeff_cols: list[dict[int, int]],
        budgets: Optional[list[Optional[int]]] = None,
        weighted: bool = True,
        assembly_dedup: bool = True,
        depth_weight: float = 0.0,
        *,
        build_counts: bool = True,
    ) -> None:
        self.prog = prog
        self.budgets = budgets if budgets is not None else [None] * len(coeff_cols)
        self.weighted = weighted
        self.assembly_dedup = assembly_dedup
        # beyond-paper: under tight delay budgets, prefer subexpressions
        # with shallow operands (they leave headroom for further reuse
        # before the per-output depth budget binds):
        # priority /= (1 + depth_weight * max(depth_a, depth_b))
        self.depth_weight = depth_weight
        self.stats = CSEStats()

        # Column digit state, vectorized: the CSD digits of every column
        # are computed in one batch instead of per coefficient.
        self.cols: list[_ColStore] = []
        for col in coeff_cols:
            items = [(r, c) for r, c in col.items() if c != 0]
            if not items:
                self.cols.append(_ColStore([], [], []))
                continue
            rows = np.array([r for r, _ in items], dtype=np.int64)
            coeffs = np.array([c for _, c in items], dtype=np.int64)
            csd = to_csd(coeffs)  # [n, B]
            rr, pp = np.nonzero(csd)
            self.cols.append(
                _ColStore(rows[rr], pp.astype(np.int64), csd[rr, pp].astype(np.int64))
            )

        # Frequency machinery (packed-int keyed).  Start tiny: the real
        # table is sized by _build_initial_counts, and the assembly-only
        # path (build_counts=False) never touches it.
        self.counts = _CountTable(1 << 8)
        # program row -> columns that may contain digits of that row
        self.row_cols: dict[int, set[int]] = {}
        self.heap: list[tuple[float, int, int]] = []
        self._seq = 0
        self._weights: dict[int, float] = {}
        # keys believed to have a live heap entry.  Pop discards the flag
        # even when duplicate entries remain: a key may be re-pushed
        # spuriously (harmless extra entry) but is never lost while viable.
        self._inheap: set[int] = set()
        self._impl_cache: dict[int, int] = {}
        self._combine_cache: dict[tuple, Term] = {}
        self._deferred: Optional[np.ndarray] = None  # low-priority tier

        # Per-program-row metadata mirrors (lsb, msb, depth, is_zero) for
        # vectorized weight computation; synced lazily as rows are added.
        self._meta_n = 0
        self._meta_lsb = np.zeros(0, dtype=np.int64)
        self._meta_msb = np.zeros(0, dtype=np.int64)
        self._meta_depth = np.zeros(0, dtype=np.int64)
        self._meta_zero = np.zeros(0, dtype=bool)

        if build_counts:
            self._build_initial_counts()

    # ------------------------------------------------------------------
    # Weights (static per key: operand qints are fixed at row creation)
    # ------------------------------------------------------------------
    def _sync_meta(self) -> None:
        n = len(self.prog.rows)
        if self._meta_n == n:
            return
        if n > self._meta_lsb.shape[0]:
            cap = max(2 * n, 64)
            for name in ("_meta_lsb", "_meta_msb", "_meta_depth"):
                a = getattr(self, name)
                b = np.zeros(cap, dtype=np.int64)
                b[: self._meta_n] = a[: self._meta_n]
                setattr(self, name, b)
            z = np.zeros(cap, dtype=bool)
            z[: self._meta_n] = self._meta_zero[: self._meta_n]
            self._meta_zero = z
        for k in range(self._meta_n, n):
            r = self.prog.rows[k]
            q = r.qint
            self._meta_depth[k] = r.depth
            if q.is_zero:
                self._meta_zero[k] = True
            else:
                self._meta_lsb[k] = q.lsb
                self._meta_msb[k] = q.msb
        self._meta_n = n

    def _weights_vec(self, keys: np.ndarray) -> np.ndarray:
        """Vectorized heap weights for an array of packed keys."""
        self._sync_meta()
        rest = keys >> 1
        s = (rest & 0xFFFF) - _S_OFF
        rest = rest >> 16
        j = rest & _ROW_MASK
        i = rest >> _ROW_BITS
        w = np.ones(keys.shape[0], dtype=np.float64)
        if self.weighted:
            sh_a = np.maximum(0, -s)
            sh_b = np.maximum(0, s)
            msb_a = self._meta_msb[i] + sh_a
            lsb_a = self._meta_lsb[i] + sh_a
            msb_b = self._meta_msb[j] + sh_b
            lsb_b = self._meta_lsb[j] + sh_b
            ov = np.minimum(msb_a, msb_b) - np.maximum(lsb_a, lsb_b) + 1
            ov = np.where(
                self._meta_zero[i] | self._meta_zero[j], 0, np.maximum(ov, 0)
            )
            w = (ov + 1).astype(np.float64)
        if self.depth_weight:
            d = np.maximum(self._meta_depth[i], self._meta_depth[j])
            w = w / (1.0 + self.depth_weight * d)
        return w

    def _weight(self, key: int) -> float:
        """Scalar weight; bitwise-identical to :meth:`_weights_vec` (the
        run-loop staleness test compares the two with float equality)."""
        w = self._weights.get(key)
        if w is not None:
            return w
        self._sync_meta()
        i, j, s, _sign = _unpack_key(key)
        w = 1.0
        if self.weighted:
            if self._meta_zero[i] or self._meta_zero[j]:
                ov = 0
            else:
                sh_a = -s if s < 0 else 0
                sh_b = s if s > 0 else 0
                msb_a = int(self._meta_msb[i]) + sh_a
                lsb_a = int(self._meta_lsb[i]) + sh_a
                msb_b = int(self._meta_msb[j]) + sh_b
                lsb_b = int(self._meta_lsb[j]) + sh_b
                ov = min(msb_a, msb_b) - max(lsb_a, lsb_b) + 1
                if ov < 0:
                    ov = 0
            w = float(ov + 1)
        if self.depth_weight:
            d = max(int(self._meta_depth[i]), int(self._meta_depth[j]))
            w = w / (1.0 + self.depth_weight * d)
        self._weights[key] = w
        return w

    # ------------------------------------------------------------------
    # Frequency table construction and maintenance
    # ------------------------------------------------------------------
    def _register_rows(self, rows: np.ndarray, c: int) -> None:
        """Record that column c holds digits of these program rows."""
        rc = self.row_cols
        for r in np.unique(rows).tolist():
            cols = rc.get(r)
            if cols is None:
                rc[r] = {c}
            else:
                cols.add(c)

    def _build_initial_counts(self) -> None:
        key_arrays: list[np.ndarray] = []
        cnt_arrays: list[np.ndarray] = []
        for c, store in enumerate(self.cols):
            n = len(store)
            if n < 2:
                continue
            rows, poss, digs = store.live()
            self._register_rows(rows, c)
            ii, jj = _triu(n)
            packed = _canon_pack(
                rows[ii], poss[ii], digs[ii], rows[jj], poss[jj], digs[jj]
            )
            uniq, cnt = np.unique(packed, return_counts=True)
            key_arrays.append(uniq)
            cnt_arrays.append(cnt)
        if not key_arrays:
            return
        keys_cat = np.concatenate(key_arrays)
        cnts_cat = np.concatenate(cnt_arrays)
        uniq, inv = np.unique(keys_cat, return_inverse=True)
        sums = np.bincount(inv, weights=cnts_cat.astype(np.float64)).astype(np.int64)
        cap = 1 << 16
        while uniq.shape[0] * 2 > cap:
            cap *= 2
        self.counts = _CountTable(cap)
        self.counts.add_batch(uniq, sums)
        mask = sums >= 2
        keys2, cnts2 = uniq[mask], sums[mask]
        # Lazy tier loading: seed the heap with the top-priority tier only
        # and defer the long tail.  Deferred keys are reconsidered when the
        # heap drains (run() -> _refill), by which point most have fallen
        # below 2 occurrences and are never pushed at all.  Order is
        # near-max-first, not exact: a deferred key never rises without
        # being re-inserted through the delta path, but an in-heap key
        # whose count decays below the tier boundary is still implemented
        # before the deferred tier loads.  Measured effect on adder counts
        # is within the greedy tie-break noise (<1%, see
        # docs/solver_performance.md and tests/test_solver_regression.py).
        if keys2.shape[0] > 4096:
            pris = cnts2 * self._weights_vec(keys2)
            lo = pris < np.quantile(pris, 0.8)
            self._deferred = keys2[lo]
            keys2, cnts2 = keys2[~lo], cnts2[~lo]
        self._push_batch(keys2, cnts2)

    def _push_batch(self, keys: np.ndarray, cnts: np.ndarray) -> None:
        if keys.shape[0] == 0:
            return
        pris = -(cnts * self._weights_vec(keys))
        seq = self._seq
        heap = self.heap
        inheap = self._inheap
        for key, pri in zip(keys.tolist(), pris.tolist()):
            heapq.heappush(heap, (pri, seq, key))
            inheap.add(key)
            seq += 1
        self._seq = seq

    def _push(self, key: int, cnt: int) -> None:
        heapq.heappush(self.heap, (-cnt * self._weight(key), self._seq, key))
        self._inheap.add(key)
        self._seq += 1

    def _pairs_against(self, store: _ColStore, rows, poss, digs) -> np.ndarray:
        """Packed keys of a digit set against every live digit plus the
        pairs within the set itself (flat array, with multiplicity)."""
        out = []
        if store.n:
            R, P, D = store.live()
            out.append(
                _canon_pack(
                    rows[:, None], poss[:, None], digs[:, None],
                    R[None, :], P[None, :], D[None, :],
                ).ravel()
            )
        m = rows.shape[0]
        if m > 1:
            ii, jj = _triu(m)
            out.append(
                _canon_pack(rows[ii], poss[ii], digs[ii], rows[jj], poss[jj], digs[jj])
            )
        if not out:
            return np.zeros(0, dtype=np.int64)
        return np.concatenate(out) if len(out) > 1 else out[0]

    def _apply_deltas(self, rem_parts: list, add_parts: list) -> None:
        """One signed-delta count update for a whole implementation step."""
        parts = rem_parts + add_parts
        if not parts:
            return
        keys = np.concatenate(parts)
        if not keys.shape[0]:
            return
        n_rem = sum(a.shape[0] for a in rem_parts)
        signs = np.ones(keys.shape[0], dtype=np.float64)
        signs[:n_rem] = -1.0
        uniq, inv = np.unique(keys, return_inverse=True)
        delta = np.bincount(inv, weights=signs).astype(np.int64)
        changed = delta != 0
        uniq = uniq[changed]
        delta = delta[changed]
        new = self.counts.add_batch(uniq, delta)
        # (re)insert keys that became viable while absent from the heap
        pmask = (delta > 0) & (new >= 2)
        if pmask.any():
            inheap = self._inheap
            pkeys = uniq[pmask]
            absent = np.array(
                [k not in inheap for k in pkeys.tolist()], dtype=bool
            )
            if absent.any():
                self._push_batch(pkeys[absent], new[pmask][absent])

    # ------------------------------------------------------------------
    # Occurrence search
    # ------------------------------------------------------------------
    def _find_occurrences(self, key: int) -> dict[int, np.ndarray]:
        """Disjoint occurrences per column: sorted base positions p such
        that the digit pair ((i, p), (j, p+s)) matches the pattern.

        ``row_cols`` may contain stale columns; a column with no digits
        left on the pattern's rows is pruned here."""
        i, j, s, sign = _unpack_key(key)
        out: dict[int, np.ndarray] = {}
        ci = self.row_cols.get(i)
        cj = self.row_cols.get(j) if j != i else ci
        if not ci or not cj:
            return out
        cols = ci & cj if j != i else list(ci)
        for c in cols:
            store = self.cols[c]
            di_map = store.by_row.get(i)
            if not di_map:
                ci.discard(c)  # column no longer holds row i digits
                continue
            if i != j:
                dj_map = store.by_row.get(j)
                if not dj_map:
                    cj.discard(c)
                    continue
                # digits are +-1, so d_i * d_j == sign  <=>  d_j == sign * d_i
                dj_get = dj_map.get
                ps = sorted(
                    p for p, d in di_map.items() if dj_get(p + s) == sign * d
                )
            else:
                if len(di_map) < 2:
                    continue
                # chains like p, p+s, p+2s share digits: greedy disjoint match
                used: set[int] = set()
                ps = []
                dj_get = di_map.get
                for p in sorted(di_map):
                    if p in used or (p + s) in used:
                        continue
                    if dj_get(p + s) == sign * di_map[p]:
                        ps.append(p)
                        used.add(p)
                        used.add(p + s)
            if ps:
                out[c] = np.array(ps, dtype=np.int64)
        return out

    # ------------------------------------------------------------------
    # Main loop
    # ------------------------------------------------------------------
    def run(self) -> list[Optional[Term]]:
        counts = self.counts
        inheap = self._inheap
        heap = self.heap
        while heap or self._refill():
            neg_pri, _, key = heapq.heappop(heap)
            inheap.discard(key)
            cnt = counts.get(key)
            if cnt < 2:
                continue
            cur_pri = cnt * self._weight(key)
            if -neg_pri > cur_pri + 1e-9 or -neg_pri < cur_pri - 1e-9:
                self._push(key, cnt)  # stale either way: correct and re-sort
                continue
            implemented = self._implement(key)
            # keep viable keys represented in the heap
            cnt = counts.get(key)
            if implemented and cnt >= 2 and key not in inheap:
                self._push(key, cnt)
        return self._assemble()

    def _refill(self) -> bool:
        """Load the deferred low-priority tier once the heap drains."""
        deferred, self._deferred = self._deferred, None
        if deferred is None:
            return False
        inheap = self._inheap
        cnts = self.counts.get_batch(deferred)
        viable = cnts >= 2
        if viable.any():
            viable &= np.array(
                [k not in inheap for k in deferred.tolist()], dtype=bool
            )
        if not viable.any():
            return False
        self._push_batch(deferred[viable], cnts[viable])
        return True

    def _implement(self, key: int) -> bool:
        i, j, s, sign = _unpack_key(key)
        occs = self._find_occurrences(key)
        d_i_depth = self.prog.rows[i].depth
        d_j_depth = self.prog.rows[j].depth
        u_depth = max(d_i_depth, d_j_depth) + 1
        # Delay-constraint filter, per column.  Replacing k occurrences
        # moves exactly k digits of row i and k of row j onto the new row
        # (depth u_depth), so the column's leaf-depth multiset after k
        # acceptances depends only on k: simulate on the depth histogram.
        accepted: dict[int, np.ndarray] = {}
        total = 0
        for c, ps in occs.items():
            budget = self.budgets[c]
            if budget is None:
                accepted[c] = ps
                total += ps.shape[0]
                continue
            store = self.cols[c]
            self._sync_meta()
            dep = self._meta_depth[store.rows[: store.n]]
            lv, cn = np.unique(dep, return_counts=True)
            base = dict(zip(lv.tolist(), cn.tolist()))
            n_ps = ps.shape[0]
            n_keep = 0
            for n_seen in range(n_ps):
                k = n_keep + 1
                hist = dict(base)
                hist[d_i_depth] = hist.get(d_i_depth, 0) - k
                hist[d_j_depth] = hist.get(d_j_depth, 0) - k
                hist[u_depth] = hist.get(u_depth, 0) + k
                if min_tree_depth_hist(hist) <= budget:
                    n_keep = k
                else:
                    # feasibility depends only on k, so every remaining
                    # occurrence in this column is rejected too
                    self.stats.n_rejected_by_depth += n_ps - n_seen
                    break
            if n_keep:
                accepted[c] = ps[:n_keep]
                total += n_keep
        if total < 2:
            return False  # dormant until counts change again
        u = self._impl_cache.get(key)
        if u is None:
            u = self.prog.add_op(i, j, max(0, -s), max(0, s), sign)
            self._impl_cache[key] = u
        self.stats.n_patterns_implemented += 1
        rem_parts: list[np.ndarray] = []
        add_parts: list[np.ndarray] = []
        for c, ps in accepted.items():
            store = self.cols[c]
            k = ps.shape[0]
            r_rows = np.concatenate(
                [np.full(k, i, dtype=np.int64), np.full(k, j, dtype=np.int64)]
            )
            r_poss = np.concatenate([ps, ps + s])
            ds = [
                store.remove(r, p)
                for r, p in zip(r_rows.tolist(), r_poss.tolist())
            ]
            r_digs = np.array(ds, dtype=np.int64)
            rem_parts.append(self._pairs_against(store, r_rows, r_poss, r_digs))
            a_poss = ps + min(0, s)
            a_digs = r_digs[:k]
            a_rows = np.full(k, u, dtype=np.int64)
            add_keys = self._pairs_against(store, a_rows, a_poss, a_digs)
            add_parts.append(add_keys)
            cols_u = self.row_cols.get(u)
            if cols_u is None:
                self.row_cols[u] = {c}
            else:
                cols_u.add(c)
            for p, d in zip(a_poss.tolist(), a_digs.tolist()):
                store.add(u, p, d)
            self.stats.n_occurrences_replaced += k
        self._apply_deltas(rem_parts, add_parts)
        return True

    # ------------------------------------------------------------------
    # Final adder-tree assembly per column
    # ------------------------------------------------------------------
    def _combine(self, t1: Term, t2: Term) -> Term:
        if self.assembly_dedup:
            ck = (t1, t2) if (t1.row, t1.shift, t1.sign) <= (t2.row, t2.shift, t2.sign) else (t2, t1)
            hit = self._combine_cache.get(ck)
            if hit is not None:
                return hit
        if t1.sign == t2.sign:
            m = min(t1.shift, t2.shift)
            u = self.prog.add_op(t1.row, t2.row, t1.shift - m, t2.shift - m, +1)
            res = Term(t1.sign, u, m)
        else:
            pos, neg = (t1, t2) if t1.sign > 0 else (t2, t1)
            m = min(pos.shift, neg.shift)
            u = self.prog.add_op(pos.row, neg.row, pos.shift - m, neg.shift - m, -1)
            res = Term(1, u, m)
        self.stats.n_assembly_adders += 1
        if self.assembly_dedup:
            self._combine_cache[ck] = res
        return res

    def _assemble(self) -> list[Optional[Term]]:
        outputs: list[Optional[Term]] = []
        for store in self.cols:
            if not len(store):
                outputs.append(None)
                continue
            R, P, D = store.live()
            order = np.lexsort((P, R))  # (row, pos) lexicographic
            # merge two shallowest first: optimal max-depth (min-max Huffman)
            h: list[tuple[int, int, int, Term]] = []
            seq = 0
            for k in order.tolist():
                row, pos, d = int(R[k]), int(P[k]), int(D[k])
                t = Term(d, row, pos)
                h.append((self.prog.rows[row].depth, self.prog.rows[row].qint.width, seq, t))
                seq += 1
            heapq.heapify(h)
            while len(h) > 1:
                _, _, _, t1 = heapq.heappop(h)
                _, _, _, t2 = heapq.heappop(h)
                t = self._combine(t1, t2)
                heapq.heappush(h, (self.prog.rows[t.row].depth, self.prog.rows[t.row].qint.width, seq, t))
                seq += 1
            outputs.append(h[0][3])
        return outputs
