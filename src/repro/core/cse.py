"""Stage 2 of da4ml: cost-aware two-term common subexpression elimination.

Operates on the CSD digit tensor of an integer coefficient matrix whose
rows are *existing program values* (inputs, or stage-1 intermediates).
State (paper §4.4):

  * ``M_expr`` — sparse digit storage, per output column a compacted
    numpy triple ``(rows, poss, digs)`` with digit in {-1, +1} plus a
    ``(row, pos) -> slot`` index (:class:`_ColStore`);
  * ``L_impl`` — the DAIS program rows (implemented values).

Each update step selects a two-term subexpression — canonical four-tuple
``(i, j, s, sign)`` encoding ``u = (x_i << max(0,-s)) + sign * (x_j <<
max(0,s))``, packed into a single int64 key — and implements it,
replacing every occurrence's digit pair with a single digit on the new
row.

Key differences from prior art that this module reproduces:

  * subexpressions are matched across *different power-of-two scalings*
    (relative shift ``s`` is part of the key, not a uniform row/column
    shift as in MCMT [13]) and across *signed digits* (``sign`` in key),
    unlike Scalable CMVM [57];
  * selection is most-frequent-first via a cached frequency table, not
    the O(|L_impl|^2) one-step-lookahead of [4, 14] — the paper measures
    the lookahead is worth <2% adders;
  * frequency is weighted by the *operand bit overlap* (paper §4.4): the
    cost model (Eq. 1) prefers operands with similar bitwidths/shifts, but
    weighting by full cost would reward half-adder overhead bits; overlap
    weighting is the paper's compromise;
  * a delay constraint is enforced per output column: a replacement is
    rejected if the column's minimal achievable merge-tree depth would
    exceed its budget.

Selection semantics (shared by both engines, enforced identical by
test): repeatedly implement the key with the maximum priority
``count * weight`` among keys with ``count >= 2`` that are not dormant,
breaking priority ties toward the smallest packed key.  A key whose
implementation fails (all occurrences depth-rejected, or fewer than two
disjoint occurrences survive) goes *dormant* and is reconsidered only
when its count next increases.

Three interchangeable engines realise this rule
(``engine="batch"`` is the default; see docs/solver_performance.md):

  * ``engine="heap"`` — exact lazy max-heap of ``(-priority, key)``
    entries with lazy deletion: a fresh entry is pushed whenever a key's
    count increases, so for every eligible key some entry bounds its
    current priority from above; stale entries are corrected (or
    discarded) at pop time.
  * ``engine="batch"`` — generation-stamped top-k candidate array.
    Cached priorities are upper bounds (counts only decay without a
    re-append); each implementation step bumps a generation counter, and
    an entry's cached score is exact iff its stamp is current.  One
    selection round takes the running max of the cached scores,
    re-scores the stale entries *at that value* in one vectorized sweep,
    and implements the smallest exact winner — the common path performs
    zero heap operations.  Keys outside the top-k array live in a
    deferred *rest* tier summarised by one stale upper bound; only when
    the running best decays to that bound is the tier re-scored (one
    vectorized sweep) and re-partitioned.
  * ``engine="arena"`` — the batch selection rule over a fully
    array-resident core (:class:`CSEArena`): column digit stores are
    bump-allocated windows over flat reusable buffers carrying packed
    ``row << 16 | pos`` tokens, the pair-count table (with per-key
    dormancy bytes) lives in preallocated open-addressed arrays, and the
    per-step replace/count-delta pass is fused — pair keys are computed
    straight from (token, digit) windows and scattered into the count
    table with one ``np.add.at`` instead of the batch engine's
    sort + ``reduceat`` dedup.  Buffers persist (per thread, see
    :func:`get_thread_arena`) so repeated solves run allocation-quiet.
    Selection semantics are shared with ``batch`` verbatim, so programs
    are bit-identical across all three engines.

Performance notes (the solver fast path; see docs/solver_performance.md):

  * pattern keys are packed int64s; the initial pair-count table is built
    in a single vectorized pass (one ``_canon_pack`` + one ``np.unique``
    over every column's upper-triangle pairs at once);
  * the count update after an implementation step is ONE signed-delta
    batch (removed/added digits against the live stores, all accepted
    columns concatenated into a single packed-key array), deduplicated
    with a single ``np.unique`` and applied through the vectorized
    open-addressed :class:`_CountTable`;
  * ``row_cols`` maps each program row to the set of columns that may
    hold its digits (pruned lazily when a scan finds none), so locating a
    pattern's columns is one set intersection — no per-(key, column)
    count bookkeeping on the hot path;
  * priorities (overlap-bit weights) are computed vectorized from
    per-row ``lsb/msb/depth`` metadata arrays synced with the program;
  * the delay-constraint simulation in ``_implement`` evaluates a whole
    candidate batch per trial: replacing k occurrences shifts exactly
    k digits of row i and k of row j onto the new row's depth, so the
    feasibility of every acceptance count k = 1..n is one
    :func:`min_tree_depth_hist_batch` call on an O(distinct depths)
    histogram instead of n scalar tree simulations.
"""

from __future__ import annotations

import heapq
import threading
import weakref
from dataclasses import dataclass

import numpy as np

from ..obs import trace
from ..obs.metrics import get_registry
from .cost import min_tree_depth_hist, min_tree_depth_hist_batch, overlap_bits  # noqa: F401
from .csd import to_csd
from .dais import DAISProgram, Term

# ----------------------------------------------------------------------
# Pattern keys
# ----------------------------------------------------------------------
# Canonical key (i, j, s, sign): rows i <= j in program order; when i == j,
# s > 0.  Digit pair ((i, p), (j, p + s)) with product sign realises
#   d_i * 2^min(p, p+s) * u,   u = (x_i << max(0,-s)) + sign*(x_j << max(0,s))
#
# Keys are packed into a single int64 (rows < 2^21, |s| < 2^14, 1 sign
# bit) so they can be produced and deduplicated by vectorized numpy code.
# ``key >> 17`` strips shift and sign, leaving the packed row pair.

_ROW_BITS = 21
_ROW_MASK = (1 << _ROW_BITS) - 1
_S_OFF = 1 << 14

# Digit tokens: row << _TOK_BITS | pos packs one digit slot into an int64
# whose natural order IS the (row, pos) lexicographic order the canonical
# key needs — the arena engine's pair builder swaps with min/max instead
# of the 4-way compare of _canon_pack.  Positions are CSD digit indices
# (< csd_span <= 66), far below the 2^16 field.
_TOK_BITS = 16
_TOK_MASK = (1 << _TOK_BITS) - 1

# batch engine: size of the active candidate tier (the rest is deferred
# behind a single stale upper bound).  1024 won the sweep in
# docs/solver_performance.md: small enough that the per-selection running
# max is cheap, large enough that the stale bound effectively never binds.
_TIER = 1024


def _pack_keys(r1, r2, s, sg):
    """Pack canonical key components (scalars or arrays) into int64."""
    return (((r1 << _ROW_BITS) | r2) << 16 | (s + _S_OFF)) << 1 | (sg > 0)


def _unpack_key(key: int) -> tuple[int, int, int, int]:
    sign = 1 if (key & 1) else -1
    rest = key >> 1
    s = (rest & 0xFFFF) - _S_OFF
    rest >>= 16
    return (rest >> _ROW_BITS, rest & _ROW_MASK, s, sign)


def _canon_pack(rA, pA, dA, rB, pB, dB):
    """Vectorized canonical packed keys for digit pairs (arrays broadcast)."""
    swap = (rB < rA) | ((rB == rA) & (pB < pA))
    r1 = np.where(swap, rB, rA)
    p1 = np.where(swap, pB, pA)
    r2 = np.where(swap, rA, rB)
    p2 = np.where(swap, pA, pB)
    return _pack_keys(r1, r2, p2 - p1, dA * dB)


def _pack_pair_keys(tA, dA, tB, dB):
    """Canonical packed keys for digit pairs given (token, digit) arrays.

    Bit-for-bit identical to :func:`_canon_pack` on the unpacked
    components: token order equals (row, pos) lexicographic order, so the
    canonical swap is one ``minimum``/``maximum`` pair, and the sign bit
    is ``(dA * dB + 1) >> 1`` (digits are +-1)."""
    mn = np.minimum(tA, tB)
    mx = np.maximum(tA, tB)
    key = ((mn >> _TOK_BITS) << _ROW_BITS) | (mx >> _TOK_BITS)
    key = (key << 16) | ((mx & _TOK_MASK) - (mn & _TOK_MASK) + _S_OFF)
    return (key << 1) | ((dA * dB + 1) >> 1)


_HASH_MULT = np.uint64(0x9E3779B97F4A7C15)


class _CountTable:
    """Open-addressed int64 -> int64 counter with vectorized batch ops.

    Replaces a Python dict on the CSE hot path: a whole implementation
    step's count delta becomes a handful of numpy gathers/scatters with
    linear probing (multiplicative hashing on the HIGH product bits)
    instead of one dict operation per key.  Keys must be >= 0 (-1 is the
    empty sentinel); absent keys read as 0 and zeroed entries are kept.
    """

    __slots__ = ("mask", "shift", "keys", "vals", "n")

    def __init__(self, cap: int = 1 << 16) -> None:
        self.mask = cap - 1
        self.shift = np.uint64(64 - (cap.bit_length() - 1))
        self.keys = np.full(cap, -1, dtype=np.int64)
        self.vals = np.zeros(cap, dtype=np.int64)
        self.n = 0

    def _slots_claim(self, k: np.ndarray) -> np.ndarray:
        """Slot per key (existing or newly claimed); keys must be unique.
        (The arena engine's duplicate-bearing per-step stream goes
        through :meth:`_ArenaCountTable.scatter_add` instead.)"""
        mask = self.mask
        idx = ((k.astype(np.uint64) * _HASH_MULT) >> self.shift).astype(np.int64)
        out = np.empty(k.shape[0], dtype=np.int64)
        pending = np.arange(k.shape[0])
        while pending.size:
            slots = idx[pending]
            cur = self.keys[slots]
            hit = cur == k[pending]
            empty = cur == -1
            if empty.any():
                e = pending[empty]
                self.keys[idx[e]] = k[e]  # duplicate slots: last write wins
                won = self.keys[idx[e]] == k[e]
                self.n += int(won.sum())
                hit = hit.copy()
                hit[empty] = won
            out[pending[hit]] = idx[pending[hit]]
            pending = pending[~hit]
            idx[pending] = (idx[pending] + 1) & mask
        return out

    def add_batch(self, k: np.ndarray, delta: np.ndarray) -> np.ndarray:
        """counts[k] += delta for unique keys; returns the new counts."""
        # grow until the worst case (every key new) fits under 33% load —
        # probes then almost always resolve in one vectorized round, and a
        # single under-sized growth step could leave the table full and
        # turn the linear probe into an infinite loop
        while (self.n + k.shape[0]) * 3 > self.mask + 1:
            self._grow()
        slots = self._slots_claim(k)
        new = self.vals[slots] + delta
        self.vals[slots] = new
        return new

    def get_batch(self, k: np.ndarray) -> np.ndarray:
        mask = self.mask
        idx = ((k.astype(np.uint64) * _HASH_MULT) >> self.shift).astype(np.int64)
        out = np.zeros(k.shape[0], dtype=np.int64)
        pending = np.arange(k.shape[0])
        while pending.size:
            slots = idx[pending]
            cur = self.keys[slots]
            hit = cur == k[pending]
            out[pending[hit]] = self.vals[slots[hit]]
            done = hit | (cur == -1)
            pending = pending[~done]
            idx[pending] = (idx[pending] + 1) & mask
        return out

    def get(self, key: int) -> int:
        mask = self.mask
        keys = self.keys
        idx = ((key * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF) >> int(self.shift)
        while True:
            cur = keys[idx]
            if cur == key:
                return int(self.vals[idx])
            if cur == -1:
                return 0
            idx = (idx + 1) & mask

    def _grow(self) -> None:
        live = self.keys != -1
        lk, lv = self.keys[live], self.vals[live]
        cap = (self.mask + 1) * 2
        while self.n * 2 > cap:
            cap *= 2
        self.mask = cap - 1
        self.shift = np.uint64(64 - (cap.bit_length() - 1))
        self.keys = np.full(cap, -1, dtype=np.int64)
        self.vals = np.zeros(cap, dtype=np.int64)
        self.n = 0
        slots = self._slots_claim(lk)
        self.vals[slots] = lv


class CSEArena:
    """Reusable numpy workspace for ``engine="arena"`` CSE solves.

    Every long-lived mutable buffer of one arena-engine run lives here:
    the open-addressed pair-count table (plus per-key dormancy bytes),
    the candidate-tier arrays, the flat column-store buffers (handed out
    as bump-allocated windows), and the per-step scratch vectors.
    Buffers only ever grow — ``n_reallocs`` counts growth events — so a
    second solve of the same shape reports zero new reallocations and
    the hot loop runs entirely inside memory allocated by the first.

    One arena serves one CSE run at a time; ``CSE`` falls back to a
    fresh private arena when the thread's arena is busy.  Use
    :func:`get_thread_arena` for the per-thread instance that
    ``CSE(engine="arena")`` picks up automatically — per-thread reuse is
    what keeps the compiler's thread-pool solves allocation-quiet
    across layers.  Not thread-safe; never share one arena between
    threads.
    """

    __slots__ = (
        "scratch", "tab_keys", "tab_vals", "tab_dorm", "col_bufs",
        "col_cap", "col_top", "n_reallocs", "n_solves", "busy",
        "_col_demand", "_col_demand_hw", "_owner",
    )

    _COL_FIELDS = ("rows", "poss", "digs", "toks")

    def __init__(self) -> None:
        self.scratch: dict[str, np.ndarray] = {}
        self.tab_keys: np.ndarray | None = None
        self.tab_vals: np.ndarray | None = None
        self.tab_dorm: np.ndarray | None = None
        self.col_bufs: dict[str, np.ndarray] = {}
        self.col_cap = 0
        self.col_top = 0
        self.n_reallocs = 0
        self.n_solves = 0
        self.busy = False
        self._col_demand = 0
        self._col_demand_hw = 0
        self._owner: weakref.ref | None = None

    # -- lifecycle -----------------------------------------------------
    def acquire(self, owner=None) -> bool:
        """Claim the arena for one CSE run; False when already in use.

        The owner is held by weakref: if a previous owner died without
        releasing (e.g. its ``__init__`` raised after acquiring), the
        arena is reclaimed here instead of staying busy forever."""
        if self.busy and not (
            self._owner is not None and self._owner() is None
        ):
            return False
        self.busy = True
        self._owner = weakref.ref(owner) if owner is not None else None
        self.n_solves += 1
        self.col_top = 0
        self._col_demand = 0
        return True

    def release(self) -> None:
        # grow the column arena to this run's high-water NOW (not at the
        # next acquire), so the realloc is charged to the run that
        # discovered the demand and a repeat solve starts preallocated
        self._col_demand_hw = max(self._col_demand_hw, self._col_demand)
        if self.col_cap < self._col_demand_hw:
            self._grow_cols(self._col_demand_hw)
        self.busy = False
        self._owner = None

    # -- named scratch vectors ----------------------------------------
    def take(self, name: str, n: int, dtype=np.int64) -> np.ndarray:
        """A named scratch buffer of capacity >= n (slice ``[:n]``)."""
        buf = self.scratch.get(name)
        if buf is None or buf.shape[0] < n or buf.dtype != dtype:
            cap = 256
            while cap < n:
                cap <<= 1
            self.scratch[name] = buf = np.empty(cap, dtype=dtype)
            self.n_reallocs += 1
        return buf

    # -- column-store bump allocator ----------------------------------
    def col_alloc(self, cap: int) -> dict[str, np.ndarray]:
        """One column window (rows/poss/digs/toks) of capacity ``cap``."""
        self._col_demand += cap
        if self.col_top + cap > self.col_cap:
            self._grow_cols(max(2 * self.col_cap, self.col_top + cap))
        k = self.col_top
        self.col_top = k + cap
        return {f: self.col_bufs[f][k : k + cap] for f in self._COL_FIELDS}

    def _grow_cols(self, need: int) -> None:
        cap = 1 << 12
        while cap < need:
            cap <<= 1
        # live windows keep referencing the orphaned buffers (their views
        # hold the old base arrays alive); only new windows land here
        self.col_bufs = {f: np.empty(cap, dtype=np.int64) for f in self._COL_FIELDS}
        self.col_cap = cap
        self.col_top = 0
        self.n_reallocs += 1


_ARENA_TLS = threading.local()


def get_thread_arena() -> CSEArena:
    """The calling thread's shared :class:`CSEArena` (created on first
    use).  ``CSE(engine="arena")`` picks this up when no explicit arena
    is passed, so consecutive solves on one thread — including each of
    the compiler's thread-pool workers — reuse warm buffers."""
    ar = getattr(_ARENA_TLS, "arena", None)
    if ar is None:
        ar = _ARENA_TLS.arena = CSEArena()
    return ar


class _ArenaCountTable(_CountTable):
    """Arena-resident :class:`_CountTable` with per-key dormancy flags.

    The key/value/dormancy arrays live in (and are reused from) a
    :class:`CSEArena`; ``reset`` re-claims them for a new run and
    ``_grow`` re-homes them, each charging the arena a reallocation only
    on a genuine capacity increase.  Dormancy is a parallel byte per
    slot, so the selection loop tests a whole candidate batch with one
    vectorized probe instead of Python set membership."""

    __slots__ = ("arena", "dorm")

    def __init__(self, arena: CSEArena) -> None:
        self.arena = arena
        self.mask = 0
        self.shift = np.uint64(0)
        self.keys = None
        self.vals = None
        self.dorm = None
        self.n = 0

    def reset(self, n_expected: int) -> None:
        """Clear and size for ~n_expected initial keys, kept under 1/10
        load: a CSE run roughly triples its key population (every minted
        row spawns fresh pair keys) and occupancy may overcount duplicate
        claims, so the generous factor is what keeps the hot loop free of
        mid-run rehashes.  Reuses the arena's buffers whenever they are
        already big enough."""
        cap = 1 << 16
        while n_expected * 10 > cap:
            cap <<= 1
        self._rehome(cap)

    def _rehome(self, cap: int) -> None:
        """Point this table at a cleared ``cap``-entry slice of the
        arena's buffers, (re)allocating them only on a genuine capacity
        increase.  Slice, don't adopt, an oversized buffer: a small run
        (e.g. the stage-2 CSE after a big stage 1) then only wipes what
        it uses."""
        ar = self.arena
        if ar.tab_keys is None or ar.tab_keys.shape[0] < cap:
            ar.tab_keys = np.empty(cap, dtype=np.int64)
            ar.tab_vals = np.empty(cap, dtype=np.int64)
            ar.tab_dorm = np.empty(cap, dtype=np.int8)
            ar.n_reallocs += 1
        self.keys = ar.tab_keys[:cap]
        self.vals = ar.tab_vals[:cap]
        self.dorm = ar.tab_dorm[:cap]
        self.keys.fill(-1)
        self.vals.fill(0)
        self.dorm.fill(0)
        self.mask = cap - 1
        self.shift = np.uint64(64 - (cap.bit_length() - 1))
        self.n = 0

    def reserve(self, k: int) -> None:
        """Ensure ``k`` further (possibly new) keys fit under 50% load."""
        while (self.n + k) * 2 > self.mask + 1:
            self._grow()

    def _grow(self) -> None:
        live = self.keys != -1
        lk, lv, ld = self.keys[live], self.vals[live], self.dorm[live]
        cap = (self.mask + 1) * 2
        while self.n * 4 > cap:
            cap *= 2
        self._rehome(cap)
        slots = self._slots_claim(lk)
        self.vals[slots] = lv
        self.dorm[slots] = ld

    def scatter_add(self, k: np.ndarray, delta: np.ndarray):
        """Fused claim + scatter for a (possibly duplicated) key batch:
        returns ``(slots, before, after)`` where ``before``/``after`` are
        each key's count on either side of one ``np.add.at``.  The first
        probe round runs without index indirection (it touches every
        key); later rounds only handle the collision tail.  Occupancy may
        overcount duplicate new keys — it only drives the growth
        heuristic, which the 50% reserve threshold absorbs."""
        self.reserve(k.shape[0])
        mask = self.mask
        keys = self.keys
        idx = ((k.view(np.uint64) * _HASH_MULT) >> self.shift).view(np.int64)
        cur = keys[idx]
        hit = cur == k
        if not hit.all():
            empty = cur == -1
            if empty.any():
                e = np.flatnonzero(empty)
                keys[idx[e]] = k[e]  # duplicate slots: last write wins
                won = keys[idx[e]] == k[e]
                # exact occupancy (duplicate winners share a slot): the
                # unique() runs only over this step's new keys, and keeps
                # `n` honest so the 1/10 reset sizing never rehashes
                self.n += int(np.unique(idx[e][won]).size)
                hit[e] = won
            pending = np.flatnonzero(~hit)
            while pending.size:
                slots = (idx[pending] + 1) & mask
                idx[pending] = slots
                cur = keys[slots]
                hitp = cur == k[pending]
                empty = cur == -1
                if empty.any():
                    e = pending[empty]
                    keys[idx[e]] = k[e]
                    won = keys[idx[e]] == k[e]
                    self.n += int(np.unique(idx[e][won]).size)
                    hitp = hitp.copy()
                    hitp[empty] = won
                pending = pending[~hitp]
        vals = self.vals
        before = vals[idx]
        np.add.at(vals, idx, delta)
        after = vals[idx]
        return idx, before, after

    # -- dormancy ------------------------------------------------------
    def slots_lookup(self, k: np.ndarray) -> np.ndarray:
        """Slot per key, -1 when absent (read-only probe)."""
        mask = self.mask
        idx = ((k.astype(np.uint64) * _HASH_MULT) >> self.shift).astype(np.int64)
        out = np.full(k.shape[0], -1, dtype=np.int64)
        pending = np.arange(k.shape[0])
        while pending.size:
            slots = idx[pending]
            cur = self.keys[slots]
            hit = cur == k[pending]
            out[pending[hit]] = slots[hit]
            done = hit | (cur == -1)
            pending = pending[~done]
            idx[pending] = (idx[pending] + 1) & mask
        return out

    def dormant_mask(self, k: np.ndarray) -> np.ndarray:
        slots = self.slots_lookup(k)
        out = np.zeros(k.shape[0], dtype=bool)
        found = slots >= 0
        out[found] = self.dorm[slots[found]] != 0
        return out

    def set_dormant(self, key: int) -> None:
        mask = self.mask
        keys = self.keys
        idx = ((key * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF) >> int(self.shift)
        while True:
            cur = keys[idx]
            if cur == key:
                self.dorm[idx] = 1
                return
            if cur == -1:
                return  # absent keys have count 0: nothing to mark
            idx = (idx + 1) & mask

    def is_dormant(self, key: int) -> bool:
        mask = self.mask
        keys = self.keys
        idx = ((key * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF) >> int(self.shift)
        while True:
            cur = keys[idx]
            if cur == key:
                return bool(self.dorm[idx])
            if cur == -1:
                return False
            idx = (idx + 1) & mask


_TRIU_CACHE: dict[int, tuple[np.ndarray, np.ndarray]] = {}


def _triu(m: int) -> tuple[np.ndarray, np.ndarray]:
    hit = _TRIU_CACHE.get(m)
    if hit is None:
        hit = _TRIU_CACHE[m] = np.triu_indices(m, k=1)
    return hit


def _concat3(parts: list[tuple]) -> tuple:
    """Concatenate a list of (rows, poss, digs) triples componentwise."""
    if len(parts) == 1:
        return parts[0]
    return (
        np.concatenate([t[0] for t in parts]),
        np.concatenate([t[1] for t in parts]),
        np.concatenate([t[2] for t in parts]),
    )


def _step_pairs(sets: list[tuple], snaps: list[tuple], set_signs: list[int]):
    """All digit pairs one implementation step contributes for a list of
    per-column digit sets: each set element against every live digit of
    its column's (post-removal) store snapshot, plus the pairs within the
    set itself.  Columns are processed as one block-structured cross
    product — A-side components repeated per element, B-side gathered
    through a block-local index — so the whole step (removed and added
    sets together) needs a handful of numpy ops instead of per-column
    tiling.  Returns componentwise (A, B) tuples plus the per-pair count
    delta sign (the A-side set's sign), or None for an empty step."""
    cat_a = _concat3(sets)
    cat_s = _concat3(snaps)
    m = np.array([t[0].shape[0] for t in sets], dtype=np.int64)
    n = np.array([t[0].shape[0] for t in snaps], dtype=np.int64)
    sgn = np.asarray(set_signs, dtype=np.int64)
    a_parts: list[list] = [[], [], []]
    b_parts: list[list] = [[], [], []]
    s_parts: list[np.ndarray] = []
    reps = np.repeat(n, m)  # pairs per set element
    total = int(reps.sum())
    if total:
        ends = np.cumsum(reps)
        off_elem = np.repeat(np.cumsum(n) - n, m)  # store offset per element
        gidx = np.arange(total, dtype=np.int64) - np.repeat(
            ends - reps - off_elem, reps
        )
        for q in range(3):
            a_parts[q].append(np.repeat(cat_a[q], reps))
            b_parts[q].append(cat_s[q][gidx])
        s_parts.append(np.repeat(np.repeat(sgn, m), reps))
    ii_parts: list[np.ndarray] = []
    jj_parts: list[np.ndarray] = []
    tri_n = np.zeros(len(sets), dtype=np.int64)
    off = 0
    for si, t in enumerate(sets):
        mm = t[0].shape[0]
        if mm > 1:
            ii, jj = _triu(mm)
            tri_n[si] = ii.shape[0]
            ii_parts.append(ii + off)
            jj_parts.append(jj + off)
        off += mm
    if ii_parts:
        ii = np.concatenate(ii_parts) if len(ii_parts) > 1 else ii_parts[0]
        jj = np.concatenate(jj_parts) if len(jj_parts) > 1 else jj_parts[0]
        for q in range(3):
            a_parts[q].append(cat_a[q][ii])
            b_parts[q].append(cat_a[q][jj])
        s_parts.append(np.repeat(sgn, tri_n))
    if not a_parts[0]:
        return None
    a = tuple(np.concatenate(p) if len(p) > 1 else p[0] for p in a_parts)
    b = tuple(np.concatenate(p) if len(p) > 1 else p[0] for p in b_parts)
    s = np.concatenate(s_parts) if len(s_parts) > 1 else s_parts[0]
    return a, b, s


class _ColStore:
    """Compacted column digit store: parallel (rows, poss, digs, toks)
    vectors for the live digits plus a ``(row, pos) -> slot`` index.
    Removal swaps the last live slot in, so ``[:n]`` is always dense and
    directly usable by vectorized pair-key / occurrence / depth
    computations.  ``toks`` caches ``row << _TOK_BITS | pos`` per digit;
    the arena engine's pair builder consumes (toks, digs) windows
    directly.  With ``alloc`` (an arena's bump allocator) the vectors
    are windows into flat reusable buffers, and growth is an
    index-window move — the live slice relocates to a fresh window, the
    backing buffers persist across solves."""

    __slots__ = ("rows", "poss", "digs", "toks", "n", "index", "by_row", "_alloc")

    def __init__(self, rows, poss, digs, alloc=None) -> None:
        rows = np.asarray(rows, dtype=np.int64)
        poss = np.asarray(poss, dtype=np.int64)
        digs = np.asarray(digs, dtype=np.int64)
        n = int(rows.shape[0])
        self._alloc = alloc
        if alloc is not None:
            # digits only ever move within a column (k removed pairs are
            # replaced by k digits of the new row), so n never exceeds
            # the initial count; +4 absorbs the degenerate tiny columns
            w = alloc(n + 4)
            w["rows"][:n] = rows
            w["poss"][:n] = poss
            w["digs"][:n] = digs
            self.rows = w["rows"]
            self.poss = w["poss"]
            self.digs = w["digs"]
            self.toks = w["toks"]
        else:
            self.rows, self.poss, self.digs = rows, poss, digs
            self.toks = np.empty(n, dtype=np.int64)
        np.left_shift(self.rows[:n], _TOK_BITS, out=self.toks[:n])
        np.bitwise_or(self.toks[:n], self.poss[:n], out=self.toks[:n])
        self.n = n
        self.index = {}
        self.by_row: dict[int, dict[int, int]] = {}
        for k, (r, p, d) in enumerate(
            zip(rows.tolist(), poss.tolist(), digs.tolist())
        ):
            self.index[(r, p)] = k
            self.by_row.setdefault(r, {})[p] = d

    def __len__(self) -> int:
        return self.n

    def __contains__(self, rp) -> bool:
        return rp in self.index

    def get(self, row: int, pos: int) -> int:
        return int(self.digs[self.index[(row, pos)]])

    def live(self):
        return self.rows[: self.n], self.poss[: self.n], self.digs[: self.n]

    def add(self, row: int, pos: int, d: int) -> None:
        assert (row, pos) not in self.index, "duplicate digit slot"
        if self.n == self.rows.shape[0]:
            cap = max(2 * self.n, 8)
            if self._alloc is not None:
                w = self._alloc(cap)
                for name in ("rows", "poss", "digs", "toks"):
                    w[name][: self.n] = getattr(self, name)[: self.n]
                    setattr(self, name, w[name])
            else:
                for name in ("rows", "poss", "digs", "toks"):
                    a = getattr(self, name)
                    b = np.zeros(cap, dtype=np.int64)
                    b[: self.n] = a[: self.n]
                    setattr(self, name, b)
        k = self.n
        self.rows[k] = row
        self.poss[k] = pos
        self.digs[k] = d
        self.toks[k] = (row << _TOK_BITS) | pos
        self.index[(row, pos)] = k
        self.by_row.setdefault(row, {})[pos] = d
        self.n += 1

    def remove(self, row: int, pos: int) -> int:
        k = self.index.pop((row, pos))
        d = int(self.digs[k])
        last = self.n - 1
        if k != last:
            r2, p2 = int(self.rows[last]), int(self.poss[last])
            self.rows[k] = r2
            self.poss[k] = p2
            self.digs[k] = self.digs[last]
            self.toks[k] = self.toks[last]
            self.index[(r2, p2)] = k
        self.n = last
        m = self.by_row[row]
        del m[pos]
        if not m:
            del self.by_row[row]
        return d


@dataclass
class CSEStats:
    n_patterns_implemented: int = 0
    n_occurrences_replaced: int = 0
    n_rejected_by_depth: int = 0
    n_assembly_adders: int = 0
    # engine introspection (batch: tier reloads / stale-entry corrections;
    # heap: pops that had to correct or discard a stale entry)
    n_tier_reloads: int = 0
    n_stale_corrections: int = 0
    # observability: candidate-tier compactions this run, and arena
    # buffer-growth events charged to this run (0 for heap/batch)
    n_compactions: int = 0
    n_arena_reallocs: int = 0


class CSE:
    def __init__(
        self,
        prog: DAISProgram,
        coeff_cols: list[dict[int, int]],
        budgets: list[int | None] | None = None,
        weighted: bool = True,
        assembly_dedup: bool = True,
        depth_weight: float = 0.0,
        *,
        engine: str = "batch",
        build_counts: bool = True,
        arena: CSEArena | None = None,
    ) -> None:
        if engine not in ("heap", "batch", "arena"):
            raise ValueError(f"unknown CSE engine {engine!r}")
        self.prog = prog
        self.budgets = budgets if budgets is not None else [None] * len(coeff_cols)
        self.weighted = weighted
        self.assembly_dedup = assembly_dedup
        self.engine = engine
        # engine="arena": claim the (per-thread, unless given) workspace
        # for this run; released at the end of run().  A busy arena —
        # another live arena CSE on this thread — falls back to a fresh
        # private workspace so correctness never depends on reuse.
        self.arena: CSEArena | None = None
        self._arena_owned = False
        alloc = None
        if engine == "arena":
            ar = arena if arena is not None else get_thread_arena()
            if not ar.acquire(owner=self):
                ar = CSEArena()
                ar.acquire(owner=self)
            self.arena = ar
            self._arena_owned = True
            self._arena_reallocs0 = ar.n_reallocs
            alloc = ar.col_alloc
        # beyond-paper: under tight delay budgets, prefer subexpressions
        # with shallow operands (they leave headroom for further reuse
        # before the per-output depth budget binds):
        # priority /= (1 + depth_weight * max(depth_a, depth_b))
        self.depth_weight = depth_weight
        self.stats = CSEStats()

        # Column digit state, vectorized: the CSD digits of every column
        # are computed in one batch instead of per coefficient.
        self.cols: list[_ColStore] = []
        for col in coeff_cols:
            items = [(r, c) for r, c in col.items() if c != 0]
            if not items:
                self.cols.append(_ColStore([], [], []))
                continue
            rows = np.array([r for r, _ in items], dtype=np.int64)
            coeffs = np.array([c for _, c in items], dtype=np.int64)
            csd = to_csd(coeffs)  # [n, B]
            rr, pp = np.nonzero(csd)
            self.cols.append(
                _ColStore(
                    rows[rr], pp.astype(np.int64), csd[rr, pp].astype(np.int64),
                    alloc=alloc,
                )
            )

        # Frequency machinery (packed-int keyed).  Start tiny: the real
        # table is sized by _build_initial_counts, and the assembly-only
        # path (build_counts=False) never touches it.
        if engine == "arena":
            self.counts: _CountTable = _ArenaCountTable(self.arena)
            if not build_counts:
                self.counts.reset(0)
        else:
            self.counts = _CountTable(1 << 8)
        # program row -> columns that may contain digits of that row
        self.row_cols: dict[int, set[int]] = {}
        self._weights: dict[int, float] = {}
        # keys whose last implementation attempt failed; excluded from
        # selection until their count next increases.  heap/batch track
        # them in a Python set; arena keeps a dormancy byte per count-
        # table slot (_any_dormant just gates the vectorized probe).
        self._dormant: set[int] = set()
        self._any_dormant = False
        self._impl_cache: dict[int, int] = {}
        self._combine_cache: dict[tuple, Term] = {}

        # engine="heap": (-priority, key) entries, lazy deletion
        self.heap: list[tuple[float, int]] = []
        # engine="batch"/"arena": active candidate arrays + deferred rest
        # tier (arena: tier arrays live in the reusable workspace)
        self._gen = 0
        self._an = 0
        if engine == "arena":
            self._akeys = self.arena.take("tier_keys", 1024)
            self._apri = self.arena.take("tier_pri", 1024, np.float64)
            self._awt = self.arena.take("tier_wt", 1024, np.float64)
            self._agen = self.arena.take("tier_gen", 1024)
        else:
            self._akeys = np.empty(0, dtype=np.int64)
            self._apri = np.empty(0, dtype=np.float64)
            self._awt = np.empty(0, dtype=np.float64)  # static per-key weights
            self._agen = np.empty(0, dtype=np.int64)
        self._rest: np.ndarray | None = None
        self._rest_bound = -np.inf

        # Per-program-row metadata mirrors (lsb, msb, depth, is_zero) for
        # vectorized weight computation; synced lazily as rows are added.
        self._meta_n = 0
        self._meta_lsb = np.zeros(0, dtype=np.int64)
        self._meta_msb = np.zeros(0, dtype=np.int64)
        self._meta_depth = np.zeros(0, dtype=np.int64)
        self._meta_zero = np.zeros(0, dtype=bool)

        if build_counts:
            with trace.span("cse.pair_build", engine=engine, n_cols=len(coeff_cols)):
                self._build_initial_counts()

    # ------------------------------------------------------------------
    # Weights (static per key: operand qints are fixed at row creation)
    # ------------------------------------------------------------------
    def _sync_meta(self) -> None:
        n = len(self.prog.rows)
        if self._meta_n == n:
            return
        if n > self._meta_lsb.shape[0]:
            cap = max(2 * n, 64)
            for name in ("_meta_lsb", "_meta_msb", "_meta_depth"):
                a = getattr(self, name)
                b = np.zeros(cap, dtype=np.int64)
                b[: self._meta_n] = a[: self._meta_n]
                setattr(self, name, b)
            z = np.zeros(cap, dtype=bool)
            z[: self._meta_n] = self._meta_zero[: self._meta_n]
            self._meta_zero = z
        for k in range(self._meta_n, n):
            r = self.prog.rows[k]
            q = r.qint
            self._meta_depth[k] = r.depth
            if q.is_zero:
                self._meta_zero[k] = True
            else:
                self._meta_lsb[k] = q.lsb
                self._meta_msb[k] = q.msb
        self._meta_n = n

    def _weights_vec(self, keys: np.ndarray) -> np.ndarray:
        """Vectorized priority weights for an array of packed keys."""
        self._sync_meta()
        rest = keys >> 1
        s = (rest & 0xFFFF) - _S_OFF
        rest = rest >> 16
        j = rest & _ROW_MASK
        i = rest >> _ROW_BITS
        w = np.ones(keys.shape[0], dtype=np.float64)
        if self.weighted:
            sh_a = np.maximum(0, -s)
            sh_b = np.maximum(0, s)
            msb_a = self._meta_msb[i] + sh_a
            lsb_a = self._meta_lsb[i] + sh_a
            msb_b = self._meta_msb[j] + sh_b
            lsb_b = self._meta_lsb[j] + sh_b
            ov = np.minimum(msb_a, msb_b) - np.maximum(lsb_a, lsb_b) + 1
            ov = np.where(
                self._meta_zero[i] | self._meta_zero[j], 0, np.maximum(ov, 0)
            )
            w = (ov + 1).astype(np.float64)
        if self.depth_weight:
            d = np.maximum(self._meta_depth[i], self._meta_depth[j])
            w = w / (1.0 + self.depth_weight * d)
        return w

    def _weight(self, key: int) -> float:
        """Scalar weight; bitwise-identical to :meth:`_weights_vec` (both
        engines compare the two with exact float equality, so the scalar
        and vector paths must stay in lockstep)."""
        w = self._weights.get(key)
        if w is not None:
            return w
        self._sync_meta()
        i, j, s, _sign = _unpack_key(key)
        w = 1.0
        if self.weighted:
            if self._meta_zero[i] or self._meta_zero[j]:
                ov = 0
            else:
                sh_a = -s if s < 0 else 0
                sh_b = s if s > 0 else 0
                msb_a = int(self._meta_msb[i]) + sh_a
                lsb_a = int(self._meta_lsb[i]) + sh_a
                msb_b = int(self._meta_msb[j]) + sh_b
                lsb_b = int(self._meta_lsb[j]) + sh_b
                ov = min(msb_a, msb_b) - max(lsb_a, lsb_b) + 1
                if ov < 0:
                    ov = 0
            w = float(ov + 1)
        if self.depth_weight:
            d = max(int(self._meta_depth[i]), int(self._meta_depth[j]))
            w = w / (1.0 + self.depth_weight * d)
        self._weights[key] = w
        return w

    # ------------------------------------------------------------------
    # Frequency table construction and maintenance
    # ------------------------------------------------------------------
    def _register_rows(self, rows: np.ndarray, c: int) -> None:
        """Record that column c holds digits of these program rows."""
        rc = self.row_cols
        for r in np.unique(rows).tolist():
            cols = rc.get(r)
            if cols is None:
                rc[r] = {c}
            else:
                cols.add(c)

    def _build_initial_counts(self) -> None:
        # One vectorized pass: concatenate every column's live digits,
        # offset each column's cached upper-triangle indices into the
        # concatenated frame, then pack and count ALL pairs with one
        # pack + np.unique — no per-column tables or gathers.  The arena
        # engine packs straight from the cached (token, digit) vectors
        # and counts into the reusable pre-sized table.
        arena = self.engine == "arena"
        stores: list[_ColStore] = []
        ii_parts: list[np.ndarray] = []
        jj_parts: list[np.ndarray] = []
        off = 0
        for c, store in enumerate(self.cols):
            n = len(store)
            if n < 2:
                continue
            self._register_rows(store.rows[:n], c)
            stores.append(store)
            ii, jj = _triu(n)
            ii_parts.append(ii + off)
            jj_parts.append(jj + off)
            off += n
        if not stores:
            if arena:
                self.counts.reset(0)
            return
        ii = np.concatenate(ii_parts) if len(ii_parts) > 1 else ii_parts[0]
        jj = np.concatenate(jj_parts) if len(jj_parts) > 1 else jj_parts[0]
        if arena:
            cat_tok = np.concatenate([s.toks[: s.n] for s in stores])
            cat_dig = np.concatenate([s.digs[: s.n] for s in stores])
            packed = _pack_pair_keys(
                cat_tok[ii], cat_dig[ii], cat_tok[jj], cat_dig[jj]
            )
        else:
            cat = _concat3([s.live() for s in stores])
            packed = _canon_pack(
                cat[0][ii], cat[1][ii], cat[2][ii],
                cat[0][jj], cat[1][jj], cat[2][jj],
            )
        uniq, cnt = np.unique(packed, return_counts=True)
        sums = cnt.astype(np.int64)
        if arena:
            self.counts.reset(uniq.shape[0])
        else:
            cap = 1 << 16
            while uniq.shape[0] * 3 > cap:
                cap *= 2
            self.counts = _CountTable(cap)
        self.counts.add_batch(uniq, sums)
        mask = sums >= 2
        keys2, cnts2 = uniq[mask], sums[mask]
        if keys2.shape[0] == 0:
            return
        wts = self._weights_vec(keys2)
        pris = cnts2 * wts
        if self.engine == "heap":
            self.heap = list(zip((-pris).tolist(), keys2.tolist()))
            heapq.heapify(self.heap)
            return
        # batch engine: seed the active tier with the top-k priorities and
        # summarise the rest behind one stale upper bound.  The bound stays
        # valid because a deferred key's count can only decrease without
        # routing through _apply_deltas's increase path, which re-appends
        # it to the active tier at its exact new priority.
        if keys2.shape[0] > _TIER:
            thr = np.partition(pris, pris.shape[0] - _TIER)[pris.shape[0] - _TIER]
            hi = pris >= thr
            lo_pris = pris[~hi]
            if lo_pris.shape[0]:
                self._rest = keys2[~hi]
                self._rest_bound = float(lo_pris.max())
            keys2, pris, wts = keys2[hi], pris[hi], wts[hi]
        self._active_append(keys2, pris, wts)

    def _active_append(self, keys: np.ndarray, pris: np.ndarray,
                       wts: np.ndarray) -> None:
        """Append exact-scored entries to the active tier (stamped with the
        current generation)."""
        m = keys.shape[0]
        if m == 0:
            return
        if self._an + m > self._akeys.shape[0]:
            self._compact(m)
        k = self._an
        self._akeys[k : k + m] = keys
        self._apri[k : k + m] = pris
        self._awt[k : k + m] = wts
        self._agen[k : k + m] = self._gen
        self._an = k + m

    def _compact(self, m: int) -> None:
        """Drop dead entries; if the live tier still exceeds 2x _TIER,
        demote everything below the top-_TIER cached priorities back to
        the rest tier (their cached scores are upper bounds, so folding
        them into the stale bound keeps selection exact) — the running-max
        scan stays O(_TIER) for the whole run."""
        self.stats.n_compactions += 1
        if self.engine == "arena":
            self._compact_arena(m)
            return
        live = self._apri[: self._an] > 0.0
        an = int(live.sum())
        ak = self._akeys[: self._an][live]
        ap = self._apri[: self._an][live]
        aw = self._awt[: self._an][live]
        ag = self._agen[: self._an][live]
        if an > 2 * _TIER:
            thr = np.partition(ap, an - _TIER)[an - _TIER]
            hi = ap >= thr
            self._demote_to_rest(ak[~hi], ap[~hi])
            ak, ap, aw, ag = ak[hi], ap[hi], aw[hi], ag[hi]
            an = ak.shape[0]
        cap = max(self._akeys.shape[0], 1024)
        while an + m > cap:
            cap *= 2
        for name, src, dt in (
            ("_akeys", ak, np.int64), ("_apri", ap, np.float64),
            ("_awt", aw, np.float64), ("_agen", ag, np.int64),
        ):
            buf = np.empty(cap, dtype=dt)
            buf[:an] = src
            setattr(self, name, buf)
        self._an = an

    def _compact_arena(self, m: int) -> None:
        """Arena tier compaction: live entries are moved down **inside**
        the workspace buffers (gather through a scratch window, write
        back — an index-window move) instead of copied into fresh
        arrays; only a genuine capacity shortfall reallocates."""
        an = self._an
        ar = self.arena
        live_idx = np.flatnonzero(self._apri[:an] > 0.0)
        k = live_idx.shape[0]
        if k > 2 * _TIER:
            ap = self._apri[live_idx]
            thr = np.partition(ap, k - _TIER)[k - _TIER]
            hi = ap >= thr
            demoted = live_idx[~hi]
            self._demote_to_rest(self._akeys[demoted], self._apri[demoted])
            live_idx = live_idx[hi]
            k = live_idx.shape[0]
        for name, arr, dt in (
            ("c_keys", self._akeys, np.int64), ("c_pri", self._apri, np.float64),
            ("c_wt", self._awt, np.float64), ("c_gen", self._agen, np.int64),
        ):
            tmp = ar.take(name, k, dt)
            np.take(arr, live_idx, out=tmp[:k])
            arr[:k] = tmp[:k]
        self._an = k
        if k + m > self._akeys.shape[0]:
            cap = self._akeys.shape[0]
            while k + m > cap:
                cap *= 2
            for nm, dt, attr in (
                ("tier_keys", np.int64, "_akeys"), ("tier_pri", np.float64, "_apri"),
                ("tier_wt", np.float64, "_awt"), ("tier_gen", np.int64, "_agen"),
            ):
                old = getattr(self, attr)
                buf = ar.take(nm, cap, dt)
                buf[:k] = old[:k]
                setattr(self, attr, buf)

    def _demote_to_rest(self, keys: np.ndarray, pris: np.ndarray) -> None:
        """Fold demoted candidate entries into the deferred rest tier.
        Their cached priorities are upper bounds, so folding them into
        the single stale bound keeps selection exact (shared by the
        batch and arena compaction paths — the demotion rule must stay
        identical for the engines to stay bit-identical)."""
        if not keys.shape[0]:
            return
        if self._rest is None:
            self._rest = keys
            self._rest_bound = float(pris.max())
        else:
            self._rest = np.concatenate([self._rest, keys])
            self._rest_bound = max(self._rest_bound, float(pris.max()))

    def _reload_rest(self) -> None:
        """Re-score the deferred tier in one vectorized sweep and
        re-partition it (called when the running best decays to the stale
        bound, so a deferred key could now be the global max)."""
        rest, self._rest = self._rest, None
        self._rest_bound = -np.inf
        self.stats.n_tier_reloads += 1
        cnts = self.counts.get_batch(rest)
        viable = cnts >= 2
        if viable.any():
            dorm = self._dormant_mask_of(rest)
            if dorm is not None:
                viable &= ~dorm
        keys = rest[viable]
        if keys.shape[0] == 0:
            return
        wts = self._weights_vec(keys)
        pris = cnts[viable] * wts
        if keys.shape[0] > _TIER:
            thr = np.partition(pris, pris.shape[0] - _TIER)[pris.shape[0] - _TIER]
            hi = pris >= thr
            lo_pris = pris[~hi]
            if lo_pris.shape[0]:
                self._rest = keys[~hi]
                self._rest_bound = float(lo_pris.max())
            keys, pris, wts = keys[hi], pris[hi], wts[hi]
        self._active_append(keys, pris, wts)

    def _mark_dormant(self, key: int) -> None:
        if self.engine == "arena":
            self.counts.set_dormant(key)
        else:
            self._dormant.add(key)
        self._any_dormant = True

    def _is_dormant(self, key: int) -> bool:
        if not self._any_dormant:
            return False
        if self.engine == "arena":
            return self.counts.is_dormant(key)
        return key in self._dormant

    def _dormant_mask_of(self, keys: np.ndarray) -> np.ndarray | None:
        """Boolean dormancy mask for an array of keys (None = none are)."""
        if not self._any_dormant:
            return None
        if self.engine == "arena":
            return self.counts.dormant_mask(keys)
        d = self._dormant
        if not d:
            return None
        return np.fromiter((k in d for k in keys.tolist()), bool, keys.shape[0])

    def _apply_deltas(self, keys: np.ndarray, signs: np.ndarray) -> None:
        """One signed-delta count update for a whole implementation step
        (``signs``: -1 for removed digit pairs, +1 for added ones).  Keys
        whose count increased to >= 2 leave dormancy and are (re)inserted
        into the engine's candidate pool at their exact new priority.
        """
        if not keys.shape[0]:
            return
        order = np.argsort(keys)
        sk = keys[order]
        first = np.empty(sk.shape[0], dtype=bool)
        first[0] = True
        np.not_equal(sk[1:], sk[:-1], out=first[1:])
        starts = np.flatnonzero(first)
        uniq = sk[starts]
        delta = np.add.reduceat(signs[order], starts)
        changed = delta != 0
        uniq = uniq[changed]
        delta = delta[changed]
        if not uniq.shape[0]:
            return
        self._gen += 1  # cached batch-engine scores may now be stale
        new = self.counts.add_batch(uniq, delta)
        pmask = (delta > 0) & (new >= 2)
        if pmask.any():
            pkeys = uniq[pmask]
            wts = self._weights_vec(pkeys)
            pris = new[pmask] * wts
            if self._dormant:
                dormant = self._dormant
                for k in pkeys.tolist():
                    dormant.discard(k)
            if self.engine == "heap":
                heap = self.heap
                for key, neg in zip(pkeys.tolist(), (-pris).tolist()):
                    heapq.heappush(heap, (neg, key))
            else:
                self._active_append(pkeys, pris, wts)

    def _apply_deltas_arena(self, keys: np.ndarray, signs: np.ndarray) -> None:
        """Fused count update of one implementation step: claim a slot
        per (non-unique) pair key, scatter the signed deltas with one
        ``np.add.at``, and read each key's net movement off the before /
        after slot values — no per-step sort, reduceat, or dedup.  A key
        whose count rose to >= 2 wakes from dormancy and (re)enters the
        active tier at its exact new priority, matching the batch
        engine's rule bit for bit (within one step a key's deltas all
        share a sign, so before/after comparison equals the net-delta
        test on the deduplicated stream)."""
        n = keys.shape[0]
        if not n:
            return
        tab: _ArenaCountTable = self.counts
        slots, before, after = tab.scatter_add(keys, signs)
        self._gen += 1  # cached tier scores may now be stale
        inc = (after > before) & (after >= 2)
        if not inc.any():
            return
        tab.dorm[slots[inc]] = 0
        uq, ui = np.unique(keys[inc], return_index=True)
        pv = after[inc][ui]
        wts = self._weights_vec(uq)
        self._active_append(uq, pv * wts, wts)

    # ------------------------------------------------------------------
    # Occurrence search
    # ------------------------------------------------------------------
    def _find_occurrences(self, key: int) -> dict[int, np.ndarray]:
        """Disjoint occurrences per column: sorted base positions p such
        that the digit pair ((i, p), (j, p+s)) matches the pattern.

        ``row_cols`` may contain stale columns; a column with no digits
        left on the pattern's rows is pruned here."""
        i, j, s, sign = _unpack_key(key)
        out: dict[int, np.ndarray] = {}
        ci = self.row_cols.get(i)
        cj = self.row_cols.get(j) if j != i else ci
        if not ci or not cj:
            return out
        cols = ci & cj if j != i else list(ci)
        for c in cols:
            store = self.cols[c]
            di_map = store.by_row.get(i)
            if not di_map:
                ci.discard(c)  # column no longer holds row i digits
                continue
            if i != j:
                dj_map = store.by_row.get(j)
                if not dj_map:
                    cj.discard(c)
                    continue
                # digits are +-1, so d_i * d_j == sign  <=>  d_j == sign * d_i
                dj_get = dj_map.get
                if len(di_map) == 1:
                    (p, d), = di_map.items()
                    ps = [p] if dj_get(p + s) == sign * d else []
                else:
                    ps = sorted(
                        p for p, d in di_map.items() if dj_get(p + s) == sign * d
                    )
            else:
                if len(di_map) < 2:
                    continue
                # chains like p, p+s, p+2s share digits: greedy disjoint match
                used: set[int] = set()
                ps = []
                dj_get = di_map.get
                for p in sorted(di_map):
                    if p in used or (p + s) in used:
                        continue
                    if dj_get(p + s) == sign * di_map[p]:
                        ps.append(p)
                        used.add(p)
                        used.add(p + s)
            if ps:
                out[c] = np.array(ps, dtype=np.int64)
        return out

    # ------------------------------------------------------------------
    # Main loop
    # ------------------------------------------------------------------
    def run(self) -> list[Term | None]:
        try:
            with trace.span("cse.select", engine=self.engine):
                if self.engine == "heap":
                    self._run_heap()
                else:
                    self._run_batch()
            with trace.span("cse.assemble", engine=self.engine):
                return self._assemble()
        finally:
            if self._arena_owned:
                self.stats.n_arena_reallocs = (
                    self.arena.n_reallocs - self._arena_reallocs0
                )
                # hand the workspace back for the next solve on this
                # thread; the stores' windows become reusable, so a CSE
                # must not be mutated after run() (solve_cmvm never does)
                self.arena.release()
                self._arena_owned = False
            self._emit_counters()

    def _emit_counters(self) -> None:
        """Fold this run's CSEStats into the process metrics registry
        (one dict update per solve — nowhere near the hot path)."""
        st = self.stats
        reg = get_registry()
        eng = self.engine
        reg.inc("cse_runs_total", 1, engine=eng)
        reg.inc("cse_patterns_implemented_total", st.n_patterns_implemented, engine=eng)
        reg.inc("cse_occurrences_replaced_total", st.n_occurrences_replaced, engine=eng)
        reg.inc("cse_compactions_total", st.n_compactions, engine=eng)
        reg.inc("cse_tier_reloads_total", st.n_tier_reloads, engine=eng)
        if st.n_arena_reallocs:
            reg.inc("cse_arena_reallocs_total", st.n_arena_reallocs, engine=eng)

    def _run_heap(self) -> None:
        """Exact lazy max-heap realisation of the selection rule."""
        counts = self.counts
        dormant = self._dormant
        heap = self.heap
        while heap:
            neg_pri, key = heapq.heappop(heap)
            if key in dormant:
                continue
            cnt = counts.get(key)
            if cnt < 2:
                continue
            cur = cnt * self._weight(key)
            if -neg_pri != cur:
                self.stats.n_stale_corrections += 1
                if -neg_pri > cur:
                    # stale-high: this entry may be the key's only cover
                    heapq.heappush(heap, (-cur, key))
                # stale-low: a fresher entry pushed by the count increase
                # already bounds the key from above — drop this one
                continue
            if self._implement(key):
                cnt = counts.get(key)
                if cnt >= 2:
                    heapq.heappush(heap, (-cnt * self._weight(key), key))
            else:
                dormant.add(key)

    def _run_batch(self) -> None:
        """Generation-stamped candidate-array realisation of the selection
        rule (shared by the batch and arena engines): zero heap
        operations on the common path."""
        counts = self.counts
        while True:
            an = self._an
            best = self._apri[:an].max() if an else -np.inf
            if self._rest is not None and best <= self._rest_bound:
                # a deferred key could tie or beat the active best
                self._reload_rest()
                continue
            if best <= 0.0:
                break
            idxs = np.nonzero(self._apri[:an] == best)[0]
            kk = self._akeys[idxs]
            stale = self._agen[idxs] != self._gen
            n_stale = int(stale.sum())
            if n_stale:
                self.stats.n_stale_corrections += n_stale
                if n_stale <= 4:
                    # scalar probes beat the vectorized machinery on the
                    # typical 1-2 entry correction (same arithmetic)
                    gen = self._gen
                    for q in idxs[stale].tolist():
                        kq = int(self._akeys[q])
                        cnt = counts.get(kq)
                        if cnt >= 2 and not self._is_dormant(kq):
                            self._apri[q] = cnt * self._awt[q]
                        else:
                            self._apri[q] = 0.0
                        self._agen[q] = gen
                else:
                    sk = kk[stale]
                    cnts = counts.get_batch(sk)
                    pri = np.where(cnts >= 2, cnts * self._awt[idxs[stale]], 0.0)
                    dorm = self._dormant_mask_of(sk)
                    if dorm is not None:
                        pri[dorm] = 0.0
                    self._apri[idxs[stale]] = pri
                    self._agen[idxs[stale]] = self._gen
            winners = kk[self._apri[idxs] == best]
            if winners.shape[0] == 0:
                continue  # every entry at `best` was stale-high
            key = int(winners.min())
            if self._implement(key):
                # eagerly re-score the winner's entries at its post-step
                # count: its cached best is now stale, and correcting it
                # here saves one full selection round per implementation.
                # (positions are re-scanned: the step's appends may have
                # compacted/reordered the active arrays)
                sel = np.flatnonzero(self._akeys[: self._an] == key)
                cnt = counts.get(key)
                pri = cnt * self._awt[sel] if cnt >= 2 else 0.0
                self._apri[sel] = pri
                self._agen[sel] = self._gen
            else:
                self._mark_dormant(key)
                # zero the key's cached entries so the running max moves on
                sel = self._akeys[: self._an] == key
                self._apri[: self._an][sel] = 0.0
            if self._an > 3 * _TIER and self.engine == "arena":
                # keep the running-max scan short: drop dead entries in
                # place (exactness-preserving whenever it runs)
                self._compact(0)

    def _implement(self, key: int) -> bool:
        i, j, s, sign = _unpack_key(key)
        occs = self._find_occurrences(key)
        d_i_depth = self.prog.rows[i].depth
        d_j_depth = self.prog.rows[j].depth
        u_depth = max(d_i_depth, d_j_depth) + 1
        # Delay-constraint filter, per column.  Replacing k occurrences
        # moves exactly k digits of row i and k of row j onto the new row
        # (depth u_depth), so the column's leaf-depth multiset after k
        # acceptances depends only on k: score the whole candidate batch
        # k = 1..n in one histogram sweep (min_tree_depth_hist_batch).
        accepted: dict[int, np.ndarray] = {}
        total = 0
        for c, ps in occs.items():
            budget = self.budgets[c]
            if budget is None:
                accepted[c] = ps
                total += ps.shape[0]
                continue
            store = self.cols[c]
            self._sync_meta()
            dep = self._meta_depth[store.rows[: store.n]]
            lv, cn = np.unique(dep, return_counts=True)
            extra = np.array([d_i_depth, d_j_depth, u_depth], dtype=np.int64)
            levels = np.union1d(lv, extra)
            base = np.zeros(levels.shape[0], dtype=np.int64)
            base[np.searchsorted(levels, lv)] = cn
            li = int(np.searchsorted(levels, d_i_depth))
            lj = int(np.searchsorted(levels, d_j_depth))
            lu = int(np.searchsorted(levels, u_depth))
            n_ps = ps.shape[0]
            ks = np.arange(1, n_ps + 1, dtype=np.int64)
            hists = np.broadcast_to(base, (n_ps, levels.shape[0])).copy()
            hists[:, li] -= ks
            hists[:, lj] -= ks  # li == lj when i == j: both ops apply
            hists[:, lu] += ks
            feas = min_tree_depth_hist_batch(levels, hists) <= budget
            n_keep = n_ps if bool(feas.all()) else int(feas.argmin())
            if n_keep < n_ps:
                # feasibility depends only on k, so every occurrence past
                # the first infeasible acceptance is rejected too
                self.stats.n_rejected_by_depth += n_ps - n_keep
            if n_keep:
                accepted[c] = ps[:n_keep]
                total += n_keep
        if total < 2:
            return False  # dormant until counts increase again
        u = self._impl_cache.get(key)
        if u is None:
            u = self.prog.add_op(i, j, max(0, -s), max(0, s), sign)
            self._impl_cache[key] = u
        self.stats.n_patterns_implemented += 1
        if self.engine == "arena":
            self._replace_occurrences_arena(u, i, j, s, accepted)
            return True
        # Replace occurrences column by column, collecting the removed and
        # added digit sets plus a view of each column's post-removal store;
        # every digit pair the step touches is then built block-structured
        # and counted in ONE _canon_pack + _apply_deltas call (_step_pairs).
        rem_sets: list[tuple] = []
        add_sets: list[tuple] = []
        snaps: list[tuple] = []
        for c, ps in accepted.items():
            store = self.cols[c]
            k = ps.shape[0]
            r_rows = np.empty(2 * k, dtype=np.int64)
            r_rows[:k] = i
            r_rows[k:] = j
            r_poss = np.concatenate([ps, ps + s])
            ds = [
                store.remove(r, p)
                for r, p in zip(r_rows.tolist(), r_poss.tolist())
            ]
            r_digs = np.array(ds, dtype=np.int64)
            # the live slices below stay valid without copying: from here
            # on this store only appends (slots < n_c are never disturbed,
            # and a capacity grow leaves the viewed buffer intact)
            n_c = store.n
            snaps.append((store.rows[:n_c], store.poss[:n_c], store.digs[:n_c]))
            rem_sets.append((r_rows, r_poss, r_digs))
            a_poss = ps + min(0, s)
            a_digs = r_digs[:k]
            # read-only broadcast view: gathers/repeats in _step_pairs copy
            a_rows = np.broadcast_to(np.int64(u), (k,))
            add_sets.append((a_rows, a_poss, a_digs))
            cols_u = self.row_cols.get(u)
            if cols_u is None:
                self.row_cols[u] = {c}
            else:
                cols_u.add(c)
            for p, d in zip(a_poss.tolist(), a_digs.tolist()):
                store.add(u, p, d)
            self.stats.n_occurrences_replaced += k
        res = _step_pairs(
            rem_sets + add_sets,
            snaps + snaps,
            [-1] * len(rem_sets) + [1] * len(add_sets),
        )
        if res is not None:
            a, b, signs = res
            packed = _canon_pack(a[0], a[1], a[2], b[0], b[1], b[2])
            self._apply_deltas(packed, signs)
        return True

    def _replace_occurrences_arena(self, u, i, j, s, accepted) -> None:
        """Fused replace + count-delta pass of the arena engine.

        Removes and adds digits through the arena-resident stores, then
        builds every pair key the step touches straight from the cached
        (token, digit) windows into reusable scratch — one pass replaces
        ``_step_pairs`` + ``_canon_pack`` + the sort/reduceat dedup of
        ``_apply_deltas``.  The pair multiset is identical to the batch
        engine's: each removed/added digit against its column's
        post-removal snapshot, plus the pairs inside each set.  The
        snapshot of a column is concatenated once and shared by that
        column's removed and added sets via per-set offsets."""
        ar = self.arena
        cols = self.cols
        row_cols = self.row_cols
        stats = self.stats
        ncols = len(accepted)
        n_occ = 0
        for ps in accepted.values():
            n_occ += ps.shape[0]
        na = 3 * n_occ  # A-side digits: 2 removed + 1 added per occurrence
        a_tok = ar.take("a_tok", na)
        a_dig = ar.take("a_dig", na)
        nsets = 2 * ncols
        set_m = ar.take("set_m", nsets)
        set_n = ar.take("set_n", nsets)
        set_off = ar.take("set_off", nsets)
        i_t = np.int64(i) << _TOK_BITS
        j_t = np.int64(j) << _TOK_BITS
        u_t = np.int64(u) << _TOK_BITS
        off0 = min(0, s)
        w = 0           # removed sets fill [0, 2*n_occ)
        wa = 2 * n_occ  # added sets fill [2*n_occ, 3*n_occ)
        si = 0
        boff = 0
        snaps: list[tuple[np.ndarray, np.ndarray]] = []
        i_ti = int(i_t)
        j_ti = int(j_t)
        u_ti = int(u_t)
        for c, ps in accepted.items():
            store = cols[c]
            k = ps.shape[0]
            pl = ps.tolist()
            if k <= 2:
                # scalar writes beat 1-2 element vector ops (same values)
                for t, p in enumerate(pl):
                    a_tok[w + t] = i_ti | p
                    a_tok[w + k + t] = j_ti | (p + s)
                    a_tok[wa + t] = u_ti | (p + off0)
            else:
                a_tok[w : w + k] = i_t | ps
                a_tok[w + k : w + 2 * k] = j_t | (ps + s)
                a_tok[wa : wa + k] = u_t | (ps + off0)
            rd = a_dig[w : w + 2 * k]
            rem = store.remove
            for t, p in enumerate(pl):
                d = rem(i, p)
                rd[t] = d
                a_dig[wa + t] = d
            for t, p in enumerate(pl):
                rd[k + t] = rem(j, p + s)
            n_c = store.n
            set_m[si] = 2 * k
            set_n[si] = n_c
            set_off[si] = boff
            set_m[ncols + si] = k
            set_n[ncols + si] = n_c
            set_off[ncols + si] = boff
            # live views stay valid without copying: from here on this
            # store only appends (and a window move freezes, never
            # mutates, the viewed buffer)
            snaps.append((store.toks[:n_c], store.digs[:n_c]))
            boff += n_c
            cols_u = row_cols.get(u)
            if cols_u is None:
                row_cols[u] = {c}
            else:
                cols_u.add(c)
            add = store.add
            for t, p in enumerate(pl):
                add(u, p + off0, int(rd[t]))
            stats.n_occurrences_replaced += k
            w += 2 * k
            wa += k
            si += 1
        # ---- pair-key build: A x snapshot cross products + intra-set ----
        b_tok = ar.take("b_tok", max(boff, 1))
        b_dig = ar.take("b_dig", max(boff, 1))
        o = 0
        for tk, dg in snaps:
            nn = tk.shape[0]
            b_tok[o : o + nn] = tk
            b_dig[o : o + nn] = dg
            o += nn
        m_t = set_m[:nsets]
        reps = np.repeat(set_n[:nsets], m_t)  # pairs per A element
        n_cross = int(reps.sum())
        # intra-set pairs: concatenate every set's offset upper-triangle
        # indices, then gather once (removed sets lead, so signs are two
        # contiguous fills)
        tri_ii: list[np.ndarray] = []
        tri_jj: list[np.ndarray] = []
        tri_n = 0
        rem_tri = 0
        off_a = 0
        for t in range(nsets):
            mm = int(set_m[t])
            if mm > 1:
                ii, jj = _triu(mm)
                tri_ii.append(ii + off_a)
                tri_jj.append(jj + off_a)
                tri_n += ii.shape[0]
                if t < ncols:
                    rem_tri = tri_n
            off_a += mm
        tot = n_cross + tri_n
        if tot == 0:
            return
        p_tA = ar.take("p_tA", tot)
        p_dA = ar.take("p_dA", tot)
        p_tB = ar.take("p_tB", tot)
        p_dB = ar.take("p_dB", tot)
        p_sg = ar.take("p_sg", tot)
        if n_cross:
            ends = np.cumsum(reps)
            off_elem = np.repeat(set_off[:nsets], m_t)  # B offset per element
            base = np.repeat(ends - reps - off_elem, reps)
            gidx = np.arange(n_cross, dtype=np.int64) - base
            p_tA[:n_cross] = np.repeat(a_tok[:na], reps)
            p_dA[:n_cross] = np.repeat(a_dig[:na], reps)
            np.take(b_tok, gidx, out=p_tB[:n_cross])
            np.take(b_dig, gidx, out=p_dB[:n_cross])
            # A elements are laid out removed-first, so pair signs are two
            # contiguous fills instead of a repeat chain
            rem_cross = int(reps[: 2 * n_occ].sum())
            p_sg[:rem_cross] = -1
            p_sg[rem_cross:n_cross] = 1
        if tri_n:
            ii = np.concatenate(tri_ii) if len(tri_ii) > 1 else tri_ii[0]
            jj = np.concatenate(tri_jj) if len(tri_jj) > 1 else tri_jj[0]
            np.take(a_tok, ii, out=p_tA[n_cross:tot])
            np.take(a_dig, ii, out=p_dA[n_cross:tot])
            np.take(a_tok, jj, out=p_tB[n_cross:tot])
            np.take(a_dig, jj, out=p_dB[n_cross:tot])
            p_sg[n_cross : n_cross + rem_tri] = -1
            p_sg[n_cross + rem_tri : tot] = 1
        keys = _pack_pair_keys(p_tA[:tot], p_dA[:tot], p_tB[:tot], p_dB[:tot])
        self._apply_deltas_arena(keys, p_sg[:tot])

    # ------------------------------------------------------------------
    # Final adder-tree assembly per column
    # ------------------------------------------------------------------
    def _combine(self, t1: Term, t2: Term) -> Term:
        if self.assembly_dedup:
            ck = (t1, t2) if (t1.row, t1.shift, t1.sign) <= (t2.row, t2.shift, t2.sign) else (t2, t1)
            hit = self._combine_cache.get(ck)
            if hit is not None:
                return hit
        if t1.sign == t2.sign:
            m = min(t1.shift, t2.shift)
            u = self.prog.add_op(t1.row, t2.row, t1.shift - m, t2.shift - m, +1)
            res = Term(t1.sign, u, m)
        else:
            pos, neg = (t1, t2) if t1.sign > 0 else (t2, t1)
            m = min(pos.shift, neg.shift)
            u = self.prog.add_op(pos.row, neg.row, pos.shift - m, neg.shift - m, -1)
            res = Term(1, u, m)
        self.stats.n_assembly_adders += 1
        if self.assembly_dedup:
            self._combine_cache[ck] = res
        return res

    def _assemble(self) -> list[Term | None]:
        outputs: list[Term | None] = []
        for store in self.cols:
            if not len(store):
                outputs.append(None)
                continue
            R, P, D = store.live()
            order = np.lexsort((P, R))  # (row, pos) lexicographic
            # merge two shallowest first: optimal max-depth (min-max Huffman)
            h: list[tuple[int, int, int, Term]] = []
            seq = 0
            for k in order.tolist():
                row, pos, d = int(R[k]), int(P[k]), int(D[k])
                t = Term(d, row, pos)
                h.append((self.prog.rows[row].depth, self.prog.rows[row].qint.width, seq, t))
                seq += 1
            heapq.heapify(h)
            while len(h) > 1:
                _, _, _, t1 = heapq.heappop(h)
                _, _, _, t2 = heapq.heappop(h)
                t = self._combine(t1, t2)
                heapq.heappush(h, (self.prog.rows[t.row].depth, self.prog.rows[t.row].qint.width, seq, t))
                seq += 1
            outputs.append(h[0][3])
        return outputs
