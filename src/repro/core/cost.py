"""Cost and delay models for the adder graph (paper §3, Eq. 1).

The dominant operation is ``a +/- (b << s)``.  Its expected cost is the
number of full/half adders needed, i.e. the number of output bits that
depend on more than one input bit:

    cost(bw_a, bw_b, s, sign) = max(bw_a, bw_b + s) - min(0, s) + 1   (1)

We evaluate the model on exact quantized intervals, which is strictly
tighter than raw (W, I) bookkeeping: accumulating k terms only pays carry
bits the reachable range actually requires.

Delay is modelled as adder depth (every adder = 1 unit, routing dominates
— §3), following [4, 5, 23].
"""

from __future__ import annotations

import heapq
from collections.abc import Iterable

import numpy as np

from .fixed_point import QInterval


def adder_cost(qa: QInterval, qb: QInterval, sh_a: int, sh_b: int, sign: int) -> int:
    """Eq. (1) on quantized intervals for ``(a<<sh_a) + sign*(b<<sh_b)``.

    Bit positions are absolute (qint exps included), so differently-scaled
    operands are costed exactly.  Returns the number of output bit
    positions at or above the higher of the two LSBs, plus one carry —
    bits below both LSBs are wiring, not logic.
    """
    if qa.is_zero or qb.is_zero:
        return 0
    a = qa.shift(sh_a)
    b = qb.shift(sh_b)
    msb = max(a.msb, b.msb)
    lsb_hi = max(a.lsb, b.lsb)
    lsb_lo = min(a.lsb, b.lsb)
    if lsb_hi > msb:
        # disjoint ranges: pure concatenation, no adder logic in theory;
        # charge 1 for the splice (sign handling / carry into the gap).
        # Eq. (1) is stated only for overlapping operands (§3).
        return 1
    # Eq. (1): max(bw_a, bw_b + s) - min(0, s) + 1, expressed in absolute
    # bit positions: every position from the lower LSB to the MSB, plus
    # one carry bit.
    return msb - lsb_lo + 2


def overlap_bits(qa: QInterval, qb: QInterval, sh_a: int, sh_b: int) -> int:
    """Number of bit positions where both operands carry data (CSE weight).

    The paper weights subexpression frequency by operand bit overlap so
    that half-adder 'overhead' bits (which widen downstream accumulators)
    are not rewarded.
    """
    if qa.is_zero or qb.is_zero:
        return 0
    a = qa.shift(sh_a)
    b = qb.shift(sh_b)
    lo = max(a.lsb, b.lsb)
    hi = min(a.msb, b.msb)
    return max(hi - lo + 1, 0)


def ceil_log2(n: int) -> int:
    if n <= 1:
        return 0
    return (n - 1).bit_length()


def min_tree_depth(depths: Iterable[int]) -> int:
    """Minimal achievable max-depth of a binary merge tree over leaves
    with given depths (merge cost: max(d1, d2) + 1).

    Greedy on a min-heap (always merge the two shallowest) is optimal for
    this objective — the min-max analogue of Huffman coding.
    """
    h = list(depths)
    if not h:
        return 0
    heapq.heapify(h)
    while len(h) > 1:
        d1 = heapq.heappop(h)
        d2 = heapq.heappop(h)
        heapq.heappush(h, max(d1, d2) + 1)
    return h[0]


def min_tree_depth_hist(hist: dict) -> int:
    """``min_tree_depth`` over a depth histogram ``{depth: count}``.

    Equivalent to expanding the histogram into a leaf list, but O(distinct
    depths) instead of O(n log n): within one depth level, greedy pairwise
    merging sends ceil(n/2) nodes to the next level (an odd leftover at
    depth d merges with the next-shallowest node at some d' > d, yielding
    d' + 1 — exactly as if it already sat at depth d'), and a lone node
    floats up to the next populated level unchanged.
    """
    items = sorted((d, c) for d, c in hist.items() if c > 0)
    if not items:
        return 0
    carry = 0
    pos = 0
    for d, c in items:
        if carry == 0:
            pos, carry = d, c
            continue
        while pos < d and carry > 1:
            carry = (carry + 1) // 2
            pos += 1
        pos = d  # a lone leftover merges as if at the deeper level
        carry += c
    while carry > 1:
        carry = (carry + 1) // 2
        pos += 1
    return pos


def min_tree_depth_hist_batch(levels: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """:func:`min_tree_depth_hist` for a *batch* of histograms sharing one
    sorted level axis: ``counts[b, l]`` leaves at depth ``levels[l]``.

    This is the CSE delay-constraint batch scorer: one call evaluates the
    feasibility of every candidate acceptance count k = 1..n of a pattern
    in a column (each k shifts k leaves per operand row onto the merged
    row's depth), replacing n sequential scalar simulations per trial.

    Exactly matches the scalar recurrence: within one level, ``c`` leaves
    plus an incoming carry merge pairwise; advancing a carry across a gap
    of ``t`` levels is ``max(ceil(carry / 2^t), 1)`` (ceil-division
    composes across stages, and the ``max(. , 1)`` clamp commutes with
    it).  ``pos`` only advances while the carry still has pairs to merge
    (``ceil_log2(carry)`` steps), so zero-count levels — which the scalar
    version filters out before iterating — are exact no-ops.
    """
    levels = np.asarray(levels, dtype=np.int64)
    counts = np.asarray(counts, dtype=np.int64)
    n_b, n_l = counts.shape
    pos = np.zeros(n_b, dtype=np.int64)
    carry = np.zeros(n_b, dtype=np.int64)
    for li in range(n_l):
        d = int(levels[li])
        c = counts[:, li]
        started = carry > 0
        if started.any():
            t = np.minimum(np.where(started, d - pos, 0), 62)
            # halvings until the carry collapses to 1 = ceil_log2(carry);
            # frexp is exact here (carry - 1 < 2^53)
            h = np.frexp(np.maximum(carry - 1, 0).astype(np.float64))[1]
            pos = pos + np.minimum(t, h.astype(np.int64))
            carry = np.where(
                started, np.maximum((carry + (1 << t) - 1) >> t, 1), carry
            )
        pos = np.where(c > 0, d, pos)
        carry = carry + c
    while True:
        m = carry > 1
        if not m.any():
            break
        carry = np.where(m, (carry + 1) >> 1, carry)
        pos = np.where(m, pos + 1, pos)
    return np.where(carry > 0, pos, 0)


def lut_estimate(cost_bits: int) -> int:
    """FPGA LUT estimate: ~1 LUT per full/half adder bit (6-input LUTs
    with carry chains absorb one result bit each on UltraScale+)."""
    return cost_bits


def delay_estimate_ns(depth: int, per_adder_ns: float = 0.45) -> float:
    """Rough logic+routing delay estimate used for pipelining decisions."""
    return depth * per_adder_ns
