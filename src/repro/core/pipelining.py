"""Pipelining of DAIS programs (paper §5.2).

A DAIS program describes a combinational circuit.  Registers are inserted
greedily whenever the accumulated estimated delay along a path exceeds a
user threshold: each adder is assumed to cost one delay unit by default
(routing dominates on FPGAs, §3), and the threshold `max_delay_per_stage`
expresses how many adder levels fit in one clock period.

The algorithm is local and greedy, exactly as in the paper: stage(u) =
max over operands of (stage(op) + carry), where a value is re-registered
when its combinational depth within the current stage would exceed the
threshold.  Register (FF) cost is the bitwidth of every value crossing a
stage boundary, including inputs carried forward for later consumers.
"""

from __future__ import annotations

from dataclasses import dataclass

from .dais import KIND_INPUT, DAISProgram


@dataclass
class PipelineReport:
    n_stages: int
    stage_of_row: list[int]
    intra_depth: list[int]
    ff_bits: int
    latency_cycles: int

    @property
    def ii(self) -> int:
        return 1  # fully pipelined, one new input per cycle


def pipeline(prog: DAISProgram, max_delay_per_stage: int = 5) -> PipelineReport:
    n = len(prog.rows)
    stage = [0] * n
    intra = [0] * n  # adder depth within the assigned stage
    for i, r in enumerate(prog.rows):
        if r.kind == KIND_INPUT:
            stage[i], intra[i] = 0, 0
            continue
        ops = [r.a] if r.b < 0 else [r.a, r.b]
        s = max(stage[o] for o in ops)
        d = 1 + max((intra[o] if stage[o] == s else 0) for o in ops)
        if d > max_delay_per_stage:
            s, d = s + 1, 1
        stage[i], intra[i] = s, d

    out_rows = [t.row for t in prog.outputs if t is not None]
    n_stages = (max((stage[i] for i in out_rows), default=0)) + 1

    # FF cost: every value alive across a stage boundary is registered at
    # each boundary it crosses (width bits per boundary).
    last_use = [stage[i] for i in range(n)]
    for i, r in enumerate(prog.rows):
        if r.kind != KIND_INPUT:
            for o in ([r.a] if r.b < 0 else [r.a, r.b]):
                last_use[o] = max(last_use[o], stage[i])
    for t in prog.outputs:
        if t is not None:
            # max, not assignment: a row can be consumed by an op in a
            # later stage than any output; its carry registers still cost
            # FF bits (mirrors the emission rule in verilog.py)
            last_use[t.row] = max(last_use[t.row], n_stages - 1)
    ff = 0
    for i, r in enumerate(prog.rows):
        crossings = max(last_use[i] - stage[i], 0)
        ff += crossings * r.qint.width
    return PipelineReport(n_stages, stage, intra, ff, n_stages - 1)
