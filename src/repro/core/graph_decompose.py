"""Stage 1 of da4ml: graph-based decomposition M = M1 @ M2 (paper §4.3).

Each column v_i of the constant matrix M is a vertex; the root vertex v_0
carries the zero vector.  The distance between vertices is

    dist(v_i, v_j) = min( nnz_csd(v_i - v_j), nnz_csd(v_i + v_j) )

i.e. the CSD digit count of the cheaper transfer vector.  A depth-capped
approximate minimum spanning tree is grown with Prim's algorithm (cap
2^dc vertices from the root for delay constraint dc >= 0; unbounded for
dc = -1).  Every MST edge contributes one column (its transfer vector) to
M1; tracing root->vertex paths yields the {-1, 0, +1} combination matrix
M2 with M == M1 @ M2.

For matrices with uncorrelated columns the decomposition degrades to the
trivial M1 = M, M2 = I (shuffled), exactly as the paper describes; the
tie-break below prefers the root parent so no depth is added in that
case.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .csd import csd_nnz


@dataclass
class Decomposition:
    m1: np.ndarray  # [d_in, K]   transfer vectors (non-zero MST edges)
    m2: np.ndarray  # [K, d_out]  {-1,0,+1} path-combination matrix
    path_len: np.ndarray  # [d_out] number of M1 columns feeding each output
    mst_depth: np.ndarray  # [d_out] MST depth of each column's vertex

    @property
    def is_trivial(self) -> bool:
        return bool(np.all(self.mst_depth <= 1))


def decompose(m: np.ndarray, dc: int = -1) -> Decomposition:
    """Decompose integer matrix m [d_in, d_out] into m1 @ m2."""
    m = np.asarray(m, dtype=np.int64)
    d_in, d_out = m.shape
    cap = (1 << dc) if dc >= 0 else d_out + 1

    visited = np.zeros(d_out, dtype=bool)
    depth = np.zeros(d_out, dtype=np.int64)
    # best known connection for each unvisited vertex
    best_dist = csd_nnz(m).sum(axis=0)  # distance to root (v_0 = 0)
    best_parent = np.full(d_out, -1, dtype=np.int64)  # -1 = root
    best_flip = np.zeros(d_out, dtype=bool)  # True: v_j = w - v_parent

    edges: list[tuple[int, int, bool]] = []  # (child, parent, flip)
    for _ in range(d_out):
        cand = np.where(~visited, best_dist, np.iinfo(np.int64).max)
        j = int(np.argmin(cand))
        visited[j] = True
        par = int(best_parent[j])
        depth[j] = 1 if par < 0 else depth[par] + 1
        edges.append((j, par, bool(best_flip[j])))
        if depth[j] < cap:
            # relax unvisited vertices through the new vertex
            unv = ~visited
            if unv.any():
                diff = csd_nnz(m[:, unv] - m[:, j : j + 1]).sum(axis=0)
                summ = csd_nnz(m[:, unv] + m[:, j : j + 1]).sum(axis=0)
                d_new = np.minimum(diff, summ)
                flip_new = summ < diff
                idx = np.where(unv)[0]
                # strict improvement only: ties keep the shallower parent
                upd = d_new < best_dist[idx]
                best_dist[idx[upd]] = d_new[upd]
                best_parent[idx[upd]] = j
                best_flip[idx[upd]] = flip_new[upd]

    # Translate MST edges into M1 columns and M2 path combinations.
    m1_cols: list[np.ndarray] = []
    contrib: dict[int, dict[int, int]] = {}  # vertex -> {m1_col: sign}
    # process in insertion order: parents always precede children
    for child, par, flip in edges:
        parent_contrib = {} if par < 0 else contrib[par]
        base = {k: -v for k, v in parent_contrib.items()} if flip else dict(parent_contrib)
        pvec = np.zeros(d_in, dtype=np.int64) if par < 0 else m[:, par]
        w = m[:, child] + pvec if flip else m[:, child] - pvec
        if np.any(w != 0):
            e = len(m1_cols)
            m1_cols.append(w)
            base[e] = 1
        contrib[child] = base

    k = len(m1_cols)
    m1 = np.stack(m1_cols, axis=1) if k else np.zeros((d_in, 0), dtype=np.int64)
    m2 = np.zeros((k, d_out), dtype=np.int64)
    for j in range(d_out):
        for e, sgn in contrib[j].items():
            m2[e, j] = sgn

    assert np.array_equal(m1 @ m2, m), "decomposition must be exact"
    path_len = np.count_nonzero(m2, axis=0).astype(np.int64)
    return Decomposition(m1, m2, path_len, depth)
