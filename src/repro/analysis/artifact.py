"""Artifact auditor: is this ``da4ml-design`` directory trustworthy?

Audits a saved design directory (``manifest.json`` + ``design.npz``)
without trusting the loader: the content digest is recomputed from the
format specification (sha256 over ``"da4ml-design-arrays-v1"`` plus the
sorted npz keys and raw bytes — the contract ``save_design`` writes),
the embedded compile-config digest is recomputed through the typed
config, every npz key the manifest references must exist (and every npz
array should be referenced by something), and the manifest's resource
totals must equal what its own per-layer reports sum to.

The deep check then actually loads the design — through the real
``load_design`` path — and asserts the load ran **zero** solver calls,
which is the artifact format's core promise.  The loaded design is
returned so the program/steps passes can run on it.
"""

from __future__ import annotations

import hashlib
import json
from pathlib import Path
from typing import Any

import numpy as np

from .diagnostics import DiagnosticReport

__all__ = ["audit_artifact"]

_PASS = "artifact"
_FORMAT = "da4ml-design"
_VERSION = 1
_PROGRAM_KEYS = ("rows", "outputs", "n_inputs")


def _digest(arrays: dict[str, np.ndarray]) -> str:
    # the format's content-digest spec, restated (not imported from
    # runtime.artifact — the auditor must not inherit a loader bug)
    h = hashlib.sha256(b"da4ml-design-arrays-v1")
    for k in sorted(arrays):
        h.update(k.encode())
        h.update(np.ascontiguousarray(arrays[k]).tobytes())
    return h.hexdigest()


def _referenced_keys(manifest: dict) -> set[str]:
    keys: set[str] = {"out_qints"}
    for i in range(int(manifest.get("n_programs", 0))):
        keys.update(f"prog{i}_{k}" for k in _PROGRAM_KEYS)

    def walk(entries: list) -> None:
        for e in entries:
            keys.update((e.get("arrays") or {}).values())
            if "body" in e:
                walk(e["body"])

    walk(manifest.get("steps", []))
    return keys


def audit_artifact(
    path: str | Path,
    report: DiagnosticReport | None = None,
    *,
    load: bool = True,
) -> tuple[DiagnosticReport, Any]:
    """Audit one artifact directory.  Returns ``(report, design)`` —
    ``design`` is the loaded :class:`CompiledDesign` when ``load`` is
    true and the artifact was loadable, else None."""
    rep = report if report is not None else DiagnosticReport()
    path = Path(path)
    loc = {"artifact": str(path)}

    manifest_path = path / "manifest.json"
    npz_path = path / "design.npz"
    if not manifest_path.is_file() or not npz_path.is_file():
        rep.add(
            "DA040",
            "not a design artifact directory (manifest.json/design.npz missing)",
            loc=loc, passname=_PASS,
        )
        return rep, None
    try:
        manifest = json.loads(manifest_path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        rep.add("DA040", f"manifest.json unreadable: {e}", loc=loc, passname=_PASS)
        return rep, None
    if manifest.get("format") != _FORMAT or manifest.get("version") != _VERSION:
        rep.add(
            "DA040",
            f"unsupported format/version "
            f"({manifest.get('format')!r} v{manifest.get('version')!r})",
            loc=loc, passname=_PASS,
        )
        return rep, None
    try:
        with np.load(npz_path, allow_pickle=False) as z:
            arrays = {k: z[k] for k in z.files}
    except Exception as e:
        rep.add("DA040", f"design.npz unreadable: {e}", loc=loc, passname=_PASS)
        return rep, None

    want = manifest.get("arrays_sha256")
    if want is None:
        rep.add(
            "DA041", "manifest carries no arrays_sha256 content digest",
            loc=loc, passname=_PASS, severity="warning",
        )
    elif _digest(arrays) != want:
        rep.add(
            "DA041",
            "design.npz content does not match the manifest digest "
            "(tampered or mixed-generation artifact)",
            loc=loc, passname=_PASS,
        )

    cfg_dict = manifest.get("compile_config")
    cfg_digest = manifest.get("compile_config_digest")
    if cfg_dict is not None:
        from ..flow.config import CompileConfig, ConfigError  # stdlib-only module

        try:
            derived = CompileConfig.from_dict(cfg_dict).digest()
        except (ConfigError, TypeError) as e:
            rep.add(
                "DA042", f"embedded compile_config does not validate: {e}",
                loc=loc, passname=_PASS,
            )
        else:
            if cfg_digest is not None and derived != cfg_digest:
                rep.add(
                    "DA042",
                    "compile_config_digest does not match the embedded config",
                    loc=loc, passname=_PASS,
                )

    wanted = _referenced_keys(manifest)
    missing = sorted(wanted - set(arrays))
    if missing:
        rep.add(
            "DA044",
            f"manifest references {len(missing)} missing npz key(s) "
            f"(first: {missing[0]!r})",
            loc=loc, passname=_PASS,
        )
    orphans = sorted(set(arrays) - wanted)
    if orphans:
        rep.add(
            "DA043",
            f"{len(orphans)} npz array(s) referenced by nothing "
            f"(first: {orphans[0]!r})",
            loc=loc, passname=_PASS,
        )

    reports = manifest.get("reports") or []
    res = manifest.get("resources")
    if res is not None and reports:
        derived_res = {
            "total_adders": sum(r.get("adders", 0) for r in reports),
            "total_cost_bits": sum(r.get("cost_bits", 0) for r in reports),
            "total_ff_bits": sum(r.get("ff_bits", 0) for r in reports),
            "latency_cycles": sum(r.get("stages", 0) for r in reports),
            "max_depth": max((r.get("depth", 0) for r in reports), default=0),
        }
        bad = {k: (res.get(k), v) for k, v in derived_res.items() if res.get(k) != v}
        if bad:
            k, (claimed, v) = next(iter(sorted(bad.items())))
            rep.add(
                "DA045",
                f"manifest resource totals disagree with the layer reports "
                f"({len(bad)} field(s); first: {k} claimed {claimed}, derived {v})",
                loc=loc, passname=_PASS,
            )

    design = None
    if load and not missing:
        from ..runtime.artifact import load_design  # lazy: pulls in jax

        try:
            design = load_design(path)
        except Exception as e:
            rep.add(
                "DA046", f"load_design failed: {type(e).__name__}: {e}",
                loc=loc, passname=_PASS,
            )
        else:
            stats = design.solver_stats or {}
            if stats.get("n_solves", 0) != 0 or not stats.get("loaded_from_artifact"):
                rep.add(
                    "DA046",
                    "artifact load ran solver work (cold start must be solve-free)",
                    loc=loc, passname=_PASS,
                )
    return rep, design
