"""StepSpec pipeline checker: re-derive the design's interval flow.

The compile plan phase propagates exact per-feature ``QInterval``s
through every step (dense / conv / requant / transpose / relu / pool /
residual) and bakes the results into the design: requant shift arrays,
bias pre-shifts, residual alignment shifts, and the final
``out_qints``.  This pass *replays* that propagation from the input
quantization alone — with its own transfer functions, not the
compiler's — and checks every baked value against the re-derivation.

The one piece of information the step topology does not carry is each
CMVM's weight matrix; it is recovered **exactly** from the packed DAIS
program by evaluating it on unit vectors (the program computes
``y = x @ W`` bit-exactly, so ``W = evaluate(I)``).  The affine interval
of each output column then anchors the flow, and the program's own input
rows must carry exactly the intervals the flow derives at that point
(``DA022``) — a disagreement means the program was solved for different
input ranges than the pipeline feeds it.

Exp bookkeeping relies on two step params written at compile time:
``wscale`` on dense/conv (the weight grid exponent) and ``exp`` on
requant (the target grid exponent).  Artifacts saved before those
existed degrade gracefully: interval checks stop with one ``DA029``
info note, structural checks (shapes, table refs, array arity) continue.
"""

from __future__ import annotations

from typing import Any

import numpy as np

from ..core.dais import DAISProgram
from ..core.fixed_point import QInterval
from .diagnostics import DiagnosticReport

__all__ = ["check_steps"]

_PASS = "steps"
_I32 = (-(1 << 31), (1 << 31) - 1)


# ----------------------------------------------------------------------
# Independent transfer functions (deliberately not imported from
# repro.nn.compiler — the whole point is a second derivation)
# ----------------------------------------------------------------------
def _union(qs: list[QInterval]) -> QInterval:
    q0 = qs[0]
    if all(q is q0 or q == q0 for q in qs):
        return q0
    for q in qs[1:]:
        q0 = q0.union(q)
    return q0


def _requant(q: QInterval, lo: int, hi: int, exp: int) -> tuple[QInterval, bool]:
    """floor+saturate onto the fixed<lo, hi, exp> grid; returns
    (result, clipped?)."""
    if q.is_zero:
        return QInterval(0, 0, exp), False
    d = q.exp - exp
    qlo = q.lo << d if d >= 0 else q.lo >> (-d)
    qhi = q.hi << d if d >= 0 else q.hi >> (-d)
    clipped = qlo < lo or qhi > hi
    return QInterval(min(max(qlo, lo), hi), min(max(qhi, lo), hi), exp), clipped


def _affine_qints(w: np.ndarray, qin: list[QInterval]) -> list[QInterval]:
    """Exact per-output interval of ``y = x @ w`` (affine form)."""
    exps = {q.exp for q in qin}
    if len(exps) == 1 and not any(q.is_zero for q in qin):
        # vectorized fast path (uniform exp, endpoints provably inside
        # int64): per-column sum of min/max of the endpoint products
        e = exps.pop()
        try:
            lo_v = np.array([q.lo for q in qin], dtype=np.int64)
            hi_v = np.array([q.hi for q in qin], dtype=np.int64)
        except OverflowError:
            lo_v = hi_v = None
    else:
        lo_v = hi_v = None
    if lo_v is not None:
        mag = np.maximum(np.abs(lo_v), np.abs(hi_v)).astype(float)
        bound = (np.abs(w).astype(float) * mag[:, None]).sum(axis=0).max(initial=0.0)
        if bound < float(1 << 52):  # exact in float, far inside int64
            a = w * lo_v[:, None]
            b = w * hi_v[:, None]
            lows = np.minimum(a, b).sum(axis=0)
            highs = np.maximum(a, b).sum(axis=0)
            live = np.any(w != 0, axis=0)
            return [
                QInterval(int(lows[j]), int(highs[j]), e) if live[j]
                else QInterval(0, 0, 0)
                for j in range(w.shape[1])
            ]
    out: list[QInterval] = []
    for j in range(w.shape[1]):
        q: QInterval | None = None
        col = w[:, j]
        for i in np.nonzero(col)[0]:
            t = qin[int(i)].scale(int(col[i]))
            q = t if q is None else q.add(t)
        out.append(QInterval(0, 0, 0) if q is None else q)
    return out


def _exps(qints: list[QInterval], fallback: int = 0) -> list[int]:
    return [fallback if q.is_zero else q.exp for q in qints]


class _Flow:
    """Mutable walk state: feature shape + per-feature intervals.

    ``exact`` drops to False once metadata needed for exact interval
    replay is missing (legacy artifact) or a structural error makes the
    downstream flow meaningless; structural checks continue either way.
    """

    def __init__(self, shape: tuple, qints: list[QInterval]) -> None:
        self.shape = shape
        self.qints = qints
        self.exact = True


def check_steps(
    design: Any,
    report: DiagnosticReport | None = None,
    *,
    programs: list | None = None,
) -> DiagnosticReport:
    rep = report if report is not None else DiagnosticReport()
    specs = getattr(design, "step_specs", None) or []
    if programs is None:
        programs = list(getattr(design, "programs", None) or [])
    in_quant = getattr(design, "in_quant", None)
    if in_quant is None:
        rep.add(
            "DA029", "design carries no input quantization; interval flow skipped",
            loc={}, passname=_PASS,
        )
        return rep

    shape = tuple(getattr(design, "in_shape", ()) or ())
    n_feat = int(np.prod(shape)) if shape else 0
    flow = _Flow(shape, [in_quant.qint] * n_feat)
    # weight matrices recovered per program index (shared CMVMs hit once)
    w_cache: dict[int, np.ndarray | None] = {}

    _walk(specs, flow, programs, w_cache, rep, path="")

    if not flow.exact:
        return rep
    out_shape = tuple(getattr(design, "out_shape", ()) or ())
    n = int(np.prod(flow.shape)) if flow.shape else 0
    # Flatten emits no StepSpec, so a trailing flatten is invisible here:
    # a 1-D out_shape of the same flat size is the same feature order.
    flat_ok = flow.shape == out_shape or (
        n == int(np.prod(out_shape)) and (out_shape == (n,) or flow.shape == (n,))
    )
    if not flat_ok:
        rep.add(
            "DA021",
            f"final flow shape {flow.shape} != design.out_shape {out_shape}",
            loc={"step": "end"}, passname=_PASS,
        )
    else:
        claimed = list(getattr(design, "out_qints", []) or [])
        if len(claimed) != len(flow.qints):
            rep.add(
                "DA026",
                f"design.out_qints has {len(claimed)} entries, flow derives "
                f"{len(flow.qints)}",
                loc={"step": "end"}, passname=_PASS,
            )
        else:
            bad = [i for i, (c, d) in enumerate(zip(claimed, flow.qints)) if c != d]
            if bad:
                i = bad[0]
                rep.add(
                    "DA026",
                    f"{len(bad)} output interval(s) differ from the re-derived "
                    f"flow (first: feature {i}: claimed {claimed[i]}, derived "
                    f"{flow.qints[i]})",
                    loc={"step": "end", "feature": i}, passname=_PASS,
                )
    return rep


# ----------------------------------------------------------------------
def _walk(
    specs: list,
    flow: _Flow,
    programs: list,
    w_cache: dict[int, np.ndarray | None],
    rep: DiagnosticReport,
    path: str,
) -> None:
    for k, s in enumerate(specs):
        if not flow.exact:
            # the first defect (or missing legacy metadata) was reported;
            # downstream state is unknowable, so stop instead of cascading
            return
        here = f"{path}{k}"
        loc = {"step": here, "kind": getattr(s, "kind", "?")}
        kind = getattr(s, "kind", None)
        if kind == "dense":
            _step_dense(s, flow, programs, w_cache, rep, loc)
        elif kind == "conv":
            _step_conv(s, flow, programs, w_cache, rep, loc)
        elif kind == "requant":
            _step_requant(s, flow, rep, loc)
        elif kind == "transpose":
            _step_transpose(s, flow, rep, loc)
        elif kind == "relu":
            if flow.exact and flow.qints and all(q.lo >= 0 for q in flow.qints):
                rep.add(
                    "DA025", "relu over a provably non-negative flow is a no-op",
                    loc=loc, passname=_PASS,
                )
            flow.qints = [
                q if q.is_zero else QInterval(max(q.lo, 0), max(q.hi, 0), q.exp)
                for q in flow.qints
            ]
        elif kind in ("maxpool", "avgpool"):
            _step_pool(s, flow, rep, loc)
        elif kind == "residual":
            _step_residual(s, flow, programs, w_cache, rep, loc)
        else:
            rep.add("DA027", f"unknown step kind {kind!r}", loc=loc, passname=_PASS)
            flow.exact = False
            return
        if flow.exact and any(
            q.lo < _I32[0] or q.hi > _I32[1] for q in flow.qints
        ):
            rep.add(
                "DA028",
                "derived interval exceeds the int32 executor range after this step",
                loc=loc, passname=_PASS,
            )


def _cmvm_core(
    s: Any,
    qin: list[QInterval],
    programs: list,
    w_cache: dict[int, np.ndarray | None],
    rep: DiagnosticReport,
    loc: dict,
) -> list[QInterval] | None:
    """Shared dense/conv core.  Returns the per-instance output qints,
    or None when the flow cannot continue exactly."""
    t = getattr(s, "table", -1)
    if not isinstance(t, int) or not 0 <= t < len(programs):
        rep.add(
            "DA020",
            f"table index {t} out of range (design has {len(programs)} programs)",
            loc=loc, passname=_PASS,
        )
        return None
    parr = programs[t]
    if parr is None:
        rep.add(
            "DA029", f"program {t} is not packed; CMVM interval check skipped",
            loc=loc, passname=_PASS,
        )
        return None
    prog = DAISProgram.from_arrays(parr) if not isinstance(parr, DAISProgram) else parr
    if prog.n_inputs != len(qin):
        rep.add(
            "DA022",
            f"flow feeds {len(qin)} features but program {t} takes "
            f"{prog.n_inputs} inputs",
            loc=loc, passname=_PASS,
        )
        return None
    bad = [
        i for i in range(prog.n_inputs) if prog.rows[i].qint != qin[i]
    ]
    if bad:
        i = bad[0]
        rep.add(
            "DA022",
            f"{len(bad)} program input interval(s) differ from the derived "
            f"flow (first: input {i}: program {prog.rows[i].qint}, flow {qin[i]})",
            loc={**loc, "input": i}, passname=_PASS,
        )
        return None

    wscale = s.params.get("wscale")
    if wscale is None:
        rep.add(
            "DA029",
            "step lacks the 'wscale' param; exact interval replay stops here",
            loc=loc, passname=_PASS,
        )
        return None

    if t not in w_cache:
        try:
            w_cache[t] = prog.evaluate(np.eye(prog.n_inputs, dtype=np.int64))
        except Exception:
            w_cache[t] = None
    w = w_cache[t]
    if w is None:
        rep.add(
            "DA029", f"program {t} could not be evaluated for matrix recovery",
            loc=loc, passname=_PASS,
        )
        return None

    out_q = [q.shift(int(wscale)) for q in _affine_qints(w, qin)]

    bias = s.arrays.get("bias")
    shift = s.arrays.get("shift")
    if bias is None:
        if shift is not None:
            rep.add(
                "DA023", "step has a pre-shift array but no bias",
                loc=loc, passname=_PASS,
            )
        return out_q

    bias = np.asarray(bias, np.int64)
    if bias.shape != (len(out_q),):
        rep.add(
            "DA023",
            f"bias array has shape {bias.shape}, step has {len(out_q)} outputs",
            loc=loc, passname=_PASS,
        )
        return None
    e_b = int(wscale) + min(q.exp for q in qin)
    exps = _exps(out_q, fallback=e_b)
    tgt = [min(e, e_b) for e in exps]
    pre = [e - g for e, g in zip(exps, tgt)]
    want_shift = np.asarray(pre, np.int64)
    if shift is None:
        if want_shift.any():
            rep.add(
                "DA023",
                "bias pre-shift array missing but the derived flow needs "
                f"nonzero pre-shifts (first at output {int(np.nonzero(want_shift)[0][0])})",
                loc=loc, passname=_PASS,
            )
            return None
    else:
        shift = np.asarray(shift, np.int64)
        if shift.shape != want_shift.shape or (shift != want_shift).any():
            rep.add(
                "DA023",
                "bias pre-shift array differs from the derived exp alignment",
                loc=loc, passname=_PASS,
            )
            return None
    return [
        QInterval((q.lo << p) + int(b), (q.hi << p) + int(b), g)
        if not q.is_zero
        else QInterval(min(int(b), 0), max(int(b), 0), g)
        for q, b, p, g in zip(out_q, bias.tolist(), pre, tgt)
    ]


def _step_dense(
    s: Any,
    flow: _Flow,
    programs: list,
    w_cache: dict[int, np.ndarray | None],
    rep: DiagnosticReport,
    loc: dict,
) -> None:
    d_in = s.params.get("d_in")
    if flow.shape and flow.shape[-1] != d_in and int(np.prod(flow.shape)) == d_in:
        # Flatten compiles to a shape change only (no StepSpec, flat
        # feature order is preserved), so a dense over the whole flat
        # vector implies an elided flatten — replay it here.
        flow.shape = (d_in,)
    if not flow.shape or flow.shape[-1] != d_in:
        rep.add(
            "DA021",
            f"dense expects trailing dim {d_in}, flow shape is {flow.shape}",
            loc=loc, passname=_PASS,
        )
        flow.exact = False
        return
    lead = int(np.prod(flow.shape[:-1]))
    if not flow.exact:
        return
    qarr = np.array(flow.qints, dtype=object).reshape(lead, d_in)
    qin = [_union(list(qarr[:, i])) for i in range(d_in)]
    out_q = _cmvm_core(s, qin, programs, w_cache, rep, loc)
    if out_q is None:
        flow.exact = False
        return
    flow.shape = flow.shape[:-1] + (len(out_q),)
    flow.qints = list(out_q) * lead


def _step_conv(
    s: Any,
    flow: _Flow,
    programs: list,
    w_cache: dict[int, np.ndarray | None],
    rep: DiagnosticReport,
    loc: dict,
) -> None:
    p = s.params
    need = ("h", "w", "cin", "kh", "kw", "sh", "sw", "oh", "ow")
    if any(p.get(k) is None for k in need):
        rep.add("DA023", "conv step params incomplete", loc=loc, passname=_PASS)
        flow.exact = False
        return
    h, w, cin = p["h"], p["w"], p["cin"]
    kh, kw, sh, sw, oh, ow = p["kh"], p["kw"], p["sh"], p["sw"], p["oh"], p["ow"]
    if flow.shape != (h, w, cin):
        rep.add(
            "DA021",
            f"conv expects input shape {(h, w, cin)}, flow shape is {flow.shape}",
            loc=loc, passname=_PASS,
        )
        flow.exact = False
        return
    if oh != (h - kh) // sh + 1 or ow != (w - kw) // sw + 1:
        rep.add(
            "DA021",
            f"conv output grid ({oh},{ow}) inconsistent with "
            f"shape/kernel/stride ({h},{w})/({kh},{kw})/({sh},{sw})",
            loc=loc, passname=_PASS,
        )
        flow.exact = False
        return
    if not flow.exact:
        return
    qarr = np.array(flow.qints, dtype=object).reshape(h, w, cin)
    qin = []
    for dy in range(kh):
        for dx in range(kw):
            for c in range(cin):
                qin.append(
                    _union(
                        [
                            qarr[i * sh + dy, j * sw + dx, c]
                            for i in range(oh)
                            for j in range(ow)
                        ]
                    )
                )
    out_q = _cmvm_core(s, qin, programs, w_cache, rep, loc)
    if out_q is None:
        flow.exact = False
        return
    flow.shape = (oh, ow, len(out_q))
    flow.qints = list(out_q) * (oh * ow)


def _step_requant(s: Any, flow: _Flow, rep: DiagnosticReport, loc: dict) -> None:
    d = s.arrays.get("d")
    if d is None or np.asarray(d).shape != (len(flow.qints),):
        rep.add(
            "DA023",
            f"requant shift array missing or wrong length "
            f"(flow has {len(flow.qints)} features)",
            loc=loc, passname=_PASS,
        )
        flow.exact = False
        return
    lo, hi = s.params.get("lo"), s.params.get("hi")
    if lo is None or hi is None or lo > hi:
        rep.add(
            "DA023", f"requant clip range ({lo}, {hi}) malformed",
            loc=loc, passname=_PASS,
        )
        flow.exact = False
        return
    if not flow.exact:
        return
    exp = s.params.get("exp")
    if exp is None:
        rep.add(
            "DA029",
            "requant step lacks the 'exp' param; exact interval replay stops here",
            loc=loc, passname=_PASS,
        )
        flow.exact = False
        return
    exp = int(exp)
    d = np.asarray(d, np.int64)
    want_d = np.asarray(
        [e - exp for e in _exps(flow.qints, fallback=exp)], np.int64
    )
    if (d != want_d).any():
        i = int(np.nonzero(d != want_d)[0][0])
        rep.add(
            "DA023",
            f"requant shift array differs from the derived exp delta "
            f"(first at feature {i}: stored {int(d[i])}, derived {int(want_d[i])})",
            loc={**loc, "feature": i}, passname=_PASS,
        )
        flow.exact = False
        return
    new_q, any_clip, any_change = [], False, False
    for q in flow.qints:
        nq, clipped = _requant(q, int(lo), int(hi), exp)
        any_clip = any_clip or clipped
        any_change = any_change or nq != q
        new_q.append(nq)
    if any_clip:
        rep.add(
            "DA024",
            "derived interval exceeds the requant clip range; values will saturate",
            loc=loc, passname=_PASS,
        )
    if not any_change and not d.any() and flow.qints:
        rep.add(
            "DA025", "requant is a provable no-op on the derived flow",
            loc=loc, passname=_PASS,
        )
    flow.qints = new_q


def _step_transpose(s: Any, flow: _Flow, rep: DiagnosticReport, loc: dict) -> None:
    shape = tuple(s.params.get("shape") or ())
    perm = tuple(s.params.get("perm") or ())
    if shape != flow.shape:
        rep.add(
            "DA021",
            f"transpose declares shape {shape}, flow shape is {flow.shape}",
            loc=loc, passname=_PASS,
        )
        flow.exact = False
        return
    if sorted(perm) != list(range(len(shape))):
        rep.add(
            "DA023", f"transpose perm {perm} is not a permutation",
            loc=loc, passname=_PASS,
        )
        flow.exact = False
        return
    flow.shape = tuple(shape[i] for i in perm)
    if flow.exact:
        arr = np.array(flow.qints, dtype=object).reshape(shape)
        flow.qints = list(arr.transpose(perm).reshape(-1))


def _step_pool(s: Any, flow: _Flow, rep: DiagnosticReport, loc: dict) -> None:
    p = s.params
    h, w, c, ph, pw = (p.get(k) for k in ("h", "w", "c", "ph", "pw"))
    if None in (h, w, c, ph, pw):
        rep.add("DA023", "pool step params incomplete", loc=loc, passname=_PASS)
        flow.exact = False
        return
    if flow.shape != (h, w, c) or h % ph or w % pw:
        rep.add(
            "DA021",
            f"pool window ({ph},{pw}) does not tile flow shape {flow.shape} "
            f"(declared {(h, w, c)})",
            loc=loc, passname=_PASS,
        )
        flow.exact = False
        return
    is_avg = s.kind == "avgpool"
    k = ph * pw
    if is_avg and k & (k - 1):
        rep.add(
            "DA023", f"avgpool window {ph}x{pw} is not a power of two",
            loc=loc, passname=_PASS,
        )
        flow.exact = False
        return
    flow.shape = (h // ph, w // pw, c)
    if not flow.exact:
        return
    qarr = np.array(flow.qints, dtype=object).reshape(h, w, c)
    new = []
    for i in range(h // ph):
        for j in range(w // pw):
            for ch in range(c):
                block = [
                    qarr[i * ph + a, j * pw + b, ch]
                    for a in range(ph)
                    for b in range(pw)
                ]
                if is_avg:
                    q = block[0]
                    for qq in block[1:]:
                        q = q.add(qq)
                    new.append(q.shift(-int(k).bit_length() + 1))
                else:
                    new.append(_union(block))
    flow.qints = new


def _step_residual(
    s: Any,
    flow: _Flow,
    programs: list,
    w_cache: dict[int, np.ndarray | None],
    rep: DiagnosticReport,
    loc: dict,
) -> None:
    body = getattr(s, "body", None) or []
    inner = _Flow(flow.shape, list(flow.qints))
    inner.exact = flow.exact
    _walk(body, inner, programs, w_cache, rep, path=f"{loc['step']}/body/")
    if inner.shape != flow.shape:
        rep.add(
            "DA021",
            f"residual body changes shape {flow.shape} -> {inner.shape}",
            loc=loc, passname=_PASS,
        )
        flow.exact = False
        return
    sa = s.arrays.get("sa")
    sb = s.arrays.get("sb")
    n = len(flow.qints)
    if sa is None or sb is None or np.asarray(sa).shape != (n,) or np.asarray(sb).shape != (n,):
        rep.add(
            "DA023", "residual alignment arrays missing or wrong length",
            loc=loc, passname=_PASS,
        )
        flow.exact = False
        return
    if not (flow.exact and inner.exact):
        flow.exact = False
        return
    ea = _exps(flow.qints)
    eb = _exps(inner.qints)
    e = [min(a, b) for a, b in zip(ea, eb)]
    want_sa = np.asarray([a - x for a, x in zip(ea, e)], np.int64)
    want_sb = np.asarray([b - x for b, x in zip(eb, e)], np.int64)
    if (np.asarray(sa, np.int64) != want_sa).any() or (
        np.asarray(sb, np.int64) != want_sb
    ).any():
        rep.add(
            "DA023",
            "residual alignment shifts differ from the derived exp alignment",
            loc=loc, passname=_PASS,
        )
        flow.exact = False
        return
    new = []
    for qa, qb, ee in zip(flow.qints, inner.qints, e):
        qa2 = qa if not qa.is_zero else QInterval(0, 0, int(ee))
        qb2 = qb if not qb.is_zero else QInterval(0, 0, int(ee))
        new.append(qa2.add(qb2))
    flow.qints = new
