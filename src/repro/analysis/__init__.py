"""Static design verification: abstract-interpretation lint passes.

Three passes over a compiled design (or a saved artifact directory),
each emitting structured :class:`Diagnostic` findings with stable
``DA0xx`` codes:

* :mod:`repro.analysis.program` — DAIS program verifier: re-derives
  every row's interval/depth/cost from the inputs, re-derives the
  pipeline schedule, audits the emitted Verilog's declared widths.
* :mod:`repro.analysis.steps` — StepSpec pipeline checker: replays the
  compiler's interval flow across the step topology and checks every
  baked array (requant shifts, bias pre-shifts, residual alignments)
  and the final output intervals against the re-derivation.
* :mod:`repro.analysis.artifact` — artifact auditor: content digests,
  config-digest consistency, npz key integrity, solve-free loadability.

Entry points: :func:`verify_design` (design object or artifact path,
``tier`` in ``off``/``cheap``/``strict``), ``python -m repro.analysis``
over artifact directories, ``Flow.verify``, ``CompileConfig(verify=...)``
(compile-time gate), and ``load_design(verify=...)``.

See ``docs/analysis.md`` for the full diagnostic-code reference.
"""

from .artifact import audit_artifact
from .diagnostics import CODES, Diagnostic, DiagnosticReport
from .program import (
    check_emission,
    check_pipeline,
    check_program,
    derive_row_qints,
    required_signed_width,
)
from .steps import check_steps
from .verify import TIERS, DesignVerificationError, verify_design

__all__ = [
    "CODES",
    "TIERS",
    "DesignVerificationError",
    "Diagnostic",
    "DiagnosticReport",
    "audit_artifact",
    "check_emission",
    "check_pipeline",
    "check_program",
    "check_steps",
    "derive_row_qints",
    "required_signed_width",
    "verify_design",
]
