"""Structured diagnostics for the design verifier.

Every finding of every analysis pass is a :class:`Diagnostic` with a
stable ``DA0xx`` code, a severity, a human message, and a structured
location (program index / row / step path / artifact file).  Codes are
append-only: a code, once assigned a meaning, is never reused for a
different defect class — CI logs and mutation-canary tests key on them.

Code blocks by pass:

    DA001-DA019   DAIS program verifier (repro.analysis.program)
    DA020-DA039   StepSpec pipeline checker (repro.analysis.steps)
    DA040-DA059   artifact auditor (repro.analysis.artifact)

Severity semantics: ``error`` findings mean the design is provably
malformed or its metadata provably inconsistent — gates (compile-time
verify, the design-lint CI job, the CLI) fail on them.  ``warning``
findings are suspicious-but-legal constructs (possible requant
saturation, dead steps, orphan arrays).  ``info`` is narration (skipped
checks on legacy artifacts).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

__all__ = [
    "CODES",
    "Diagnostic",
    "DiagnosticReport",
    "Severity",
]

Severity = str  # "error" | "warning" | "info"

# code -> (default severity, one-line description); the reference table
# rendered in docs/analysis.md is generated from this registry.
CODES: dict[str, tuple[str, str]] = {
    # -- DAIS program verifier ----------------------------------------
    "DA001": ("error", "malformed row: bad kind, operand slot, or sign"),
    "DA002": ("error", "input-section violation: op before input, or n_inputs mismatch"),
    "DA003": ("error", "shift violation: negative shift or un-normalised shift pair"),
    "DA004": ("error", "row interval differs from abstract-interpretation derivation"),
    "DA005": ("error", "row adder depth differs from derived depth"),
    "DA006": ("error", "row cost differs from the Eq.(1) adder-cost model"),
    "DA007": ("error", "dangling output term: row out of range or bad sign"),
    "DA008": ("warning", "dead row: op not reachable from any output"),
    "DA009": ("error", "emitted wire narrower than the signed width its interval needs"),
    "DA010": ("error", "pipeline report disagrees with re-derived schedule/FF/latency"),
    "DA011": ("error", "emitted RTL is structurally unsound (register imbalance, parse)"),
    "DA012": ("error", "program totals (cost_bits/depth) disagree with claimed report"),
    "DA013": ("info", "program check skipped (simulator width limit or unpackable program)"),
    # -- StepSpec pipeline checker ------------------------------------
    "DA020": ("error", "CMVM step references a missing or out-of-range table/program"),
    "DA021": ("error", "shape flow broken: step input size incompatible with params"),
    "DA022": ("error", "CMVM arity/interval mismatch between step flow and program inputs"),
    "DA023": ("error", "malformed step arrays (bias/shift/requant lengths or values)"),
    "DA024": ("warning", "requant may saturate: derived interval exceeds clip range"),
    "DA025": ("warning", "dead step: provably a no-op on every reachable value"),
    "DA026": ("error", "design output intervals differ from re-derived interval flow"),
    "DA027": ("error", "unknown step kind"),
    "DA028": ("warning", "derived interval exceeds the int32 executor range"),
    "DA029": ("info", "step check skipped (legacy artifact lacks wscale/exp metadata)"),
    # -- artifact auditor ---------------------------------------------
    "DA040": ("error", "not a loadable design artifact (missing/bad manifest or format)"),
    "DA041": ("error", "design.npz content does not match the manifest digest"),
    "DA042": ("error", "compile-config digest inconsistent with the embedded config"),
    "DA043": ("warning", "orphan npz arrays not referenced by any step or program"),
    "DA044": ("error", "manifest references an npz key that does not exist"),
    "DA045": ("error", "manifest resource totals disagree with the layer reports"),
    "DA046": ("error", "artifact load failed or re-ran solves"),
    "DA047": ("error", "layer report claims match no program (stages/FF/adders)"),
}

_SEVERITIES = ("error", "warning", "info")


@dataclass(frozen=True)
class Diagnostic:
    """One verifier finding."""

    code: str
    message: str
    severity: Severity = ""
    # structured location, e.g. {"program": 0, "row": 17} or
    # {"step": "3/residual.1"} or {"artifact": "manifest.json"}
    loc: dict = field(default_factory=dict)
    passname: str = ""

    def __post_init__(self) -> None:
        if self.code not in CODES:
            raise ValueError(f"unknown diagnostic code {self.code!r}")
        if not self.severity:
            object.__setattr__(self, "severity", CODES[self.code][0])
        if self.severity not in _SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    def to_dict(self) -> dict:
        return {
            "code": self.code,
            "severity": self.severity,
            "message": self.message,
            "loc": dict(self.loc),
            "pass": self.passname,
        }

    def __str__(self) -> str:
        loc = ",".join(f"{k}={v}" for k, v in sorted(self.loc.items()))
        where = f" [{loc}]" if loc else ""
        return f"{self.code} {self.severity}{where}: {self.message}"


@dataclass
class DiagnosticReport:
    """Ordered collection of findings plus per-pass accounting.

    ``ok`` is the gate predicate: no error-severity findings.  Reports
    compose — pass functions append into one shared report so one
    ``verify_design`` call yields one flat, JSON-serializable result.
    """

    diagnostics: list[Diagnostic] = field(default_factory=list)
    # pass name -> wall seconds (filled by verify_design)
    pass_wall_s: dict = field(default_factory=dict)
    tier: str = "cheap"

    def add(
        self,
        code: str,
        message: str,
        *,
        loc: dict | None = None,
        passname: str = "",
        severity: str = "",
    ) -> Diagnostic:
        d = Diagnostic(code, message, severity, dict(loc or {}), passname)
        self.diagnostics.append(d)
        return d

    def extend(self, other: "DiagnosticReport") -> None:
        self.diagnostics.extend(other.diagnostics)
        self.pass_wall_s.update(other.pass_wall_s)

    @property
    def errors(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    @property
    def warnings(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "warning"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def codes(self) -> set[str]:
        return {d.code for d in self.diagnostics}

    def by_code(self, code: str) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.code == code]

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "tier": self.tier,
            "n_errors": len(self.errors),
            "n_warnings": len(self.warnings),
            "diagnostics": [d.to_dict() for d in self.diagnostics],
            "pass_wall_s": dict(self.pass_wall_s),
        }

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    def summary(self) -> str:
        head = (
            f"verify[{self.tier}]: "
            f"{'OK' if self.ok else 'FAIL'} "
            f"({len(self.errors)} errors, {len(self.warnings)} warnings)"
        )
        return "\n".join([head] + [f"  {d}" for d in self.diagnostics])
