"""DAIS program verifier: abstract interpretation over shift-add rows.

The solver annotates every row of a :class:`~repro.core.dais.DAISProgram`
with an exact :class:`~repro.core.fixed_point.QInterval`, an adder depth,
and an Eq.(1) adder-bit cost — and the compiler, the pipeliner, and the
Verilog emitter all *trust* those annotations.  This pass re-derives
every annotation from the input intervals alone (shift/add/sub/neg
transfer functions) and reports any row where the claimed metadata
differs from the derived truth, plus structural defects (dangling refs,
op-before-input, un-normalised shifts, dead rows).

Two further checks close the PR 7 regression classes without running a
single test vector:

* :func:`check_pipeline` re-derives the greedy register schedule and the
  carry-register (FF) bill with an independent implementation and
  compares it against :func:`repro.core.pipelining.pipeline`'s claim —
  a clobbered ``last_use`` carry (assignment where a ``max`` is needed)
  shows up as an FF/latency disagreement (``DA010``).
* :func:`check_emission` emits the Verilog and audits the *text*: every
  declared wire must be at least the minimal signed width its interval
  requires — including the explicit sign bit a non-negative interval
  pays on a ``signed`` wire (``DA009``) — and the netlist's own
  register-balance analysis must pass (``DA011``).
"""

from __future__ import annotations

import re

from ..core.cost import adder_cost
from ..core.dais import KIND_ADD, KIND_INPUT, KIND_NEG, DAISProgram
from ..core.fixed_point import QInterval
from ..core.pipelining import PipelineReport, pipeline
from ..core.rtlsim import RTLSimError, parse_verilog
from ..core.verilog import emit_verilog
from .diagnostics import DiagnosticReport

__all__ = [
    "check_emission",
    "check_pipeline",
    "check_program",
    "derive_row_qints",
    "required_signed_width",
]

_PASS = "program"


def required_signed_width(q: QInterval) -> int:
    """Minimal width of a ``signed`` wire that can carry interval ``q``.

    Independent restatement of the emission rule: the minimal
    two's-complement width of the interval, plus one explicit sign bit
    when the interval is non-negative (a non-negative value on a signed
    wire of its magnitude width would read back negative), floor 1.
    Deliberately NOT delegated to ``repro.core.verilog`` — this is the
    verifier's own ground truth the emitter is audited against.
    """
    if q.is_zero:
        return 1
    if q.lo < 0:
        mag = max(q.hi, -q.lo - 1)
        w = (mag.bit_length() + 1) if mag > 0 else 1
    else:
        w = q.hi.bit_length() + 1  # magnitude bits + explicit sign bit
    return max(w, 1)


def derive_row_qints(prog: DAISProgram) -> list[QInterval | None]:
    """Abstract interpretation: per-row intervals derived from inputs.

    Input rows are ground truth (they are the caller's specification);
    every op row's interval is re-derived through the exact transfer
    functions.  Rows whose operands are structurally invalid derive to
    ``None`` (reported separately by :func:`check_program`).
    """
    # raw (lo, hi, exp) endpoint arithmetic — semantically identical to
    # QInterval.shift + add/sub/neg (zero intervals keep their exp), but
    # without the per-op object churn: this runs on every compile.
    derived: list[QInterval | None] = []
    vals: list[tuple[int, int, int] | None] = []
    for i, r in enumerate(prog.rows):
        if r.kind == KIND_INPUT:
            q = r.qint
            derived.append(q)
            vals.append((q.lo, q.hi, q.exp))
            continue
        va = vals[r.a] if 0 <= r.a < i else None
        if r.kind == KIND_NEG:
            if va is None:
                derived.append(None)
                vals.append(None)
                continue
            alo, ahi, ae = va
            v = (-ahi, -alo, ae)
        else:
            vb = vals[r.b] if 0 <= r.b < i else None
            if va is None or vb is None or r.sh_a < 0 or r.sh_b < 0:
                derived.append(None)
                vals.append(None)
                continue
            alo, ahi, ae = va
            blo, bhi, be = vb
            if alo != 0 or ahi != 0:
                ae += r.sh_a
            if blo != 0 or bhi != 0:
                be += r.sh_b
            if blo == 0 == bhi:
                v = (alo, ahi, ae)
            elif alo == 0 == ahi:
                v = (blo, bhi, be) if r.sign > 0 else (-bhi, -blo, be)
            else:
                e = ae if ae < be else be
                al, ah = alo << (ae - e), ahi << (ae - e)
                bl, bh = blo << (be - e), bhi << (be - e)
                v = (al + bl, ah + bh, e) if r.sign > 0 else (al - bh, ah - bl, e)
        vals.append(v)
        derived.append(QInterval(*v))
    return derived


def check_program(
    prog: DAISProgram,
    report: DiagnosticReport | None = None,
    *,
    program_index: int | None = None,
) -> DiagnosticReport:
    """Structural + metadata verification of one DAIS program."""
    rep = report if report is not None else DiagnosticReport()

    def loc(**kw: object) -> dict:
        base: dict = {} if program_index is None else {"program": program_index}
        base.update(kw)
        return base

    n = len(prog.rows)
    n_inputs = sum(1 for r in prog.rows if r.kind == KIND_INPUT)
    if n_inputs != prog.n_inputs:
        rep.add(
            "DA002",
            f"program claims n_inputs={prog.n_inputs} but has {n_inputs} input rows",
            loc=loc(), passname=_PASS,
        )
    seen_op = False
    structural_ok = True
    for i, r in enumerate(prog.rows):
        if r.kind == KIND_INPUT:
            if seen_op:
                rep.add(
                    "DA002", "input row appears after an op row",
                    loc=loc(row=i), passname=_PASS,
                )
                structural_ok = False
            continue
        seen_op = True
        if r.kind not in (KIND_ADD, KIND_NEG):
            rep.add("DA001", f"unknown row kind {r.kind}", loc=loc(row=i), passname=_PASS)
            structural_ok = False
            continue
        operands = (r.a, r.b) if r.kind == KIND_ADD else (r.a,)
        for o in operands:
            if not 0 <= o < i:
                rep.add(
                    "DA001",
                    f"operand ref {o} is dangling (must name an earlier row, got row {i})",
                    loc=loc(row=i), passname=_PASS,
                )
                structural_ok = False
        if r.kind == KIND_ADD:
            if r.sign not in (-1, 1):
                rep.add("DA001", f"op sign must be ±1, got {r.sign}", loc=loc(row=i), passname=_PASS)
                structural_ok = False
            if r.sh_a < 0 or r.sh_b < 0:
                rep.add(
                    "DA003", f"negative operand shift ({r.sh_a}, {r.sh_b})",
                    loc=loc(row=i), passname=_PASS,
                )
                structural_ok = False
            elif min(r.sh_a, r.sh_b) != 0:
                rep.add(
                    "DA003",
                    f"shift pair ({r.sh_a}, {r.sh_b}) not normalised (min must be 0)",
                    loc=loc(row=i), passname=_PASS,
                )
        else:  # KIND_NEG
            if r.sh_a != 0 or r.sh_b != 0:
                rep.add(
                    "DA003", "negation row must carry zero shifts",
                    loc=loc(row=i), passname=_PASS,
                )

    for j, t in enumerate(prog.outputs):
        if t is None:
            continue
        if not 0 <= t.row < n:
            rep.add(
                "DA007", f"output {j} references row {t.row} (program has {n} rows)",
                loc=loc(output=j), passname=_PASS,
            )
            structural_ok = False
        if t.sign not in (-1, 1):
            rep.add(
                "DA007", f"output {j} sign must be ±1, got {t.sign}",
                loc=loc(output=j), passname=_PASS,
            )

    # metadata re-derivation (only meaningful on structurally sound rows)
    derived = derive_row_qints(prog)
    for i, r in enumerate(prog.rows):
        if r.kind == KIND_INPUT:
            continue
        dq = derived[i]
        if dq is None:
            continue  # structural defect already reported
        if r.qint != dq:
            rep.add(
                "DA004",
                f"row interval {r.qint} differs from derived {dq}",
                loc=loc(row=i), passname=_PASS,
            )
        ra = prog.rows[r.a]
        d_depth = (max(ra.depth, prog.rows[r.b].depth) if r.kind == KIND_ADD else ra.depth) + 1
        if r.depth != d_depth:
            rep.add(
                "DA005",
                f"row depth {r.depth} differs from derived {d_depth}",
                loc=loc(row=i), passname=_PASS,
            )
        if r.kind == KIND_ADD:
            d_cost = adder_cost(derived[r.a], derived[r.b], r.sh_a, r.sh_b, r.sign)
        else:
            d_cost = (derived[r.a].width if derived[r.a] is not None else 0) + 1
        if r.cost != d_cost:
            rep.add(
                "DA006",
                f"row cost {r.cost} differs from the cost-model value {d_cost}",
                loc=loc(row=i), passname=_PASS,
            )

    # dead rows: ops unreachable from any output (the solver prunes, so a
    # shipped program carrying dead logic is suspicious, not fatal)
    if structural_ok:
        live = [False] * n
        stack = [t.row for t in prog.outputs if t is not None and 0 <= t.row < n]
        while stack:
            i = stack.pop()
            if live[i]:
                continue
            live[i] = True
            r = prog.rows[i]
            if r.kind != KIND_INPUT:
                stack.append(r.a)
                if r.kind == KIND_ADD:
                    stack.append(r.b)
        dead = [i for i, r in enumerate(prog.rows) if r.kind != KIND_INPUT and not live[i]]
        if dead:
            rep.add(
                "DA008",
                f"{len(dead)} op row(s) unreachable from any output "
                f"(first: row {dead[0]})",
                loc=loc(), passname=_PASS,
            )
    return rep


# ----------------------------------------------------------------------
# Pipeline re-derivation
# ----------------------------------------------------------------------
def _derive_schedule(prog: DAISProgram, max_delay_per_stage: int) -> tuple[int, list[int], int]:
    """Independent re-derivation of the greedy register schedule.

    Returns ``(n_stages, stage_of_row, ff_bits)`` computed from scratch:
    the same local greedy rule the paper specifies, with the carry bill
    built from a per-row *latest consumer stage* that honours both op
    consumers and output taps (the ``max`` rule PR 7 fixed).
    """
    n = len(prog.rows)
    stage = [0] * n
    within = [0] * n
    for i, r in enumerate(prog.rows):
        if r.kind == KIND_INPUT:
            continue
        ops = [r.a, r.b] if r.kind == KIND_ADD else [r.a]
        s = max(stage[o] for o in ops)
        d = 1 + max((within[o] for o in ops if stage[o] == s), default=0)
        if d > max_delay_per_stage:
            s += 1
            d = 1
        stage[i], within[i] = s, d
    tapped = [stage[t.row] for t in prog.outputs if t is not None]
    n_stages = max(tapped, default=0) + 1

    # latest stage each row's value is still needed in: every op consumer
    # AND the final output stage for tapped rows — never an overwrite.
    needed_until = list(stage)
    for i, r in enumerate(prog.rows):
        if r.kind == KIND_INPUT:
            continue
        for o in ([r.a, r.b] if r.kind == KIND_ADD else [r.a]):
            if stage[i] > needed_until[o]:
                needed_until[o] = stage[i]
    for t in prog.outputs:
        if t is not None and n_stages - 1 > needed_until[t.row]:
            needed_until[t.row] = n_stages - 1
    ff_bits = sum(
        (needed_until[i] - stage[i]) * r.qint.width
        for i, r in enumerate(prog.rows)
        if needed_until[i] > stage[i]
    )
    return n_stages, stage, ff_bits


def check_pipeline(
    prog: DAISProgram,
    max_delay_per_stage: int,
    report: DiagnosticReport | None = None,
    *,
    program_index: int | None = None,
    claimed: PipelineReport | None = None,
    derived: tuple[int, list[int], int] | None = None,
) -> DiagnosticReport:
    """Compare the production pipeliner's claim against a re-derivation.

    ``claimed`` defaults to calling :func:`repro.core.pipelining.pipeline`
    fresh, so a regression in the pipeliner itself (not just a stale
    stored report) is caught.  ``derived`` lets callers that already ran
    :func:`_derive_schedule` (verify_design shares it with the report
    matcher) skip the recomputation.
    """
    rep = report if report is not None else DiagnosticReport()
    loc: dict = {} if program_index is None else {"program": program_index}
    loc["max_delay_per_stage"] = max_delay_per_stage
    if claimed is None:
        claimed = pipeline(prog, max_delay_per_stage)
    n_stages, stage, ff_bits = (
        derived if derived is not None else _derive_schedule(prog, max_delay_per_stage)
    )
    if claimed.n_stages != n_stages:
        rep.add(
            "DA010",
            f"claimed n_stages={claimed.n_stages}, derived {n_stages}",
            loc=loc, passname=_PASS,
        )
    if list(claimed.stage_of_row) != stage:
        first = next(
            (i for i, (a, b) in enumerate(zip(claimed.stage_of_row, stage)) if a != b),
            None,
        )
        rep.add(
            "DA010",
            f"claimed stage assignment differs from derived (first at row {first})",
            loc=loc, passname=_PASS,
        )
    if claimed.ff_bits != ff_bits:
        rep.add(
            "DA010",
            f"claimed ff_bits={claimed.ff_bits}, derived {ff_bits} "
            "(a clobbered last-use/stage-carry produces exactly this drift)",
            loc=loc, passname=_PASS,
        )
    if claimed.latency_cycles != n_stages - 1:
        rep.add(
            "DA010",
            f"claimed latency_cycles={claimed.latency_cycles}, derived {n_stages - 1}",
            loc=loc, passname=_PASS,
        )
    return rep


# ----------------------------------------------------------------------
# Emission audit
# ----------------------------------------------------------------------
_VALUE_WIRE_RE = re.compile(r"^v(\d+)_s(\d+)$")


def check_emission(
    prog: DAISProgram,
    max_delay_per_stage: int | None,
    report: DiagnosticReport | None = None,
    *,
    program_index: int | None = None,
    src: str | None = None,
) -> DiagnosticReport:
    """Audit the emitted Verilog text against the program's intervals.

    ``src`` defaults to a fresh :func:`repro.core.verilog.emit_verilog`
    call so emitter regressions are caught; tests may pass doctored text.
    No simulation runs — the netlist is parsed, its declared widths are
    compared against :func:`required_signed_width` of the (re-derived)
    intervals, and the parser's structural register-balance analysis must
    accept it.
    """
    rep = report if report is not None else DiagnosticReport()
    loc: dict = {} if program_index is None else {"program": program_index}
    if src is None:
        try:
            src = emit_verilog(prog, max_delay_per_stage=max_delay_per_stage)
        except Exception as e:
            rep.add(
                "DA011", f"emit_verilog failed: {type(e).__name__}: {e}",
                loc=loc, passname=_PASS,
            )
            return rep
    try:
        mod = parse_verilog(src)
    except RTLSimError as e:
        if "the simulator supports" in str(e):
            rep.add("DA013", f"emission audit skipped: {e}", loc=loc, passname=_PASS)
        else:
            rep.add(
                "DA011",
                f"emitted RTL failed structural analysis: {e}",
                loc=loc, passname=_PASS,
            )
        return rep

    derived = derive_row_qints(prog)

    def want_width(q: QInterval | None) -> int | None:
        return None if q is None else required_signed_width(q)

    n_rows = len(prog.rows)
    for name, sig in mod.signals.items():
        m = _VALUE_WIRE_RE.match(name)
        q: QInterval | None = None
        if m is not None:
            row = int(m.group(1))
            q = derived[row] if row < n_rows else None
        elif name.startswith("x") and name[1:].isdigit():
            i = int(name[1:])
            q = prog.rows[i].qint if i < prog.n_inputs else None
        elif name.startswith("y") and name[1:].isdigit():
            j = int(name[1:])
            outs = prog.output_qints()
            q = outs[j] if j < len(outs) else None
        need = want_width(q)
        if need is None:
            continue
        if not sig.signed:
            rep.add(
                "DA009",
                f"signal {name} is unsigned; all emitted values must be signed wires",
                loc={**loc, "signal": name}, passname=_PASS,
            )
        if sig.width < need:
            rep.add(
                "DA009",
                f"signal {name} declared [{sig.width - 1}:0] but interval {q} "
                f"needs {need} signed bits (sign-bit rule included)",
                loc={**loc, "signal": name}, passname=_PASS,
            )

    if max_delay_per_stage is not None:
        want_lat = _derive_schedule(prog, max_delay_per_stage)[0] - 1
        if mod.latency_cycles != want_lat:
            rep.add(
                "DA011",
                f"emitted module exhibits latency {mod.latency_cycles}, "
                f"schedule derivation says {want_lat}",
                loc=loc, passname=_PASS,
            )
    return rep
