"""``verify_design``: one entry point over the three analysis passes.

Tiers:

``off``     no checks, empty report.
``cheap``   program verifier (structure, intervals, depth, cost, pipeline
            re-derivation) + step-flow checker + report/program matching.
            Pure Python over the packed programs — fast enough to run on
            every compile (``CompileConfig.verify`` defaults to it).
``strict``  everything in cheap, plus the Verilog emission audit of every
            program (declared widths vs required signed widths, netlist
            register balance) — the static closure of the PR 7 bug
            classes.  This is what the design-lint CI job and the CLI
            run.

``verify_design`` accepts either a compiled design object or an artifact
directory path; a path additionally runs the artifact auditor first and
then verifies the loaded design.
"""

from __future__ import annotations

import time
from pathlib import Path
from typing import Any

from ..core.dais import DAISProgram
from .artifact import audit_artifact
from .diagnostics import DiagnosticReport
from .program import _derive_schedule, check_emission, check_pipeline, check_program
from .steps import check_steps

__all__ = ["DesignVerificationError", "TIERS", "verify_design"]

TIERS = ("off", "cheap", "strict")

_STRUCTURAL = frozenset({"DA001", "DA002", "DA007"})


class DesignVerificationError(RuntimeError):
    """A verification gate found error-severity diagnostics.

    Carries the full :class:`DiagnosticReport` as ``.report``.
    """

    def __init__(self, report: DiagnosticReport, context: str = "design") -> None:
        self.report = report
        errs = report.errors
        head = ", ".join(d.code for d in errs[:4]) + ("…" if len(errs) > 4 else "")
        super().__init__(
            f"{context} failed static verification with {len(errs)} "
            f"error(s) [{head}] — see .report for diagnostics"
        )


def _unpack_programs(design: Any, rep: DiagnosticReport) -> list:
    progs = []
    for i, parr in enumerate(list(getattr(design, "programs", None) or [])):
        if parr is None:
            rep.add(
                "DA013",
                f"program {i} is not int64-packable; its checks are skipped",
                loc={"program": i}, passname="program",
            )
            progs.append(None)
        elif isinstance(parr, DAISProgram):
            progs.append(parr)
        else:
            try:
                progs.append(DAISProgram.from_arrays(parr))
            except Exception as e:
                rep.add(
                    "DA001",
                    f"program {i} arrays do not decode: {type(e).__name__}: {e}",
                    loc={"program": i}, passname="program",
                )
                progs.append(None)
    return progs


def _check_reports(design: Any, progs: list, scheds: list, rep: DiagnosticReport) -> None:
    """Match every LayerReport against some program's re-derived schedule.

    Reports do not name their program (layers deduplicate onto shared
    slots), so each report must be *explained by* at least one program:
    same stage count and FF bill (DA047 when none matches), and cost
    totals consistent with that program plus a bias stage (DA012).
    ``scheds`` holds each program's already-derived ``_derive_schedule``
    result (None where the program was skipped)."""
    reports = list(getattr(design, "reports", None) or [])
    if not reports:
        return
    derived = []
    for p, sched in zip(progs, scheds):
        if p is None or sched is None:
            derived.append(None)
            continue
        n_stages, _, ff = sched
        derived.append((n_stages, ff, p.n_adders, p.cost_bits, p.depth))
    if not any(d is not None for d in derived):
        return
    for k, r in enumerate(reports):
        loc = {"report": k, "layer": getattr(r, "name", f"report{k}")}
        sched = [
            d for d in derived if d is not None and d[0] == r.stages and d[1] == r.ff_bits
        ]
        if not sched:
            rep.add(
                "DA047",
                f"report claims {r.stages} stages / {r.ff_bits} FF bits but no "
                "program's re-derived schedule matches",
                loc=loc, passname="program",
            )
            continue
        # bias adds at most: +n_out adders / one depth level / bias widths
        if not any(
            r.adders >= na and r.cost_bits >= cb and d <= r.depth <= d + 1
            for (_, _, na, cb, d) in sched
        ):
            rep.add(
                "DA012",
                f"report totals (adders={r.adders}, cost_bits={r.cost_bits}, "
                f"depth={r.depth}) are inconsistent with every schedule-matched "
                "program",
                loc=loc, passname="program",
            )


def verify_design(
    design: Any,
    tier: str = "cheap",
    *,
    max_delay_per_stage: int | None = None,
) -> DiagnosticReport:
    """Statically verify a compiled design (or an artifact directory).

    Returns a :class:`DiagnosticReport`; ``report.ok`` is the gate
    predicate (no error-severity findings).  Never raises on findings —
    gate callers (compile, CLI, CI bench) decide how to fail.
    """
    if tier not in TIERS:
        raise ValueError(f"unknown verify tier {tier!r} (expected one of {TIERS})")
    rep = DiagnosticReport(tier=tier)
    if tier == "off":
        return rep

    if isinstance(design, (str, Path)):
        t0 = time.perf_counter()
        rep, loaded = audit_artifact(design, rep)
        rep.pass_wall_s["artifact"] = time.perf_counter() - t0
        if loaded is None:
            return rep
        design = loaded

    cfg = getattr(design, "config", None)
    mdps = max_delay_per_stage
    if mdps is None:
        mdps = getattr(cfg, "max_delay_per_stage", None) or 5

    # -- program pass --------------------------------------------------
    t0 = time.perf_counter()
    progs = _unpack_programs(design, rep)
    structural_ok: list[bool] = []
    scheds: list[tuple | None] = []
    by_prog: dict[int, float] = {}
    for i, p in enumerate(progs):
        tp = time.perf_counter()
        if p is None:
            structural_ok.append(False)
            scheds.append(None)
            continue
        sub = DiagnosticReport()
        check_program(p, sub, program_index=i)
        ok = not any(d.code in _STRUCTURAL for d in sub.errors)
        structural_ok.append(ok)
        rep.extend(sub)
        if ok:
            sched = _derive_schedule(p, mdps)
            check_pipeline(p, mdps, rep, program_index=i, derived=sched)
        else:
            sched = None
        scheds.append(sched)
        by_prog[i] = time.perf_counter() - tp
    _check_reports(
        design, [p if s else None for p, s in zip(progs, structural_ok)], scheds, rep
    )
    rep.pass_wall_s["program"] = time.perf_counter() - t0
    # per-program wall (keyed by program index) for per-layer attribution
    rep.pass_wall_s["program_by_index"] = by_prog

    # -- steps pass ----------------------------------------------------
    t0 = time.perf_counter()
    check_steps(design, rep, programs=progs)
    rep.pass_wall_s["steps"] = time.perf_counter() - t0

    # -- emission audit (strict only: emits + parses every program) ----
    if tier == "strict":
        t0 = time.perf_counter()
        for i, (p, ok) in enumerate(zip(progs, structural_ok)):
            if p is not None and ok:
                check_emission(p, mdps, rep, program_index=i)
        rep.pass_wall_s["emission"] = time.perf_counter() - t0
    return rep
