"""CLI: statically verify saved design artifacts.

    python -m repro.analysis ARTIFACT_DIR [ARTIFACT_DIR ...]
        [--tier cheap|strict] [--json OUT.json] [--quiet]

Runs the artifact auditor plus the program/steps (and, under
``--tier strict``, emission) passes on every directory and prints a
per-artifact summary.  Exit status 1 if any artifact produced an
error-severity diagnostic, 0 otherwise — suitable as a CI gate.
"""

from __future__ import annotations

import argparse
import json
import sys

from .verify import TIERS, verify_design


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Statically verify da4ml-design artifact directories.",
    )
    ap.add_argument("paths", nargs="+", help="artifact directories to verify")
    ap.add_argument(
        "--tier", choices=[t for t in TIERS if t != "off"], default="strict",
        help="verification tier (default: strict)",
    )
    ap.add_argument(
        "--json", metavar="OUT", default=None,
        help="write all diagnostics as one JSON document to OUT ('-' = stdout)",
    )
    ap.add_argument(
        "--quiet", action="store_true",
        help="suppress per-diagnostic lines (summaries only)",
    )
    args = ap.parse_args(argv)

    results = {}
    n_errors = 0
    for path in args.paths:
        rep = verify_design(path, tier=args.tier)
        results[path] = rep.to_dict()
        n_errors += len(rep.errors)
        status = "OK" if rep.ok else "FAIL"
        line = (
            f"{status:<5} {path}  "
            f"({len(rep.errors)} errors, {len(rep.warnings)} warnings, "
            f"tier={args.tier})"
        )
        print(line)
        if not args.quiet:
            for d in rep.diagnostics:
                print(f"    {d}")

    if args.json is not None:
        doc = json.dumps(results, indent=2, sort_keys=True)
        if args.json == "-":
            print(doc)
        else:
            with open(args.json, "w") as f:
                f.write(doc + "\n")
    return 1 if n_errors else 0


if __name__ == "__main__":
    sys.exit(main())
