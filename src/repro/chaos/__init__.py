"""Deterministic fault injection for resilience testing.

See :mod:`repro.chaos.plan` for the fault-site registry and plan spec,
and ``docs/robustness.md`` for how the runtime consumes each site.
"""

from repro.chaos.plan import (
    MODES,
    SITES,
    FaultInjectedError,
    FaultPlan,
    FaultRule,
    ThreadKillFault,
    active,
    fault_point,
    get_plan,
    io_fault,
    plan_from_spec,
    set_plan,
)

__all__ = [
    "MODES",
    "SITES",
    "FaultInjectedError",
    "FaultPlan",
    "FaultRule",
    "ThreadKillFault",
    "active",
    "fault_point",
    "get_plan",
    "io_fault",
    "plan_from_spec",
    "set_plan",
]
