"""Deterministic fault injection for the serve and artifact paths.

The resilience machinery in ``repro.runtime`` (circuit breaker, shard
supervision, deadline shedding, crash-safe artifact commit) is only
trustworthy if every failure mode it claims to handle can be *provoked*
on demand.  This module provides that provocation: a seeded
:class:`FaultPlan` that fires scripted faults at named **sites** woven
through the runtime.

Design constraints (mirrors ``repro.obs.trace``):

* **Zero-cost when disabled.**  Call sites invoke the module-level
  :func:`fault_point`.  With no plan installed this is one global read
  and a ``None`` comparison — no allocation, no lock, no clock read.
  The serve perf gate holds this to the same <1.05x bound as
  ``REPRO_TRACE``.

* **Deterministic and replayable.**  A plan is a list of
  :class:`FaultRule` plus a seed.  Rules can fire on explicit hit
  indices (``at=(0, 3, 7)``) for exact counter assertions, or at a
  probability (``rate=0.2``) drawn from a per-site ``random.Random``
  seeded from ``(seed, site)`` — so the same plan replays the same
  fault schedule regardless of thread interleaving *per site*.

* **Env-driven.**  ``REPRO_CHAOS`` may carry a JSON plan spec (see
  :func:`plan_from_spec`) so chaos runs need no code changes — same
  shape as ``REPRO_TRACE=1`` for tracing.

Fault sites (the registry — keep in sync with ``docs/robustness.md``):

================================  =============================================
site                              effect at the call site
================================  =============================================
``serve.dispatch``                jit dispatch: ``raise`` / ``delay`` / ``hang``
``serve.gather``                  slab gather before dispatch: ``raise``
``serve.dispatcher``              dispatcher loop top: ``kill_thread`` (escapes
                                  the ``except Exception`` guard), ``delay``
``artifact.save.arrays``          npz write: ``raise`` (crash before any commit)
``artifact.save.truncate``        npz tmp file: ``truncate`` (torn write)
``artifact.save.commit``          between npz replace and manifest write:
                                  ``raise`` (crash inside the commit window)
``artifact.load.read``            manifest/npz read: ``raise``
================================  =============================================

Modes: ``raise`` (FaultInjectedError), ``delay`` (sleep ``delay_s``),
``hang`` (sleep ``delay_s``, default 30s — long enough to trip deadlines
and heartbeats, short enough to not wedge a test run), ``kill_thread``
(raise :class:`ThreadKillFault`, a ``BaseException`` that escapes
``except Exception`` guards), ``truncate`` (chop a file in half — only
meaningful at ``io_fault`` sites).
"""

from __future__ import annotations

import json
import os
import threading
import time
import zlib
from collections.abc import Iterator
from contextlib import contextmanager
from dataclasses import dataclass, field
from random import Random

__all__ = [
    "FaultInjectedError",
    "ThreadKillFault",
    "FaultRule",
    "FaultPlan",
    "SITES",
    "MODES",
    "fault_point",
    "io_fault",
    "get_plan",
    "set_plan",
    "active",
    "plan_from_spec",
]

SITES: tuple[str, ...] = (
    "serve.dispatch",
    "serve.gather",
    "serve.dispatcher",
    "artifact.save.arrays",
    "artifact.save.truncate",
    "artifact.save.commit",
    "artifact.load.read",
)

MODES: tuple[str, ...] = ("raise", "delay", "hang", "kill_thread", "truncate")

_HANG_S = 30.0  # "hang" sleeps this long: past any deadline, short of a wedge


class FaultInjectedError(RuntimeError):
    """An injected fault fired at a chaos site (``raise`` mode)."""

    def __init__(self, site: str, hit: int) -> None:
        super().__init__(f"injected fault at {site!r} (hit #{hit})")
        self.site = site
        self.hit = hit


class ThreadKillFault(BaseException):
    """Injected dispatcher-thread death.

    Deliberately a ``BaseException`` subclass so it sails past the
    ``except Exception`` guard around batch execution and kills the
    dispatcher thread itself — the scenario shard supervision exists
    to handle.
    """

    def __init__(self, site: str, hit: int) -> None:
        super().__init__(f"injected thread kill at {site!r} (hit #{hit})")
        self.site = site
        self.hit = hit


@dataclass(frozen=True)
class FaultRule:
    """One scripted fault: *where*, *how*, and *when* to fire.

    Exactly one trigger style per rule:

    * ``at``: explicit zero-based hit indices at the site — fully
      deterministic, for exact counter assertions.
    * ``rate``: independent per-hit probability from the plan's seeded
      per-site RNG — deterministic for a fixed (seed, site, hit order).

    ``after`` skips the first N hits before either trigger applies, and
    ``max_fires`` caps total firings (0 = unlimited) so a breaker can
    observe *recovery* after a burst of failures.
    """

    site: str
    mode: str = "raise"
    rate: float = 0.0
    at: tuple[int, ...] = ()
    after: int = 0
    max_fires: int = 0
    delay_s: float = 0.0

    def __post_init__(self) -> None:
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; known: {SITES}")
        if self.mode not in MODES:
            raise ValueError(f"unknown fault mode {self.mode!r}; known: {MODES}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")
        if self.rate > 0.0 and self.at:
            raise ValueError("give either rate or at, not both")
        object.__setattr__(self, "at", tuple(int(i) for i in self.at))

    def to_dict(self) -> dict:
        return {
            "site": self.site,
            "mode": self.mode,
            "rate": self.rate,
            "at": list(self.at),
            "after": self.after,
            "max_fires": self.max_fires,
            "delay_s": self.delay_s,
        }


@dataclass
class _SiteState:
    """Mutable per-site bookkeeping: hit counter, RNG, fire counts."""

    rng: Random
    hits: int = 0
    fires: dict[int, int] = field(default_factory=dict)  # rule index -> count


class FaultPlan:
    """A seeded, replayable schedule of faults across named sites.

    Thread-safe: the serve path hits sites from many dispatcher threads;
    one plan lock serialises counter updates (the lock is only ever
    taken while a plan is installed, so the disabled path stays free).
    """

    def __init__(self, rules: list[FaultRule] | tuple[FaultRule, ...] = (), seed: int = 0) -> None:
        self.rules: tuple[FaultRule, ...] = tuple(rules)
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._sites: dict[str, _SiteState] = {}
        self._by_site: dict[str, list[tuple[int, FaultRule]]] = {}
        for i, r in enumerate(self.rules):
            self._by_site.setdefault(r.site, []).append((i, r))

    def _state(self, site: str) -> _SiteState:
        st = self._sites.get(site)
        if st is None:
            # per-site RNG keyed on (seed, site): per-site schedules are
            # independent of how other sites interleave
            st = _SiteState(rng=Random((self.seed << 32) ^ zlib.crc32(site.encode())))
            self._sites[site] = st
        return st

    def check(self, site: str) -> tuple[str, int, float] | None:
        """Advance the site's hit counter; return (mode, hit, delay_s) if a rule fires."""
        rules = self._by_site.get(site)
        if not rules:
            return None
        with self._lock:
            st = self._state(site)
            hit = st.hits
            st.hits += 1
            for idx, r in rules:
                if hit < r.after:
                    continue
                n_fired = st.fires.get(idx, 0)
                if r.max_fires and n_fired >= r.max_fires:
                    continue
                if r.at:
                    fire = hit in r.at
                elif r.rate > 0.0:
                    fire = st.rng.random() < r.rate
                else:
                    fire = False
                if fire:
                    st.fires[idx] = n_fired + 1
                    return (r.mode, hit, r.delay_s)
        return None

    def stats(self) -> dict:
        """Hit and fire counts per site — for test assertions and bench JSON."""
        with self._lock:
            out: dict = {"seed": self.seed, "sites": {}}
            for site, st in sorted(self._sites.items()):
                out["sites"][site] = {
                    "hits": st.hits,
                    "fires": sum(st.fires.values()),
                }
            return out

    def reset(self) -> None:
        """Clear all counters and re-seed site RNGs (exact replay)."""
        with self._lock:
            self._sites.clear()

    def to_dict(self) -> dict:
        return {"seed": self.seed, "rules": [r.to_dict() for r in self.rules]}


# ---------------------------------------------------------------------------
# module-level activation (the zero-cost gate)

_PLAN: FaultPlan | None = None


def get_plan() -> FaultPlan | None:
    """The currently installed plan, or None when injection is off."""
    return _PLAN


def set_plan(plan: FaultPlan | None) -> None:
    """Install (or clear, with None) the process-wide fault plan."""
    global _PLAN
    _PLAN = plan


@contextmanager
def active(plan: FaultPlan) -> Iterator[FaultPlan]:
    """Install *plan* for the ``with`` body, restoring the previous plan after."""
    prev = _PLAN
    set_plan(plan)
    try:
        yield plan
    finally:
        set_plan(prev)


def fault_point(site: str) -> None:
    """Maybe fire an injected fault at *site*.

    The disabled path (no plan installed) is a single global read — this
    is the line woven into serve hot paths, so it must stay that cheap.
    """
    plan = _PLAN
    if plan is None:
        return
    fired = plan.check(site)
    if fired is None:
        return
    mode, hit, delay_s = fired
    if mode == "raise":
        raise FaultInjectedError(site, hit)
    if mode == "delay":
        time.sleep(delay_s)
        return
    if mode == "hang":
        time.sleep(delay_s if delay_s > 0.0 else _HANG_S)
        return
    if mode == "kill_thread":
        raise ThreadKillFault(site, hit)
    # "truncate" only makes sense at io_fault sites; at a plain
    # fault_point it degrades to a raise so misconfigurations are loud
    raise FaultInjectedError(site, hit)


def io_fault(site: str, path: str) -> None:
    """Maybe corrupt the file at *path* (torn/truncated write) or raise.

    ``truncate`` mode chops the file to half its size in place —
    simulating a crash mid-write that left a torn artifact on disk.
    Other modes behave as in :func:`fault_point`.
    """
    plan = _PLAN
    if plan is None:
        return
    fired = plan.check(site)
    if fired is None:
        return
    mode, hit, delay_s = fired
    if mode == "truncate":
        size = os.path.getsize(path)
        with open(path, "r+b") as fh:
            fh.truncate(size // 2)
        return
    if mode == "raise":
        raise FaultInjectedError(site, hit)
    if mode in ("delay", "hang"):
        time.sleep(delay_s if delay_s > 0.0 else _HANG_S if mode == "hang" else 0.0)
        return
    raise FaultInjectedError(site, hit)


def plan_from_spec(spec: str | dict) -> FaultPlan:
    """Build a plan from a JSON string or dict.

    Spec shape (also accepted via the ``REPRO_CHAOS`` env var)::

        {"seed": 7, "rules": [
            {"site": "serve.dispatch", "mode": "raise", "rate": 0.1},
            {"site": "artifact.save.truncate", "mode": "truncate", "at": [0]}
        ]}
    """
    doc = json.loads(spec) if isinstance(spec, str) else spec
    rules = [
        FaultRule(
            site=r["site"],
            mode=r.get("mode", "raise"),
            rate=float(r.get("rate", 0.0)),
            at=tuple(r.get("at", ())),
            after=int(r.get("after", 0)),
            max_fires=int(r.get("max_fires", 0)),
            delay_s=float(r.get("delay_s", 0.0)),
        )
        for r in doc.get("rules", ())
    ]
    return FaultPlan(rules, seed=int(doc.get("seed", 0)))


_env_spec = os.environ.get("REPRO_CHAOS", "").strip()
if _env_spec and _env_spec not in ("0", "false", "off"):
    set_plan(plan_from_spec(_env_spec))
