"""Adafactor (Shazeer & Stern 2018), momentum-free, factored second
moment — O(n+m) state for an n x m matrix instead of O(nm).

This is the memory-floor optimizer for the 1T-parameter cells: optimizer
state is ~0.1% of parameter memory for large matrices, vs 800% for f32
Adam.  Tensors of rank >= 2 factor over their last two dims; vectors fall
back to a full second moment.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Any  # row second moments (or full v for rank<2)
    vc: Any  # col second moments (or None placeholders)


def make_adafactor(
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
    min_dim_size_to_factor: int = 16,
):
    def _factored(shape):
        return len(shape) >= 2 and shape[-1] >= min_dim_size_to_factor and shape[-2] >= min_dim_size_to_factor

    def init(params):
        def mk(p):
            if _factored(p.shape):
                return (
                    jnp.zeros(p.shape[:-1], jnp.float32),
                    jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                )
            return (jnp.zeros(p.shape, jnp.float32), None)

        pairs = jax.tree.map(mk, params)
        leaves, treedef = jax.tree.flatten(params)
        flat_pairs = treedef.flatten_up_to(pairs)
        vr = treedef.unflatten([p[0] for p in flat_pairs])
        vc = treedef.unflatten([p[1] for p in flat_pairs])
        return AdafactorState(jnp.zeros((), jnp.int32), vr, vc)

    def update(grads, state: AdafactorState, params, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-0.8)  # the paper's decay schedule

        def upd(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p.shape):
                new_vr = beta2 * vr + (1 - beta2) * g2.mean(axis=-1)
                new_vc = beta2 * vc + (1 - beta2) * g2.mean(axis=-2)
                denom = new_vr.mean(axis=-1, keepdims=True)[..., None]
                precond = (new_vr[..., None] / jnp.maximum(denom, eps)) * new_vc[..., None, :]
                u = g / jnp.sqrt(jnp.maximum(precond, eps))
            else:
                new_vr = beta2 * vr + (1 - beta2) * g2
                new_vc = None
                u = g / jnp.sqrt(jnp.maximum(new_vr, eps))
            # update clipping (RMS(u) <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            pf = p.astype(jnp.float32)
            new_p = pf - lr * u - lr * weight_decay * pf
            return new_p.astype(p.dtype), new_vr, new_vc

        g_leaves, treedef = jax.tree.flatten(grads)
        vr_l = treedef.flatten_up_to(state.vr)
        vc_l = treedef.flatten_up_to(state.vc)
        p_l = treedef.flatten_up_to(params)
        out = [upd(*a) for a in zip(g_leaves, vr_l, vc_l, p_l)]
        return (
            treedef.unflatten([o[0] for o in out]),
            AdafactorState(
                step,
                treedef.unflatten([o[1] for o in out]),
                treedef.unflatten([o[2] for o in out]),
            ),
        )

    return init, update
