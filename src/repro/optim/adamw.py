"""AdamW with mixed-precision master params and optional 8-bit moments.

Functional API:
    init(params)                      -> OptState
    update(grads, state, params, lr)  -> (new_params, new_state)

Memory modes (RunConfig):
  master_dtype="float32"  classic mixed precision: f32 master copy,
                          bf16 working params; moments in f32.
  master_dtype=None       bf16 params are the master (no copy).
  state_dtype="int8"      blockwise-quantized moments (8-bit Adam),
                          ~8x less optimizer HBM than f32.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .quantized_state import Quantized, dequantize, quantize


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any
    master: Any  # f32 master params, or None


def _maybe_q(x, state_dtype, signed):
    if state_dtype == "int8":
        return quantize(x, signed)
    return x


def _maybe_dq(x):
    return dequantize(x) if isinstance(x, Quantized) else x


def make_adamw(
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    master_dtype: str | None = "float32",
    state_dtype: str | None = None,
):
    def init(params):
        zeros = jax.tree.map(
            lambda p: _maybe_q(jnp.zeros(p.shape, jnp.float32), state_dtype, True),
            params,
        )
        zeros_v = jax.tree.map(
            lambda p: _maybe_q(jnp.zeros(p.shape, jnp.float32), state_dtype, False),
            params,
        )
        master = (
            # copy=True: with f32 params astype would alias the param
            # buffer, breaking donation of (params, opt_state) pairs
            jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True), params)
            if master_dtype == "float32"
            else None
        )
        return AdamWState(jnp.zeros((), jnp.int32), zeros, zeros_v, master)

    def update(grads, state: AdamWState, params, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1**t
        c2 = 1.0 - b2**t
        masters = state.master if state.master is not None else params

        def upd(g, m_q, v_q, p, master):
            g = g.astype(jnp.float32)
            m = b1 * _maybe_dq(m_q) + (1 - b1) * g
            v = b2 * _maybe_dq(v_q) + (1 - b2) * g * g
            mh = m / c1
            vh = v / c2
            master_f = master.astype(jnp.float32)
            new_master = master_f - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * master_f)
            return (
                new_master.astype(p.dtype),
                _maybe_q(m, state_dtype, True),
                _maybe_q(v, state_dtype, False),
                new_master if master_dtype == "float32" else None,
            )

        g_leaves, treedef = jax.tree.flatten(grads)
        m_leaves = treedef.flatten_up_to(state.m)
        v_leaves = treedef.flatten_up_to(state.v)
        p_leaves = treedef.flatten_up_to(params)
        ma_leaves = treedef.flatten_up_to(masters)
        out = [
            upd(*args) for args in zip(g_leaves, m_leaves, v_leaves, p_leaves, ma_leaves)
        ]
        new_params = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        new_master = (
            treedef.unflatten([o[3] for o in out]) if master_dtype == "float32" else None
        )
        return new_params, AdamWState(step, new_m, new_v, new_master)

    return init, update
