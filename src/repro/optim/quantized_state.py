"""Blockwise 8-bit optimizer-state compression (8-bit Adam style).

Large-model training at 1T scale cannot afford fp32 (or even bf16) Adam
moments per parameter: int8 moments with per-block fp32 scales cut
optimizer HBM by ~4x vs bf16 and ~8x vs fp32, which is what lets
kimi-k2-1t train on 512 chips (EXPERIMENTS.md §Dry-run).  Blocks are
256 elements over the flattened tensor; m uses symmetric signed scaling,
v (non-negative) uses unsigned scaling.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

BLOCK = 256


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class Quantized:
    q: jnp.ndarray  # int8 payload, [n_blocks, BLOCK]
    scale: jnp.ndarray  # f32 per-block scales
    shape: tuple = field(metadata=dict(static=True))
    signed: bool = field(metadata=dict(static=True))


def _pad_len(n: int) -> int:
    return -(-n // BLOCK) * BLOCK


def quantize(x: jnp.ndarray, signed: bool) -> Quantized:
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    pad = _pad_len(flat.size) - flat.size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    if signed:
        scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    else:
        scale = jnp.max(blocks, axis=1, keepdims=True) / 255.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.round(blocks / scale)
    q = jnp.clip(q, -127 if signed else 0, 127 if signed else 255)
    dtype = jnp.int8 if signed else jnp.uint8
    return Quantized(q.astype(dtype), scale[:, 0], shape, signed)


def dequantize(z: Quantized) -> jnp.ndarray:
    blocks = z.q.astype(jnp.float32) * z.scale[:, None]
    flat = blocks.reshape(-1)
    n = 1
    for s in z.shape:
        n *= s
    return flat[:n].reshape(z.shape)
