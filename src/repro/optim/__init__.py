"""Optimizers with large-scale memory modes (f32 master / bf16 / int8
moments / factored)."""

from __future__ import annotations

import jax.numpy as jnp

from .adafactor import AdafactorState, make_adafactor
from .adamw import AdamWState, make_adamw
from .quantized_state import Quantized, dequantize, quantize


def lr_schedule(run_cfg, step):
    """Linear warmup then cosine decay to 10%."""
    lr, warm = run_cfg.learning_rate, max(run_cfg.warmup_steps, 1)
    t = jnp.asarray(step, jnp.float32) + 1.0  # step 0 trains at lr/warmup
    warmup = lr * jnp.minimum(t / warm, 1.0)
    total = 10000.0
    frac = jnp.clip((t - warm) / (total - warm), 0.0, 1.0)
    cos = 0.1 * lr + 0.9 * lr * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(t < warm, warmup, cos)


def make_optimizer(run_cfg):
    if run_cfg.optimizer == "adafactor":
        return make_adafactor(weight_decay=run_cfg.weight_decay)
    return make_adamw(
        weight_decay=run_cfg.weight_decay,
        master_dtype=run_cfg.master_dtype,
        state_dtype=run_cfg.state_dtype,
    )


__all__ = [
    "AdafactorState",
    "AdamWState",
    "Quantized",
    "dequantize",
    "lr_schedule",
    "make_adafactor",
    "make_adamw",
    "make_optimizer",
    "quantize",
]
