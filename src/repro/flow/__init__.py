"""repro.flow — the one coherent entrypoint for the da4ml pipeline.

Typed configs (importable without jax — stdlib only):

    SolverConfig    one CMVM solve           (repro.core.solve_cmvm)
    CompileConfig   one model compile        (repro.nn.compile_model)
    ServeConfig     one serving deployment   (repro.runtime engine)

Facade (loaded lazily, pulls in jax):

    Flow            Flow.compile / Flow.load / Flow.serve
    Deployment      versioned model rollout over a ServeEngine
    Design          alias of repro.nn.CompiledDesign (save/load methods)

Quickstart::

    from repro.flow import CompileConfig, Flow, ServeConfig, SolverConfig

    design = Flow.compile(model, params, in_shape, in_quant,
                          config=CompileConfig(solver=SolverConfig(dc=2)))
    design.save("artifacts/jet")

    dep = Flow.serve(ServeConfig(max_batch=256))
    dep.register("jet", Design.load("artifacts/jet"))   # -> version 1
    y = dep.infer("jet", x_int)
    dep.register("jet", new_design)                     # v2: flip + drain v1

The facade symbols are exported via module ``__getattr__`` (PEP 562) so
``from repro.flow.config import SolverConfig`` — the import the numpy-only
solver core uses — never drags in jax.
"""

from .config import UNSET, CompileConfig, ConfigError, ServeConfig, SolverConfig

__all__ = [
    "UNSET",
    "CompileConfig",
    "CompiledDesign",
    "ConfigError",
    "Deployment",
    "Design",
    "Flow",
    "ServeConfig",
    "SolverConfig",
]

_LAZY = ("Flow", "Deployment", "Design", "CompiledDesign")


def __getattr__(name: str):
    if name in ("Flow", "Deployment"):
        from . import facade

        return getattr(facade, name)
    if name in ("Design", "CompiledDesign"):
        from ..nn.compiler import CompiledDesign

        return CompiledDesign
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
