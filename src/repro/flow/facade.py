"""The ``repro.flow`` facade: compile once, deploy versioned, serve many.

``Flow`` is the single entrypoint over the three pipeline stages —

    Flow.compile(model, params, in_shape, in_quant, config=CompileConfig())
        -> CompiledDesign            (design.save(path) persists it)
    Flow.load(path)
        -> CompiledDesign            (ms cold start, zero solver calls)
    Flow.serve(ServeConfig())
        -> Deployment                (versioned registry over ServeEngine)

``Deployment`` adds the rollout layer the bare :class:`ServeEngine`
deliberately refuses to provide (its ``register`` rejects duplicate
names): every model name maps to numbered versions, ``register`` of an
existing name creates the next version, flips the serving alias
atomically, and then drains the previous version — queued and in-flight
requests of v1 complete with v1's results while new traffic already
lands on v2.  ``activate`` flips back for rollback when old versions are
kept alive (``drain=False``).
"""

from __future__ import annotations

import threading
from pathlib import Path

import numpy as np

from ..nn.compiler import CompiledDesign, _compile_model
from ..runtime.engine import EngineClosedError, ServeEngine
from .config import CompileConfig, ServeConfig

__all__ = ["Deployment", "Flow"]


class Flow:
    """Facade over compile -> artifact -> serve (all methods static)."""

    @staticmethod
    def compile(
        model,
        params,
        in_shape,
        in_quant,
        config: CompileConfig | None = None,
    ) -> CompiledDesign:
        """Compile a quantized model into a bit-exact integer design.

        Equivalent to ``repro.nn.compile_model(..., config=config)`` —
        the two paths share one implementation, so designs are
        bit-identical however they are built.
        """
        return _compile_model(
            model, params, in_shape, in_quant, config or CompileConfig()
        )

    @staticmethod
    def load(
        path: str | Path, verify: str = "off", on_corrupt: str = "raise"
    ) -> CompiledDesign:
        """Load a ``design.save(path)`` artifact (zero solver calls).

        ``verify`` runs the static verifier on the loaded design
        ("off" default, "cheap", "strict"); error-severity findings
        raise :class:`repro.analysis.DesignVerificationError`.

        Torn/truncated/mixed-generation artifacts raise
        :class:`repro.runtime.ArtifactCorruptError`;
        ``on_corrupt="quarantine"`` first renames the damaged directory
        to ``<name>.quarantined`` so a sweep over an artifact store can
        catch, log, and continue.
        """
        from ..runtime.artifact import load_design

        return load_design(path, verify=verify, on_corrupt=on_corrupt)

    @staticmethod
    def verify(design_or_path, tier: str = "strict"):
        """Statically verify a compiled design or artifact directory.

        Returns a :class:`repro.analysis.DiagnosticReport` (never raises
        on findings; check ``report.ok`` / ``report.errors``).  Artifact
        paths additionally run the artifact auditor before the program
        and step passes.
        """
        from ..analysis import verify_design

        return verify_design(design_or_path, tier=tier)

    @staticmethod
    def serve(
        config: ServeConfig | None = None,
        models: dict | None = None,
        warmup: bool = False,
    ) -> "Deployment":
        """Create a :class:`Deployment`; optionally register ``models``
        (name -> design or artifact path) as version 1 each."""
        dep = Deployment(config)
        for name, design in (models or {}).items():
            dep.register(name, design, warmup=warmup)
        return dep


class Deployment:
    """Versioned model registry + serving alias over a :class:`ServeEngine`.

    Each registered design gets an engine entry ``{name}@v{version}``;
    ``name`` is a serving *alias* pointing at the active version.  The
    rollout sequence of ``register`` on an existing name is:

      1. register v_new (optionally warmed up) next to v_old;
      2. flip the alias to v_new atomically (new submits land on v_new);
      3. drain v_old: its dispatcher finishes queued and in-flight
         requests — their futures complete with v_old's results — then
         shuts down (skipped with ``drain=False``, keeping v_old around
         for ``activate``-based rollback).
    """

    def __init__(
        self,
        config: ServeConfig | None = None,
        engine: ServeEngine | None = None,
        drain_timeout: float = 30.0,
    ):
        if engine is not None and config is not None:
            raise ValueError("pass either config= or an existing engine=, not both")
        self.engine = engine if engine is not None else ServeEngine(config=config or ServeConfig())
        self.config = self.engine.config
        # how long a retired version may take to finish its queued work
        # before remaining requests are failed loudly
        self.drain_timeout = drain_timeout
        self._lock = threading.Lock()
        # name -> {version: engine key}; None marks a registration in flight
        self._versions: dict[str, dict[int, str | None]] = {}
        self._active: dict[str, int] = {}

    # -- registry ------------------------------------------------------
    @staticmethod
    def _key(name: str, version: int) -> str:
        return f"{name}@v{version}"

    def register(
        self,
        name: str,
        design: CompiledDesign | str | Path,
        version: int | None = None,
        warmup: bool = False,
        drain: bool = True,
    ) -> int:
        """Register ``design`` (or an artifact path) as a version of
        ``name`` and make it the active one.  Returns the version number.

        ``version=None`` auto-increments; an explicit duplicate version
        raises ``ValueError``.  See the class docstring for the rollout
        sequence; ``drain=False`` keeps the previous version serving its
        engine key (for rollback via :meth:`activate`).
        """
        with self._lock:
            vers = self._versions.setdefault(name, {})
            if version is None:
                version = max(vers, default=0) + 1
            elif version in vers:
                raise ValueError(
                    f"model {name!r} version {version} already registered"
                )
            vers[version] = None  # reserve against concurrent registers
        key = self._key(name, version)
        try:
            self.engine.register(key, design, warmup=warmup)
        except BaseException:
            with self._lock:
                vers = self._versions.get(name)
                if vers is not None:
                    vers.pop(version, None)
                    if not vers:
                        del self._versions[name]
            raise
        with self._lock:
            # setdefault: a concurrent whole-model unregister may have
            # dropped the map; this register then (re)creates the model
            self._versions.setdefault(name, {})[version] = key
            old = self._active.get(name)
            self._active[name] = version  # atomic alias flip
        if drain and old is not None and old != version:
            self._retire(name, old)
        return version

    def _retire(self, name: str, version: int) -> None:
        """Drain and drop one version (its queued/in-flight futures
        complete before the dispatcher stops, bounded by
        ``drain_timeout``)."""
        with self._lock:
            key = self._versions.get(name, {}).pop(version, None)
        if key is not None:
            self.engine.unregister(key, timeout=self.drain_timeout)

    def activate(self, name: str, version: int) -> None:
        """Flip the serving alias to an already-registered version
        (rollback path for ``register(..., drain=False)``)."""
        with self._lock:
            if self._versions.get(name, {}).get(version) is None:
                raise KeyError(f"model {name!r} has no live version {version}")
            self._active[name] = version

    def unregister(self, name: str, version: int | None = None) -> None:
        """Drop one version, or the whole model (all versions + alias)."""
        if version is not None:
            with self._lock:
                if self._active.get(name) == version:
                    del self._active[name]
            self._retire(name, version)
            return
        # claim the whole version map atomically so a concurrent
        # register of the same name starts a fresh history instead of
        # being clobbered (and no engine runner can leak untracked)
        with self._lock:
            vers = self._versions.pop(name, {})
            self._active.pop(name, None)
        for _, key in sorted(vers.items()):
            if key is not None:
                self.engine.unregister(key, timeout=self.drain_timeout)

    def models(self) -> list[str]:
        with self._lock:
            return sorted(self._active)

    def versions(self, name: str) -> list[int]:
        """Live versions of ``name`` (drained versions drop out)."""
        with self._lock:
            return sorted(
                v for v, k in self._versions.get(name, {}).items() if k is not None
            )

    def active_version(self, name: str) -> int:
        with self._lock:
            try:
                return self._active[name]
            except KeyError:
                raise KeyError(f"model {name!r} has no active version") from None

    def _active_key(self, name: str) -> str:
        with self._lock:
            try:
                return self._versions[name][self._active[name]]
            except KeyError:
                raise KeyError(f"model {name!r} has no active version") from None

    def _on_active(self, name: str, call):
        """Resolve the alias and call the engine, re-resolving if the
        version was retired between the two steps (a submit racing a
        rollout must land on the new version, not KeyError — and a
        submit that reached the old runner just as it closed gets
        EngineClosedError from the engine, which is the same race one
        step later, so it retries onto the new version too)."""
        for _ in range(8):
            key = self._active_key(name)
            try:
                return call(key)
            except KeyError:
                continue  # alias flipped and the old runner drained mid-call
            except EngineClosedError:
                continue  # runner grabbed just before its drain closed it
        raise KeyError(f"model {name!r}: active version kept changing; giving up")

    # -- serving (alias-resolved passthrough) --------------------------
    def submit(self, name: str, x: np.ndarray, deadline_s: float | None = None):
        return self._on_active(
            name, lambda key: self.engine.submit(key, x, deadline_s=deadline_s)
        )

    def submit_batch(self, name: str, xs, deadline_s: float | None = None) -> list:
        return self._on_active(
            name, lambda key: self.engine.submit_batch(key, xs, deadline_s=deadline_s)
        )

    def infer(
        self,
        name: str,
        x: np.ndarray,
        timeout: float | None = 30.0,
        deadline_s: float | None = None,
    ):
        return self._on_active(
            name,
            lambda key: self.engine.infer(key, x, timeout, deadline_s=deadline_s),
        )

    def warmup(self, name: str) -> float:
        return self._on_active(name, self.engine.warmup)

    def stats(self, name: str | None = None) -> dict:
        """Per-model stats of the *active* version (annotated with the
        version number), or all models when ``name`` is None."""
        if name is not None:
            s = self._on_active(name, self.engine.stats)
            s["version"] = self.active_version(name)
            s["model"] = name
            return s
        return {n: self.stats(n) for n in self.models()}

    def metrics_text(self) -> str:
        """Prometheus text exposition over every live engine entry
        (``{name}@v{version}`` keys; see ``ServeEngine.metrics_text``)."""
        return self.engine.metrics_text()

    # -- lifecycle -----------------------------------------------------
    def shutdown(self, timeout: float = 5.0) -> None:
        self.engine.shutdown(timeout)
        with self._lock:
            self._versions.clear()
            self._active.clear()

    def __enter__(self) -> "Deployment":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
