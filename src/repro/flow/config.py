"""Typed configuration objects for the ``repro.flow`` API.

One frozen dataclass per pipeline stage:

    SolverConfig    options of one CMVM solve (repro.core.solve_cmvm)
    CompileConfig   options of one model compile (repro.nn.compile_model),
                    nesting a SolverConfig
    ServeConfig     options of one serving deployment (repro.runtime /
                    repro.flow.Deployment)

Each config validates on construction, round-trips through
``to_dict``/``from_dict`` (plain JSON-serializable values), and exposes a
stable content ``digest()`` — a sha256 over a versioned canonical JSON
form.  ``SolutionCache`` keys and design-artifact manifests derive from
these digests, so "same config" has exactly one definition across the
solver cache, the compiler, and the artifact store (instead of each
layer hashing its own ad-hoc kwarg tuple).

Runtime-only fields that cannot affect the produced design — the live
``cache`` handle and the ``jobs`` parallelism of ``CompileConfig`` — are
excluded from ``to_dict``/``digest`` (``jobs`` is serialized but not
digested; ``cache`` is neither).

This module is importable without jax or numpy (stdlib only), so the
solver's process-pool workers and numpy-only benches can use it freely.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import warnings
from dataclasses import dataclass, field
from collections.abc import Callable
from typing import Any, ClassVar

_DIGEST_VERSION = "da4ml-flow-config-v1"


class _Unset:
    """Sentinel for legacy-kwarg shims (distinguishes "not passed" from
    an explicit default).  Singleton; reprs as ``UNSET`` so shimmed
    signatures stay readable (and API-snapshot stable)."""

    _instance: "_Unset" | None = None

    def __new__(cls) -> "_Unset":
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:
        return "UNSET"


UNSET = _Unset()


class ConfigError(ValueError):
    """Invalid configuration value."""


def resolve_legacy(
    api: str,
    config: "_ConfigBase" | None,
    legacy: dict,
    config_cls: type,
    build: Callable[[dict], "_ConfigBase"],
) -> "_ConfigBase":
    """Shared deprecation-shim logic for the legacy-kwarg entrypoints
    (``solve_cmvm``, ``compile_model``, ``ServeEngine``).

    ``legacy`` holds the explicitly-passed legacy kwargs (UNSET values
    filtered by the caller).  Passing both spellings is a loud
    ``TypeError``; the legacy spelling warns ``DeprecationWarning`` once
    per call site; ``build(legacy)`` constructs the equivalent config.
    A ``config`` of the wrong type is rejected here so mix-ups like
    ``Flow.compile(..., config=SolverConfig(...))`` fail with a named
    error instead of an opaque AttributeError downstream.
    """
    if config is not None:
        if legacy:
            raise TypeError(
                f"{api}: pass either config= or the legacy option kwargs "
                f"({sorted(legacy)}), not both"
            )
        if not isinstance(config, config_cls):
            raise ConfigError(
                f"{api}: config must be a {config_cls.__name__}, "
                f"got {type(config).__name__}"
            )
        return config
    if legacy:
        warnings.warn(
            f"{api}'s option kwargs are deprecated; pass "
            f"config=repro.flow.{config_cls.__name__}(...) instead",
            DeprecationWarning,
            stacklevel=3,  # helper -> shim -> caller
        )
    return build(legacy)


@dataclass(frozen=True)
class _ConfigBase:
    # subclass knobs (ClassVar: not dataclass fields)
    _RUNTIME_ONLY: ClassVar[tuple] = ()  # excluded from to_dict AND digest
    _DIGEST_EXCLUDE: ClassVar[tuple] = ()  # in to_dict but excluded from digest
    _NESTED: ClassVar[dict] = {}  # field name -> nested config class

    def to_dict(self) -> dict:
        """Plain JSON-serializable dict (drops runtime-only fields)."""
        out: dict = {}
        for f in dataclasses.fields(self):
            if f.name in self._RUNTIME_ONLY:
                continue
            v = getattr(self, f.name)
            if isinstance(v, _ConfigBase):
                v = v.to_dict()
            elif isinstance(v, tuple):
                v = list(v)
            out[f.name] = v
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "_ConfigBase":
        """Inverse of :meth:`to_dict`; rejects unknown keys."""
        if not isinstance(d, dict):
            raise ConfigError(f"{cls.__name__}.from_dict expects a dict, got {type(d).__name__}")
        names = {f.name for f in dataclasses.fields(cls)} - set(cls._RUNTIME_ONLY)
        unknown = set(d) - names
        if unknown:
            raise ConfigError(f"{cls.__name__}: unknown config keys {sorted(unknown)}")
        kw = dict(d)
        for name, sub in cls._NESTED.items():
            if name in kw and isinstance(kw[name], dict):
                kw[name] = sub.from_dict(kw[name])
        return cls(**kw)

    def digest(self) -> str:
        """sha256 content digest of the config identity (stable across
        processes; changes iff a digested field changes)."""
        d = self.to_dict()
        for name in self._DIGEST_EXCLUDE:
            d.pop(name, None)
        payload = json.dumps(
            [_DIGEST_VERSION, type(self).__name__, d], sort_keys=True, separators=(",", ":")
        )
        return hashlib.sha256(payload.encode()).hexdigest()

    def replace(self, **changes: Any) -> "_ConfigBase":
        """Functional update (configs are frozen)."""
        return dataclasses.replace(self, **changes)

    def _require(self, cond: bool, msg: str) -> None:
        if not cond:
            raise ConfigError(f"{type(self).__name__}: {msg}")


@dataclass(frozen=True)
class SolverConfig(_ConfigBase):
    """Options of one CMVM solve (``y = x @ M`` -> DAIS adder graph).

    dc            delay constraint: extra adder-depth levels allowed
                  beyond each output's minimum (-1 = unconstrained).
    engine        CSE frequency engine: "batch" (vectorized, default),
                  "arena" (preallocated-workspace fast path), or "heap"
                  (exact lazy max-heap reference); all bit-identical.
                  The engine is part of the config digest, so solution-
                  cache keys and artifact manifests distinguish engines.
    decompose     enable stage-1 graph decomposition (M = M1 @ M2).
    weighted      weight CSE pair scores by operand width.
    dedup         deduplicate identical terms during assembly.
    depth_weight  depth penalty mixed into the CSE score (0 = off).
    """

    dc: int = -1
    engine: str = "batch"
    decompose: bool = True
    weighted: bool = True
    dedup: bool = True
    depth_weight: float = 0.0

    def __post_init__(self) -> None:
        self._require(isinstance(self.dc, int) and self.dc >= -1, f"dc must be >= -1, got {self.dc}")
        self._require(
            self.engine in ("batch", "heap", "arena"),
            f"unknown CSE engine {self.engine!r} "
            "(expected 'batch', 'heap', or 'arena')",
        )
        self._require(
            isinstance(self.depth_weight, (int, float)) and self.depth_weight >= 0.0,
            f"depth_weight must be >= 0, got {self.depth_weight}",
        )


def _default_compile_solver() -> SolverConfig:
    # compile_model's historical default is dc=2 (vs the solver-level
    # default dc=-1 used for the paper's unconstrained tables)
    return SolverConfig(dc=2)


@dataclass(frozen=True)
class CompileConfig(_ConfigBase):
    """Options of one model compile (``repro.nn.compile_model``).

    strategy             "da" (CMVM solver) or "latency" (per-output CSD
                         trees, the hls4ml latency-strategy baseline).
    max_delay_per_stage  pipelining budget per register stage.
    use_pallas           execute CMVMs through the Pallas adder-graph
                         kernel instead of the jnp gather executor.
    jobs                 solver thread-pool width (None = cpu_count,
                         1 = in-line serial); never changes the bits —
                         serial fallbacks are recorded loudly in
                         ``solver_stats["pool_fallback"]``.
    cache                optional live ``SolutionCache`` handle; runtime
                         only — excluded from to_dict/digest.
    solver               nested :class:`SolverConfig` (default dc=2).
    verify               static-verification tier run on every compiled
                         design ("off", "cheap", "strict"; default
                         "cheap" — see repro.analysis).  Error-severity
                         findings fail the compile loudly.  Never changes
                         the produced bits, so it is excluded from the
                         config digest (like ``jobs``).
    """

    _RUNTIME_ONLY: ClassVar[tuple] = ("cache",)
    _DIGEST_EXCLUDE: ClassVar[tuple] = ("jobs", "verify")
    _NESTED: ClassVar[dict] = {"solver": SolverConfig}

    strategy: str = "da"
    max_delay_per_stage: int = 5
    use_pallas: bool = False
    jobs: int | None = None
    cache: Any | None = None
    solver: SolverConfig = field(default_factory=_default_compile_solver)
    verify: str = "cheap"

    def __post_init__(self) -> None:
        self._require(
            self.strategy in ("da", "latency"),
            f"unknown strategy {self.strategy!r} (expected 'da' or 'latency')",
        )
        self._require(
            isinstance(self.max_delay_per_stage, int) and self.max_delay_per_stage >= 1,
            f"max_delay_per_stage must be >= 1, got {self.max_delay_per_stage}",
        )
        self._require(
            self.jobs is None or (isinstance(self.jobs, int) and self.jobs >= 1),
            f"jobs must be None or >= 1, got {self.jobs}",
        )
        self._require(
            isinstance(self.solver, SolverConfig),
            f"solver must be a SolverConfig, got {type(self.solver).__name__}",
        )
        self._require(
            self.cache is None or (hasattr(self.cache, "get") and hasattr(self.cache, "put")),
            "cache must be None or a SolutionCache-like object with get/put",
        )
        self._require(
            self.verify in ("off", "cheap", "strict"),
            f"unknown verify tier {self.verify!r} "
            "(expected 'off', 'cheap', or 'strict')",
        )


@dataclass(frozen=True)
class ServeConfig(_ConfigBase):
    """Options of one serving deployment (microbatched engine).

    max_batch     largest microbatch (and largest jit shape bucket).
    max_wait_us   batching window after the first queued request.
    queue_depth   bounded per-model request queue (backpressure limit;
                  divided across shards).
    backpressure  "block" (submit waits for queue space) or "reject"
                  (submit raises / fails the future with QueueFullError).
    buckets       explicit batch-shape buckets (None: powers of two up
                  to max_batch); the largest bucket must cover max_batch.
    shards        dispatch shards per model: each shard is one request
                  queue + payload slab + dispatcher thread behind the
                  shared submit path (1 = the single-dispatcher engine).

    Resilience knobs (see docs/robustness.md):

    deadline_ms   default per-request deadline: requests not dispatched
                  within this budget are *shed* — failed with
                  DeadlineExceededError instead of executed (None: no
                  default; per-call ``deadline_s`` always wins).
    fallback      degraded mode while the circuit breaker is open:
                  "none" fails fast with CircuitOpenError; "interpreter"
                  serves batches through the bit-exact numpy StepSpec
                  interpreter (correct answers at reduced throughput).
    breaker_threshold      consecutive dispatch failures that trip the
                  per-model breaker (closed -> open).
    breaker_cooldown_ms    initial open-state cooldown before a single
                  half-open probe; doubles on every failed probe.
    breaker_cooldown_max_ms  cap on the exponential cooldown backoff.
    supervise     run a per-model supervisor thread that detects dead
                  dispatcher threads and restarts them.
    restart_budget  dispatcher restarts allowed per shard before the
                  model is escalated to unhealthy (submits then fail
                  with ModelUnhealthyError).
    """

    max_batch: int = 256
    max_wait_us: float = 200.0
    queue_depth: int = 8192
    backpressure: str = "block"
    buckets: tuple | None = None
    shards: int = 1
    deadline_ms: float | None = None
    fallback: str = "none"
    breaker_threshold: int = 8
    breaker_cooldown_ms: float = 250.0
    breaker_cooldown_max_ms: float = 8000.0
    supervise: bool = True
    restart_budget: int = 2

    def __post_init__(self) -> None:
        self._require(
            isinstance(self.max_batch, int) and self.max_batch >= 1,
            f"max_batch must be >= 1, got {self.max_batch}",
        )
        self._require(
            isinstance(self.max_wait_us, (int, float)) and self.max_wait_us >= 0,
            f"max_wait_us must be >= 0, got {self.max_wait_us}",
        )
        self._require(
            isinstance(self.queue_depth, int) and self.queue_depth >= 1,
            f"queue_depth must be >= 1, got {self.queue_depth}",
        )
        self._require(
            self.backpressure in ("block", "reject"),
            f"backpressure must be 'block' or 'reject', got {self.backpressure!r}",
        )
        self._require(
            isinstance(self.shards, int) and self.shards >= 1,
            f"shards must be >= 1, got {self.shards}",
        )
        self._require(
            self.deadline_ms is None
            or (isinstance(self.deadline_ms, (int, float)) and self.deadline_ms > 0),
            f"deadline_ms must be None or > 0, got {self.deadline_ms}",
        )
        self._require(
            self.fallback in ("none", "interpreter"),
            f"fallback must be 'none' or 'interpreter', got {self.fallback!r}",
        )
        self._require(
            isinstance(self.breaker_threshold, int) and self.breaker_threshold >= 1,
            f"breaker_threshold must be >= 1, got {self.breaker_threshold}",
        )
        self._require(
            isinstance(self.breaker_cooldown_ms, (int, float))
            and self.breaker_cooldown_ms > 0,
            f"breaker_cooldown_ms must be > 0, got {self.breaker_cooldown_ms}",
        )
        self._require(
            isinstance(self.breaker_cooldown_max_ms, (int, float))
            and self.breaker_cooldown_max_ms >= self.breaker_cooldown_ms,
            "breaker_cooldown_max_ms must be >= breaker_cooldown_ms, got "
            f"{self.breaker_cooldown_max_ms}",
        )
        self._require(
            isinstance(self.supervise, bool),
            f"supervise must be a bool, got {self.supervise!r}",
        )
        self._require(
            isinstance(self.restart_budget, int) and self.restart_budget >= 0,
            f"restart_budget must be >= 0, got {self.restart_budget}",
        )
        if self.buckets is not None:
            buckets = tuple(sorted(int(b) for b in self.buckets))
            self._require(
                len(buckets) > 0 and all(b >= 1 for b in buckets),
                f"buckets must be positive ints, got {self.buckets!r}",
            )
            self._require(
                buckets[-1] >= self.max_batch,
                f"largest bucket ({buckets[-1]}) must cover max_batch ({self.max_batch})",
            )
            object.__setattr__(self, "buckets", buckets)
