"""Compiled-design artifacts: solve once, cold-start in milliseconds.

A ``CompiledDesign`` is the product of multi-second CMVM solves, but its
execution pipeline is fully determined by plain integer data: the packed
DAIS program of every unique CMVM (``DAISProgram.to_arrays``), the
bias / pre-shift / requant arrays of each step, the step topology, and
the quantization metadata.  ``save_design`` persists exactly that — a
single no-pickle ``design.npz`` plus a human-readable ``manifest.json``
(format ``da4ml-design`` v1) — and ``load_design`` rebuilds a design
whose ``forward_int`` is bit-identical to the one that was saved, with
**zero** solver calls (``solver_stats["n_solves"] == 0``).

The loader reconstructs the instruction tables with ``compile_tables``
(deterministic) and the executable steps through the same
``repro.nn.compiler.build_steps`` builder the compiler itself uses, so
there is no separate "deserialized" execution path to drift.  Rebuilt
tables carry the same content digest as the originals, so a process that
already jitted a design reuses its XLA executables for the loaded copy.

Layout of ``<path>/``:

    manifest.json   format/version, in/out shapes, quantization, step
                    topology (arrays referenced by npz key), per-layer
                    resource reports, compile-time solver stats.
    design.npz      all integer arrays (programs, biases, shifts,
                    requant deltas, output qints), int64, no pickle.

Crash safety: ``save_design`` commits in order — arrays first, manifest
last — with each file written to a temp name, fsync'd, atomically
renamed into place, and the directory fsync'd after each rename.  The
manifest (which binds the arrays by content digest) is therefore the
commit record: a crash at any point leaves either the previous complete
artifact or a stray temp file, never a manifest pointing at missing or
torn arrays.  ``load_design`` maps every torn/truncated/mixed-generation
shape to :class:`ArtifactCorruptError` (a ``ValueError``) and can
optionally quarantine the corrupt directory aside so a cold-start sweep
over an artifact store survives one bad entry.
"""

from __future__ import annotations

import hashlib
import json
import os
import time
import zipfile
from dataclasses import asdict
from pathlib import Path

import numpy as np

from ..chaos import fault_point, io_fault

from ..core.dais import DAISProgram, qints_from_array, qints_to_array
from ..flow.config import CompileConfig
from ..kernels.adder_graph import compile_tables
from ..nn.compiler import CompiledDesign, LayerReport, StepSpec, build_steps
from ..nn.quant import QuantConfig

FORMAT_NAME = "da4ml-design"
FORMAT_VERSION = 1
_PROGRAM_KEYS = ("rows", "outputs", "n_inputs")


class ArtifactCorruptError(ValueError):
    """The artifact directory exists but its contents are damaged —
    truncated/torn ``design.npz``, unparsable ``manifest.json``, a
    manifest whose content digest does not match the arrays
    (mixed-generation), or arrays missing keys the manifest references.

    Subclasses ``ValueError`` so callers that guarded loads with the
    historical ``except ValueError`` keep working.  When
    ``load_design(..., on_corrupt="quarantine")`` moved the directory
    aside, the destination is recorded on ``quarantined_to``.
    """

    def __init__(self, message: str, quarantined_to: Path | None = None):
        super().__init__(message)
        self.quarantined_to = quarantined_to


def _fsync_replace(tmp: Path, dst: Path) -> None:
    """fsync ``tmp``, rename it over ``dst``, fsync the directory.

    The file fsync makes the rename publish *complete* contents; the
    directory fsync makes the rename itself durable, so a crash cannot
    reorder "manifest committed" before "arrays durable"."""
    with open(tmp, "rb") as fh:
        os.fsync(fh.fileno())
    tmp.replace(dst)
    dfd = os.open(dst.parent, os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


def _arrays_digest(arrays: dict[str, np.ndarray]) -> str:
    """Content hash binding manifest.json to its design.npz.

    The two files are replaced individually; a crash between the two
    replaces could pair a stale manifest with fresh arrays (the npz key
    names repeat across saves, so the mix would load without error).
    The manifest stores this digest and the loader recomputes it, so a
    mixed-generation artifact fails loudly instead of mis-executing."""
    h = hashlib.sha256(b"da4ml-design-arrays-v1")
    for k in sorted(arrays):
        h.update(k.encode())
        h.update(np.ascontiguousarray(arrays[k]).tobytes())
    return h.hexdigest()


def _sanitize(obj):
    """Keep only JSON-serializable scalars (recursively) from a stats dict."""
    if isinstance(obj, dict):
        out = {}
        for k, v in obj.items():
            s = _sanitize(v)
            if s is not None:
                out[str(k)] = s
        return out
    if isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    return None


def save_design(design: CompiledDesign, path: str | Path) -> Path:
    """Persist a compiled design to ``path`` (a directory, created).

    Raises ``ValueError`` if any of the design's DAIS programs could not
    be packed into int64 arrays (interval endpoints beyond int64 — not
    reachable for realistic quantized networks).
    """
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    arrays: dict[str, np.ndarray] = {}

    for i, parr in enumerate(design.programs):
        if parr is None:
            raise ValueError(
                f"program {i} is not int64-serializable; design cannot be saved"
            )
        for k in _PROGRAM_KEYS:
            arrays[f"prog{i}_{k}"] = parr[k]

    counter = iter(range(1 << 30))

    def spec_json(s: StepSpec) -> dict:
        entry: dict = {"kind": s.kind, "params": s.params, "table": s.table}
        refs: dict[str, str] = {}
        for name, arr in s.arrays.items():
            key = f"step{next(counter)}_{name}"
            arrays[key] = np.asarray(arr, np.int64)
            refs[name] = key
        entry["arrays"] = refs
        if s.body is not None:
            entry["body"] = [spec_json(b) for b in s.body]
        return entry

    steps_json = [spec_json(s) for s in design.step_specs]
    try:
        arrays["out_qints"] = qints_to_array(design.out_qints)
    except OverflowError as e:
        raise ValueError(f"output qints not int64-serializable: {e}") from e

    assert design.in_quant is not None, "design must carry its input quantization"
    manifest = {
        "format": FORMAT_NAME,
        "version": FORMAT_VERSION,
        "arrays_sha256": _arrays_digest(arrays),
        "in_quant": {
            "bits": design.in_quant.bits,
            "int_bits": design.in_quant.int_bits,
            "signed": design.in_quant.signed,
        },
        "in_shape": list(design.in_shape),
        "out_shape": list(design.out_shape),
        "use_pallas": bool(design.use_pallas),
        "n_programs": len(design.programs),
        "steps": steps_json,
        # the typed CompileConfig that produced the design: round-trips
        # through load_design, and its content digest gives artifacts a
        # config identity (same definition the SolutionCache keys use)
        "compile_config": (
            design.config.to_dict() if design.config is not None else None
        ),
        "compile_config_digest": (
            design.config.digest() if design.config is not None else None
        ),
        "reports": [asdict(r) for r in design.reports],
        "solver_stats": _sanitize(design.solver_stats),
        # rule4ml-style per-design resource summary for downstream tooling
        "resources": {
            "total_adders": design.total_adders,
            "total_cost_bits": design.total_cost_bits,
            "total_ff_bits": design.total_ff_bits,
            "latency_cycles": design.latency_cycles,
            "max_depth": design.max_depth,
        },
    }

    # ordered commit: arrays first, manifest (the commit record) last.
    # Each step is write-temp -> fsync -> rename -> fsync-dir, so a
    # crash anywhere leaves the previous complete artifact (or a stray
    # *.tmp.* the next save overwrites), never a manifest that points
    # at missing or torn arrays.  The chaos fault points let
    # tests/test_chaos.py provoke every interleaving.
    tmp = path / "design.tmp.npz"
    fault_point("artifact.save.arrays")
    np.savez_compressed(tmp, **arrays)
    io_fault("artifact.save.truncate", tmp)  # simulated torn write
    _fsync_replace(tmp, path / "design.npz")
    fault_point("artifact.save.commit")  # crash between arrays and commit
    tmp_manifest = path / "manifest.tmp.json"
    tmp_manifest.write_text(json.dumps(manifest, indent=2, sort_keys=True))
    _fsync_replace(tmp_manifest, path / "manifest.json")
    return path


def _quarantine(path: Path) -> Path:
    """Rename a corrupt artifact directory aside (``<name>.quarantined``,
    numeric suffix on collision) so a cold-start sweep can continue past
    it while keeping the evidence for a postmortem."""
    dst = path.with_name(path.name + ".quarantined")
    n = 1
    while dst.exists():
        dst = path.with_name(f"{path.name}.quarantined.{n}")
        n += 1
    path.rename(dst)
    return dst


def _corrupt(path: Path, message: str, on_corrupt: str) -> ArtifactCorruptError:
    """Build (and, if asked, quarantine for) a corruption error."""
    quarantined_to = None
    if on_corrupt == "quarantine":
        try:
            quarantined_to = _quarantine(path)
            message += f" (quarantined to {quarantined_to})"
        except OSError:
            pass  # read-only store: still raise the typed error
    return ArtifactCorruptError(message, quarantined_to=quarantined_to)


def load_design(
    path: str | Path, verify: str = "off", on_corrupt: str = "raise"
) -> CompiledDesign:
    """Rebuild a compiled design from a ``save_design`` artifact.

    Cold-starts in milliseconds: no CMVM solves run; instruction tables
    are recompiled from the packed DAIS programs and the executable
    steps come from the shared ``build_steps`` builder, so the result is
    bit-identical to the design that was saved.

    ``verify`` ("off" default / "cheap" / "strict") runs the static
    verifier (:mod:`repro.analysis`) on the rebuilt design; error-
    severity findings raise ``DesignVerificationError``.  Default off:
    the digest check above already guards integrity, and artifact loads
    sit on serving cold-start paths.

    Damage — torn/truncated ``design.npz``, unparsable or missing-but-
    committed ``manifest.json``, digest mismatch, dangling array refs —
    raises :class:`ArtifactCorruptError` (a ``ValueError``).  A wrong
    *format* or *version* stays a plain ``ValueError``: the file is
    intact, it just isn't ours.  ``on_corrupt`` ("raise" default /
    "quarantine") controls what happens first: "quarantine" renames the
    corrupt directory to ``<name>.quarantined`` (recorded on the
    error's ``quarantined_to``) so a sweep over an artifact store can
    catch, log, and continue without tripping on the same entry twice.
    """
    if on_corrupt not in ("raise", "quarantine"):
        raise ValueError(f"on_corrupt must be 'raise' or 'quarantine', got {on_corrupt!r}")
    t0 = time.perf_counter()
    path = Path(path)
    fault_point("artifact.load.read")
    try:
        manifest_text = (path / "manifest.json").read_text()
    except FileNotFoundError:
        if (path / "design.npz").exists():
            # arrays landed but the commit record didn't: an interrupted
            # save, indistinguishable from corruption for the loader
            raise _corrupt(
                path,
                f"{path}: design.npz present but manifest.json missing "
                "(interrupted save; artifact never committed)",
                on_corrupt,
            ) from None
        raise
    try:
        manifest = json.loads(manifest_text)
    except json.JSONDecodeError as e:
        raise _corrupt(
            path, f"{path}: manifest.json is not valid JSON ({e})", on_corrupt
        ) from e
    if not isinstance(manifest, dict) or manifest.get("format") != FORMAT_NAME:
        raise ValueError(f"{path}: not a {FORMAT_NAME} artifact")
    if manifest.get("version") != FORMAT_VERSION:
        raise ValueError(
            f"{path}: unsupported artifact version {manifest.get('version')}"
        )
    try:
        with np.load(path / "design.npz", allow_pickle=False) as z:
            arrays = {k: z[k] for k in z.files}
    except FileNotFoundError:
        raise _corrupt(
            path,
            f"{path}: manifest.json present but design.npz missing",
            on_corrupt,
        ) from None
    except (zipfile.BadZipFile, OSError, ValueError, EOFError) as e:
        raise _corrupt(
            path,
            f"{path}: design.npz is torn or truncated ({e})",
            on_corrupt,
        ) from e
    want = manifest.get("arrays_sha256")
    if want is not None and _arrays_digest(arrays) != want:
        raise _corrupt(
            path,
            f"{path}: design.npz does not match manifest.json "
            "(corrupt or mixed-generation artifact)",
            on_corrupt,
        )

    try:
        return _rebuild(path, manifest, arrays, verify, t0)
    except KeyError as e:
        # manifest references an array key the npz does not carry
        raise _corrupt(
            path,
            f"{path}: manifest references missing array {e} "
            "(corrupt or mixed-generation artifact)",
            on_corrupt,
        ) from e


def _rebuild(
    path: Path, manifest: dict, arrays: dict, verify: str, t0: float
) -> CompiledDesign:
    programs = []
    tables = []
    for i in range(manifest["n_programs"]):
        parr = {k: arrays[f"prog{i}_{k}"] for k in _PROGRAM_KEYS}
        programs.append(parr)
        tables.append(compile_tables(DAISProgram.from_arrays(parr)))

    def spec_from(entry: dict) -> StepSpec:
        return StepSpec(
            entry["kind"],
            params=entry["params"],
            arrays={name: arrays[key] for name, key in entry["arrays"].items()},
            table=entry.get("table", -1),
            body=(
                [spec_from(b) for b in entry["body"]] if "body" in entry else None
            ),
        )

    specs = [spec_from(e) for e in manifest["steps"]]
    iq = manifest["in_quant"]
    use_pallas = bool(manifest.get("use_pallas", False))
    cfg_dict = manifest.get("compile_config")
    config = CompileConfig.from_dict(cfg_dict) if cfg_dict is not None else None
    design = CompiledDesign(
        in_quant=QuantConfig(iq["bits"], iq["int_bits"], iq["signed"]),
        in_shape=tuple(manifest["in_shape"]),
        out_shape=tuple(manifest["out_shape"]),
        out_qints=qints_from_array(arrays["out_qints"]),
        reports=[LayerReport(**r) for r in manifest["reports"]],
        step_specs=specs,
        tables=tables,
        programs=programs,
        use_pallas=use_pallas,
        config=config,
    )
    design.steps = build_steps(specs, tables, use_pallas)
    design.solver_stats = {
        "n_solves": 0,
        "n_cache_hits": 0,
        "n_pool_solves": 0,
        "pool_fallback": "loaded_from_artifact",
        "solver_time_s": 0.0,
        "loaded_from_artifact": True,
        "load_s": time.perf_counter() - t0,
        "compile_solver_stats": manifest.get("solver_stats", {}),
    }
    if verify != "off":
        from ..analysis import DesignVerificationError, verify_design

        vrep = verify_design(design, tier=verify)
        design.solver_stats["verify"] = {
            "tier": verify,
            "ok": vrep.ok,
            "n_errors": len(vrep.errors),
            "n_warnings": len(vrep.warnings),
            "pass_wall_s": {
                k: v for k, v in vrep.pass_wall_s.items() if isinstance(v, float)
            },
        }
        if not vrep.ok:
            raise DesignVerificationError(vrep, context=f"artifact {path}")
    return design
