"""Microbatched serving engine for compiled DA designs.

The deployment model of the paper (and hls4ml): a design is compiled
once, then serves inference at fixed microsecond-scale latency.  This
engine is the software analogue of the always-ready FPGA datapath — a
multi-model registry where each registered ``CompiledDesign`` (in-memory
or cold-started from a ``save_design`` artifact) gets:

  * a bounded request queue (backpressure: block or reject when full);
  * a dispatcher thread that drains the queue into microbatches —
    at most ``max_batch`` requests, waiting at most ``max_wait_us``
    after the first — mirroring serve/engine.py's slot design;
  * bucketed batch shapes (powers of two up to ``max_batch``) so the
    jitted integer forward pass compiles once per bucket and every
    batch is padded to the next bucket instead of a fresh shape;
  * per-request latency accounting (submit -> result) with p50/p95/p99
    and throughput in ``stats()``.

Requests are single samples on the integer input grid (``in_shape``,
as ``CompiledDesign.forward_int`` consumes them); ``submit`` returns a
``concurrent.futures.Future`` resolving to the integer output.
"""

from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from pathlib import Path
from typing import Optional, Union

import jax
import numpy as np

from ..flow.config import UNSET, ServeConfig, resolve_legacy
from ..nn.compiler import CompiledDesign
from .artifact import load_design
from .metrics import LatencyRecorder


def _serve_config_from_legacy(legacy: dict) -> ServeConfig:
    if "overflow" in legacy:
        legacy["backpressure"] = legacy.pop("overflow")
    if legacy.get("buckets") is not None:
        legacy["buckets"] = tuple(legacy["buckets"])
    return ServeConfig(**legacy)


class QueueFullError(RuntimeError):
    """Raised by ``submit`` when overflow policy is "reject" and the
    model's request queue is at capacity."""


class _Request:
    __slots__ = ("x", "t_submit", "future")

    def __init__(self, x: np.ndarray, t_submit: float, future: Future):
        self.x = x
        self.t_submit = t_submit
        self.future = future


def _default_buckets(max_batch: int) -> tuple[int, ...]:
    out = [1]
    while out[-1] < max_batch:
        out.append(min(out[-1] * 2, max_batch))
    return tuple(out)


class _ModelRunner(threading.Thread):
    def __init__(
        self,
        name: str,
        design: CompiledDesign,
        max_batch: int,
        queue_depth: int,
        max_wait_us: float,
        buckets: Optional[tuple[int, ...]],
    ):
        super().__init__(daemon=True, name=f"da4ml-serve-{name}")
        self.model_name = name
        self.design = design
        self.max_batch = max_batch
        self.max_wait_s = max_wait_us * 1e-6
        self.buckets = tuple(sorted(buckets)) if buckets else _default_buckets(max_batch)
        if self.buckets[-1] < max_batch:
            raise ValueError("largest bucket must cover max_batch")
        self.in_shape = tuple(design.in_shape)
        self.q: queue.Queue[_Request] = queue.Queue(queue_depth)
        self.metrics = LatencyRecorder()
        self.n_batches = 0
        self.n_rejected = 0
        self._occupancy_sum = 0.0
        # serving-perf observability: how often each bucket shape is
        # dispatched, and which bucket shapes have been jit-compiled.
        # jit caches per shape for a fixed design, so each flag is 0/1 —
        # a bookkeeping mirror of "first dispatch or warmup touched this
        # bucket", not an XLA retrace counter.  Without an up-front
        # warmup, flags flipping mid-traffic are exactly the requests
        # that paid a compile in their latency.
        self.bucket_hits: dict[int, int] = {b: 0 for b in self.buckets}
        self.jit_compiles: dict[int, int] = {b: 0 for b in self.buckets}
        self._fn = jax.jit(design.forward_int)
        self._stop = threading.Event()
        self._drained = threading.Event()

    # -- dispatcher ----------------------------------------------------
    def run(self) -> None:
        while True:
            batch = self._collect()
            if batch:
                self._execute(batch)
            elif self._stop.is_set():
                break
        self._fail_pending()
        self._drained.set()

    def _collect(self) -> list[_Request]:
        try:
            first = self.q.get(timeout=0.02)
        except queue.Empty:
            return []
        batch = [first]
        deadline = time.perf_counter() + self.max_wait_s
        while len(batch) < self.max_batch:
            try:
                # drain whatever is queued; when empty, block (GIL
                # released, in <=20ms slices so stop() is honored even
                # under a long batching window) instead of spinning
                # against the submitter threads
                batch.append(self.q.get_nowait())
                continue
            except queue.Empty:
                pass
            rem = deadline - time.perf_counter()
            if rem <= 0 or self._stop.is_set():
                break
            try:
                batch.append(self.q.get(timeout=min(rem, 0.02)))
            except queue.Empty:
                pass
        return batch

    def _fail_pending(self) -> None:
        """Fail any requests still queued once the dispatcher is gone
        (e.g. a submit that raced shutdown) instead of leaving their
        futures to hang until the client's result() timeout."""
        while True:
            try:
                r = self.q.get_nowait()
            except queue.Empty:
                return
            if r.future.set_running_or_notify_cancel():
                r.future.set_exception(
                    RuntimeError(f"model {self.model_name!r}: engine shut down")
                )

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def _execute(self, batch: list[_Request]) -> None:
        # claim the futures; drop any the client cancelled while queued
        batch = [r for r in batch if r.future.set_running_or_notify_cancel()]
        if not batch:
            return
        n = len(batch)
        b = self._bucket(n)
        try:
            x = np.zeros((b, *self.in_shape), np.int32)
            for i, r in enumerate(batch):
                x[i] = r.x
            y = np.asarray(self._fn(x))
        except Exception as e:  # resolve futures instead of killing the thread
            for r in batch:
                r.future.set_exception(e)
            return
        now = time.perf_counter()
        for i, r in enumerate(batch):
            r.future.set_result(y[i])
            self.metrics.record(now - r.t_submit, now=now)
        self.n_batches += 1
        # counted only on success, keeping sum(bucket_hits) == n_batches
        self.bucket_hits[b] += 1
        if not self.jit_compiles[b]:
            self.jit_compiles[b] = 1  # first dispatch of this shape compiles
        self._occupancy_sum += n / b

    # -- control -------------------------------------------------------
    def warmup(self) -> float:
        """Compile every bucket shape up front; returns wall seconds."""
        t0 = time.perf_counter()
        for b in self.buckets:
            if not self.jit_compiles[b]:
                self.jit_compiles[b] = 1
            np.asarray(self._fn(np.zeros((b, *self.in_shape), np.int32)))
        return time.perf_counter() - t0

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        self._drained.wait(timeout)
        self._fail_pending()  # catch puts that raced the dispatcher exit

    def stats(self) -> dict:
        s = self.metrics.snapshot()
        s.update(
            model=self.model_name,
            n_batches=self.n_batches,
            n_rejected=self.n_rejected,
            queue_depth=self.q.qsize(),
            mean_batch_occupancy=(
                self._occupancy_sum / self.n_batches if self.n_batches else 0.0
            ),
            buckets=list(self.buckets),
            # bucket hit histogram + which bucket shapes have been jit
            # compiled (0/1 per bucket; jax caches by shape): batches
            # landing in oversized buckets, or — when serving without an
            # up-front warmup — shapes compiling mid-traffic, show up
            # here instead of only as a latency blip
            bucket_hits={int(b): int(c) for b, c in self.bucket_hits.items()},
            jit_compiles={int(b): int(c) for b, c in self.jit_compiles.items()},
            n_jit_compiles=int(sum(self.jit_compiles.values())),
        )
        return s


class ServeEngine:
    """Multi-model registry + microbatched dispatch over compiled designs.

    The canonical way to set knobs is ``config=``, a
    :class:`repro.flow.ServeConfig` (max_batch, max_wait_us,
    queue_depth, backpressure, buckets); this is what ``Flow.serve``
    constructs.  The individual kwargs are a deprecated shim kept for
    one release (``overflow`` maps to ``backpressure``): they construct
    the equivalent config and delegate.

    ``register`` rejects duplicate model names loudly — replacing a
    model in place would silently mix two designs' results under one
    name.  Rolling a model forward is a *versioning* operation:
    ``repro.flow.Deployment.register(name, design, version=...)`` gives
    register-v2 / atomic-alias-flip / drain-v1 semantics on top of this
    engine.
    """

    def __init__(
        self,
        max_batch=UNSET,
        queue_depth=UNSET,
        max_wait_us=UNSET,
        buckets=UNSET,
        overflow=UNSET,
        config: Optional[ServeConfig] = None,
    ):
        legacy = {
            name: val
            for name, val in (
                ("max_batch", max_batch),
                ("queue_depth", queue_depth),
                ("max_wait_us", max_wait_us),
                ("buckets", buckets),
                ("overflow", overflow),
            )
            if val is not UNSET
        }
        config = resolve_legacy(
            "ServeEngine", config, legacy, ServeConfig, _serve_config_from_legacy
        )
        self.config = config
        self.max_batch = config.max_batch
        self.queue_depth = config.queue_depth
        self.max_wait_us = config.max_wait_us
        self.buckets = config.buckets
        self.overflow = config.backpressure
        self._runners: dict[str, _ModelRunner] = {}
        self._lock = threading.Lock()

    # -- registry ------------------------------------------------------
    def register(
        self,
        name: str,
        design: Union[CompiledDesign, str, Path],
        warmup: bool = False,
    ) -> CompiledDesign:
        """Register a design (or load one from an artifact path)."""
        if not isinstance(design, CompiledDesign):
            design = load_design(design)
        runner = _ModelRunner(
            name, design, self.max_batch, self.queue_depth,
            self.max_wait_us, self.buckets,
        )
        with self._lock:
            if name in self._runners:
                # never replace silently: two designs would be mixed under
                # one name.  Version rollout lives in flow.Deployment.
                raise ValueError(
                    f"model {name!r} already registered (roll a new version "
                    "via repro.flow.Deployment.register(..., version=))"
                )
            self._runners[name] = runner
        try:
            if warmup:
                runner.warmup()
            runner.start()
        except BaseException:  # failed warmup/start must not leave a dead entry
            with self._lock:
                self._runners.pop(name, None)
            raise
        return design

    def unregister(self, name: str, timeout: float = 5.0) -> None:
        """Drop a model after draining its queue (waiting up to
        ``timeout`` seconds for the dispatcher to finish; requests still
        queued after that are failed loudly, never left hanging)."""
        with self._lock:
            runner = self._runners.pop(name)
        runner.stop(timeout)

    def models(self) -> list[str]:
        with self._lock:
            return sorted(self._runners)

    def _runner(self, name: str) -> _ModelRunner:
        try:
            return self._runners[name]
        except KeyError:
            raise KeyError(f"model {name!r} is not registered") from None

    # -- serving -------------------------------------------------------
    def _validate(self, name: str, runner: _ModelRunner, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x)
        if x.shape != runner.in_shape:
            raise ValueError(
                f"model {name!r} expects one sample of shape {runner.in_shape}, "
                f"got {x.shape}"
            )
        if not np.issubdtype(x.dtype, np.integer):
            raise TypeError(
                f"model {name!r} expects integer-grid samples, got dtype "
                f"{x.dtype} (quantize floats with the design's in_quant first)"
            )
        return x

    def submit(self, name: str, x: np.ndarray) -> Future:
        """Enqueue one sample (integer grid, shape ``in_shape``)."""
        runner = self._runner(name)
        x = self._validate(name, runner, x)
        r = _Request(x, time.perf_counter(), Future())
        if self.overflow == "reject":
            try:
                runner.q.put_nowait(r)
            except queue.Full:
                runner.n_rejected += 1
                raise QueueFullError(
                    f"queue for model {name!r} is full "
                    f"({runner.q.maxsize} requests)"
                ) from None
        else:
            runner.q.put(r)
        return r.future

    def submit_batch(self, name: str, xs) -> list[Future]:
        """Enqueue many samples at once; returns one Future per sample.

        Amortizes per-request overhead (registry lookup, validation,
        clock read) across the batch — the high-throughput entrypoint
        for clients that already hold several requests.  ``xs`` is an
        iterable of samples or an ``[n, *in_shape]`` array.

        Backpressure mirrors ``submit`` per sample, except that with the
        "reject" policy an overflowing sample's Future is *failed* with
        :class:`QueueFullError` (and counted) instead of raising, so one
        full queue cannot lose the whole batch: every returned Future
        resolves either to a result or to the rejection.
        """
        runner = self._runner(name)
        xs = [self._validate(name, runner, x) for x in xs]
        now = time.perf_counter()
        reqs = [_Request(x, now, Future()) for x in xs]
        reject = self.overflow == "reject"
        for r in reqs:
            if reject:
                try:
                    runner.q.put_nowait(r)
                except queue.Full:
                    runner.n_rejected += 1
                    if r.future.set_running_or_notify_cancel():
                        r.future.set_exception(
                            QueueFullError(
                                f"queue for model {name!r} is full "
                                f"({runner.q.maxsize} requests)"
                            )
                        )
            else:
                runner.q.put(r)
        return [r.future for r in reqs]

    def infer(self, name: str, x: np.ndarray, timeout: Optional[float] = 30.0):
        """Synchronous single-sample convenience wrapper."""
        return self.submit(name, x).result(timeout)

    def warmup(self, name: str) -> float:
        return self._runner(name).warmup()

    def stats(self, name: Optional[str] = None) -> dict:
        if name is not None:
            return self._runner(name).stats()
        with self._lock:
            runners = list(self._runners.items())
        return {n: r.stats() for n, r in runners}

    # -- lifecycle -----------------------------------------------------
    def shutdown(self, timeout: float = 5.0) -> None:
        """Stop all dispatchers after draining their queues."""
        with self._lock:
            runners = list(self._runners.values())
            self._runners.clear()
        for r in runners:
            r.stop(timeout)

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
