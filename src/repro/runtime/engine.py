"""Sharded, microbatched serving engine for compiled DA designs.

The deployment model of the paper (and hls4ml): a design is compiled
once, then serves inference at fixed microsecond-scale latency.  This
engine is the software analogue of the always-ready FPGA datapath — a
multi-model registry where each registered ``CompiledDesign`` (in-memory
or cold-started from a ``save_design`` artifact) gets:

  * N dispatch *shards* (``ServeConfig.shards``), each a bounded request
    queue + dispatcher thread + preallocated payload slab; ``submit``
    places requests round-robin across shards, ``submit_batch`` spreads
    contiguous chunks, and the per-model ``queue_depth`` backpressure
    budget is divided across shards;
  * a payload **slab** per shard: submitters write samples straight into
    a preallocated ring of slots and dispatchers gather whole batches
    out of it with one vectorized copy into a bucket-shaped scratch
    array — no per-request array allocations or per-request copies on
    the dispatch path;
  * microbatch formation per shard — at most ``max_batch`` requests,
    waiting at most ``max_wait_us`` after the first — with bucketed
    batch shapes (powers of two up to ``max_batch``) so the jitted
    integer forward pass (shared by all shards) compiles once per
    bucket and every batch is padded to the next bucket;
  * per-request latency accounting (submit -> result, p50/p95/p99,
    throughput) plus per-stage accounting (queue wait / batch-form /
    pad / dispatch / copy-out) and per-shard counters, merged across
    shards in ``stats()``.

Requests are single samples on the integer input grid (``in_shape``,
as ``CompiledDesign.forward_int`` consumes them); ``submit`` returns a
``concurrent.futures.Future`` resolving to the integer output.

Shutdown discipline: every Future handed out is resolved — with a
result while draining, or with :class:`EngineClosedError` once the
model is closed.  The closed flag is checked *under the shard lock* on
every enqueue, so a ``submit`` that grabbed a runner reference just
before ``unregister``/``shutdown`` popped it either lands in the queue
before the dispatcher's final drain (and is served) or observes the
flag and fails fast — the put-after-final-sweep window that used to
hang futures cannot occur.

Resilience layer (docs/robustness.md; provoked end-to-end by
``tests/test_chaos.py`` through :mod:`repro.chaos`):

  * **Deadlines + shedding** — requests may carry a deadline (per call
    or ``ServeConfig.deadline_ms``); expired requests are failed with
    :class:`DeadlineExceededError` at enqueue and again at batch-form
    time (``n_shed``) instead of burning dispatcher work.
  * **Circuit breaker** — consecutive jit-dispatch failures trip a
    per-model :class:`~repro.runtime.resilience.CircuitBreaker`
    (closed -> open -> half-open probes with capped exponential
    backoff); while open, batches fail fast with
    :class:`CircuitOpenError` or degrade to the bit-exact numpy
    interpreter (``ServeConfig.fallback="interpreter"``).
  * **Shard supervision** — a per-model supervisor thread detects dead
    dispatcher threads, fails their in-flight/pending futures with
    :class:`ShardCrashedError`, restarts them within
    ``ServeConfig.restart_budget``, then escalates to
    :class:`ModelUnhealthyError`.
  * **Client-timeout accounting** — ``infer`` ties its ``timeout`` into
    the deadline path (abandoned work is shed, not executed) and counts
    expiries in ``n_client_timeouts``.

The core invariant, asserted by the chaos soak under every injected
fault schedule: *every submitted Future resolves — with a result or a
typed error — and every slab slot returns to the free list.*
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from concurrent.futures import Future
from concurrent.futures import TimeoutError as FutureTimeoutError  # noqa: F401
from pathlib import Path

import jax
import numpy as np

from ..chaos import ThreadKillFault, fault_point
from ..flow.config import UNSET, ServeConfig, resolve_legacy
from ..nn.compiler import CompiledDesign
from ..obs import trace
from ..obs.flight import FlightRecorder
from ..obs.metrics import Histogram, get_registry, render_prometheus
from .artifact import load_design
from .metrics import LatencyRecorder, StageAccumulator
from .resilience import CircuitBreaker


def _serve_config_from_legacy(legacy: dict) -> ServeConfig:
    if "overflow" in legacy:
        legacy["backpressure"] = legacy.pop("overflow")
    if legacy.get("buckets") is not None:
        legacy["buckets"] = tuple(legacy["buckets"])
    return ServeConfig(**legacy)


class QueueFullError(RuntimeError):
    """Raised by ``submit`` when overflow policy is "reject" and the
    model's request queue is at capacity."""


class EngineClosedError(RuntimeError):
    """Raised by ``submit`` (or set on a Future) when the request raced
    ``unregister``/``shutdown``: the model's dispatchers are stopping or
    gone, so the request is failed fast instead of queued forever."""


class DeadlineExceededError(RuntimeError):
    """The request's deadline expired before dispatch; it was shed
    (counted in ``n_shed``) instead of executed."""


class CircuitOpenError(RuntimeError):
    """The model's circuit breaker is open and no fallback is
    configured: the request failed fast instead of hitting the broken
    dispatch path (counted in ``n_fast_failed``)."""


class ShardCrashedError(RuntimeError):
    """The dispatch shard's thread died; its in-flight and pending
    futures were failed with this error.  With supervision enabled the
    shard is restarted and new submits retry onto the replacement."""


class ModelUnhealthyError(RuntimeError):
    """The model exhausted its dispatcher restart budget (or crashed
    with supervision disabled); submits fail fast until it is
    re-registered."""


class _Request:
    __slots__ = ("slot", "t_submit", "future", "tid", "deadline")

    def __init__(
        self,
        slot: int,
        t_submit: float,
        future: Future,
        tid: int = 0,
        deadline: float | None = None,
    ):
        self.slot = slot
        self.t_submit = t_submit
        self.future = future
        self.tid = tid  # per-shard trace id, stamped at enqueue
        self.deadline = deadline  # absolute perf_counter seconds, or None


def _default_buckets(max_batch: int) -> tuple[int, ...]:
    out = [1]
    while out[-1] < max_batch:
        out.append(min(out[-1] * 2, max_batch))
    return tuple(out)


class _Shard(threading.Thread):
    """One dispatch lane of a model: bounded request deque + payload
    slab + dispatcher thread.

    All shard state (deque, free-slot stack, counters) is guarded by one
    lock; submitters copy their sample into a reserved slab slot while
    holding it (the samples are small — the copy is cheaper than a
    second lock round-trip), and the dispatcher drains a whole batch in
    a single lock acquisition, then gathers the batch out of the slab
    with one vectorized copy into a per-bucket scratch array.

    Crash discipline: the dispatcher loop is wrapped in a
    ``BaseException`` handler (injected thread kills are
    ``BaseException`` precisely so they get past the per-batch
    ``except Exception`` guard).  On crash the shard marks itself dead,
    fails its in-flight and pending futures with
    :class:`ShardCrashedError`, wakes blocked submitters, and sets
    ``_drained`` — a dead shard never strands a future or a slab slot.
    """

    def __init__(self, runner: "_ModelRunner", idx: int, depth: int):
        super().__init__(
            daemon=True, name=f"da4ml-serve-{runner.model_name}-s{idx}"
        )
        self.runner = runner
        self.idx = idx
        self.depth = depth
        self.max_batch = runner.max_batch
        self.max_wait_s = runner.max_wait_s
        self.in_shape = runner.in_shape
        self._fn = runner._fn
        self._fallback_fn = runner._fallback_fn
        self._closed = runner._closed  # runner-wide: set first in stop()

        # payload slab: depth queued + max_batch executing slots can be
        # live at once; slots are recycled through a free-list stack
        cap = depth + runner.max_batch
        self.slab = np.empty((cap, *self.in_shape), np.int32)
        self._free: list[int] = list(range(cap))
        self._pending: deque[_Request] = deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        # bucket-shaped scratch: the gather target, reused every batch
        # (safe: the jitted call's result is materialized before reuse)
        self._scratch = {
            b: np.zeros((b, *self.in_shape), np.int32) for b in runner.buckets
        }

        self.metrics = LatencyRecorder()
        self.stage = StageAccumulator()
        # observability (single writer: this dispatcher thread) — per-stage
        # µs histograms and the per-request flight recorder; trace ids are
        # stamped at enqueue under the shard lock (shard idx in high bits
        # keeps them unique across shards)
        self.stage_hist = {s: Histogram() for s in StageAccumulator.STAGES}
        self.flight = FlightRecorder(capacity=2048, slow_k=16)
        self._tid_seq = itertools.count()
        self._tid_base = idx << 40
        self.n_batches = 0
        self.n_rejected = 0  # guarded by self._lock (shared with submitters)
        self.n_shed = 0  # guarded by self._lock (submitters + dispatcher)
        self.n_fast_failed = 0  # dispatcher-only writer
        self.n_fallback_batches = 0  # dispatcher-only writer
        self._occupancy_sum = 0.0
        self.bucket_hits: dict[int, int] = {b: 0 for b in runner.buckets}
        self._stop = threading.Event()
        self._drained = threading.Event()
        # crash state: flipped once by _on_crash, read under the lock by
        # submitters and lock-free by the supervisor
        self.dead = False
        self.crash_exc: BaseException | None = None
        self.heartbeat = time.perf_counter()
        self._executing: list[_Request] = []  # claimed, awaiting dispatch

    # -- enqueue (submitter threads) -----------------------------------
    def _closed_error(self) -> EngineClosedError:
        return EngineClosedError(
            f"model {self.runner.model_name!r}: engine shut down"
        )

    def _full_error(self) -> QueueFullError:
        return QueueFullError(
            f"queue for model {self.runner.model_name!r} is full "
            f"({self.depth} requests on shard {self.idx})"
        )

    def _crash_error(self) -> ShardCrashedError:
        return ShardCrashedError(
            f"model {self.runner.model_name!r}: dispatch shard {self.idx} "
            f"crashed ({self.crash_exc!r})"
        )

    def _deadline_error(self) -> DeadlineExceededError:
        return DeadlineExceededError(
            f"model {self.runner.model_name!r}: deadline expired before "
            "dispatch (request shed)"
        )

    def _final_error(self) -> RuntimeError:
        return self._crash_error() if self.dead else self._closed_error()

    def put_one(
        self, x: np.ndarray, t_submit: float, block: bool,
        deadline: float | None = None,
    ) -> Future:
        fut: Future = Future()
        if deadline is not None and t_submit >= deadline:
            # the caller handed us an already-expired budget: shed at
            # the door, before a slab slot is even reserved
            with self._lock:
                self.n_shed += 1
            if fut.set_running_or_notify_cancel():
                fut.set_exception(self._deadline_error())
            return fut
        with self._lock:
            while True:
                if self.dead:
                    raise self._crash_error()
                if self._closed.is_set():
                    raise self._closed_error()
                if self._free and len(self._pending) < self.depth:
                    break
                if not block:
                    self.n_rejected += 1
                    raise self._full_error()
                # timed wait: re-checks the closed flag even if a racing
                # stop() notified before we started waiting
                self._not_full.wait(0.05)
            slot = self._free.pop()
            self.slab[slot] = x
            self._pending.append(
                _Request(
                    slot, t_submit, fut,
                    self._tid_base | next(self._tid_seq), deadline,
                )
            )
            self._not_empty.notify()
        return fut

    def put_many(
        self, xs: list, t_submit: float, block: bool,
        deadline: float | None = None,
    ) -> list[Future]:
        """Enqueue a chunk under one lock acquisition.  With the reject
        policy, overflowing samples' futures are *failed* with
        :class:`QueueFullError` (and counted) instead of raising; if the
        shard closes (or crashes) mid-chunk the remaining futures are
        failed with :class:`EngineClosedError` /
        :class:`ShardCrashedError` — every returned Future resolves."""
        futs: list[Future] = [Future() for _ in xs]
        if deadline is not None and t_submit >= deadline:
            with self._lock:
                self.n_shed += len(xs)
            err = self._deadline_error()
            for f in futs:
                if f.set_running_or_notify_cancel():
                    f.set_exception(err)
            return futs
        i, n = 0, len(xs)
        with self._lock:
            while i < n:
                if self.dead or self._closed.is_set():
                    break
                space = min(len(self._free), self.depth - len(self._pending))
                if space <= 0:
                    if not block:
                        self.n_rejected += 1
                        f = futs[i]
                        if f.set_running_or_notify_cancel():
                            f.set_exception(self._full_error())
                        i += 1
                        continue
                    self._not_full.wait(0.05)
                    continue
                for j in range(i, min(i + space, n)):
                    slot = self._free.pop()
                    self.slab[slot] = xs[j]
                    self._pending.append(
                        _Request(
                            slot, t_submit, futs[j],
                            self._tid_base | next(self._tid_seq), deadline,
                        )
                    )
                i = min(i + space, n)
                self._not_empty.notify()
        for j in range(i, n):  # chunk tail cut off by a racing shutdown/crash
            f = futs[j]
            if f.set_running_or_notify_cancel():
                f.set_exception(self._final_error())
        return futs

    # -- dispatcher ----------------------------------------------------
    def run(self) -> None:
        try:
            while True:
                self.heartbeat = time.perf_counter()
                fault_point("serve.dispatcher")
                batch, t_first = self._collect()
                if batch:
                    with trace.span("serve.batch", shard=self.idx, n=len(batch)):
                        self._execute(batch, t_first)
                elif self._stop.is_set():
                    break
            self._fail_pending(self._closed_error)
            self._drained.set()
        except BaseException as e:  # dispatcher death: clean up, never strand
            self._on_crash(e)

    def _collect(self) -> tuple[list[_Request], float]:
        with self._lock:
            while not self._pending:
                if self._stop.is_set():
                    return [], 0.0
                self.heartbeat = time.perf_counter()
                self._not_empty.wait(0.05)
            t_first = time.perf_counter()
            if len(self._pending) < self.max_batch and not self._stop.is_set():
                deadline = t_first + self.max_wait_s
                while len(self._pending) < self.max_batch:
                    rem = deadline - time.perf_counter()
                    if rem <= 0 or self._stop.is_set():
                        break
                    self._not_empty.wait(min(rem, 0.02))
            n = min(len(self._pending), self.max_batch)
            batch = [self._pending.popleft() for _ in range(n)]
            self._not_full.notify_all()
            return batch, t_first

    def _free_slots(self, slots: list) -> None:
        with self._lock:
            self._free.extend(slots)
            self._not_full.notify_all()

    def _fail_pending(self, err_factory) -> None:
        """Fail any requests still queued once the dispatcher is gone
        (drain timeout or crash) instead of leaving their futures to
        hang until the client's result() timeout."""
        with self._lock:
            reqs = list(self._pending)
            self._pending.clear()
            self._free.extend(r.slot for r in reqs)
            self._not_full.notify_all()
        for r in reqs:
            if r.future.set_running_or_notify_cancel():
                r.future.set_exception(err_factory())

    def _on_crash(self, exc: BaseException) -> None:
        """Dispatcher-thread death: mark dead, wake blocked submitters,
        fail in-flight and pending futures, release their slots, and
        report to the runner (which escalates or lets the supervisor
        revive this lane)."""
        self.crash_exc = exc
        with self._lock:
            self.dead = True
            self._not_empty.notify_all()
            self._not_full.notify_all()
        claimed, self._executing = self._executing, []
        for r in claimed:
            if not r.future.done():
                r.future.set_exception(self._crash_error())
        self._fail_pending(self._crash_error)
        self._drained.set()
        self.runner._note_crash(self, exc)

    def _bucket(self, n: int) -> int:
        for b in self.runner.buckets:
            if b >= n:
                return b
        return self.runner.buckets[-1]

    def _dispatch(self, x: np.ndarray) -> tuple[np.ndarray, bool]:
        """Run one padded batch through the breaker-routed dispatch path.
        Returns (outputs, used_fallback)."""
        breaker = self.runner.breaker
        route = breaker.route()
        if route == "reject":
            if self._fallback_fn is not None:
                return np.asarray(self._fallback_fn(x)), True
            raise CircuitOpenError(
                f"model {self.runner.model_name!r}: circuit breaker open "
                "and no fallback configured"
            )
        probe = route == "probe"
        try:
            fault_point("serve.dispatch")
            y = np.asarray(self._fn(x))
        except ThreadKillFault:
            breaker.record(ok=False, probe=probe)  # never leave a probe hung
            raise
        except Exception:
            breaker.record(ok=False, probe=probe)
            if self._fallback_fn is not None:
                return np.asarray(self._fallback_fn(x)), True
            raise
        breaker.record(ok=True, probe=probe)
        return y, False

    def _execute(self, batch: list[_Request], t_first: float) -> None:
        t_formed = time.perf_counter()
        # claim the futures; drop any the client cancelled while queued,
        # shed any whose deadline expired while they sat in the queue
        claimed: list[_Request] = []
        expired: list[_Request] = []
        for r in batch:
            if not r.future.set_running_or_notify_cancel():
                continue
            if r.deadline is not None and t_formed >= r.deadline:
                expired.append(r)
            else:
                claimed.append(r)
        self.stage.add("batch_form", t_formed - t_first)
        slots = [r.slot for r in batch]
        if expired:
            with self._lock:
                self.n_shed += len(expired)
            for r in expired:
                r.future.set_exception(self._deadline_error())
        if not claimed:
            self._free_slots(slots)
            return
        self.stage.add(
            "queue_wait",
            sum(t_formed - r.t_submit for r in claimed),
            len(claimed),
        )
        n = len(claimed)
        b = self._bucket(n)
        x = self._scratch[b]
        self._executing = claimed  # crash handler fails these if we die here
        try:
            try:
                fault_point("serve.gather")
                x[:n] = self.slab[[r.slot for r in claimed]]
                if n < b:
                    x[n:] = 0
            finally:
                self._free_slots(slots)  # slots recycle even on failure
            t_pad = time.perf_counter()
            self.stage.add("pad", t_pad - t_formed)
            y, used_fallback = self._dispatch(x)
        except ThreadKillFault:
            raise  # run()'s crash handler resolves self._executing
        except Exception as e:  # resolve futures instead of killing the thread
            self._executing = []
            if isinstance(e, CircuitOpenError):
                self.n_fast_failed += len(claimed)
            for r in claimed:
                r.future.set_exception(e)
            return
        self._executing = []
        t_done = time.perf_counter()
        self.stage.add("dispatch", t_done - t_pad)
        if used_fallback:
            self.n_fallback_batches += 1
        lats = []
        for i, r in enumerate(claimed):
            r.future.set_result(y[i])
            lats.append(t_done - r.t_submit)
        self.metrics.record_many(lats, t_done)
        self.n_batches += 1
        # counted only on success, keeping sum(bucket_hits) == n_batches
        self.bucket_hits[b] += 1
        jc = self.runner.jit_compiles
        if not used_fallback and not jc[b]:
            jc[b] = 1  # first dispatch of this shape compiled (any shard)
        self._occupancy_sum += n / b
        t_out = time.perf_counter()
        self.stage.add("copy_out", t_out - t_done)
        self._observe_batch(claimed, lats, b, n, t_first, t_formed, t_pad, t_done, t_out)

    def _observe_batch(
        self, claimed, lats, b, n, t_first, t_formed, t_pad, t_done, t_out
    ) -> None:
        """Feed the per-stage histograms, the flight recorder, and the
        process-registry gauges after a successful batch.  This thread is
        the sole writer of all three, so the path stays lock-free; the
        batch-shared stage times are charged to every request's flight
        record while queue_wait stays per-request."""
        bf_us = (t_formed - t_first) * 1e6
        pad_us = (t_pad - t_formed) * 1e6
        disp_us = (t_done - t_pad) * 1e6
        out_us = (t_out - t_done) * 1e6
        hists = self.stage_hist
        hists["batch_form"].observe(bf_us)
        hists["pad"].observe(pad_us)
        hists["dispatch"].observe(disp_us)
        hists["copy_out"].observe(out_us)
        qh = hists["queue_wait"]
        fl = self.flight
        ts_us = t_done * 1e6
        for r, lat in zip(claimed, lats):
            qw_us = (t_formed - r.t_submit) * 1e6
            qh.observe(qw_us)
            fl.record(
                r.tid, self.idx, b, n, lat * 1e6,
                (qw_us, bf_us, pad_us, disp_us, out_us), ts_us=ts_us,
            )
        # unlocked reads: both lens are single CPython ops, and a gauge
        # only needs to be approximately current
        reg = get_registry()
        model = self.runner.model_name
        reg.set_gauge(
            "serve_queue_depth", len(self._pending), model=model, shard=self.idx
        )
        reg.set_gauge(
            "serve_slab_occupancy",
            1.0 - len(self._free) / self.slab.shape[0],
            model=model, shard=self.idx,
        )

    # -- control -------------------------------------------------------
    def initiate_stop(self) -> None:
        self._stop.set()
        with self._lock:
            self._not_empty.notify_all()
            self._not_full.notify_all()

    def snapshot(self) -> dict:
        with self._lock:
            qsize = len(self._pending)
            n_rejected = self.n_rejected
            n_shed = self.n_shed
        n_batches = self.n_batches
        return {
            "shard": self.idx,
            "n_batches": n_batches,
            "n_rejected": n_rejected,
            "n_shed": n_shed,
            "n_fast_failed": self.n_fast_failed,
            "n_fallback_batches": self.n_fallback_batches,
            "n_requests": self.metrics.n_total,
            "queue_depth": qsize,
            "dead": self.dead,
            "heartbeat_age_s": max(0.0, time.perf_counter() - self.heartbeat),
            "mean_batch_occupancy": (
                self._occupancy_sum / n_batches if n_batches else 0.0
            ),
            "bucket_hits": {int(b): int(c) for b, c in self.bucket_hits.items()},
            "per_stage": self.stage.snapshot(),
            "flight": self.flight.snapshot(),
        }


class _Supervisor(threading.Thread):
    """Per-model watchdog: polls the runner's dispatcher threads and
    revives dead ones (heartbeat staleness is surfaced in ``stats()``;
    thread death — crash flag or ``Thread.is_alive`` — triggers the
    restart path)."""

    def __init__(self, runner: "_ModelRunner", interval_s: float = 0.05):
        super().__init__(daemon=True, name=f"da4ml-supervise-{runner.model_name}")
        self.runner = runner
        self.interval_s = interval_s

    def run(self) -> None:
        r = self.runner
        while not r._closed.wait(self.interval_s):
            for idx in range(r.n_shards):
                sh = r.shards[idx]
                if sh.ident is None:
                    continue  # not started yet
                if (sh.dead or not sh.is_alive()) and not sh._stop.is_set():
                    r._revive(idx, sh)


class _ModelRunner:
    """One registered model: shared jitted forward + N dispatch shards
    + circuit breaker + (optional) supervisor."""

    def __init__(
        self,
        name: str,
        design: CompiledDesign,
        max_batch: int,
        queue_depth: int,
        max_wait_us: float,
        buckets: tuple[int, ...] | None,
        shards: int = 1,
        config: ServeConfig | None = None,
    ):
        self.model_name = name
        self.design = design
        self.max_batch = max_batch
        self.max_wait_s = max_wait_us * 1e-6
        self.buckets = tuple(sorted(buckets)) if buckets else _default_buckets(max_batch)
        if self.buckets[-1] < max_batch:
            raise ValueError("largest bucket must cover max_batch")
        self.in_shape = tuple(design.in_shape)
        self._fn = jax.jit(design.forward_int)
        # resilience knobs come from the ServeConfig; the engine params
        # above stay positional for backward compatibility
        rcfg = config if config is not None else ServeConfig()
        self.supervise = rcfg.supervise
        self.restart_budget = rcfg.restart_budget
        self.deadline_default_s = (
            rcfg.deadline_ms * 1e-3 if rcfg.deadline_ms is not None else None
        )
        self._fallback_fn = None
        if rcfg.fallback == "interpreter":
            from ..nn.interpreter import numpy_forward_fn  # lazy: nn imports stay light

            self._fallback_fn = numpy_forward_fn(design)
        self.breaker = CircuitBreaker(
            threshold=rcfg.breaker_threshold,
            cooldown_s=rcfg.breaker_cooldown_ms * 1e-3,
            cooldown_max_s=rcfg.breaker_cooldown_max_ms * 1e-3,
            on_event=self._breaker_event,
        )
        # lifecycle events (breaker transitions, crashes, restarts) land
        # in a runner-level recorder merged into the stats flight view
        self.flight_events = FlightRecorder(capacity=8, slow_k=0)
        # which bucket shapes have been jit-compiled (0/1 per bucket;
        # jax caches per shape for a fixed design, and the jitted fn is
        # shared by every shard).  A flag is set only *after* a trace
        # actually completed — warmup or first dispatch — so a warmup
        # that raises mid-loop never reports untraced buckets as
        # compiled.  Without an up-front warmup, flags flipping
        # mid-traffic are exactly the requests that paid a compile.
        self.jit_compiles: dict[int, int] = {b: 0 for b in self.buckets}
        self.n_shards = max(1, int(shards))
        # the per-model queue_depth backpressure budget is divided
        # across shards (ceil, so capacity never shrinks below it)
        depth = -(-queue_depth // self.n_shards)
        self._depth = depth
        self._closed = threading.Event()
        self.shards = [_Shard(self, i, depth) for i in range(self.n_shards)]
        self._rr = itertools.count()  # round-robin placement cursor
        # supervision state: restart accounting + health flag, guarded by
        # _restart_lock (shards list swaps happen under it too)
        self._restart_lock = threading.Lock()
        self._count_lock = threading.Lock()
        self._retired: list[_Shard] = []
        self.restarts_used = [0] * self.n_shards
        self.n_restarts = 0
        self.n_crashes = 0
        self.n_client_timeouts = 0
        self.healthy = True
        self._supervisor: _Supervisor | None = None

    def start(self) -> None:
        for sh in self.shards:
            sh.start()
        if self.supervise and self._supervisor is None:
            self._supervisor = _Supervisor(self)
            self._supervisor.start()

    # -- resilience plumbing -------------------------------------------
    def _record_event(self, kind: str, **fields) -> None:
        self.flight_events.record_event(
            kind, ts_us=time.perf_counter() * 1e6, **fields
        )

    def _breaker_event(self, kind: str, snap: dict) -> None:
        self._record_event(
            kind,
            state=snap["state"],
            n_trips=snap["n_trips"],
            n_reopens=snap["n_reopens"],
            n_recoveries=snap["n_recoveries"],
            cooldown_s=snap["cooldown_s"],
        )

    def _note_crash(self, shard: _Shard, exc: BaseException) -> None:
        with self._count_lock:
            self.n_crashes += 1
        self._record_event("shard_crash", shard=shard.idx, error=repr(exc))
        if not self.supervise and self.healthy:
            # nobody will revive this lane: fail the model loudly rather
            # than letting submits bounce off a permanently dead shard
            self.healthy = False
            self._record_event(
                "model_unhealthy", shard=shard.idx,
                reason="crash with supervision disabled",
            )

    def _revive(self, idx: int, dead_shard: _Shard) -> None:
        """Swap a fresh dispatcher in for a dead one (supervisor thread).
        Budget-limited: exhausting ``restart_budget`` on a lane marks
        the whole model unhealthy instead of restart-looping forever."""
        with self._restart_lock:
            if self._closed.is_set() or self.shards[idx] is not dead_shard:
                return
            if not dead_shard.dead:
                # the thread died without running its crash handler
                # (the handler catches BaseException, so this is a
                # belt-and-braces path) — never leave futures hanging
                dead_shard._on_crash(RuntimeError("dispatcher thread died"))
            if self.restarts_used[idx] >= self.restart_budget:
                if self.healthy:
                    self.healthy = False
                    self._record_event(
                        "model_unhealthy", shard=idx,
                        reason="restart budget exhausted",
                        restarts=self.restarts_used[idx],
                    )
                return
            fresh = _Shard(self, idx, self._depth)
            self.restarts_used[idx] += 1
            self.n_restarts += 1
            self._retired.append(dead_shard)
            self.shards[idx] = fresh
            fresh.start()
            self._record_event(
                "shard_restart", shard=idx, restart_n=self.restarts_used[idx]
            )

    def count_client_timeout(self) -> None:
        with self._count_lock:
            self.n_client_timeouts += 1

    def _unhealthy_error(self) -> ModelUnhealthyError:
        return ModelUnhealthyError(
            f"model {self.model_name!r} is unhealthy "
            f"(dispatcher restart budget of {self.restart_budget} exhausted)"
        )

    def deadline_abs(self, t_submit: float, deadline_s: float | None) -> float | None:
        """Absolute deadline for a request: per-call value wins, then
        the config default, then None (no deadline)."""
        if deadline_s is None:
            if self.deadline_default_s is None:
                return None
            deadline_s = self.deadline_default_s
        return t_submit + deadline_s

    # -- serving -------------------------------------------------------
    def submit_one(
        self, x: np.ndarray, t_submit: float, block: bool,
        deadline: float | None = None,
    ) -> Future:
        last: ShardCrashedError | None = None
        for _ in range(8):
            if not self.healthy:
                raise self._unhealthy_error()
            sh = self.shards[next(self._rr) % self.n_shards]
            try:
                return sh.put_one(x, t_submit, block, deadline)
            except ShardCrashedError as e:
                last = e
                if self._closed.is_set() or not self.supervise:
                    raise
                # the retry window must outlast one supervisor poll
                # interval, or a submit racing the revive fails spuriously
                time.sleep(0.02)
        if not self.healthy:
            raise self._unhealthy_error()
        assert last is not None
        raise last

    def submit_many(
        self, xs: list, t_submit: float, block: bool,
        deadline: float | None = None,
    ) -> list[Future]:
        if not self.healthy:
            raise self._unhealthy_error()
        if self.n_shards == 1 or len(xs) <= 1:
            sh = self.shards[next(self._rr) % self.n_shards]
            return sh.put_many(xs, t_submit, block, deadline)
        # contiguous chunks, one per shard round-robin: one lock
        # acquisition per shard instead of one per request
        chunk = -(-len(xs) // self.n_shards)
        futs: list[Future] = []
        for i in range(0, len(xs), chunk):
            sh = self.shards[next(self._rr) % self.n_shards]
            futs.extend(sh.put_many(xs[i : i + chunk], t_submit, block, deadline))
        return futs

    # -- control -------------------------------------------------------
    def warmup(self) -> float:
        """Compile every bucket shape up front; returns wall seconds.
        Flags are set per bucket only after its trace+run returned, so a
        mid-loop failure leaves only truthful flags behind."""
        t0 = time.perf_counter()
        for b in self.buckets:
            np.asarray(self._fn(np.zeros((b, *self.in_shape), np.int32)))
            self.jit_compiles[b] = 1
        return time.perf_counter() - t0

    def stop(self, timeout: float = 5.0) -> None:
        # closed first: from here on every enqueue attempt fails fast
        # (checked under the shard lock, closing the put-after-sweep
        # race) and the supervisor revives nothing; already-queued
        # requests are still drained and served.
        self._closed.set()
        with self._restart_lock:  # no shard swap can race the drain below
            shards = list(self.shards)
        for sh in shards:
            sh.initiate_stop()
        deadline = time.perf_counter() + timeout
        for sh in shards:
            if sh.dead:
                continue  # crashed: its handler already set _drained —
                # don't burn the live shards' drain budget waiting on it
            sh._drained.wait(max(0.0, deadline - time.perf_counter()))
        for sh in shards:
            # drain timed out, or the shard died before stop() was even
            # called: fail leftovers loudly (typed by how the lane ended)
            sh._fail_pending(sh._final_error)
        if self._supervisor is not None:
            self._supervisor.join(timeout=1.0)

    def stats(self) -> dict:
        with self._restart_lock:
            live = list(self.shards)
            retired = list(self._retired)
            restarts_used = list(self.restarts_used)
        all_shards = retired + live
        shard_snaps = []
        for sh in all_shards:
            snap = sh.snapshot()
            snap["retired"] = sh in retired
            shard_snaps.append(snap)
        s = LatencyRecorder.merged_snapshot([sh.metrics for sh in all_shards])
        bucket_hits = {int(b): 0 for b in self.buckets}
        n_batches = n_rejected = n_shed = n_fast_failed = n_fallback = qdepth = 0
        occupancy = 0.0
        for sh, snap in zip(all_shards, shard_snaps):
            n_batches += snap["n_batches"]
            n_rejected += snap["n_rejected"]
            n_shed += snap["n_shed"]
            n_fast_failed += snap["n_fast_failed"]
            n_fallback += snap["n_fallback_batches"]
            qdepth += snap["queue_depth"]
            occupancy += sh._occupancy_sum
            for b, c in snap["bucket_hits"].items():
                bucket_hits[b] += c
        with self._count_lock:
            n_client_timeouts = self.n_client_timeouts
            n_crashes = self.n_crashes
        s.update(
            model=self.model_name,
            n_shards=self.n_shards,
            n_batches=n_batches,
            n_rejected=n_rejected,
            n_shed=n_shed,
            n_fast_failed=n_fast_failed,
            n_fallback_batches=n_fallback,
            n_client_timeouts=n_client_timeouts,
            queue_depth=qdepth,
            mean_batch_occupancy=(occupancy / n_batches if n_batches else 0.0),
            buckets=list(self.buckets),
            # aggregated bucket hit histogram + which bucket shapes have
            # been jit compiled; per-shard histograms (each satisfying
            # sum(bucket_hits) == n_batches) live under "shards"
            bucket_hits=bucket_hits,
            jit_compiles={int(b): int(c) for b, c in self.jit_compiles.items()},
            n_jit_compiles=int(sum(self.jit_compiles.values())),
            per_stage=StageAccumulator.merged_snapshot(
                [sh.stage for sh in all_shards]
            ),
            # cross-shard flight view: overall slowest-K request records
            # plus time-ordered lifecycle events (breaker transitions,
            # crashes, restarts) from the runner-level recorder
            flight=FlightRecorder.merged(
                [sh.flight for sh in all_shards] + [self.flight_events]
            ),
            breaker=self.breaker.snapshot(),
            supervision={
                "supervise": self.supervise,
                "healthy": self.healthy,
                "n_crashes": n_crashes,
                "n_restarts": self.n_restarts,
                "restart_budget": self.restart_budget,
                "restarts_used": restarts_used,
            },
            shards=shard_snaps,
        )
        return s


class ServeEngine:
    """Multi-model registry + sharded microbatched dispatch over
    compiled designs.

    The canonical way to set knobs is ``config=``, a
    :class:`repro.flow.ServeConfig` (max_batch, max_wait_us,
    queue_depth, backpressure, buckets, shards, plus the resilience
    knobs: deadline_ms, fallback, breaker_*, supervise,
    restart_budget); this is what ``Flow.serve`` constructs.  The
    individual kwargs are a deprecated shim kept for one release
    (``overflow`` maps to ``backpressure``): they construct the
    equivalent config and delegate.

    ``register`` rejects duplicate model names loudly — replacing a
    model in place would silently mix two designs' results under one
    name.  Rolling a model forward is a *versioning* operation:
    ``repro.flow.Deployment.register(name, design, version=...)`` gives
    register-v2 / atomic-alias-flip / drain-v1 semantics on top of this
    engine.
    """

    def __init__(
        self,
        max_batch=UNSET,
        queue_depth=UNSET,
        max_wait_us=UNSET,
        buckets=UNSET,
        overflow=UNSET,
        config: ServeConfig | None = None,
    ):
        legacy = {
            name: val
            for name, val in (
                ("max_batch", max_batch),
                ("queue_depth", queue_depth),
                ("max_wait_us", max_wait_us),
                ("buckets", buckets),
                ("overflow", overflow),
            )
            if val is not UNSET
        }
        config = resolve_legacy(
            "ServeEngine", config, legacy, ServeConfig, _serve_config_from_legacy
        )
        self.config = config
        self.max_batch = config.max_batch
        self.queue_depth = config.queue_depth
        self.max_wait_us = config.max_wait_us
        self.buckets = config.buckets
        self.overflow = config.backpressure
        self.shards = config.shards
        self._runners: dict[str, _ModelRunner] = {}
        self._lock = threading.Lock()

    # -- registry ------------------------------------------------------
    def register(
        self,
        name: str,
        design: CompiledDesign | str | Path,
        warmup: bool = False,
    ) -> CompiledDesign:
        """Register a design (or load one from an artifact path)."""
        if not isinstance(design, CompiledDesign):
            design = load_design(design)
        runner = _ModelRunner(
            name, design, self.max_batch, self.queue_depth,
            self.max_wait_us, self.buckets, self.shards, config=self.config,
        )
        with self._lock:
            if name in self._runners:
                # never replace silently: two designs would be mixed under
                # one name.  Version rollout lives in flow.Deployment.
                raise ValueError(
                    f"model {name!r} already registered (roll a new version "
                    "via repro.flow.Deployment.register(..., version=))"
                )
            self._runners[name] = runner
        try:
            if warmup:
                runner.warmup()
            runner.start()
        except BaseException:  # failed warmup/start must not leave a dead entry
            with self._lock:
                self._runners.pop(name, None)
            raise
        return design

    def unregister(self, name: str, timeout: float = 5.0) -> None:
        """Drop a model after draining its queues (waiting up to
        ``timeout`` seconds for the dispatchers to finish; requests
        still queued after that are failed loudly, never left hanging)."""
        with self._lock:
            runner = self._runners.pop(name)
        runner.stop(timeout)

    def models(self) -> list[str]:
        with self._lock:
            return sorted(self._runners)

    def _runner(self, name: str) -> _ModelRunner:
        try:
            return self._runners[name]
        except KeyError:
            raise KeyError(f"model {name!r} is not registered") from None

    # -- serving -------------------------------------------------------
    def _validate(self, name: str, runner: _ModelRunner, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x)
        if x.shape != runner.in_shape:
            raise ValueError(
                f"model {name!r} expects one sample of shape {runner.in_shape}, "
                f"got {x.shape}"
            )
        if not np.issubdtype(x.dtype, np.integer):
            raise TypeError(
                f"model {name!r} expects integer-grid samples, got dtype "
                f"{x.dtype} (quantize floats with the design's in_quant first)"
            )
        return x

    def submit(self, name: str, x: np.ndarray, deadline_s: float | None = None) -> Future:
        """Enqueue one sample (integer grid, shape ``in_shape``).

        ``deadline_s`` (relative seconds; default
        ``ServeConfig.deadline_ms``) bounds how long the request may
        wait for dispatch — on expiry the Future fails with
        :class:`DeadlineExceededError` instead of executing dead work.

        May raise :class:`QueueFullError` (reject policy, queue at
        capacity), :class:`EngineClosedError` (the submit raced
        ``unregister``/``shutdown``; under a :class:`repro.flow.Deployment`
        rollout the deployment layer retries onto the new version),
        :class:`ShardCrashedError` (dispatch lane died mid-enqueue) or
        :class:`ModelUnhealthyError` (restart budget exhausted)."""
        runner = self._runner(name)
        x = self._validate(name, runner, x)
        t_submit = time.perf_counter()
        return runner.submit_one(
            x, t_submit, block=self.overflow != "reject",
            deadline=runner.deadline_abs(t_submit, deadline_s),
        )

    def submit_batch(self, name: str, xs, deadline_s: float | None = None) -> list[Future]:
        """Enqueue many samples at once; returns one Future per sample.

        Amortizes per-request overhead (registry lookup, validation,
        clock read, shard lock) across the batch — the high-throughput
        entrypoint for clients that already hold several requests.
        ``xs`` is an iterable of samples or an ``[n, *in_shape]`` array;
        chunks are spread across shards.  ``deadline_s`` applies to
        every sample in the batch (see ``submit``).

        Backpressure mirrors ``submit`` per sample, except that with the
        "reject" policy an overflowing sample's Future is *failed* with
        :class:`QueueFullError` (and counted) instead of raising, so one
        full queue cannot lose the whole batch; samples cut off by a
        racing shutdown are failed with :class:`EngineClosedError` (or
        :class:`ShardCrashedError` if the lane died).  Every returned
        Future resolves.
        """
        runner = self._runner(name)
        xs = [self._validate(name, runner, x) for x in xs]
        t_submit = time.perf_counter()
        return runner.submit_many(
            xs, t_submit, block=self.overflow != "reject",
            deadline=runner.deadline_abs(t_submit, deadline_s),
        )

    def infer(
        self,
        name: str,
        x: np.ndarray,
        timeout: float | None = 30.0,
        deadline_s: float | None = None,
    ):
        """Synchronous single-sample convenience wrapper.

        The client ``timeout`` is tied into the deadline path: unless a
        deadline is configured or passed explicitly, the request carries
        ``deadline_s=timeout``, so work abandoned by an expired
        ``result(timeout)`` is *shed* by the dispatcher instead of
        executed into a slab slot nobody is waiting on.  Client-side
        expiries are counted in ``stats()["n_client_timeouts"]``.
        """
        if deadline_s is None:
            dms = self.config.deadline_ms
            deadline_s = dms * 1e-3 if dms is not None else timeout
        fut = self.submit(name, x, deadline_s=deadline_s)
        try:
            return fut.result(timeout)
        except FutureTimeoutError:
            try:
                self._runner(name).count_client_timeout()
            except KeyError:
                pass  # model unregistered while we waited
            raise

    def warmup(self, name: str) -> float:
        return self._runner(name).warmup()

    def stats(self, name: str | None = None) -> dict:
        if name is not None:
            return self._runner(name).stats()
        with self._lock:
            runners = list(self._runners.items())
        return {n: r.stats() for n, r in runners}

    def metrics_text(self) -> str:
        """Prometheus text exposition (format 0.0.4) over every model.

        Families are derived from the live runners — request/batch/reject
        counters, per-shard queue-depth gauges, per-bucket hit counters,
        per-stage wall totals and µs histograms, latency-percentile
        gauges, and the resilience families (shed/fast-fail/fallback/
        client-timeout counters, breaker state and trip counts, restart
        counts, health gauge) — so scraping this endpoint and reading
        ``stats()`` can never disagree.  Process-wide solver/compiler
        counters live in ``repro.obs.metrics.get_registry()`` (exposed
        by ``benchmarks/run.py obs``), not here, to avoid double
        counting.
        """
        with self._lock:
            runners = list(self._runners.items())
        req, batches, rejected, qd, bucket, jit = [], [], [], [], [], []
        stage_tot, stage_hist, lat = [], [], []
        shed, fastf, fallb, ctime = [], [], [], []
        brk_state, brk_trips, restarts, healthy = [], [], [], []
        _BRK_STATE = {"closed": 0, "half_open": 1, "open": 2}
        for name, r in runners:
            s = r.stats()
            m = {"model": name}
            req.append((m, s["n_requests"]))
            batches.append((m, s["n_batches"]))
            rejected.append((m, s["n_rejected"]))
            jit.append((m, s["n_jit_compiles"]))
            shed.append((m, s["n_shed"]))
            fastf.append((m, s["n_fast_failed"]))
            fallb.append((m, s["n_fallback_batches"]))
            ctime.append((m, s["n_client_timeouts"]))
            brk_state.append((m, _BRK_STATE.get(s["breaker"]["state"], -1)))
            brk_trips.append((m, s["breaker"]["n_trips"]))
            restarts.append((m, s["supervision"]["n_restarts"]))
            healthy.append((m, int(s["supervision"]["healthy"])))
            for snap in s["shards"]:
                qd.append(
                    ({"model": name, "shard": snap["shard"]}, snap["queue_depth"])
                )
            for b, c in s["bucket_hits"].items():
                bucket.append(({"model": name, "bucket": b}, c))
            for st in StageAccumulator.STAGES:
                stage_tot.append(
                    ({"model": name, "stage": st}, s["per_stage"][st]["total_ms"] / 1e3)
                )
                stage_hist.append(
                    (
                        {"model": name, "stage": st},
                        Histogram.merged(sh.stage_hist[st] for sh in r.shards),
                    )
                )
            if s["n_latency_samples"]:
                for q in ("p50", "p99"):
                    lat.append(({"model": name, "quantile": q}, s[f"{q}_ms"]))
        families = [
            ("serve_requests_total", "counter", "requests completed", req),
            ("serve_batches_total", "counter", "batches dispatched", batches),
            ("serve_rejected_total", "counter",
             "requests rejected by backpressure", rejected),
            ("serve_shed_total", "counter",
             "requests shed on an expired deadline", shed),
            ("serve_fast_failed_total", "counter",
             "requests failed fast by an open circuit breaker", fastf),
            ("serve_fallback_batches_total", "counter",
             "batches served by the interpreter fallback", fallb),
            ("serve_client_timeouts_total", "counter",
             "infer() client-side result timeouts", ctime),
            ("serve_breaker_state", "gauge",
             "circuit breaker state (0=closed 1=half_open 2=open)", brk_state),
            ("serve_breaker_trips_total", "counter",
             "circuit breaker closed->open transitions", brk_trips),
            ("serve_restarts_total", "counter",
             "dispatcher threads restarted by supervision", restarts),
            ("serve_healthy", "gauge",
             "1 while the model serves, 0 once escalated unhealthy", healthy),
            ("serve_queue_depth", "gauge", "queued requests per shard", qd),
            ("serve_bucket_hits_total", "counter",
             "batches dispatched per bucket shape", bucket),
            ("serve_jit_compiled_buckets", "gauge",
             "bucket shapes jit-compiled so far", jit),
            ("serve_stage_seconds_total", "counter",
             "wall seconds charged per dispatch stage", stage_tot),
            ("serve_stage_us", "histogram",
             "per-stage wall microseconds per batch (queue_wait: per request)",
             stage_hist),
            ("serve_latency_ms", "gauge",
             "end-to-end latency percentiles", lat),
        ]
        return render_prometheus(families)

    # -- lifecycle -----------------------------------------------------
    def shutdown(self, timeout: float = 5.0) -> None:
        """Stop all dispatchers after draining their queues."""
        with self._lock:
            runners = list(self._runners.values())
            self._runners.clear()
        for r in runners:
            r.stop(timeout)

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
